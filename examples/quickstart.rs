//! Quickstart: compress and decompress a small synthetic HCCI dataset with
//! GBATC and verify the error bound.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use gbatc::compressor::{CompressOptions, GbatcCompressor};
use gbatc::config::Manifest;
use gbatc::data::{generate, Profile};
use gbatc::metrics;
use gbatc::runtime::ExecService;

fn main() -> gbatc::Result<()> {
    // 1. a dataset: 8 timesteps x 58 species x 40 x 40 (use `gen-data` or
    //    artifacts/dataset.bin for bigger ones)
    let ds = generate(Profile::Tiny, 42);
    println!(
        "dataset: {}x{}x{}x{} ({:.1} MB)",
        ds.nt,
        ds.ns,
        ds.ny,
        ds.nx,
        ds.pd_bytes() as f64 / 1e6
    );

    // 2. the AOT runtime (artifacts built once by `make artifacts`)
    let service = ExecService::start("artifacts", 4)?;
    let handle = service.handle();
    let manifest = Manifest::load("artifacts/manifest.txt")?;
    let compressor = GbatcCompressor::new(&handle, manifest.decoder_params, manifest.tcn_params);

    // 3. compress with a guaranteed per-species NRMSE of 1e-3
    let opts = CompressOptions {
        nrmse_target: 1e-3,
        ..Default::default()
    };
    let report = compressor.compress(&ds, &opts)?;
    println!(
        "compressed: CR {:.1} | every block residual <= tau ({:.3e} <= {:.3e})",
        report.archive.compression_ratio(),
        report.max_block_residual,
        report.tau
    );
    println!("  {}", report.breakdown);

    // 4. decompress and measure
    let recon = compressor.decompress(&report.archive, 0)?;
    let npix = ds.ny * ds.nx;
    let mut worst = (0usize, 0.0f64);
    let mut mean = 0.0;
    for s in 0..ds.ns {
        let mut o = Vec::with_capacity(ds.nt * npix);
        let mut r = Vec::with_capacity(ds.nt * npix);
        for t in 0..ds.nt {
            let off = (t * ds.ns + s) * npix;
            o.extend_from_slice(&ds.mass[off..off + npix]);
            r.extend_from_slice(&recon[off..off + npix]);
        }
        let e = metrics::nrmse(&o, &r);
        mean += e / ds.ns as f64;
        if e > worst.1 {
            worst = (s, e);
        }
    }
    println!(
        "decompressed: mean NRMSE {:.3e}, worst species {} at {:.3e}",
        mean,
        gbatc::chem::SPECIES[worst.0].name,
        worst.1
    );
    assert!(mean <= opts.nrmse_target * 1.05);
    println!("quickstart OK");
    Ok(())
}
