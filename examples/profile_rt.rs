//! Runtime profiling helper (§Perf): per-executable latency + throughput.
use std::time::Instant;
fn main() {
    let dir = std::env::var("GBATC_ARTIFACTS").unwrap_or("artifacts".into());
    let service = gbatc::runtime::ExecService::start(&dir, 4).unwrap();
    let h = service.handle();
    let spec = h.spec();
    let il = spec.instance_len();
    let blocks = vec![0.1f32; spec.batch * il];
    for _ in 0..2 { let _ = h.encode(blocks.clone(), spec.batch).unwrap(); }
    let t = Instant::now();
    for _ in 0..5 { let _ = h.encode(blocks.clone(), spec.batch).unwrap(); }
    println!("encode: {:.3}s/batch ({} blocks)", t.elapsed().as_secs_f64()/5.0, spec.batch);
    let z = vec![0.1f32; spec.batch * spec.latent];
    let t = Instant::now();
    for _ in 0..5 { let _ = h.decode(z.clone(), spec.batch).unwrap(); }
    println!("decode: {:.3}s/batch", t.elapsed().as_secs_f64()/5.0);
    let pts = vec![0.1f32; spec.points * spec.species];
    for _ in 0..2 { let _ = h.tcn(pts.clone(), spec.points).unwrap(); }
    let t = Instant::now();
    for _ in 0..5 { let _ = h.tcn(pts.clone(), spec.points).unwrap(); }
    let per = t.elapsed().as_secs_f64()/5.0;
    println!("tcn:    {:.3}s/batch ({} pts, {:.2} Mpts/s)", per, spec.points,
             spec.points as f64 / per / 1e6);
}
