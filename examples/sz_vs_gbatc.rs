//! Head-to-head: GBATC vs GBA vs SZ on the same dataset at matched error
//! targets — a compact version of the paper's Fig. 4 comparison, printed
//! as a table.
//!
//! ```bash
//! cargo run --release --example sz_vs_gbatc -- [profile] [seed]
//! ```

use gbatc::compressor::{
    CompressOptions, GbatcCompressor, SzCompressOptions, SzCompressor,
};
use gbatc::config::Manifest;
use gbatc::data::{generate, Profile};
use gbatc::metrics;
use gbatc::runtime::ExecService;

fn main() -> gbatc::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let profile = Profile::parse(args.first().map(|s| s.as_str()).unwrap_or("small"))
        .expect("profile: tiny|small|medium");
    let seed: u64 = args.get(1).map(|s| s.parse().unwrap()).unwrap_or(7);

    let ds = generate(profile, seed);
    println!(
        "dataset {:?} seed {seed}: {}x{}x{}x{} ({:.1} MB)\n",
        profile,
        ds.nt,
        ds.ns,
        ds.ny,
        ds.nx,
        ds.pd_bytes() as f64 / 1e6
    );

    let service = ExecService::start("artifacts", 4)?;
    let handle = service.handle();
    let manifest = Manifest::load("artifacts/manifest.txt")?;
    let comp = GbatcCompressor::new(&handle, manifest.decoder_params, manifest.tcn_params);
    let szc = SzCompressor::new(SzCompressOptions::default());

    let mean_nrmse = |recon: &[f32]| -> f64 {
        let npix = ds.ny * ds.nx;
        let mut mean = 0.0;
        for s in 0..ds.ns {
            let mut o = Vec::new();
            let mut r = Vec::new();
            for t in 0..ds.nt {
                let off = (t * ds.ns + s) * npix;
                o.extend_from_slice(&ds.mass[off..off + npix]);
                r.extend_from_slice(&recon[off..off + npix]);
            }
            mean += metrics::nrmse(&o, &r) / ds.ns as f64;
        }
        mean
    };

    println!(
        "{:<8} {:>10} {:>12} {:>12}",
        "method", "target", "CR", "mean NRMSE"
    );
    for target in [3e-3, 1e-3, 3e-4] {
        for (name, use_tcn) in [("GBATC", true), ("GBA", false)] {
            let opts = CompressOptions {
                nrmse_target: target,
                use_tcn,
                ..Default::default()
            };
            let report = comp.compress(&ds, &opts)?;
            let recon = comp.decompress(&report.archive, 0)?;
            println!(
                "{:<8} {:>10.0e} {:>12.1} {:>12.3e}",
                name,
                target,
                report.archive.compression_ratio(),
                mean_nrmse(&recon)
            );
        }
        let archive = szc.compress(&ds, target)?;
        let recon = szc.decompress(&archive)?;
        println!(
            "{:<8} {:>10.0e} {:>12.1} {:>12.3e}",
            "SZ",
            target,
            ds.pd_bytes() as f64 / archive.total_bytes() as f64,
            mean_nrmse(&recon)
        );
        println!();
    }
    Ok(())
}
