//! End-to-end driver — the full system on the real small workload shipped
//! in `artifacts/dataset.bin` (the python-generated HCCI field the AE was
//! trained on, like the paper compressing the S3D dataset it models):
//!
//!   load dataset -> GBATC compress (PJRT encoder, Huffman latents, TCN,
//!   Algorithm-1 guarantee) -> archive to disk -> decompress -> PD NRMSE /
//!   SSIM / PSNR per species -> QoI production-rate errors via the
//!   synthetic mechanism -> report, with SZ on the same data for contrast.
//!
//! Results are recorded in EXPERIMENTS.md.
//!
//! ```bash
//! make artifacts && cargo run --release --example end_to_end
//! ```

use gbatc::chem::{self, Mechanism};
use gbatc::compressor::{
    CompressOptions, GbatcCompressor, SzCompressOptions, SzCompressor,
};
use gbatc::config::Manifest;
use gbatc::data::{io, Dataset};
use gbatc::metrics;
use gbatc::runtime::ExecService;
use gbatc::util::Timer;

fn species_nrmse(orig: &Dataset, recon_mass: &[f32]) -> (Vec<f64>, f64) {
    let npix = orig.ny * orig.nx;
    let mut per = Vec::with_capacity(orig.ns);
    for s in 0..orig.ns {
        let mut o = Vec::with_capacity(orig.nt * npix);
        let mut r = Vec::with_capacity(orig.nt * npix);
        for t in 0..orig.nt {
            let off = (t * orig.ns + s) * npix;
            o.extend_from_slice(&orig.mass[off..off + npix]);
            r.extend_from_slice(&recon_mass[off..off + npix]);
        }
        per.push(metrics::nrmse(&o, &r));
    }
    let mean = per.iter().sum::<f64>() / per.len() as f64;
    (per, mean)
}

/// QoI NRMSE on a strided spatial sample (production rates are pointwise).
fn qoi_nrmse(orig: &Dataset, recon_mass: &[f32], stride: usize) -> (Vec<f64>, f64) {
    let mech = Mechanism::standard();
    let ns = orig.ns;
    let mut idxs = Vec::new();
    for t in 0..orig.nt {
        for y in (0..orig.ny).step_by(stride) {
            for x in (0..orig.nx).step_by(stride) {
                idxs.push((t, y, x));
            }
        }
    }
    let n = idxs.len();
    let mut ys_o = vec![0.0f32; ns * n];
    let mut ys_r = vec![0.0f32; ns * n];
    let mut temps = vec![0.0f32; n];
    for (i, &(t, y, x)) in idxs.iter().enumerate() {
        temps[i] = orig.temp_at(t, y, x);
        for s in 0..ns {
            let off = ((t * ns + s) * orig.ny + y) * orig.nx + x;
            ys_o[s * n + i] = orig.mass[off];
            ys_r[s * n + i] = recon_mass[off];
        }
    }
    let mut w_o = vec![0.0f64; ns * n];
    let mut w_r = vec![0.0f64; ns * n];
    chem::production_rates(&mech, &ys_o, &temps, orig.pressure, n, &mut w_o);
    chem::production_rates(&mech, &ys_r, &temps, orig.pressure, n, &mut w_r);
    metrics::nrmse::nrmse_per_species_f64(&w_o, &w_r, ns)
}

fn main() -> gbatc::Result<()> {
    let ds = io::read_dataset("artifacts/dataset.bin")?;
    println!(
        "== end-to-end GBATC on artifacts/dataset.bin: {}x{}x{}x{} ({:.1} MB)",
        ds.nt,
        ds.ns,
        ds.ny,
        ds.nx,
        ds.pd_bytes() as f64 / 1e6
    );

    let service = ExecService::start("artifacts", 4)?;
    let handle = service.handle();
    let manifest = Manifest::load("artifacts/manifest.txt")?;
    let comp = GbatcCompressor::new(&handle, manifest.decoder_params, manifest.tcn_params);

    let target = 1e-3;
    let opts = CompressOptions {
        nrmse_target: target,
        ..Default::default()
    };

    // --- GBATC ---
    let t = Timer::start();
    let report = comp.compress(&ds, &opts)?;
    let t_comp = t.secs();
    report.archive.write_file("/tmp/end_to_end.gba")?;
    let t = Timer::start();
    let recon = comp.decompress(&report.archive, 0)?;
    let t_dec = t.secs();

    let (per, mean) = species_nrmse(&ds, &recon);
    let (qoi_per, qoi_mean) = qoi_nrmse(&ds, &recon, 4);
    println!("GBATC @ target {target:.0e}:");
    println!(
        "  CR {:.1} | compress {:.1}s ({:.1} MB/s) | decompress {:.1}s",
        report.archive.compression_ratio(),
        t_comp,
        ds.pd_bytes() as f64 / 1e6 / t_comp,
        t_dec
    );
    println!("  {}", report.breakdown);
    println!(
        "  PD mean NRMSE {mean:.3e} (bound: every block ℓ2 <= {:.2e}) | QoI mean NRMSE {qoi_mean:.3e}",
        report.tau
    );
    for name in ["H2O", "CO", "C2H3", "nC3H7COCH2"] {
        let s = chem::index_of(name).unwrap();
        let a = ds.species_field(s);
        let mut r = vec![0.0f32; a.data.len()];
        let npix = ds.ny * ds.nx;
        for t in 0..ds.nt {
            let off = (t * ds.ns + s) * npix;
            r[t * npix..(t + 1) * npix].copy_from_slice(&recon[off..off + npix]);
        }
        let mid = ds.nt / 2;
        println!(
            "  {:>12}: NRMSE {:.2e} | PSNR {:>5.1} dB | SSIM(mid) {:.5} | QoI NRMSE {:.2e}",
            name,
            per[s],
            metrics::psnr(&a.data, &r),
            metrics::ssim2d(a.frame(mid), &r[mid * npix..(mid + 1) * npix], ds.ny, ds.nx),
            qoi_per[s],
        );
    }

    // --- SZ on the same data ---
    let szc = SzCompressor::new(SzCompressOptions::default());
    let t = Timer::start();
    let sz_archive = szc.compress(&ds, target)?;
    let sz_comp = t.secs();
    let sz_recon = szc.decompress(&sz_archive)?;
    let (_, sz_mean) = species_nrmse(&ds, &sz_recon);
    let (_, sz_qoi) = qoi_nrmse(&ds, &sz_recon, 4);
    println!("SZ   @ target {target:.0e}:");
    println!(
        "  CR {:.1} | compress {:.1}s | PD mean NRMSE {:.3e} | QoI mean NRMSE {:.3e}",
        ds.pd_bytes() as f64 / sz_archive.total_bytes() as f64,
        sz_comp,
        sz_mean,
        sz_qoi
    );

    assert!(mean <= target * 1.05, "GBATC exceeded target");
    println!("end_to_end OK");
    Ok(())
}
