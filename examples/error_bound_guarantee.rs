//! Demonstrates Algorithm 1's guarantee: for a sweep of error targets, the
//! archive certifies that EVERY spatiotemporal block of EVERY species
//! satisfies ‖x − x^G‖₂ ≤ τ after decompression — not just on average —
//! and verifies it independently on the decompressed output.
//!
//! ```bash
//! cargo run --release --example error_bound_guarantee
//! ```

use gbatc::compressor::{CompressOptions, GbatcCompressor};
use gbatc::config::Manifest;
use gbatc::data::blocks::{BlockGrid, BlockShape};
use gbatc::data::{generate, Profile};
use gbatc::runtime::ExecService;

fn main() -> gbatc::Result<()> {
    let ds = generate(Profile::Tiny, 11);
    let service = ExecService::start("artifacts", 4)?;
    let handle = service.handle();
    let manifest = Manifest::load("artifacts/manifest.txt")?;
    let comp = GbatcCompressor::new(&handle, manifest.decoder_params, manifest.tcn_params);

    println!(
        "{:>10} {:>12} {:>14} {:>14} {:>10}",
        "target", "tau", "max block l2", "blocks>tau", "CR"
    );
    for target in [1e-2, 3e-3, 1e-3, 3e-4] {
        let opts = CompressOptions {
            nrmse_target: target,
            ..Default::default()
        };
        let report = comp.compress(&ds, &opts)?;
        let recon = comp.decompress(&report.archive, 0)?;

        // independent verification on the decompressed data, block by block
        let grid = BlockGrid::for_dataset(&ds, BlockShape::default())?;
        let ranges = ds.species_ranges();
        let d = grid.shape.d();
        let mut worst = 0.0f64;
        let mut violations = 0usize;
        let mut ov = vec![0.0f32; d];
        let mut rv = vec![0.0f32; d];
        for b in 0..grid.n_blocks() {
            for s in 0..ds.ns {
                grid.gather_species(&ds.mass, b, s, &mut ov);
                grid.gather_species(&recon, b, s, &mut rv);
                let range = (ranges[s].1 - ranges[s].0).max(1e-30) as f64;
                let l2: f64 = ov
                    .iter()
                    .zip(&rv)
                    .map(|(&a, &bb)| {
                        let e = (a - bb) as f64 / range; // normalized units
                        e * e
                    })
                    .sum::<f64>()
                    .sqrt();
                worst = worst.max(l2);
                // small fp slack: the guarantee is certified in f32 math
                if l2 > report.tau * (1.0 + 1e-5) + 1e-9 {
                    violations += 1;
                }
            }
        }
        println!(
            "{:>10.0e} {:>12.3e} {:>14.3e} {:>14} {:>10.1}",
            target,
            report.tau,
            worst,
            violations,
            report.archive.compression_ratio()
        );
        assert_eq!(violations, 0, "guarantee violated!");
    }
    println!("\nevery block of every species within tau at every target — guarantee holds");
    Ok(())
}
