//! HLO-text loading (the AOT interchange format — see DESIGN.md §2:
//! serialized protos from jax >= 0.5 carry 64-bit instruction ids that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids).

use std::path::Path;

use crate::error::{Error, Result};

/// Load an HLO-text file into an `XlaComputation`.
pub fn load_computation<P: AsRef<Path>>(path: P) -> Result<xla::XlaComputation> {
    let path = path.as_ref();
    if !path.exists() {
        return Err(Error::runtime(format!(
            "artifact {} not found — run `make artifacts` first",
            path.display()
        )));
    }
    let proto = xla::HloModuleProto::from_text_file(
        path.to_str()
            .ok_or_else(|| Error::runtime("non-utf8 artifact path"))?,
    )?;
    Ok(xla::XlaComputation::from_proto(&proto))
}
