//! Deterministic pure-Rust execution backend.
//!
//! Used whenever the `pjrt` feature (the vendored `xla` crate) is absent:
//! a segment-pooling autoencoder whose encode averages `instance_len/latent`
//! contiguous segments of the `[S, kt, by, bx]` instance and whose decode
//! broadcasts each latent back over its segment, plus an identity TCN.
//!
//! This is a weak model on purpose — Algorithm 1 certifies the per-block
//! error bound against whatever the decoder produces, so the *guarantees*
//! of the system (and every archive/pipeline/shard code path) are exactly
//! as testable as with the trained PJRT artifacts; only the compression
//! ratio suffers.  It is also what `ExecService::start_reference` uses so
//! tests, benches, and the CLI `--reference` flag run in the offline image.

use crate::error::{Error, Result};
use crate::runtime::executor::RuntimeSpec;

/// Pure-Rust stand-in for the compiled encoder/decoder/TCN executables.
pub struct ReferenceRuntime {
    spec: RuntimeSpec,
}

impl ReferenceRuntime {
    pub fn new(spec: RuntimeSpec) -> Result<ReferenceRuntime> {
        if spec.species == 0 || spec.latent == 0 || spec.batch == 0 || spec.points == 0 {
            return Err(Error::config(format!(
                "reference runtime: degenerate spec {spec:?}"
            )));
        }
        if spec.block.0 == 0 || spec.block.1 == 0 || spec.block.2 == 0 {
            return Err(Error::config(format!(
                "reference runtime: degenerate block {:?}",
                spec.block
            )));
        }
        Ok(ReferenceRuntime { spec })
    }

    pub fn from_manifest(m: &crate::config::Manifest) -> Result<ReferenceRuntime> {
        Self::new(RuntimeSpec::from_manifest(m))
    }

    pub fn spec(&self) -> RuntimeSpec {
        self.spec
    }

    /// Segment `j` of an instance: `[j*il/L, (j+1)*il/L)`.
    #[inline]
    fn segment(&self, j: usize) -> (usize, usize) {
        let il = self.spec.instance_len();
        let l = self.spec.latent;
        (j * il / l, (j + 1) * il / l)
    }

    /// Encode `n` instances `[n, S, kt, by, bx]` to `[n, latent]` by
    /// segment-averaging.
    pub fn encode(&self, blocks: &[f32], n: usize) -> Result<Vec<f32>> {
        let s = &self.spec;
        let il = s.instance_len();
        if blocks.len() != n * il || n > s.batch {
            return Err(Error::shape(format!(
                "reference encode: {} values for {} instances (batch {})",
                blocks.len(),
                n,
                s.batch
            )));
        }
        let mut out = vec![0.0f32; n * s.latent];
        for k in 0..n {
            let inst = &blocks[k * il..(k + 1) * il];
            for j in 0..s.latent {
                let (lo, hi) = self.segment(j);
                if hi > lo {
                    let sum: f64 = inst[lo..hi].iter().map(|&v| v as f64).sum();
                    out[k * s.latent + j] = (sum / (hi - lo) as f64) as f32;
                }
            }
        }
        Ok(out)
    }

    /// Decode `n` latents `[n, latent]` to `[n, S, kt, by, bx]` by
    /// broadcasting each latent over its segment.
    pub fn decode(&self, latents: &[f32], n: usize) -> Result<Vec<f32>> {
        let s = &self.spec;
        let il = s.instance_len();
        if latents.len() != n * s.latent || n > s.batch {
            return Err(Error::shape(format!(
                "reference decode: {} values for {} instances (batch {})",
                latents.len(),
                n,
                s.batch
            )));
        }
        let mut out = vec![0.0f32; n * il];
        for k in 0..n {
            let inst = &mut out[k * il..(k + 1) * il];
            for j in 0..s.latent {
                let (lo, hi) = self.segment(j);
                let v = latents[k * s.latent + j];
                for o in &mut inst[lo..hi] {
                    *o = v;
                }
            }
        }
        Ok(out)
    }

    /// Identity tensor-correction: `[n, S]` -> `[n, S]` unchanged.
    pub fn tcn(&self, pts: &[f32], n: usize) -> Result<Vec<f32>> {
        let s = &self.spec;
        if pts.len() != n * s.species || n > s.points {
            return Err(Error::shape(format!(
                "reference tcn: {} values for {} points (cap {})",
                pts.len(),
                n,
                s.points
            )));
        }
        Ok(pts.to_vec())
    }
}

impl RuntimeSpec {
    /// The spec the offline CLI (`--reference`) and tests use when no AOT
    /// manifest exists: the paper's 58-species 4x5x4 block, latent 36.
    pub fn reference_default() -> RuntimeSpec {
        RuntimeSpec {
            species: crate::chem::species::NS,
            block: (4, 5, 4),
            latent: 36,
            batch: 64,
            points: 4096,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> RuntimeSpec {
        RuntimeSpec {
            species: 3,
            block: (2, 2, 2),
            latent: 4,
            batch: 8,
            points: 16,
        }
    }

    #[test]
    fn segments_partition_instance() {
        let rt = ReferenceRuntime::new(spec()).unwrap();
        let il = spec().instance_len();
        let mut covered = vec![0usize; il];
        for j in 0..spec().latent {
            let (lo, hi) = rt.segment(j);
            for c in &mut covered[lo..hi] {
                *c += 1;
            }
        }
        assert!(covered.iter().all(|&c| c == 1), "{covered:?}");
    }

    #[test]
    fn encode_decode_shapes_and_determinism() {
        let rt = ReferenceRuntime::new(spec()).unwrap();
        let il = spec().instance_len();
        let blocks: Vec<f32> = (0..2 * il).map(|i| (i % 13) as f32 * 0.1).collect();
        let z1 = rt.encode(&blocks, 2).unwrap();
        let z2 = rt.encode(&blocks, 2).unwrap();
        assert_eq!(z1, z2);
        assert_eq!(z1.len(), 2 * spec().latent);
        let x = rt.decode(&z1, 2).unwrap();
        assert_eq!(x.len(), 2 * il);
        // constant instance reconstructs exactly
        let c = vec![0.25f32; il];
        let z = rt.encode(&c, 1).unwrap();
        let xc = rt.decode(&z, 1).unwrap();
        for v in xc {
            assert!((v - 0.25).abs() < 1e-6);
        }
    }

    #[test]
    fn tcn_is_identity() {
        let rt = ReferenceRuntime::new(spec()).unwrap();
        let pts: Vec<f32> = (0..12).map(|i| i as f32).collect();
        assert_eq!(rt.tcn(&pts, 4).unwrap(), pts);
    }

    #[test]
    fn bad_shapes_are_errors() {
        let rt = ReferenceRuntime::new(spec()).unwrap();
        assert!(rt.encode(&[0.0; 3], 1).is_err());
        assert!(rt.decode(&[0.0; 3], 1).is_err());
        assert!(rt.tcn(&[0.0; 5], 1).is_err());
    }
}
