//! Compiled model executables (encoder / decoder / TCN) with fixed AOT
//! batch shapes and tail padding.
//!
//! [`RuntimeSpec`] is backend-neutral; the PJRT-backed [`ModelRuntime`]
//! only exists under the `pjrt` feature (the offline image has no `xla`
//! crate — see [`crate::runtime::reference`] for the default backend).

#[cfg(feature = "pjrt")]
use std::path::Path;

use crate::config::Manifest;
#[cfg(feature = "pjrt")]
use crate::error::Error;
#[cfg(feature = "pjrt")]
use crate::error::Result;
#[cfg(feature = "pjrt")]
use crate::runtime::client::load_computation;

/// Shapes baked into the AOT artifacts (from `manifest.txt`).
#[derive(Clone, Copy, Debug)]
pub struct RuntimeSpec {
    pub species: usize,
    pub block: (usize, usize, usize),
    pub latent: usize,
    /// encoder/decoder batch (blocks per execution)
    pub batch: usize,
    /// TCN batch (points per execution)
    pub points: usize,
}

impl RuntimeSpec {
    pub fn from_manifest(m: &Manifest) -> RuntimeSpec {
        RuntimeSpec {
            species: m.species,
            block: (m.block_t, m.block_y, m.block_x),
            latent: m.latent,
            batch: m.encoder_batch,
            points: m.tcn_points,
        }
    }

    pub fn block_len(&self) -> usize {
        self.block.0 * self.block.1 * self.block.2
    }

    pub fn instance_len(&self) -> usize {
        self.species * self.block_len()
    }
}

/// The three compiled executables plus the PJRT client that owns them.
/// `!Send` — lives on the executor-service thread (see `pool`).
#[cfg(feature = "pjrt")]
pub struct ModelRuntime {
    pub spec: RuntimeSpec,
    client: xla::PjRtClient,
    encoder: xla::PjRtLoadedExecutable,
    decoder: xla::PjRtLoadedExecutable,
    tcn: Option<xla::PjRtLoadedExecutable>,
    // trained weights, fed as trailing arguments on every execution (HLO
    // text elides large constants, so aot.py exports weights separately)
    encoder_params: Vec<xla::Literal>,
    decoder_params: Vec<xla::Literal>,
    tcn_params: Vec<xla::Literal>,
}

#[cfg(feature = "pjrt")]
fn literal_f32(data: &[f32], dims: &[usize]) -> xla::Literal {
    let n: usize = dims.iter().product();
    debug_assert_eq!(data.len(), n);
    // SAFETY: reinterpreting a live `&[f32]` as its own bytes — same
    // allocation, `len * 4` bytes, and u8 has no alignment requirement.
    let bytes =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, dims, bytes)
        .expect("literal creation")
}

/// Load a `GBPR` params sidecar written by `aot.py::write_params_sidecar`:
/// magic, u32 count, then per tensor: u32 name_len, name, u32 ndim,
/// u32 dims..., f32 data — in the argument order the HLO expects.
#[cfg(feature = "pjrt")]
fn load_params_sidecar(path: &Path) -> Result<Vec<xla::Literal>> {
    let bytes = std::fs::read(path).map_err(|e| {
        Error::runtime(format!("params sidecar {}: {e}", path.display()))
    })?;
    let mut pos = 0usize;
    let take = |pos: &mut usize, n: usize| -> Result<&[u8]> {
        let s = bytes
            .get(*pos..*pos + n)
            .ok_or_else(|| Error::runtime(format!("truncated sidecar {}", path.display())))?;
        *pos += n;
        Ok(s)
    };
    if take(&mut pos, 4)? != b"GBPR" {
        return Err(Error::runtime(format!("bad sidecar magic in {}", path.display())));
    }
    let rd_u32 = |pos: &mut usize| -> Result<u32> {
        Ok(u32::from_le_bytes(take(pos, 4)?.try_into().unwrap()))
    };
    let count = rd_u32(&mut pos)? as usize;
    let mut literals = Vec::with_capacity(count);
    for _ in 0..count {
        let name_len = rd_u32(&mut pos)? as usize;
        let _name = take(&mut pos, name_len)?;
        let ndim = rd_u32(&mut pos)? as usize;
        let mut dims = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            dims.push(rd_u32(&mut pos)? as usize);
        }
        let n: usize = dims.iter().product();
        let raw = take(&mut pos, n * 4)?;
        let lit = xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::F32,
            &dims,
            raw,
        )?;
        literals.push(lit);
    }
    Ok(literals)
}

#[cfg(feature = "pjrt")]
impl ModelRuntime {
    /// Load and compile all artifacts from a directory.
    pub fn load<P: AsRef<Path>>(dir: P) -> Result<ModelRuntime> {
        let dir = dir.as_ref();
        let manifest = Manifest::load(dir.join("manifest.txt"))?;
        let spec = RuntimeSpec::from_manifest(&manifest);
        let client = xla::PjRtClient::cpu()?;
        let encoder = client.compile(&load_computation(dir.join("encoder.hlo.txt"))?)?;
        let decoder = client.compile(&load_computation(dir.join("decoder.hlo.txt"))?)?;
        let encoder_params = load_params_sidecar(&dir.join("encoder.params"))?;
        let decoder_params = load_params_sidecar(&dir.join("decoder.params"))?;
        let tcn_path = dir.join("tcn.hlo.txt");
        let (tcn, tcn_params) = if tcn_path.exists() {
            (
                Some(client.compile(&load_computation(tcn_path)?)?),
                load_params_sidecar(&dir.join("tcn.params"))?,
            )
        } else {
            (None, Vec::new())
        };
        Ok(ModelRuntime {
            spec,
            client,
            encoder,
            decoder,
            tcn,
            encoder_params,
            decoder_params,
            tcn_params,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn has_tcn(&self) -> bool {
        self.tcn.is_some()
    }

    fn run(
        exe: &xla::PjRtLoadedExecutable,
        params: &[xla::Literal],
        input: &[f32],
        in_dims: &[usize],
        out_len: usize,
    ) -> Result<Vec<f32>> {
        let lit = literal_f32(input, in_dims);
        // argument order: data batch first, then trained weights (the order
        // aot.py lowered them in)
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(1 + params.len());
        args.push(&lit);
        args.extend(params.iter());
        let result = exe.execute::<&xla::Literal>(&args)?;
        let out = result[0][0].to_literal_sync()?.to_tuple1()?;
        let v = out.to_vec::<f32>()?;
        if v.len() != out_len {
            return Err(Error::runtime(format!(
                "unexpected output length {} != {}",
                v.len(),
                out_len
            )));
        }
        Ok(v)
    }

    /// Encode up to `batch` blocks: `blocks` is `[n, S, kt, by, bx]`
    /// row-major with n <= batch; returns `[n, latent]`.
    pub fn encode(&self, blocks: &[f32], n: usize) -> Result<Vec<f32>> {
        let s = &self.spec;
        let il = s.instance_len();
        assert_eq!(blocks.len(), n * il);
        assert!(n <= s.batch, "{n} > batch {}", s.batch);
        let mut padded;
        let input = if n == s.batch {
            blocks
        } else {
            padded = vec![0.0f32; s.batch * il];
            padded[..n * il].copy_from_slice(blocks);
            &padded[..]
        };
        let dims = [s.batch, s.species, s.block.0, s.block.1, s.block.2];
        let out = Self::run(&self.encoder, &self.encoder_params, input, &dims, s.batch * s.latent)?;
        Ok(out[..n * s.latent].to_vec())
    }

    /// Decode up to `batch` latents: `[n, latent]` -> `[n, S, kt, by, bx]`.
    pub fn decode(&self, latents: &[f32], n: usize) -> Result<Vec<f32>> {
        let s = &self.spec;
        assert_eq!(latents.len(), n * s.latent);
        assert!(n <= s.batch);
        let mut padded;
        let input = if n == s.batch {
            latents
        } else {
            padded = vec![0.0f32; s.batch * s.latent];
            padded[..n * s.latent].copy_from_slice(latents);
            &padded[..]
        };
        let out = Self::run(
            &self.decoder,
            &self.decoder_params,
            input,
            &[s.batch, s.latent],
            s.batch * s.instance_len(),
        )?;
        Ok(out[..n * s.instance_len()].to_vec())
    }

    /// Tensor-correct up to `points` species vectors: `[n, S]` -> `[n, S]`.
    pub fn tcn(&self, pts: &[f32], n: usize) -> Result<Vec<f32>> {
        let s = &self.spec;
        let tcn = self
            .tcn
            .as_ref()
            .ok_or_else(|| Error::runtime("tcn artifact not loaded"))?;
        assert_eq!(pts.len(), n * s.species);
        assert!(n <= s.points);
        let mut padded;
        let input = if n == s.points {
            pts
        } else {
            padded = vec![0.0f32; s.points * s.species];
            padded[..n * s.species].copy_from_slice(pts);
            &padded[..]
        };
        let out = Self::run(
            tcn,
            &self.tcn_params,
            input,
            &[s.points, s.species],
            s.points * s.species,
        )?;
        Ok(out[..n * s.species].to_vec())
    }
}
