//! Execution runtime: serves encoder/decoder/TCN requests to the rest of
//! the system through the [`ExecHandle`] service interface.
//!
//! Two backends stand behind the same service:
//! * **PJRT** (`pjrt` feature): loads the AOT HLO-text artifacts produced
//!   by `python/compile/aot.py` and executes them via the `xla` crate.  The
//!   PJRT handles are `!Send` (raw pointers), so the runtime lives on a
//!   dedicated executor-service thread ([`pool::ExecService`]); worker
//!   threads talk to it through bounded channels.  XLA CPU parallelizes
//!   each execution internally, so one service thread saturates the machine
//!   for our batch sizes.
//! * **Reference** (default): a deterministic pure-Rust pooling
//!   autoencoder ([`reference::ReferenceRuntime`]) — weak compression, but
//!   Algorithm 1 certifies identical error bounds, so every request-path
//!   code path runs (and is tested) in the offline image.

#[cfg(feature = "pjrt")]
pub mod client;
pub mod executor;
pub mod pool;
pub mod reference;

#[cfg(feature = "pjrt")]
pub use client::load_computation;
#[cfg(feature = "pjrt")]
pub use executor::ModelRuntime;
pub use executor::RuntimeSpec;
pub use pool::{ExecHandle, ExecService};
pub use reference::ReferenceRuntime;
