//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the request path.
//!
//! The `xla` crate's PJRT handles are `!Send` (raw pointers), so the
//! runtime lives on a dedicated executor-service thread
//! ([`pool::ExecService`]); worker threads talk to it through bounded
//! channels.  XLA CPU parallelizes each execution internally, so one
//! service thread saturates the machine for our batch sizes.

pub mod client;
pub mod executor;
pub mod pool;

pub use client::load_computation;
pub use executor::{ModelRuntime, RuntimeSpec};
pub use pool::{ExecHandle, ExecService};
