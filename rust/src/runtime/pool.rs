//! Executor service: a dedicated thread owning the execution backend,
//! serving encode/decode/TCN requests over bounded channels.  Worker
//! threads hold cloneable [`ExecHandle`]s; requests are processed FIFO,
//! giving natural backpressure (the channel bound).
//!
//! The backend is either the PJRT runtime (`pjrt` feature; `!Send`, hence
//! constructed *inside* the service thread) or the pure-Rust
//! [`ReferenceRuntime`].  Shard workers from the coordinator engine all
//! funnel into the same service, which serializes accelerator access.

use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::thread::JoinHandle;

#[cfg(not(feature = "pjrt"))]
use crate::config::Manifest;
use crate::error::{Error, Result};
use crate::runtime::executor::RuntimeSpec;
use crate::runtime::reference::ReferenceRuntime;

enum Request {
    Encode {
        data: Vec<f32>,
        n: usize,
        reply: SyncSender<Result<Vec<f32>>>,
    },
    Decode {
        data: Vec<f32>,
        n: usize,
        reply: SyncSender<Result<Vec<f32>>>,
    },
    Tcn {
        data: Vec<f32>,
        n: usize,
        reply: SyncSender<Result<Vec<f32>>>,
    },
}

/// The execution backend living on the service thread.
enum Backend {
    Reference(ReferenceRuntime),
    #[cfg(feature = "pjrt")]
    Pjrt(crate::runtime::executor::ModelRuntime),
}

impl Backend {
    fn encode(&self, data: &[f32], n: usize) -> Result<Vec<f32>> {
        match self {
            Backend::Reference(rt) => rt.encode(data, n),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(rt) => rt.encode(data, n),
        }
    }

    fn decode(&self, data: &[f32], n: usize) -> Result<Vec<f32>> {
        match self {
            Backend::Reference(rt) => rt.decode(data, n),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(rt) => rt.decode(data, n),
        }
    }

    fn tcn(&self, data: &[f32], n: usize) -> Result<Vec<f32>> {
        match self {
            Backend::Reference(rt) => rt.tcn(data, n),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(rt) => rt.tcn(data, n),
        }
    }

    fn spec(&self) -> RuntimeSpec {
        match self {
            Backend::Reference(rt) => rt.spec(),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(rt) => rt.spec,
        }
    }

    fn has_tcn(&self) -> bool {
        match self {
            Backend::Reference(_) => true,
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(rt) => rt.has_tcn(),
        }
    }
}

/// Build the artifact-directory backend: PJRT when the `pjrt` feature is
/// on, otherwise a reference runtime shaped by the manifest.
#[cfg(feature = "pjrt")]
fn make_artifact_backend(dir: &str) -> Result<Backend> {
    Ok(Backend::Pjrt(crate::runtime::executor::ModelRuntime::load(
        dir,
    )?))
}

#[cfg(not(feature = "pjrt"))]
fn make_artifact_backend(dir: &str) -> Result<Backend> {
    let manifest = Manifest::load(format!("{dir}/manifest.txt"))?;
    Ok(Backend::Reference(ReferenceRuntime::from_manifest(
        &manifest,
    )?))
}

/// Cloneable handle to the executor service.
#[derive(Clone)]
pub struct ExecHandle {
    tx: SyncSender<Request>,
    spec: RuntimeSpec,
    has_tcn: bool,
}

/// The service: join handle + the original request sender.
pub struct ExecService {
    handle: ExecHandle,
    join: Option<JoinHandle<()>>,
}

impl ExecService {
    /// Spawn the service thread, loading artifacts from `dir`.  With the
    /// `pjrt` feature this compiles the AOT artifacts; without it, the
    /// manifest alone seeds a [`ReferenceRuntime`] with the same shapes.
    pub fn start(dir: &str, queue_depth: usize) -> Result<ExecService> {
        let dir = dir.to_string();
        Self::spawn(queue_depth, move || make_artifact_backend(&dir))
    }

    /// Spawn a service backed by the pure-Rust reference runtime with an
    /// explicit spec — no artifacts or manifest needed (offline tests,
    /// benches, and the CLI `--reference` flag).
    pub fn start_reference(spec: RuntimeSpec, queue_depth: usize) -> Result<ExecService> {
        Self::spawn(queue_depth, move || {
            Ok(Backend::Reference(ReferenceRuntime::new(spec)?))
        })
    }

    fn spawn<F>(queue_depth: usize, make: F) -> Result<ExecService>
    where
        F: FnOnce() -> Result<Backend> + Send + 'static,
    {
        let (tx, rx) = sync_channel::<Request>(queue_depth.max(1));
        let (spec_tx, spec_rx) = sync_channel::<Result<(RuntimeSpec, bool)>>(1);
        let join = std::thread::Builder::new()
            .name("gbatc-exec".into())
            .spawn(move || {
                // the backend may be !Send (PJRT), so build it here
                let backend = match make() {
                    Ok(b) => {
                        let _ = spec_tx.send(Ok((b.spec(), b.has_tcn())));
                        b
                    }
                    Err(e) => {
                        let _ = spec_tx.send(Err(e));
                        return;
                    }
                };
                Self::serve(backend, rx);
            })
            .map_err(|e| Error::runtime(format!("spawn exec thread: {e}")))?;
        let (spec, has_tcn) = spec_rx
            .recv()
            .map_err(|_| Error::runtime("exec thread died during startup"))??;
        Ok(ExecService {
            handle: ExecHandle { tx, spec, has_tcn },
            join: Some(join),
        })
    }

    fn serve(backend: Backend, rx: Receiver<Request>) {
        while let Ok(req) = rx.recv() {
            match req {
                Request::Encode { data, n, reply } => {
                    let _ = reply.send(backend.encode(&data, n));
                }
                Request::Decode { data, n, reply } => {
                    let _ = reply.send(backend.decode(&data, n));
                }
                Request::Tcn { data, n, reply } => {
                    let _ = reply.send(backend.tcn(&data, n));
                }
            }
        }
    }

    pub fn handle(&self) -> ExecHandle {
        self.handle.clone()
    }

    pub fn spec(&self) -> RuntimeSpec {
        self.handle.spec
    }
}

impl Drop for ExecService {
    fn drop(&mut self) {
        // The service thread exits once every ExecHandle (sender clone) is
        // gone; joining here would deadlock while callers still hold
        // handles, so the thread is detached instead.
        let _ = self.join.take();
    }
}

impl ExecHandle {
    pub fn spec(&self) -> RuntimeSpec {
        self.spec
    }

    pub fn has_tcn(&self) -> bool {
        self.has_tcn
    }

    fn roundtrip(
        &self,
        make: impl FnOnce(SyncSender<Result<Vec<f32>>>) -> Request,
    ) -> Result<Vec<f32>> {
        let (reply_tx, reply_rx) = sync_channel(1);
        self.tx
            .send(make(reply_tx))
            .map_err(|_| Error::runtime("exec service is down"))?;
        reply_rx
            .recv()
            .map_err(|_| Error::runtime("exec service dropped reply"))?
    }

    /// Encode `n` blocks (`[n, S, kt, by, bx]` f32) to `[n, latent]`.
    pub fn encode(&self, data: Vec<f32>, n: usize) -> Result<Vec<f32>> {
        self.roundtrip(|reply| Request::Encode { data, n, reply })
    }

    /// Decode `n` latents to `[n, S, kt, by, bx]`.
    pub fn decode(&self, data: Vec<f32>, n: usize) -> Result<Vec<f32>> {
        self.roundtrip(|reply| Request::Decode { data, n, reply })
    }

    /// Tensor-correct `n` species vectors `[n, S]`.
    pub fn tcn(&self, data: Vec<f32>, n: usize) -> Result<Vec<f32>> {
        self.roundtrip(|reply| Request::Tcn { data, n, reply })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reference_service_roundtrips() {
        let spec = RuntimeSpec {
            species: 2,
            block: (2, 2, 2),
            latent: 4,
            batch: 8,
            points: 32,
        };
        let svc = ExecService::start_reference(spec, 2).unwrap();
        let h = svc.handle();
        assert_eq!(h.spec().latent, 4);
        assert!(h.has_tcn());
        let il = spec.instance_len();
        let blocks = vec![0.5f32; 3 * il];
        let z = h.encode(blocks, 3).unwrap();
        assert_eq!(z.len(), 3 * 4);
        let x = h.decode(z, 3).unwrap();
        assert_eq!(x.len(), 3 * il);
        let pts = vec![1.0f32; 5 * 2];
        assert_eq!(h.tcn(pts.clone(), 5).unwrap(), pts);
    }

    #[test]
    fn degenerate_spec_is_clean_error() {
        let spec = RuntimeSpec {
            species: 0,
            block: (2, 2, 2),
            latent: 4,
            batch: 8,
            points: 32,
        };
        assert!(ExecService::start_reference(spec, 2).is_err());
    }
}
