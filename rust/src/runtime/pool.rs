//! Executor service: a dedicated thread owning the (!Send) PJRT runtime,
//! serving encode/decode/TCN requests over bounded channels.  Worker
//! threads hold cloneable [`ExecHandle`]s; requests are processed FIFO,
//! giving natural backpressure (the channel bound) while XLA parallelizes
//! each execution internally.

use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::thread::JoinHandle;

use crate::error::{Error, Result};
use crate::runtime::executor::{ModelRuntime, RuntimeSpec};

enum Request {
    Encode {
        data: Vec<f32>,
        n: usize,
        reply: SyncSender<Result<Vec<f32>>>,
    },
    Decode {
        data: Vec<f32>,
        n: usize,
        reply: SyncSender<Result<Vec<f32>>>,
    },
    Tcn {
        data: Vec<f32>,
        n: usize,
        reply: SyncSender<Result<Vec<f32>>>,
    },
}

/// Cloneable handle to the executor service.
#[derive(Clone)]
pub struct ExecHandle {
    tx: SyncSender<Request>,
    spec: RuntimeSpec,
    has_tcn: bool,
}

/// The service: join handle + the original request sender.
pub struct ExecService {
    handle: ExecHandle,
    join: Option<JoinHandle<()>>,
}

impl ExecService {
    /// Spawn the service thread, loading artifacts from `dir`.
    pub fn start(dir: &str, queue_depth: usize) -> Result<ExecService> {
        let (tx, rx) = sync_channel::<Request>(queue_depth.max(1));
        let (spec_tx, spec_rx) = sync_channel::<Result<(RuntimeSpec, bool)>>(1);
        let dir = dir.to_string();
        let join = std::thread::Builder::new()
            .name("gbatc-exec".into())
            .spawn(move || {
                let runtime = match ModelRuntime::load(&dir) {
                    Ok(rt) => {
                        let _ = spec_tx.send(Ok((rt.spec, rt.has_tcn())));
                        rt
                    }
                    Err(e) => {
                        let _ = spec_tx.send(Err(e));
                        return;
                    }
                };
                Self::serve(runtime, rx);
            })
            .map_err(|e| Error::runtime(format!("spawn exec thread: {e}")))?;
        let (spec, has_tcn) = spec_rx
            .recv()
            .map_err(|_| Error::runtime("exec thread died during startup"))??;
        Ok(ExecService {
            handle: ExecHandle { tx, spec, has_tcn },
            join: Some(join),
        })
    }

    fn serve(runtime: ModelRuntime, rx: Receiver<Request>) {
        while let Ok(req) = rx.recv() {
            match req {
                Request::Encode { data, n, reply } => {
                    let _ = reply.send(runtime.encode(&data, n));
                }
                Request::Decode { data, n, reply } => {
                    let _ = reply.send(runtime.decode(&data, n));
                }
                Request::Tcn { data, n, reply } => {
                    let _ = reply.send(runtime.tcn(&data, n));
                }
            }
        }
    }

    pub fn handle(&self) -> ExecHandle {
        self.handle.clone()
    }

    pub fn spec(&self) -> RuntimeSpec {
        self.handle.spec
    }
}

impl Drop for ExecService {
    fn drop(&mut self) {
        // The service thread exits once every ExecHandle (sender clone) is
        // gone; joining here would deadlock while callers still hold
        // handles, so the thread is detached instead.
        let _ = self.join.take();
    }
}

impl ExecHandle {
    pub fn spec(&self) -> RuntimeSpec {
        self.spec
    }

    pub fn has_tcn(&self) -> bool {
        self.has_tcn
    }

    fn roundtrip(
        &self,
        make: impl FnOnce(SyncSender<Result<Vec<f32>>>) -> Request,
    ) -> Result<Vec<f32>> {
        let (reply_tx, reply_rx) = sync_channel(1);
        self.tx
            .send(make(reply_tx))
            .map_err(|_| Error::runtime("exec service is down"))?;
        reply_rx
            .recv()
            .map_err(|_| Error::runtime("exec service dropped reply"))?
    }

    /// Encode `n` blocks (`[n, S, kt, by, bx]` f32) to `[n, latent]`.
    pub fn encode(&self, data: Vec<f32>, n: usize) -> Result<Vec<f32>> {
        self.roundtrip(|reply| Request::Encode { data, n, reply })
    }

    /// Decode `n` latents to `[n, S, kt, by, bx]`.
    pub fn decode(&self, data: Vec<f32>, n: usize) -> Result<Vec<f32>> {
        self.roundtrip(|reply| Request::Decode { data, n, reply })
    }

    /// Tensor-correct `n` species vectors `[n, S]`.
    pub fn tcn(&self, data: Vec<f32>, n: usize) -> Result<Vec<f32>> {
        self.roundtrip(|reply| Request::Tcn { data, n, reply })
    }
}
