//! SZ pipeline assembly: predictor -> bins -> Huffman (`IntCodec`) -> RLE
//! lossless backend, with per-field auto predictor selection (SZ3
//! behaviour).

use crate::entropy::IntCodec;
use crate::error::{Error, Result};
use crate::sz::interp::Interp3;
use crate::sz::lorenzo::Lorenzo3;
use crate::sz::quantizer::{ErrorBoundQuantizer, Sym};
use crate::sz::SzField;
use crate::util::bytes::{ByteReader, ByteWriter};

/// Predictor selection.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SzMode {
    Lorenzo,
    Interp,
    /// Compress with both, keep the smaller payload (per field).
    Auto,
}

impl SzMode {
    pub fn parse(s: &str) -> Option<SzMode> {
        match s {
            "lorenzo" => Some(SzMode::Lorenzo),
            "interp" => Some(SzMode::Interp),
            "auto" => Some(SzMode::Auto),
            _ => None,
        }
    }
}

fn encode_syms(syms: &[Sym]) -> Result<Vec<u8>> {
    let mut bins = Vec::with_capacity(syms.len());
    let mut escapes: Vec<f32> = Vec::new();
    const ESC: i64 = i64::MIN + 1;
    for s in syms {
        match s {
            Sym::Bin(b) => bins.push(*b),
            Sym::Escape(v) => {
                bins.push(ESC);
                escapes.push(*v);
            }
        }
    }
    let mut w = ByteWriter::new();
    w.blob(&IntCodec::encode(&bins)?);
    w.u64(escapes.len() as u64);
    w.f32s(&escapes);
    Ok(w.finish())
}

fn decode_syms(buf: &[u8], n: usize) -> Result<Vec<Sym>> {
    let mut r = ByteReader::new(buf);
    let bins = IntCodec::decode(r.blob()?)?;
    let n_esc = r.u64()? as usize;
    let escapes = r.f32s(n_esc)?;
    if bins.len() != n {
        return Err(Error::codec(format!(
            "sz: expected {n} symbols, got {}",
            bins.len()
        )));
    }
    const ESC: i64 = i64::MIN + 1;
    let mut ei = 0;
    let syms = bins
        .into_iter()
        .map(|b| {
            if b == ESC {
                let v = escapes.get(ei).copied().unwrap_or(0.0);
                ei += 1;
                Sym::Escape(v)
            } else {
                Sym::Bin(b)
            }
        })
        .collect();
    Ok(syms)
}

fn compress_one(
    field: &[f32],
    dims: (usize, usize, usize),
    eb: f64,
    mode: SzMode,
) -> Result<(Vec<u8>, Vec<f32>)> {
    let q = ErrorBoundQuantizer::new(eb);
    let mut work = field.to_vec();
    let mut syms = Vec::with_capacity(field.len());
    match mode {
        SzMode::Lorenzo => Lorenzo3::new(dims.0, dims.1, dims.2).compress(&mut work, &q, &mut syms),
        SzMode::Interp => {
            Interp3::new(dims.0, dims.1, dims.2).compress(&mut work, &q, &mut syms)?
        }
        SzMode::Auto => unreachable!(),
    }
    let raw = encode_syms(&syms)?;
    // lossless backend: byte RLE (no zstd in the offline image) — the
    // symbol stream is already Huffman-packed, so the residual gain from
    // a heavier backend is small
    Ok((crate::util::rle::compress(&raw), work))
}

/// Compress one scalar field `[nt, ny, nx]` under absolute error bound `eb`.
pub fn sz_compress(
    field: &[f32],
    dims: (usize, usize, usize),
    eb: f64,
    mode: SzMode,
) -> Result<SzField> {
    Ok(sz_compress_with_recon(field, dims, eb, mode)?.0)
}

/// [`sz_compress`] that also returns the reconstruction the decompressor
/// will produce.  The predictors code every point against already-
/// *reconstructed* neighbors (the property that keeps compressor and
/// decompressor in lockstep), so the compressor's working buffer ends the
/// pass holding exactly the decompressed field — trial callers such as
/// the rate–distortion planner measure their certified error from it for
/// free instead of paying a decode pass.  Bit-equality with
/// [`sz_decompress`] is asserted in the tests below.
pub fn sz_compress_with_recon(
    field: &[f32],
    dims: (usize, usize, usize),
    eb: f64,
    mode: SzMode,
) -> Result<(SzField, Vec<f32>)> {
    assert_eq!(field.len(), dims.0 * dims.1 * dims.2);
    let (mode, payload, recon) = match mode {
        SzMode::Auto => {
            let (lz, lz_recon) = compress_one(field, dims, eb, SzMode::Lorenzo)?;
            let (ip, ip_recon) = compress_one(field, dims, eb, SzMode::Interp)?;
            if ip.len() <= lz.len() {
                (SzMode::Interp, ip, ip_recon)
            } else {
                (SzMode::Lorenzo, lz, lz_recon)
            }
        }
        m => {
            let (payload, recon) = compress_one(field, dims, eb, m)?;
            (m, payload, recon)
        }
    };
    Ok((
        SzField {
            mode,
            eb,
            dims,
            payload,
        },
        recon,
    ))
}

/// Decompress a field produced by [`sz_compress`].
pub fn sz_decompress(f: &SzField) -> Result<Vec<f32>> {
    let n = f.dims.0 * f.dims.1 * f.dims.2;
    let raw = crate::util::rle::decompress(&f.payload, n * 16 + (1 << 20))?;
    let syms = decode_syms(&raw, n)?;
    let q = ErrorBoundQuantizer::new(f.eb);
    let mut out = vec![0.0f32; n];
    match f.mode {
        SzMode::Lorenzo => Lorenzo3::new(f.dims.0, f.dims.1, f.dims.2).decompress(
            &mut out,
            &q,
            &mut syms.into_iter(),
        )?,
        SzMode::Interp => Interp3::new(f.dims.0, f.dims.1, f.dims.2).decompress(
            &mut out,
            &q,
            &mut syms.into_iter(),
        )?,
        SzMode::Auto => return Err(Error::codec("sz: Auto is not a stored mode")),
    }
    Ok(out)
}

/// Serialized size of a compressed field including headers.
pub fn sz_payload_bytes(f: &SzField) -> usize {
    // mode(1) + eb(8) + dims(24) + payload length prefix(8)
    41 + f.payload.len()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate, Profile};
    use crate::util::Prng;

    #[test]
    fn roundtrip_both_modes_respect_bound() {
        let ds = generate(Profile::Tiny, 11);
        let f = ds.species_field(5); // CO
        let dims = (ds.nt, ds.ny, ds.nx);
        for mode in [SzMode::Lorenzo, SzMode::Interp] {
            let eb = 1e-4 * 0.05; // small absolute bound
            let c = sz_compress(&f.data, dims, eb, mode).unwrap();
            let out = sz_decompress(&c).unwrap();
            for (a, b) in f.data.iter().zip(&out) {
                assert!(((a - b).abs() as f64) <= eb + 1e-9, "{mode:?}");
            }
            assert!(c.payload.len() < f.data.len() * 4);
        }
    }

    #[test]
    fn auto_picks_not_worse() {
        let ds = generate(Profile::Tiny, 12);
        let f = ds.species_field(4); // H2O
        let dims = (ds.nt, ds.ny, ds.nx);
        let eb = 1e-5;
        let a = sz_compress(&f.data, dims, eb, SzMode::Auto).unwrap();
        let l = sz_compress(&f.data, dims, eb, SzMode::Lorenzo).unwrap();
        let i = sz_compress(&f.data, dims, eb, SzMode::Interp).unwrap();
        assert!(a.payload.len() <= l.payload.len().min(i.payload.len()));
        let out = sz_decompress(&a).unwrap();
        for (x, y) in f.data.iter().zip(&out) {
            assert!(((x - y).abs() as f64) <= eb + 1e-9);
        }
    }

    #[test]
    fn tighter_bound_bigger_payload() {
        let ds = generate(Profile::Tiny, 13);
        let f = ds.species_field(1); // O2
        let dims = (ds.nt, ds.ny, ds.nx);
        let tight = sz_compress(&f.data, dims, 1e-7, SzMode::Interp).unwrap();
        let loose = sz_compress(&f.data, dims, 1e-3, SzMode::Interp).unwrap();
        assert!(tight.payload.len() > loose.payload.len());
    }

    /// The compressor's working buffer must be the decompressor's output,
    /// bit for bit — the zero-recompute planner trial depends on it.
    #[test]
    fn compressor_recon_is_bit_identical_to_decompress() {
        let ds = generate(Profile::Tiny, 21);
        let dims = (ds.nt, ds.ny, ds.nx);
        for s in [0usize, 5] {
            let f = ds.species_field(s);
            for mode in [SzMode::Lorenzo, SzMode::Interp, SzMode::Auto] {
                let (field, recon) = sz_compress_with_recon(&f.data, dims, 1e-5, mode).unwrap();
                let decoded = sz_decompress(&field).unwrap();
                assert_eq!(recon, decoded, "species {s} mode {mode:?}");
            }
        }
    }

    #[test]
    fn random_noise_still_bounded() {
        // worst case for prediction: white noise
        let mut rng = Prng::new(7);
        let dims = (3, 17, 19);
        let f: Vec<f32> = (0..dims.0 * dims.1 * dims.2)
            .map(|_| rng.normal() as f32)
            .collect();
        let eb = 0.01;
        let c = sz_compress(&f, dims, eb, SzMode::Auto).unwrap();
        let out = sz_decompress(&c).unwrap();
        for (a, b) in f.iter().zip(&out) {
            assert!(((a - b).abs() as f64) <= eb + 1e-9);
        }
    }
}
