//! Multilevel spline-interpolation predictor (SZ3's flagship, §II-D:
//! "from linear to cubic spline interpolation is selected according to the
//! prediction accuracy").
//!
//! A coarse base grid (stride `SMAX`) is coded first with delta prediction;
//! then, level by level (stride halving each time), the remaining points
//! are predicted by 1D interpolation along one axis per pass — cubic when
//! four aligned neighbors exist, linear otherwise.  Knownness of neighbors
//! is purely geometric, so the decompressor replays the identical schedule
//! over its reconstruction buffer.

use crate::error::{Error, Result};
use crate::sz::quantizer::{ErrorBoundQuantizer, Sym};

const SMAX: usize = 32;

pub struct Interp3 {
    pub nt: usize,
    pub ny: usize,
    pub nx: usize,
}

#[derive(Clone, Copy)]
enum Axis {
    T,
    Y,
    X,
}

impl Interp3 {
    pub fn new(nt: usize, ny: usize, nx: usize) -> Self {
        Self { nt, ny, nx }
    }

    #[inline]
    fn idx(&self, t: usize, y: usize, x: usize) -> usize {
        (t * self.ny + y) * self.nx + x
    }

    /// 1D interpolation along `axis` at (t,y,x) with step `s`, reading the
    /// reconstruction buffer.  Cubic if 4 aligned neighbors exist.
    fn predict(&self, r: &[f32], t: usize, y: usize, x: usize, s: usize, axis: Axis) -> f64 {
        let (pos, extent) = match axis {
            Axis::T => (t, self.nt),
            Axis::Y => (y, self.ny),
            Axis::X => (x, self.nx),
        };
        let get = |p: usize| -> f64 {
            let (tt, yy, xx) = match axis {
                Axis::T => (p, y, x),
                Axis::Y => (t, p, x),
                Axis::X => (t, y, p),
            };
            r[self.idx(tt, yy, xx)] as f64
        };
        let has_l = pos >= s;
        let has_r = pos + s < extent;
        let has_ll = pos >= 3 * s;
        let has_rr = pos + 3 * s < extent;
        match (has_l, has_r) {
            (true, true) => {
                if has_ll && has_rr {
                    // cubic: -1/16, 9/16, 9/16, -1/16
                    (-get(pos - 3 * s) + 9.0 * get(pos - s) + 9.0 * get(pos + s)
                        - get(pos + 3 * s))
                        / 16.0
                } else {
                    0.5 * (get(pos - s) + get(pos + s))
                }
            }
            (true, false) => get(pos - s),
            (false, true) => get(pos + s),
            (false, false) => 0.0,
        }
    }

    /// Visit every point in schedule order, calling `f(index, prediction)`;
    /// `f` must write the reconstructed value into the buffer it owns.
    fn schedule<F: FnMut(usize, f64, &mut [f32]) -> Result<()>>(
        &self,
        buf: &mut [f32],
        mut f: F,
    ) -> Result<()> {
        // 1. base grid (stride SMAX): raster order, delta from previous base
        let mut prev = 0.0f64;
        for t in (0..self.nt).step_by(SMAX) {
            for y in (0..self.ny).step_by(SMAX) {
                for x in (0..self.nx).step_by(SMAX) {
                    let i = self.idx(t, y, x);
                    f(i, prev, buf)?;
                    prev = buf[i] as f64;
                }
            }
        }
        // 2. levels: stride s = SMAX/2 .. 1
        let mut s = SMAX / 2;
        while s >= 1 {
            let s2 = s * 2;
            // pass along T: t odd multiple of s, y/x on 2s grid
            for t in (s..self.nt).step_by(s2) {
                for y in (0..self.ny).step_by(s2) {
                    for x in (0..self.nx).step_by(s2) {
                        let p = self.predict(buf, t, y, x, s, Axis::T);
                        f(self.idx(t, y, x), p, buf)?;
                    }
                }
            }
            // pass along Y: t on s grid, y odd multiple of s, x on 2s grid
            for t in (0..self.nt).step_by(s) {
                for y in (s..self.ny).step_by(s2) {
                    for x in (0..self.nx).step_by(s2) {
                        let p = self.predict(buf, t, y, x, s, Axis::Y);
                        f(self.idx(t, y, x), p, buf)?;
                    }
                }
            }
            // pass along X: t,y on s grid, x odd multiple of s
            for t in (0..self.nt).step_by(s) {
                for y in (0..self.ny).step_by(s) {
                    for x in (s..self.nx).step_by(s2) {
                        let p = self.predict(buf, t, y, x, s, Axis::X);
                        f(self.idx(t, y, x), p, buf)?;
                    }
                }
            }
            s /= 2;
        }
        Ok(())
    }

    /// Compress: `data` is overwritten with the reconstruction.
    pub fn compress(
        &self,
        data: &mut [f32],
        q: &ErrorBoundQuantizer,
        syms: &mut Vec<Sym>,
    ) -> Result<()> {
        self.schedule(data, |i, pred, buf| {
            let (sym, recon) = q.quantize(buf[i] as f64, pred);
            syms.push(sym);
            buf[i] = recon as f32;
            Ok(())
        })
    }

    /// Decompress into `out` (zeroed), consuming symbols in schedule order.
    pub fn decompress<I: Iterator<Item = Sym>>(
        &self,
        out: &mut [f32],
        q: &ErrorBoundQuantizer,
        syms: &mut I,
    ) -> Result<()> {
        self.schedule(out, |i, pred, buf| {
            let sym = syms
                .next()
                .ok_or_else(|| Error::codec("interp: symbol underrun"))?;
            buf[i] = match sym {
                Sym::Bin(b) => q.reconstruct(b, pred) as f32,
                Sym::Escape(lit) => lit,
            };
            Ok(())
        })
    }

    /// Total points the schedule visits (must equal field size).
    pub fn n_points(&self) -> usize {
        self.nt * self.ny * self.nx
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    fn smooth_field(nt: usize, ny: usize, nx: usize, seed: u64) -> Vec<f32> {
        let mut rng = Prng::new(seed);
        let (a, b) = (rng.next_f32(), rng.next_f32());
        let mut v = Vec::with_capacity(nt * ny * nx);
        for t in 0..nt {
            for y in 0..ny {
                for x in 0..nx {
                    v.push(
                        ((t as f32) * 0.4 + a).sin() * ((y as f32) * 0.11 + b).cos()
                            + ((x as f32) * 0.09).sin(),
                    );
                }
            }
        }
        v
    }

    #[test]
    fn schedule_visits_every_point_once() {
        for (nt, ny, nx) in [(8, 40, 40), (16, 80, 80), (5, 33, 17), (1, 1, 1), (3, 7, 70)] {
            let ip = Interp3::new(nt, ny, nx);
            let mut buf = vec![0.0f32; nt * ny * nx];
            let mut seen = vec![0u8; nt * ny * nx];
            ip.schedule(&mut buf, |i, _pred, _buf| {
                seen[i] += 1;
                Ok(())
            })
            .unwrap();
            assert!(
                seen.iter().all(|&c| c == 1),
                "{nt}x{ny}x{nx}: min {:?} max {:?}",
                seen.iter().min(),
                seen.iter().max()
            );
        }
    }

    #[test]
    fn roundtrip_within_bound() {
        let (nt, ny, nx) = (8, 30, 28);
        let orig = smooth_field(nt, ny, nx, 3);
        let eb = 1e-4;
        let q = ErrorBoundQuantizer::new(eb);
        let ip = Interp3::new(nt, ny, nx);
        let mut work = orig.clone();
        let mut syms = Vec::new();
        ip.compress(&mut work, &q, &mut syms).unwrap();
        let mut out = vec![0.0f32; orig.len()];
        ip.decompress(&mut out, &q, &mut syms.iter().cloned())
            .unwrap();
        for (a, b) in orig.iter().zip(&out) {
            assert!((a - b).abs() as f64 <= eb + 1e-9);
        }
        assert_eq!(out, work);
    }

    #[test]
    fn smooth_data_mostly_zero_bins() {
        let (nt, ny, nx) = (8, 64, 64);
        let orig = smooth_field(nt, ny, nx, 4);
        let q = ErrorBoundQuantizer::new(1e-3);
        let ip = Interp3::new(nt, ny, nx);
        let mut work = orig.clone();
        let mut syms = Vec::new();
        ip.compress(&mut work, &q, &mut syms).unwrap();
        let zeros = syms.iter().filter(|s| matches!(s, Sym::Bin(0))).count();
        assert!(
            zeros as f64 > 0.5 * syms.len() as f64,
            "only {}/{} zero bins",
            zeros,
            syms.len()
        );
    }
}
