//! 3D Lorenzo predictor (SZ1.4 / SZ2's fallback).
//!
//! Predicts x[t,y,x] from its seven already-processed neighbors:
//! p = a+b+c - ab-ac-bc + abc (inclusion–exclusion on the unit cube).
//! Compression and decompression share `process`, which walks the field in
//! raster order reading *reconstructed* values — the property that makes
//! the decompressor's predictions identical to the compressor's.

use crate::sz::quantizer::{ErrorBoundQuantizer, Sym};

/// Raster-order Lorenzo pass.  `recon` starts as a copy of the input on
/// compression (values are replaced in place by reconstructions) or as a
/// zero buffer on decompression.  `emit` produces the symbol stream on
/// compression; `next_sym` supplies it on decompression.
pub struct Lorenzo3 {
    pub nt: usize,
    pub ny: usize,
    pub nx: usize,
}

impl Lorenzo3 {
    pub fn new(nt: usize, ny: usize, nx: usize) -> Self {
        Self { nt, ny, nx }
    }

    #[inline]
    fn predict(&self, r: &[f32], t: usize, y: usize, x: usize) -> f64 {
        let nx = self.nx;
        let ny = self.ny;
        if t > 0 && y > 0 && x > 0 {
            // interior cells (the vast majority): all seven neighbors
            // exist, so compute one base index and use fixed offsets —
            // same seven terms in the same order as the branchy path
            // below, including the `0.0 + a` start (signed-zero bits)
            let sy = nx;
            let st = ny * nx;
            let i = (t * ny + y) * nx + x;
            let mut p = 0.0f64;
            p += r[i - 1] as f64;
            p += r[i - sy] as f64;
            p += r[i - st] as f64;
            p -= r[i - sy - 1] as f64;
            p -= r[i - st - 1] as f64;
            p -= r[i - st - sy] as f64;
            p += r[i - st - sy - 1] as f64;
            return p;
        }
        let at = |tt: usize, yy: usize, xx: usize| -> f64 { r[(tt * ny + yy) * nx + xx] as f64 };
        let mut p = 0.0;
        if x > 0 {
            p += at(t, y, x - 1);
        }
        if y > 0 {
            p += at(t, y - 1, x);
        }
        if t > 0 {
            p += at(t - 1, y, x);
        }
        if x > 0 && y > 0 {
            p -= at(t, y - 1, x - 1);
        }
        if x > 0 && t > 0 {
            p -= at(t - 1, y, x - 1);
        }
        if y > 0 && t > 0 {
            p -= at(t - 1, y - 1, x);
        }
        if x > 0 && y > 0 && t > 0 {
            p += at(t - 1, y - 1, x - 1);
        }
        p
    }

    /// Compress: fills `syms` and overwrites `data` with reconstructions.
    pub fn compress(&self, data: &mut [f32], q: &ErrorBoundQuantizer, syms: &mut Vec<Sym>) {
        for t in 0..self.nt {
            for y in 0..self.ny {
                for x in 0..self.nx {
                    let i = (t * self.ny + y) * self.nx + x;
                    let pred = self.predict(data, t, y, x);
                    let (sym, recon) = q.quantize(data[i] as f64, pred);
                    syms.push(sym);
                    data[i] = recon as f32;
                }
            }
        }
    }

    /// Decompress: consumes symbols in the same order.
    pub fn decompress<I: Iterator<Item = Sym>>(
        &self,
        out: &mut [f32],
        q: &ErrorBoundQuantizer,
        syms: &mut I,
    ) -> crate::error::Result<()> {
        for t in 0..self.nt {
            for y in 0..self.ny {
                for x in 0..self.nx {
                    let i = (t * self.ny + y) * self.nx + x;
                    let pred = self.predict(out, t, y, x);
                    let sym = syms
                        .next()
                        .ok_or_else(|| crate::error::Error::codec("lorenzo: symbol underrun"))?;
                    out[i] = match sym {
                        Sym::Bin(b) => q.reconstruct(b, pred) as f32,
                        Sym::Escape(lit) => lit,
                    };
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    fn smooth_field(nt: usize, ny: usize, nx: usize, seed: u64) -> Vec<f32> {
        let mut rng = Prng::new(seed);
        let (a, b, c) = (rng.next_f32(), rng.next_f32(), rng.next_f32());
        let mut v = Vec::with_capacity(nt * ny * nx);
        for t in 0..nt {
            for y in 0..ny {
                for x in 0..nx {
                    v.push(
                        ((t as f32) * 0.3 + a).sin()
                            + ((y as f32) * 0.2 + b).cos() * ((x as f32) * 0.15 + c).sin(),
                    );
                }
            }
        }
        v
    }

    #[test]
    fn roundtrip_within_bound() {
        let (nt, ny, nx) = (6, 20, 20);
        let orig = smooth_field(nt, ny, nx, 1);
        let eb = 1e-4;
        let q = ErrorBoundQuantizer::new(eb);
        let lz = Lorenzo3::new(nt, ny, nx);

        let mut work = orig.clone();
        let mut syms = Vec::new();
        lz.compress(&mut work, &q, &mut syms);

        let mut out = vec![0.0f32; orig.len()];
        lz.decompress(&mut out, &q, &mut syms.iter().cloned())
            .unwrap();
        for (a, b) in orig.iter().zip(&out) {
            assert!((a - b).abs() as f64 <= eb + 1e-9, "{a} vs {b}");
        }
        // decompressor output must equal compressor's reconstruction
        assert_eq!(out, work);
    }

    /// Original all-branches predictor — the oracle for the interior
    /// fast path.
    fn predict_ref(lz: &Lorenzo3, r: &[f32], t: usize, y: usize, x: usize) -> f64 {
        let nx = lz.nx;
        let ny = lz.ny;
        let at = |tt: usize, yy: usize, xx: usize| -> f64 { r[(tt * ny + yy) * nx + xx] as f64 };
        let mut p = 0.0;
        if x > 0 {
            p += at(t, y, x - 1);
        }
        if y > 0 {
            p += at(t, y - 1, x);
        }
        if t > 0 {
            p += at(t - 1, y, x);
        }
        if x > 0 && y > 0 {
            p -= at(t, y - 1, x - 1);
        }
        if x > 0 && t > 0 {
            p -= at(t - 1, y, x - 1);
        }
        if y > 0 && t > 0 {
            p -= at(t - 1, y - 1, x);
        }
        if x > 0 && y > 0 && t > 0 {
            p += at(t - 1, y - 1, x - 1);
        }
        p
    }

    #[test]
    fn interior_fast_path_is_bit_identical_to_branchy_predictor() {
        let (nt, ny, nx) = (4, 7, 9);
        let mut rng = Prng::new(5);
        let mut field: Vec<f32> = (0..nt * ny * nx)
            .map(|_| (rng.normal() * 2.0) as f32)
            .collect();
        // include exact zeros and negative zeros: the fast path must
        // preserve the branchy path's signed-zero arithmetic bit for bit
        field[3] = 0.0;
        field[10] = -0.0;
        field[17] = -0.0;
        let lz = Lorenzo3::new(nt, ny, nx);
        for t in 0..nt {
            for y in 0..ny {
                for x in 0..nx {
                    let got = lz.predict(&field, t, y, x);
                    let want = predict_ref(&lz, &field, t, y, x);
                    assert_eq!(
                        got.to_bits(),
                        want.to_bits(),
                        "({t},{y},{x}): {got} vs {want}"
                    );
                }
            }
        }
    }

    #[test]
    fn smooth_fields_yield_small_bins() {
        let (nt, ny, nx) = (4, 24, 24);
        let orig = smooth_field(nt, ny, nx, 2);
        let q = ErrorBoundQuantizer::new(1e-3);
        let lz = Lorenzo3::new(nt, ny, nx);
        let mut work = orig.clone();
        let mut syms = Vec::new();
        lz.compress(&mut work, &q, &mut syms);
        let small = syms
            .iter()
            .filter(|s| matches!(s, Sym::Bin(b) if b.abs() < 32))
            .count();
        assert!(small as f64 > 0.95 * syms.len() as f64);
    }
}
