//! SZ3-style error-bounded lossy compressor — the paper's baseline (§II-D).
//!
//! Prediction-based: each scalar is predicted from already-*decompressed*
//! neighbors, the prediction error is quantized on a linear scale bounded
//! by the user's absolute error bound, the quantization bins are Huffman
//! coded and the stream gets a byte-RLE lossless pass.  Two predictors, per-field auto-select
//! (SZ3 behaviour):
//! * `lorenzo` — 3D Lorenzo (SZ1.4/SZ2 fallback predictor),
//! * `interp`  — multilevel cubic/linear spline interpolation (SZ3's
//!   flagship predictor).
//!
//! Like SZ, each scalar field (one species' `[T, Y, X]` trajectory) is
//! compressed independently — the paper contrasts this with GBATC's use of
//! cross-species structure.

pub mod codec;
pub mod interp;
pub mod lorenzo;
pub mod quantizer;

pub use codec::{sz_compress, sz_decompress, SzMode};
pub use quantizer::ErrorBoundQuantizer;

/// Compressed payload for one scalar field.
#[derive(Clone, Debug)]
pub struct SzField {
    pub mode: SzMode,
    pub eb: f64,
    pub dims: (usize, usize, usize),
    pub payload: Vec<u8>,
}
