//! SZ's linear-scale error-bounded quantizer with literal escape.
//!
//! Prediction error `e` maps to bin `round(e / (2*eb))`; reconstruction
//! `pred + 2*eb*bin` is within `eb` of the original.  Errors too large for
//! the bin range escape to a raw f32 literal (bin = ESCAPE), which still
//! satisfies the bound trivially (within f32 rounding of the original).

/// Quantizer state for one field.
#[derive(Clone, Copy, Debug)]
pub struct ErrorBoundQuantizer {
    pub eb: f64,
    pub max_bin: i64,
}

/// Symbol emitted per value: a bin or an escape literal.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Sym {
    Bin(i64),
    Escape(f32),
}

impl ErrorBoundQuantizer {
    pub fn new(eb: f64) -> Self {
        assert!(eb > 0.0 && eb.is_finite());
        Self {
            eb,
            max_bin: 1 << 20,
        }
    }

    /// Quantize `x` against prediction `pred`; returns the symbol and the
    /// reconstructed value the decompressor will see.
    #[inline]
    pub fn quantize(&self, x: f64, pred: f64) -> (Sym, f64) {
        let bin = ((x - pred) / (2.0 * self.eb)).round();
        if bin.abs() as i64 > self.max_bin || !bin.is_finite() {
            let lit = x as f32;
            (Sym::Escape(lit), lit as f64)
        } else {
            let b = bin as i64;
            (Sym::Bin(b), pred + 2.0 * self.eb * b as f64)
        }
    }

    /// Decompressor side: reconstruct from a bin symbol.
    #[inline]
    pub fn reconstruct(&self, bin: i64, pred: f64) -> f64 {
        pred + 2.0 * self.eb * bin as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    #[test]
    fn error_bound_holds() {
        let q = ErrorBoundQuantizer::new(1e-3);
        let mut rng = Prng::new(1);
        for _ in 0..20_000 {
            let x = rng.uniform(-10.0, 10.0);
            let pred = x + rng.uniform(-0.5, 0.5);
            let (sym, recon) = q.quantize(x, pred);
            match sym {
                Sym::Bin(b) => {
                    assert_eq!(recon, q.reconstruct(b, pred));
                    assert!((x - recon).abs() <= 1e-3 + 1e-12);
                }
                Sym::Escape(lit) => assert_eq!(lit as f64, recon),
            }
        }
    }

    #[test]
    fn escape_on_wild_prediction() {
        let q = ErrorBoundQuantizer::new(1e-9);
        let (sym, _) = q.quantize(1e6, -1e6);
        assert!(matches!(sym, Sym::Escape(_)));
    }

    #[test]
    fn perfect_prediction_is_bin_zero() {
        let q = ErrorBoundQuantizer::new(0.01);
        let (sym, recon) = q.quantize(3.25, 3.25);
        assert_eq!(sym, Sym::Bin(0));
        assert_eq!(recon, 3.25);
    }
}
