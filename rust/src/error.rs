//! Crate-wide error type (hand-rolled — the offline image vendors no
//! `thiserror`; the `xla` variant only exists under the `pjrt` feature).

use std::fmt;

/// Unified error for every GBATC subsystem.
#[derive(Debug)]
pub enum Error {
    Io(std::io::Error),

    /// An IO failure annotated with *what* was being done — the serving
    /// request path wraps socket/file errors in this so a worker thread
    /// can log "writing query response: broken pipe" instead of a bare
    /// errno (and never panics on a client disconnect).
    IoContext {
        what: String,
        source: std::io::Error,
    },

    /// A malformed network request/response: bad request line, unknown
    /// endpoint parameters, oversized head, truncated framing.  Every
    /// protocol failure on the serve path is this variant — typed, never
    /// a panic.
    Protocol(String),

    #[cfg(feature = "pjrt")]
    Xla(xla::Error),

    Format(String),
    Config(String),
    Shape(String),
    Codec(String),
    Guarantee(String),
    Runtime(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::IoContext { what, source } => write!(f, "{what}: {source}"),
            Error::Protocol(m) => write!(f, "protocol error: {m}"),
            #[cfg(feature = "pjrt")]
            Error::Xla(e) => write!(f, "xla/pjrt error: {e}"),
            Error::Format(m) => write!(f, "format error: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Shape(m) => write!(f, "shape error: {m}"),
            Error::Codec(m) => write!(f, "codec error: {m}"),
            Error::Guarantee(m) => write!(f, "guarantee unsatisfiable: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            Error::IoContext { source, .. } => Some(source),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e)
    }
}

pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    pub fn format(msg: impl Into<String>) -> Self {
        Error::Format(msg.into())
    }
    pub fn shape(msg: impl Into<String>) -> Self {
        Error::Shape(msg.into())
    }
    pub fn codec(msg: impl Into<String>) -> Self {
        Error::Codec(msg.into())
    }
    pub fn config(msg: impl Into<String>) -> Self {
        Error::Config(msg.into())
    }
    pub fn guarantee(msg: impl Into<String>) -> Self {
        Error::Guarantee(msg.into())
    }
    pub fn runtime(msg: impl Into<String>) -> Self {
        Error::Runtime(msg.into())
    }
    pub fn protocol(msg: impl Into<String>) -> Self {
        Error::Protocol(msg.into())
    }
    /// Wrap an IO error with what was being attempted.
    pub fn io_ctx(what: impl Into<String>, source: std::io::Error) -> Self {
        Error::IoContext {
            what: what.into(),
            source,
        }
    }
}
