//! Crate-wide error type.

use thiserror::Error;

/// Unified error for every GBATC subsystem.
#[derive(Error, Debug)]
pub enum Error {
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    #[error("xla/pjrt error: {0}")]
    Xla(#[from] xla::Error),

    #[error("format error: {0}")]
    Format(String),

    #[error("config error: {0}")]
    Config(String),

    #[error("shape error: {0}")]
    Shape(String),

    #[error("codec error: {0}")]
    Codec(String),

    #[error("guarantee unsatisfiable: {0}")]
    Guarantee(String),

    #[error("runtime error: {0}")]
    Runtime(String),
}

pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    pub fn format(msg: impl Into<String>) -> Self {
        Error::Format(msg.into())
    }
    pub fn shape(msg: impl Into<String>) -> Self {
        Error::Shape(msg.into())
    }
    pub fn codec(msg: impl Into<String>) -> Self {
        Error::Codec(msg.into())
    }
    pub fn config(msg: impl Into<String>) -> Self {
        Error::Config(msg.into())
    }
    pub fn runtime(msg: impl Into<String>) -> Self {
        Error::Runtime(msg.into())
    }
}
