//! Non-blocking connection state machine for the event-driven server.
//!
//! One [`Conn`] per accepted socket.  It owns the incremental
//! [`HttpParser`] and an **in-order response queue**: every admitted
//! request reserves a slot (`begin_request` → sequence id), responses
//! complete in any order (`complete`), and only the contiguous ready
//! prefix is ever staged to the socket — pipelined clients get their
//! responses strictly in request order even when a cold decode for
//! request 1 finishes after a cache-warm request 2.
//!
//! The struct is deliberately platform-neutral (plain nonblocking
//! `TcpStream` I/O, no epoll types) so its tests run everywhere and the
//! reactor in `serve::server` stays the only Linux-gated code.
//!
//! Backpressure lives here as observable state, policy lives in the
//! server: [`Conn::write_backlog`] and `HttpParser::buffered` are the
//! meters; the event loop parks read interest when either passes its
//! cap and resumes it when [`Conn::flush`] drains.

use std::collections::VecDeque;
use std::io::{ErrorKind, Read, Write};
use std::net::TcpStream;
use std::time::Instant;

use crate::error::{Error, Result};
use crate::obs::{Phase, SpanBuilder, SpanRecord};

use super::http::HttpParser;

/// What a nonblocking read pass produced.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReadOutcome {
    /// Bytes were fed to the parser.
    Data(usize),
    /// Socket has nothing right now (`EWOULDBLOCK`).
    WouldBlock,
    /// Peer sent FIN (or the socket errored terminally).
    Closed,
}

/// What a flush pass left behind.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WriteOutcome {
    /// Everything staged was written; the write buffer is empty.
    Done,
    /// A short write hit `EWOULDBLOCK`; re-arm write interest.
    Blocked,
}

pub struct Conn {
    pub stream: TcpStream,
    pub parser: HttpParser,
    /// Generation stamped into this slot's epoll token; a stale event
    /// for a recycled slot fails the generation check and is dropped.
    pub generation: u32,
    /// In-order response slots: `None` = response still being computed.
    /// A completed slot may carry the request's trace span; it rides the
    /// queue so its write phase can be closed when the bytes hit the
    /// wire.
    queue: VecDeque<Option<(Vec<u8>, Option<SpanBuilder>)>>,
    /// Spans of staged responses, ordered by wire offset: the span
    /// finishes once `total_flushed` passes its response's last byte.
    /// Entries: (wire end offset, span, span-relative staging mark ns).
    pending_spans: VecDeque<(u64, SpanBuilder, u64)>,
    /// Cumulative response bytes staged into / drained out of `wbuf`.
    total_staged: u64,
    total_flushed: u64,
    /// Sequence id of `queue.front()`.
    head_seq: u64,
    /// Sequence id the next admitted request will get.
    next_seq: u64,
    /// Bytes sitting in ready-but-unstaged slots (backlog accounting).
    ready_bytes: usize,
    /// Staged output and how much of it already reached the socket.
    wbuf: Vec<u8>,
    wpos: usize,
    /// Requests admitted whose response slot is still `None`.
    pub inflight: usize,
    /// Requests parsed on this connection (keep-alive reuse = all past
    /// the first).
    pub requests: u64,
    /// Stop reading **and parsing**; close once the response queue and
    /// write buffer drain (`Connection: close`, parse error, shutdown).
    pub close_after: bool,
    /// Peer sent FIN (half-close): no more reads, but requests already
    /// buffered still parse and their responses still get written —
    /// a pipelining client may legally shut down its write side early.
    pub peer_eof: bool,
    /// Last socket activity, for idle reaping.
    pub last_activity: Instant,
    /// Interest bits currently registered in the reactor (the server
    /// diffs desired-vs-registered to skip redundant `epoll_ctl`s).
    pub reg_read: bool,
    pub reg_write: bool,
    /// Parser bytes charged against the server's global read meter.
    pub metered: usize,
}

impl Conn {
    pub fn new(stream: TcpStream, max_head: usize, generation: u32, now: Instant) -> Conn {
        Conn {
            stream,
            parser: HttpParser::new(max_head),
            generation,
            queue: VecDeque::new(),
            pending_spans: VecDeque::new(),
            total_staged: 0,
            total_flushed: 0,
            head_seq: 0,
            next_seq: 0,
            ready_bytes: 0,
            wbuf: Vec::new(),
            wpos: 0,
            inflight: 0,
            requests: 0,
            close_after: false,
            peer_eof: false,
            last_activity: now,
            reg_read: false,
            reg_write: false,
            metered: 0,
        }
    }

    /// One nonblocking read; bytes go straight into the parser.
    pub fn read_some(&mut self, scratch: &mut [u8]) -> ReadOutcome {
        loop {
            match self.stream.read(scratch) {
                Ok(0) => return ReadOutcome::Closed,
                Ok(n) => {
                    self.parser.feed(&scratch[..n]);
                    return ReadOutcome::Data(n);
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => return ReadOutcome::WouldBlock,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => return ReadOutcome::Closed,
            }
        }
    }

    /// Admit a parsed request: reserve its in-order response slot and
    /// return the sequence id its response must complete under.
    pub fn begin_request(&mut self) -> u64 {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.queue.push_back(None);
        self.inflight += 1;
        self.requests += 1;
        seq
    }

    /// Deliver the serialized response for `seq`.  Tolerates unknown or
    /// already-filled sequence ids (a worker may complete after the
    /// connection died and its slot was recycled — the generation check
    /// in the server makes that a no-op before it ever reaches here).
    pub fn complete(&mut self, seq: u64, bytes: Vec<u8>) {
        self.complete_traced(seq, bytes, None);
    }

    /// [`complete`](Self::complete) carrying the request's trace span.
    /// The span stays with the response through staging; its `Write`
    /// phase closes when the last response byte drains to the socket
    /// (harvest with [`take_finished_spans`](Self::take_finished_spans)).
    pub fn complete_traced(&mut self, seq: u64, bytes: Vec<u8>, span: Option<SpanBuilder>) {
        if seq < self.head_seq {
            return;
        }
        let idx = (seq - self.head_seq) as usize;
        if let Some(slot) = self.queue.get_mut(idx) {
            if slot.is_none() {
                self.ready_bytes += bytes.len();
                *slot = Some((bytes, span));
                self.inflight -= 1;
            }
        }
    }

    /// Move the contiguous ready prefix of the queue into the write
    /// buffer.  A `None` at the front blocks everything behind it —
    /// that is exactly the in-order guarantee.
    fn stage_ready(&mut self) {
        while let Some(Some(_)) = self.queue.front() {
            if let Some(Some((bytes, span))) = self.queue.pop_front() {
                self.head_seq += 1;
                self.ready_bytes -= bytes.len();
                self.wbuf.extend_from_slice(&bytes);
                self.total_staged += bytes.len() as u64;
                if let Some(sp) = span {
                    let staged_at = sp.mark();
                    self.pending_spans
                        .push_back((self.total_staged, sp, staged_at));
                }
            }
        }
        if self.wpos > 0 && self.wpos == self.wbuf.len() {
            self.wbuf.clear();
            self.wpos = 0;
        }
    }

    /// Finish spans whose response bytes have fully reached the socket:
    /// their `Write` phase spans staging → drain.  Call after a flush;
    /// wait-free (no locks — plain queue pops on the reactor thread).
    pub fn take_finished_spans(&mut self, out: &mut Vec<SpanRecord>) {
        while let Some(&(end_off, _, _)) = self.pending_spans.front() {
            if end_off > self.total_flushed {
                break;
            }
            if let Some((_, mut sp, staged_at)) = self.pending_spans.pop_front() {
                let now = sp.mark();
                sp.add_phase(Phase::Write, staged_at, now.saturating_sub(staged_at));
                out.push(sp.finish());
            }
        }
    }

    /// Write as much staged output as the socket accepts.
    pub fn flush(&mut self) -> Result<WriteOutcome> {
        self.stage_ready();
        while self.wpos < self.wbuf.len() {
            match self.stream.write(&self.wbuf[self.wpos..]) {
                Ok(0) => return Err(Error::protocol("peer closed mid-response")),
                Ok(n) => {
                    self.wpos += n;
                    self.total_flushed += n as u64;
                    self.stage_ready();
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => return Ok(WriteOutcome::Blocked),
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => return Err(Error::io_ctx("writing response", e)),
            }
        }
        self.wbuf.clear();
        self.wpos = 0;
        Ok(WriteOutcome::Done)
    }

    /// Output bytes not yet on the wire (staged + ready-but-unstaged).
    /// This is the bounded-write-buffer meter: a slow reader's backlog
    /// grows here and the server parks its read interest at the cap.
    pub fn write_backlog(&self) -> usize {
        (self.wbuf.len() - self.wpos) + self.ready_bytes
    }

    /// Whether any response bytes are waiting for the socket.
    pub fn wants_write(&self) -> bool {
        self.wpos < self.wbuf.len() || matches!(self.queue.front(), Some(Some(_)))
    }

    /// All admitted requests answered and all bytes written — a
    /// `close_after` connection can now shut down gracefully (FIN after
    /// the last response, never an RST that races it).
    pub fn drained(&self) -> bool {
        self.queue.is_empty() && self.wpos >= self.wbuf.len()
    }

    pub fn idle_millis(&self, now: Instant) -> u128 {
        now.duration_since(self.last_activity).as_millis()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    fn pair() -> (TcpStream, TcpStream) {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let c = TcpStream::connect(l.local_addr().unwrap()).unwrap();
        let (s, _) = l.accept().unwrap();
        s.set_nonblocking(true).unwrap();
        (s, c)
    }

    #[test]
    fn out_of_order_completion_writes_in_order() {
        let (server, mut client) = pair();
        let mut conn = Conn::new(server, 8 * 1024, 0, Instant::now());
        let a = conn.begin_request();
        let b = conn.begin_request();
        let c = conn.begin_request();
        assert_eq!(conn.inflight, 3);

        // responses land out of order: c, a, b
        conn.complete(c, b"CC".to_vec());
        assert!(!conn.wants_write(), "front slot still pending");
        conn.complete(a, b"AA".to_vec());
        assert!(conn.wants_write());
        assert_eq!(conn.flush().unwrap(), WriteOutcome::Done);
        conn.complete(b, b"BB".to_vec());
        assert_eq!(conn.flush().unwrap(), WriteOutcome::Done);
        assert_eq!(conn.inflight, 0);
        assert!(conn.drained());

        let mut got = [0u8; 6];
        client.read_exact(&mut got).unwrap();
        assert_eq!(&got, b"AABBCC");
    }

    #[test]
    fn backlog_counts_staged_and_ready_bytes() {
        let (server, _client) = pair();
        let mut conn = Conn::new(server, 8 * 1024, 0, Instant::now());
        let a = conn.begin_request();
        let b = conn.begin_request();
        conn.complete(b, vec![0u8; 100]); // ready but blocked behind `a`
        assert_eq!(conn.write_backlog(), 100);
        conn.complete(a, vec![0u8; 50]);
        assert_eq!(conn.write_backlog(), 150);
        conn.flush().unwrap();
        assert_eq!(conn.write_backlog(), 0);
    }

    #[test]
    fn spans_finish_only_after_their_bytes_drain() {
        let (server, mut client) = pair();
        let mut conn = Conn::new(server, 8 * 1024, 0, Instant::now());
        let a = conn.begin_request();
        let b = conn.begin_request();
        let mut sp = SpanBuilder::new(7, true);
        sp.status = 200;
        sp.set_target("/query?dataset=hcci");
        // b completes first (with a span) but is blocked behind a
        conn.complete_traced(b, b"BB".to_vec(), Some(sp));
        let mut done = Vec::new();
        conn.take_finished_spans(&mut done);
        assert!(done.is_empty(), "span must not finish before its bytes flush");
        conn.complete(a, b"AA".to_vec());
        conn.flush().unwrap();
        conn.take_finished_spans(&mut done);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].trace_id, 7);
        assert_eq!(done[0].status, 200);
        assert_eq!(done[0].target(), "/query?dataset=hcci");
        let write = done[0].phases[Phase::Write as usize];
        assert!(write.1 <= done[0].total_ns);
        let mut got = [0u8; 4];
        client.read_exact(&mut got).unwrap();
        assert_eq!(&got, b"AABB");
    }

    #[test]
    fn stale_and_duplicate_completions_are_noops() {
        let (server, _client) = pair();
        let mut conn = Conn::new(server, 8 * 1024, 0, Instant::now());
        let a = conn.begin_request();
        conn.complete(a, b"X".to_vec());
        conn.complete(a, b"Y".to_vec()); // duplicate: ignored
        conn.complete(a + 5, b"Z".to_vec()); // never admitted: ignored
        conn.flush().unwrap();
        conn.complete(a, b"W".to_vec()); // already flushed: ignored
        assert_eq!(conn.inflight, 0);
        assert!(conn.drained());
    }
}
