//! Consistent-hash routing across in-process [`ArchiveStore`] replicas.
//!
//! The in-process step of the ROADMAP's scale-out plan: one
//! [`QueryRouter`] owns N store replicas, each with its **own decoded
//! plane cache**, and hashes dataset keys onto a ring of virtual nodes
//! so every dataset has a stable home replica.  Repeat queries for the
//! same dataset land on the same replica and hit the same warm cache
//! (warm-cache affinity) — the property the `serve_event` tests assert
//! via per-replica hit counters.  All replicas share **one executor
//! service**: replica 0 starts it, siblings are built
//! [`ArchiveStore::with_handle`] on its [`ArchiveStore::exec_handle`],
//! so N replicas do not mean N model backends.
//!
//! Virtual nodes (default 64 per replica) smooth the ring: with plain
//! modulo hashing, adding a replica would remap nearly every dataset;
//! on the ring, only the keys in the new replica's arcs move.
//!
//! **Failover**: a mount that fails on its home replica walks the ring
//! to the next *distinct* replica and tries there.  The placement map
//! records where a dataset actually lives — routing consults it first,
//! so failover placements keep their affinity too.

use std::collections::HashMap;
use std::sync::{Arc, RwLock};

use crate::api::Query;
use crate::coordinator::engine::RangeDecode;
use crate::error::{Error, Result};
use crate::obs::SpanBuilder;
use crate::store::{ArchiveStore, DatasetInfo, StoreConfig, StoreObsSnapshot, StoreStats};

/// Knobs of a [`QueryRouter`].
#[derive(Clone, Debug)]
pub struct RouterConfig {
    /// In-process store replicas (>= 1).
    pub replicas: usize,
    /// Virtual nodes per replica on the hash ring.
    pub vnodes: usize,
    /// Per-replica store configuration.  `cache_bytes` is **per
    /// replica** — N replicas hold N separate caches of this size.
    pub store: StoreConfig,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            replicas: 1,
            vnodes: 64,
            store: StoreConfig::default(),
        }
    }
}

/// FNV-1a with a splitmix-style avalanche; good enough key mixing for
/// ring placement without pulling in a hash dependency.
fn hash64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h ^= h >> 33;
    h = h.wrapping_mul(0xff51_afd7_ed55_8ccd);
    h ^= h >> 33;
    h
}

/// The replica front tier; see the module docs.
pub struct QueryRouter {
    replicas: Vec<Arc<ArchiveStore>>,
    /// Sorted ring of `(point, replica index)` virtual nodes.
    ring: Vec<(u64, usize)>,
    /// Where each mounted dataset actually lives (home replica, or its
    /// failover sibling).
    placement: RwLock<HashMap<String, usize>>,
}

impl QueryRouter {
    /// Build `cfg.replicas` stores sharing one executor service.
    pub fn new(cfg: RouterConfig) -> Result<QueryRouter> {
        if cfg.replicas == 0 {
            return Err(Error::config("router needs at least 1 replica"));
        }
        let first = Arc::new(ArchiveStore::new(cfg.store.clone())?);
        let mut replicas = vec![Arc::clone(&first)];
        for _ in 1..cfg.replicas {
            replicas.push(Arc::new(ArchiveStore::with_handle(
                first.exec_handle(),
                cfg.store.clone(),
            )));
        }
        Ok(Self::assemble(replicas, cfg.vnodes))
    }

    /// Wrap one existing store as a single-replica router — how
    /// `QueryServer::bind` keeps the plain-store API: every dataset
    /// routes to replica 0, including ones mounted on the store
    /// directly before or after the wrap.
    pub fn single(store: Arc<ArchiveStore>) -> QueryRouter {
        Self::assemble(vec![store], 1)
    }

    /// Assemble a router over caller-built replicas — for embedders
    /// (and tests) that manage their own executor service.  The
    /// replicas should share one service (build siblings with
    /// [`ArchiveStore::with_handle`]); nothing here enforces it, but N
    /// independent backends defeat the point of in-process replicas.
    pub fn from_replicas(replicas: Vec<Arc<ArchiveStore>>, vnodes: usize) -> Result<QueryRouter> {
        if replicas.is_empty() {
            return Err(Error::config("router needs at least 1 replica"));
        }
        Ok(Self::assemble(replicas, vnodes))
    }

    fn assemble(replicas: Vec<Arc<ArchiveStore>>, vnodes: usize) -> QueryRouter {
        let mut ring = Vec::with_capacity(replicas.len() * vnodes.max(1));
        for r in 0..replicas.len() {
            for v in 0..vnodes.max(1) {
                ring.push((hash64(format!("replica-{r}-vnode-{v}").as_bytes()), r));
            }
        }
        ring.sort_unstable();
        QueryRouter {
            replicas,
            ring,
            placement: RwLock::new(HashMap::new()),
        }
    }

    pub fn replica_count(&self) -> usize {
        self.replicas.len()
    }

    /// Direct replica access (tests assert per-replica cache counters).
    pub fn replica(&self, idx: usize) -> &Arc<ArchiveStore> {
        &self.replicas[idx]
    }

    /// The replica the hash ring names as home for `dataset` (before
    /// any failover placement).
    pub fn primary_of(&self, dataset: &str) -> usize {
        let h = hash64(dataset.as_bytes());
        let idx = self.ring.partition_point(|&(p, _)| p < h);
        let idx = if idx == self.ring.len() { 0 } else { idx };
        self.ring[idx].1
    }

    /// Ring walk from the home position yielding each distinct replica
    /// once — the mount failover order.
    fn candidates(&self, dataset: &str) -> Vec<usize> {
        let h = hash64(dataset.as_bytes());
        let start = {
            let i = self.ring.partition_point(|&(p, _)| p < h);
            if i == self.ring.len() {
                0
            } else {
                i
            }
        };
        let mut out = Vec::with_capacity(self.replicas.len());
        for k in 0..self.ring.len() {
            let r = self.ring[(start + k) % self.ring.len()].1;
            if !out.contains(&r) {
                out.push(r);
                if out.len() == self.replicas.len() {
                    break;
                }
            }
        }
        out
    }

    /// Which replica serves `dataset`: its recorded placement, else the
    /// ring primary (covers `single()`-wrapped stores with datasets
    /// mounted out-of-band).
    pub fn route_of(&self, dataset: &str) -> usize {
        let placed = self
            .placement
            .read()
            .ok()
            .and_then(|g| g.get(dataset).copied());
        placed.unwrap_or_else(|| self.primary_of(dataset))
    }

    fn record_placement(&self, dataset: &str, replica: usize) -> Result<()> {
        self.placement
            .write()
            .map_err(|_| Error::runtime("router placement lock poisoned"))?
            .insert(dataset.to_string(), replica);
        Ok(())
    }

    fn mount_with<F>(&self, name: &str, mut mount: F) -> Result<usize>
    where
        F: FnMut(&ArchiveStore) -> Result<()>,
    {
        let mut last_err = None;
        for r in self.candidates(name) {
            match mount(&self.replicas[r]) {
                Ok(()) => {
                    self.record_placement(name, r)?;
                    return Ok(r);
                }
                Err(e) => last_err = Some(e),
            }
        }
        Err(last_err.unwrap_or_else(|| Error::config("router has no replicas")))
    }

    /// Mount an archive file on the dataset's home replica, failing over
    /// along the ring.  Returns the replica index that took it.
    pub fn mount_file<P: AsRef<std::path::Path>>(&self, name: &str, path: P) -> Result<usize> {
        let path = path.as_ref();
        self.mount_with(name, |store| store.mount_file(name, path))
    }

    /// Mount serialized archive bytes (see [`QueryRouter::mount_file`]).
    pub fn mount_bytes(&self, name: &str, bytes: Vec<u8>) -> Result<usize> {
        // the closure may run once per candidate; clone per attempt
        self.mount_with(name, |store| store.mount_bytes(name, bytes.clone()))
    }

    /// Unmount a dataset from whichever replica holds it.
    pub fn unmount(&self, name: &str) -> Result<()> {
        let r = self.route_of(name);
        self.replicas[r].unmount(name)?;
        if let Ok(mut g) = self.placement.write() {
            g.remove(name);
        }
        Ok(())
    }

    /// Whether any replica serves `name`.
    pub fn contains(&self, name: &str) -> bool {
        self.replicas[self.route_of(name)].contains(name)
    }

    /// Execute a query on the dataset's replica (warm-cache affinity).
    pub fn query(&self, dataset: &str, q: &Query) -> Result<RangeDecode> {
        self.replicas[self.route_of(dataset)].query(dataset, q)
    }

    /// [`query`](Self::query) with phase attribution into `span`
    /// (cache-probe / decode / salvage — see
    /// [`ArchiveStore::query_traced`]).
    pub fn query_traced(
        &self,
        dataset: &str,
        q: &Query,
        span: Option<&mut SpanBuilder>,
    ) -> Result<RangeDecode> {
        self.replicas[self.route_of(dataset)].query_traced(dataset, q, span)
    }

    /// Side-effect-free warmth probe on the dataset's replica.
    pub fn is_warm(&self, dataset: &str, q: &Query) -> bool {
        self.replicas[self.route_of(dataset)].is_warm(dataset, q)
    }

    /// Catalog entry of one dataset, from its replica.
    pub fn dataset_info(&self, name: &str) -> Result<DatasetInfo> {
        self.replicas[self.route_of(name)].dataset_info(name)
    }

    /// Union catalog across all replicas, sorted by name.
    pub fn datasets(&self) -> Vec<DatasetInfo> {
        let mut out: Vec<DatasetInfo> = self
            .replicas
            .iter()
            .flat_map(|r| r.datasets())
            .collect();
        out.sort_by(|a, b| a.name.cmp(&b.name));
        out
    }

    /// Per-replica counter snapshots, in replica order.
    pub fn replica_stats(&self) -> Vec<StoreStats> {
        self.replicas.iter().map(|r| r.stats()).collect()
    }

    /// Store-side histograms merged across replicas (decode time,
    /// cache-probe time) — the `/metrics` store section.
    pub fn obs_snapshot(&self) -> StoreObsSnapshot {
        let mut agg = StoreObsSnapshot::default();
        for r in &self.replicas {
            agg.merge(&r.obs().snapshot());
        }
        agg
    }

    /// Aggregate snapshot: counters summed across replicas, dataset
    /// catalog unioned.  `cache.capacity_bytes`/`lock_shards` sum too —
    /// the fleet-wide budget, matching the per-replica note on
    /// [`RouterConfig::store`].
    pub fn stats(&self) -> StoreStats {
        let per = self.replica_stats();
        let mut agg = StoreStats {
            queries: 0,
            decoded_sections: 0,
            decoded_bytes: 0,
            cache: Default::default(),
            datasets: self.datasets(),
        };
        for s in &per {
            agg.queries += s.queries;
            agg.decoded_sections += s.decoded_sections;
            agg.decoded_bytes += s.decoded_bytes;
            agg.cache.hits += s.cache.hits;
            agg.cache.misses += s.cache.misses;
            agg.cache.admitted += s.cache.admitted;
            agg.cache.rejected += s.cache.rejected;
            agg.cache.evicted += s.cache.evicted;
            agg.cache.resident_sections += s.cache.resident_sections;
            agg.cache.resident_bytes += s.cache.resident_bytes;
            agg.cache.capacity_bytes += s.cache.capacity_bytes;
            agg.cache.lock_shards += s.cache.lock_shards;
        }
        agg
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn router(replicas: usize) -> QueryRouter {
        QueryRouter::new(RouterConfig {
            replicas,
            vnodes: 64,
            store: StoreConfig {
                cache_bytes: 1 << 20,
                cache_shards: 2,
                ..Default::default()
            },
        })
        .unwrap()
    }

    #[test]
    fn ring_is_stable_and_covers_all_replicas() {
        let r = router(4);
        let names: Vec<String> = (0..200).map(|i| format!("ds-{i}")).collect();
        let homes: Vec<usize> = names.iter().map(|n| r.primary_of(n)).collect();
        // deterministic
        for (n, &h) in names.iter().zip(&homes) {
            assert_eq!(r.primary_of(n), h);
        }
        // with 64 vnodes/replica, 200 keys must touch every replica
        for replica in 0..4 {
            assert!(
                homes.iter().any(|&h| h == replica),
                "replica {replica} owns no keys"
            );
        }
    }

    #[test]
    fn adding_a_replica_moves_few_keys() {
        let small = router(3);
        let big = router(4);
        let badly_moved = (0..500)
            .map(|i| format!("ds-{i}"))
            .filter(|n| {
                let before = small.primary_of(n);
                let after = big.primary_of(n);
                // consistent hashing: keys either stay put or move onto
                // the new replica — never shuffle between old replicas
                after != before && after != 3
            })
            .count();
        assert_eq!(badly_moved, 0, "keys must only move onto the new replica");
    }

    #[test]
    fn single_routes_everything_to_replica_zero() {
        let store = Arc::new(ArchiveStore::new(StoreConfig::default()).unwrap());
        let r = QueryRouter::single(store);
        assert_eq!(r.replica_count(), 1);
        for i in 0..50 {
            assert_eq!(r.route_of(&format!("ds-{i}")), 0);
        }
    }

    #[test]
    fn aggregate_stats_sum_replica_counters() {
        let r = router(3);
        let agg = r.stats();
        assert_eq!(agg.cache.lock_shards, 3 * 2);
        assert_eq!(agg.cache.capacity_bytes, 3 << 20);
        assert_eq!(agg.queries, 0);
    }
}
