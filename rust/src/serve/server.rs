//! Concurrent query server — event-driven on Linux, thread-pool
//! fallback elsewhere.
//!
//! The primary implementation is a readiness-based event loop: one
//! reactor thread owns the listener, every connection state machine
//! ([`Conn`]), and a hand-rolled `epoll(7)` instance
//! ([`crate::serve::reactor`]).  Connections are non-blocking with
//! incremental HTTP/1.1 framing, keep-alive, and pipelining (responses
//! strictly in request order).  Decode work runs on a small worker pool
//! fed through a bounded job channel; **cache-warm** queries small
//! enough (`inline_warm_bytes`) are executed right on the reactor
//! thread — a warm hit is a refcount bump plus serialization, no
//! handoff.  Fairness and admission control:
//!
//! * connection cap (`max_conns`) — overload answers `503` and closes;
//! * bounded job queue — overflow answers `503` per request;
//! * per-connection in-flight cap and write-buffer cap, plus a global
//!   read-buffer byte meter — a pipelining blaster or a slow reader is
//!   throttled by parking its read interest, never by blocking the
//!   loop;
//! * round-robin event processing, so one hot fd cannot starve others;
//! * an idle timeout reaps slowlorises and abandoned keep-alives.
//!
//! Off Linux — or with `GBATC_NO_EPOLL=1` — the server falls back to
//! the blocking thread-pool implementation (bounded connection queue,
//! one connection per worker), upgraded to speak the same keep-alive +
//! pipelining protocol through the same [`HttpParser`], so both servers
//! produce identical responses and counters.
//!
//! Requests route through a [`QueryRouter`]: dataset keys consistent-
//! hash onto N in-process store replicas with warm-cache affinity
//! (`bind` wraps a single store as a 1-replica router).
//!
//! Endpoints:
//! * `GET /datasets` — JSON catalog of mounted datasets.
//! * `GET /query?dataset=D&t0=A&t1=B&species=OH,CO` — binary
//!   little-endian f32 body (`[nt, |species|, Y, X]` row-major) plus an
//!   `X-Gbatc-Meta` JSON header with dims, resolved species indices, and
//!   the certified error target.  `t0`/`t1`/`species` are optional
//!   (defaults: full axis, all species).
//! * `GET /stats` — JSON cache / decode / IO / server / event-loop /
//!   per-replica counters.
//!
//! Shutdown is graceful: [`QueryServer::shutdown`] stops accepting,
//! finishes every admitted request, flushes every response, and joins
//! every thread; counters are exact at return.

use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::api::{Query, SpeciesSel};
use crate::error::{Error, Result};
use crate::obs::{prom, HistSnapshot, Histogram, Phase, SpanBuilder, SpanRecord, TraceIds, TraceRing};
use crate::serve::http::{self, json_error, json_escape, json_usize_list, HttpParser, Request};
#[cfg(target_os = "linux")]
use crate::serve::reactor::{Reactor, Waker};
use crate::serve::router::QueryRouter;
use crate::store::ArchiveStore;

const JSON: &str = "application/json";
const BINARY: &str = "application/octet-stream";
/// Prometheus text exposition format 0.0.4 (`GET /metrics`).
const PROM: &str = "text/plain; version=0.0.4";

/// Knobs of a [`QueryServer`].
#[derive(Clone, Copy, Debug)]
pub struct ServerConfig {
    /// Decode worker threads behind the event loop (or connection
    /// workers in the thread-pool fallback).
    pub workers: usize,
    /// Bounded decode-job queue (fallback: connection queue); overflow
    /// is answered `503` immediately.
    pub queue: usize,
    /// Request-head byte cap (oversized requests get `431`).
    pub max_head_bytes: usize,
    /// Response-body byte cap per `/query` (larger requests get `413`
    /// before any decode).
    pub max_response_bytes: usize,
    /// Idle timeout: a connection with no socket progress for this long
    /// is reaped (slowloris / abandoned keep-alive).  Also the fallback
    /// server's per-connection read deadline.
    pub read_timeout_ms: u64,
    /// Connection cap of the event loop; excess accepts get `503` and
    /// close.  (The fallback's bounded queue is its own cap.)
    pub max_conns: usize,
    /// Max pipelined requests in flight per connection; further
    /// requests wait in the read buffer (read interest parked).
    pub max_inflight: usize,
    /// Per-connection write-buffer cap: a slow reader whose backlog
    /// passes this stops being read from until it drains.
    pub write_buf_bytes: usize,
    /// Global read-buffer byte meter across all connections (replaces
    /// the old bounded connection queue as the memory bound).
    pub read_buf_bytes: usize,
    /// Cache-warm `/query` responses up to this many body bytes are
    /// served inline on the reactor thread (zero handoff).
    pub inline_warm_bytes: usize,
    /// Trace sampling: 1-in-N requests get a span admitted to the
    /// slow-query ring (`/trace/slow`); every request still records
    /// into the latency histograms and carries the `X-Gbatc-Trace-Id`
    /// header.  `0` disables tracing entirely (no spans, no header).
    /// Default honours `GBATC_NO_TRACE=1` (→ 0) then
    /// `GBATC_TRACE_SAMPLE=N`, else 16.
    pub trace_sample: u32,
}

fn default_trace_sample() -> u32 {
    let no_trace = std::env::var("GBATC_NO_TRACE")
        .map(|v| v == "1" || v.eq_ignore_ascii_case("true"))
        .unwrap_or(false);
    if no_trace {
        return 0;
    }
    std::env::var("GBATC_TRACE_SAMPLE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(16)
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            workers: 4,
            queue: 64,
            max_head_bytes: 8 * 1024,
            max_response_bytes: 256 << 20,
            read_timeout_ms: 30_000,
            max_conns: 1024,
            max_inflight: 8,
            write_buf_bytes: 4 << 20,
            read_buf_bytes: 1 << 20,
            inline_warm_bytes: 4 << 20,
            trace_sample: default_trace_sample(),
        }
    }
}

/// Counter snapshot of a server; see the field docs.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ServeStats {
    /// Connections accepted.
    pub accepted: u64,
    /// `200` responses written.
    pub served: u64,
    /// `4xx` responses (bad request / unknown dataset / oversized head).
    pub client_errors: u64,
    /// `5xx` responses (decode failures surfaced to the client).
    pub server_errors: u64,
    /// Requests refused with `503` because the job queue was full.
    pub rejected_queue_full: u64,
    /// Connections refused with `503` at the connection cap.
    pub rejected_conn_cap: u64,
    /// Sockets that died mid-request/response (timeouts, disconnects).
    pub io_errors: u64,
    /// Requests served on an already-used connection (keep-alive hits:
    /// every request past a connection's first).
    pub keepalive_reuse: u64,
    /// Idle connections reaped by the timeout after serving at least
    /// one request.
    pub reaped_idle: u64,
    /// Requests parsed from bytes already buffered when the previous
    /// request finished parsing (client pipelining).
    pub pipelined: u64,
    /// Connections currently open (gauge; `0` after shutdown).
    pub active_conns: u64,
    /// Response bytes written to the wire (status line + headers +
    /// body), bumped exactly once per produced response in both modes.
    pub bytes_out: u64,
}

impl std::fmt::Display for ServeStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "accepted {} | served {} | 4xx {} | 5xx {} | busy-rejected {} | conn-cap {} | \
             io errors {} | keep-alive reuse {} | pipelined {} | reaped idle {} | active {} | \
             bytes out {}",
            self.accepted,
            self.served,
            self.client_errors,
            self.server_errors,
            self.rejected_queue_full,
            self.rejected_conn_cap,
            self.io_errors,
            self.keepalive_reuse,
            self.pipelined,
            self.reaped_idle,
            self.active_conns,
            self.bytes_out
        )
    }
}

#[derive(Default)]
struct Counters {
    accepted: AtomicU64,
    served: AtomicU64,
    client_errors: AtomicU64,
    server_errors: AtomicU64,
    rejected_queue_full: AtomicU64,
    rejected_conn_cap: AtomicU64,
    io_errors: AtomicU64,
    keepalive_reuse: AtomicU64,
    reaped_idle: AtomicU64,
    pipelined: AtomicU64,
    active_conns: AtomicU64,
    bytes_out: AtomicU64,
}

impl Counters {
    fn snapshot(&self) -> ServeStats {
        ServeStats {
            accepted: self.accepted.load(Ordering::Relaxed),
            served: self.served.load(Ordering::Relaxed),
            client_errors: self.client_errors.load(Ordering::Relaxed),
            server_errors: self.server_errors.load(Ordering::Relaxed),
            rejected_queue_full: self.rejected_queue_full.load(Ordering::Relaxed),
            rejected_conn_cap: self.rejected_conn_cap.load(Ordering::Relaxed),
            io_errors: self.io_errors.load(Ordering::Relaxed),
            keepalive_reuse: self.keepalive_reuse.load(Ordering::Relaxed),
            reaped_idle: self.reaped_idle.load(Ordering::Relaxed),
            pipelined: self.pipelined.load(Ordering::Relaxed),
            active_conns: self.active_conns.load(Ordering::Relaxed),
            bytes_out: self.bytes_out.load(Ordering::Relaxed),
        }
    }
}

/// Server-side observability shared by both modes: latency histograms
/// (always recording), the trace-ID mint, the 1-in-N ring-sampling
/// decision, and the bounded slow-query ring behind `/trace/slow`.
pub struct ServeObs {
    /// Request latency, parse start → response produced.  In the event
    /// loop this includes queue wait for offloaded decodes, so the two
    /// modes measure the same client-visible interval.
    query_ns: Histogram,
    /// Decode-job queue wait at worker dequeue (event mode; the pool
    /// fallback has no decode queue and records nothing here).
    queue_wait_ns: Histogram,
    /// Slow-span ring: bounded, lock-sharded, overwrite-oldest.
    ring: TraceRing,
    ids: TraceIds,
    /// 1-in-N ring sampling; `0` disables tracing.
    sample: u32,
    sample_seq: AtomicU64,
}

impl ServeObs {
    fn new(sample: u32) -> ServeObs {
        ServeObs {
            query_ns: Histogram::new(),
            queue_wait_ns: Histogram::new(),
            ring: TraceRing::new(256, 8),
            ids: TraceIds::new(),
            sample,
            sample_seq: AtomicU64::new(0),
        }
    }

    /// Whether tracing is on (spans minted, trace header attached).
    pub fn tracing_enabled(&self) -> bool {
        self.sample > 0
    }

    /// Mint a span for a request whose parse began at `start` and took
    /// `parse_ns`.  `None` when tracing is disabled — the histograms
    /// record regardless, via [`count_response`].
    fn begin_span(&self, start: Instant, parse_ns: u64) -> Option<SpanBuilder> {
        if self.sample == 0 {
            return None;
        }
        let n = self.sample_seq.fetch_add(1, Ordering::Relaxed);
        let sampled = n % self.sample as u64 == 0;
        let mut sp = SpanBuilder::with_start(self.ids.mint(), sampled, start);
        sp.add_phase(Phase::Parse, 0, parse_ns);
        Some(sp)
    }

    /// Request-latency snapshot (benches gate p99 off this).
    pub fn query_latency(&self) -> HistSnapshot {
        self.query_ns.snapshot()
    }

    /// Queue-wait snapshot (zero in the pool fallback).
    pub fn queue_wait(&self) -> HistSnapshot {
        self.queue_wait_ns.snapshot()
    }

    /// The `n` slowest spans currently in the ring, worst first.
    pub fn slow_spans(&self, n: usize) -> Vec<SpanRecord> {
        self.ring.slow(n)
    }

    /// `(recorded, dropped)` ring admission counters.
    pub fn span_counts(&self) -> (u64, u64) {
        (self.ring.recorded(), self.ring.dropped())
    }
}

/// Account one produced response — status-class counter, wire bytes,
/// and a query-latency sample — exactly once per response, at every
/// routed and parse-error site in both modes.  This is what keeps the
/// modes counter-identical and upholds the invariant
/// `query_ns.count == served + client_errors + server_errors`.
fn count_response(
    counters: &Counters,
    obs: &ServeObs,
    status: u16,
    wire_bytes: usize,
    total_ns: u64,
) {
    count_status(counters, status);
    counters
        .bytes_out
        .fetch_add(wire_bytes as u64, Ordering::Relaxed);
    obs.query_ns.record(total_ns);
}

/// Bump the status-class counter exactly once per produced response —
/// the one place both server modes and both execution paths (inline,
/// worker) count, so the modes stay counter-identical.
fn count_status(counters: &Counters, status: u16) {
    match status {
        200 => counters.served.fetch_add(1, Ordering::Relaxed),
        400..=499 => counters.client_errors.fetch_add(1, Ordering::Relaxed),
        _ => counters.server_errors.fetch_add(1, Ordering::Relaxed),
    };
}

/// `GBATC_NO_EPOLL=1` forces the thread-pool fallback on Linux too
/// (CI runs the serve suites in both modes).
fn epoll_disabled() -> bool {
    std::env::var("GBATC_NO_EPOLL")
        .map(|v| v == "1" || v.eq_ignore_ascii_case("true"))
        .unwrap_or(false)
}

/// A running server; see the module docs.
pub struct QueryServer {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    counters: Arc<Counters>,
    router: Arc<QueryRouter>,
    obs: Arc<ServeObs>,
    event_driven: bool,
}

impl QueryServer {
    /// Bind `addr` (e.g. `127.0.0.1:7070`, port `0` for ephemeral) and
    /// serve one store (wrapped as a 1-replica router).
    pub fn bind(store: Arc<ArchiveStore>, addr: &str, cfg: ServerConfig) -> Result<QueryServer> {
        Self::bind_router(Arc::new(QueryRouter::single(store)), addr, cfg)
    }

    /// Bind `addr` and serve a replica router.  Picks the epoll event
    /// loop when the platform has it (and `GBATC_NO_EPOLL` is unset),
    /// else the blocking thread-pool fallback — same protocol, same
    /// counters, either way.
    pub fn bind_router(
        router: Arc<QueryRouter>,
        addr: &str,
        cfg: ServerConfig,
    ) -> Result<QueryServer> {
        let listener =
            TcpListener::bind(addr).map_err(|e| Error::io_ctx(format!("binding {addr}"), e))?;
        let local = listener
            .local_addr()
            .map_err(|e| Error::io_ctx("resolving listener address", e))?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let counters = Arc::new(Counters::default());
        let obs = Arc::new(ServeObs::new(cfg.trace_sample));
        #[cfg(target_os = "linux")]
        {
            if !epoll_disabled() {
                if let (Ok(reactor), Ok(waker)) = (Reactor::new(), Waker::new()) {
                    return event::start(
                        listener, local, reactor, waker, router, counters, obs, shutdown, cfg,
                    );
                }
            }
        }
        Self::start_pool(listener, local, router, counters, obs, shutdown, cfg)
    }

    /// Blocking thread-pool fallback (also the only mode off Linux).
    fn start_pool(
        listener: TcpListener,
        addr: SocketAddr,
        router: Arc<QueryRouter>,
        counters: Arc<Counters>,
        obs: Arc<ServeObs>,
        shutdown: Arc<AtomicBool>,
        cfg: ServerConfig,
    ) -> Result<QueryServer> {
        let (tx, rx) = std::sync::mpsc::sync_channel::<TcpStream>(cfg.queue.max(1));
        let rx = Arc::new(Mutex::new(rx));
        let mut workers = Vec::with_capacity(cfg.workers.max(1));
        for i in 0..cfg.workers.max(1) {
            let rx = Arc::clone(&rx);
            let router = Arc::clone(&router);
            let counters = Arc::clone(&counters);
            let obs = Arc::clone(&obs);
            let shutdown = Arc::clone(&shutdown);
            let handle = std::thread::Builder::new()
                .name(format!("gbatc-serve-{i}"))
                .spawn(move || pool_worker_loop(rx, router, counters, obs, cfg, shutdown))
                .map_err(|e| Error::io_ctx("spawning server worker", e))?;
            workers.push(handle);
        }
        let accept = {
            let shutdown = Arc::clone(&shutdown);
            let counters = Arc::clone(&counters);
            std::thread::Builder::new()
                .name("gbatc-serve-accept".to_string())
                .spawn(move || accept_loop(listener, tx, shutdown, counters))
                .map_err(|e| Error::io_ctx("spawning accept thread", e))?
        };
        Ok(QueryServer {
            addr,
            shutdown,
            accept: Some(accept),
            workers,
            counters,
            router,
            obs,
            event_driven: false,
        })
    }

    /// The bound address (resolves port `0`).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Whether the epoll event loop is serving (false: thread-pool
    /// fallback).
    pub fn event_driven(&self) -> bool {
        self.event_driven
    }

    /// The router this server fronts (replica counters live here).
    pub fn router(&self) -> &Arc<QueryRouter> {
        &self.router
    }

    /// Counter snapshot (also served at `/stats`).
    pub fn stats(&self) -> ServeStats {
        self.counters.snapshot()
    }

    /// Server-side observability: latency histograms, slow-span ring.
    pub fn obs(&self) -> &ServeObs {
        &self.obs
    }

    /// Graceful shutdown: stop accepting, finish every admitted
    /// request, flush every response, join every thread.  Returns the
    /// final counters.
    pub fn shutdown(mut self) -> ServeStats {
        self.request_stop();
        if let Some(j) = self.accept.take() {
            let _ = j.join();
        }
        for j in self.workers.drain(..) {
            let _ = j.join();
        }
        self.counters.snapshot()
    }

    fn request_stop(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // wake the loop (or the blocking accept) with a throwaway
        // connection
        let _ = TcpStream::connect(self.addr);
    }
}

impl Drop for QueryServer {
    fn drop(&mut self) {
        // dropped without `shutdown()`: stop accepting and let the
        // threads drain; joining here could block an unwinding thread,
        // so the handles are simply released
        if self.accept.is_some() {
            self.request_stop();
        }
    }
}

// ---- thread-pool fallback --------------------------------------------

fn accept_loop(
    listener: TcpListener,
    tx: SyncSender<TcpStream>,
    shutdown: Arc<AtomicBool>,
    counters: Arc<Counters>,
) {
    loop {
        let conn = match listener.accept() {
            Ok((conn, _)) => conn,
            Err(_) => {
                if shutdown.load(Ordering::SeqCst) {
                    break;
                }
                std::thread::sleep(Duration::from_millis(10));
                continue;
            }
        };
        if shutdown.load(Ordering::SeqCst) {
            break; // the wake-up connection itself lands here
        }
        counters.accepted.fetch_add(1, Ordering::Relaxed);
        match tx.try_send(conn) {
            Ok(()) => {}
            Err(TrySendError::Full(mut conn)) => {
                counters.rejected_queue_full.fetch_add(1, Ordering::Relaxed);
                let bytes = http::serialize_response(
                    503,
                    JSON,
                    &[],
                    json_error("request queue full, retry later").as_bytes(),
                    false,
                );
                // a pre-parse rejection, not a routed response: bytes
                // are accounted but no status class / latency sample
                counters
                    .bytes_out
                    .fetch_add(bytes.len() as u64, Ordering::Relaxed);
                let _ = conn.write_all(&bytes);
            }
            Err(TrySendError::Disconnected(_)) => break,
        }
    }
    // dropping `tx` here disconnects the workers once the queue drains
}

fn pool_worker_loop(
    rx: Arc<Mutex<Receiver<TcpStream>>>,
    router: Arc<QueryRouter>,
    counters: Arc<Counters>,
    obs: Arc<ServeObs>,
    cfg: ServerConfig,
    shutdown: Arc<AtomicBool>,
) {
    loop {
        // hold the receiver lock only for the dequeue, not the requests
        let conn = {
            let guard = match rx.lock() {
                Ok(g) => g,
                Err(poisoned) => poisoned.into_inner(),
            };
            guard.recv()
        };
        let mut conn = match conn {
            Ok(c) => c,
            Err(_) => break, // accept loop gone and queue drained
        };
        counters.active_conns.fetch_add(1, Ordering::Relaxed);
        serve_pool_conn(&mut conn, &router, &counters, &obs, &cfg, &shutdown);
        counters.active_conns.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Serve one connection end to end on a worker thread: keep-alive loop
/// through the same incremental parser as the event loop.  Reads poll
/// with a short timeout so an idle keep-alive client neither wedges
/// graceful shutdown nor outlives the idle deadline.  Every outcome
/// lands in a counter; nothing here panics or kills the worker.
fn serve_pool_conn(
    conn: &mut TcpStream,
    router: &QueryRouter,
    counters: &Counters,
    obs: &ServeObs,
    cfg: &ServerConfig,
    shutdown: &AtomicBool,
) {
    let _ = conn.set_nodelay(true);
    let poll_ms = cfg.read_timeout_ms.clamp(1, 250);
    let _ = conn.set_read_timeout(Some(Duration::from_millis(poll_ms)));
    let mut parser = HttpParser::new(cfg.max_head_bytes);
    let mut scratch = [0u8; 16 * 1024];
    let mut nreq = 0u64;
    let mut last_activity = Instant::now();
    loop {
        // answer everything already parseable before reading more
        loop {
            let t_parse = Instant::now();
            let parsed = parser.next_request();
            let parse_ns = t_parse.elapsed().as_nanos() as u64;
            match parsed {
                Ok(Some(req)) => {
                    nreq += 1;
                    if nreq > 1 {
                        counters.keepalive_reuse.fetch_add(1, Ordering::Relaxed);
                    }
                    if req.pipelined {
                        counters.pipelined.fetch_add(1, Ordering::Relaxed);
                    }
                    let keep = !req.close && !shutdown.load(Ordering::SeqCst);
                    let mut span = obs.begin_span(t_parse, parse_ns);
                    if let Some(sp) = span.as_mut() {
                        sp.set_target(&req.target());
                    }
                    let (status, content_type, extra, body) =
                        route(&req, router, counters, cfg, obs, span.as_mut());
                    if let Some(sp) = span.as_mut() {
                        sp.status = status;
                    }
                    let headers: Vec<(&str, &str)> =
                        extra.iter().map(|(k, v)| (k.as_str(), v.as_str())).collect();
                    let bytes =
                        http::serialize_response(status, content_type, &headers, &body, keep);
                    count_response(
                        counters,
                        obs,
                        status,
                        bytes.len(),
                        t_parse.elapsed().as_nanos() as u64,
                    );
                    let t_write = match span.as_ref() {
                        Some(sp) => sp.mark(),
                        None => 0,
                    };
                    if conn.write_all(&bytes).and_then(|_| conn.flush()).is_err() {
                        counters.io_errors.fetch_add(1, Ordering::Relaxed);
                        return;
                    }
                    if let Some(mut sp) = span {
                        let end = sp.mark();
                        sp.add_phase(Phase::Write, t_write, end.saturating_sub(t_write));
                        if sp.sampled {
                            obs.ring.push(sp.finish());
                        }
                    }
                    last_activity = Instant::now();
                    if !keep {
                        if parser.has_buffered_data() {
                            drain(conn);
                        }
                        return;
                    }
                }
                Ok(None) => break,
                Err(Error::Protocol(msg)) => {
                    let status = if msg.starts_with(http::OVERSIZE_MARK) {
                        431
                    } else {
                        400
                    };
                    let bytes = http::serialize_response(
                        status,
                        JSON,
                        &[],
                        json_error(&msg).as_bytes(),
                        false,
                    );
                    count_response(counters, obs, status, bytes.len(), parse_ns);
                    if conn.write_all(&bytes).and_then(|_| conn.flush()).is_err() {
                        counters.io_errors.fetch_add(1, Ordering::Relaxed);
                    }
                    // the stream can't be re-synchronized; drain what the
                    // client is still sending so close() sends FIN, not
                    // RST (an RST can destroy the error response in
                    // flight)
                    drain(conn);
                    return;
                }
                Err(_) => return,
            }
        }
        match conn.read(&mut scratch) {
            Ok(0) => {
                if parser.has_buffered_data() {
                    // died mid-request (partial head / body)
                    counters.io_errors.fetch_add(1, Ordering::Relaxed);
                }
                return;
            }
            Ok(n) => {
                parser.feed(&scratch[..n]);
                last_activity = Instant::now();
            }
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                if shutdown.load(Ordering::SeqCst) {
                    return; // graceful: drop the idle keep-alive
                }
                if last_activity.elapsed().as_millis() >= cfg.read_timeout_ms as u128 {
                    if nreq == 0 {
                        counters.io_errors.fetch_add(1, Ordering::Relaxed);
                    } else {
                        counters.reaped_idle.fetch_add(1, Ordering::Relaxed);
                    }
                    return;
                }
            }
            Err(_) => {
                counters.io_errors.fetch_add(1, Ordering::Relaxed);
                return;
            }
        }
    }
}

/// Read and discard whatever request bytes are still arriving, bounded
/// in time and volume, so the socket closes cleanly (FIN) with an empty
/// receive queue.
fn drain(conn: &mut TcpStream) {
    let _ = conn.set_read_timeout(Some(Duration::from_millis(200)));
    let mut scratch = [0u8; 4096];
    for _ in 0..64 {
        match conn.read(&mut scratch) {
            Ok(0) | Err(_) => break,
            Ok(_) => {}
        }
    }
}

// ---- request routing (shared by both modes) --------------------------

type Routed = (u16, &'static str, Vec<(String, String)>, Vec<u8>);

fn route(
    req: &Request,
    router: &QueryRouter,
    counters: &Counters,
    cfg: &ServerConfig,
    obs: &ServeObs,
    mut span: Option<&mut SpanBuilder>,
) -> Routed {
    let trace_id = span.as_ref().map(|sp| sp.trace_id);
    let mut routed: Routed = if req.method != "GET" {
        (
            405,
            JSON,
            Vec::new(),
            json_error("only GET is supported").into_bytes(),
        )
    } else {
        match req.path.as_str() {
            "/datasets" => (200, JSON, Vec::new(), datasets_json(router).into_bytes()),
            "/stats" => (
                200,
                JSON,
                Vec::new(),
                stats_json(router, counters).into_bytes(),
            ),
            "/metrics" => (
                200,
                PROM,
                Vec::new(),
                metrics_text(router, counters, obs).into_bytes(),
            ),
            "/trace/slow" => {
                let n = req
                    .param("n")
                    .and_then(|v| v.parse::<usize>().ok())
                    .unwrap_or(16)
                    .min(256);
                (200, JSON, Vec::new(), trace_slow_json(obs, n).into_bytes())
            }
            "/query" => handle_query(req, router, cfg.max_response_bytes, span.as_deref_mut()),
            other => (
                404,
                JSON,
                Vec::new(),
                json_error(&format!(
                    "no endpoint `{other}` (try /datasets, /query, /stats, /metrics, /trace/slow)"
                ))
                .into_bytes(),
            ),
        }
    };
    // every routed response advertises its trace ID when tracing is on,
    // sampled into the ring or not — the client can always correlate
    if let Some(id) = trace_id {
        routed
            .2
            .push((http::TRACE_ID_HEADER.to_string(), format!("{id:016x}")));
    }
    routed
}

fn parse_opt_usize(req: &Request, key: &str) -> Result<Option<usize>> {
    match req.param(key) {
        None | Some("") => Ok(None),
        Some(v) => v
            .parse()
            .map(Some)
            .map_err(|e| Error::protocol(format!("query parameter {key}={v}: {e}"))),
    }
}

/// Parse the `/query` parameters far enough to know the dataset, the
/// typed query, and the response size.  `None` means the request will
/// fail (or be capped) before any decode — always cheap to answer
/// inline.
fn query_plan(req: &Request, router: &QueryRouter) -> Option<(String, Query, usize)> {
    let dataset = match req.param("dataset") {
        Some(d) if !d.is_empty() => d,
        _ => return None,
    };
    let info = router.dataset_info(dataset).ok()?;
    let t0 = parse_opt_usize(req, "t0").ok()?.unwrap_or(0);
    let t1 = parse_opt_usize(req, "t1").ok()?.unwrap_or(info.dims.0);
    let species = SpeciesSel::parse(req.param("species").unwrap_or(""));
    let (_, ns, ny, nx) = info.dims;
    let nsel = species.resolve(ns).ok()?.len();
    let want = t1
        .saturating_sub(t0)
        .saturating_mul(nsel)
        .saturating_mul(ny)
        .saturating_mul(nx)
        .saturating_mul(4);
    Some((
        dataset.to_string(),
        Query {
            time: t0..t1,
            species,
        },
        want,
    ))
}

fn handle_query(
    req: &Request,
    router: &QueryRouter,
    max_response_bytes: usize,
    mut span: Option<&mut SpanBuilder>,
) -> Routed {
    let bad = |msg: &str| (400, JSON, Vec::new(), json_error(msg).into_bytes());
    let dataset = match req.param("dataset") {
        Some(d) if !d.is_empty() => d,
        _ => return bad("missing dataset parameter"),
    };
    let info = match router.dataset_info(dataset) {
        Ok(i) => i,
        // a missing mount is the client's 404; anything else (e.g. a
        // poisoned mount table) is a server-side 500, not a fake 404
        Err(Error::Config(msg)) => return (404, JSON, Vec::new(), json_error(&msg).into_bytes()),
        Err(e) => return (500, JSON, Vec::new(), json_error(&e.to_string()).into_bytes()),
    };
    let (t0, t1) = match (parse_opt_usize(req, "t0"), parse_opt_usize(req, "t1")) {
        (Ok(t0), Ok(t1)) => (t0.unwrap_or(0), t1.unwrap_or(info.dims.0)),
        (Err(e), _) | (_, Err(e)) => return bad(&e.to_string()),
    };
    let species = SpeciesSel::parse(req.param("species").unwrap_or(""));
    // bound the response volume before any decode
    let (_, ns, ny, nx) = info.dims;
    let nsel = match species.resolve(ns) {
        Ok(sel) => sel.len(),
        Err(e) => return bad(&e.to_string()),
    };
    let want = t1
        .saturating_sub(t0)
        .saturating_mul(nsel)
        .saturating_mul(ny)
        .saturating_mul(nx)
        .saturating_mul(4);
    if want > max_response_bytes {
        return (
            413,
            JSON,
            Vec::new(),
            json_error(&format!(
                "response would be {want} bytes (cap {max_response_bytes}); \
                 narrow t0/t1 or the species list"
            ))
            .into_bytes(),
        );
    }
    let q = Query {
        time: t0..t1,
        species,
    };
    match router.query_traced(dataset, &q, span.as_deref_mut()) {
        Ok(dec) => {
            // strict clients would rather fail than read salvaged data
            if req.strict && !dec.degraded.is_empty() {
                return (
                    503,
                    JSON,
                    Vec::new(),
                    json_error(&format!(
                        "strict query touches {} quarantined section(s); \
                         repair the archive or retry without X-Gbatc-Strict",
                        dec.degraded.len()
                    ))
                    .into_bytes(),
                );
            }
            let t_ser = Instant::now();
            let mut meta = format!(
                "{{\"dataset\":\"{}\",\"t0\":{},\"nt\":{},\"ny\":{},\"nx\":{},\"species\":{},\
                 \"nrmse_target\":{:e},\"pressure\":{:e}}}",
                json_escape(dataset),
                dec.t0,
                dec.nt,
                dec.ny,
                dec.nx,
                json_usize_list(&dec.species),
                info.nrmse_target,
                info.pressure
            );
            // healthy responses keep the exact historical meta bytes;
            // degraded ones append their fields before the closing brace
            if !dec.degraded.is_empty() {
                meta.pop();
                let mut secs = String::from("[");
                for (i, &(sh, sp)) in dec.degraded.iter().enumerate() {
                    if i > 0 {
                        secs.push(',');
                    }
                    secs.push_str(&format!("[{sh},{sp}]"));
                }
                secs.push(']');
                let bound = match dec.degraded_bound {
                    Some(b) => format!("{b:e}"),
                    None => "null".to_string(),
                };
                meta.push_str(&format!(
                    ",\"degraded\":true,\"degraded_sections\":{secs},\"degraded_bound\":{bound}}}"
                ));
            }
            let mut body = Vec::with_capacity(dec.mass.len() * 4);
            for v in &dec.mass {
                body.extend_from_slice(&v.to_le_bytes());
            }
            if let Some(sp) = span {
                let ser_ns = t_ser.elapsed().as_nanos() as u64;
                let end = sp.mark();
                sp.add_phase(Phase::Serialize, end.saturating_sub(ser_ns), ser_ns);
            }
            (200, BINARY, vec![("X-Gbatc-Meta".to_string(), meta)], body)
        }
        Err(e) => {
            let status = match e {
                // raced an unmount between the info lookup and the query
                Error::Config(_) if !router.contains(dataset) => 404,
                Error::Shape(_) | Error::Config(_) | Error::Protocol(_) => 400,
                _ => 500,
            };
            (
                status,
                JSON,
                Vec::new(),
                json_error(&e.to_string()).into_bytes(),
            )
        }
    }
}

fn datasets_json(router: &QueryRouter) -> String {
    let mut out = String::from("{\"datasets\":[");
    for (i, d) in router.datasets().iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let (nt, ns, ny, nx) = d.dims;
        out.push_str(&format!(
            "{{\"name\":\"{}\",\"nt\":{nt},\"ns\":{ns},\"ny\":{ny},\"nx\":{nx},\
             \"n_shards\":{},\"kt_window\":{},\"nrmse_target\":{:e},\"archive_bytes\":{}}}",
            json_escape(&d.name),
            d.n_shards,
            d.kt_window,
            d.nrmse_target,
            d.archive_bytes
        ));
    }
    out.push_str("]}");
    out
}

fn stats_json(router: &QueryRouter, counters: &Counters) -> String {
    let st = router.stats();
    let sv = counters.snapshot();
    let c = st.cache;
    let mut out = format!(
        "{{\"queries\":{},\"decoded_sections\":{},\"decoded_bytes\":{},\
         \"cache\":{{\"hits\":{},\"misses\":{},\"admitted\":{},\"rejected\":{},\
         \"evicted\":{},\"resident_sections\":{},\"resident_bytes\":{},\
         \"capacity_bytes\":{},\"lock_shards\":{}}},\
         \"server\":{{\"accepted\":{},\"served\":{},\"client_errors\":{},\
         \"server_errors\":{},\"rejected_queue_full\":{},\"io_errors\":{},\
         \"rejected_conn_cap\":{},\"keepalive_reuse\":{},\"reaped_idle\":{},\
         \"pipelined\":{},\"active_conns\":{},\"bytes_out\":{}}},\
         \"replicas\":[",
        st.queries,
        st.decoded_sections,
        st.decoded_bytes,
        c.hits,
        c.misses,
        c.admitted,
        c.rejected,
        c.evicted,
        c.resident_sections,
        c.resident_bytes,
        c.capacity_bytes,
        c.lock_shards,
        sv.accepted,
        sv.served,
        sv.client_errors,
        sv.server_errors,
        sv.rejected_queue_full,
        sv.io_errors,
        sv.rejected_conn_cap,
        sv.keepalive_reuse,
        sv.reaped_idle,
        sv.pipelined,
        sv.active_conns,
        sv.bytes_out
    );
    for (i, r) in router.replica_stats().iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "{{\"replica\":{i},\"queries\":{},\"cache_hits\":{},\"cache_misses\":{},\
             \"datasets\":{}}}",
            r.queries,
            r.cache.hits,
            r.cache.misses,
            r.datasets.len()
        ));
    }
    out.push_str("],\"datasets\":[");
    for (i, d) in st.datasets.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "{{\"name\":\"{}\",\"archive_bytes\":{},\"toc_reads\":{},\"toc_bytes\":{},\
             \"payload_reads\":{},\"payload_bytes\":{}}}",
            json_escape(&d.name),
            d.archive_bytes,
            d.io.toc_reads,
            d.io.toc_bytes,
            d.io.payload_reads,
            d.io.payload_bytes
        ));
    }
    out.push_str("]}");
    out
}

/// `GET /metrics` — Prometheus text exposition format 0.0.4.
fn metrics_text(router: &QueryRouter, counters: &Counters, obs: &ServeObs) -> String {
    let sv = counters.snapshot();
    let st = router.stats();
    let store = router.obs_snapshot();
    let (recorded, dropped) = obs.span_counts();
    let mut out = String::with_capacity(4096);
    prom::render_histogram(
        &mut out,
        "gbatc_query_seconds",
        "Request latency, parse start to response produced",
        &obs.query_latency(),
    );
    prom::render_histogram(
        &mut out,
        "gbatc_queue_wait_seconds",
        "Decode-job queue wait at worker dequeue (event mode)",
        &obs.queue_wait(),
    );
    prom::render_histogram(
        &mut out,
        "gbatc_decode_seconds",
        "Engine decode passes inside the store",
        &store.decode_ns,
    );
    prom::render_histogram(
        &mut out,
        "gbatc_cache_probe_seconds",
        "Per-query section-cache probe time",
        &store.probe_ns,
    );
    prom::render_counter_family(
        &mut out,
        "gbatc_responses_total",
        "Responses produced, by status class",
        "class",
        &[
            ("2xx", sv.served),
            ("4xx", sv.client_errors),
            ("5xx", sv.server_errors),
        ],
    );
    prom::render_counter(
        &mut out,
        "gbatc_bytes_out_total",
        "Response bytes written to the wire",
        sv.bytes_out,
    );
    prom::render_counter(
        &mut out,
        "gbatc_connections_accepted_total",
        "Connections accepted",
        sv.accepted,
    );
    prom::render_counter_family(
        &mut out,
        "gbatc_rejections_total",
        "Requests or connections refused with 503",
        "reason",
        &[
            ("queue_full", sv.rejected_queue_full),
            ("conn_cap", sv.rejected_conn_cap),
        ],
    );
    prom::render_counter_family(
        &mut out,
        "gbatc_cache_lookups_total",
        "Section-cache lookups, by outcome",
        "outcome",
        &[("hit", st.cache.hits), ("miss", st.cache.misses)],
    );
    prom::render_counter_family(
        &mut out,
        "gbatc_trace_spans_total",
        "Trace spans offered to the slow-query ring",
        "outcome",
        &[("recorded", recorded), ("dropped", dropped)],
    );
    prom::render_gauge(
        &mut out,
        "gbatc_active_connections",
        "Connections currently open",
        sv.active_conns,
    );
    out
}

/// `GET /trace/slow` — the `n` worst spans with per-phase breakdowns.
fn trace_slow_json(obs: &ServeObs, n: usize) -> String {
    let spans = obs.slow_spans(n);
    let (recorded, dropped) = obs.span_counts();
    let mut out = format!("{{\"recorded\":{recorded},\"dropped\":{dropped},\"spans\":[");
    for (i, sp) in spans.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "{{\"trace_id\":\"{:016x}\",\"target\":\"{}\",\"status\":{},\"total_ns\":{},\
             \"phases\":{{",
            sp.trace_id,
            json_escape(sp.target()),
            sp.status,
            sp.total_ns
        ));
        let mut first = true;
        for ph in Phase::ALL {
            let (start, dur) = sp.phases[ph as usize];
            if start == 0 && dur == 0 {
                continue; // phase never entered
            }
            if !first {
                out.push(',');
            }
            first = false;
            out.push_str(&format!(
                "\"{}\":{{\"start_ns\":{start},\"dur_ns\":{dur}}}",
                ph.name()
            ));
        }
        out.push_str("}}");
    }
    out.push_str("]}");
    out
}

// ---- event-driven implementation (Linux) -----------------------------

#[cfg(target_os = "linux")]
mod event {
    use std::collections::VecDeque;
    use std::io::{Read, Write};
    use std::net::{SocketAddr, TcpListener};
    use std::os::unix::io::AsRawFd;
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
    use std::sync::{Arc, Mutex};
    use std::time::Instant;

    use crate::error::{Error, Result};
    use crate::obs::{Phase, SpanBuilder, SpanRecord};
    use crate::serve::conn::{Conn, ReadOutcome};
    use crate::serve::http::{self, json_error, Request};
    use crate::serve::reactor::{Event, Reactor, Waker};
    use crate::serve::router::QueryRouter;

    use super::{count_response, route, Counters, QueryServer, ServeObs, ServerConfig, JSON};

    /// Reserved tokens: real connection tokens are `slot | gen << 32`
    /// with `slot < max_conns`, so they can never collide with these.
    const TOKEN_LISTENER: u64 = u64::MAX;
    const TOKEN_WAKER: u64 = u64::MAX - 1;

    fn token_of(slot: usize, generation: u32) -> u64 {
        (slot as u64 & 0xffff_ffff) | ((generation as u64) << 32)
    }

    fn token_slot(token: u64) -> usize {
        (token & 0xffff_ffff) as usize
    }

    fn token_gen(token: u64) -> u32 {
        (token >> 32) as u32
    }

    /// One offloaded request on its way to a decode worker.
    struct Job {
        token: u64,
        seq: u64,
        keep_alive: bool,
        req: Request,
        /// Parse start on the reactor — the latency histogram measures
        /// from here, so queue wait is part of the client-visible time.
        t0: Instant,
        /// Enqueue instant; worker dequeue minus this is queue wait.
        enqueued: Instant,
        span: Option<SpanBuilder>,
    }

    /// One serialized response on its way back to the reactor.
    struct Done {
        token: u64,
        seq: u64,
        bytes: Vec<u8>,
        /// Sampled span riding home to finish after its bytes flush.
        span: Option<SpanBuilder>,
    }

    /// Build the reactor thread + decode workers and hand back the
    /// running server.
    #[allow(clippy::too_many_arguments)]
    pub(super) fn start(
        listener: TcpListener,
        addr: SocketAddr,
        reactor: Reactor,
        waker: Waker,
        router: Arc<QueryRouter>,
        counters: Arc<Counters>,
        obs: Arc<ServeObs>,
        shutdown: Arc<AtomicBool>,
        cfg: ServerConfig,
    ) -> Result<QueryServer> {
        listener
            .set_nonblocking(true)
            .map_err(|e| Error::io_ctx("setting listener nonblocking", e))?;
        reactor.add(listener.as_raw_fd(), TOKEN_LISTENER, true, false)?;
        let waker = Arc::new(waker);
        reactor.add(waker.fd(), TOKEN_WAKER, true, false)?;

        let (jobs_tx, jobs_rx) = sync_channel::<Job>(cfg.queue.max(1));
        let jobs_rx = Arc::new(Mutex::new(jobs_rx));
        let done: Arc<Mutex<VecDeque<Done>>> = Arc::new(Mutex::new(VecDeque::new()));

        let mut workers = Vec::with_capacity(cfg.workers.max(1));
        for i in 0..cfg.workers.max(1) {
            let jobs_rx = Arc::clone(&jobs_rx);
            let router = Arc::clone(&router);
            let counters = Arc::clone(&counters);
            let obs = Arc::clone(&obs);
            let done = Arc::clone(&done);
            let waker = Arc::clone(&waker);
            let handle = std::thread::Builder::new()
                .name(format!("gbatc-serve-{i}"))
                .spawn(move || decode_worker(jobs_rx, router, counters, obs, cfg, done, waker))
                .map_err(|e| Error::io_ctx("spawning decode worker", e))?;
            workers.push(handle);
        }

        let ev = EventLoop {
            reactor,
            waker,
            listener,
            router: Arc::clone(&router),
            counters: Arc::clone(&counters),
            obs: Arc::clone(&obs),
            cfg,
            jobs: jobs_tx,
            done,
            conns: Vec::new(),
            free: Vec::new(),
            active: 0,
            next_gen: 0,
            read_meter: 0,
            jobs_inflight: 0,
            closing: false,
            meter_parked: Vec::new(),
            scratch: vec![0u8; 16 * 1024],
            span_scratch: Vec::new(),
        };
        let accept = {
            let shutdown = Arc::clone(&shutdown);
            std::thread::Builder::new()
                .name("gbatc-serve-reactor".to_string())
                .spawn(move || ev.run(shutdown))
                .map_err(|e| Error::io_ctx("spawning reactor thread", e))?
        };
        Ok(QueryServer {
            addr,
            shutdown,
            accept: Some(accept),
            workers,
            counters,
            router,
            obs,
            event_driven: true,
        })
    }

    #[allow(clippy::too_many_arguments)]
    fn decode_worker(
        rx: Arc<Mutex<Receiver<Job>>>,
        router: Arc<QueryRouter>,
        counters: Arc<Counters>,
        obs: Arc<ServeObs>,
        cfg: ServerConfig,
        done: Arc<Mutex<VecDeque<Done>>>,
        waker: Arc<Waker>,
    ) {
        loop {
            // hold the receiver lock only for the dequeue
            let job = {
                let guard = match rx.lock() {
                    Ok(g) => g,
                    Err(poisoned) => poisoned.into_inner(),
                };
                guard.recv()
            };
            let Ok(mut job) = job else { break }; // reactor gone, queue drained
            let wait_ns = job.enqueued.elapsed().as_nanos() as u64;
            obs.queue_wait_ns.record(wait_ns);
            if let Some(sp) = job.span.as_mut() {
                let end = sp.mark();
                sp.add_phase(Phase::QueueWait, end.saturating_sub(wait_ns), wait_ns);
            }
            let (status, content_type, extra, body) =
                route(&job.req, &router, &counters, &cfg, &obs, job.span.as_mut());
            if let Some(sp) = job.span.as_mut() {
                sp.status = status;
            }
            let headers: Vec<(&str, &str)> =
                extra.iter().map(|(k, v)| (k.as_str(), v.as_str())).collect();
            let bytes = http::serialize_response(status, content_type, &headers, &body, job.keep_alive);
            count_response(
                &counters,
                &obs,
                status,
                bytes.len(),
                job.t0.elapsed().as_nanos() as u64,
            );
            {
                let mut guard = match done.lock() {
                    Ok(g) => g,
                    Err(poisoned) => poisoned.into_inner(),
                };
                guard.push_back(Done {
                    token: job.token,
                    seq: job.seq,
                    bytes,
                    span: job.span.filter(|sp| sp.sampled),
                });
            }
            waker.wake();
        }
    }

    struct EventLoop {
        reactor: Reactor,
        waker: Arc<Waker>,
        listener: TcpListener,
        router: Arc<QueryRouter>,
        counters: Arc<Counters>,
        obs: Arc<ServeObs>,
        cfg: ServerConfig,
        jobs: SyncSender<Job>,
        done: Arc<Mutex<VecDeque<Done>>>,
        /// Connection slab; tokens carry `slot | generation << 32`.
        conns: Vec<Option<Conn>>,
        free: Vec<usize>,
        active: usize,
        next_gen: u32,
        /// Sum of all parsers' buffered bytes (global admission meter).
        read_meter: usize,
        jobs_inflight: usize,
        closing: bool,
        /// Tokens whose read interest was parked purely by the global
        /// meter; resumed when it drops below the cap.
        meter_parked: Vec<u64>,
        scratch: Vec<u8>,
        /// Reusable buffer for harvesting flushed spans off a conn.
        span_scratch: Vec<SpanRecord>,
    }

    impl EventLoop {
        fn conn_read_cap(&self) -> usize {
            // room for a head plus a fat pipelined batch behind it
            self.cfg.max_head_bytes.saturating_mul(2)
        }

        fn run(mut self, shutdown: Arc<AtomicBool>) {
            let mut events: Vec<Event> = Vec::new();
            let mut rot = 0usize;
            let mut last_reap = Instant::now();
            let reap_every = (self.cfg.read_timeout_ms / 4).clamp(50, 1000) as u128;
            loop {
                if shutdown.load(Ordering::SeqCst) && !self.closing {
                    self.begin_close();
                }
                if self.closing && self.active == 0 && self.jobs_inflight == 0 {
                    break;
                }
                events.clear();
                if self.reactor.wait(&mut events, 100).is_err() {
                    break;
                }
                // round-robin fairness: start each batch at a rotating
                // offset so one busy fd at the front of the epoll batch
                // cannot monopolize the loop
                let n = events.len();
                for k in 0..n {
                    let ev = events[(rot + k) % n];
                    if ev.token == TOKEN_LISTENER {
                        self.accept_burst();
                    } else if ev.token == TOKEN_WAKER {
                        self.waker.drain();
                        self.apply_done();
                    } else {
                        let slot = token_slot(ev.token);
                        let valid = matches!(
                            self.conns.get(slot),
                            Some(Some(c)) if c.generation == token_gen(ev.token)
                        );
                        if valid {
                            self.pump_io(slot, ev.readable || ev.hangup);
                        }
                    }
                }
                if n > 0 {
                    rot = rot.wrapping_add(1);
                }
                self.apply_done();
                self.resume_parked();
                let now = Instant::now();
                if now.duration_since(last_reap).as_millis() >= reap_every {
                    last_reap = now;
                    self.reap(now);
                }
            }
            // dropping `self.jobs` disconnects the decode workers
        }

        /// Accept everything pending (level-triggered listener).
        fn accept_burst(&mut self) {
            loop {
                let stream = match self.listener.accept() {
                    Ok((stream, _)) => stream,
                    Err(_) => break, // WouldBlock, or transient
                };
                if self.closing {
                    continue; // shutdown wake / raced connects: drop
                }
                self.counters.accepted.fetch_add(1, Ordering::Relaxed);
                if self.active >= self.cfg.max_conns {
                    self.counters.rejected_conn_cap.fetch_add(1, Ordering::Relaxed);
                    let mut s = stream;
                    let _ = s.set_nodelay(true);
                    let bytes = http::serialize_response(
                        503,
                        JSON,
                        &[],
                        json_error("connection limit reached, retry later").as_bytes(),
                        false,
                    );
                    self.counters
                        .bytes_out
                        .fetch_add(bytes.len() as u64, Ordering::Relaxed);
                    // fresh socket, empty send buffer: this tiny write
                    // won't block meaningfully
                    let _ = s.write_all(&bytes);
                    continue;
                }
                let _ = stream.set_nodelay(true);
                if stream.set_nonblocking(true).is_err() {
                    continue;
                }
                self.next_gen = self.next_gen.wrapping_add(1);
                let generation = self.next_gen;
                let slot = match self.free.pop() {
                    Some(s) => s,
                    None => {
                        self.conns.push(None);
                        self.conns.len() - 1
                    }
                };
                let token = token_of(slot, generation);
                let mut conn =
                    Conn::new(stream, self.cfg.max_head_bytes, generation, Instant::now());
                if self
                    .reactor
                    .add(conn.stream.as_raw_fd(), token, true, false)
                    .is_err()
                {
                    self.free.push(slot);
                    continue;
                }
                conn.reg_read = true;
                self.active += 1;
                self.counters.active_conns.fetch_add(1, Ordering::Relaxed);
                self.conns[slot] = Some(conn);
            }
        }

        /// Run one connection's state machine: optional read, parse +
        /// dispatch, flush, close-or-rearm.
        fn pump_io(&mut self, slot: usize, do_read: bool) {
            let Some(conn_opt) = self.conns.get_mut(slot) else {
                return;
            };
            let Some(mut conn) = conn_opt.take() else {
                return;
            };
            let token = token_of(slot, conn.generation);
            if self.drive(token, &mut conn, do_read) {
                self.update_interest(token, &mut conn);
                self.conns[slot] = Some(conn);
            } else {
                self.release(slot, conn);
            }
        }

        /// The state machine body.  Returns whether the connection
        /// stays alive.
        fn drive(&mut self, token: u64, conn: &mut Conn, do_read: bool) -> bool {
            let now = Instant::now();
            let mut activity = false;
            if do_read && !conn.close_after && !conn.peer_eof {
                loop {
                    // global meter, adjusted for this conn's stale share
                    let meter = self.read_meter - conn.metered + conn.parser.buffered();
                    if meter >= self.cfg.read_buf_bytes {
                        break;
                    }
                    if conn.parser.buffered() >= self.conn_read_cap() {
                        break;
                    }
                    match conn.read_some(&mut self.scratch) {
                        ReadOutcome::Data(_) => activity = true,
                        ReadOutcome::WouldBlock => break,
                        ReadOutcome::Closed => {
                            conn.peer_eof = true;
                            break;
                        }
                    }
                }
            }
            // parse + dispatch up to the per-conn caps
            loop {
                if conn.close_after
                    || conn.inflight >= self.cfg.max_inflight
                    || conn.write_backlog() >= self.cfg.write_buf_bytes
                {
                    break;
                }
                let t_parse = Instant::now();
                let parsed = conn.parser.next_request();
                let parse_ns = t_parse.elapsed().as_nanos() as u64;
                match parsed {
                    Ok(Some(req)) => {
                        activity = true;
                        if req.pipelined {
                            self.counters.pipelined.fetch_add(1, Ordering::Relaxed);
                        }
                        let seq = conn.begin_request();
                        if conn.requests > 1 {
                            self.counters.keepalive_reuse.fetch_add(1, Ordering::Relaxed);
                        }
                        let keep_alive = !req.close && !self.closing;
                        if req.close || self.closing {
                            conn.close_after = true;
                        }
                        let mut span = self.obs.begin_span(t_parse, parse_ns);
                        if let Some(sp) = span.as_mut() {
                            sp.set_target(&req.target());
                        }
                        self.dispatch(token, conn, seq, req, keep_alive, t_parse, span);
                    }
                    Ok(None) => break,
                    Err(Error::Protocol(msg)) => {
                        activity = true;
                        let status = if msg.starts_with(http::OVERSIZE_MARK) {
                            431
                        } else {
                            400
                        };
                        let seq = conn.begin_request();
                        let bytes = http::serialize_response(
                            status,
                            JSON,
                            &[],
                            json_error(&msg).as_bytes(),
                            false,
                        );
                        count_response(&self.counters, &self.obs, status, bytes.len(), parse_ns);
                        conn.complete(seq, bytes);
                        conn.close_after = true;
                        break;
                    }
                    Err(_) => {
                        conn.close_after = true;
                        break;
                    }
                }
            }
            // settle this conn's share of the global read meter
            let buffered = conn.parser.buffered();
            self.read_meter = self.read_meter - conn.metered + buffered;
            conn.metered = buffered;
            // flush whatever is ready, in order
            let backlog_before = conn.write_backlog();
            match conn.flush() {
                Ok(_) => {
                    if conn.write_backlog() != backlog_before {
                        activity = true;
                    }
                }
                Err(_) => {
                    self.counters.io_errors.fetch_add(1, Ordering::Relaxed);
                    return false;
                }
            }
            // spans whose responses have fully drained finish here, on
            // the reactor: a bounded pop loop plus a try_lock ring push
            self.span_scratch.clear();
            conn.take_finished_spans(&mut self.span_scratch);
            for rec in self.span_scratch.drain(..) {
                self.obs.ring.push(rec);
            }
            if activity {
                conn.last_activity = now;
            }
            if conn.close_after && conn.drained() {
                return false;
            }
            if conn.peer_eof && conn.inflight == 0 && conn.drained() {
                if conn.parser.has_buffered_data() {
                    // FIN behind a partial request: died mid-request
                    self.counters.io_errors.fetch_add(1, Ordering::Relaxed);
                }
                return false;
            }
            true
        }

        /// Answer one admitted request: offload cold `/query` decodes to
        /// the worker pool, everything else (catalog, stats, errors, and
        /// cache-warm queries under the inline cap) inline right here.
        #[allow(clippy::too_many_arguments)]
        fn dispatch(
            &mut self,
            token: u64,
            conn: &mut Conn,
            seq: u64,
            req: Request,
            keep_alive: bool,
            t0: Instant,
            span: Option<SpanBuilder>,
        ) {
            let (req, mut span) = if self.should_offload(&req) {
                match self.jobs.try_send(Job {
                    token,
                    seq,
                    keep_alive,
                    req,
                    t0,
                    enqueued: Instant::now(),
                    span,
                }) {
                    Ok(()) => {
                        self.jobs_inflight += 1;
                        return;
                    }
                    Err(TrySendError::Full(_)) => {
                        self.counters
                            .rejected_queue_full
                            .fetch_add(1, Ordering::Relaxed);
                        let bytes = http::serialize_response(
                            503,
                            JSON,
                            &[],
                            json_error("request queue full, retry later").as_bytes(),
                            keep_alive,
                        );
                        // pre-route rejection: bytes accounted, no
                        // status class / latency sample (matches the
                        // pool fallback's pre-parse queue rejection)
                        self.counters
                            .bytes_out
                            .fetch_add(bytes.len() as u64, Ordering::Relaxed);
                        conn.complete(seq, bytes);
                        return;
                    }
                    // workers gone (tearing down): answer inline
                    Err(TrySendError::Disconnected(job)) => (job.req, job.span),
                }
            } else {
                (req, span)
            };
            let (status, content_type, extra, body) =
                route(&req, &self.router, &self.counters, &self.cfg, &self.obs, span.as_mut());
            if let Some(sp) = span.as_mut() {
                sp.status = status;
            }
            let headers: Vec<(&str, &str)> =
                extra.iter().map(|(k, v)| (k.as_str(), v.as_str())).collect();
            let bytes = http::serialize_response(status, content_type, &headers, &body, keep_alive);
            count_response(
                &self.counters,
                &self.obs,
                status,
                bytes.len(),
                t0.elapsed().as_nanos() as u64,
            );
            conn.complete_traced(seq, bytes, span.filter(|sp| sp.sampled));
        }

        /// A request goes to the worker pool only when it will actually
        /// decode: a well-formed, under-cap `/query` that is not
        /// cache-warm-and-small.  Everything else is cheap inline.
        fn should_offload(&self, req: &Request) -> bool {
            if req.method != "GET" || req.path != "/query" {
                return false;
            }
            let Some((dataset, q, want)) = super::query_plan(req, &self.router) else {
                return false; // will 4xx before any decode
            };
            if want > self.cfg.max_response_bytes {
                return false; // 413 inline
            }
            if want <= self.cfg.inline_warm_bytes && self.router.is_warm(&dataset, &q) {
                return false; // warm fast path: serve from the loop
            }
            true
        }

        /// Apply every completed worker response, then pump the owning
        /// connections (which may unthrottle their reads).
        fn apply_done(&mut self) {
            loop {
                let next = {
                    let mut guard = match self.done.lock() {
                        Ok(g) => g,
                        Err(poisoned) => poisoned.into_inner(),
                    };
                    guard.pop_front()
                };
                let Some(Done {
                    token,
                    seq,
                    bytes,
                    span,
                }) = next
                else {
                    break;
                };
                self.jobs_inflight = self.jobs_inflight.saturating_sub(1);
                let slot = token_slot(token);
                let mut landed = false;
                if let Some(Some(conn)) = self.conns.get_mut(slot) {
                    if conn.generation == token_gen(token) {
                        conn.complete_traced(seq, bytes, span);
                        landed = true;
                    }
                }
                // a stale token means the conn died mid-decode; the
                // response is simply dropped
                if landed {
                    self.pump_io(slot, false);
                }
            }
        }

        /// Re-pump connections parked by the global read meter once it
        /// has headroom again.
        fn resume_parked(&mut self) {
            if self.meter_parked.is_empty() || self.read_meter >= self.cfg.read_buf_bytes {
                return;
            }
            let parked = std::mem::take(&mut self.meter_parked);
            for token in parked {
                let slot = token_slot(token);
                let valid = matches!(
                    self.conns.get(slot),
                    Some(Some(c)) if c.generation == token_gen(token)
                );
                if valid {
                    self.pump_io(slot, false);
                }
            }
        }

        /// Diff desired-vs-registered epoll interest and apply it.
        /// Read interest is parked while the conn is throttled (inflight
        /// cap, write backlog, per-conn or global read meter) — with a
        /// level-triggered reactor that is what keeps the loop from
        /// spinning on data it refuses to consume.
        fn update_interest(&mut self, token: u64, conn: &mut Conn) {
            let meter_ok = self.read_meter < self.cfg.read_buf_bytes;
            let throttled_locally = conn.inflight >= self.cfg.max_inflight
                || conn.write_backlog() >= self.cfg.write_buf_bytes
                || conn.parser.buffered() >= self.conn_read_cap();
            let want_r =
                !conn.close_after && !conn.peer_eof && !throttled_locally && meter_ok;
            if !meter_ok && !conn.close_after && !conn.peer_eof && !throttled_locally {
                self.meter_parked.push(token);
            }
            let want_w = conn.wants_write();
            if (want_r != conn.reg_read || want_w != conn.reg_write)
                && self
                    .reactor
                    .modify(conn.stream.as_raw_fd(), token, want_r, want_w)
                    .is_ok()
            {
                conn.reg_read = want_r;
                conn.reg_write = want_w;
            }
        }

        /// Close a connection: refund its meter share, drain the socket
        /// (FIN, not RST — an RST can destroy the last response in
        /// flight), free the slot.
        fn release(&mut self, slot: usize, mut conn: Conn) {
            self.read_meter -= conn.metered;
            self.active -= 1;
            self.counters.active_conns.fetch_sub(1, Ordering::Relaxed);
            let mut scratch = [0u8; 4096];
            for _ in 0..32 {
                match conn.stream.read(&mut scratch) {
                    Ok(0) | Err(_) => break, // EOF or WouldBlock: done
                    Ok(_) => {}
                }
            }
            drop(conn); // closes the fd; epoll deregisters implicitly
            self.free.push(slot);
        }

        /// Reap connections with no socket progress for the idle
        /// timeout.  In-flight decodes exempt a conn — idleness is the
        /// client's silence, not the server's work.
        fn reap(&mut self, now: Instant) {
            let timeout = self.cfg.read_timeout_ms as u128;
            if timeout == 0 {
                return;
            }
            for slot in 0..self.conns.len() {
                let expired = match &self.conns[slot] {
                    Some(c) => c.inflight == 0 && c.idle_millis(now) >= timeout,
                    None => false,
                };
                if expired {
                    if let Some(conn) = self.conns[slot].take() {
                        if conn.requests == 0 {
                            // never completed a request: a slowloris or
                            // dead socket, same as the old read timeout
                            self.counters.io_errors.fetch_add(1, Ordering::Relaxed);
                        } else {
                            self.counters.reaped_idle.fetch_add(1, Ordering::Relaxed);
                        }
                        self.release(slot, conn);
                    }
                }
            }
        }

        /// Begin graceful shutdown: stop accepting, mark every conn
        /// close-after-drain, pump them once.  The loop exits when the
        /// last response has flushed and the last job has come home.
        fn begin_close(&mut self) {
            self.closing = true;
            let _ = self.reactor.del(self.listener.as_raw_fd());
            for slot in 0..self.conns.len() {
                if let Some(conn) = self.conns[slot].as_mut() {
                    conn.close_after = true;
                }
            }
            for slot in 0..self.conns.len() {
                if self.conns[slot].is_some() {
                    self.pump_io(slot, false);
                }
            }
        }
    }
}
