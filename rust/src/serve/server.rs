//! Concurrent query server — a fixed thread-pool over a `TcpListener`.
//!
//! External demand drives the concurrency here (unlike the engine's
//! internal shard workers): the accept loop pushes connections into a
//! *bounded* queue and `workers` threads drain it, so a traffic burst
//! degrades to fast `503`s instead of unbounded thread or memory growth.
//! Every request failure — malformed query string, oversized head,
//! client disconnect mid-response — is a typed error mapped to an HTTP
//! status (or swallowed into a counter when the socket is gone); worker
//! threads never panic and never exit early.
//!
//! Endpoints:
//! * `GET /datasets` — JSON catalog of mounted datasets.
//! * `GET /query?dataset=D&t0=A&t1=B&species=OH,CO` — binary
//!   little-endian f32 body (`[nt, |species|, Y, X]` row-major) plus an
//!   `X-Gbatc-Meta` JSON header with dims, resolved species indices, and
//!   the certified error target.  `t0`/`t1`/`species` are optional
//!   (defaults: full axis, all species).
//! * `GET /stats` — JSON cache / decode / IO / server counters.
//!
//! Shutdown is graceful: [`QueryServer::shutdown`] stops accepting,
//! lets the workers drain the queue and finish in-flight responses, and
//! joins every thread.

use std::io::Read;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::api::{Query, SpeciesSel};
use crate::error::{Error, Result};
use crate::serve::http::{self, json_error, json_escape, json_usize_list, Request};
use crate::store::ArchiveStore;

const JSON: &str = "application/json";
const BINARY: &str = "application/octet-stream";

/// Knobs of a [`QueryServer`].
#[derive(Clone, Copy, Debug)]
pub struct ServerConfig {
    /// Worker threads handling requests.
    pub workers: usize,
    /// Bounded connection queue between accept and the workers; overflow
    /// is answered `503` immediately.
    pub queue: usize,
    /// Request-head byte cap (oversized requests get `431`).
    pub max_head_bytes: usize,
    /// Response-body byte cap per `/query` (larger requests get `413`
    /// before any decode) — the bounded queue limits connections, this
    /// limits bytes: at most `workers * max_response_bytes * 2` of
    /// response/decode buffers are ever in flight.
    pub max_response_bytes: usize,
    /// Per-connection socket read timeout.
    pub read_timeout_ms: u64,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            workers: 4,
            queue: 64,
            max_head_bytes: 8 * 1024,
            max_response_bytes: 256 << 20,
            read_timeout_ms: 30_000,
        }
    }
}

/// Counter snapshot of a server; see the field docs.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ServeStats {
    /// Connections accepted.
    pub accepted: u64,
    /// `200` responses written.
    pub served: u64,
    /// `4xx` responses (bad request / unknown dataset / oversized head).
    pub client_errors: u64,
    /// `5xx` responses (decode failures surfaced to the client).
    pub server_errors: u64,
    /// Connections refused with `503` because the queue was full.
    pub rejected_queue_full: u64,
    /// Sockets that died mid-request/response (timeouts, disconnects).
    pub io_errors: u64,
}

impl std::fmt::Display for ServeStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "accepted {} | served {} | 4xx {} | 5xx {} | busy-rejected {} | io errors {}",
            self.accepted,
            self.served,
            self.client_errors,
            self.server_errors,
            self.rejected_queue_full,
            self.io_errors
        )
    }
}

#[derive(Default)]
struct Counters {
    accepted: AtomicU64,
    served: AtomicU64,
    client_errors: AtomicU64,
    server_errors: AtomicU64,
    rejected_queue_full: AtomicU64,
    io_errors: AtomicU64,
}

impl Counters {
    fn snapshot(&self) -> ServeStats {
        ServeStats {
            accepted: self.accepted.load(Ordering::Relaxed),
            served: self.served.load(Ordering::Relaxed),
            client_errors: self.client_errors.load(Ordering::Relaxed),
            server_errors: self.server_errors.load(Ordering::Relaxed),
            rejected_queue_full: self.rejected_queue_full.load(Ordering::Relaxed),
            io_errors: self.io_errors.load(Ordering::Relaxed),
        }
    }
}

/// A running server; see the module docs.
pub struct QueryServer {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    counters: Arc<Counters>,
}

impl QueryServer {
    /// Bind `addr` (e.g. `127.0.0.1:7070`, port `0` for ephemeral) and
    /// start serving `store` on `cfg.workers` threads.
    pub fn bind(store: Arc<ArchiveStore>, addr: &str, cfg: ServerConfig) -> Result<QueryServer> {
        let listener =
            TcpListener::bind(addr).map_err(|e| Error::io_ctx(format!("binding {addr}"), e))?;
        let local = listener
            .local_addr()
            .map_err(|e| Error::io_ctx("resolving listener address", e))?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let counters = Arc::new(Counters::default());
        let (tx, rx) = std::sync::mpsc::sync_channel::<TcpStream>(cfg.queue.max(1));
        let rx = Arc::new(Mutex::new(rx));

        let mut workers = Vec::with_capacity(cfg.workers.max(1));
        for i in 0..cfg.workers.max(1) {
            let rx = Arc::clone(&rx);
            let store = Arc::clone(&store);
            let counters = Arc::clone(&counters);
            let handle = std::thread::Builder::new()
                .name(format!("gbatc-serve-{i}"))
                .spawn(move || worker_loop(rx, store, counters, cfg))
                .map_err(|e| Error::io_ctx("spawning server worker", e))?;
            workers.push(handle);
        }
        let accept = {
            let shutdown = Arc::clone(&shutdown);
            let counters = Arc::clone(&counters);
            std::thread::Builder::new()
                .name("gbatc-serve-accept".to_string())
                .spawn(move || accept_loop(listener, tx, shutdown, counters))
                .map_err(|e| Error::io_ctx("spawning accept thread", e))?
        };
        Ok(QueryServer {
            addr: local,
            shutdown,
            accept: Some(accept),
            workers,
            counters,
        })
    }

    /// The bound address (resolves port `0`).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Counter snapshot (also served at `/stats`).
    pub fn stats(&self) -> ServeStats {
        self.counters.snapshot()
    }

    /// Graceful shutdown: stop accepting, drain the queue, finish
    /// in-flight responses, join every thread.  Returns the final
    /// counters.
    pub fn shutdown(mut self) -> ServeStats {
        self.request_stop();
        if let Some(j) = self.accept.take() {
            let _ = j.join();
        }
        for j in self.workers.drain(..) {
            let _ = j.join();
        }
        self.counters.snapshot()
    }

    fn request_stop(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // wake the blocking accept with a throwaway connection
        let _ = TcpStream::connect(self.addr);
    }
}

impl Drop for QueryServer {
    fn drop(&mut self) {
        // dropped without `shutdown()`: stop accepting and let the
        // workers drain; joining here could block an unwinding thread,
        // so the worker handles are simply released
        if self.accept.is_some() {
            self.request_stop();
        }
    }
}

fn accept_loop(
    listener: TcpListener,
    tx: SyncSender<TcpStream>,
    shutdown: Arc<AtomicBool>,
    counters: Arc<Counters>,
) {
    loop {
        let conn = match listener.accept() {
            Ok((conn, _)) => conn,
            Err(_) => {
                if shutdown.load(Ordering::SeqCst) {
                    break;
                }
                std::thread::sleep(Duration::from_millis(10));
                continue;
            }
        };
        if shutdown.load(Ordering::SeqCst) {
            break; // the wake-up connection itself lands here
        }
        counters.accepted.fetch_add(1, Ordering::Relaxed);
        match tx.try_send(conn) {
            Ok(()) => {}
            Err(TrySendError::Full(mut conn)) => {
                counters.rejected_queue_full.fetch_add(1, Ordering::Relaxed);
                let _ = http::write_response(
                    &mut conn,
                    503,
                    JSON,
                    &[],
                    json_error("request queue full, retry later").as_bytes(),
                );
            }
            Err(TrySendError::Disconnected(_)) => break,
        }
    }
    // dropping `tx` here disconnects the workers once the queue drains
}

fn worker_loop(
    rx: Arc<Mutex<Receiver<TcpStream>>>,
    store: Arc<ArchiveStore>,
    counters: Arc<Counters>,
    cfg: ServerConfig,
) {
    loop {
        // hold the receiver lock only for the dequeue, not the request
        let conn = {
            let guard = match rx.lock() {
                Ok(g) => g,
                Err(poisoned) => poisoned.into_inner(),
            };
            guard.recv()
        };
        let mut conn = match conn {
            Ok(c) => c,
            Err(_) => break, // accept loop gone and queue drained
        };
        let _ = conn.set_read_timeout(Some(Duration::from_millis(cfg.read_timeout_ms.max(1))));
        let _ = conn.set_nodelay(true);
        handle_conn(&mut conn, &store, &counters, cfg);
    }
}

/// Serve one connection end to end.  Every outcome lands in a counter;
/// nothing here panics or kills the worker.
fn handle_conn(
    conn: &mut TcpStream,
    store: &ArchiveStore,
    counters: &Counters,
    cfg: ServerConfig,
) {
    let req = match http::read_request(conn, cfg.max_head_bytes) {
        Ok(r) => r,
        Err(Error::Protocol(msg)) => {
            counters.client_errors.fetch_add(1, Ordering::Relaxed);
            let status = if msg.starts_with(http::OVERSIZE_MARK) { 431 } else { 400 };
            if http::write_response(conn, status, JSON, &[], json_error(&msg).as_bytes()).is_err()
            {
                counters.io_errors.fetch_add(1, Ordering::Relaxed);
            }
            // the request head was never fully consumed; drain what the
            // client is still sending so close() sends FIN, not RST (an
            // RST can destroy the error response in flight)
            drain(conn);
            return;
        }
        Err(_) => {
            // read timeout or disconnect before a full request
            counters.io_errors.fetch_add(1, Ordering::Relaxed);
            return;
        }
    };
    let (status, content_type, extra, body) = route(&req, store, counters, &cfg);
    match status {
        200 => counters.served.fetch_add(1, Ordering::Relaxed),
        400..=499 => counters.client_errors.fetch_add(1, Ordering::Relaxed),
        _ => counters.server_errors.fetch_add(1, Ordering::Relaxed),
    };
    let headers: Vec<(&str, &str)> = extra.iter().map(|(k, v)| (k.as_str(), v.as_str())).collect();
    if http::write_response(conn, status, content_type, &headers, &body).is_err() {
        counters.io_errors.fetch_add(1, Ordering::Relaxed);
    }
}

/// Read and discard whatever request bytes are still arriving, bounded
/// in time and volume, so the socket closes cleanly (FIN) with an empty
/// receive queue.
fn drain(conn: &mut TcpStream) {
    let _ = conn.set_read_timeout(Some(Duration::from_millis(200)));
    let mut scratch = [0u8; 4096];
    for _ in 0..64 {
        match conn.read(&mut scratch) {
            Ok(0) | Err(_) => break,
            Ok(_) => {}
        }
    }
}

type Routed = (u16, &'static str, Vec<(String, String)>, Vec<u8>);

fn route(req: &Request, store: &ArchiveStore, counters: &Counters, cfg: &ServerConfig) -> Routed {
    if req.method != "GET" {
        return (
            405,
            JSON,
            Vec::new(),
            json_error("only GET is supported").into_bytes(),
        );
    }
    match req.path.as_str() {
        "/datasets" => (200, JSON, Vec::new(), datasets_json(store).into_bytes()),
        "/stats" => (
            200,
            JSON,
            Vec::new(),
            stats_json(store, counters).into_bytes(),
        ),
        "/query" => handle_query(req, store, cfg.max_response_bytes),
        other => (
            404,
            JSON,
            Vec::new(),
            json_error(&format!(
                "no endpoint `{other}` (try /datasets, /query, /stats)"
            ))
            .into_bytes(),
        ),
    }
}

fn parse_opt_usize(req: &Request, key: &str) -> Result<Option<usize>> {
    match req.param(key) {
        None | Some("") => Ok(None),
        Some(v) => v
            .parse()
            .map(Some)
            .map_err(|e| Error::protocol(format!("query parameter {key}={v}: {e}"))),
    }
}

fn handle_query(req: &Request, store: &ArchiveStore, max_response_bytes: usize) -> Routed {
    let bad = |msg: &str| (400, JSON, Vec::new(), json_error(msg).into_bytes());
    let dataset = match req.param("dataset") {
        Some(d) if !d.is_empty() => d,
        _ => return bad("missing dataset parameter"),
    };
    let info = match store.dataset_info(dataset) {
        Ok(i) => i,
        // a missing mount is the client's 404; anything else (e.g. a
        // poisoned mount table) is a server-side 500, not a fake 404
        Err(Error::Config(msg)) => return (404, JSON, Vec::new(), json_error(&msg).into_bytes()),
        Err(e) => return (500, JSON, Vec::new(), json_error(&e.to_string()).into_bytes()),
    };
    let (t0, t1) = match (parse_opt_usize(req, "t0"), parse_opt_usize(req, "t1")) {
        (Ok(t0), Ok(t1)) => (t0.unwrap_or(0), t1.unwrap_or(info.dims.0)),
        (Err(e), _) | (_, Err(e)) => return bad(&e.to_string()),
    };
    let species = SpeciesSel::parse(req.param("species").unwrap_or(""));
    // bound the response volume before any decode: the bounded queue
    // limits concurrent connections, this limits bytes per response
    let (_, ns, ny, nx) = info.dims;
    let nsel = match species.resolve(ns) {
        Ok(sel) => sel.len(),
        Err(e) => return bad(&e.to_string()),
    };
    let want = t1
        .saturating_sub(t0)
        .saturating_mul(nsel)
        .saturating_mul(ny)
        .saturating_mul(nx)
        .saturating_mul(4);
    if want > max_response_bytes {
        return (
            413,
            JSON,
            Vec::new(),
            json_error(&format!(
                "response would be {want} bytes (cap {max_response_bytes}); \
                 narrow t0/t1 or the species list"
            ))
            .into_bytes(),
        );
    }
    let q = Query {
        time: t0..t1,
        species,
    };
    match store.query(dataset, &q) {
        Ok(dec) => {
            let meta = format!(
                "{{\"dataset\":\"{}\",\"t0\":{},\"nt\":{},\"ny\":{},\"nx\":{},\"species\":{},\
                 \"nrmse_target\":{:e},\"pressure\":{:e}}}",
                json_escape(dataset),
                dec.t0,
                dec.nt,
                dec.ny,
                dec.nx,
                json_usize_list(&dec.species),
                info.nrmse_target,
                info.pressure
            );
            let mut body = Vec::with_capacity(dec.mass.len() * 4);
            for v in &dec.mass {
                body.extend_from_slice(&v.to_le_bytes());
            }
            (
                200,
                BINARY,
                vec![("X-Gbatc-Meta".to_string(), meta)],
                body,
            )
        }
        Err(e) => {
            let status = match e {
                // raced an unmount between the info lookup and the query
                Error::Config(_) if !store.contains(dataset) => 404,
                Error::Shape(_) | Error::Config(_) | Error::Protocol(_) => 400,
                _ => 500,
            };
            (status, JSON, Vec::new(), json_error(&e.to_string()).into_bytes())
        }
    }
}

fn datasets_json(store: &ArchiveStore) -> String {
    let mut out = String::from("{\"datasets\":[");
    for (i, d) in store.datasets().iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let (nt, ns, ny, nx) = d.dims;
        out.push_str(&format!(
            "{{\"name\":\"{}\",\"nt\":{nt},\"ns\":{ns},\"ny\":{ny},\"nx\":{nx},\
             \"n_shards\":{},\"kt_window\":{},\"nrmse_target\":{:e},\"archive_bytes\":{}}}",
            json_escape(&d.name),
            d.n_shards,
            d.kt_window,
            d.nrmse_target,
            d.archive_bytes
        ));
    }
    out.push_str("]}");
    out
}

fn stats_json(store: &ArchiveStore, counters: &Counters) -> String {
    let st = store.stats();
    let sv = counters.snapshot();
    let c = st.cache;
    let mut out = format!(
        "{{\"queries\":{},\"decoded_sections\":{},\"decoded_bytes\":{},\
         \"cache\":{{\"hits\":{},\"misses\":{},\"admitted\":{},\"rejected\":{},\
         \"evicted\":{},\"resident_sections\":{},\"resident_bytes\":{},\
         \"capacity_bytes\":{},\"lock_shards\":{}}},\
         \"server\":{{\"accepted\":{},\"served\":{},\"client_errors\":{},\
         \"server_errors\":{},\"rejected_queue_full\":{},\"io_errors\":{}}},\
         \"datasets\":[",
        st.queries,
        st.decoded_sections,
        st.decoded_bytes,
        c.hits,
        c.misses,
        c.admitted,
        c.rejected,
        c.evicted,
        c.resident_sections,
        c.resident_bytes,
        c.capacity_bytes,
        c.lock_shards,
        sv.accepted,
        sv.served,
        sv.client_errors,
        sv.server_errors,
        sv.rejected_queue_full,
        sv.io_errors
    );
    for (i, d) in st.datasets.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "{{\"name\":\"{}\",\"archive_bytes\":{},\"toc_reads\":{},\"toc_bytes\":{},\
             \"payload_reads\":{},\"payload_bytes\":{}}}",
            json_escape(&d.name),
            d.archive_bytes,
            d.io.toc_reads,
            d.io.toc_bytes,
            d.io.payload_reads,
            d.io.payload_bytes
        ));
    }
    out.push_str("]}");
    out
}
