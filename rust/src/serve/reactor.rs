//! Readiness reactor — a hand-rolled `epoll(7)` wrapper plus an
//! `eventfd(2)` waker, raw `extern "C"` declarations only (the crate
//! stays dependency-free, same policy as `archive::mmap`).
//!
//! Linux-only by design: `epoll` has no portable twin in `std`, so off
//! Linux [`Reactor::new`] returns a typed error and the server falls
//! back to the blocking thread-pool implementation (the same
//! typed-fallback shape `MmapSource` uses).  `GBATC_NO_EPOLL=1` forces
//! that fallback on Linux too, which is how CI keeps both servers green.
//!
//! The reactor is **level-triggered**: the event loop must either drain
//! a ready fd or drop the interest bit (see `serve::conn` — read
//! interest is parked while a connection is throttled), otherwise
//! `wait` would spin.  Tokens are caller-chosen `u64`s carried in
//! `epoll_event.data`; the connection table pairs a slot index with a
//! generation counter so a stale event harvested in the same batch as a
//! close can never touch a recycled slot.

use crate::error::{Error, Result};

/// One readiness notification out of [`Reactor::wait`].
#[derive(Clone, Copy, Debug)]
pub struct Event {
    /// The token registered with the fd.
    pub token: u64,
    /// Readable (or a pending accept on a listener).
    pub readable: bool,
    /// Writable again after a short write.
    pub writable: bool,
    /// Peer hung up or the fd errored — the connection is done.
    pub hangup: bool,
}

#[cfg(target_os = "linux")]
mod sys {
    use std::os::raw::c_int;

    pub const EPOLLIN: u32 = 0x001;
    pub const EPOLLOUT: u32 = 0x004;
    pub const EPOLLERR: u32 = 0x008;
    pub const EPOLLHUP: u32 = 0x010;
    pub const EPOLLRDHUP: u32 = 0x2000;

    pub const EPOLL_CTL_ADD: c_int = 1;
    pub const EPOLL_CTL_DEL: c_int = 2;
    pub const EPOLL_CTL_MOD: c_int = 3;
    pub const EPOLL_CLOEXEC: c_int = 0o2000000;

    pub const EFD_CLOEXEC: c_int = 0o2000000;
    pub const EFD_NONBLOCK: c_int = 0o4000;

    /// `struct epoll_event`: packed on x86_64 only (kernel ABI).
    #[repr(C)]
    #[cfg_attr(target_arch = "x86_64", repr(packed))]
    #[derive(Clone, Copy)]
    pub struct EpollEvent {
        pub events: u32,
        pub data: u64,
    }

    extern "C" {
        pub fn epoll_create1(flags: c_int) -> c_int;
        pub fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut EpollEvent) -> c_int;
        pub fn epoll_wait(
            epfd: c_int,
            events: *mut EpollEvent,
            maxevents: c_int,
            timeout: c_int,
        ) -> c_int;
        pub fn eventfd(initval: u32, flags: c_int) -> c_int;
        pub fn close(fd: c_int) -> c_int;
    }
}

/// The epoll instance.  `fd`s are raw (`i32`) so callers pass
/// `AsRawFd::as_raw_fd()` without this module needing platform traits.
pub struct Reactor {
    #[cfg(target_os = "linux")]
    epfd: i32,
}

#[cfg(target_os = "linux")]
impl Reactor {
    /// Create an epoll instance (`EPOLL_CLOEXEC`).
    pub fn new() -> Result<Reactor> {
        // SAFETY: plain syscall wrapper, no pointers involved.
        let epfd = unsafe { sys::epoll_create1(sys::EPOLL_CLOEXEC) };
        if epfd < 0 {
            return Err(Error::io_ctx(
                "epoll_create1",
                std::io::Error::last_os_error(),
            ));
        }
        Ok(Reactor { epfd })
    }

    fn mask(readable: bool, writable: bool) -> u32 {
        let mut m = sys::EPOLLRDHUP; // always learn about peer shutdown
        if readable {
            m |= sys::EPOLLIN;
        }
        if writable {
            m |= sys::EPOLLOUT;
        }
        m
    }

    fn ctl(&self, op: i32, fd: i32, events: u32, token: u64, what: &str) -> Result<()> {
        let mut ev = sys::EpollEvent {
            events,
            data: token,
        };
        // SAFETY: `ev` outlives the call; the kernel copies it.
        let rc = unsafe { sys::epoll_ctl(self.epfd, op, fd, &mut ev) };
        if rc < 0 {
            return Err(Error::io_ctx(
                format!("epoll_ctl {what}"),
                std::io::Error::last_os_error(),
            ));
        }
        Ok(())
    }

    /// Register `fd` under `token` with the given interest set.
    pub fn add(&self, fd: i32, token: u64, readable: bool, writable: bool) -> Result<()> {
        self.ctl(
            sys::EPOLL_CTL_ADD,
            fd,
            Self::mask(readable, writable),
            token,
            "add",
        )
    }

    /// Change the interest set of a registered `fd`.
    pub fn modify(&self, fd: i32, token: u64, readable: bool, writable: bool) -> Result<()> {
        self.ctl(
            sys::EPOLL_CTL_MOD,
            fd,
            Self::mask(readable, writable),
            token,
            "mod",
        )
    }

    /// Deregister `fd` (also implicit when the fd closes).
    pub fn del(&self, fd: i32) -> Result<()> {
        self.ctl(sys::EPOLL_CTL_DEL, fd, 0, 0, "del")
    }

    /// Block up to `timeout_ms` (-1 = forever) and append ready events to
    /// `out`.  Returns how many arrived; `EINTR` reports zero events.
    pub fn wait(&self, out: &mut Vec<Event>, timeout_ms: i32) -> Result<usize> {
        let mut buf = [sys::EpollEvent { events: 0, data: 0 }; 128];
        // SAFETY: buf is a live array of `maxevents` entries.
        let n = unsafe {
            sys::epoll_wait(self.epfd, buf.as_mut_ptr(), buf.len() as i32, timeout_ms)
        };
        if n < 0 {
            let e = std::io::Error::last_os_error();
            if e.kind() == std::io::ErrorKind::Interrupted {
                return Ok(0);
            }
            return Err(Error::io_ctx("epoll_wait", e));
        }
        for ev in buf.iter().take(n as usize) {
            // copy fields out of the (possibly packed) struct
            let bits = ev.events;
            let token = ev.data;
            out.push(Event {
                token,
                readable: bits & sys::EPOLLIN != 0,
                writable: bits & sys::EPOLLOUT != 0,
                hangup: bits & (sys::EPOLLERR | sys::EPOLLHUP | sys::EPOLLRDHUP) != 0,
            });
        }
        Ok(n as usize)
    }
}

#[cfg(target_os = "linux")]
impl Drop for Reactor {
    fn drop(&mut self) {
        // SAFETY: epfd came from a successful epoll_create1, closed once.
        unsafe {
            sys::close(self.epfd);
        }
    }
}

#[cfg(not(target_os = "linux"))]
impl Reactor {
    /// No epoll off Linux: the server catches this typed error and runs
    /// the blocking thread-pool fallback instead.
    pub fn new() -> Result<Reactor> {
        Err(Error::runtime(
            "epoll: unsupported on this platform (thread-pool fallback)",
        ))
    }

    pub fn add(&self, _fd: i32, _token: u64, _r: bool, _w: bool) -> Result<()> {
        Err(Error::runtime("epoll: unsupported on this platform"))
    }

    pub fn modify(&self, _fd: i32, _token: u64, _r: bool, _w: bool) -> Result<()> {
        Err(Error::runtime("epoll: unsupported on this platform"))
    }

    pub fn del(&self, _fd: i32) -> Result<()> {
        Err(Error::runtime("epoll: unsupported on this platform"))
    }

    pub fn wait(&self, _out: &mut Vec<Event>, _timeout_ms: i32) -> Result<usize> {
        Err(Error::runtime("epoll: unsupported on this platform"))
    }
}

/// Cross-thread wakeup for the event loop: decode workers signal
/// response completions through an `eventfd`, registered in the reactor
/// like any other fd.  The write side is `Sync` (an 8-byte eventfd write
/// is atomic), so worker threads share one [`Waker`] behind an `Arc`.
pub struct Waker {
    #[cfg(target_os = "linux")]
    fd: i32,
}

#[cfg(target_os = "linux")]
impl Waker {
    pub fn new() -> Result<Waker> {
        // SAFETY: plain syscall wrapper.
        let fd = unsafe { sys::eventfd(0, sys::EFD_CLOEXEC | sys::EFD_NONBLOCK) };
        if fd < 0 {
            return Err(Error::io_ctx("eventfd", std::io::Error::last_os_error()));
        }
        Ok(Waker { fd })
    }

    /// The fd to register in the reactor (read interest).
    pub fn fd(&self) -> i32 {
        self.fd
    }

    /// Signal the loop.  Never blocks: if the counter is saturated the
    /// loop is already overdue for a wake, so the failure is ignored.
    pub fn wake(&self) {
        let one = 1u64.to_ne_bytes();
        // SAFETY: fd is a live eventfd; the buffer is 8 valid bytes.
        unsafe {
            libc_write(self.fd, one.as_ptr(), one.len());
        }
    }

    /// Drain pending wakeups so level-triggered polling goes quiet.
    pub fn drain(&self) {
        let mut buf = [0u8; 8];
        // SAFETY: fd is a live nonblocking eventfd; buffer is 8 bytes.
        unsafe {
            libc_read(self.fd, buf.as_mut_ptr(), buf.len());
        }
    }
}

#[cfg(target_os = "linux")]
extern "C" {
    #[link_name = "write"]
    fn libc_write(fd: i32, buf: *const u8, count: usize) -> isize;
    #[link_name = "read"]
    fn libc_read(fd: i32, buf: *mut u8, count: usize) -> isize;
}

#[cfg(target_os = "linux")]
impl Drop for Waker {
    fn drop(&mut self) {
        // SAFETY: fd came from a successful eventfd, closed once.
        unsafe {
            sys::close(self.fd);
        }
    }
}

// SAFETY: the waker only carries an fd; eventfd reads/writes are atomic
// syscalls with no shared userspace state.
#[cfg(target_os = "linux")]
unsafe impl Send for Waker {}
#[cfg(target_os = "linux")]
unsafe impl Sync for Waker {}

#[cfg(not(target_os = "linux"))]
impl Waker {
    pub fn new() -> Result<Waker> {
        Err(Error::runtime(
            "eventfd: unsupported on this platform (thread-pool fallback)",
        ))
    }

    pub fn fd(&self) -> i32 {
        -1
    }

    pub fn wake(&self) {}

    pub fn drain(&self) {}
}

#[cfg(all(test, target_os = "linux"))]
mod tests {
    use super::*;
    use std::io::{Read, Write};
    use std::net::{TcpListener, TcpStream};
    use std::os::unix::io::AsRawFd;

    #[test]
    fn readiness_round_trip() {
        let reactor = Reactor::new().unwrap();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        listener.set_nonblocking(true).unwrap();
        reactor
            .add(listener.as_raw_fd(), 7, true, false)
            .unwrap();

        // nothing pending: a short wait times out empty
        let mut events = Vec::new();
        reactor.wait(&mut events, 10).unwrap();
        assert!(events.is_empty());

        // a connect makes the listener readable
        let mut client = TcpStream::connect(listener.local_addr().unwrap()).unwrap();
        events.clear();
        reactor.wait(&mut events, 1000).unwrap();
        assert!(events.iter().any(|e| e.token == 7 && e.readable));

        let (mut server_side, _) = listener.accept().unwrap();
        server_side.set_nonblocking(true).unwrap();
        reactor
            .add(server_side.as_raw_fd(), 9, true, false)
            .unwrap();
        client.write_all(b"ping").unwrap();
        events.clear();
        reactor.wait(&mut events, 1000).unwrap();
        assert!(events.iter().any(|e| e.token == 9 && e.readable));
        let mut buf = [0u8; 8];
        assert_eq!(server_side.read(&mut buf).unwrap(), 4);

        // interest can be modified and removed
        reactor
            .modify(server_side.as_raw_fd(), 9, true, true)
            .unwrap();
        events.clear();
        reactor.wait(&mut events, 1000).unwrap();
        assert!(events.iter().any(|e| e.token == 9 && e.writable));
        reactor.del(server_side.as_raw_fd()).unwrap();

        // peer close surfaces as hangup on a registered fd
        reactor
            .add(server_side.as_raw_fd(), 11, true, false)
            .unwrap();
        drop(client);
        events.clear();
        reactor.wait(&mut events, 1000).unwrap();
        assert!(events.iter().any(|e| e.token == 11 && e.hangup));
    }

    #[test]
    fn waker_wakes_and_drains() {
        let reactor = Reactor::new().unwrap();
        let waker = Waker::new().unwrap();
        reactor.add(waker.fd(), 99, true, false).unwrap();

        let mut events = Vec::new();
        reactor.wait(&mut events, 10).unwrap();
        assert!(events.is_empty());

        waker.wake();
        waker.wake(); // coalesces
        events.clear();
        reactor.wait(&mut events, 1000).unwrap();
        assert!(events.iter().any(|e| e.token == 99 && e.readable));

        waker.drain();
        events.clear();
        reactor.wait(&mut events, 10).unwrap();
        assert!(
            events.iter().all(|e| e.token != 99),
            "drained waker must go quiet"
        );
    }
}
