//! Network query serving — the wire on top of [`crate::store`].
//!
//! A dependency-free `std::net` HTTP/1.1 stack:
//!
//! * [`http`] — incremental framing ([`http::HttpParser`]: GET-only
//!   requests, `Connection` semantics, pipelining-safe head/body
//!   splitting) plus the hand-rolled JSON helpers the offline image
//!   needs.
//! * [`reactor`] — a hand-rolled `epoll(7)` + `eventfd(2)` readiness
//!   layer (Linux; typed errors elsewhere so the server falls back to
//!   its thread pool).
//! * [`conn`] — the per-connection state machine: nonblocking reads
//!   into the parser, an in-order response queue for pipelined
//!   requests, and backlog meters the server's backpressure policy
//!   reads.
//! * [`router`] — [`QueryRouter`]: consistent-hash placement of dataset
//!   keys across N in-process store replicas, with warm-cache affinity
//!   and mount failover.
//! * [`server`] — [`QueryServer`]: an event-driven loop (keep-alive,
//!   pipelining, fairness, admission control) with a decode worker
//!   pool; off Linux it degrades to a blocking thread pool speaking
//!   the identical protocol.  Endpoints: `GET /datasets`,
//!   `GET /query?dataset=..&t0=..&t1=..&species=..` (binary f32 body +
//!   `X-Gbatc-Meta` JSON header), `GET /stats`, `GET /metrics`
//!   (Prometheus text), `GET /trace/slow` (worst spans, per-phase
//!   breakdowns; see [`crate::obs`]).
//! * [`client`] — [`QueryClient`]: the small blocking keep-alive client
//!   behind `gbatc query` and the loopback tests; responses decode to
//!   [`ClientDecode`] with bytes bit-identical to a local
//!   [`ArchiveReader`](crate::api::ArchiveReader) query.
//!
//! The request path is an unwrap-free zone: malformed query strings,
//! oversized requests, and client disconnects surface as
//! [`Error::Protocol`](crate::Error::Protocol) /
//! [`Error::IoContext`](crate::Error::IoContext) and map to HTTP
//! statuses — neither the reactor thread nor a worker ever panics.

pub mod client;
pub mod conn;
pub mod http;
pub mod reactor;
pub mod router;
pub mod server;

pub use client::{ClientDecode, QueryClient};
pub use router::{QueryRouter, RouterConfig};
pub use server::{QueryServer, ServeObs, ServeStats, ServerConfig};
