//! Network query serving — the wire on top of [`crate::store`].
//!
//! A dependency-free `std::net` HTTP/1.1 stack in three parts:
//!
//! * [`http`] — minimal framing (GET-only requests, `Content-Length`
//!   bodies, `Connection: close`) plus the hand-rolled JSON helpers the
//!   offline image needs.
//! * [`server`] — [`QueryServer`]: a fixed thread-pool over a
//!   `TcpListener` with a bounded request queue (overflow answers `503`),
//!   graceful shutdown, and per-outcome counters.  Endpoints:
//!   `GET /datasets`, `GET /query?dataset=..&t0=..&t1=..&species=..`
//!   (binary f32 body + `X-Gbatc-Meta` JSON header), `GET /stats`.
//! * [`client`] — [`QueryClient`]: the small blocking client behind
//!   `gbatc query` and the loopback tests; responses decode to
//!   [`ClientDecode`] with bytes bit-identical to a local
//!   [`ArchiveReader`](crate::api::ArchiveReader) query.
//!
//! The request path is an unwrap-free zone: malformed query strings,
//! oversized requests, and client disconnects surface as
//! [`Error::Protocol`](crate::Error::Protocol) /
//! [`Error::IoContext`](crate::Error::IoContext) and map to HTTP
//! statuses — a worker thread never panics.

pub mod client;
pub mod http;
pub mod server;

pub use client::{ClientDecode, QueryClient};
pub use server::{QueryServer, ServeStats, ServerConfig};
