//! Blocking query client — the consumer half of the wire protocol, used
//! by `gbatc query` and the loopback tests.
//!
//! One request per TCP connection (`Connection: close`), so the client
//! is trivially thread-safe: share one [`QueryClient`] across threads
//! and call it concurrently.

use std::io::Write;
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

use crate::error::{Error, Result};
use crate::serve::http::{self, HttpResponse};

/// A blocking client for one server address.
#[derive(Clone, Debug)]
pub struct QueryClient {
    addr: String,
    timeout: Duration,
}

/// A decoded `/query` response.
#[derive(Clone, Debug)]
pub struct ClientDecode {
    /// First timestep of the window.
    pub t0: usize,
    /// Timesteps decoded.
    pub nt: usize,
    pub ny: usize,
    pub nx: usize,
    /// Resolved species indices, ascending (row order of `mass`).
    pub species: Vec<usize>,
    /// Loosest certified NRMSE target of the dataset.
    pub nrmse_target: f64,
    /// Ambient pressure [Pa] from the archive header.
    pub pressure: f64,
    /// Row-major `[nt, species.len(), ny, nx]` mass fractions —
    /// bit-identical to a local decode of the same range.
    pub mass: Vec<f32>,
    /// The raw `X-Gbatc-Meta` JSON, for fields not parsed above.
    pub meta_json: String,
}

impl QueryClient {
    /// A client for `addr` (e.g. `127.0.0.1:7070`) with a 30 s timeout.
    pub fn new(addr: impl Into<String>) -> QueryClient {
        QueryClient {
            addr: addr.into(),
            timeout: Duration::from_secs(30),
        }
    }

    /// Override the connect/read/write timeout.
    pub fn timeout(mut self, timeout: Duration) -> QueryClient {
        self.timeout = timeout;
        self
    }

    /// Connect with the configured timeout (not the OS default, which
    /// can be minutes), trying each resolved address.
    fn connect(&self) -> Result<TcpStream> {
        let addrs = self
            .addr
            .to_socket_addrs()
            .map_err(|e| Error::io_ctx(format!("resolving {}", self.addr), e))?;
        let mut last: Option<std::io::Error> = None;
        for a in addrs {
            match TcpStream::connect_timeout(&a, self.timeout) {
                Ok(s) => return Ok(s),
                Err(e) => last = Some(e),
            }
        }
        Err(Error::io_ctx(
            format!("connecting to {}", self.addr),
            last.unwrap_or_else(|| {
                std::io::Error::new(std::io::ErrorKind::NotFound, "no addresses resolved")
            }),
        ))
    }

    fn get(&self, target: &str) -> Result<HttpResponse> {
        let mut stream = self.connect()?;
        let _ = stream.set_read_timeout(Some(self.timeout));
        let _ = stream.set_write_timeout(Some(self.timeout));
        let req = format!(
            "GET {target} HTTP/1.1\r\nHost: {}\r\nConnection: close\r\n\r\n",
            self.addr
        );
        stream
            .write_all(req.as_bytes())
            .map_err(|e| Error::io_ctx("sending request", e))?;
        http::read_response(&mut stream)
    }

    fn get_ok(&self, target: &str) -> Result<HttpResponse> {
        let resp = self.get(target)?;
        if resp.status != 200 {
            return Err(Error::protocol(format!(
                "{target}: HTTP {} — {}",
                resp.status,
                String::from_utf8_lossy(&resp.body)
            )));
        }
        Ok(resp)
    }

    /// Raw JSON catalog from `GET /datasets`.
    pub fn datasets_json(&self) -> Result<String> {
        let resp = self.get_ok("/datasets")?;
        String::from_utf8(resp.body).map_err(|_| Error::protocol("/datasets body is not UTF-8"))
    }

    /// Raw JSON counters from `GET /stats`.
    pub fn stats_json(&self) -> Result<String> {
        let resp = self.get_ok("/stats")?;
        String::from_utf8(resp.body).map_err(|_| Error::protocol("/stats body is not UTF-8"))
    }

    /// Run a remote query.  `t0`/`t1` default to the dataset's full time
    /// axis; `species` is the CLI list syntax (names and/or indices,
    /// empty = all).
    pub fn query(
        &self,
        dataset: &str,
        t0: Option<usize>,
        t1: Option<usize>,
        species: &str,
    ) -> Result<ClientDecode> {
        let mut target = format!("/query?dataset={dataset}");
        if let Some(t0) = t0 {
            target.push_str(&format!("&t0={t0}"));
        }
        if let Some(t1) = t1 {
            target.push_str(&format!("&t1={t1}"));
        }
        if !species.is_empty() {
            target.push_str(&format!("&species={species}"));
        }
        let resp = self.get_ok(&target)?;
        let meta = resp
            .header("x-gbatc-meta")
            .ok_or_else(|| Error::protocol("query response lacks the X-Gbatc-Meta header"))?
            .to_string();
        let t0 = http::json_u64(&meta, "t0")? as usize;
        let nt = http::json_u64(&meta, "nt")? as usize;
        let ny = http::json_u64(&meta, "ny")? as usize;
        let nx = http::json_u64(&meta, "nx")? as usize;
        let species = http::json_usize_array(&meta, "species")?;
        let nrmse_target = http::json_f64(&meta, "nrmse_target")?;
        let pressure = http::json_f64(&meta, "pressure")?;
        let expect = nt * species.len() * ny * nx * 4;
        if resp.body.len() != expect {
            return Err(Error::protocol(format!(
                "query body is {} bytes, meta implies {expect}",
                resp.body.len()
            )));
        }
        let mass = resp
            .body
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();
        Ok(ClientDecode {
            t0,
            nt,
            ny,
            nx,
            species,
            nrmse_target,
            pressure,
            mass,
            meta_json: meta,
        })
    }
}
