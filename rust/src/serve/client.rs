//! Blocking query client — the consumer half of the wire protocol, used
//! by `gbatc query` and the loopback tests.
//!
//! The client speaks HTTP/1.1 keep-alive: one TCP connection is cached
//! and reused across requests (requests run in lockstep — write, then
//! read the full response — so reuse is always safe).  The connection is
//! dropped when the server answers `Connection: close`, and a request
//! that fails on a cached socket is retried exactly once on a fresh
//! connection (the server may have reaped the idle socket between
//! requests — that is normal keep-alive behavior, not an error).
//!
//! [`QueryClient::connections_opened`] counts the physical TCP connects,
//! so tests can assert that N sequential queries used exactly one
//! connection.  Cloning a client clones the address and timeout but
//! **not** the cached socket or the counter — each clone owns its own
//! connection, which keeps concurrent use trivially correct.

use std::io::Write;
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::error::{Error, Result};
use crate::serve::http::{self, HttpResponse};

/// A blocking keep-alive client for one server address.
#[derive(Debug)]
pub struct QueryClient {
    addr: String,
    timeout: Duration,
    reuse: bool,
    /// Send `X-Gbatc-Strict: 1` — degraded responses become errors
    /// (the server answers `503` instead of salvaged data).
    strict: bool,
    /// The cached keep-alive connection (lockstep request/response, so
    /// one at a time; concurrent callers serialize here).
    sock: Mutex<Option<TcpStream>>,
    /// Physical TCP connections opened over this client's lifetime.
    opened: AtomicU64,
}

impl Clone for QueryClient {
    fn clone(&self) -> QueryClient {
        QueryClient {
            addr: self.addr.clone(),
            timeout: self.timeout,
            reuse: self.reuse,
            strict: self.strict,
            sock: Mutex::new(None),
            opened: AtomicU64::new(0),
        }
    }
}

/// A decoded `/query` response.
#[derive(Clone, Debug)]
pub struct ClientDecode {
    /// First timestep of the window.
    pub t0: usize,
    /// Timesteps decoded.
    pub nt: usize,
    pub ny: usize,
    pub nx: usize,
    /// Resolved species indices, ascending (row order of `mass`).
    pub species: Vec<usize>,
    /// Loosest certified NRMSE target of the dataset.
    pub nrmse_target: f64,
    /// Ambient pressure [Pa] from the archive header.
    pub pressure: f64,
    /// Row-major `[nt, species.len(), ny, nx]` mass fractions —
    /// bit-identical to a local decode of the same range.
    pub mass: Vec<f32>,
    /// The response touched quarantined sections and was served from
    /// best-effort salvage (see `degraded_sections`/`degraded_bound` in
    /// `meta_json`); `nrmse_target` no longer certifies it.
    pub degraded: bool,
    /// Loosened certified NRMSE bound of a degraded response (`None`
    /// when healthy, or when no bound could be stated).
    pub degraded_bound: Option<f64>,
    /// The raw `X-Gbatc-Meta` JSON, for fields not parsed above.
    pub meta_json: String,
    /// The server's `X-Gbatc-Trace-Id` (16 hex digits), when the server
    /// has tracing enabled; correlates with `/trace/slow`.
    pub trace_id: Option<String>,
}

impl QueryClient {
    /// A client for `addr` (e.g. `127.0.0.1:7070`) with a 30 s timeout.
    pub fn new(addr: impl Into<String>) -> QueryClient {
        QueryClient {
            addr: addr.into(),
            timeout: Duration::from_secs(30),
            reuse: true,
            strict: false,
            sock: Mutex::new(None),
            opened: AtomicU64::new(0),
        }
    }

    /// Override the connect/read/write timeout.
    pub fn timeout(mut self, timeout: Duration) -> QueryClient {
        self.timeout = timeout;
        self
    }

    /// Disable keep-alive reuse: every request opens a fresh connection
    /// and sends `Connection: close` (the pre-keep-alive behavior).
    pub fn reuse(mut self, reuse: bool) -> QueryClient {
        self.reuse = reuse;
        self
    }

    /// Refuse degraded data: every request carries `X-Gbatc-Strict: 1`,
    /// so a query touching a quarantined section fails with the
    /// server's `503` instead of returning salvaged mass fractions.
    pub fn strict(mut self, strict: bool) -> QueryClient {
        self.strict = strict;
        self
    }

    /// Physical TCP connections this client has opened so far.
    pub fn connections_opened(&self) -> u64 {
        self.opened.load(Ordering::Relaxed)
    }

    /// Connect with the configured timeout (not the OS default, which
    /// can be minutes), trying each resolved address.
    fn connect(&self) -> Result<TcpStream> {
        let addrs = self
            .addr
            .to_socket_addrs()
            .map_err(|e| Error::io_ctx(format!("resolving {}", self.addr), e))?;
        let mut last: Option<std::io::Error> = None;
        for a in addrs {
            match TcpStream::connect_timeout(&a, self.timeout) {
                Ok(s) => {
                    self.opened.fetch_add(1, Ordering::Relaxed);
                    let _ = s.set_nodelay(true);
                    let _ = s.set_read_timeout(Some(self.timeout));
                    let _ = s.set_write_timeout(Some(self.timeout));
                    return Ok(s);
                }
                Err(e) => last = Some(e),
            }
        }
        Err(Error::io_ctx(
            format!("connecting to {}", self.addr),
            last.unwrap_or_else(|| {
                std::io::Error::new(std::io::ErrorKind::NotFound, "no addresses resolved")
            }),
        ))
    }

    /// One request/response exchange on `stream`.
    fn exchange(&self, stream: &mut TcpStream, target: &str) -> Result<HttpResponse> {
        let connection = if self.reuse { "keep-alive" } else { "close" };
        let strict = if self.strict {
            "X-Gbatc-Strict: 1\r\n"
        } else {
            ""
        };
        let req = format!(
            "GET {target} HTTP/1.1\r\nHost: {}\r\nConnection: {connection}\r\n{strict}\r\n",
            self.addr
        );
        stream
            .write_all(req.as_bytes())
            .map_err(|e| Error::io_ctx("sending request", e))?;
        http::read_response(stream)
    }

    fn get(&self, target: &str) -> Result<HttpResponse> {
        if !self.reuse {
            let mut stream = self.connect()?;
            return self.exchange(&mut stream, target);
        }
        let mut guard = match self.sock.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        // a cached socket may have been reaped server-side while idle;
        // one failed exchange on a *reused* socket earns one retry on a
        // fresh connection, after which errors are real
        let mut fresh = false;
        let mut stream = match guard.take() {
            Some(s) => s,
            None => {
                fresh = true;
                self.connect()?
            }
        };
        let resp = match self.exchange(&mut stream, target) {
            Ok(resp) => resp,
            Err(e) => {
                if fresh {
                    return Err(e);
                }
                stream = self.connect()?;
                self.exchange(&mut stream, target)?
            }
        };
        if !resp.closes_connection() {
            *guard = Some(stream);
        }
        Ok(resp)
    }

    fn get_ok(&self, target: &str) -> Result<HttpResponse> {
        let resp = self.get(target)?;
        if resp.status != 200 {
            return Err(Error::protocol(format!(
                "{target}: HTTP {} — {}",
                resp.status,
                String::from_utf8_lossy(&resp.body)
            )));
        }
        Ok(resp)
    }

    /// Raw JSON catalog from `GET /datasets`.
    pub fn datasets_json(&self) -> Result<String> {
        let resp = self.get_ok("/datasets")?;
        String::from_utf8(resp.body).map_err(|_| Error::protocol("/datasets body is not UTF-8"))
    }

    /// Raw JSON counters from `GET /stats`.
    pub fn stats_json(&self) -> Result<String> {
        let resp = self.get_ok("/stats")?;
        String::from_utf8(resp.body).map_err(|_| Error::protocol("/stats body is not UTF-8"))
    }

    /// Prometheus text exposition from `GET /metrics`.
    pub fn metrics_text(&self) -> Result<String> {
        let resp = self.get_ok("/metrics")?;
        String::from_utf8(resp.body).map_err(|_| Error::protocol("/metrics body is not UTF-8"))
    }

    /// Raw JSON from `GET /trace/slow?n=N` — the server's worst spans.
    pub fn trace_slow_json(&self, n: usize) -> Result<String> {
        let resp = self.get_ok(&format!("/trace/slow?n={n}"))?;
        String::from_utf8(resp.body).map_err(|_| Error::protocol("/trace/slow body is not UTF-8"))
    }

    /// Run a remote query.  `t0`/`t1` default to the dataset's full time
    /// axis; `species` is the CLI list syntax (names and/or indices,
    /// empty = all).
    pub fn query(
        &self,
        dataset: &str,
        t0: Option<usize>,
        t1: Option<usize>,
        species: &str,
    ) -> Result<ClientDecode> {
        let mut target = format!("/query?dataset={dataset}");
        if let Some(t0) = t0 {
            target.push_str(&format!("&t0={t0}"));
        }
        if let Some(t1) = t1 {
            target.push_str(&format!("&t1={t1}"));
        }
        if !species.is_empty() {
            target.push_str(&format!("&species={species}"));
        }
        let resp = self.get_ok(&target)?;
        let trace_id = resp.header("x-gbatc-trace-id").map(|v| v.to_string());
        let meta = resp
            .header("x-gbatc-meta")
            .ok_or_else(|| Error::protocol("query response lacks the X-Gbatc-Meta header"))?
            .to_string();
        let t0 = http::json_u64(&meta, "t0")? as usize;
        let nt = http::json_u64(&meta, "nt")? as usize;
        let ny = http::json_u64(&meta, "ny")? as usize;
        let nx = http::json_u64(&meta, "nx")? as usize;
        let species = http::json_usize_array(&meta, "species")?;
        let nrmse_target = http::json_f64(&meta, "nrmse_target")?;
        let pressure = http::json_f64(&meta, "pressure")?;
        let expect = nt * species.len() * ny * nx * 4;
        if resp.body.len() != expect {
            return Err(Error::protocol(format!(
                "query body is {} bytes, meta implies {expect}",
                resp.body.len()
            )));
        }
        let mass = resp
            .body
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();
        // degraded fields are absent from healthy responses; a `null`
        // bound parses as "no statable bound"
        let degraded = meta.contains("\"degraded\":true");
        let degraded_bound = if degraded {
            http::json_f64(&meta, "degraded_bound").ok()
        } else {
            None
        };
        Ok(ClientDecode {
            t0,
            nt,
            ny,
            nx,
            species,
            nrmse_target,
            pressure,
            mass,
            degraded,
            degraded_bound,
            meta_json: meta,
            trace_id,
        })
    }
}
