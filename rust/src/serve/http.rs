//! Minimal HTTP/1.1 framing — just enough protocol for the query server
//! and its blocking client, with zero dependencies.
//!
//! Scope (deliberate): `GET`-only requests, `Content-Length`-framed
//! bodies, keep-alive and pipelining via [`HttpParser`] (incremental by
//! construction — bytes may arrive one at a time, split anywhere,
//! including mid-CRLF), no percent-decoding (dataset names and species
//! lists are plain tokens — enforced at mount).  Every malformed input
//! is a typed [`Error::Protocol`]; every socket failure is a typed
//! [`Error::IoContext`] — nothing on this path panics.
//!
//! The same parser feeds both servers: the epoll event loop hands it
//! whatever a nonblocking `read(2)` returned, the thread-pool fallback
//! hands it blocking-read chunks, and the dribble tests hand it one
//! byte at a time — framing never depends on how reads were sized.

use std::io::{Read, Write};
use std::net::TcpStream;

use crate::error::{Error, Result};

/// Head-size cap for *responses* read by the client (the meta header
/// carries a species index array, so it is roomier than the server's
/// request cap).
pub const MAX_RESPONSE_HEAD: usize = 64 * 1024;

/// Message prefix of the over-cap head error — the one protocol failure
/// the server maps to its own status (`431`), so the mapping keys on
/// this shared constant rather than on incidental wording.
pub const OVERSIZE_MARK: &str = "oversized head:";

/// Response header carrying the request's trace ID (16 hex digits)
/// when the server has tracing enabled.
pub const TRACE_ID_HEADER: &str = "X-Gbatc-Trace-Id";

/// A parsed request line + query string + the little header state the
/// server acts on.
#[derive(Clone, Debug)]
pub struct Request {
    pub method: String,
    /// Path without the query string, e.g. `/query`.
    pub path: String,
    /// `key=value` pairs of the query string, in order.
    pub params: Vec<(String, String)>,
    /// Client asked to end the connection after this exchange
    /// (`Connection: close`, or HTTP/1.0 without `keep-alive`).
    pub close: bool,
    /// This request was already buffered when the previous one was
    /// parsed — i.e. the client pipelined it (no socket read between
    /// the two yields).  Feeds the server's `pipelined` counter.
    pub pipelined: bool,
    /// Client sent `X-Gbatc-Strict: 1` — it would rather get a `503`
    /// than a degraded (salvaged, loosened-bound) query response.
    pub strict: bool,
}

impl Request {
    /// First value of a query parameter.
    pub fn param(&self, key: &str) -> Option<&str> {
        self.params
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }

    /// The request target reassembled from path + query string (trace
    /// span labels; the parse split them apart).
    pub fn target(&self) -> String {
        if self.params.is_empty() {
            return self.path.clone();
        }
        let mut out = String::with_capacity(self.path.len() + 16);
        out.push_str(&self.path);
        for (i, (k, v)) in self.params.iter().enumerate() {
            out.push(if i == 0 { '?' } else { '&' });
            out.push_str(k);
            if !v.is_empty() {
                out.push('=');
                out.push_str(v);
            }
        }
        out
    }
}

/// Byte offset just past the `\r\n\r\n` head terminator, if present.
fn head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n").map(|i| i + 4)
}

/// Incremental request framing: feed bytes as they arrive, pull zero or
/// more complete requests out.  One parser per connection; its buffer
/// carries pipelined requests and partial heads across reads, and a
/// declared `Content-Length` body is discarded before the next request
/// is framed (GET bodies are ignored but must not desync the stream).
#[derive(Debug)]
pub struct HttpParser {
    buf: Vec<u8>,
    max_head: usize,
    /// Body bytes of the previous request still to discard.
    skip: usize,
    /// Whether `feed` ran since the last yielded request — when it did
    /// not, the next request was pipelined in the same segment.
    fed_since_yield: bool,
}

impl HttpParser {
    /// A parser rejecting heads over `max_head` bytes.
    pub fn new(max_head: usize) -> HttpParser {
        HttpParser {
            buf: Vec::new(),
            max_head,
            skip: 0,
            fed_since_yield: true,
        }
    }

    /// Append freshly read bytes (any split, including mid-CRLF).
    pub fn feed(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
        self.fed_since_yield = true;
    }

    /// Bytes currently buffered (the server's read-buffer byte meter).
    pub fn buffered(&self) -> usize {
        self.buf.len()
    }

    /// Whether a complete, not-yet-parsed request may be sitting in the
    /// buffer (cheap check used to resume parsing after throttling).
    pub fn has_buffered_data(&self) -> bool {
        self.buf.len() > self.skip
    }

    /// Try to frame the next request out of the buffer.  `Ok(None)`
    /// means "need more bytes"; errors are fatal to the connection (the
    /// stream cannot be re-synchronized after a malformed head).
    pub fn next_request(&mut self) -> Result<Option<Request>> {
        // discard the previous request's declared body first
        if self.skip > 0 {
            let n = self.skip.min(self.buf.len());
            self.buf.drain(..n);
            self.skip -= n;
            if self.skip > 0 {
                return Ok(None);
            }
        }
        let end = match head_end(&self.buf) {
            Some(end) => end,
            None => {
                if self.buf.len() > self.max_head {
                    return Err(Error::protocol(format!(
                        "{OVERSIZE_MARK} request head over {} bytes",
                        self.max_head
                    )));
                }
                return Ok(None);
            }
        };
        if end > self.max_head {
            return Err(Error::protocol(format!(
                "{OVERSIZE_MARK} request head over {} bytes",
                self.max_head
            )));
        }
        let pipelined = !self.fed_since_yield;
        let (mut req, body_len) = parse_request_head(&self.buf[..end])?;
        if body_len > self.max_head {
            return Err(Error::protocol(format!(
                "request body of {body_len} bytes on a GET-only endpoint"
            )));
        }
        req.pipelined = pipelined;
        self.buf.drain(..end);
        // queue the body discard (may span future reads)
        self.skip = body_len;
        let n = self.skip.min(self.buf.len());
        self.buf.drain(..n);
        self.skip -= n;
        self.fed_since_yield = false;
        Ok(Some(req))
    }
}

/// Parse one complete request head (including the blank line).  Returns
/// the request plus its declared `Content-Length` (0 when absent).
fn parse_request_head(head_bytes: &[u8]) -> Result<(Request, usize)> {
    let head = std::str::from_utf8(head_bytes)
        .map_err(|_| Error::protocol("request head is not UTF-8"))?;
    let mut lines = head.lines();
    let line = lines
        .next()
        .ok_or_else(|| Error::protocol("empty request"))?;
    let mut toks = line.split_whitespace();
    let (method, target, version) = match (toks.next(), toks.next(), toks.next(), toks.next()) {
        (Some(m), Some(t), Some(v), None) => (m, t, v),
        _ => {
            return Err(Error::protocol(format!(
                "malformed request line `{line}`"
            )))
        }
    };
    if !version.starts_with("HTTP/1.") {
        return Err(Error::protocol(format!("unsupported version `{version}`")));
    }
    if !target.starts_with('/') {
        return Err(Error::protocol(format!("malformed target `{target}`")));
    }
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p, q),
        None => (target, ""),
    };
    let params = query
        .split('&')
        .filter(|kv| !kv.is_empty())
        .map(|kv| match kv.split_once('=') {
            Some((k, v)) => (k.to_string(), v.to_string()),
            None => (kv.to_string(), String::new()),
        })
        .collect();

    // HTTP/1.1 defaults to keep-alive, HTTP/1.0 to close
    let mut close = version == "HTTP/1.0";
    let mut body_len = 0usize;
    let mut strict = false;
    for line in lines {
        if line.is_empty() {
            break;
        }
        let Some((k, v)) = line.split_once(':') else {
            return Err(Error::protocol(format!("malformed header `{line}`")));
        };
        let name = k.trim().to_ascii_lowercase();
        let value = v.trim();
        match name.as_str() {
            "connection" => {
                let value = value.to_ascii_lowercase();
                if value.contains("close") {
                    close = true;
                } else if value.contains("keep-alive") {
                    close = false;
                }
            }
            "content-length" => {
                body_len = value.parse().map_err(|e| {
                    Error::protocol(format!("bad Content-Length `{value}`: {e}"))
                })?;
            }
            "x-gbatc-strict" => strict = value == "1",
            _ => {}
        }
    }
    Ok((
        Request {
            method: method.to_string(),
            path: path.to_string(),
            params,
            close,
            pipelined: false,
            strict,
        },
        body_len,
    ))
}

/// Standard reason phrase for the statuses this server emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Content Too Large",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// Serialize one complete response (head + body) into a byte buffer —
/// what the event loop queues on a connection's write side.
pub fn serialize_response(
    status: u16,
    content_type: &str,
    extra_headers: &[(&str, &str)],
    body: &[u8],
    keep_alive: bool,
) -> Vec<u8> {
    let mut head = format!(
        "HTTP/1.1 {status} {}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: {}\r\n",
        reason(status),
        body.len(),
        if keep_alive { "keep-alive" } else { "close" }
    );
    for (k, v) in extra_headers {
        head.push_str(k);
        head.push_str(": ");
        head.push_str(v);
        head.push_str("\r\n");
    }
    head.push_str("\r\n");
    let mut out = head.into_bytes();
    out.extend_from_slice(body);
    out
}

/// Write one complete response on a blocking stream.
pub fn write_response(
    stream: &mut TcpStream,
    status: u16,
    content_type: &str,
    extra_headers: &[(&str, &str)],
    body: &[u8],
    keep_alive: bool,
) -> Result<()> {
    let bytes = serialize_response(status, content_type, extra_headers, body, keep_alive);
    let ctx = |e| Error::io_ctx("writing response", e);
    stream.write_all(&bytes).map_err(ctx)?;
    stream.flush().map_err(ctx)
}

/// Read from `stream` until a full head (`\r\n\r\n`) is buffered,
/// rejecting heads over `max_bytes`.  Returns the buffer and the offset
/// where the body (if any) begins inside it — chunked reads may have
/// pulled body bytes in already.
fn read_head(stream: &mut TcpStream, max_bytes: usize, what: &str) -> Result<(Vec<u8>, usize)> {
    let mut buf = Vec::with_capacity(512);
    let mut chunk = [0u8; 1024];
    loop {
        if let Some(end) = head_end(&buf) {
            return Ok((buf, end));
        }
        if buf.len() > max_bytes {
            return Err(Error::protocol(format!(
                "{OVERSIZE_MARK} {what} head over {max_bytes} bytes"
            )));
        }
        let n = stream
            .read(&mut chunk)
            .map_err(|e| Error::io_ctx(format!("reading {what}"), e))?;
        if n == 0 {
            return Err(Error::protocol(format!(
                "connection closed before a full {what} head"
            )));
        }
        buf.extend_from_slice(&chunk[..n]);
    }
}

/// A complete response as the blocking client reads it.
#[derive(Clone, Debug)]
pub struct HttpResponse {
    pub status: u16,
    /// `(lowercased name, value)` pairs.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl HttpResponse {
    /// Header value by case-insensitive name.
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers
            .iter()
            .find(|(k, _)| *k == name)
            .map(|(_, v)| v.as_str())
    }

    /// Whether the server will close the connection after this response
    /// (the client must not reuse its socket).
    pub fn closes_connection(&self) -> bool {
        self.header("connection")
            .map(|v| v.to_ascii_lowercase().contains("close"))
            .unwrap_or(false)
    }
}

/// Read one `Content-Length`-framed response off `stream`.  Reads
/// exactly one response's bytes: the client drives requests in
/// lockstep, so nothing past the body can be in flight yet and the
/// stream stays aligned for keep-alive reuse.
pub fn read_response(stream: &mut TcpStream) -> Result<HttpResponse> {
    let (buf, end) = read_head(stream, MAX_RESPONSE_HEAD, "response")?;
    let head = std::str::from_utf8(&buf[..end])
        .map_err(|_| Error::protocol("response head is not UTF-8"))?;
    let mut lines = head.lines();
    let status_line = lines
        .next()
        .ok_or_else(|| Error::protocol("empty response"))?;
    // "HTTP/1.1 200 OK"
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| Error::protocol(format!("malformed status line `{status_line}`")))?;
    let mut headers = Vec::new();
    for line in lines {
        if line.is_empty() {
            break;
        }
        let (k, v) = line
            .split_once(':')
            .ok_or_else(|| Error::protocol(format!("malformed header `{line}`")))?;
        headers.push((k.trim().to_ascii_lowercase(), v.trim().to_string()));
    }
    let content_length: usize = headers
        .iter()
        .find(|(k, _)| k == "content-length")
        .and_then(|(_, v)| v.parse().ok())
        .ok_or_else(|| Error::protocol("response has no valid Content-Length"))?;
    let mut body = buf[end..].to_vec();
    if body.len() > content_length {
        return Err(Error::protocol(format!(
            "response body overruns Content-Length {content_length}"
        )));
    }
    let have = body.len();
    body.resize(content_length, 0);
    stream
        .read_exact(&mut body[have..])
        .map_err(|e| Error::io_ctx("reading response body", e))?;
    Ok(HttpResponse {
        status,
        headers,
        body,
    })
}

// ---- tiny JSON helpers (no serde in the offline image) ----------------

/// Escape a string for a JSON literal.
pub fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// `{"error":"..."}` body for error responses.
pub fn json_error(msg: &str) -> String {
    format!("{{\"error\":\"{}\"}}", json_escape(msg))
}

/// The raw token after `"key":` in flat JSON (up to `,`, `}`, or `]`).
fn json_token<'a>(json: &'a str, key: &str) -> Result<&'a str> {
    let pat = format!("\"{key}\":");
    let at = json
        .find(&pat)
        .ok_or_else(|| Error::protocol(format!("JSON field `{key}` missing")))?;
    let rest = &json[at + pat.len()..];
    let end = rest
        .find(|c| c == ',' || c == '}' || c == ']')
        .unwrap_or(rest.len());
    Ok(rest[..end].trim())
}

/// Parse `"key": <unsigned integer>` out of flat JSON.
pub fn json_u64(json: &str, key: &str) -> Result<u64> {
    json_token(json, key)?
        .parse()
        .map_err(|e| Error::protocol(format!("JSON field `{key}`: {e}")))
}

/// Parse `"key": <number>` out of flat JSON.
pub fn json_f64(json: &str, key: &str) -> Result<f64> {
    json_token(json, key)?
        .parse()
        .map_err(|e| Error::protocol(format!("JSON field `{key}`: {e}")))
}

/// Parse `"key": [i0, i1, ...]` out of flat JSON.
pub fn json_usize_array(json: &str, key: &str) -> Result<Vec<usize>> {
    let pat = format!("\"{key}\":");
    let at = json
        .find(&pat)
        .ok_or_else(|| Error::protocol(format!("JSON field `{key}` missing")))?;
    let rest = json[at + pat.len()..].trim_start();
    let rest = rest
        .strip_prefix('[')
        .ok_or_else(|| Error::protocol(format!("JSON field `{key}` is not an array")))?;
    let end = rest
        .find(']')
        .ok_or_else(|| Error::protocol(format!("JSON array `{key}` unterminated")))?;
    rest[..end]
        .split(',')
        .map(str::trim)
        .filter(|t| !t.is_empty())
        .map(|t| {
            t.parse()
                .map_err(|e| Error::protocol(format!("JSON array `{key}` entry `{t}`: {e}")))
        })
        .collect()
}

/// Render `[i0,i1,...]`.
pub fn json_usize_list(v: &[usize]) -> String {
    let mut out = String::from("[");
    for (i, x) in v.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&x.to_string());
    }
    out.push(']');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_helpers_round_trip() {
        let meta = "{\"t0\":3,\"nt\":4,\"nrmse\":1e-3,\"species\":[1, 3, 7],\"tail\":0}";
        assert_eq!(json_u64(meta, "t0").unwrap(), 3);
        assert_eq!(json_u64(meta, "nt").unwrap(), 4);
        assert_eq!(json_f64(meta, "nrmse").unwrap(), 1e-3);
        assert_eq!(json_usize_array(meta, "species").unwrap(), vec![1, 3, 7]);
        assert_eq!(json_usize_array("{\"s\":[]}", "s").unwrap(), Vec::<usize>::new());
        assert!(json_u64(meta, "missing").is_err());
        assert!(json_usize_array(meta, "t0").is_err());
        assert_eq!(json_usize_list(&[1, 3, 7]), "[1,3,7]");
        assert_eq!(json_usize_list(&[]), "[]");
        assert_eq!(json_escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        assert!(json_error("boom").contains("\"error\""));
    }

    #[test]
    fn head_end_detection() {
        assert_eq!(head_end(b"GET / HTTP/1.1\r\n\r\nBODY"), Some(18));
        assert_eq!(head_end(b"partial\r\n"), None);
    }

    #[test]
    fn parser_one_byte_dribble_with_split_crlfs() {
        // the framing bug this guards: a head arriving one byte at a
        // time — every CRLF split across feeds — must still parse
        let raw = b"GET /query?dataset=d&t0=1 HTTP/1.1\r\nHost: x\r\nConnection: keep-alive\r\n\r\n";
        let mut p = HttpParser::new(8 * 1024);
        for (i, b) in raw.iter().enumerate() {
            p.feed(&[*b]);
            let got = p.next_request().unwrap();
            if i + 1 < raw.len() {
                assert!(got.is_none(), "yielded early at byte {i}");
            } else {
                let req = got.expect("full head must parse");
                assert_eq!(req.method, "GET");
                assert_eq!(req.path, "/query");
                assert_eq!(req.param("dataset"), Some("d"));
                assert_eq!(req.param("t0"), Some("1"));
                assert!(!req.close);
                assert!(!req.pipelined);
            }
        }
        assert_eq!(p.buffered(), 0);
    }

    #[test]
    fn parser_pipelined_requests_in_one_segment() {
        let mut p = HttpParser::new(8 * 1024);
        let mut seg = Vec::new();
        for i in 0..3 {
            seg.extend_from_slice(format!("GET /r{i} HTTP/1.1\r\n\r\n").as_bytes());
        }
        p.feed(&seg);
        for i in 0..3 {
            let req = p.next_request().unwrap().expect("buffered request");
            assert_eq!(req.path, format!("/r{i}"));
            assert_eq!(req.pipelined, i > 0, "request {i}");
        }
        assert!(p.next_request().unwrap().is_none());
    }

    #[test]
    fn parser_discards_declared_bodies_between_requests() {
        let mut p = HttpParser::new(8 * 1024);
        p.feed(b"GET /a HTTP/1.1\r\nContent-Length: 5\r\n\r\nBOD");
        let req = p.next_request().unwrap().expect("first request");
        assert_eq!(req.path, "/a");
        // body incomplete: no next request yet
        assert!(p.next_request().unwrap().is_none());
        p.feed(b"Y!GET /b HTTP/1.1\r\n\r\n");
        let req = p.next_request().unwrap().expect("second request");
        assert_eq!(req.path, "/b");
    }

    #[test]
    fn parser_connection_and_version_semantics() {
        let mut p = HttpParser::new(8 * 1024);
        p.feed(b"GET /a HTTP/1.1\r\nConnection: close\r\n\r\n");
        assert!(p.next_request().unwrap().unwrap().close);
        p.feed(b"GET /b HTTP/1.0\r\n\r\n");
        assert!(p.next_request().unwrap().unwrap().close, "1.0 defaults to close");
        p.feed(b"GET /c HTTP/1.0\r\nConnection: keep-alive\r\n\r\n");
        assert!(!p.next_request().unwrap().unwrap().close);
        p.feed(b"GET /d HTTP/1.1\r\n\r\n");
        assert!(!p.next_request().unwrap().unwrap().close, "1.1 defaults to keep-alive");
    }

    #[test]
    fn parser_reads_strict_header() {
        let mut p = HttpParser::new(8 * 1024);
        p.feed(b"GET /query HTTP/1.1\r\nX-Gbatc-Strict: 1\r\n\r\n");
        assert!(p.next_request().unwrap().unwrap().strict);
        p.feed(b"GET /query HTTP/1.1\r\n\r\n");
        assert!(!p.next_request().unwrap().unwrap().strict);
        p.feed(b"GET /query HTTP/1.1\r\nx-gbatc-strict: 0\r\n\r\n");
        assert!(!p.next_request().unwrap().unwrap().strict);
    }

    #[test]
    fn parser_rejects_oversized_and_malformed() {
        let mut p = HttpParser::new(64);
        p.feed(&vec![b'x'; 100]);
        let err = p.next_request().unwrap_err().to_string();
        assert!(err.contains("oversized"), "{err}");

        let mut p = HttpParser::new(8 * 1024);
        p.feed(b"NONSENSE\r\n\r\n");
        assert!(p.next_request().is_err());

        let mut p = HttpParser::new(8 * 1024);
        p.feed(b"GET /a HTTP/1.1\r\nContent-Length: nope\r\n\r\n");
        assert!(p.next_request().is_err());
    }

    #[test]
    fn serialize_response_frames_both_modes() {
        let ka = serialize_response(200, "application/json", &[("X-K", "v")], b"{}", true);
        let s = String::from_utf8(ka).unwrap();
        assert!(s.starts_with("HTTP/1.1 200 OK\r\n"), "{s}");
        assert!(s.contains("Connection: keep-alive\r\n"), "{s}");
        assert!(s.contains("Content-Length: 2\r\n"), "{s}");
        assert!(s.contains("X-K: v\r\n"), "{s}");
        assert!(s.ends_with("\r\n\r\n{}"), "{s}");
        let cl = serialize_response(400, "application/json", &[], b"", false);
        assert!(String::from_utf8(cl).unwrap().contains("Connection: close\r\n"));
    }
}
