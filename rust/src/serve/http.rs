//! Minimal HTTP/1.1 framing — just enough protocol for the query server
//! and its blocking client, with zero dependencies.
//!
//! Scope (deliberate): `GET`-only requests, one request per connection
//! (`Connection: close` everywhere), `Content-Length`-framed bodies, no
//! percent-decoding (dataset names and species lists are plain tokens —
//! enforced at mount).  Every malformed input is a typed
//! [`Error::Protocol`]; every socket failure is a typed
//! [`Error::IoContext`] — nothing on this path panics.

use std::io::{Read, Write};
use std::net::TcpStream;

use crate::error::{Error, Result};

/// Head-size cap for *responses* read by the client (the meta header
/// carries a species index array, so it is roomier than the server's
/// request cap).
pub const MAX_RESPONSE_HEAD: usize = 64 * 1024;

/// Message prefix of the over-cap head error — the one protocol failure
/// the server maps to its own status (`431`), so the mapping keys on
/// this shared constant rather than on incidental wording.
pub const OVERSIZE_MARK: &str = "oversized head:";

/// A parsed request line + query string.
#[derive(Clone, Debug)]
pub struct Request {
    pub method: String,
    /// Path without the query string, e.g. `/query`.
    pub path: String,
    /// `key=value` pairs of the query string, in order.
    pub params: Vec<(String, String)>,
}

impl Request {
    /// First value of a query parameter.
    pub fn param(&self, key: &str) -> Option<&str> {
        self.params
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }
}

/// Byte offset just past the `\r\n\r\n` head terminator, if present.
fn head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n").map(|i| i + 4)
}

/// Read from `stream` until a full head (`\r\n\r\n`) is buffered,
/// rejecting heads over `max_bytes`.  Returns the buffer and the offset
/// where the body (if any) begins inside it — chunked reads may have
/// pulled body bytes in already.
fn read_head(stream: &mut TcpStream, max_bytes: usize, what: &str) -> Result<(Vec<u8>, usize)> {
    let mut buf = Vec::with_capacity(512);
    let mut chunk = [0u8; 1024];
    loop {
        if let Some(end) = head_end(&buf) {
            return Ok((buf, end));
        }
        if buf.len() > max_bytes {
            return Err(Error::protocol(format!(
                "{OVERSIZE_MARK} {what} head over {max_bytes} bytes"
            )));
        }
        let n = stream
            .read(&mut chunk)
            .map_err(|e| Error::io_ctx(format!("reading {what}"), e))?;
        if n == 0 {
            return Err(Error::protocol(format!(
                "connection closed before a full {what} head"
            )));
        }
        buf.extend_from_slice(&chunk[..n]);
    }
}

/// Read and parse one request head.  `max_bytes` bounds the head (GET
/// requests carry no body we care about).
pub fn read_request(stream: &mut TcpStream, max_bytes: usize) -> Result<Request> {
    let (buf, end) = read_head(stream, max_bytes, "request")?;
    let head = std::str::from_utf8(&buf[..end])
        .map_err(|_| Error::protocol("request head is not UTF-8"))?;
    let line = head
        .lines()
        .next()
        .ok_or_else(|| Error::protocol("empty request"))?;
    let mut toks = line.split_whitespace();
    let (method, target, version) = match (toks.next(), toks.next(), toks.next(), toks.next()) {
        (Some(m), Some(t), Some(v), None) => (m, t, v),
        _ => {
            return Err(Error::protocol(format!(
                "malformed request line `{line}`"
            )))
        }
    };
    if !version.starts_with("HTTP/1.") {
        return Err(Error::protocol(format!("unsupported version `{version}`")));
    }
    if !target.starts_with('/') {
        return Err(Error::protocol(format!("malformed target `{target}`")));
    }
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p, q),
        None => (target, ""),
    };
    let params = query
        .split('&')
        .filter(|kv| !kv.is_empty())
        .map(|kv| match kv.split_once('=') {
            Some((k, v)) => (k.to_string(), v.to_string()),
            None => (kv.to_string(), String::new()),
        })
        .collect();
    Ok(Request {
        method: method.to_string(),
        path: path.to_string(),
        params,
    })
}

/// Standard reason phrase for the statuses this server emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Content Too Large",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// Write one complete `Connection: close` response.
pub fn write_response(
    stream: &mut TcpStream,
    status: u16,
    content_type: &str,
    extra_headers: &[(&str, &str)],
    body: &[u8],
) -> Result<()> {
    let mut head = format!(
        "HTTP/1.1 {status} {}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n",
        reason(status),
        body.len()
    );
    for (k, v) in extra_headers {
        head.push_str(k);
        head.push_str(": ");
        head.push_str(v);
        head.push_str("\r\n");
    }
    head.push_str("\r\n");
    let ctx = |e| Error::io_ctx("writing response", e);
    stream.write_all(head.as_bytes()).map_err(ctx)?;
    stream.write_all(body).map_err(ctx)?;
    stream.flush().map_err(ctx)
}

/// A complete response as the blocking client reads it.
#[derive(Clone, Debug)]
pub struct HttpResponse {
    pub status: u16,
    /// `(lowercased name, value)` pairs.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl HttpResponse {
    /// Header value by case-insensitive name.
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers
            .iter()
            .find(|(k, _)| *k == name)
            .map(|(_, v)| v.as_str())
    }
}

/// Read one `Content-Length`-framed response off `stream`.
pub fn read_response(stream: &mut TcpStream) -> Result<HttpResponse> {
    let (buf, end) = read_head(stream, MAX_RESPONSE_HEAD, "response")?;
    let head = std::str::from_utf8(&buf[..end])
        .map_err(|_| Error::protocol("response head is not UTF-8"))?;
    let mut lines = head.lines();
    let status_line = lines
        .next()
        .ok_or_else(|| Error::protocol("empty response"))?;
    // "HTTP/1.1 200 OK"
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| Error::protocol(format!("malformed status line `{status_line}`")))?;
    let mut headers = Vec::new();
    for line in lines {
        if line.is_empty() {
            break;
        }
        let (k, v) = line
            .split_once(':')
            .ok_or_else(|| Error::protocol(format!("malformed header `{line}`")))?;
        headers.push((k.trim().to_ascii_lowercase(), v.trim().to_string()));
    }
    let content_length: usize = headers
        .iter()
        .find(|(k, _)| k == "content-length")
        .and_then(|(_, v)| v.parse().ok())
        .ok_or_else(|| Error::protocol("response has no valid Content-Length"))?;
    let mut body = buf[end..].to_vec();
    if body.len() > content_length {
        return Err(Error::protocol(format!(
            "response body overruns Content-Length {content_length}"
        )));
    }
    let have = body.len();
    body.resize(content_length, 0);
    stream
        .read_exact(&mut body[have..])
        .map_err(|e| Error::io_ctx("reading response body", e))?;
    Ok(HttpResponse {
        status,
        headers,
        body,
    })
}

// ---- tiny JSON helpers (no serde in the offline image) ----------------

/// Escape a string for a JSON literal.
pub fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// `{"error":"..."}` body for error responses.
pub fn json_error(msg: &str) -> String {
    format!("{{\"error\":\"{}\"}}", json_escape(msg))
}

/// The raw token after `"key":` in flat JSON (up to `,`, `}`, or `]`).
fn json_token<'a>(json: &'a str, key: &str) -> Result<&'a str> {
    let pat = format!("\"{key}\":");
    let at = json
        .find(&pat)
        .ok_or_else(|| Error::protocol(format!("JSON field `{key}` missing")))?;
    let rest = &json[at + pat.len()..];
    let end = rest
        .find(|c| c == ',' || c == '}' || c == ']')
        .unwrap_or(rest.len());
    Ok(rest[..end].trim())
}

/// Parse `"key": <unsigned integer>` out of flat JSON.
pub fn json_u64(json: &str, key: &str) -> Result<u64> {
    json_token(json, key)?
        .parse()
        .map_err(|e| Error::protocol(format!("JSON field `{key}`: {e}")))
}

/// Parse `"key": <number>` out of flat JSON.
pub fn json_f64(json: &str, key: &str) -> Result<f64> {
    json_token(json, key)?
        .parse()
        .map_err(|e| Error::protocol(format!("JSON field `{key}`: {e}")))
}

/// Parse `"key": [i0, i1, ...]` out of flat JSON.
pub fn json_usize_array(json: &str, key: &str) -> Result<Vec<usize>> {
    let pat = format!("\"{key}\":");
    let at = json
        .find(&pat)
        .ok_or_else(|| Error::protocol(format!("JSON field `{key}` missing")))?;
    let rest = json[at + pat.len()..].trim_start();
    let rest = rest
        .strip_prefix('[')
        .ok_or_else(|| Error::protocol(format!("JSON field `{key}` is not an array")))?;
    let end = rest
        .find(']')
        .ok_or_else(|| Error::protocol(format!("JSON array `{key}` unterminated")))?;
    rest[..end]
        .split(',')
        .map(str::trim)
        .filter(|t| !t.is_empty())
        .map(|t| {
            t.parse()
                .map_err(|e| Error::protocol(format!("JSON array `{key}` entry `{t}`: {e}")))
        })
        .collect()
}

/// Render `[i0,i1,...]`.
pub fn json_usize_list(v: &[usize]) -> String {
    let mut out = String::from("[");
    for (i, x) in v.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&x.to_string());
    }
    out.push(']');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_helpers_round_trip() {
        let meta = "{\"t0\":3,\"nt\":4,\"nrmse\":1e-3,\"species\":[1, 3, 7],\"tail\":0}";
        assert_eq!(json_u64(meta, "t0").unwrap(), 3);
        assert_eq!(json_u64(meta, "nt").unwrap(), 4);
        assert_eq!(json_f64(meta, "nrmse").unwrap(), 1e-3);
        assert_eq!(json_usize_array(meta, "species").unwrap(), vec![1, 3, 7]);
        assert_eq!(json_usize_array("{\"s\":[]}", "s").unwrap(), Vec::<usize>::new());
        assert!(json_u64(meta, "missing").is_err());
        assert!(json_usize_array(meta, "t0").is_err());
        assert_eq!(json_usize_list(&[1, 3, 7]), "[1,3,7]");
        assert_eq!(json_usize_list(&[]), "[]");
        assert_eq!(json_escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        assert!(json_error("boom").contains("\"error\""));
    }

    #[test]
    fn head_end_detection() {
        assert_eq!(head_end(b"GET / HTTP/1.1\r\n\r\nBODY"), Some(18));
        assert_eq!(head_end(b"partial\r\n"), None);
    }
}
