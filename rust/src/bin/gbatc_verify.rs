//! `gbatc-verify` — the in-repo invariant linter (CI's `verify` job).
//!
//! Walks the source tree named by `verify.toml` and enforces the
//! project invariants the compiler cannot: the unsafe audit (SAFETY
//! comments + committed inventory), the determinism lints over the
//! archive-byte-producing modules, panic-freedom on the request path,
//! and no blocking I/O in the reactor files.  Exits 0 when clean, 1 on
//! findings, 2 on configuration or I/O errors.
//!
//! ```text
//! gbatc-verify [--root PATH] [--quiet]
//! ```
//!
//! Without `--root`, the manifest is located by walking upward from the
//! current directory, so the binary works from any repo subdirectory.

use std::path::PathBuf;
use std::process::ExitCode;

use gbatc::analysis;

fn main() -> ExitCode {
    let mut root: Option<PathBuf> = None;
    let mut quiet = false;
    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--root" => match args.next() {
                Some(p) => root = Some(PathBuf::from(p)),
                None => {
                    eprintln!("gbatc-verify: --root requires a path");
                    return ExitCode::from(2);
                }
            },
            "--quiet" | "-q" => quiet = true,
            "--help" | "-h" => {
                println!("usage: gbatc-verify [--root PATH] [--quiet]");
                println!();
                println!("Lints the source tree against the invariants in verify.toml:");
                println!("unsafe audit, determinism, panic freedom, reactor blocking.");
                println!("Exits 0 when clean, 1 on findings, 2 on config/IO errors.");
                return ExitCode::SUCCESS;
            }
            other => {
                eprintln!("gbatc-verify: unknown argument `{other}` (try --help)");
                return ExitCode::from(2);
            }
        }
    }

    let root = match root {
        Some(r) => r,
        None => {
            let cwd = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
            match analysis::find_root(&cwd) {
                Some(r) => r,
                None => {
                    eprintln!(
                        "gbatc-verify: no verify.toml found from {} upward (use --root)",
                        cwd.display()
                    );
                    return ExitCode::from(2);
                }
            }
        }
    };

    let report = match analysis::verify_root(&root) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("gbatc-verify: {e}");
            return ExitCode::from(2);
        }
    };

    for finding in &report.findings {
        println!("{finding}");
    }
    if !quiet {
        eprintln!(
            "gbatc-verify: {} file(s), {} unsafe site(s), {} finding(s)",
            report.files_scanned,
            report.unsafe_sites,
            report.findings.len()
        );
    }
    if report.findings.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}
