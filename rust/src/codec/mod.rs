//! Bitstream codecs for the three compressed payloads of GBATC:
//! AE latents (`latent`), PCA residual coefficients (`coeffs`), and the
//! per-block basis-index bitmaps with the paper's Fig.-2 shortest-prefix
//! encoding (`indices`).

pub mod coeffs;
pub mod indices;
pub mod latent;

pub use coeffs::{CoeffCodec, SpeciesCoeffs};
pub use indices::{decode_indices, encode_indices, raw_bitmap_bits};
pub use latent::LatentCodec;
