//! PCA-coefficient codec (Algorithm 1's storage payload).
//!
//! Per species, per block: the selected basis indices (Fig.-2 prefix
//! bitmaps, one shared bitstream) and the quantized coefficients (one
//! shared `IntCodec` Huffman stream).  Coefficients are stored in index
//! order so the two streams zip deterministically on decode.

use crate::codec::indices::{decode_indices, encode_indices};
use crate::entropy::IntCodec;
use crate::error::{Error, Result};
use crate::quant::UniformQuantizer;
use crate::util::bytes::{ByteReader, ByteWriter};
use crate::util::{BitReader, BitWriter};

/// One species' decoded coefficient payload: per block, the (basis index,
/// dequantized coefficient) pairs in ascending index order.
#[derive(Clone, Debug)]
pub struct SpeciesCoeffs {
    pub d: usize,
    pub bin: f64,
    pub per_block: Vec<Vec<(usize, f64)>>,
}

/// Encoder/decoder for one species' coefficients.
pub struct CoeffCodec;

impl CoeffCodec {
    /// `per_block[b]` = (index, *quantized integer* coefficient) pairs,
    /// ascending index. `d` = block vector dim, `bin` = quantizer width.
    pub fn encode(per_block: &[Vec<(usize, i64)>], d: usize, bin: f64) -> Result<Vec<u8>> {
        let mut bitmap = BitWriter::new();
        let mut values: Vec<i64> = Vec::new();
        for block in per_block {
            debug_assert!(block.windows(2).all(|w| w[0].0 < w[1].0));
            let idxs: Vec<usize> = block.iter().map(|&(i, _)| i).collect();
            encode_indices(&mut bitmap, &idxs, d)?;
            values.extend(block.iter().map(|&(_, q)| q));
        }
        let mut w = ByteWriter::new();
        w.u64(per_block.len() as u64);
        w.u64(d as u64);
        w.f64(bin);
        w.blob(&bitmap.finish());
        w.blob(&IntCodec::encode(&values)?);
        Ok(w.finish())
    }

    pub fn decode(buf: &[u8]) -> Result<SpeciesCoeffs> {
        let mut r = ByteReader::new(buf);
        let n_blocks = r.u64()? as usize;
        let d = r.u64()? as usize;
        let bin = r.f64()?;
        let bitmap = r.blob()?;
        let values = IntCodec::decode(r.blob()?)?;
        let q = UniformQuantizer::new(bin);

        let mut br = BitReader::new(bitmap);
        let mut per_block = Vec::with_capacity(n_blocks);
        let mut vi = 0usize;
        for _ in 0..n_blocks {
            let idxs = decode_indices(&mut br)?;
            let mut block = Vec::with_capacity(idxs.len());
            for i in idxs {
                let qv = *values
                    .get(vi)
                    .ok_or_else(|| Error::codec("coeffs: value stream underrun"))?;
                vi += 1;
                block.push((i, q.dequantize(qv)));
            }
            per_block.push(block);
        }
        if vi != values.len() {
            return Err(Error::codec("coeffs: value stream overrun"));
        }
        Ok(SpeciesCoeffs { d, bin, per_block })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Arbitrary};
    use crate::util::Prng;

    #[test]
    fn roundtrip_basic() {
        let per_block = vec![
            vec![(0usize, 5i64), (1, -3), (7, 100)],
            vec![],
            vec![(2, 1)],
        ];
        let bin = 0.5;
        let buf = CoeffCodec::encode(&per_block, 80, bin).unwrap();
        let dec = CoeffCodec::decode(&buf).unwrap();
        assert_eq!(dec.per_block.len(), 3);
        assert_eq!(dec.d, 80);
        for (orig, got) in per_block.iter().zip(&dec.per_block) {
            assert_eq!(orig.len(), got.len());
            for (&(i, q), &(gi, gv)) in orig.iter().zip(got) {
                assert_eq!(i, gi);
                assert!((gv - q as f64 * bin).abs() < 1e-12);
            }
        }
    }

    #[derive(Clone, Debug)]
    struct Case {
        d: usize,
        blocks: Vec<Vec<(usize, i64)>>,
    }
    impl Arbitrary for Case {
        fn generate(rng: &mut Prng) -> Self {
            let d = 2 + rng.index(100);
            let nb = rng.index(30);
            let blocks = (0..nb)
                .map(|_| {
                    let mut blk = Vec::new();
                    for i in 0..d {
                        if rng.next_f64() < 1.5 / (1.0 + i as f64) {
                            blk.push((i, (rng.normal() * 50.0) as i64));
                        }
                    }
                    blk
                })
                .collect();
            Case { d, blocks }
        }
        fn shrink(&self) -> Vec<Self> {
            if self.blocks.is_empty() {
                vec![]
            } else {
                vec![Case {
                    d: self.d,
                    blocks: self.blocks[..self.blocks.len() / 2].to_vec(),
                }]
            }
        }
    }

    #[test]
    fn prop_roundtrip_indices_and_counts() {
        check::<Case, _>(13, 150, |c| {
            let buf = CoeffCodec::encode(&c.blocks, c.d, 0.25).unwrap();
            let dec = CoeffCodec::decode(&buf).unwrap();
            dec.per_block.len() == c.blocks.len()
                && c.blocks.iter().zip(&dec.per_block).all(|(a, b)| {
                    a.len() == b.len()
                        && a.iter().zip(b).all(|(&(i, q), &(gi, gv))| {
                            i == gi && (gv - q as f64 * 0.25).abs() < 1e-12
                        })
                })
        });
    }

    #[test]
    fn corrupt_stream_is_error_not_panic() {
        let per_block = vec![vec![(0usize, 1i64), (3, -2)]; 10];
        let buf = CoeffCodec::encode(&per_block, 16, 0.1).unwrap();
        let short = &buf[..buf.len() - 3];
        assert!(CoeffCodec::decode(short).is_err());
    }
}
