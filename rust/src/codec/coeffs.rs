//! PCA-coefficient codec (Algorithm 1's storage payload).
//!
//! Per species, per block: the selected basis indices (Fig.-2 prefix
//! bitmaps, one shared bitstream) and the quantized coefficients (one
//! shared `IntCodec` Huffman stream).  Coefficients are stored in index
//! order so the two streams zip deterministically on decode.

use crate::codec::indices::{decode_indices, encode_indices};
use crate::entropy::IntCodec;
use crate::error::{Error, Result};
use crate::quant::UniformQuantizer;
use crate::util::bytes::{ByteReader, ByteWriter};
use crate::util::{BitReader, BitWriter};

/// One species' decoded coefficient payload: per block, the (basis index,
/// dequantized coefficient) pairs in ascending index order.
#[derive(Clone, Debug)]
pub struct SpeciesCoeffs {
    pub d: usize,
    pub bin: f64,
    pub per_block: Vec<Vec<(usize, f64)>>,
}

/// Encoder/decoder for one species' coefficients.
pub struct CoeffCodec;

impl CoeffCodec {
    /// `per_block[b]` = (index, *quantized integer* coefficient) pairs,
    /// ascending index. `d` = block vector dim, `bin` = quantizer width.
    pub fn encode(per_block: &[Vec<(usize, i64)>], d: usize, bin: f64) -> Result<Vec<u8>> {
        let mut bitmap = BitWriter::new();
        let mut values: Vec<i64> = Vec::new();
        for block in per_block {
            debug_assert!(block.windows(2).all(|w| w[0].0 < w[1].0));
            let idxs: Vec<usize> = block.iter().map(|&(i, _)| i).collect();
            encode_indices(&mut bitmap, &idxs, d)?;
            values.extend(block.iter().map(|&(_, q)| q));
        }
        let mut w = ByteWriter::new();
        w.u64(per_block.len() as u64);
        w.u64(d as u64);
        w.f64(bin);
        w.blob(&bitmap.finish());
        w.blob(&IntCodec::encode(&values)?);
        Ok(w.finish())
    }

    /// Best-effort decode for degraded-mode serving: keep the prefix of
    /// *fully* decoded blocks and leave the rest empty (⇒ prior-only
    /// reconstruction for those blocks).  Tolerates truncated bitmap and
    /// value streams — a blob whose declared length overruns the buffer
    /// is clamped to what survives.  Returns the coefficients plus the
    /// number of salvaged blocks; errors only when even the fixed header
    /// fields are unreadable or implausible.
    pub fn decode_salvage(buf: &[u8]) -> Result<(SpeciesCoeffs, usize)> {
        let mut r = ByteReader::new(buf);
        let n_blocks = r.u64()? as usize;
        let d = r.u64()? as usize;
        let bin = r.f64()?;
        if n_blocks > 1 << 28 || !bin.is_finite() {
            return Err(Error::codec(format!(
                "coeffs: implausible header (blocks {n_blocks}, bin {bin})"
            )));
        }
        let mut per_block = vec![Vec::new(); n_blocks];
        let bitmap = Self::clamped_blob(&mut r);
        let values = IntCodec::decode(Self::clamped_blob(&mut r)).unwrap_or_default();
        let q = UniformQuantizer::new(bin);
        let mut br = BitReader::new(bitmap);
        let mut vi = 0usize;
        let mut salvaged = 0usize;
        for slot in per_block.iter_mut() {
            let Ok(idxs) = decode_indices(&mut br) else {
                break; // torn bitmap: everything after is prior-only
            };
            if vi + idxs.len() > values.len() {
                break; // torn value stream mid-block: drop the block whole
            }
            *slot = idxs
                .into_iter()
                .map(|i| {
                    let v = (i, q.dequantize(values[vi]));
                    vi += 1;
                    v
                })
                .collect();
            salvaged += 1;
        }
        Ok((SpeciesCoeffs { d, bin, per_block }, salvaged))
    }

    /// Read a length-prefixed blob, clamping a declared length that
    /// overruns the buffer to the surviving bytes (empty when even the
    /// length is gone).
    fn clamped_blob<'a>(r: &mut ByteReader<'a>) -> &'a [u8] {
        match r.u64() {
            Ok(len) => {
                let take = usize::try_from(len).unwrap_or(usize::MAX).min(r.remaining());
                r.bytes(take).unwrap_or(&[])
            }
            Err(_) => &[],
        }
    }

    pub fn decode(buf: &[u8]) -> Result<SpeciesCoeffs> {
        let mut r = ByteReader::new(buf);
        let n_blocks = r.u64()? as usize;
        let d = r.u64()? as usize;
        let bin = r.f64()?;
        let bitmap = r.blob()?;
        let values = IntCodec::decode(r.blob()?)?;
        let q = UniformQuantizer::new(bin);

        let mut br = BitReader::new(bitmap);
        let mut per_block = Vec::with_capacity(n_blocks);
        let mut vi = 0usize;
        for _ in 0..n_blocks {
            let idxs = decode_indices(&mut br)?;
            let mut block = Vec::with_capacity(idxs.len());
            for i in idxs {
                let qv = *values
                    .get(vi)
                    .ok_or_else(|| Error::codec("coeffs: value stream underrun"))?;
                vi += 1;
                block.push((i, q.dequantize(qv)));
            }
            per_block.push(block);
        }
        if vi != values.len() {
            return Err(Error::codec("coeffs: value stream overrun"));
        }
        Ok(SpeciesCoeffs { d, bin, per_block })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Arbitrary};
    use crate::util::Prng;

    #[test]
    fn roundtrip_basic() {
        let per_block = vec![
            vec![(0usize, 5i64), (1, -3), (7, 100)],
            vec![],
            vec![(2, 1)],
        ];
        let bin = 0.5;
        let buf = CoeffCodec::encode(&per_block, 80, bin).unwrap();
        let dec = CoeffCodec::decode(&buf).unwrap();
        assert_eq!(dec.per_block.len(), 3);
        assert_eq!(dec.d, 80);
        for (orig, got) in per_block.iter().zip(&dec.per_block) {
            assert_eq!(orig.len(), got.len());
            for (&(i, q), &(gi, gv)) in orig.iter().zip(got) {
                assert_eq!(i, gi);
                assert!((gv - q as f64 * bin).abs() < 1e-12);
            }
        }
    }

    #[derive(Clone, Debug)]
    struct Case {
        d: usize,
        blocks: Vec<Vec<(usize, i64)>>,
    }
    impl Arbitrary for Case {
        fn generate(rng: &mut Prng) -> Self {
            let d = 2 + rng.index(100);
            let nb = rng.index(30);
            let blocks = (0..nb)
                .map(|_| {
                    let mut blk = Vec::new();
                    for i in 0..d {
                        if rng.next_f64() < 1.5 / (1.0 + i as f64) {
                            blk.push((i, (rng.normal() * 50.0) as i64));
                        }
                    }
                    blk
                })
                .collect();
            Case { d, blocks }
        }
        fn shrink(&self) -> Vec<Self> {
            if self.blocks.is_empty() {
                vec![]
            } else {
                vec![Case {
                    d: self.d,
                    blocks: self.blocks[..self.blocks.len() / 2].to_vec(),
                }]
            }
        }
    }

    #[test]
    fn prop_roundtrip_indices_and_counts() {
        check::<Case, _>(13, 150, |c| {
            let buf = CoeffCodec::encode(&c.blocks, c.d, 0.25).unwrap();
            let dec = CoeffCodec::decode(&buf).unwrap();
            dec.per_block.len() == c.blocks.len()
                && c.blocks.iter().zip(&dec.per_block).all(|(a, b)| {
                    a.len() == b.len()
                        && a.iter().zip(b).all(|(&(i, q), &(gi, gv))| {
                            i == gi && (gv - q as f64 * 0.25).abs() < 1e-12
                        })
                })
        });
    }

    #[test]
    fn salvage_matches_strict_decode_on_intact_input() {
        let per_block = vec![vec![(0usize, 1i64), (3, -2)]; 10];
        let buf = CoeffCodec::encode(&per_block, 16, 0.1).unwrap();
        let strict = CoeffCodec::decode(&buf).unwrap();
        let (sal, n) = CoeffCodec::decode_salvage(&buf).unwrap();
        assert_eq!(n, 10);
        assert_eq!(sal.per_block, strict.per_block);
        // truncated input: strict errors, salvage degrades gracefully
        let short = &buf[..buf.len() - 3];
        assert!(CoeffCodec::decode(short).is_err());
        let (sal, n) = CoeffCodec::decode_salvage(short).unwrap();
        assert_eq!(sal.per_block.len(), 10);
        assert!(n < 10);
        assert_eq!(&sal.per_block[..n], &strict.per_block[..n]);
        assert!(sal.per_block[n..].iter().all(|b| b.is_empty()));
    }

    #[test]
    fn salvage_keeps_fully_decoded_block_prefix() {
        // bitmap demands 2 values per block for 10 blocks, but only 7
        // values survive: blocks 0..3 decode whole, block 3 would tear
        let d = 16usize;
        let mut bitmap = BitWriter::new();
        for _ in 0..10 {
            encode_indices(&mut bitmap, &[0, 3], d).unwrap();
        }
        let values: Vec<i64> = (0..7i64).collect();
        let mut w = ByteWriter::new();
        w.u64(10);
        w.u64(d as u64);
        w.f64(0.1);
        w.blob(&bitmap.finish());
        w.blob(&IntCodec::encode(&values).unwrap());
        let buf = w.finish();
        assert!(CoeffCodec::decode(&buf).is_err());
        let (sal, n) = CoeffCodec::decode_salvage(&buf).unwrap();
        assert_eq!(n, 3);
        for (b, blk) in sal.per_block.iter().enumerate() {
            if b < 3 {
                assert_eq!(blk.iter().map(|&(i, _)| i).collect::<Vec<_>>(), vec![0, 3]);
            } else {
                assert!(blk.is_empty());
            }
        }
    }

    #[test]
    fn corrupt_stream_is_error_not_panic() {
        let per_block = vec![vec![(0usize, 1i64), (3, -2)]; 10];
        let buf = CoeffCodec::encode(&per_block, 16, 0.1).unwrap();
        let short = &buf[..buf.len() - 3];
        assert!(CoeffCodec::decode(short).is_err());
    }
}
