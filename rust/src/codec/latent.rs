//! AE latent codec: uniform quantization + Huffman (paper §II-A).
//!
//! The latent matrix is `[n_blocks, latent_dim]` f32.  Quantized with bin
//! width `d` and entropy-coded with the self-describing `IntCodec`; the
//! decoder recovers centers `q * d`, which is exactly what the decoder HLO
//! was fed during compression (so quantization error is part of the
//! residual the guarantee stage corrects).

use crate::entropy::IntCodec;
use crate::quant::UniformQuantizer;
use crate::error::Result;
use crate::util::bytes::{ByteReader, ByteWriter};

/// Encodes/decodes the latent plane.
pub struct LatentCodec;

/// Decoded latent payload.
pub struct LatentPlane {
    pub n: usize,
    pub dim: usize,
    pub bin: f64,
    pub values: Vec<f32>, // dequantized, length n*dim
}

impl LatentCodec {
    /// Quantize + encode. Returns (payload bytes, dequantized latents the
    /// compressor must feed to the decoder to make residuals exact).
    pub fn encode(latents: &[f32], n: usize, dim: usize, bin: f64) -> Result<(Vec<u8>, Vec<f32>)> {
        assert_eq!(latents.len(), n * dim);
        let q = UniformQuantizer::new(bin);
        let qs = q.quantize_slice(latents);
        let deq = q.dequantize_slice(&qs);
        let stream = IntCodec::encode(&qs)?;

        let mut w = ByteWriter::new();
        w.u64(n as u64);
        w.u64(dim as u64);
        w.f64(bin);
        w.blob(&stream);
        Ok((w.finish(), deq))
    }

    pub fn decode(buf: &[u8]) -> Result<LatentPlane> {
        let mut r = ByteReader::new(buf);
        let n = r.u64()? as usize;
        let dim = r.u64()? as usize;
        let bin = r.f64()?;
        let stream = r.blob()?;
        let qs = IntCodec::decode(stream)?;
        let q = UniformQuantizer::new(bin);
        let values = q.dequantize_slice(&qs);
        if values.len() != n * dim {
            return Err(crate::error::Error::codec(format!(
                "latent plane length {} != {}x{}",
                values.len(),
                n,
                dim
            )));
        }
        Ok(LatentPlane {
            n,
            dim,
            bin,
            values,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    #[test]
    fn roundtrip_matches_dequantized() {
        let mut rng = Prng::new(5);
        let (n, dim) = (100, 36);
        let latents: Vec<f32> = (0..n * dim).map(|_| (rng.normal() * 2.0) as f32).collect();
        let bin = 0.02;
        let (buf, deq) = LatentCodec::encode(&latents, n, dim, bin).unwrap();
        let plane = LatentCodec::decode(&buf).unwrap();
        assert_eq!(plane.values, deq);
        assert_eq!((plane.n, plane.dim), (n, dim));
        // error bound holds
        for (a, b) in latents.iter().zip(&plane.values) {
            assert!((a - b).abs() <= (bin / 2.0) as f32 + 1e-6);
        }
    }

    #[test]
    fn coarser_bins_compress_smaller() {
        let mut rng = Prng::new(6);
        let (n, dim) = (500, 36);
        let latents: Vec<f32> = (0..n * dim).map(|_| (rng.normal() * 2.0) as f32).collect();
        let (fine, _) = LatentCodec::encode(&latents, n, dim, 1e-4).unwrap();
        let (coarse, _) = LatentCodec::encode(&latents, n, dim, 1e-1).unwrap();
        assert!(coarse.len() < fine.len());
    }
}
