//! Basis-index bitmap encoding (paper Fig. 2).
//!
//! Each block selects a subset of the D basis vectors.  Because basis
//! vectors are eigenvalue-ordered, *early* indices are selected far more
//! often, so the selection bitmap almost always ends in a run of zeros.
//! The paper stores only the shortest prefix that contains all ones,
//! preceded by that prefix's length; we code the length with Elias gamma.

use crate::error::{Error, Result};
use crate::util::{BitReader, BitWriter};

/// Encode a selection of basis indices (strictly increasing, < d).
/// Writes gamma(prefix_len + 1) then `prefix_len` raw bitmap bits.
pub fn encode_indices(w: &mut BitWriter, selected: &[usize], d: usize) -> Result<()> {
    let mut bitmap = vec![false; d];
    for &i in selected {
        if i >= d {
            return Err(Error::codec(format!("index {i} out of range {d}")));
        }
        bitmap[i] = true;
    }
    let prefix_len = selected.iter().max().map_or(0, |&m| m + 1);
    w.write_gamma(prefix_len as u64 + 1);
    for &b in &bitmap[..prefix_len] {
        w.write_bit(b);
    }
    Ok(())
}

/// Decode the selection produced by [`encode_indices`].
pub fn decode_indices(r: &mut BitReader) -> Result<Vec<usize>> {
    let prefix_len = r
        .read_gamma()
        .ok_or_else(|| Error::codec("indices: EOF in prefix length"))? as usize
        - 1;
    let mut out = Vec::new();
    for i in 0..prefix_len {
        if r.read_bit()
            .ok_or_else(|| Error::codec("indices: EOF in bitmap"))?
        {
            out.push(i);
        }
    }
    Ok(out)
}

/// Bits a raw full-width bitmap would cost (the ablation baseline).
pub fn raw_bitmap_bits(d: usize) -> usize {
    d
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Arbitrary};
    use crate::util::Prng;

    fn roundtrip(selected: &[usize], d: usize) -> Vec<usize> {
        let mut w = BitWriter::new();
        encode_indices(&mut w, selected, d).unwrap();
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        decode_indices(&mut r).unwrap()
    }

    #[test]
    fn paper_example_shape() {
        // leading indices selected -> short prefix
        assert_eq!(roundtrip(&[0, 1, 3], 80), vec![0, 1, 3]);
        assert_eq!(roundtrip(&[], 80), Vec::<usize>::new());
        assert_eq!(roundtrip(&[79], 80), vec![79]);
    }

    #[test]
    fn leading_selection_is_compact() {
        // typical case: first 4 of 80 selected -> ~4 bits of bitmap,
        // far below the 80-bit raw bitmap
        let mut w = BitWriter::new();
        encode_indices(&mut w, &[0, 1, 2, 3], 80).unwrap();
        assert!(w.bit_len() < 16, "got {} bits", w.bit_len());
        assert!(raw_bitmap_bits(80) == 80);
    }

    #[test]
    fn multiple_blocks_in_one_stream() {
        let sels: Vec<Vec<usize>> = vec![vec![0, 2], vec![], vec![5], vec![0, 1, 2, 3, 10]];
        let mut w = BitWriter::new();
        for s in &sels {
            encode_indices(&mut w, s, 16).unwrap();
        }
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        for s in &sels {
            assert_eq!(&decode_indices(&mut r).unwrap(), s);
        }
    }

    #[test]
    fn out_of_range_rejected() {
        let mut w = BitWriter::new();
        assert!(encode_indices(&mut w, &[80], 80).is_err());
    }

    #[derive(Clone, Debug)]
    struct Sel {
        d: usize,
        sel: Vec<usize>,
    }
    impl Arbitrary for Sel {
        fn generate(rng: &mut Prng) -> Self {
            let d = 1 + rng.index(128);
            // eigenvalue-ordered bias: earlier indices more likely
            let sel: Vec<usize> = (0..d)
                .filter(|&i| rng.next_f64() < 0.5 / (1.0 + i as f64 * 0.3))
                .collect();
            Sel { d, sel }
        }
        fn shrink(&self) -> Vec<Self> {
            if self.sel.is_empty() {
                vec![]
            } else {
                vec![Sel {
                    d: self.d,
                    sel: self.sel[..self.sel.len() - 1].to_vec(),
                }]
            }
        }
    }

    #[test]
    fn prop_roundtrip() {
        check::<Sel, _>(11, 300, |c| roundtrip(&c.sel, c.d) == c.sel);
    }
}
