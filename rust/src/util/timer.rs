//! Wall-clock timing + a micro-bench helper for the criterion-less benches.

use std::time::{Duration, Instant};

/// Simple scoped timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Self {
            start: Instant::now(),
        }
    }
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }
    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
    pub fn ms(&self) -> f64 {
        self.secs() * 1e3
    }
}

/// Bench statistics over repeated runs.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub iters: usize,
    pub mean_s: f64,
    pub min_s: f64,
    pub max_s: f64,
    pub stddev_s: f64,
}

impl BenchStats {
    pub fn throughput(&self, items: f64) -> f64 {
        items / self.mean_s
    }
}

impl std::fmt::Display for BenchStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "mean {:>10.3} ms  min {:>10.3} ms  max {:>10.3} ms  sd {:>8.3} ms  ({} iters)",
            self.mean_s * 1e3,
            self.min_s * 1e3,
            self.max_s * 1e3,
            self.stddev_s * 1e3,
            self.iters
        )
    }
}

/// Run `f` repeatedly: `warmup` discarded iterations then `iters` measured.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        times.push(t.elapsed().as_secs_f64());
    }
    let mean = times.iter().sum::<f64>() / iters as f64;
    let var = times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>() / iters as f64;
    BenchStats {
        iters,
        mean_s: mean,
        min_s: times.iter().cloned().fold(f64::INFINITY, f64::min),
        max_s: times.iter().cloned().fold(0.0, f64::max),
        stddev_s: var.sqrt(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_iters() {
        let mut n = 0;
        let stats = bench(2, 5, || n += 1);
        assert_eq!(n, 7);
        assert_eq!(stats.iters, 5);
        assert!(stats.min_s <= stats.mean_s && stats.mean_s <= stats.max_s);
    }
}
