//! CRC-32 (IEEE 802.3, reflected polynomial `0xEDB88320`) — the
//! integrity checksum behind the streaming journal and shard trailers
//! (`archive::stream`) and the `repair`/`inspect --verify` tooling.
//!
//! Hand-rolled (the offline image vendors no crc crates): a slice-by-8
//! table kernel processes eight input bytes per step with eight
//! compile-time tables, and the one-table bytewise form is kept as the
//! oracle — `crc32_bytewise` is property-tested equal to [`crc32`] and
//! is the "before" side of the `crc32_sweep` row in
//! `benches/perf_hotpaths.rs`, so the cost of integrity checking stays
//! visible in CI.

/// Reflected CRC-32 polynomial (IEEE 802.3 / zlib / PNG).
const POLY: u32 = 0xEDB8_8320;

/// Eight slice-by-8 tables; `TABLES[0]` is the classic bytewise table.
static TABLES: [[u32; 256]; 8] = build_tables();

const fn build_tables() -> [[u32; 256]; 8] {
    let mut t = [[0u32; 256]; 8];
    let mut i = 0usize;
    while i < 256 {
        let mut crc = i as u32;
        let mut k = 0;
        while k < 8 {
            crc = if crc & 1 != 0 { (crc >> 1) ^ POLY } else { crc >> 1 };
            k += 1;
        }
        t[0][i] = crc;
        i += 1;
    }
    let mut j = 1usize;
    while j < 8 {
        let mut i = 0usize;
        while i < 256 {
            let prev = t[j - 1][i];
            t[j][i] = (prev >> 8) ^ t[0][(prev & 0xFF) as usize];
            i += 1;
        }
        j += 1;
    }
    t
}

/// Streaming CRC-32 state: feed bytes in any chunking, then
/// [`finalize`](Crc32::finalize).  Chunking never changes the digest.
#[derive(Clone, Copy, Debug)]
pub struct Crc32 {
    state: u32,
}

impl Default for Crc32 {
    fn default() -> Self {
        Self::new()
    }
}

impl Crc32 {
    pub fn new() -> Crc32 {
        Crc32 { state: !0 }
    }

    /// Absorb `bytes` (slice-by-8 over the aligned middle, bytewise
    /// head/tail).
    pub fn update(&mut self, bytes: &[u8]) {
        let mut crc = self.state;
        let mut chunks = bytes.chunks_exact(8);
        for c in &mut chunks {
            let lo = u32::from_le_bytes([c[0], c[1], c[2], c[3]]) ^ crc;
            crc = TABLES[7][(lo & 0xFF) as usize]
                ^ TABLES[6][((lo >> 8) & 0xFF) as usize]
                ^ TABLES[5][((lo >> 16) & 0xFF) as usize]
                ^ TABLES[4][(lo >> 24) as usize]
                ^ TABLES[3][c[4] as usize]
                ^ TABLES[2][c[5] as usize]
                ^ TABLES[1][c[6] as usize]
                ^ TABLES[0][c[7] as usize];
        }
        for &b in chunks.remainder() {
            crc = (crc >> 8) ^ TABLES[0][((crc ^ b as u32) & 0xFF) as usize];
        }
        self.state = crc;
    }

    /// The digest of everything absorbed so far.
    pub fn finalize(&self) -> u32 {
        !self.state
    }
}

/// One-shot CRC-32 of `bytes` (slice-by-8 kernel).
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(bytes);
    c.finalize()
}

/// One-shot bytewise CRC-32 — the single-table oracle the fast kernel is
/// tested and benchmarked against.
pub fn crc32_bytewise(bytes: &[u8]) -> u32 {
    let mut crc = !0u32;
    for &b in bytes {
        crc = (crc >> 8) ^ TABLES[0][((crc ^ b as u32) & 0xFF) as usize];
    }
    !crc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Arbitrary};
    use crate::util::Prng;

    #[test]
    fn known_vectors() {
        // canonical IEEE CRC-32 check values
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
        assert_eq!(crc32_bytewise(b"123456789"), 0xCBF4_3926);
    }

    #[derive(Clone, Debug)]
    struct Blob(Vec<u8>);

    impl Arbitrary for Blob {
        fn generate(rng: &mut Prng) -> Blob {
            let n = rng.index(600);
            Blob((0..n).map(|_| rng.next_u64() as u8).collect())
        }
        fn shrink(&self) -> Vec<Self> {
            if self.0.is_empty() {
                Vec::new()
            } else {
                vec![Blob(self.0[..self.0.len() / 2].to_vec())]
            }
        }
    }

    #[test]
    fn prop_slice_by_8_matches_bytewise_oracle() {
        check::<Blob, _>(31, 200, |b| crc32(&b.0) == crc32_bytewise(&b.0));
    }

    #[test]
    fn prop_chunking_is_invariant() {
        check::<Blob, _>(32, 100, |b| {
            let whole = crc32(&b.0);
            let mut c = Crc32::new();
            let mut rest = b.0.as_slice();
            let mut step = 1usize;
            while !rest.is_empty() {
                let n = step.min(rest.len());
                c.update(&rest[..n]);
                rest = &rest[n..];
                step = step * 2 + 1;
            }
            c.finalize() == whole
        });
    }

    #[test]
    fn single_bit_flips_change_the_digest() {
        let data = vec![0x5Au8; 257];
        let base = crc32(&data);
        for bit in [0usize, 7, 8, 1024, 257 * 8 - 1] {
            let mut flipped = data.clone();
            flipped[bit / 8] ^= 1 << (bit % 8);
            assert_ne!(crc32(&flipped), base, "bit {bit} collision");
        }
    }
}
