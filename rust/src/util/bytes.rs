//! Little-endian byte (de)serialization helpers for the hand-rolled binary
//! formats (no serde in the offline image).

use crate::error::{Error, Result};

/// Append-only byte sink with LE primitive writers.
#[derive(Default)]
pub struct ByteWriter {
    pub buf: Vec<u8>,
}

impl ByteWriter {
    pub fn new() -> Self {
        Self::default()
    }
    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    pub fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    pub fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    pub fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    pub fn bytes(&mut self, v: &[u8]) {
        self.buf.extend_from_slice(v);
    }
    /// Length-prefixed (u64) byte blob.
    pub fn blob(&mut self, v: &[u8]) {
        self.u64(v.len() as u64);
        self.bytes(v);
    }
    pub fn f32s(&mut self, v: &[f32]) {
        self.buf.reserve(v.len() * 4);
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }
    pub fn finish(self) -> Vec<u8> {
        self.buf
    }
}

/// Cursor-style LE reader with explicit error reporting.
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            return Err(Error::format(format!(
                "unexpected EOF: need {} bytes at {} of {}",
                n,
                self.pos,
                self.buf.len()
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    pub fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }
    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    pub fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    pub fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        self.take(n)
    }
    pub fn blob(&mut self) -> Result<&'a [u8]> {
        let n = self.u64()? as usize;
        self.take(n)
    }
    pub fn f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        let raw = self.take(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
    pub fn pos(&self) -> usize {
        self.pos
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_primitives() {
        let mut w = ByteWriter::new();
        w.u8(7);
        w.u16(65535);
        w.u32(0xDEADBEEF);
        w.u64(u64::MAX - 1);
        w.f32(1.5);
        w.f64(-2.25);
        w.blob(b"hello");
        w.f32s(&[1.0, 2.0, 3.0]);
        let bytes = w.finish();
        let mut r = ByteReader::new(&bytes);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u16().unwrap(), 65535);
        assert_eq!(r.u32().unwrap(), 0xDEADBEEF);
        assert_eq!(r.u64().unwrap(), u64::MAX - 1);
        assert_eq!(r.f32().unwrap(), 1.5);
        assert_eq!(r.f64().unwrap(), -2.25);
        assert_eq!(r.blob().unwrap(), b"hello");
        assert_eq!(r.f32s(3).unwrap(), vec![1.0, 2.0, 3.0]);
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn eof_is_error() {
        let bytes = [1u8, 2];
        let mut r = ByteReader::new(&bytes);
        assert!(r.u32().is_err());
    }
}
