//! Small shared utilities: PRNG, bit streams, byte codecs, property-test
//! driver, and timers.  All hand-rolled — the offline image vendors no
//! rand/serde/proptest (see DESIGN.md §2).

pub mod bits;
pub mod bytes;
pub mod crc32;
pub mod prng;
pub mod prop;
pub mod rle;
pub mod timer;

pub use bits::{BitReader, BitWriter};
pub use prng::Prng;
pub use timer::Timer;
