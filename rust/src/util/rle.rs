//! Byte-oriented run-length coding — the dependency-free lossless backend
//! behind the SZ payloads (the offline image vendors no zstd).  The input
//! stream is already Huffman-packed by `IntCodec`, so a heavier backend
//! buys little; RLE crushes the long repeat runs that bit-packed
//! all-same-symbol regions produce.
//!
//! Format: token `t < 0x80` copies the next `t + 1` literal bytes;
//! token `t >= 0x80` repeats the following byte `t - 0x80 + 3` times
//! (runs of 3..=130; longer runs chain).  Worst-case expansion is
//! 1 byte per 128 literals.

use crate::error::{Error, Result};

const MIN_RUN: usize = 3;
const MAX_LIT: usize = 128;
const MAX_RUN: usize = 127 + MIN_RUN;

pub fn compress(input: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(input.len() + input.len() / MAX_LIT + 16);
    let mut i = 0;
    let mut lit_start = 0;
    while i < input.len() {
        let b = input[i];
        let mut run = 1;
        while run < MAX_RUN && i + run < input.len() && input[i + run] == b {
            run += 1;
        }
        if run >= MIN_RUN {
            flush_literals(&mut out, &input[lit_start..i]);
            out.push(0x80 + (run - MIN_RUN) as u8);
            out.push(b);
            i += run;
            lit_start = i;
        } else {
            // short runs stay in the pending literal range
            i += run;
        }
    }
    flush_literals(&mut out, &input[lit_start..]);
    out
}

fn flush_literals(out: &mut Vec<u8>, mut lits: &[u8]) {
    while !lits.is_empty() {
        let n = lits.len().min(MAX_LIT);
        out.push((n - 1) as u8);
        out.extend_from_slice(&lits[..n]);
        lits = &lits[n..];
    }
}

/// Decode, refusing to grow beyond `max_len` (corruption guard).
pub fn decompress(input: &[u8], max_len: usize) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    let mut i = 0;
    while i < input.len() {
        let tok = input[i] as usize;
        i += 1;
        if tok < 0x80 {
            let n = tok + 1;
            let lit = input
                .get(i..i + n)
                .ok_or_else(|| Error::codec("rle: truncated literal run"))?;
            if out.len() + n > max_len {
                return Err(Error::codec("rle: output exceeds cap"));
            }
            out.extend_from_slice(lit);
            i += n;
        } else {
            let n = tok - 0x80 + MIN_RUN;
            let b = *input
                .get(i)
                .ok_or_else(|| Error::codec("rle: truncated repeat run"))?;
            i += 1;
            if out.len() + n > max_len {
                return Err(Error::codec("rle: output exceeds cap"));
            }
            out.extend(std::iter::repeat(b).take(n));
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    fn roundtrip(data: &[u8]) {
        let c = compress(data);
        let d = decompress(&c, data.len()).unwrap();
        assert_eq!(d, data);
    }

    #[test]
    fn roundtrips() {
        roundtrip(&[]);
        roundtrip(&[7]);
        roundtrip(&[1, 2, 3, 4, 5]);
        roundtrip(&[0; 1000]);
        roundtrip(&[9, 9, 9, 1, 1, 2, 2, 2, 2, 3]);
        let mut rng = Prng::new(3);
        let noisy: Vec<u8> = (0..5000).map(|_| rng.next_u64() as u8).collect();
        roundtrip(&noisy);
        let runny: Vec<u8> = (0..5000).map(|i| ((i / 200) % 7) as u8).collect();
        roundtrip(&runny);
    }

    #[test]
    fn runs_compress_noise_does_not_explode() {
        let zeros = vec![0u8; 10_000];
        assert!(compress(&zeros).len() < 200);
        let mut rng = Prng::new(4);
        let noisy: Vec<u8> = (0..10_000).map(|_| rng.next_u64() as u8).collect();
        assert!(compress(&noisy).len() <= 10_000 + 10_000 / 128 + 16);
    }

    #[test]
    fn truncation_and_caps_are_errors() {
        let c = compress(&[5u8; 100]);
        assert!(decompress(&c[..c.len() - 1], 1000).is_err());
        assert!(decompress(&c, 10).is_err());
        assert!(decompress(&[0x00], 10).is_err()); // literal run with no byte
        assert!(decompress(&[0x85], 10).is_err()); // repeat run with no byte
    }
}
