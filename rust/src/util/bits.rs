//! LSB-first bit streams used by the Huffman coder and the Fig.-2 index
//! codec.  Writes accumulate into a u64 register and spill whole bytes.

/// Append-only bit writer (LSB-first within each byte).
#[derive(Default)]
pub struct BitWriter {
    buf: Vec<u8>,
    acc: u64,
    nbits: u32,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Write the low `n` bits of `v` (n <= 57).
    #[inline]
    pub fn write(&mut self, v: u64, n: u32) {
        debug_assert!(n <= 57);
        debug_assert!(n == 64 || v < (1u64 << n));
        self.acc |= v << self.nbits;
        self.nbits += n;
        while self.nbits >= 8 {
            self.buf.push((self.acc & 0xFF) as u8);
            self.acc >>= 8;
            self.nbits -= 8;
        }
    }

    #[inline]
    pub fn write_bit(&mut self, b: bool) {
        self.write(b as u64, 1);
    }

    /// Unary-coded non-negative integer: n ones then a zero.
    pub fn write_unary(&mut self, n: u64) {
        for _ in 0..n {
            self.write_bit(true);
        }
        self.write_bit(false);
    }

    /// Elias-gamma code for v >= 1.
    pub fn write_gamma(&mut self, v: u64) {
        debug_assert!(v >= 1);
        let nbits = 64 - v.leading_zeros();
        self.write_unary((nbits - 1) as u64);
        if nbits > 1 {
            self.write(v & !(1 << (nbits - 1)), nbits - 1);
        }
    }

    /// Number of bits written so far.
    pub fn bit_len(&self) -> usize {
        self.buf.len() * 8 + self.nbits as usize
    }

    /// Flush to a byte vector (zero-padded to a byte boundary).
    pub fn finish(mut self) -> Vec<u8> {
        if self.nbits > 0 {
            self.buf.push((self.acc & 0xFF) as u8);
        }
        self.buf
    }
}

/// Reader matching [`BitWriter`]'s layout.
pub struct BitReader<'a> {
    buf: &'a [u8],
    pos: usize, // bit position
}

impl<'a> BitReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    /// Read `n` bits (n <= 57); returns None past end-of-stream.
    #[inline]
    pub fn read(&mut self, n: u32) -> Option<u64> {
        if self.pos + n as usize > self.buf.len() * 8 {
            return None;
        }
        let mut v = 0u64;
        let mut got = 0u32;
        while got < n {
            let byte = self.buf[(self.pos + got as usize) / 8];
            let bit_off = ((self.pos + got as usize) % 8) as u32;
            let take = (8 - bit_off).min(n - got);
            let bits = ((byte >> bit_off) as u64) & ((1u64 << take) - 1);
            v |= bits << got;
            got += take;
        }
        self.pos += n as usize;
        Some(v)
    }

    #[inline]
    pub fn read_bit(&mut self) -> Option<bool> {
        self.read(1).map(|b| b != 0)
    }

    pub fn read_unary(&mut self) -> Option<u64> {
        let mut n = 0;
        loop {
            match self.read_bit()? {
                true => n += 1,
                false => return Some(n),
            }
        }
    }

    pub fn read_gamma(&mut self) -> Option<u64> {
        let extra = self.read_unary()? as u32;
        if extra == 0 {
            return Some(1);
        }
        let low = self.read(extra)?;
        Some((1 << extra) | low)
    }

    /// Bits remaining.
    pub fn remaining(&self) -> usize {
        self.buf.len() * 8 - self.pos
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    #[test]
    fn roundtrip_fixed_widths() {
        let mut w = BitWriter::new();
        w.write(0b101, 3);
        w.write(0xDEAD, 16);
        w.write(1, 1);
        w.write(0x1FFFFF, 21);
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read(3), Some(0b101));
        assert_eq!(r.read(16), Some(0xDEAD));
        assert_eq!(r.read(1), Some(1));
        assert_eq!(r.read(21), Some(0x1FFFFF));
    }

    #[test]
    fn roundtrip_random_sequence() {
        let mut rng = Prng::new(99);
        let items: Vec<(u64, u32)> = (0..2000)
            .map(|_| {
                let n = 1 + rng.index(57) as u32;
                let v = rng.next_u64() & ((1u64 << n) - 1).max(1);
                (v.min((1u64 << n) - 1), n)
            })
            .collect();
        let mut w = BitWriter::new();
        for &(v, n) in &items {
            w.write(v, n);
        }
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        for &(v, n) in &items {
            assert_eq!(r.read(n), Some(v));
        }
    }

    #[test]
    fn unary_and_gamma() {
        let mut w = BitWriter::new();
        for i in 0..40u64 {
            w.write_unary(i % 7);
            w.write_gamma(i + 1);
        }
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        for i in 0..40u64 {
            assert_eq!(r.read_unary(), Some(i % 7));
            assert_eq!(r.read_gamma(), Some(i + 1));
        }
    }

    #[test]
    fn read_past_end_is_none() {
        let mut w = BitWriter::new();
        w.write(0b11, 2);
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read(8), Some(0b11)); // padded zeros
        assert_eq!(r.read(1), None);
    }
}
