//! LSB-first bit streams used by the Huffman coder and the Fig.-2 index
//! codec.  Writes accumulate into a u64 register and spill whole bytes;
//! reads refill a u64 accumulator from whole-word loads (byte loads only
//! on the tail), so multi-bit reads — and the Huffman prefix-table fast
//! path via [`BitReader::peek`]/[`BitReader::skip`] — touch memory once
//! per ~7 bytes instead of once per bit.

/// Append-only bit writer (LSB-first within each byte).
#[derive(Default)]
pub struct BitWriter {
    buf: Vec<u8>,
    acc: u64,
    nbits: u32,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Write the low `n` bits of `v` (n <= 57).
    #[inline]
    pub fn write(&mut self, v: u64, n: u32) {
        debug_assert!(n <= 57);
        debug_assert!(n == 64 || v < (1u64 << n));
        self.acc |= v << self.nbits;
        self.nbits += n;
        while self.nbits >= 8 {
            self.buf.push((self.acc & 0xFF) as u8);
            self.acc >>= 8;
            self.nbits -= 8;
        }
    }

    #[inline]
    pub fn write_bit(&mut self, b: bool) {
        self.write(b as u64, 1);
    }

    /// Unary-coded non-negative integer: n ones then a zero.
    pub fn write_unary(&mut self, n: u64) {
        for _ in 0..n {
            self.write_bit(true);
        }
        self.write_bit(false);
    }

    /// Elias-gamma code for v >= 1.
    pub fn write_gamma(&mut self, v: u64) {
        debug_assert!(v >= 1);
        let nbits = 64 - v.leading_zeros();
        self.write_unary((nbits - 1) as u64);
        if nbits > 1 {
            self.write(v & !(1 << (nbits - 1)), nbits - 1);
        }
    }

    /// Number of bits written so far.
    pub fn bit_len(&self) -> usize {
        self.buf.len() * 8 + self.nbits as usize
    }

    /// Flush to a byte vector (zero-padded to a byte boundary).
    pub fn finish(mut self) -> Vec<u8> {
        if self.nbits > 0 {
            self.buf.push((self.acc & 0xFF) as u8);
        }
        self.buf
    }
}

/// Reader matching [`BitWriter`]'s layout.
///
/// Internally the next bits of the stream sit LSB-first in a u64
/// accumulator; [`Self::refill`] tops it up with one `u64::from_le_bytes`
/// load while at least 8 input bytes remain.  All the public reads are
/// served from the accumulator, so the per-bit cost of the old
/// byte-index/bit-offset arithmetic is gone.
pub struct BitReader<'a> {
    buf: &'a [u8],
    /// Next stream bits, LSB-first; bits at and above `acc_bits` are zero.
    acc: u64,
    /// Valid bit count in `acc`.
    acc_bits: u32,
    /// Next byte of `buf` to load into `acc`.
    byte_pos: usize,
}

impl<'a> BitReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self {
            buf,
            acc: 0,
            acc_bits: 0,
            byte_pos: 0,
        }
    }

    /// Top up `acc` to >= 57 valid bits (or until the buffer drains):
    /// whole-word loads while 8 bytes remain, byte loads on the tail.
    #[inline]
    fn refill(&mut self) {
        while self.acc_bits <= 56 {
            if self.byte_pos + 8 <= self.buf.len() {
                let w = u64::from_le_bytes(
                    self.buf[self.byte_pos..self.byte_pos + 8]
                        .try_into()
                        .expect("8-byte window"),
                );
                // only whole bytes are consumed, so `byte_pos` stays exact
                let take_bytes = ((64 - self.acc_bits) / 8) as usize;
                let take_bits = (take_bytes * 8) as u32;
                let w = if take_bits == 64 {
                    w
                } else {
                    w & ((1u64 << take_bits) - 1)
                };
                self.acc |= w << self.acc_bits;
                self.acc_bits += take_bits;
                self.byte_pos += take_bytes;
            } else if self.byte_pos < self.buf.len() {
                self.acc |= (self.buf[self.byte_pos] as u64) << self.acc_bits;
                self.acc_bits += 8;
                self.byte_pos += 1;
            } else {
                break;
            }
        }
    }

    /// Read `n` bits (n <= 57); returns None past end-of-stream (the
    /// reader position is unchanged in that case).
    #[inline]
    pub fn read(&mut self, n: u32) -> Option<u64> {
        debug_assert!(n <= 57);
        if self.acc_bits < n {
            self.refill();
            if self.acc_bits < n {
                return None;
            }
        }
        let v = self.acc & ((1u64 << n) - 1);
        self.acc >>= n;
        self.acc_bits -= n;
        Some(v)
    }

    #[inline]
    pub fn read_bit(&mut self) -> Option<bool> {
        self.read(1).map(|b| b != 0)
    }

    /// Look at the next `n` bits (n <= 57) without consuming them; bits
    /// past the end of the stream read as zero (check [`Self::remaining`]
    /// before consuming).
    #[inline]
    pub fn peek(&mut self, n: u32) -> u64 {
        debug_assert!(n <= 57);
        if self.acc_bits < n {
            self.refill();
        }
        self.acc & ((1u64 << n) - 1)
    }

    /// Consume `n` previously peeked bits; `n` must not exceed
    /// [`Self::remaining`].
    #[inline]
    pub fn skip(&mut self, n: u32) {
        if self.acc_bits < n {
            self.refill();
        }
        debug_assert!(self.acc_bits >= n, "skip past end of stream");
        self.acc >>= n;
        self.acc_bits -= n;
    }

    pub fn read_unary(&mut self) -> Option<u64> {
        let mut n = 0;
        loop {
            match self.read_bit()? {
                true => n += 1,
                false => return Some(n),
            }
        }
    }

    pub fn read_gamma(&mut self) -> Option<u64> {
        let extra = self.read_unary()? as u32;
        if extra == 0 {
            return Some(1);
        }
        let low = self.read(extra)?;
        Some((1 << extra) | low)
    }

    /// Bits remaining.
    pub fn remaining(&self) -> usize {
        (self.buf.len() - self.byte_pos) * 8 + self.acc_bits as usize
    }

    /// Valid bits currently buffered in the accumulator (the batch
    /// Huffman decoder budgets table lookups against this without
    /// touching memory).
    #[inline]
    pub fn buffered(&self) -> u32 {
        self.acc_bits
    }

    /// Top the accumulator up to >= 57 buffered bits (or until the
    /// stream drains) — one amortized refill for a run of
    /// [`Self::peek_buffered`]/[`Self::skip`] calls.
    #[inline]
    pub fn fill(&mut self) {
        self.refill();
    }

    /// The buffered bits, LSB-first, without refilling; bits at and
    /// above [`Self::buffered`] are zero.  Mask to the width you need.
    #[inline]
    pub fn peek_buffered(&self) -> u64 {
        self.acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    #[test]
    fn roundtrip_fixed_widths() {
        let mut w = BitWriter::new();
        w.write(0b101, 3);
        w.write(0xDEAD, 16);
        w.write(1, 1);
        w.write(0x1FFFFF, 21);
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read(3), Some(0b101));
        assert_eq!(r.read(16), Some(0xDEAD));
        assert_eq!(r.read(1), Some(1));
        assert_eq!(r.read(21), Some(0x1FFFFF));
    }

    #[test]
    fn roundtrip_random_sequence() {
        let mut rng = Prng::new(99);
        let items: Vec<(u64, u32)> = (0..2000)
            .map(|_| {
                let n = 1 + rng.index(57) as u32;
                let v = rng.next_u64() & ((1u64 << n) - 1).max(1);
                (v.min((1u64 << n) - 1), n)
            })
            .collect();
        let mut w = BitWriter::new();
        for &(v, n) in &items {
            w.write(v, n);
        }
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        for &(v, n) in &items {
            assert_eq!(r.read(n), Some(v));
        }
    }

    #[test]
    fn unary_and_gamma() {
        let mut w = BitWriter::new();
        for i in 0..40u64 {
            w.write_unary(i % 7);
            w.write_gamma(i + 1);
        }
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        for i in 0..40u64 {
            assert_eq!(r.read_unary(), Some(i % 7));
            assert_eq!(r.read_gamma(), Some(i + 1));
        }
    }

    #[test]
    fn read_past_end_is_none() {
        let mut w = BitWriter::new();
        w.write(0b11, 2);
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read(8), Some(0b11)); // padded zeros
        assert_eq!(r.read(1), None);
    }

    /// Reference reader with the pre-overhaul byte-index arithmetic; the
    /// word-refill reader must agree bit for bit on arbitrary read plans.
    struct NaiveReader<'a> {
        buf: &'a [u8],
        pos: usize,
    }

    impl<'a> NaiveReader<'a> {
        fn read(&mut self, n: u32) -> Option<u64> {
            if self.pos + n as usize > self.buf.len() * 8 {
                return None;
            }
            let mut v = 0u64;
            let mut got = 0u32;
            while got < n {
                let byte = self.buf[(self.pos + got as usize) / 8];
                let bit_off = ((self.pos + got as usize) % 8) as u32;
                let take = (8 - bit_off).min(n - got);
                let bits = ((byte >> bit_off) as u64) & ((1u64 << take) - 1);
                v |= bits << got;
                got += take;
            }
            self.pos += n as usize;
            Some(v)
        }
    }

    #[test]
    fn word_refill_matches_naive_reader() {
        let mut rng = Prng::new(41);
        for case in 0..50 {
            let len = rng.index(64);
            let bytes: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
            let mut fast = BitReader::new(&bytes);
            let mut slow = NaiveReader { buf: &bytes, pos: 0 };
            loop {
                let n = 1 + rng.index(57) as u32;
                let a = fast.read(n);
                let b = slow.read(n);
                assert_eq!(a, b, "case {case}: {n}-bit read diverged");
                if a.is_none() {
                    break;
                }
            }
        }
    }

    #[test]
    fn peek_and_skip_match_read() {
        let mut rng = Prng::new(17);
        let bytes: Vec<u8> = (0..37).map(|_| rng.next_u64() as u8).collect();
        let mut a = BitReader::new(&bytes);
        let mut b = BitReader::new(&bytes);
        loop {
            let n = 1 + rng.index(30) as u32;
            if a.remaining() < n as usize {
                break;
            }
            let peeked = a.peek(n);
            a.skip(n);
            assert_eq!(b.read(n), Some(peeked));
        }
    }

    #[test]
    fn truncated_last_word_tail_is_exact() {
        // streams whose byte length leaves the final refill a partial
        // word (len % 8 != 0) exercise the byte-at-a-time tail of
        // `refill`; every read/peek/remaining near the end must match
        // the naive reader exactly, including reads that straddle the
        // last whole-word boundary
        let mut rng = Prng::new(73);
        for tail in 1..8usize {
            let len = 24 + tail; // 3 whole words + a truncated last word
            let bytes: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
            for first in [1u32, 7, 13, 57] {
                let mut fast = BitReader::new(&bytes);
                let mut slow = NaiveReader { buf: &bytes, pos: 0 };
                // land the reader just before the truncated word, then
                // walk across it bit by bit and in odd widths
                assert_eq!(fast.read(first), slow.read(first));
                loop {
                    assert_eq!(fast.remaining(), bytes.len() * 8 - slow.pos);
                    let n = 1 + (rng.index(12) as u32);
                    let want = slow.read(n);
                    if want.is_some() {
                        // peek must agree with the upcoming read
                        assert_eq!(fast.peek(n), want.unwrap(), "tail {tail} width {n}");
                    }
                    assert_eq!(fast.read(n), want, "tail {tail} width {n}");
                    if want.is_none() {
                        break;
                    }
                }
                // fully drained: trailing peeks zero-pad, reads fail
                assert_eq!(fast.peek(13) & ((1 << fast.remaining()) - 1), fast.peek(13));
                assert_eq!(fast.read(fast.remaining() as u32 + 1), None);
            }
        }
    }

    #[test]
    fn buffered_fill_and_peek_buffered_expose_accumulator() {
        let mut rng = Prng::new(91);
        let bytes: Vec<u8> = (0..21).map(|_| rng.next_u64() as u8).collect();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.buffered(), 0);
        r.fill();
        assert!(r.buffered() >= 57);
        // the buffered view is exactly what peek() serves
        let n = 13;
        assert_eq!(r.peek_buffered() & ((1 << n) - 1), r.peek(n));
        r.skip(n);
        assert_eq!(r.buffered(), 64 - n);
        // drain to the tail: after a fill the accumulator either holds
        // >= 57 bits or the entire rest of the stream
        while r.remaining() > 0 {
            r.fill();
            // after a fill with bits left, the accumulator is non-empty
            assert!(r.buffered() >= 57 || r.buffered() as usize == r.remaining());
            let take = r.buffered().min(9);
            r.skip(take);
        }
        assert_eq!(r.buffered(), 0);
        assert_eq!(r.peek_buffered(), 0);
    }

    #[test]
    fn peek_past_end_zero_pads() {
        let mut w = BitWriter::new();
        w.write(0b1011, 4);
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        // one stored byte = 8 real bits; the peek beyond them is zero
        assert_eq!(r.peek(12), 0b0000_1011);
        assert_eq!(r.remaining(), 8);
        r.skip(8);
        assert_eq!(r.remaining(), 0);
        assert_eq!(r.peek(12), 0);
        assert_eq!(r.read(1), None);
    }
}
