//! Minimal property-based testing driver (the offline image has no proptest).
//!
//! `check` runs a property over `n` random cases; on failure it performs a
//! bounded shrink search (halving numeric parameters via the case's own
//! `shrink` hook) and panics with the smallest failing case found.

use crate::util::Prng;

/// A generated test case: how to build one and how to shrink it.
pub trait Arbitrary: Clone + std::fmt::Debug {
    fn generate(rng: &mut Prng) -> Self;
    /// Candidate smaller versions of `self` (default: none).
    fn shrink(&self) -> Vec<Self> {
        Vec::new()
    }
}

/// Run `prop` over `n` random cases with deterministic seeding.
pub fn check<T: Arbitrary, F: Fn(&T) -> bool>(seed: u64, n: usize, prop: F) {
    let mut rng = Prng::new(seed);
    for i in 0..n {
        let case = T::generate(&mut rng);
        if !prop(&case) {
            let minimal = shrink_loop(case, &prop);
            panic!("property failed (seed {seed}, case {i}): {minimal:#?}");
        }
    }
}

fn shrink_loop<T: Arbitrary, F: Fn(&T) -> bool>(mut failing: T, prop: &F) -> T {
    // bounded: at most 200 shrink steps
    'outer: for _ in 0..200 {
        for cand in failing.shrink() {
            if !prop(&cand) {
                failing = cand;
                continue 'outer;
            }
        }
        break;
    }
    failing
}

/// Helper: random f32 vector with values spanning several magnitudes —
/// matches CFD species data (1e-9 .. 1e-1) better than uniform [0,1).
pub fn cfd_like_vec(rng: &mut Prng, n: usize) -> Vec<f32> {
    let scale = 10f64.powf(rng.uniform(-9.0, -1.0));
    (0..n)
        .map(|_| (rng.normal() * scale) as f32)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Clone, Debug)]
    struct SmallVec(Vec<u32>);

    impl Arbitrary for SmallVec {
        fn generate(rng: &mut Prng) -> Self {
            let n = rng.index(20);
            SmallVec((0..n).map(|_| rng.next_u64() as u32 % 100).collect())
        }
        fn shrink(&self) -> Vec<Self> {
            let mut out = Vec::new();
            if !self.0.is_empty() {
                out.push(SmallVec(self.0[..self.0.len() / 2].to_vec()));
                out.push(SmallVec(self.0[1..].to_vec()));
            }
            out
        }
    }

    #[test]
    fn passing_property_passes() {
        check::<SmallVec, _>(1, 200, |v| v.0.len() < 20);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_shrinks_and_panics() {
        check::<SmallVec, _>(2, 200, |v| v.0.len() < 5);
    }

    #[test]
    fn cfd_like_vec_spans_magnitudes() {
        let mut rng = Prng::new(5);
        let v = cfd_like_vec(&mut rng, 100);
        assert_eq!(v.len(), 100);
        assert!(v.iter().any(|x| *x != 0.0));
    }
}
