//! Deterministic xoshiro256** PRNG (Blackman & Vigna) — the crate's only
//! randomness source, used by the synthetic data generator, the property
//! tests, and the benches.  Reproducible across platforms.

/// xoshiro256** 1.0; seeded via splitmix64 so any u64 seed is well-mixed.
#[derive(Clone, Debug)]
pub struct Prng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Prng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Self {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in [lo, hi) (hi > lo).
    #[inline]
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(hi > lo);
        lo + self.next_u64() % (hi - lo)
    }

    /// Uniform usize in [0, n).
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Fork a decorrelated child stream (for per-thread generators).
    pub fn fork(&mut self, stream: u64) -> Prng {
        Prng::new(self.next_u64() ^ stream.wrapping_mul(0xA24BAED4963EE407))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let (mut a, mut b) = (Prng::new(1), Prng::new(2));
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Prng::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn normal_moments() {
        let mut r = Prng::new(11);
        let n = 50_000;
        let (mut m1, mut m2) = (0.0, 0.0);
        for _ in 0..n {
            let v = r.normal();
            m1 += v;
            m2 += v * v;
        }
        assert!((m1 / n as f64).abs() < 0.02);
        assert!((m2 / n as f64 - 1.0).abs() < 0.03);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Prng::new(3);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
