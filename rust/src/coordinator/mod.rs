//! L3 coordination: the shard-oriented compression engine, batching of
//! blocks toward the AOT executable's fixed batch shapes, a work-stealing
//! parallel-for for CPU-bound stages (per-species guarantee passes, SZ
//! fields), a bounded two-stage pipeline (CPU workers feeding the executor
//! service), and progress counters.

pub mod batcher;
pub mod engine;
pub mod pipeline;
pub mod progress;
pub mod scheduler;

pub use batcher::Batcher;
pub use engine::{RangeDecode, ShardEngine, WorkspaceMeter};
pub use pipeline::Pipeline;
pub use progress::{Progress, StageClock, StageTimes};
pub use scheduler::{par_for, par_map, par_try_for, par_try_map};
