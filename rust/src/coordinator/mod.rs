//! L3 coordination: batching of blocks toward the AOT executable's fixed
//! batch shapes, a work-stealing parallel-for for CPU-bound stages
//! (per-species guarantee passes, SZ fields), a bounded two-stage pipeline
//! (CPU workers feeding the PJRT executor service), and progress counters.

pub mod batcher;
pub mod pipeline;
pub mod progress;
pub mod scheduler;

pub use batcher::Batcher;
pub use pipeline::Pipeline;
pub use progress::Progress;
pub use scheduler::par_for;
