//! Lightweight progress / metrics counters shared across pipeline stages.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use crate::obs::Histogram;

/// Thread-safe counters for one compression/decompression run.
#[derive(Debug)]
pub struct Progress {
    start: Instant,
    pub blocks_encoded: AtomicU64,
    pub blocks_decoded: AtomicU64,
    pub species_guaranteed: AtomicU64,
    pub exec_calls: AtomicU64,
    pub exec_ns: AtomicU64,
    pub cpu_ns: AtomicU64,
}

impl Default for Progress {
    fn default() -> Self {
        Self::new()
    }
}

impl Progress {
    pub fn new() -> Self {
        Self {
            start: Instant::now(),
            blocks_encoded: AtomicU64::new(0),
            blocks_decoded: AtomicU64::new(0),
            species_guaranteed: AtomicU64::new(0),
            exec_calls: AtomicU64::new(0),
            exec_ns: AtomicU64::new(0),
            cpu_ns: AtomicU64::new(0),
        }
    }

    pub fn add(&self, counter: &AtomicU64, v: u64) {
        counter.fetch_add(v, Ordering::Relaxed);
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn summary(&self) -> String {
        format!(
            "elapsed {:.2}s | encoded {} decoded {} blocks | {} exec calls ({:.2}s) | cpu stages {:.2}s | species {} ",
            self.elapsed_s(),
            self.blocks_encoded.load(Ordering::Relaxed),
            self.blocks_decoded.load(Ordering::Relaxed),
            self.exec_calls.load(Ordering::Relaxed),
            self.exec_ns.load(Ordering::Relaxed) as f64 / 1e9,
            self.cpu_ns.load(Ordering::Relaxed) as f64 / 1e9,
            self.species_guaranteed.load(Ordering::Relaxed),
        )
    }
}

/// Per-stage wall-time attribution of a compression run, summed across
/// workers (so a stage can exceed the elapsed wall time on multi-core
/// runs — it is "CPU-seconds spent in the stage").  Each stage is a
/// full [`Histogram`] of per-call nanoseconds (not a single counter),
/// so [`StageTimes`] reports distributions — total, count, p50/p99/max
/// — and perf PRs can see tail behavior, not just sums.
#[derive(Debug, Default)]
pub struct StageClock {
    /// PCA covariance fits + eigendecompositions.
    pub pca_fit: Histogram,
    /// Guarantee projection + greedy coefficient loops.
    pub guarantee: Histogram,
    /// Entropy encoding on the GBATC path (latent plane + coefficients).
    pub entropy: Histogram,
    /// Self-contained stage trials run by the `--codec auto` planner.
    pub planner_trials: Histogram,
}

impl StageClock {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one timed call of a stage (pass a field of `self`).
    pub fn add_ns(&self, stage: &Histogram, ns: u64) {
        stage.record(ns);
    }

    pub fn snapshot(&self) -> StageTimes {
        StageTimes {
            pca_fit: StageDist::of(&self.pca_fit),
            guarantee: StageDist::of(&self.guarantee),
            entropy: StageDist::of(&self.entropy),
            planner_trials: StageDist::of(&self.planner_trials),
        }
    }
}

/// Distribution summary of one stage: total CPU-seconds plus per-call
/// quantiles in milliseconds.
#[derive(Debug, Clone, Copy, Default)]
pub struct StageDist {
    /// Summed stage time in seconds (the historical headline number).
    pub total_s: f64,
    /// Timed calls recorded.
    pub count: u64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub max_ms: f64,
}

impl StageDist {
    fn of(h: &Histogram) -> StageDist {
        let s = h.snapshot();
        StageDist {
            total_s: s.sum as f64 / 1e9,
            count: s.count,
            p50_ms: s.p50() as f64 / 1e6,
            p99_ms: s.p99() as f64 / 1e6,
            max_ms: s.max as f64 / 1e6,
        }
    }
}

/// Snapshot of a [`StageClock`] — carried by `CompressReport` and
/// printed by `gbatc compress` and the perf benches.
#[derive(Debug, Clone, Copy, Default)]
pub struct StageTimes {
    pub pca_fit: StageDist,
    pub guarantee: StageDist,
    pub entropy: StageDist,
    pub planner_trials: StageDist,
}

impl std::fmt::Display for StageTimes {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let stage = |d: &StageDist| {
            if d.count == 0 {
                format!("{:.3}s", d.total_s)
            } else {
                format!(
                    "{:.3}s (n={} p50 {:.2}ms p99 {:.2}ms max {:.2}ms)",
                    d.total_s, d.count, d.p50_ms, d.p99_ms, d.max_ms
                )
            }
        };
        write!(
            f,
            "pca fit {} | guarantee loop {} | entropy encode {} | planner trials {}",
            stage(&self.pca_fit),
            stage(&self.guarantee),
            stage(&self.entropy),
            stage(&self.planner_trials)
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_clock_snapshots_distributions() {
        let c = StageClock::new();
        c.add_ns(&c.pca_fit, 1_500_000_000);
        c.add_ns(&c.pca_fit, 500_000_000);
        c.add_ns(&c.planner_trials, 250_000_000);
        let t = c.snapshot();
        assert!((t.pca_fit.total_s - 2.0).abs() < 1e-9);
        assert_eq!(t.pca_fit.count, 2);
        assert!((t.planner_trials.total_s - 0.25).abs() < 1e-9);
        assert_eq!(t.guarantee.total_s, 0.0);
        assert_eq!(t.guarantee.count, 0);
        // per-call quantiles: p50 of {0.5s, 1.5s} lands near 0.5s, max
        // is exact; bucketed estimates carry ≤1.6% relative error
        assert!((t.pca_fit.p50_ms - 500.0).abs() <= 500.0 * 0.02, "{}", t.pca_fit.p50_ms);
        assert!((t.pca_fit.max_ms - 1500.0).abs() < 1e-6);
        let line = t.to_string();
        assert!(line.contains("pca fit 2.000s"), "{line}");
        assert!(line.contains("planner trials 0.250s"), "{line}");
        assert!(line.contains("n=2"), "{line}");
    }

    #[test]
    fn counters_accumulate() {
        let p = Progress::new();
        p.add(&p.blocks_encoded, 5);
        p.add(&p.blocks_encoded, 3);
        assert_eq!(p.blocks_encoded.load(Ordering::Relaxed), 8);
        assert!(p.summary().contains("encoded 8"));
    }
}
