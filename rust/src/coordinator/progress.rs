//! Lightweight progress / metrics counters shared across pipeline stages.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Thread-safe counters for one compression/decompression run.
#[derive(Debug)]
pub struct Progress {
    start: Instant,
    pub blocks_encoded: AtomicU64,
    pub blocks_decoded: AtomicU64,
    pub species_guaranteed: AtomicU64,
    pub exec_calls: AtomicU64,
    pub exec_ns: AtomicU64,
    pub cpu_ns: AtomicU64,
}

impl Default for Progress {
    fn default() -> Self {
        Self::new()
    }
}

impl Progress {
    pub fn new() -> Self {
        Self {
            start: Instant::now(),
            blocks_encoded: AtomicU64::new(0),
            blocks_decoded: AtomicU64::new(0),
            species_guaranteed: AtomicU64::new(0),
            exec_calls: AtomicU64::new(0),
            exec_ns: AtomicU64::new(0),
            cpu_ns: AtomicU64::new(0),
        }
    }

    pub fn add(&self, counter: &AtomicU64, v: u64) {
        counter.fetch_add(v, Ordering::Relaxed);
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn summary(&self) -> String {
        format!(
            "elapsed {:.2}s | encoded {} decoded {} blocks | {} exec calls ({:.2}s) | cpu stages {:.2}s | species {} ",
            self.elapsed_s(),
            self.blocks_encoded.load(Ordering::Relaxed),
            self.blocks_decoded.load(Ordering::Relaxed),
            self.exec_calls.load(Ordering::Relaxed),
            self.exec_ns.load(Ordering::Relaxed) as f64 / 1e9,
            self.cpu_ns.load(Ordering::Relaxed) as f64 / 1e9,
            self.species_guaranteed.load(Ordering::Relaxed),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let p = Progress::new();
        p.add(&p.blocks_encoded, 5);
        p.add(&p.blocks_encoded, 3);
        assert_eq!(p.blocks_encoded.load(Ordering::Relaxed), 8);
        assert!(p.summary().contains("encoded 8"));
    }
}
