//! Lightweight progress / metrics counters shared across pipeline stages.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Thread-safe counters for one compression/decompression run.
#[derive(Debug)]
pub struct Progress {
    start: Instant,
    pub blocks_encoded: AtomicU64,
    pub blocks_decoded: AtomicU64,
    pub species_guaranteed: AtomicU64,
    pub exec_calls: AtomicU64,
    pub exec_ns: AtomicU64,
    pub cpu_ns: AtomicU64,
}

impl Default for Progress {
    fn default() -> Self {
        Self::new()
    }
}

impl Progress {
    pub fn new() -> Self {
        Self {
            start: Instant::now(),
            blocks_encoded: AtomicU64::new(0),
            blocks_decoded: AtomicU64::new(0),
            species_guaranteed: AtomicU64::new(0),
            exec_calls: AtomicU64::new(0),
            exec_ns: AtomicU64::new(0),
            cpu_ns: AtomicU64::new(0),
        }
    }

    pub fn add(&self, counter: &AtomicU64, v: u64) {
        counter.fetch_add(v, Ordering::Relaxed);
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn summary(&self) -> String {
        format!(
            "elapsed {:.2}s | encoded {} decoded {} blocks | {} exec calls ({:.2}s) | cpu stages {:.2}s | species {} ",
            self.elapsed_s(),
            self.blocks_encoded.load(Ordering::Relaxed),
            self.blocks_decoded.load(Ordering::Relaxed),
            self.exec_calls.load(Ordering::Relaxed),
            self.exec_ns.load(Ordering::Relaxed) as f64 / 1e9,
            self.cpu_ns.load(Ordering::Relaxed) as f64 / 1e9,
            self.species_guaranteed.load(Ordering::Relaxed),
        )
    }
}

/// Per-stage wall-time attribution of a compression run, summed across
/// workers (so a stage can exceed the elapsed wall time on multi-core
/// runs — it is "CPU-seconds spent in the stage").  Snapshotted into
/// [`StageTimes`] on `CompressReport` so perf PRs have in-tree numbers.
#[derive(Debug, Default)]
pub struct StageClock {
    /// PCA covariance fits + eigendecompositions.
    pub pca_fit_ns: AtomicU64,
    /// Guarantee projection + greedy coefficient loops.
    pub guarantee_ns: AtomicU64,
    /// Entropy encoding on the GBATC path (latent plane + coefficients).
    pub entropy_ns: AtomicU64,
    /// Self-contained stage trials run by the `--codec auto` planner.
    pub planner_trials_ns: AtomicU64,
}

impl StageClock {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add_ns(&self, counter: &AtomicU64, ns: u64) {
        counter.fetch_add(ns, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> StageTimes {
        StageTimes {
            pca_fit_s: self.pca_fit_ns.load(Ordering::Relaxed) as f64 / 1e9,
            guarantee_s: self.guarantee_ns.load(Ordering::Relaxed) as f64 / 1e9,
            entropy_s: self.entropy_ns.load(Ordering::Relaxed) as f64 / 1e9,
            planner_trials_s: self.planner_trials_ns.load(Ordering::Relaxed) as f64 / 1e9,
        }
    }
}

/// Snapshot of a [`StageClock`] in seconds — carried by `CompressReport`
/// and printed by `gbatc compress` and the perf benches.
#[derive(Debug, Clone, Copy, Default)]
pub struct StageTimes {
    pub pca_fit_s: f64,
    pub guarantee_s: f64,
    pub entropy_s: f64,
    pub planner_trials_s: f64,
}

impl std::fmt::Display for StageTimes {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "pca fit {:.3}s | guarantee loop {:.3}s | entropy encode {:.3}s | planner trials {:.3}s",
            self.pca_fit_s, self.guarantee_s, self.entropy_s, self.planner_trials_s
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_clock_snapshots_seconds() {
        let c = StageClock::new();
        c.add_ns(&c.pca_fit_ns, 1_500_000_000);
        c.add_ns(&c.pca_fit_ns, 500_000_000);
        c.add_ns(&c.planner_trials_ns, 250_000_000);
        let t = c.snapshot();
        assert!((t.pca_fit_s - 2.0).abs() < 1e-9);
        assert!((t.planner_trials_s - 0.25).abs() < 1e-9);
        assert_eq!(t.guarantee_s, 0.0);
        let line = t.to_string();
        assert!(line.contains("pca fit 2.000s"), "{line}");
        assert!(line.contains("planner trials 0.250s"), "{line}");
    }

    #[test]
    fn counters_accumulate() {
        let p = Progress::new();
        p.add(&p.blocks_encoded, 5);
        p.add(&p.blocks_encoded, 3);
        assert_eq!(p.blocks_encoded.load(Ordering::Relaxed), 8);
        assert!(p.summary().contains("encoded 8"));
    }
}
