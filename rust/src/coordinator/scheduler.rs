//! Work-stealing parallel-for over an index range using `std::thread::scope`
//! (no rayon/crossbeam in the offline image).  Tasks pull indices from a
//! shared atomic counter, so uneven per-item cost (e.g. species with very
//! different coefficient loads) balances automatically.
//!
//! The `par_try_*` variants propagate `Result`s from the request path
//! instead of panicking: the first error wins, remaining items still run
//! (workers only pull cheap indices after an error is latched).

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::error::{Error, Result};

/// Run `f(i)` for every `i in 0..n` on up to `threads` workers.
/// `f` must be `Sync` (called concurrently from many threads).
pub fn par_for<F: Fn(usize) + Sync>(n: usize, threads: usize, f: F) {
    let threads = threads.max(1).min(n.max(1));
    if threads == 1 || n <= 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    });
}

/// Parallel map collecting results in index order.
pub fn par_map<T: Send, F: Fn(usize) -> T + Sync>(n: usize, threads: usize, f: F) -> Vec<T> {
    let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    par_for(n, threads, |i| {
        let v = f(i);
        *slots[i].lock().unwrap() = Some(v);
    });
    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("missing result"))
        .collect()
}

/// Fallible parallel-for: runs `f(i)` for every index, short-circuiting new
/// work once an error is latched; returns the first error observed.
pub fn par_try_for<F: Fn(usize) -> Result<()> + Sync>(
    n: usize,
    threads: usize,
    f: F,
) -> Result<()> {
    let failed = AtomicBool::new(false);
    let err: Mutex<Option<Error>> = Mutex::new(None);
    par_for(n, threads, |i| {
        if failed.load(Ordering::Relaxed) {
            return;
        }
        if let Err(e) = f(i) {
            failed.store(true, Ordering::Relaxed);
            if let Ok(mut slot) = err.lock() {
                slot.get_or_insert(e);
            }
        }
    });
    match err.into_inner() {
        Ok(Some(e)) => Err(e),
        Ok(None) => Ok(()),
        Err(_) => Err(Error::runtime("parallel error slot poisoned")),
    }
}

/// Fallible parallel map collecting results in index order; the first error
/// aborts the map.
pub fn par_try_map<T: Send, F: Fn(usize) -> Result<T> + Sync>(
    n: usize,
    threads: usize,
    f: F,
) -> Result<Vec<T>> {
    let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    par_try_for(n, threads, |i| {
        let v = f(i)?;
        *slots[i]
            .lock()
            .map_err(|_| Error::runtime("parallel result slot poisoned"))? = Some(v);
        Ok(())
    })?;
    slots
        .into_iter()
        .map(|m| {
            m.into_inner()
                .map_err(|_| Error::runtime("parallel result slot poisoned"))?
                .ok_or_else(|| Error::runtime("missing parallel result"))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn visits_every_index_once() {
        let hits: Vec<AtomicU64> = (0..500).map(|_| AtomicU64::new(0)).collect();
        par_for(500, 8, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn single_thread_fallback() {
        let sum = AtomicU64::new(0);
        par_for(10, 1, |i| {
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 45);
    }

    #[test]
    fn par_map_ordered() {
        let v = par_map(100, 6, |i| i * i);
        assert_eq!(v[7], 49);
        assert_eq!(v.len(), 100);
    }

    #[test]
    fn empty_range_ok() {
        par_for(0, 4, |_| panic!("should not run"));
        let v: Vec<usize> = par_map(0, 4, |i| i);
        assert!(v.is_empty());
    }

    #[test]
    fn try_for_propagates_first_error() {
        let r = par_try_for(100, 4, |i| {
            if i == 17 {
                Err(Error::runtime("boom"))
            } else {
                Ok(())
            }
        });
        assert!(r.is_err());
        assert!(par_try_for(50, 4, |_| Ok(())).is_ok());
    }

    #[test]
    fn try_map_ordered_or_error() {
        let v = par_try_map(64, 4, |i| Ok(i * 2)).unwrap();
        assert_eq!(v[31], 62);
        let r: Result<Vec<usize>> =
            par_try_map(64, 4, |i| if i == 5 { Err(Error::runtime("x")) } else { Ok(i) });
        assert!(r.is_err());
    }
}
