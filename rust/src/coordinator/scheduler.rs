//! Work-stealing parallel-for over an index range using scoped threads
//! (no rayon in the offline image).  Tasks pull indices from a shared
//! atomic counter, so uneven per-item cost (e.g. species with very
//! different coefficient loads) balances automatically.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Run `f(i)` for every `i in 0..n` on up to `threads` workers.
/// `f` must be `Sync` (called concurrently from many threads).
pub fn par_for<F: Fn(usize) + Sync>(n: usize, threads: usize, f: F) {
    let threads = threads.max(1).min(n.max(1));
    if threads == 1 || n <= 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    crossbeam_utils::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|_| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    })
    .expect("scoped thread panicked");
}

/// Parallel map collecting results in index order.
pub fn par_map<T: Send, F: Fn(usize) -> T + Sync>(n: usize, threads: usize, f: F) -> Vec<T> {
    let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    par_for(n, threads, |i| {
        let v = f(i);
        *slots[i].lock().unwrap() = Some(v);
    });
    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("missing result"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn visits_every_index_once() {
        let hits: Vec<AtomicU64> = (0..500).map(|_| AtomicU64::new(0)).collect();
        par_for(500, 8, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn single_thread_fallback() {
        let sum = AtomicU64::new(0);
        par_for(10, 1, |i| {
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 45);
    }

    #[test]
    fn par_map_ordered() {
        let v = par_map(100, 6, |i| i * i);
        assert_eq!(v[7], 49);
        assert_eq!(v.len(), 100);
    }

    #[test]
    fn empty_range_ok() {
        par_for(0, 4, |_| panic!("should not run"));
        let v: Vec<usize> = par_map(0, 4, |i| i);
        assert!(v.is_empty());
    }
}
