//! Block batcher: groups block ids into fixed-size batches matching the
//! AOT executable's baked batch dimension, gathering + normalizing on the
//! fly.  The last batch is short; the executor pads it.

use crate::data::blocks::BlockGrid;

/// Iterator over (first_block_id, n_in_batch) pairs.
pub struct Batcher {
    n_blocks: usize,
    batch: usize,
    next: usize,
}

impl Batcher {
    pub fn new(n_blocks: usize, batch: usize) -> Self {
        assert!(batch > 0);
        Self {
            n_blocks,
            batch,
            next: 0,
        }
    }

    pub fn n_batches(&self) -> usize {
        self.n_blocks.div_ceil(self.batch)
    }
}

impl Iterator for Batcher {
    type Item = (usize, usize);
    fn next(&mut self) -> Option<(usize, usize)> {
        if self.next >= self.n_blocks {
            return None;
        }
        let start = self.next;
        let n = self.batch.min(self.n_blocks - start);
        self.next += n;
        Some((start, n))
    }
}

/// Gather blocks `[start, start+n)` from normalized mass data into a
/// contiguous `[n, S, kt, by, bx]` buffer.
pub fn gather_batch(grid: &BlockGrid, norm_mass: &[f32], start: usize, n: usize) -> Vec<f32> {
    let il = grid.instance_len();
    let mut out = vec![0.0f32; n * il];
    for (k, b) in (start..start + n).enumerate() {
        grid.gather(norm_mass, b, &mut out[k * il..(k + 1) * il]);
    }
    out
}

/// Scatter a decoded `[n, S, kt, by, bx]` batch back into normalized mass.
pub fn scatter_batch(
    grid: &BlockGrid,
    norm_mass: &mut [f32],
    start: usize,
    n: usize,
    batch: &[f32],
) {
    let il = grid.instance_len();
    debug_assert_eq!(batch.len(), n * il);
    for (k, b) in (start..start + n).enumerate() {
        grid.scatter(norm_mass, b, &batch[k * il..(k + 1) * il]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::blocks::BlockShape;
    use crate::data::Dataset;
    use crate::util::Prng;

    #[test]
    fn batches_cover_range() {
        let b: Vec<_> = Batcher::new(10, 4).collect();
        assert_eq!(b, vec![(0, 4), (4, 4), (8, 2)]);
        assert_eq!(Batcher::new(10, 4).n_batches(), 3);
        assert_eq!(Batcher::new(8, 4).n_batches(), 2);
        assert_eq!(Batcher::new(0, 4).count(), 0);
    }

    #[test]
    fn gather_scatter_batch_roundtrip() {
        let mut ds = Dataset::new(4, 3, 10, 8);
        let mut rng = Prng::new(9);
        for v in ds.mass.iter_mut() {
            *v = rng.next_f32();
        }
        let grid = BlockGrid::for_dataset(&ds, BlockShape::default()).unwrap();
        let mut out = vec![0.0f32; ds.mass.len()];
        for (start, n) in Batcher::new(grid.n_blocks(), 3) {
            let batch = gather_batch(&grid, &ds.mass, start, n);
            scatter_batch(&grid, &mut out, start, n, &batch);
        }
        assert_eq!(out, ds.mass);
    }
}
