//! The shard-oriented compression engine — owns the executor handle, the
//! codec-stage registry, and the guarantee stage, and drives time-window
//! shards through the encode/decode pipelines.
//!
//! Compression processes `ceil(T / kt_window)` independent shards (see
//! [`crate::data::shards`]), up to `shard_workers` concurrently; every
//! worker funnels accelerator batches into the single [`ExecHandle`]
//! service, which serializes them with queue-depth backpressure.  Peak
//! working memory is bounded by the shard extent (times the worker count)
//! rather than the full field — [`WorkspaceMeter`] accounts for it and the
//! bound is reported in `CompressReport::peak_workspace_bytes`.
//!
//! Per (shard, species) section the engine runs the codec policy in
//! [`CompressOptions::codec`]: classic all-GBATC, one self-contained
//! registry stage (SZ / dense), or the rate–distortion planner
//! ([`crate::compressor::registry::plan_shard`]) that trials the
//! candidate stages and keeps the smallest encoding certifying the
//! per-species NRMSE budget.  The chosen stage is recorded as a codec tag
//! in the `GBA2` TOC; all-GBATC archives keep the version-2 byte layout.
//!
//! Decompression walks the `GBA2` TOC.  [`ShardEngine::decompress_range`]
//! reads and decodes only the shards intersecting the requested time
//! window and, within them, only the requested species' sections,
//! through any [`SectionSource`] (in-memory, file, counting), dispatching
//! each section's decode by its codec tag — the shard's shared AE+TCN
//! reconstruction runs only when a selected section is GBATC.  Its output
//! is bit-identical to the same slice of a full decode: both paths run
//! the exact same per-shard float pipeline.

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::archive::{
    CodecTag, Gba2Archive, Gba2Header, SectionSource, ShardPayload, ShardToc, SliceSource,
};
use crate::codec::LatentCodec;
use crate::compressor::accounting::{model_param_bytes, SizeBreakdown};
use crate::compressor::gba::{
    denormalize_in_place, normalize_window, CompressOptions, CompressReport, SpeciesDisjoint,
};
use crate::compressor::registry::{
    self, plan_archive, CodecChoice, GbatcSectionStats, GbatcShardCodec, SectionCodec,
    SectionEncoding, SectionPlan, SectionSalvage, SectionView, TrialCache, DENSE_STAGE, SZ_STAGE,
};
use crate::coordinator::scheduler::{par_try_for, par_try_map};
use crate::coordinator::{Pipeline, Progress, StageClock};
use crate::data::blocks::{BlockGrid, BlockShape};
use crate::data::shards::ShardPlan;
use crate::data::Dataset;
use crate::error::{Error, Result};
use crate::gae::guarantee::GuaranteeParams;
use crate::runtime::ExecHandle;

/// Worker threads for CPU stages (0 = all cores).
pub(crate) fn effective_threads(threads: usize) -> usize {
    if threads > 0 {
        threads
    } else {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
    }
}

/// Tracks concurrent working-set charges; the high-water mark backs the
/// `peak_workspace_bytes` accounting in `CompressReport`.
#[derive(Debug, Default)]
pub struct WorkspaceMeter {
    current: AtomicUsize,
    peak: AtomicUsize,
}

impl WorkspaceMeter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Charge `bytes` until the returned guard drops.
    pub fn charge(&self, bytes: usize) -> WorkspaceCharge<'_> {
        let now = self.current.fetch_add(bytes, Ordering::Relaxed) + bytes;
        self.peak.fetch_max(now, Ordering::Relaxed);
        WorkspaceCharge { meter: self, bytes }
    }

    pub fn peak_bytes(&self) -> usize {
        self.peak.load(Ordering::Relaxed)
    }
}

pub struct WorkspaceCharge<'a> {
    meter: &'a WorkspaceMeter,
    bytes: usize,
}

impl Drop for WorkspaceCharge<'_> {
    fn drop(&mut self) {
        self.meter.current.fetch_sub(self.bytes, Ordering::Relaxed);
    }
}

/// Working-set bytes one shard's compression pass needs: normalized input
/// + reconstruction (shard-sized), the latent plane twice (raw +
/// dequantized), and the per-species guarantee temporaries of up to
/// `guarantee_threads` concurrent species passes.
pub fn shard_workspace_bytes(
    shard_values: usize,
    n_blocks: usize,
    latent: usize,
    d: usize,
    guarantee_threads: usize,
) -> usize {
    let norm = shard_values * 4;
    let recon = shard_values * 4;
    let latents = 2 * n_blocks * latent * 4;
    // per species: orig + recon gathers, residuals, corrected (4 x nb*d
    // f32) plus PCA covariance/basis (d*d f64 + f32)
    let per_species = 16 * n_blocks * d + 12 * d * d;
    norm + recon + latents + guarantee_threads * per_species
}

/// Bytes the encode/decode pipelines hold in flight for one shard:
/// `queue_depth` queued batches plus a producer- and a consumer-side
/// working batch, capped at two full shard copies.
pub fn pipeline_workspace_bytes(
    queue_depth: usize,
    batch: usize,
    instance_len: usize,
    shard_values: usize,
) -> usize {
    ((queue_depth + 2) * batch * instance_len * 4).min(2 * shard_values * 4)
}

/// Copy one normalized `[Y, X]` row and denormalize it in place with a
/// species' archive range.  This is *the* per-element egress op — both
/// [`ShardEngine::decompress_range`] and the `gbatc::store` cached
/// assembly call it (and it mirrors `denormalize_in_place`), so the
/// bit-identity of cached and uncached reads is structural rather than a
/// convention two copied loops would have to keep.
#[inline]
pub fn denorm_row_into(dst: &mut [f32], src: &[f32], lo: f32, hi: f32) {
    let range = (hi - lo).max(1e-30);
    dst.copy_from_slice(src);
    for v in dst {
        *v = *v * range + lo;
    }
}

/// One selected time window + species subset, decoded.
#[derive(Debug)]
pub struct RangeDecode {
    /// First timestep of the window.
    pub t0: usize,
    /// Timesteps decoded.
    pub nt: usize,
    pub ny: usize,
    pub nx: usize,
    /// Species indices, ascending (row order of `mass`).
    pub species: Vec<usize>,
    /// Row-major `[nt, species.len(), ny, nx]` mass fractions.
    pub mass: Vec<f32>,
    /// High-water mark of the decode working sets (output window + one
    /// shard's buffers at a time — never the full `[T, S, Y, X]` field).
    pub peak_workspace_bytes: usize,
    /// Sections served from best-effort salvage instead of a healthy
    /// decode, as ascending (shard index, species index) pairs.  Empty
    /// for a fully healthy response; only the degraded-mode store path
    /// ever populates it.
    pub degraded: Vec<(usize, usize)>,
    /// Loosened certified NRMSE bound covering the salvaged sections
    /// (`None` when the response is healthy, or when nothing usable
    /// survived and no bound can be stated).
    pub degraded_bound: Option<f64>,
}

/// The shard-oriented engine; borrows an executor-service handle.
pub struct ShardEngine<'a> {
    handle: &'a ExecHandle,
    /// Decoder+TCN parameter counts (CR accounting).
    pub decoder_params: usize,
    pub tcn_params: usize,
}

pub(crate) struct ShardOut {
    pub(crate) payload: ShardPayload,
    pub(crate) max_residual: f64,
    pub(crate) n_coeffs: usize,
    pub(crate) latent_bytes: usize,
    pub(crate) bases_bytes: usize,
    pub(crate) coeff_bytes: usize,
    /// Bytes of sections encoded by self-contained stages (SZ / dense).
    pub(crate) alt_bytes: usize,
}

/// Per-species trial outcome of one shard: every stage's memoized
/// encoding (GBATC always; SZ/dense when the planner runs) plus the
/// guarantee stats for report accounting.
pub(crate) struct SpeciesTrial {
    /// Memoized per-stage encodings; the archive writer drains the
    /// winning stage's bytes from here — nothing is re-encoded.
    trials: TrialCache,
    stats: GbatcSectionStats,
    /// Whether the guarantee loop actually reached τ on this section
    /// (false only on pathological inputs); the planner never selects an
    /// uncertified GBATC candidate.
    gbatc_certified: bool,
}

/// An `--codec auto` shard whose codec choice is deferred to the
/// archive-level planner (the model-parameter charge is global, so
/// per-shard decisions alone cannot be optimal).  Holds only *encoded*
/// candidates — the shard's float working buffers are long gone, which
/// is what keeps a streaming session's peak workspace at one shard even
/// though planning happens at `finish()`.
pub(crate) struct PendingShard {
    pub(crate) t0: usize,
    pub(crate) nt: usize,
    pub(crate) latent_blob: Vec<u8>,
    pub(crate) trials: Vec<SpeciesTrial>,
}

/// One shard's outcome from a compression pass: an already-final payload
/// (single-codec policies), or the candidate encodings the archive-level
/// planner decides between after all shards finish.
pub(crate) enum ShardStage {
    Final(ShardOut),
    Trials(PendingShard),
}

/// Resolve the deferred `--codec auto` shards: run the archive-level
/// rate–distortion planner over the memoized trial costs and assemble
/// each shard's payload from its winning encodings.
pub(crate) fn plan_trials(
    pending: Vec<PendingShard>,
    model_bytes_full: usize,
) -> Result<Vec<ShardOut>> {
    let costs: Vec<(usize, Vec<SectionPlan>)> = pending
        .iter()
        .map(|p| {
            let plans = p
                .trials
                .iter()
                .map(|tr| tr.trials.plan(tr.gbatc_certified))
                .collect();
            (p.latent_blob.len(), plans)
        })
        .collect();
    let choices = plan_archive(&costs, model_bytes_full);
    pending
        .into_iter()
        .zip(choices)
        .map(|(p, (keep, tags))| assemble_shard(p.t0, p.nt, p.latent_blob, p.trials, keep, tags))
        .collect()
}

/// Running totals over finished shards — the accounting both the one-shot
/// [`ShardEngine::compress`] pass and the streaming session accumulate.
#[derive(Default)]
pub(crate) struct ShardTotals {
    pub(crate) max_residual: f64,
    pub(crate) n_coeffs: usize,
    pub(crate) latents: usize,
    pub(crate) bases: usize,
    pub(crate) coeffs: usize,
    pub(crate) alt: usize,
    /// Whether any section decodes through the model (decides whether the
    /// model-parameter bytes are charged).
    pub(crate) any_gbatc: bool,
}

impl ShardTotals {
    pub(crate) fn add(&mut self, o: &ShardOut) {
        self.max_residual = self.max_residual.max(o.max_residual);
        self.n_coeffs += o.n_coeffs;
        self.latents += o.latent_bytes;
        self.bases += o.bases_bytes;
        self.coeffs += o.coeff_bytes;
        self.alt += o.alt_bytes;
        self.any_gbatc |= o.payload.codecs.iter().any(|&c| c == CodecTag::Gbatc);
    }

    pub(crate) fn breakdown(&self, archive_bytes: usize, model_bytes: usize) -> SizeBreakdown {
        SizeBreakdown {
            latents: self.latents,
            bases: self.bases,
            coeffs: self.coeffs,
            alt_sections: self.alt,
            header: archive_bytes
                .saturating_sub(self.latents + self.bases + self.coeffs + self.alt),
            model_params: model_bytes,
        }
    }
}

/// Immutable per-run configuration shared by every shard of one
/// compression pass — one-shot or streaming session.  Resolving it once
/// (per-species guarantee params, conservative budgets, thread split)
/// guarantees both drivers feed [`ShardEngine::shard_stage`] identical
/// numbers, which is what makes streamed archives byte-identical to
/// batch-compressed ones.
pub(crate) struct ShardRunCtx {
    pub(crate) shape: BlockShape,
    pub(crate) spec: crate::runtime::RuntimeSpec,
    pub(crate) ns: usize,
    pub(crate) ny: usize,
    pub(crate) nx: usize,
    /// Per-species normalization ranges, shared (not cloned) by every
    /// shard pass and the header build.
    pub(crate) ranges: std::sync::Arc<[(f32, f32)]>,
    /// Raw per-species NRMSE targets (error messages, header display).
    pub(crate) targets: Vec<f64>,
    /// Per-species guarantee parameters (0.1%-conservative τ, see below).
    pub(crate) params: Vec<GuaranteeParams>,
    /// Per-species budgets for the self-contained stages, equally
    /// conservative.
    pub(crate) budgets: Vec<f64>,
    pub(crate) codec: CodecChoice,
    pub(crate) use_tcn: bool,
    pub(crate) latent_bin: f64,
    pub(crate) queue_depth: usize,
    pub(crate) inner_threads: usize,
    pub(crate) pca_threads: usize,
}

impl ShardRunCtx {
    /// Resolve options + per-species NRMSE targets into the run context.
    /// `targets` must have one positive entry per species — the
    /// `api::ErrorPolicy` resolves to exactly this vector (a uniform
    /// policy repeats one value).
    pub(crate) fn new(
        opts: &CompressOptions,
        targets: &[f64],
        spec: crate::runtime::RuntimeSpec,
        dims: (usize, usize, usize),
        ranges: Vec<(f32, f32)>,
        inner_threads: usize,
    ) -> Result<ShardRunCtx> {
        let (ns, ny, nx) = dims;
        if targets.len() != ns {
            return Err(Error::config(format!(
                "{} NRMSE targets for {ns} species",
                targets.len()
            )));
        }
        for (s, &t) in targets.iter().enumerate() {
            if t.is_nan() || t <= 0.0 {
                return Err(Error::config(format!(
                    "species {s}: NRMSE target {t} must be positive"
                )));
            }
        }
        if ranges.len() != ns {
            return Err(Error::shape(format!(
                "{} normalization ranges for {ns} species",
                ranges.len()
            )));
        }
        let shape = BlockShape {
            kt: spec.block.0,
            by: spec.block.1,
            bx: spec.block.2,
        };
        let d = shape.d();
        // Certify against a 0.1%-conservative tau so that the f32
        // denormalize/renormalize round trip on the decompressor side
        // (worst for species with offset >> range, e.g. N2) cannot push a
        // block past the user's bound.
        let params = targets
            .iter()
            .map(|&t| {
                let tau = t * (d as f64).sqrt();
                let tau_cert = tau * 0.999;
                GuaranteeParams {
                    tau: tau_cert,
                    coeff_bin: tau_cert / (d as f64).sqrt(),
                    store_full_basis: opts.store_full_basis,
                }
            })
            .collect();
        let budgets = targets.iter().map(|&t| t * 0.999).collect();
        // species run concurrently inside a shard; leftover cores go to
        // each species' PCA covariance fit (bit-identical at any count)
        let pca_threads = (inner_threads / ns.min(inner_threads).max(1)).max(1);
        Ok(ShardRunCtx {
            shape,
            spec,
            ns,
            ny,
            nx,
            ranges: ranges.into(),
            targets: targets.to_vec(),
            params,
            budgets,
            codec: opts.codec,
            use_tcn: opts.use_tcn,
            latent_bin: opts.latent_bin,
            queue_depth: opts.queue_depth,
            inner_threads,
            pca_threads,
        })
    }

    /// Loosest per-species target (header display; certification is
    /// per-species and stricter).
    pub(crate) fn max_target(&self) -> f64 {
        self.targets.iter().fold(f64::NEG_INFINITY, |a, &t| a.max(t))
    }

    /// Loosest per-block ℓ2 bound τ = max target · √D (report display).
    pub(crate) fn max_tau(&self) -> f64 {
        self.max_target() * (self.shape.d() as f64).sqrt()
    }
}

/// Assemble one shard's payload from its trials and the planner's
/// `(keep_latent, tags)` choice.
fn assemble_shard(
    t0: usize,
    nt: usize,
    latent_blob: Vec<u8>,
    trials: Vec<SpeciesTrial>,
    keep_latent: bool,
    tags: Vec<CodecTag>,
) -> Result<ShardOut> {
    let mut max_residual = 0.0f64;
    let mut n_coeffs = 0usize;
    let mut bases_bytes = 0usize;
    let mut coeff_bytes = 0usize;
    let mut alt_bytes = 0usize;
    let mut sec_bytes = Vec::with_capacity(trials.len());
    for (mut tr, &tag) in trials.into_iter().zip(&tags) {
        // emit the memoized trial bytes verbatim — the planner's choice
        // never costs a re-encode
        let enc = tr
            .trials
            .take(tag)
            .ok_or_else(|| Error::runtime("planner chose a stage with no memoized trial"))?;
        if tag == CodecTag::Gbatc {
            max_residual = max_residual.max(tr.stats.max_residual);
            n_coeffs += tr.stats.n_coeffs;
            bases_bytes += tr.stats.bases_bytes;
            coeff_bytes += tr.stats.coeff_bytes;
        } else {
            alt_bytes += enc.bytes.len();
        }
        sec_bytes.push(enc.bytes);
    }
    let latent_blob = if keep_latent { latent_blob } else { Vec::new() };
    let latent_bytes = latent_blob.len();
    Ok(ShardOut {
        payload: ShardPayload {
            t0,
            nt,
            latent_blob,
            species: sec_bytes,
            codecs: tags,
        },
        max_residual,
        n_coeffs,
        latent_bytes,
        bases_bytes,
        coeff_bytes,
        alt_bytes,
    })
}

impl<'a> ShardEngine<'a> {
    pub fn new(handle: &'a ExecHandle, decoder_params: usize, tcn_params: usize) -> Self {
        Self {
            handle,
            decoder_params,
            tcn_params,
        }
    }

    /// Compress a dataset shard by shard into an indexed `GBA2` archive
    /// with a uniform per-species NRMSE target (`opts.nrmse_target`).
    pub fn compress(&self, ds: &Dataset, opts: &CompressOptions) -> Result<CompressReport> {
        let targets = vec![opts.nrmse_target; ds.ns];
        self.compress_with_budgets(ds, opts, &targets)
    }

    /// [`Self::compress`] with one NRMSE target per species — the engine
    /// half of the `api::ErrorPolicy` knob.  Each (shard, species) section
    /// is planned and certified against its own budget; the report's
    /// `tau` is the loosest per-block bound (each species' residuals are
    /// additionally within its own, tighter τ).
    pub fn compress_with_budgets(
        &self,
        ds: &Dataset,
        opts: &CompressOptions,
        targets: &[f64],
    ) -> Result<CompressReport> {
        let progress = Progress::new();
        let spec = self.handle.spec();
        if ds.ns != spec.species {
            return Err(Error::shape(format!(
                "dataset has {} species, model expects {}",
                ds.ns, spec.species
            )));
        }
        let shape = BlockShape {
            kt: spec.block.0,
            by: spec.block.1,
            bx: spec.block.2,
        };
        // typed config validation before any work is spent
        opts.validate(shape.kt)?;
        // validate full-field divisibility up front
        BlockGrid::for_dataset(ds, shape)?;
        let threads = effective_threads(opts.threads);
        let plan = ShardPlan::new(ds.nt, shape.kt, opts.kt_window)?;
        let n_shards = plan.len();
        let shard_workers = opts.shard_workers.max(1).min(n_shards);
        let inner_threads = (threads / shard_workers).max(1);
        let ctx = ShardRunCtx::new(
            opts,
            targets,
            spec,
            (ds.ns, ds.ny, ds.nx),
            ds.species_ranges(),
            inner_threads,
        )?;
        let meter = WorkspaceMeter::new();
        let clock = StageClock::new();

        let stages: Vec<ShardStage> = par_try_map(n_shards, shard_workers, |i| {
            let w = plan.window(i);
            let view = ds.shard_view(w)?;
            self.shard_stage(&ctx, view.mass, w.t0, w.nt, &meter, &clock, &progress)
        })?;

        // archive-level rate–distortion choice: per-shard byte minima,
        // refined by the model charge (paid once iff any GBATC survives)
        let model_bytes_full = model_param_bytes(
            self.decoder_params + if opts.use_tcn { self.tcn_params } else { 0 },
            opts.model_bytes_f32,
        );
        let mut outs: Vec<ShardOut> = Vec::with_capacity(stages.len());
        let mut pending: Vec<PendingShard> = Vec::new();
        for stage in stages {
            match stage {
                ShardStage::Final(o) => outs.push(o),
                ShardStage::Trials(p) => pending.push(p),
            }
        }
        if !pending.is_empty() {
            outs.extend(plan_trials(pending, model_bytes_full)?);
            outs.sort_by_key(|o| o.payload.t0);
        }

        // model parameters are charged only when some section actually
        // decodes through the model (all-SZ/dense archives are model-free)
        let mut totals = ShardTotals::default();
        let mut payloads = Vec::with_capacity(outs.len());
        for o in outs {
            totals.add(&o);
            payloads.push(o.payload);
        }
        let model_bytes = if totals.any_gbatc { model_bytes_full } else { 0 };
        let header = Gba2Header {
            tcn_used: opts.use_tcn,
            dims: (ds.nt, ds.ns, ds.ny, ds.nx),
            block: (shape.kt, shape.by, shape.bx),
            latent_dim: spec.latent,
            kt_window: plan.kt_window,
            pressure: ds.pressure,
            nrmse_target: ctx.max_target(),
            model_param_bytes: model_bytes as u64,
            ranges: ctx.ranges.to_vec(),
        };
        let archive = Gba2Archive::build(header, payloads)?;
        let payload = archive.payload_bytes();
        let breakdown = totals.breakdown(payload, model_bytes);
        Ok(CompressReport {
            archive,
            breakdown,
            max_block_residual: totals.max_residual,
            tau: ctx.max_tau(),
            n_coeffs: totals.n_coeffs,
            n_shards,
            peak_workspace_bytes: meter.peak_bytes(),
            stage_times: clock.snapshot(),
            elapsed_s: progress.elapsed_s(),
            progress_summary: progress.summary(),
        })
    }

    /// Compress one raw time window `[nt_w, S, Y, X]` (a contiguous shard
    /// of the field) into its shard stage — the unit of work both the
    /// parallel one-shot pass above and the push-based
    /// `api::CompressSession` drive.  Identical inputs produce identical
    /// bytes regardless of the driver or thread counts (the determinism
    /// contract `tests/integration.rs` asserts).
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn shard_stage(
        &self,
        ctx: &ShardRunCtx,
        mass: &[f32],
        t0: usize,
        nt_w: usize,
        meter: &WorkspaceMeter,
        clock: &StageClock,
        progress: &Progress,
    ) -> Result<ShardStage> {
        let (ns, ny, nx) = (ctx.ns, ctx.ny, ctx.nx);
        let npix = ny * nx;
        let stride = ns * npix;
        if mass.len() != nt_w * stride {
            return Err(Error::shape(format!(
                "shard at t0 {t0}: {} mass values for a [{nt_w}, {ns}, {ny}, {nx}] window",
                mass.len()
            )));
        }
        let spec = ctx.spec;
        let shape = ctx.shape;
        let d = shape.d();
        let inner_threads = ctx.inner_threads;
        let grid = BlockGrid::new((nt_w, ns, ny, nx), shape)?;
        let nb = grid.n_blocks();
        // non-GBATC policies run per-species section trials: one
        // gathered plane plus trial encode/decode buffers per worker
        let trial_extra = if ctx.codec == CodecChoice::Gbatc {
            0
        } else {
            3 * nt_w * npix * 4 * inner_threads.min(ns)
        };
        let _charge = meter.charge(
            shard_workspace_bytes(nt_w * stride, nb, spec.latent, d, inner_threads.min(ns))
                + pipeline_workspace_bytes(
                    ctx.queue_depth,
                    spec.batch,
                    grid.instance_len(),
                    nt_w * stride,
                )
                + trial_extra,
        );
        let pipeline = Pipeline {
            queue_depth: ctx.queue_depth,
        };

        // 1. normalize the shard's contiguous window (global ranges)
        let norm = normalize_window(mass, &ctx.ranges, nt_w, ns, npix, inner_threads);

        // single self-contained stage: no model, no latent plane
        if matches!(ctx.codec, CodecChoice::Sz | CodecChoice::Dense) {
            let stage: &dyn SectionCodec = match ctx.codec {
                CodecChoice::Sz => &SZ_STAGE,
                _ => &DENSE_STAGE,
            };
            let encs = par_try_map(ns, inner_threads, |s| {
                let t = std::time::Instant::now();
                let plane = registry::gather_plane(&norm, nt_w, ns, npix, s);
                let sv = SectionView {
                    species: s,
                    nt: nt_w,
                    ny,
                    nx,
                    norm: &plane,
                };
                let enc = stage.encode(&sv, ctx.budgets[s])?.ok_or_else(|| {
                    Error::guarantee(format!(
                        "{} stage cannot certify NRMSE {:.3e} on shard t0 {t0} species {s}",
                        stage.name(),
                        ctx.targets[s],
                    ))
                })?;
                progress.add(&progress.species_guaranteed, 1);
                progress.add(&progress.cpu_ns, t.elapsed().as_nanos() as u64);
                Ok(enc)
            })?;
            let mut sec_bytes = Vec::with_capacity(ns);
            let mut codecs = Vec::with_capacity(ns);
            let mut alt_bytes = 0usize;
            for e in encs {
                alt_bytes += e.bytes.len();
                codecs.push(e.tag);
                sec_bytes.push(e.bytes);
            }
            return Ok(ShardStage::Final(ShardOut {
                payload: ShardPayload {
                    t0,
                    nt: nt_w,
                    latent_blob: Vec::new(),
                    species: sec_bytes,
                    codecs,
                },
                max_residual: 0.0,
                n_coeffs: 0,
                latent_bytes: 0,
                bases_bytes: 0,
                coeff_bytes: 0,
                alt_bytes,
            }));
        }

        // 2. shared-model trial: AE encode -> latents -> quantize + Huffman
        let latents = pipeline.encode_all(&grid, &norm, self.handle, progress)?;
        let t_ent = std::time::Instant::now();
        let (latent_blob, deq) = LatentCodec::encode(&latents, nb, spec.latent, ctx.latent_bin)?;
        clock.add_ns(&clock.entropy, t_ent.elapsed().as_nanos() as u64);
        drop(latents);

        // 3. decode (+ TCN) from the *dequantized* latents — exactly
        // what the decompressor will see
        let recon = pipeline.decode_all(&grid, &deq, self.handle, ctx.use_tcn, progress)?;
        drop(deq);

        // 4. per-(shard, species) stages: the Algorithm-1 guarantee,
        // plus (planner only) full SZ / dense trials on the section
        let gbatc = GbatcShardCodec {
            grid: &grid,
            norm: &norm,
            recon: &recon,
            params: &ctx.params,
            pca_threads: ctx.pca_threads,
        };
        let auto = ctx.codec == CodecChoice::Auto;
        let trials: Vec<SpeciesTrial> = par_try_map(ns, inner_threads, |s| {
            let t = std::time::Instant::now();
            let (gbatc_bytes, stats) = gbatc.encode_species(s)?;
            let gbatc_certified = stats.max_residual <= ctx.params[s].tau + 1e-12;
            clock.add_ns(&clock.pca_fit, stats.pca_fit_ns);
            clock.add_ns(&clock.guarantee, stats.guarantee_ns);
            clock.add_ns(&clock.entropy, stats.entropy_ns);
            let mut trials = TrialCache::new();
            trials.insert(SectionEncoding {
                tag: CodecTag::Gbatc,
                bytes: gbatc_bytes,
                nrmse: stats.max_residual / (d as f64).sqrt(),
            });
            if auto {
                let t_trial = std::time::Instant::now();
                let plane = registry::gather_plane(&norm, nt_w, ns, npix, s);
                let sv = SectionView {
                    species: s,
                    nt: nt_w,
                    ny,
                    nx,
                    norm: &plane,
                };
                if let Some(enc) = SZ_STAGE.encode(&sv, ctx.budgets[s])? {
                    trials.insert(enc);
                }
                if let Some(enc) = DENSE_STAGE.encode(&sv, ctx.budgets[s])? {
                    trials.insert(enc);
                }
                // only best_alt's winner is ever selectable — free the
                // losing alternative's bytes before the archive-level
                // planning wait
                trials.evict_losing_alt();
                clock.add_ns(&clock.planner_trials, t_trial.elapsed().as_nanos() as u64);
                if !gbatc_certified && trials.best_alt().is_none() {
                    return Err(Error::guarantee(format!(
                        "no stage certifies NRMSE {:.3e} on shard t0 {t0} species {s}",
                        ctx.targets[s],
                    )));
                }
            }
            progress.add(&progress.species_guaranteed, 1);
            progress.add(&progress.cpu_ns, t.elapsed().as_nanos() as u64);
            Ok(SpeciesTrial {
                trials,
                stats,
                gbatc_certified,
            })
        })?;

        // 5. single-codec GBATC finalizes here; the planner defers the
        // choice to the archive-level pass (the model-parameter charge
        // is global, so per-shard decisions alone cannot be optimal)
        if auto {
            Ok(ShardStage::Trials(PendingShard {
                t0,
                nt: nt_w,
                latent_blob,
                trials,
            }))
        } else {
            Ok(ShardStage::Final(assemble_shard(
                t0,
                nt_w,
                latent_blob,
                trials,
                true,
                vec![CodecTag::Gbatc; ns],
            )?))
        }
    }

    pub(crate) fn check_spec(&self, header: &Gba2Header) -> Result<()> {
        let spec = self.handle.spec();
        if header.dims.1 != spec.species
            || header.block != spec.block
            || header.latent_dim != spec.latent
        {
            return Err(Error::shape(format!(
                "archive (S {}, block {:?}, latent {}) does not match runtime \
                 (S {}, block {:?}, latent {})",
                header.dims.1,
                header.block,
                header.latent_dim,
                spec.species,
                spec.block,
                spec.latent
            )));
        }
        Ok(())
    }

    /// Decode one shard to corrected *normalized* mass `[nt_sh, S, Y, X]`,
    /// reading (and decoding) only the species in `sel`, dispatching every
    /// section by its codec tag.  The shared AE+TCN reconstruction runs
    /// only when a selected section is GBATC; otherwise the shard buffer
    /// starts zeroed and self-contained stages overwrite their planes.
    /// `meter` charges the real allocations so callers can bound peak
    /// decode memory.
    ///
    /// Memory note: the `norm` buffer is always full `[nt_sh, S, Y, X]`
    /// width — inherent for GBATC shards (one AE instance couples all
    /// species), and kept for model-free shards too so both callers index
    /// it uniformly; a species-packed layout for the model-free case
    /// would save `(S - |sel|) / S` of one shard buffer at the cost of a
    /// second indexing convention.  (The `SZA1` baseline's
    /// species-granular `decompress_range` override covers the classic
    /// all-SZ workload without this cost.)
    ///
    /// `norm` is a caller-owned arena: multi-shard drivers pass the same
    /// `Vec` every iteration so the shard buffer is allocated once and
    /// reused (`clear` + `resize` keeps the capacity; the model path
    /// replaces the allocation because the pipeline owns its output).
    #[allow(clippy::too_many_arguments)]
    fn decode_shard_norm_into<S: SectionSource + ?Sized>(
        &self,
        header: &Gba2Header,
        entry: &ShardToc,
        src: &S,
        sel: &[usize],
        pipeline: Pipeline,
        threads: usize,
        progress: &Progress,
        meter: &WorkspaceMeter,
        norm: &mut Vec<f32>,
    ) -> Result<()> {
        let (_, ns, ny, nx) = header.dims;
        let npix = ny * nx;
        let shape = BlockShape {
            kt: header.block.0,
            by: header.block.1,
            bx: header.block.2,
        };
        let grid = BlockGrid::new((entry.nt, ns, ny, nx), shape)?;
        let nb = grid.n_blocks();
        if entry.codecs.len() != ns {
            return Err(Error::format(format!(
                "shard at t0 {} has {} codec tags for {ns} species",
                entry.t0,
                entry.codecs.len()
            )));
        }
        let needs_model = sel
            .iter()
            .any(|&s| entry.codecs.get(s).copied() == Some(CodecTag::Gbatc));
        let _shard_charge = meter.charge(entry.nt * ns * npix * 4);

        if needs_model {
            // 1. latent plane (one section read)
            let latent_len = usize::try_from(entry.latent.1)
                .map_err(|_| Error::format("latent section length overflows"))?;
            let latent_bytes = src.read_at(entry.latent.0, latent_len)?;
            let _latent_charge = meter.charge(latent_bytes.len());
            let plane = LatentCodec::decode(&latent_bytes)?;
            if plane.n != nb || plane.dim != header.latent_dim {
                return Err(Error::format(format!(
                    "latent plane {}x{} vs expected {}x{}",
                    plane.n, plane.dim, nb, header.latent_dim
                )));
            }

            // 2. decode + optional TCN
            *norm =
                pipeline.decode_all(&grid, &plane.values, self.handle, header.tcn_used, progress)?;
        } else {
            // arena reuse: re-zero while keeping the capacity
            norm.clear();
            norm.resize(entry.nt * ns * npix, 0.0);
        }

        // 3. per-species sections (parallel; writes are species-disjoint)
        let cell = SpeciesDisjoint::new(norm.as_mut_slice());
        par_try_for(sel.len(), threads, |k| {
            let s = sel[k];
            let range = *entry
                .species
                .get(s)
                .ok_or_else(|| Error::format(format!("no TOC entry for species {s}")))?;
            let sec_len = usize::try_from(range.1)
                .map_err(|_| Error::format("species section length overflows"))?;
            let sec_raw = src.read_at(range.0, sec_len)?;
            // SAFETY: each worker only touches its own species' indices.
            let mass: &mut [f32] = unsafe { cell.slice() };
            let _plane_charge = meter.charge(entry.nt * npix * 4);
            let mut plane;
            match entry.codecs[s] {
                // GBATC refines the shared-model prior, gathered from the
                // shard buffer — the one correction implementation, shared
                // with the registry stage (the gather/scatter round trip
                // is a bit-preserving copy)
                CodecTag::Gbatc => {
                    plane = registry::gather_plane(mass, entry.nt, ns, npix, s);
                    GbatcShardCodec::correct_plane(shape, &sec_raw, entry.nt, ny, nx, &mut plane)
                        .map_err(|e| Error::codec(format!("species {s}: {e}")))?;
                }
                // self-contained stages overwrite the whole plane — no
                // prior to gather
                tag => {
                    plane = vec![0.0f32; entry.nt * npix];
                    let stage = registry::decode_stage(tag)?;
                    stage.decode(&sec_raw, entry.nt, ny, nx, &mut plane)?;
                }
            }
            registry::scatter_plane(mass, &plane, entry.nt, ns, npix, s);
            Ok(())
        })?;
        Ok(())
    }

    /// Decode the selected species of one shard to *normalized*
    /// per-species planes (`[nt_sh, Y, X]` each, returned in `sel`
    /// order), reading only that shard's touched sections.
    ///
    /// This is the fill path of the `gbatc::store` decoded-block cache:
    /// a plane's bits are independent of which *other* species were
    /// selected alongside it (the shared AE+TCN reconstruction covers all
    /// blocks, and each species' correction is self-contained), so planes
    /// are cacheable per (shard, species) and a query assembled from
    /// cached planes is bit-identical to a fresh
    /// [`Self::decompress_range`].
    ///
    /// `sel` must be strictly ascending, deduplicated species indices —
    /// the shape every [`crate::api::SpeciesSel`] resolves to.
    pub fn decode_shard_planes<S: SectionSource + ?Sized>(
        &self,
        header: &Gba2Header,
        entry: &ShardToc,
        src: &S,
        sel: &[usize],
        threads: usize,
    ) -> Result<Vec<Vec<f32>>> {
        let (_, _, ny, nx) = header.dims;
        let npix = ny * nx;
        let mut planes: Vec<Vec<f32>> = sel.iter().map(|_| vec![0.0f32; entry.nt * npix]).collect();
        {
            let mut outs: Vec<&mut [f32]> = planes.iter_mut().map(|p| p.as_mut_slice()).collect();
            let mut scratch = Vec::new();
            self.decode_shard_planes_into(header, entry, src, sel, threads, &mut scratch, &mut outs)?;
        }
        Ok(planes)
    }

    /// [`Self::decode_shard_planes`] into caller-owned buffers — the
    /// zero-copy fill path of the `gbatc::store` cache: the store decodes
    /// straight into freshly allocated `Arc<[f32]>` planes (no
    /// intermediate `Vec` per plane) and reuses `norm_scratch` as the
    /// shard-wide decode arena across shards of one query.
    ///
    /// `outs` must hold one `nt_sh * ny * nx` buffer per selected
    /// species, in `sel` order; bits written are identical to
    /// [`Self::decode_shard_planes`]'s return value.
    pub fn decode_shard_planes_into<S: SectionSource + ?Sized>(
        &self,
        header: &Gba2Header,
        entry: &ShardToc,
        src: &S,
        sel: &[usize],
        threads: usize,
        norm_scratch: &mut Vec<f32>,
        outs: &mut [&mut [f32]],
    ) -> Result<()> {
        self.check_spec(header)?;
        let (_, ns, ny, nx) = header.dims;
        let npix = ny * nx;
        if sel.windows(2).any(|w| w[0] >= w[1]) || sel.iter().any(|&s| s >= ns) {
            return Err(Error::shape(format!(
                "decode_shard_planes selection {sel:?} is not ascending unique indices < {ns}"
            )));
        }
        if outs.len() != sel.len() || outs.iter().any(|o| o.len() != entry.nt * npix) {
            return Err(Error::shape(format!(
                "decode_shard_planes_into: {} output buffers for {} selected species of {} values",
                outs.len(),
                sel.len(),
                entry.nt * npix
            )));
        }
        let progress = Progress::new();
        let meter = WorkspaceMeter::new();
        self.decode_shard_norm_into(
            header,
            entry,
            src,
            sel,
            Pipeline::default(),
            effective_threads(threads),
            &progress,
            &meter,
            norm_scratch,
        )?;
        for (k, &s) in sel.iter().enumerate() {
            registry::gather_plane_into(outs[k], norm_scratch, entry.nt, ns, npix, s);
        }
        Ok(())
    }

    /// Best-effort decode of one species' normalized plane of one shard
    /// for degraded-mode serving: never fails on damaged section *bytes*,
    /// only on I/O errors or shape-level impossibilities.
    ///
    /// * GBATC sections reconstruct from the shared-model prior (latent
    ///   plane + AE/TCN) plus whatever coefficient prefix survives in
    ///   the damaged section — zero surviving coefficients means a
    ///   prior-only plane, and a rotted latent plane leaves a zero
    ///   prior.
    /// * Self-contained sections (SZ / dense) have no prior to fall back
    ///   on: a damaged section yields a zero plane
    ///   (`salvaged_fraction == 0`).
    ///
    /// Returns the plane plus the [`SectionSalvage`] stats that feed the
    /// loosened certified bound of a degraded response.
    pub fn decode_shard_plane_salvage<S: SectionSource + ?Sized>(
        &self,
        header: &Gba2Header,
        entry: &ShardToc,
        src: &S,
        s: usize,
    ) -> Result<(Vec<f32>, SectionSalvage)> {
        self.check_spec(header)?;
        let (_, ns, ny, nx) = header.dims;
        let npix = ny * nx;
        if s >= ns || entry.codecs.len() != ns {
            return Err(Error::shape(format!(
                "salvage decode: species {s} of {ns} ({} codec tags)",
                entry.codecs.len()
            )));
        }
        let range = *entry
            .species
            .get(s)
            .ok_or_else(|| Error::format(format!("no TOC entry for species {s}")))?;
        let sec_len = usize::try_from(range.1)
            .map_err(|_| Error::format("species section length overflows"))?;
        let sec_raw = src.read_at(range.0, sec_len)?;
        match entry.codecs[s] {
            CodecTag::Gbatc => {
                let shape = BlockShape {
                    kt: header.block.0,
                    by: header.block.1,
                    bx: header.block.2,
                };
                let mut plane = self
                    .shard_prior_plane(header, entry, src, s)
                    .unwrap_or_else(|_| vec![0.0f32; entry.nt * npix]);
                let stats = GbatcShardCodec::correct_plane_salvage(
                    shape, &sec_raw, entry.nt, ny, nx, &mut plane,
                );
                Ok((plane, stats))
            }
            tag => {
                let mut plane = vec![0.0f32; entry.nt * npix];
                let decoded = registry::decode_stage(tag)
                    .and_then(|stage| stage.decode(&sec_raw, entry.nt, ny, nx, &mut plane));
                let stats = match decoded {
                    Ok(()) => SectionSalvage {
                        salvaged_fraction: 1.0,
                        max_correction: 0.0,
                    },
                    Err(_) => {
                        // a torn decode may have partially written
                        plane.fill(0.0);
                        SectionSalvage {
                            salvaged_fraction: 0.0,
                            max_correction: 0.0,
                        }
                    }
                };
                Ok((plane, stats))
            }
        }
    }

    /// The shared-model (AE + optional TCN) normalized reconstruction of
    /// one species' plane of one shard — the prior that GBATC
    /// corrections refine, and all a degraded GBATC section has left
    /// when none of its coefficients survive.
    fn shard_prior_plane<S: SectionSource + ?Sized>(
        &self,
        header: &Gba2Header,
        entry: &ShardToc,
        src: &S,
        s: usize,
    ) -> Result<Vec<f32>> {
        let (_, ns, ny, nx) = header.dims;
        let shape = BlockShape {
            kt: header.block.0,
            by: header.block.1,
            bx: header.block.2,
        };
        let grid = BlockGrid::new((entry.nt, ns, ny, nx), shape)?;
        let latent_len = usize::try_from(entry.latent.1)
            .map_err(|_| Error::format("latent section length overflows"))?;
        let latent_bytes = src.read_at(entry.latent.0, latent_len)?;
        let plane = LatentCodec::decode(&latent_bytes)?;
        if plane.n != grid.n_blocks() || plane.dim != header.latent_dim {
            return Err(Error::format(format!(
                "latent plane {}x{} vs expected {}x{}",
                plane.n,
                plane.dim,
                grid.n_blocks(),
                header.latent_dim
            )));
        }
        let progress = Progress::new();
        let norm = Pipeline::default().decode_all(
            &grid,
            &plane.values,
            self.handle,
            header.tcn_used,
            &progress,
        )?;
        Ok(registry::gather_plane(&norm, entry.nt, ns, ny * nx, s))
    }

    /// Decompress a whole archive back to mass fractions `[T, S, Y, X]`.
    pub fn decompress_all(&self, archive: &Gba2Archive, threads: usize) -> Result<Vec<f32>> {
        let progress = Progress::new();
        self.check_spec(&archive.header)?;
        let (nt, ns, ny, nx) = archive.header.dims;
        let npix = ny * nx;
        let stride = ns * npix;
        let threads = effective_threads(threads);
        let pipeline = Pipeline::default();
        let src = SliceSource(&archive.bytes);
        let sel: Vec<usize> = (0..ns).collect();
        let meter = WorkspaceMeter::new();
        let mut out = vec![0.0f32; nt * stride];
        // one shard-wide decode arena reused across shards
        let mut norm = Vec::new();
        for entry in &archive.toc {
            self.decode_shard_norm_into(
                &archive.header,
                entry,
                &src,
                &sel,
                pipeline,
                threads,
                &progress,
                &meter,
                &mut norm,
            )?;
            out[entry.t0 * stride..(entry.t0 + entry.nt) * stride].copy_from_slice(&norm);
        }
        denormalize_in_place(&mut out, &archive.header.ranges, nt, ns, npix, threads);
        Ok(out)
    }

    /// Random-access partial decode: reconstruct timesteps `[t0, t1)` of
    /// the given species (all species if empty), reading only the touched
    /// shards' latent planes and the selected species' sections from `src`.
    ///
    /// The output is bit-identical to the corresponding slice of
    /// [`Self::decompress_all`].
    pub fn decompress_range<S: SectionSource + ?Sized>(
        &self,
        src: &S,
        t0: usize,
        t1: usize,
        species: &[usize],
        threads: usize,
    ) -> Result<RangeDecode> {
        let progress = Progress::new();
        let (header, toc) = Gba2Archive::read_toc(src)?;
        self.check_spec(&header)?;
        let (nt, ns, ny, nx) = header.dims;
        if t0 >= t1 || t1 > nt {
            return Err(Error::shape(format!(
                "time range [{t0}, {t1}) out of bounds for nt {nt}"
            )));
        }
        let sel = crate::compressor::traits::select_species(species, ns)?;
        let nsel = sel.len();
        let npix = ny * nx;
        let threads = effective_threads(threads);
        let pipeline = Pipeline::default();
        // decode memory is bounded by the output window plus one shard's
        // working set at a time — never the full [T, S, Y, X] field; the
        // meter charges the real allocations and tests assert the bound
        let meter = WorkspaceMeter::new();
        let mut out = vec![0.0f32; (t1 - t0) * nsel * npix];
        let _out_charge = meter.charge(out.len() * 4);
        // one shard-wide decode arena reused across the touched shards
        let mut norm = Vec::new();
        for entry in toc.iter().filter(|e| e.t0 < t1 && e.t0 + e.nt > t0) {
            self.decode_shard_norm_into(
                &header, entry, src, &sel, pipeline, threads, &progress, &meter, &mut norm,
            )?;
            let lo_t = t0.max(entry.t0);
            let hi_t = t1.min(entry.t0 + entry.nt);
            for t in lo_t..hi_t {
                for (k, &s) in sel.iter().enumerate() {
                    let (lo, hi) = header.ranges[s];
                    let src_off = ((t - entry.t0) * ns + s) * npix;
                    let dst_off = ((t - t0) * nsel + k) * npix;
                    denorm_row_into(
                        &mut out[dst_off..dst_off + npix],
                        &norm[src_off..src_off + npix],
                        lo,
                        hi,
                    );
                }
            }
        }
        let peak_workspace_bytes = meter.peak_bytes();
        Ok(RangeDecode {
            t0,
            nt: t1 - t0,
            ny,
            nx,
            species: sel,
            mass: out,
            peak_workspace_bytes,
            degraded: Vec::new(),
            degraded_bound: None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workspace_meter_tracks_concurrent_peak() {
        let m = WorkspaceMeter::new();
        {
            let _a = m.charge(100);
            {
                let _b = m.charge(50);
            }
            let _c = m.charge(30);
        }
        assert_eq!(m.peak_bytes(), 150);
        let _d = m.charge(10);
        assert_eq!(m.peak_bytes(), 150);
    }

    #[test]
    fn workspace_estimate_scales_with_shard() {
        let small = shard_workspace_bytes(10_000, 100, 8, 80, 1);
        let big = shard_workspace_bytes(80_000, 800, 8, 80, 1);
        assert!(big > 4 * small, "{big} vs {small}");
    }
}
