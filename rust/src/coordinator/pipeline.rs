//! Batch pipelines over the executor service.
//!
//! Encode: a producer thread gathers + normalizes blocks into batches
//! (CPU) while the main loop keeps the executor busy — a bounded channel
//! provides backpressure.  Decode: batches flow decoder -> point transform
//! (CPU) -> TCN -> scatter, with the CPU transform overlapped against the
//! next decoder execution.
//!
//! Both pipelines are shard-agnostic: the [`crate::coordinator::engine`]
//! drives one pipeline per time-window shard, so buffers here are bounded
//! by the shard extent, not the full field.
//!
//! Error paths drain cleanly: the receiving side owns the channel receiver,
//! so an early `?` drops it, the blocked sender observes the disconnect,
//! and the scope joins without deadlocking.

use std::sync::mpsc::sync_channel;
use std::time::Instant;

use crate::coordinator::batcher::{gather_batch, scatter_batch, Batcher};
use crate::coordinator::progress::Progress;
use crate::data::blocks::BlockGrid;
use crate::error::{Error, Result};
use crate::runtime::ExecHandle;

/// Pipeline configuration.
#[derive(Clone, Copy, Debug)]
pub struct Pipeline {
    /// Batches in flight between producer and executor.
    pub queue_depth: usize,
}

impl Default for Pipeline {
    fn default() -> Self {
        Self { queue_depth: 4 }
    }
}

impl Pipeline {
    /// Encode every block of `norm_mass`; returns latents `[n_blocks * latent]`.
    pub fn encode_all(
        &self,
        grid: &BlockGrid,
        norm_mass: &[f32],
        handle: &ExecHandle,
        progress: &Progress,
    ) -> Result<Vec<f32>> {
        let spec = handle.spec();
        let n_blocks = grid.n_blocks();
        let latent = spec.latent;
        let mut latents = vec![0.0f32; n_blocks * latent];

        let (tx, rx) = sync_channel::<(usize, usize, Vec<f32>)>(self.queue_depth.max(1));
        let latents_ref = &mut latents;
        let result: Result<()> = std::thread::scope(|scope| {
            // producer: gather blocks into batches (CPU)
            scope.spawn(move || {
                for (start, n) in Batcher::new(n_blocks, spec.batch) {
                    let t = Instant::now();
                    let batch = gather_batch(grid, norm_mass, start, n);
                    progress.add(&progress.cpu_ns, t.elapsed().as_nanos() as u64);
                    if tx.send((start, n, batch)).is_err() {
                        break; // consumer bailed
                    }
                }
            });
            // consumer (this thread): execute on the executor service.  The
            // closure owns `rx`, so an early error drops it and unblocks the
            // producer before the scope joins.
            let consume = move || -> Result<()> {
                for (start, n, batch) in rx.iter() {
                    let t = Instant::now();
                    let out = handle.encode(batch, n)?;
                    progress.add(&progress.exec_ns, t.elapsed().as_nanos() as u64);
                    progress.add(&progress.exec_calls, 1);
                    progress.add(&progress.blocks_encoded, n as u64);
                    latents_ref[start * latent..(start + n) * latent].copy_from_slice(&out);
                }
                Ok(())
            };
            consume()
        });
        result?;
        Ok(latents)
    }

    /// Decode all latents back to a normalized mass buffer (scattered), with
    /// optional TCN correction.  Returns the reconstructed normalized mass
    /// for the grid's extent (one shard, or the whole field).
    pub fn decode_all(
        &self,
        grid: &BlockGrid,
        latents: &[f32],
        handle: &ExecHandle,
        apply_tcn: bool,
        progress: &Progress,
    ) -> Result<Vec<f32>> {
        let spec = handle.spec();
        let n_blocks = grid.n_blocks();
        let latent = spec.latent;
        if latents.len() != n_blocks * latent {
            return Err(Error::shape(format!(
                "latent plane has {} values, grid expects {} blocks x {}",
                latents.len(),
                n_blocks,
                latent
            )));
        }
        let il = grid.instance_len();
        let d = grid.shape.d();
        let ns = grid.ns;
        let mut norm_out = vec![0.0f32; grid.nt * ns * grid.ny * grid.nx];

        // stage A (this thread): decoder executions
        // stage B (worker): point transform + TCN + scatter
        let (tx, rx) = sync_channel::<(usize, usize, Vec<f32>)>(self.queue_depth.max(1));
        let norm_ref = &mut norm_out;
        let result: Result<()> = std::thread::scope(|scope| {
            let consumer = scope.spawn(move || -> Result<()> {
                for (start, n, mut batch) in rx.iter() {
                    if apply_tcn {
                        let t = Instant::now();
                        // instances [n, S, D] -> points [n*D, S]
                        let mut pts = vec![0.0f32; n * d * ns];
                        for k in 0..n {
                            grid.to_points(
                                &batch[k * il..(k + 1) * il],
                                &mut pts[k * d * ns..(k + 1) * d * ns],
                            );
                        }
                        progress.add(&progress.cpu_ns, t.elapsed().as_nanos() as u64);
                        // TCN in chunks of spec.points
                        let total = n * d;
                        let mut corrected = vec![0.0f32; total * ns];
                        let mut off = 0;
                        while off < total {
                            let m = spec.points.min(total - off);
                            let te = Instant::now();
                            let out = handle.tcn(pts[off * ns..(off + m) * ns].to_vec(), m)?;
                            progress.add(&progress.exec_ns, te.elapsed().as_nanos() as u64);
                            progress.add(&progress.exec_calls, 1);
                            corrected[off * ns..(off + m) * ns].copy_from_slice(&out);
                            off += m;
                        }
                        let t = Instant::now();
                        for k in 0..n {
                            grid.from_points(
                                &corrected[k * d * ns..(k + 1) * d * ns],
                                &mut batch[k * il..(k + 1) * il],
                            );
                        }
                        progress.add(&progress.cpu_ns, t.elapsed().as_nanos() as u64);
                    }
                    let t = Instant::now();
                    scatter_batch(grid, norm_ref, start, n, &batch);
                    progress.add(&progress.cpu_ns, t.elapsed().as_nanos() as u64);
                    progress.add(&progress.blocks_decoded, n as u64);
                }
                Ok(())
            });

            let produce = || -> Result<()> {
                for (start, n) in Batcher::new(n_blocks, spec.batch) {
                    let t = Instant::now();
                    let out =
                        handle.decode(latents[start * latent..(start + n) * latent].to_vec(), n)?;
                    progress.add(&progress.exec_ns, t.elapsed().as_nanos() as u64);
                    progress.add(&progress.exec_calls, 1);
                    if tx.send((start, n, out)).is_err() {
                        break; // consumer bailed
                    }
                }
                Ok(())
            };
            let produced = produce();
            drop(tx); // let the consumer's rx.iter() terminate
            let consumed = consumer
                .join()
                .map_err(|_| Error::runtime("decode consumer panicked"))?;
            produced.and(consumed)
        });
        result?;
        Ok(norm_out)
    }
}
