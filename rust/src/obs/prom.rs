//! Prometheus text-exposition rendering (version 0.0.4).
//!
//! Dependency-free helpers that turn [`HistSnapshot`]s and counters
//! into the `# HELP` / `# TYPE` / sample-line format `GET /metrics`
//! serves.  Histograms export on a **coarse ladder** — the fine 1920
//! internal buckets would bloat the exposition, so cumulative counts
//! are re-sliced onto power-of-four bounds from ~1 µs to ~17 s (every
//! ladder bound is an internal bucket boundary, so the re-slice is
//! exact).  `le` labels are the bounds in seconds; `_sum` is seconds
//! too, per Prometheus convention for `*_seconds` histograms.

use super::hist::HistSnapshot;

/// Export ladder bounds in nanoseconds: 2^10 .. 2^34 stepping 4×
/// (1.024 µs, 4.096 µs, …, ~17.18 s), all internal bucket boundaries.
pub const LADDER_NS: [u64; 13] = [
    1 << 10,
    1 << 12,
    1 << 14,
    1 << 16,
    1 << 18,
    1 << 20,
    1 << 22,
    1 << 24,
    1 << 26,
    1 << 28,
    1 << 30,
    1 << 32,
    1 << 34,
];

/// Seconds label for an `le` bound (no exponent notation — maximally
/// compatible float text).
fn le_label(ns: u64) -> String {
    let s = format!("{:.9}", ns as f64 / 1e9);
    let trimmed = s.trim_end_matches('0');
    let trimmed = trimmed.strip_suffix('.').unwrap_or(trimmed);
    trimmed.to_string()
}

/// Render one `*_seconds` histogram: cumulative `_bucket` lines over
/// the ladder, then `+Inf`, `_sum`, `_count`.
pub fn render_histogram(out: &mut String, name: &str, help: &str, snap: &HistSnapshot) {
    out.push_str(&format!("# HELP {name} {help}\n"));
    out.push_str(&format!("# TYPE {name} histogram\n"));
    for &bound in LADDER_NS.iter() {
        let cum = snap.cumulative_below(bound);
        out.push_str(&format!(
            "{name}_bucket{{le=\"{}\"}} {cum}\n",
            le_label(bound)
        ));
    }
    out.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {}\n", snap.count));
    out.push_str(&format!("{name}_sum {:.9}\n", snap.sum as f64 / 1e9));
    out.push_str(&format!("{name}_count {}\n", snap.count));
}

/// Render one monotonic counter.
pub fn render_counter(out: &mut String, name: &str, help: &str, value: u64) {
    out.push_str(&format!("# HELP {name} {help}\n"));
    out.push_str(&format!("# TYPE {name} counter\n"));
    out.push_str(&format!("{name} {value}\n"));
}

/// Render one labeled counter family.
pub fn render_counter_family(
    out: &mut String,
    name: &str,
    help: &str,
    label: &str,
    series: &[(&str, u64)],
) {
    out.push_str(&format!("# HELP {name} {help}\n"));
    out.push_str(&format!("# TYPE {name} counter\n"));
    for (val, count) in series {
        out.push_str(&format!("{name}{{{label}=\"{val}\"}} {count}\n"));
    }
}

/// Render one gauge.
pub fn render_gauge(out: &mut String, name: &str, help: &str, value: u64) {
    out.push_str(&format!("# HELP {name} {help}\n"));
    out.push_str(&format!("# TYPE {name} gauge\n"));
    out.push_str(&format!("{name} {value}\n"));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::Histogram;

    #[test]
    fn le_labels_are_plain_decimals() {
        assert_eq!(le_label(1 << 10), "0.000001024");
        assert_eq!(le_label(1 << 30), "1.073741824");
    }

    #[test]
    fn histogram_buckets_are_cumulative_and_capped_by_count() {
        let h = Histogram::new();
        for v in [500u64, 2_000, 2_000_000, 40_000_000_000] {
            h.record(v);
        }
        let mut out = String::new();
        render_histogram(&mut out, "t_seconds", "test", &h.snapshot());
        let counts: Vec<u64> = out
            .lines()
            .filter(|l| l.starts_with("t_seconds_bucket"))
            .map(|l| l.rsplit(' ').next().and_then(|v| v.parse().ok()).unwrap_or(0))
            .collect();
        assert_eq!(counts.len(), LADDER_NS.len() + 1);
        assert!(counts.windows(2).all(|w| w[0] <= w[1]), "not cumulative: {counts:?}");
        assert_eq!(*counts.last().unwrap(), 4, "+Inf must equal count");
        // 40 s sample is past the ladder top: only +Inf holds it
        assert_eq!(counts[LADDER_NS.len() - 1], 3);
        assert!(out.contains("t_seconds_count 4\n"));
    }
}
