//! Per-request trace spans and the bounded slow-query ring.
//!
//! A span is born when a request is parsed (its u64 trace ID is minted
//! from a process-local counter run through a splitmix64 avalanche),
//! rides the request through `serve/conn.rs` → `QueryRouter` →
//! `ArchiveStore` → the engine decode, and accumulates per-phase
//! timings ([`Phase`]) as `(first_start_ns, total_dur_ns)` offsets
//! relative to the span start.  Finished spans become fixed-size
//! [`SpanRecord`]s (no heap fields — the target is a truncated byte
//! prefix) and are pushed into a [`TraceRing`]: a lock-sharded ring
//! buffer that **overwrites oldest** when full and **drops on
//! contention** (`try_lock`), so recording on the reactor thread never
//! blocks and never allocates.  `GET /trace/slow` sorts the ring's
//! contents by total duration and returns the N worst spans.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Request phases, in canonical (monotone) order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// HTTP head framing (the `next_request` call that yielded it).
    Parse = 0,
    /// Bounded job queue wait (reactor offload only).
    QueueWait = 1,
    /// Decoded-plane cache lookups in the store.
    CacheProbe = 2,
    /// Engine decode passes for missing planes.
    Decode = 3,
    /// Best-effort salvage of quarantined sections.
    Salvage = 4,
    /// Response body assembly + meta header.
    Serialize = 5,
    /// Socket write (staging to fully flushed).
    Write = 6,
}

/// Phase count (the fixed width of span phase arrays).
pub const N_PHASES: usize = 7;

impl Phase {
    pub const ALL: [Phase; N_PHASES] = [
        Phase::Parse,
        Phase::QueueWait,
        Phase::CacheProbe,
        Phase::Decode,
        Phase::Salvage,
        Phase::Serialize,
        Phase::Write,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Phase::Parse => "parse",
            Phase::QueueWait => "queue_wait",
            Phase::CacheProbe => "cache_probe",
            Phase::Decode => "decode",
            Phase::Salvage => "salvage",
            Phase::Serialize => "serialize",
            Phase::Write => "write",
        }
    }
}

/// Target (request path) bytes kept per span record.
pub const TARGET_CAP: usize = 48;

/// A finished span — fixed-size, heap-free, `Copy`.
#[derive(Clone, Copy, Debug)]
pub struct SpanRecord {
    pub trace_id: u64,
    /// Span total, parse start → last response byte flushed.
    pub total_ns: u64,
    pub status: u16,
    /// Per-phase `(first_start_ns, total_dur_ns)` offsets from span
    /// start; `(0, 0)` for phases the request never entered.  Durations
    /// accumulate across re-entries (multi-shard queries probe and
    /// decode per shard), starts keep the first entry.
    pub phases: [(u64, u64); N_PHASES],
    target: [u8; TARGET_CAP],
    target_len: u8,
}

impl Default for SpanRecord {
    fn default() -> Self {
        SpanRecord {
            trace_id: 0,
            total_ns: 0,
            status: 0,
            phases: [(0, 0); N_PHASES],
            target: [0; TARGET_CAP],
            target_len: 0,
        }
    }
}

impl SpanRecord {
    /// The recorded request target (truncated to [`TARGET_CAP`] bytes).
    pub fn target(&self) -> &str {
        let len = (self.target_len as usize).min(TARGET_CAP);
        std::str::from_utf8(&self.target[..len]).unwrap_or("")
    }
}

/// An in-flight span.  Plain `Copy` data plus an `Instant` — cheap to
/// move through job queues and connection response slots.
#[derive(Clone, Copy, Debug)]
pub struct SpanBuilder {
    pub trace_id: u64,
    /// Whether the finished record should enter the ring (the 1-in-N
    /// sampling decision, made at mint time).
    pub sampled: bool,
    pub status: u16,
    start: Instant,
    phases: [(u64, u64); N_PHASES],
    target: [u8; TARGET_CAP],
    target_len: u8,
}

impl SpanBuilder {
    /// A span whose clock started at `start` — pass the instant taken
    /// *before* the parse call so the parse phase is inside the span.
    pub fn with_start(trace_id: u64, sampled: bool, start: Instant) -> SpanBuilder {
        SpanBuilder {
            trace_id,
            sampled,
            status: 0,
            start,
            phases: [(u64::MAX, 0); N_PHASES],
            target: [0; TARGET_CAP],
            target_len: 0,
        }
    }

    pub fn new(trace_id: u64, sampled: bool) -> SpanBuilder {
        Self::with_start(trace_id, sampled, Instant::now())
    }

    /// Nanoseconds since span start (the phase-offset clock).
    #[inline]
    pub fn mark(&self) -> u64 {
        self.start.elapsed().as_nanos() as u64
    }

    /// Account `dur_ns` of `phase` starting at offset `start_ns`.
    /// Re-entries accumulate duration and keep the first start.
    #[inline]
    pub fn add_phase(&mut self, phase: Phase, start_ns: u64, dur_ns: u64) {
        let slot = &mut self.phases[phase as usize];
        if slot.0 == u64::MAX {
            slot.0 = start_ns;
        }
        slot.1 += dur_ns;
    }

    /// Time `f` and charge it to `phase`.
    #[inline]
    pub fn time<R>(&mut self, phase: Phase, f: impl FnOnce() -> R) -> R {
        let t0 = self.mark();
        let out = f();
        let t1 = self.mark();
        self.add_phase(phase, t0, t1.saturating_sub(t0));
        out
    }

    /// Record the request target (truncated to [`TARGET_CAP`] bytes on
    /// a UTF-8 boundary).
    pub fn set_target(&mut self, target: &str) {
        let mut end = target.len().min(TARGET_CAP);
        while end > 0 && !target.is_char_boundary(end) {
            end -= 1;
        }
        self.target[..end].copy_from_slice(&target.as_bytes()[..end]);
        self.target_len = end as u8;
    }

    /// Seal the span: total = now, unentered phases normalize to `(0,0)`.
    pub fn finish(mut self) -> SpanRecord {
        for slot in self.phases.iter_mut() {
            if slot.0 == u64::MAX {
                slot.0 = 0;
            }
        }
        SpanRecord {
            trace_id: self.trace_id,
            total_ns: self.mark(),
            status: self.status,
            phases: self.phases,
            target: self.target,
            target_len: self.target_len,
        }
    }
}

/// Trace-ID mint: a relaxed counter avalanched through splitmix64 so
/// IDs are unique per process and well-mixed for ring sharding.
#[derive(Default)]
pub struct TraceIds {
    next: AtomicU64,
}

fn splitmix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

impl TraceIds {
    pub fn new() -> TraceIds {
        TraceIds::default()
    }

    /// Mint the next non-zero trace ID.
    pub fn mint(&self) -> u64 {
        let n = self.next.fetch_add(1, Ordering::Relaxed);
        let id = splitmix64(n);
        if id == 0 {
            1
        } else {
            id
        }
    }
}

/// One lock shard of the ring: a fixed slab overwritten oldest-first.
struct RingShard {
    slots: Vec<SpanRecord>,
    /// Next slot to (over)write.
    next: usize,
    /// Valid records in `slots` (caps at `slots.len()`).
    len: usize,
}

/// Bounded lock-sharded ring of finished spans; see the module docs.
pub struct TraceRing {
    shards: Vec<Mutex<RingShard>>,
    /// Shard count is a power of two; this is `shards.len() - 1`.
    mask: usize,
    recorded: AtomicU64,
    dropped: AtomicU64,
}

impl TraceRing {
    /// A ring holding ~`capacity` spans across `shards` lock shards
    /// (both rounded up to useful minima; shards to a power of two).
    pub fn new(capacity: usize, shards: usize) -> TraceRing {
        let shards = shards.max(1).next_power_of_two();
        let per = capacity.div_ceil(shards).max(1);
        let shards: Vec<Mutex<RingShard>> = (0..shards)
            .map(|_| {
                Mutex::new(RingShard {
                    slots: vec![SpanRecord::default(); per],
                    next: 0,
                    len: 0,
                })
            })
            .collect();
        let mask = shards.len() - 1;
        TraceRing {
            shards,
            mask,
            recorded: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
        }
    }

    /// Record a finished span.  `try_lock` only: a contended shard
    /// drops the span (counted) instead of blocking the caller — the
    /// reactor thread never waits here.
    pub fn push(&self, rec: SpanRecord) {
        let idx = (rec.trace_id as usize) & self.mask;
        let Some(shard) = self.shards.get(idx) else {
            return;
        };
        match shard.try_lock() {
            Ok(mut g) => {
                let cap = g.slots.len();
                let at = g.next;
                if let Some(slot) = g.slots.get_mut(at) {
                    *slot = rec;
                }
                g.next = (at + 1) % cap;
                if g.len < cap {
                    g.len += 1;
                }
                self.recorded.fetch_add(1, Ordering::Relaxed);
            }
            Err(_) => {
                self.dropped.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// The `n` worst (longest) spans currently resident, sorted by
    /// total duration descending.  Egress path — takes the shard locks.
    pub fn slow(&self, n: usize) -> Vec<SpanRecord> {
        let mut out: Vec<SpanRecord> = Vec::new();
        for shard in &self.shards {
            if let Ok(g) = shard.lock() {
                out.extend_from_slice(&g.slots[..g.len.min(g.slots.len())]);
            }
        }
        out.sort_by(|a, b| b.total_ns.cmp(&a.total_ns));
        out.truncate(n);
        out
    }

    /// Spans recorded into the ring so far.
    pub fn recorded(&self) -> u64 {
        self.recorded.load(Ordering::Relaxed)
    }

    /// Spans dropped on shard contention.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ids_are_unique_and_nonzero() {
        let ids = TraceIds::new();
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..1000 {
            let id = ids.mint();
            assert_ne!(id, 0);
            assert!(seen.insert(id), "duplicate trace id");
        }
    }

    #[test]
    fn span_phases_accumulate_and_keep_first_start() {
        let mut sp = SpanBuilder::new(7, true);
        sp.add_phase(Phase::Decode, 100, 40);
        sp.add_phase(Phase::Decode, 500, 60);
        sp.set_target("/query?dataset=hcci");
        sp.status = 200;
        let rec = sp.finish();
        assert_eq!(rec.phases[Phase::Decode as usize], (100, 100));
        assert_eq!(rec.phases[Phase::Salvage as usize], (0, 0));
        assert_eq!(rec.target(), "/query?dataset=hcci");
        assert_eq!(rec.status, 200);
    }

    #[test]
    fn target_truncates_on_char_boundary() {
        let mut sp = SpanBuilder::new(1, true);
        let long = format!("/query?dataset={}é", "x".repeat(TARGET_CAP - 16));
        sp.set_target(&long);
        let rec = sp.finish();
        assert!(rec.target().len() <= TARGET_CAP);
        assert!(rec.target().starts_with("/query?dataset="));
    }

    #[test]
    fn ring_overwrites_oldest_and_ranks_by_duration() {
        let ring = TraceRing::new(4, 1);
        for i in 0..10u64 {
            let mut rec = SpanRecord::default();
            rec.trace_id = i + 1;
            rec.total_ns = (i + 1) * 1000;
            ring.push(rec);
        }
        assert_eq!(ring.recorded(), 10);
        let slow = ring.slow(2);
        assert_eq!(slow.len(), 2);
        // only the 4 newest survive; worst-first ordering
        assert_eq!(slow[0].total_ns, 10_000);
        assert_eq!(slow[1].total_ns, 9_000);
    }

    #[test]
    fn contended_shard_drops_instead_of_blocking() {
        let ring = TraceRing::new(8, 1);
        let g = ring.shards[0].lock();
        ring.push(SpanRecord::default());
        drop(g);
        assert_eq!(ring.dropped(), 1);
        assert_eq!(ring.recorded(), 0);
    }
}
