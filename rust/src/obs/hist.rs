//! Lock-free log-bucketed latency histogram.
//!
//! The record path is integer-only and wait-free in practice: a value
//! lands in a fixed bucket computed from its bit pattern (`leading_zeros`
//! plus a 5-bit mantissa slice), then three relaxed `fetch_add`s and one
//! `fetch_max` update the shared state.  No floats, no locks, no
//! allocation — safe to call from the serve reactor thread.
//!
//! **Bucket scheme** — values below 32 get exact unit-width buckets;
//! above that, each power-of-two octave splits into 32 log-linear
//! sub-buckets (`SUB_BITS = 5`).  A bucket `[lo, hi)` therefore has
//! `hi - lo <= lo / 32`, and quantile estimates return the bucket
//! midpoint, so the relative error of any reported quantile is at most
//! `1/64 ≈ 1.6%` (comfortably inside the ~2% budget) — verified against
//! an exact sorted-sample oracle in `tests/obs.rs`.
//!
//! Quantile estimation, merging, and Prometheus export all run on
//! [`HistSnapshot`]s (plain `Vec<u64>` copies), where floats are fine.

use std::sync::atomic::{AtomicU64, Ordering};

/// Mantissa bits per octave: 32 sub-buckets, ≤1.6% quantile error.
pub const SUB_BITS: usize = 5;
/// Sub-buckets per octave (and the width of the exact linear region).
pub const SUB: usize = 1 << SUB_BITS;
/// Total buckets: the linear region plus 59 sliced octaves (values are
/// `u64`, so octaves 5..=63).
pub const N_BUCKETS: usize = SUB + (64 - SUB_BITS - 1) * SUB;

/// Bucket index of a recorded value — integer ops only.
#[inline]
pub fn bucket_index(v: u64) -> usize {
    if v < SUB as u64 {
        v as usize
    } else {
        let octave = 63 - v.leading_zeros() as usize; // >= SUB_BITS
        let sub = ((v >> (octave - SUB_BITS)) as usize) & (SUB - 1);
        SUB + (octave - SUB_BITS) * SUB + sub
    }
}

/// `[lo, hi)` bounds of bucket `idx` (the top bucket saturates).
pub fn bucket_bounds(idx: usize) -> (u64, u64) {
    if idx < SUB {
        (idx as u64, idx as u64 + 1)
    } else {
        let octave = SUB_BITS + (idx - SUB) / SUB;
        let sub = ((idx - SUB) % SUB) as u64;
        let scale = octave - SUB_BITS;
        let lo = (SUB as u64 + sub) << scale;
        let hi = lo.checked_add(1u64 << scale).unwrap_or(u64::MAX);
        (lo, hi)
    }
}

/// The concurrent histogram; see the module docs.
pub struct Histogram {
    buckets: Box<[AtomicU64]>,
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

impl std::fmt::Debug for Histogram {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Histogram")
            .field("count", &self.count.load(Ordering::Relaxed))
            .field("sum", &self.sum.load(Ordering::Relaxed))
            .field("max", &self.max.load(Ordering::Relaxed))
            .finish()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        let buckets: Vec<AtomicU64> = (0..N_BUCKETS).map(|_| AtomicU64::new(0)).collect();
        Histogram {
            buckets: buckets.into_boxed_slice(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }

    /// Record one value (nanoseconds by convention).  Integer-only,
    /// lock-free, allocation-free — the reactor-thread-safe path.
    #[inline]
    pub fn record(&self, v: u64) {
        let idx = bucket_index(v);
        if let Some(b) = self.buckets.get(idx) {
            b.fetch_add(1, Ordering::Relaxed);
        }
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    /// Samples recorded so far (relaxed read).
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Consistent-enough copy for quantile math and export.  (Counters
    /// are read relaxed; a snapshot taken mid-record can be off by the
    /// in-flight sample, never torn.)
    pub fn snapshot(&self) -> HistSnapshot {
        HistSnapshot {
            buckets: self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
            count: self.count.load(Ordering::Relaxed),
            sum: self.sum.load(Ordering::Relaxed),
            max: self.max.load(Ordering::Relaxed),
        }
    }
}

/// Plain-data copy of a [`Histogram`] — quantiles, merge, export.
#[derive(Clone, Debug)]
pub struct HistSnapshot {
    pub buckets: Vec<u64>,
    pub count: u64,
    pub sum: u64,
    pub max: u64,
}

impl Default for HistSnapshot {
    fn default() -> Self {
        HistSnapshot {
            buckets: vec![0; N_BUCKETS],
            count: 0,
            sum: 0,
            max: 0,
        }
    }
}

impl HistSnapshot {
    /// Fold another snapshot in (replica aggregation for `/metrics`).
    pub fn merge(&mut self, other: &HistSnapshot) {
        if self.buckets.len() < other.buckets.len() {
            self.buckets.resize(other.buckets.len(), 0);
        }
        for (a, &b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.max = self.max.max(other.max);
    }

    /// Estimated value at quantile `q` in `[0, 1]`: the midpoint of the
    /// bucket holding rank `ceil(q·count)`, clamped to the observed
    /// max.  `0` when empty.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut cum = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            cum += c;
            if cum >= rank {
                let (lo, hi) = bucket_bounds(i);
                let mid = lo + (hi - lo) / 2;
                return mid.min(self.max);
            }
        }
        self.max
    }

    pub fn p50(&self) -> u64 {
        self.quantile(0.50)
    }

    pub fn p90(&self) -> u64 {
        self.quantile(0.90)
    }

    pub fn p95(&self) -> u64 {
        self.quantile(0.95)
    }

    pub fn p99(&self) -> u64 {
        self.quantile(0.99)
    }

    /// Mean of the recorded values (`0` when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Samples with value `< bound` — cumulative count for Prometheus
    /// `le` buckets whose bound lands on a bucket boundary.
    pub fn cumulative_below(&self, bound: u64) -> u64 {
        let cut = bucket_index(bound);
        self.buckets.iter().take(cut).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bounds_are_contiguous_and_monotone() {
        let mut prev_hi = 0u64;
        for idx in 0..N_BUCKETS {
            let (lo, hi) = bucket_bounds(idx);
            assert_eq!(lo, prev_hi, "bucket {idx} not contiguous");
            assert!(hi > lo, "bucket {idx} empty range");
            prev_hi = hi;
        }
    }

    #[test]
    fn every_value_lands_in_its_bucket() {
        // xorshift over a wide dynamic range
        let mut x = 0x243f_6a88_85a3_08d3u64;
        for _ in 0..10_000 {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            let v = x >> (x % 60) as u32; // spread across octaves
            let idx = bucket_index(v);
            let (lo, hi) = bucket_bounds(idx);
            assert!(lo <= v && (v < hi || hi == u64::MAX), "v={v} idx={idx} lo={lo} hi={hi}");
        }
    }

    #[test]
    fn record_count_sum_max() {
        let h = Histogram::new();
        for v in [3u64, 5000, 5000, 1_000_000] {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 4);
        assert_eq!(s.sum, 3 + 5000 + 5000 + 1_000_000);
        assert_eq!(s.max, 1_000_000);
        assert_eq!(h.count(), 4);
    }

    #[test]
    fn quantile_error_is_within_two_percent() {
        let h = Histogram::new();
        let mut vals: Vec<u64> = Vec::new();
        let mut x = 0x9e37_79b9_7f4a_7c15u64;
        for _ in 0..5000 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let v = 100 + (x >> 40); // ~[100, 16.8M)
            vals.push(v);
            h.record(v);
        }
        vals.sort_unstable();
        let s = h.snapshot();
        for q in [0.5, 0.9, 0.95, 0.99] {
            let rank = ((q * vals.len() as f64).ceil() as usize).clamp(1, vals.len());
            let exact = vals[rank - 1];
            let est = s.quantile(q);
            let err = (est as f64 - exact as f64).abs();
            assert!(
                err <= (exact as f64) * 0.02 + 2.0,
                "q={q}: est {est} vs exact {exact}"
            );
        }
    }

    #[test]
    fn merge_adds_counts_and_keeps_max() {
        let a = Histogram::new();
        let b = Histogram::new();
        a.record(10);
        a.record(100);
        b.record(1_000_000);
        let mut s = a.snapshot();
        s.merge(&b.snapshot());
        assert_eq!(s.count, 3);
        assert_eq!(s.max, 1_000_000);
        assert_eq!(s.sum, 10 + 100 + 1_000_000);
    }

    #[test]
    fn empty_snapshot_quantiles_are_zero() {
        let s = Histogram::new().snapshot();
        assert_eq!(s.p50(), 0);
        assert_eq!(s.p99(), 0);
        assert_eq!(s.mean(), 0.0);
    }
}
