//! `gbatc::obs` — dependency-free observability primitives.
//!
//! The instrument layer every perf PR is judged by: lock-free
//! log-bucketed latency histograms ([`Histogram`], ≤1.6% quantile
//! error, integer-only record path), per-request trace spans with
//! phase timings ([`SpanBuilder`] → [`SpanRecord`]) feeding a bounded
//! lock-sharded slow-query ring ([`TraceRing`]), and Prometheus text
//! exposition rendering ([`prom`]).
//!
//! ```text
//!   record path (reactor-safe: no floats, no locks, no allocation)
//!     Histogram::record(ns) ── fetch_add ──► atomic fixed buckets
//!     SpanBuilder::add_phase ── plain struct, rides the request
//!     TraceRing::push ── try_lock shard, overwrite oldest, drop on
//!                        contention (counted) — never blocks
//!
//!   egress path (floats fine)
//!     Histogram::snapshot() ─► HistSnapshot: quantile / merge
//!     prom::render_histogram ─► GET /metrics  (cumulative buckets)
//!     TraceRing::slow(n)     ─► GET /trace/slow (N worst spans)
//! ```
//!
//! Consumers: the serve layer (query latency, reactor queue-wait,
//! spans), the store (decode time, cache probe), and the compression
//! side ([`crate::coordinator::StageClock`] records per-stage
//! distributions on the same histogram type).

pub mod hist;
pub mod prom;
pub mod trace;

pub use hist::{bucket_bounds, bucket_index, HistSnapshot, Histogram, N_BUCKETS};
pub use trace::{Phase, SpanBuilder, SpanRecord, TraceIds, TraceRing, N_PHASES, TARGET_CAP};
