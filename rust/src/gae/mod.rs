//! Guaranteed-autoencoder post-processing (paper §II-A/B, Algorithm 1):
//! PCA on per-species residual blocks, per-block coefficient selection
//! until the ℓ2 error bound holds, and the storage-side bookkeeping.

pub mod basis;
pub mod guarantee;

pub use basis::SpeciesBasis;
pub use guarantee::{
    guarantee_species, guarantee_species_timed, GuaranteeParams, GuaranteeResult, GuaranteeTimes,
};
