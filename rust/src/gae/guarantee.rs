//! Algorithm 1 — the error-bound guarantee loop.
//!
//! Per species: PCA on the residual blocks, then per block project the
//! residual, sort coefficients by contribution (c²), and add quantized
//! coefficients greedily until ‖x − x^G‖₂ ≤ τ.  The loop tracks the
//! *actual* corrected residual (including quantization and f32-basis
//! rounding), so the bound it certifies is exactly what the decompressor
//! reproduces.
//!
//! Hot-path layout (the §Perf overhaul): the per-block column-dot
//! projection is a cache-blocked GEMM `C = R·Uᵀ` over all above-τ blocks
//! at once, tiled over blocks and basis columns only — the d-long
//! reduction of every dot stays a single sequential f64 chain, so each
//! coefficient is bit-identical to the scalar projection it replaced,
//! while four column dots run in independent accumulators to hide the
//! add-latency chain.  The greedy loop's apply + re-measure is one fused
//! sweep ([`SpeciesBasis::axpy_col_norm2`]), and the PCA covariance fit
//! parallelizes across upper-triangular stripes
//! ([`crate::linalg::Pca::fit_threads`]) without reordering any sum.

use crate::gae::basis::SpeciesBasis;
use crate::linalg::Pca;
use crate::quant::UniformQuantizer;

/// Parameters of the guarantee pass for one species.
#[derive(Clone, Copy, Debug)]
pub struct GuaranteeParams {
    /// ℓ2 error bound per block vector (normalized units).
    pub tau: f64,
    /// Coefficient quantizer bin; must satisfy bin ≤ 2·tau/√D for the loop
    /// to be able to terminate in the worst case (we enforce it).
    pub coeff_bin: f64,
    /// Store the full D x D basis instead of truncating (ablation).
    pub store_full_basis: bool,
}

impl GuaranteeParams {
    pub fn for_tau(tau: f64, d: usize) -> Self {
        Self {
            tau,
            coeff_bin: tau / (d as f64).sqrt(),
            store_full_basis: false,
        }
    }
}

/// Wall-time attribution of one guarantee pass — the two measured
/// kernels, surfaced through `CompressReport::stage_times`.
#[derive(Clone, Copy, Debug, Default)]
pub struct GuaranteeTimes {
    /// PCA covariance fit + eigendecomposition.
    pub pca_fit_ns: u64,
    /// Projection GEMM + greedy coefficient loop.
    pub loop_ns: u64,
}

/// Output of the guarantee pass for one species.
#[derive(Clone, Debug)]
pub struct GuaranteeResult {
    /// Per block: (basis index, quantized coefficient) ascending by index.
    pub per_block: Vec<Vec<(usize, i64)>>,
    /// Corrected blocks x^G = x^R + U c_q, row-major [n_blocks, d];
    /// `None` when no block needed correction (the reconstruction already
    /// meets τ everywhere — clean shards skip the allocation).
    pub corrected: Option<Vec<f32>>,
    /// Stored basis (truncated to the highest used index unless
    /// `store_full_basis`).
    pub basis: SpeciesBasis,
    /// Total number of stored coefficients.
    pub n_coeffs: usize,
    /// Max ℓ2 residual after correction (should be <= tau).
    pub max_residual: f64,
    /// Blocks that needed correction at all.
    pub n_corrected_blocks: usize,
}

impl GuaranteeResult {
    /// The corrected blocks, falling back to `recon` when nothing was
    /// corrected (so callers never clone a clean shard).
    pub fn corrected_or<'a>(&'a self, recon: &'a [f32]) -> &'a [f32] {
        self.corrected.as_deref().unwrap_or(recon)
    }
}

/// Run Algorithm 1 for one species.
/// `orig`/`recon`: row-major `[n_blocks, d]` normalized block vectors.
pub fn guarantee_species(
    orig: &[f32],
    recon: &[f32],
    n_blocks: usize,
    d: usize,
    params: &GuaranteeParams,
) -> GuaranteeResult {
    guarantee_species_timed(orig, recon, n_blocks, d, params, 1).0
}

/// [`guarantee_species`] with per-stage timing and a PCA thread budget —
/// the engine's entry point.  Results are bit-identical for any
/// `pca_threads` (see [`Pca::fit_threads`]).
pub fn guarantee_species_timed(
    orig: &[f32],
    recon: &[f32],
    n_blocks: usize,
    d: usize,
    params: &GuaranteeParams,
    pca_threads: usize,
) -> (GuaranteeResult, GuaranteeTimes) {
    assert_eq!(orig.len(), n_blocks * d);
    assert_eq!(recon.len(), n_blocks * d);
    let tau = params.tau;
    // termination safety: with all D coefficients stored, the remaining
    // residual is bounded by √D · bin/2 (+ f32 rounding); keep it < tau.
    let bin = params.coeff_bin.min(1.9 * tau / (d as f64).sqrt());
    let quant = UniformQuantizer::new(bin);

    // 1. residuals + PCA
    let mut residuals = vec![0.0f32; n_blocks * d];
    for i in 0..n_blocks * d {
        residuals[i] = orig[i] - recon[i];
    }
    let t_pca = std::time::Instant::now();
    let pca = Pca::fit_threads(&residuals, n_blocks, d, false, pca_threads);
    let pca_fit_ns = t_pca.elapsed().as_nanos() as u64;
    // f32 basis — identical to what the decompressor will use
    let full_basis = SpeciesBasis::from_mat(&pca.basis, d);

    let t_loop = std::time::Instant::now();
    // 2. initial per-block ℓ2²; only blocks above τ enter the guarantee
    // loop (and need a coefficient projection)
    let mut norms2 = vec![0.0f64; n_blocks];
    let mut above: Vec<usize> = Vec::new();
    for (b, r0) in residuals.chunks_exact(d).enumerate() {
        let delta2: f64 = r0.iter().map(|&v| (v as f64) * (v as f64)).sum();
        norms2[b] = delta2;
        if delta2.sqrt() > tau {
            above.push(b);
        }
    }

    // 3. project every above-τ residual at once: C = R·Uᵀ, cache-blocked
    let coeffs_all = project_blocks(&residuals, &above, &full_basis, d);

    let mut per_block: Vec<Vec<(usize, i64)>> = Vec::with_capacity(n_blocks);
    let mut corrected: Option<Vec<f32>> = None;
    let mut n_coeffs = 0usize;
    let mut max_residual = 0.0f64;
    let mut max_index_used = 0usize;

    let mut resid = vec![0.0f32; d];
    let mut coeffs: Vec<(usize, f64)> = Vec::with_capacity(d);
    let mut next_above = 0usize; // cursor into `above` / `coeffs_all`

    for b in 0..n_blocks {
        let mut delta2 = norms2[b];
        let mut selected: Vec<(usize, i64)> = Vec::new();

        if next_above < above.len() && above[next_above] == b {
            let crow = &coeffs_all[next_above * d..(next_above + 1) * d];
            next_above += 1;
            resid.copy_from_slice(&residuals[b * d..(b + 1) * d]);
            coeffs.clear();
            for (j, &c) in crow.iter().enumerate() {
                coeffs.push((j, c));
            }
            // sort by squared contribution, descending (total_cmp: NaN-safe
            // on the request path)
            coeffs.sort_by(|a, b| (b.1 * b.1).total_cmp(&(a.1 * a.1)));

            for &(j, c) in coeffs.iter() {
                let q = quant.quantize(c);
                if q == 0 {
                    // zero quantized coefficient can't reduce the residual
                    continue;
                }
                let cq = quant.dequantize(q) as f32;
                // apply and re-measure exactly — one fused sweep
                delta2 = full_basis.axpy_col_norm2(j, -cq, &mut resid);
                selected.push((j, q));
                if delta2.sqrt() <= tau {
                    break;
                }
            }
            selected.sort_unstable_by_key(|&(j, _)| j);
            // corrected block = recon + U c_q == orig - resid; the buffer
            // materializes lazily on the first corrected block
            let all = corrected.get_or_insert_with(|| recon.to_vec());
            let cb = &mut all[b * d..(b + 1) * d];
            for i in 0..d {
                cb[i] = orig[b * d + i] - resid[i];
            }
            if let Some(&(j, _)) = selected.last() {
                max_index_used = max_index_used.max(j + 1);
            }
        }

        n_coeffs += selected.len();
        max_residual = max_residual.max(delta2.sqrt());
        per_block.push(selected);
    }
    let loop_ns = t_loop.elapsed().as_nanos() as u64;

    let rank = if params.store_full_basis {
        d
    } else {
        max_index_used
    };
    // truncate the already-converted basis by slicing its column-major
    // prefix — no second Mat conversion
    let basis = full_basis.truncated(rank);
    let n_corrected_blocks = above.len();

    (
        GuaranteeResult {
            per_block,
            corrected,
            basis,
            n_coeffs,
            max_residual,
            n_corrected_blocks,
        },
        GuaranteeTimes {
            pca_fit_ns,
            loop_ns,
        },
    )
}

/// Cache-blocked projection `C[k][j] = Σ_i U[i,j] · r_k[i]` for the listed
/// blocks.  Tiles iterate blocks × basis columns; the reduction over `i`
/// is one sequential f64 chain per (k, j) — never split or re-associated —
/// so every coefficient is bit-identical to the scalar `col · r` dot it
/// replaces.  Within a tile, [`crate::simd::dot4_cols`] advances four
/// column dots in lockstep (one column per SIMD lane on AVX2, four
/// independent registers on the scalar path), which pipelines the
/// multiply-add latency without touching any per-dot order of operations.
fn project_blocks(
    residuals: &[f32],
    above: &[usize],
    basis: &SpeciesBasis,
    d: usize,
) -> Vec<f64> {
    const MB: usize = 32; // blocks per tile: keeps the residual rows in L1
    const NB: usize = 16; // basis columns per tile
    let mut out = vec![0.0f64; above.len() * d];
    for kb in (0..above.len()).step_by(MB) {
        let kend = (kb + MB).min(above.len());
        for jb in (0..d).step_by(NB) {
            let jend = (jb + NB).min(d);
            for k in kb..kend {
                let r0 = &residuals[above[k] * d..above[k] * d + d];
                let crow = &mut out[k * d..(k + 1) * d];
                let mut j = jb;
                while j + 4 <= jend {
                    let c0 = basis.col(j);
                    let c1 = basis.col(j + 1);
                    let c2 = basis.col(j + 2);
                    let c3 = basis.col(j + 3);
                    let [a0, a1, a2, a3] = crate::simd::dot4_cols(c0, c1, c2, c3, r0);
                    crow[j] = a0;
                    crow[j + 1] = a1;
                    crow[j + 2] = a2;
                    crow[j + 3] = a3;
                    j += 4;
                }
                while j < jend {
                    // single dot: sequential by the determinism invariant
                    crow[j] = crate::simd::dot_col(basis.col(j), r0);
                    j += 1;
                }
            }
        }
    }
    out
}

/// Decompressor side: apply stored coefficients to reconstructed blocks.
pub fn apply_correction(
    recon: &mut [f32],
    n_blocks: usize,
    d: usize,
    basis: &SpeciesBasis,
    per_block: &[Vec<(usize, f64)>],
) {
    debug_assert_eq!(recon.len(), n_blocks * d);
    debug_assert_eq!(per_block.len(), n_blocks);
    for (b, coeffs) in per_block.iter().enumerate() {
        let out = &mut recon[b * d..(b + 1) * d];
        for &(j, c) in coeffs {
            basis.axpy_col(j, c as f32, out);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    /// Synthetic recon = orig + structured noise.
    fn make_case(n: usize, d: usize, noise: f32, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Prng::new(seed);
        // low-dim structure in the residual (PCA-friendly, like AE errors)
        let dirs: Vec<Vec<f32>> = (0..3)
            .map(|_| (0..d).map(|_| rng.normal() as f32).collect())
            .collect();
        let orig: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let mut recon = orig.clone();
        for b in 0..n {
            for dir in &dirs {
                let c = rng.normal() as f32 * noise;
                for i in 0..d {
                    recon[b * d + i] += c * dir[i];
                }
            }
            for i in 0..d {
                recon[b * d + i] += rng.normal() as f32 * noise * 0.05;
            }
        }
        (orig, recon)
    }

    #[test]
    fn bound_satisfied_for_every_block() {
        let (n, d) = (64, 80);
        let (orig, recon) = make_case(n, d, 0.3, 1);
        let tau = 0.05;
        let res = guarantee_species(&orig, &recon, n, d, &GuaranteeParams::for_tau(tau, d));
        assert!(
            res.max_residual <= tau + 1e-9,
            "max residual {} > tau {tau}",
            res.max_residual
        );
        // verify block by block against the corrected output
        let corrected = res.corrected_or(&recon);
        for b in 0..n {
            let e2: f64 = (0..d)
                .map(|i| {
                    let diff = (orig[b * d + i] - corrected[b * d + i]) as f64;
                    diff * diff
                })
                .sum();
            assert!(e2.sqrt() <= tau + 1e-9, "block {b}: {}", e2.sqrt());
        }
    }

    #[test]
    fn decompressor_reproduces_corrected_blocks() {
        let (n, d) = (32, 40);
        let (orig, recon) = make_case(n, d, 0.2, 2);
        let tau = 0.08;
        let params = GuaranteeParams::for_tau(tau, d);
        let res = guarantee_species(&orig, &recon, n, d, &params);

        // simulate decode: dequantize stored ints with the same bin
        let bin = params.coeff_bin.min(1.9 * tau / (d as f64).sqrt());
        let q = UniformQuantizer::new(bin);
        let per_block_f: Vec<Vec<(usize, f64)>> = res
            .per_block
            .iter()
            .map(|blk| blk.iter().map(|&(j, qq)| (j, q.dequantize(qq))).collect())
            .collect();
        let mut recon2 = recon.clone();
        apply_correction(&mut recon2, n, d, &res.basis, &per_block_f);
        for (a, b) in recon2.iter().zip(res.corrected_or(&recon)) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn tighter_tau_needs_more_coeffs() {
        let (n, d) = (48, 60);
        let (orig, recon) = make_case(n, d, 0.25, 3);
        let loose = guarantee_species(&orig, &recon, n, d, &GuaranteeParams::for_tau(0.2, d));
        let tight = guarantee_species(&orig, &recon, n, d, &GuaranteeParams::for_tau(0.02, d));
        assert!(tight.n_coeffs > loose.n_coeffs);
        assert!(tight.max_residual <= 0.02 + 1e-9);
    }

    #[test]
    fn already_good_blocks_store_nothing() {
        let (n, d) = (16, 20);
        let orig: Vec<f32> = vec![0.5; n * d];
        let recon = orig.clone();
        let res = guarantee_species(&orig, &recon, n, d, &GuaranteeParams::for_tau(0.01, d));
        assert_eq!(res.n_coeffs, 0);
        assert_eq!(res.n_corrected_blocks, 0);
        assert_eq!(res.basis.rank, 0);
        // satellite fix: a clean shard allocates no corrected copy
        assert!(res.corrected.is_none());
        assert_eq!(res.corrected_or(&recon), &recon[..]);
    }

    #[test]
    fn pca_beats_identity_coding_on_structured_residuals() {
        // with residuals concentrated on 3 directions, the number of
        // stored coefficients should be far below n * d
        let (n, d) = (64, 50);
        let (orig, recon) = make_case(n, d, 0.5, 4);
        // tau above the small unstructured-noise floor: the 3 structured
        // directions dominate, so a handful of coefficients per block wins
        let res = guarantee_species(&orig, &recon, n, d, &GuaranteeParams::for_tau(0.3, d));
        assert!(res.max_residual <= 0.3 + 1e-9);
        assert!(res.n_coeffs < n * 10, "stored {} coeffs", res.n_coeffs);
        assert!(res.basis.rank <= d);
    }

    /// The blocked-GEMM projection must reproduce the scalar per-column
    /// dot exactly: same reduction order, same bits.
    #[test]
    fn projection_gemm_matches_scalar_dots_exactly() {
        let mut rng = Prng::new(9);
        for &(n, d) in &[(5usize, 7usize), (40, 33), (70, 80), (3, 4)] {
            let residuals: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
            let m = {
                let mut m = crate::linalg::Mat::zeros(d, d);
                for i in 0..d {
                    for j in 0..d {
                        m[(i, j)] = rng.normal();
                    }
                }
                m
            };
            let basis = SpeciesBasis::from_mat(&m, d);
            let above: Vec<usize> = (0..n).filter(|b| b % 2 == 0).collect();
            let gemm = project_blocks(&residuals, &above, &basis, d);
            for (k, &b) in above.iter().enumerate() {
                let r0 = &residuals[b * d..(b + 1) * d];
                for j in 0..d {
                    let scalar: f64 = basis
                        .col(j)
                        .iter()
                        .zip(r0)
                        .map(|(&u, &r)| u as f64 * r as f64)
                        .sum();
                    assert_eq!(
                        gemm[k * d + j],
                        scalar,
                        "n {n} d {d} block {b} col {j}: GEMM diverged from scalar dot"
                    );
                }
            }
        }
    }

    /// The timed/threaded entry point must match the plain one bit for
    /// bit — same coefficients, same corrected blocks, same basis.
    #[test]
    fn timed_parallel_variant_is_bit_identical() {
        let (n, d) = (48, 36);
        let (orig, recon) = make_case(n, d, 0.3, 8);
        let params = GuaranteeParams::for_tau(0.05, d);
        let a = guarantee_species(&orig, &recon, n, d, &params);
        let (b, times) = guarantee_species_timed(&orig, &recon, n, d, &params, 4);
        assert_eq!(a.per_block, b.per_block);
        assert_eq!(a.corrected, b.corrected);
        assert_eq!(a.basis.data, b.basis.data);
        assert_eq!(a.basis.rank, b.basis.rank);
        assert_eq!(a.n_coeffs, b.n_coeffs);
        assert_eq!(a.max_residual.to_bits(), b.max_residual.to_bits());
        // the clocks ran
        assert!(times.pca_fit_ns > 0 || times.loop_ns > 0);
    }
}
