//! Algorithm 1 — the error-bound guarantee loop.
//!
//! Per species: PCA on the residual blocks, then per block project the
//! residual, sort coefficients by contribution (c²), and add quantized
//! coefficients greedily until ‖x − x^G‖₂ ≤ τ.  The loop tracks the
//! *actual* corrected residual (including quantization and f32-basis
//! rounding), so the bound it certifies is exactly what the decompressor
//! reproduces.

use crate::gae::basis::SpeciesBasis;
use crate::linalg::Pca;
use crate::quant::UniformQuantizer;

/// Parameters of the guarantee pass for one species.
#[derive(Clone, Copy, Debug)]
pub struct GuaranteeParams {
    /// ℓ2 error bound per block vector (normalized units).
    pub tau: f64,
    /// Coefficient quantizer bin; must satisfy bin ≤ 2·tau/√D for the loop
    /// to be able to terminate in the worst case (we enforce it).
    pub coeff_bin: f64,
    /// Store the full D x D basis instead of truncating (ablation).
    pub store_full_basis: bool,
}

impl GuaranteeParams {
    pub fn for_tau(tau: f64, d: usize) -> Self {
        Self {
            tau,
            coeff_bin: tau / (d as f64).sqrt(),
            store_full_basis: false,
        }
    }
}

/// Output of the guarantee pass for one species.
#[derive(Clone, Debug)]
pub struct GuaranteeResult {
    /// Per block: (basis index, quantized coefficient) ascending by index.
    pub per_block: Vec<Vec<(usize, i64)>>,
    /// Corrected blocks x^G = x^R + U c_q, row-major [n_blocks, d].
    pub corrected: Vec<f32>,
    /// Stored basis (truncated to the highest used index unless
    /// `store_full_basis`).
    pub basis: SpeciesBasis,
    /// Total number of stored coefficients.
    pub n_coeffs: usize,
    /// Max ℓ2 residual after correction (should be <= tau).
    pub max_residual: f64,
    /// Blocks that needed correction at all.
    pub n_corrected_blocks: usize,
}

/// Run Algorithm 1 for one species.
/// `orig`/`recon`: row-major `[n_blocks, d]` normalized block vectors.
pub fn guarantee_species(
    orig: &[f32],
    recon: &[f32],
    n_blocks: usize,
    d: usize,
    params: &GuaranteeParams,
) -> GuaranteeResult {
    assert_eq!(orig.len(), n_blocks * d);
    assert_eq!(recon.len(), n_blocks * d);
    let tau = params.tau;
    // termination safety: with all D coefficients stored, the remaining
    // residual is bounded by √D · bin/2 (+ f32 rounding); keep it < tau.
    let bin = params.coeff_bin.min(1.9 * tau / (d as f64).sqrt());
    let quant = UniformQuantizer::new(bin);

    // 1. residuals + PCA
    let mut residuals = vec![0.0f32; n_blocks * d];
    for i in 0..n_blocks * d {
        residuals[i] = orig[i] - recon[i];
    }
    let pca = Pca::fit(&residuals, n_blocks, d, false);
    // f32 basis — identical to what the decompressor will use
    let full_basis = SpeciesBasis::from_mat(&pca.basis, d);

    let mut per_block: Vec<Vec<(usize, i64)>> = Vec::with_capacity(n_blocks);
    let mut corrected = recon.to_vec();
    let mut n_coeffs = 0usize;
    let mut max_residual = 0.0f64;
    let mut max_index_used = 0usize;
    let mut n_corrected_blocks = 0usize;

    let mut resid = vec![0.0f32; d];
    let mut coeffs: Vec<(usize, f64)> = Vec::with_capacity(d);

    for b in 0..n_blocks {
        let r0 = &residuals[b * d..(b + 1) * d];
        let mut delta2: f64 = r0.iter().map(|&v| (v as f64) * (v as f64)).sum();
        let mut selected: Vec<(usize, i64)> = Vec::new();

        if delta2.sqrt() > tau {
            n_corrected_blocks += 1;
            resid.copy_from_slice(r0);
            // project: c_j = u_j . r (f32 basis, f64 accumulate)
            coeffs.clear();
            for j in 0..d {
                let col = full_basis.col(j);
                let c: f64 = col
                    .iter()
                    .zip(r0)
                    .map(|(&u, &r)| u as f64 * r as f64)
                    .sum();
                coeffs.push((j, c));
            }
            // sort by squared contribution, descending (total_cmp: NaN-safe
            // on the request path)
            coeffs.sort_by(|a, b| (b.1 * b.1).total_cmp(&(a.1 * a.1)));

            for &(j, c) in coeffs.iter() {
                let q = quant.quantize(c);
                if q == 0 {
                    // zero quantized coefficient can't reduce the residual
                    continue;
                }
                let cq = quant.dequantize(q) as f32;
                // apply and re-measure exactly
                full_basis.axpy_col(j, -cq, &mut resid);
                delta2 = resid.iter().map(|&v| (v as f64) * (v as f64)).sum();
                selected.push((j, q));
                if delta2.sqrt() <= tau {
                    break;
                }
            }
            selected.sort_unstable_by_key(|&(j, _)| j);
            // corrected block = recon + U c_q == orig - resid
            let cb = &mut corrected[b * d..(b + 1) * d];
            for i in 0..d {
                cb[i] = orig[b * d + i] - resid[i];
            }
            if let Some(&(j, _)) = selected.iter().max_by_key(|&&(j, _)| j) {
                max_index_used = max_index_used.max(j + 1);
            }
        }

        n_coeffs += selected.len();
        max_residual = max_residual.max(delta2.sqrt());
        per_block.push(selected);
    }

    let rank = if params.store_full_basis {
        d
    } else {
        max_index_used
    };
    let basis = SpeciesBasis::from_mat(&pca.basis, rank);

    GuaranteeResult {
        per_block,
        corrected,
        basis,
        n_coeffs,
        max_residual,
        n_corrected_blocks,
    }
}

/// Decompressor side: apply stored coefficients to reconstructed blocks.
pub fn apply_correction(
    recon: &mut [f32],
    n_blocks: usize,
    d: usize,
    basis: &SpeciesBasis,
    per_block: &[Vec<(usize, f64)>],
) {
    debug_assert_eq!(recon.len(), n_blocks * d);
    debug_assert_eq!(per_block.len(), n_blocks);
    for (b, coeffs) in per_block.iter().enumerate() {
        let out = &mut recon[b * d..(b + 1) * d];
        for &(j, c) in coeffs {
            basis.axpy_col(j, c as f32, out);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    /// Synthetic recon = orig + structured noise.
    fn make_case(n: usize, d: usize, noise: f32, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Prng::new(seed);
        // low-dim structure in the residual (PCA-friendly, like AE errors)
        let dirs: Vec<Vec<f32>> = (0..3)
            .map(|_| (0..d).map(|_| rng.normal() as f32).collect())
            .collect();
        let orig: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let mut recon = orig.clone();
        for b in 0..n {
            for dir in &dirs {
                let c = rng.normal() as f32 * noise;
                for i in 0..d {
                    recon[b * d + i] += c * dir[i];
                }
            }
            for i in 0..d {
                recon[b * d + i] += rng.normal() as f32 * noise * 0.05;
            }
        }
        (orig, recon)
    }

    #[test]
    fn bound_satisfied_for_every_block() {
        let (n, d) = (64, 80);
        let (orig, recon) = make_case(n, d, 0.3, 1);
        let tau = 0.05;
        let res = guarantee_species(&orig, &recon, n, d, &GuaranteeParams::for_tau(tau, d));
        assert!(
            res.max_residual <= tau + 1e-9,
            "max residual {} > tau {tau}",
            res.max_residual
        );
        // verify block by block against the corrected output
        for b in 0..n {
            let e2: f64 = (0..d)
                .map(|i| {
                    let diff = (orig[b * d + i] - res.corrected[b * d + i]) as f64;
                    diff * diff
                })
                .sum();
            assert!(e2.sqrt() <= tau + 1e-9, "block {b}: {}", e2.sqrt());
        }
    }

    #[test]
    fn decompressor_reproduces_corrected_blocks() {
        let (n, d) = (32, 40);
        let (orig, recon) = make_case(n, d, 0.2, 2);
        let tau = 0.08;
        let params = GuaranteeParams::for_tau(tau, d);
        let res = guarantee_species(&orig, &recon, n, d, &params);

        // simulate decode: dequantize stored ints with the same bin
        let bin = params.coeff_bin.min(1.9 * tau / (d as f64).sqrt());
        let q = UniformQuantizer::new(bin);
        let per_block_f: Vec<Vec<(usize, f64)>> = res
            .per_block
            .iter()
            .map(|blk| blk.iter().map(|&(j, qq)| (j, q.dequantize(qq))).collect())
            .collect();
        let mut recon2 = recon.clone();
        apply_correction(&mut recon2, n, d, &res.basis, &per_block_f);
        for (a, b) in recon2.iter().zip(&res.corrected) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn tighter_tau_needs_more_coeffs() {
        let (n, d) = (48, 60);
        let (orig, recon) = make_case(n, d, 0.25, 3);
        let loose = guarantee_species(&orig, &recon, n, d, &GuaranteeParams::for_tau(0.2, d));
        let tight = guarantee_species(&orig, &recon, n, d, &GuaranteeParams::for_tau(0.02, d));
        assert!(tight.n_coeffs > loose.n_coeffs);
        assert!(tight.max_residual <= 0.02 + 1e-9);
    }

    #[test]
    fn already_good_blocks_store_nothing() {
        let (n, d) = (16, 20);
        let orig: Vec<f32> = vec![0.5; n * d];
        let recon = orig.clone();
        let res = guarantee_species(&orig, &recon, n, d, &GuaranteeParams::for_tau(0.01, d));
        assert_eq!(res.n_coeffs, 0);
        assert_eq!(res.n_corrected_blocks, 0);
        assert_eq!(res.basis.rank, 0);
        assert_eq!(res.corrected, recon);
    }

    #[test]
    fn pca_beats_identity_coding_on_structured_residuals() {
        // with residuals concentrated on 3 directions, the number of
        // stored coefficients should be far below n * d
        let (n, d) = (64, 50);
        let (orig, recon) = make_case(n, d, 0.5, 4);
        // tau above the small unstructured-noise floor: the 3 structured
        // directions dominate, so a handful of coefficients per block wins
        let res = guarantee_species(&orig, &recon, n, d, &GuaranteeParams::for_tau(0.3, d));
        assert!(res.max_residual <= 0.3 + 1e-9);
        assert!(res.n_coeffs < n * 10, "stored {} coeffs", res.n_coeffs);
        assert!(res.basis.rank <= d);
    }
}
