//! Stored form of a per-species PCA basis.
//!
//! The paper stores an 80x80 basis per species.  We truncate storage to the
//! highest basis index any block actually selected (unused trailing columns
//! cannot affect reconstruction — eigenvalue ordering makes early columns
//! do nearly all the work), which is a pure storage optimization with an
//! ablation toggle (`store_full`) in the benches.

use crate::error::{Error, Result};
use crate::util::bytes::{ByteReader, ByteWriter};

/// Column-major truncated orthonormal basis (f32 storage).
#[derive(Clone, Debug)]
pub struct SpeciesBasis {
    /// Block-vector dimension D.
    pub d: usize,
    /// Stored columns (<= D).
    pub rank: usize,
    /// Column-major: col(j) = data[j*d .. (j+1)*d].
    pub data: Vec<f32>,
}

impl SpeciesBasis {
    /// Build from a row-major D x D f64 basis, keeping the first `rank`
    /// columns rounded to f32 — the *exact* values the decompressor uses.
    pub fn from_mat(basis: &crate::linalg::Mat, rank: usize) -> SpeciesBasis {
        let d = basis.rows;
        let rank = rank.min(basis.cols);
        let mut data = vec![0.0f32; d * rank];
        for j in 0..rank {
            for i in 0..d {
                data[j * d + i] = basis[(i, j)] as f32;
            }
        }
        SpeciesBasis { d, rank, data }
    }

    #[inline]
    pub fn col(&self, j: usize) -> &[f32] {
        &self.data[j * self.d..(j + 1) * self.d]
    }

    /// Truncate to the first `rank` columns.  Column-major storage makes
    /// this a prefix slice of `data` — bit-identical to re-running
    /// [`Self::from_mat`] at the smaller rank, without converting the
    /// whole matrix again.
    pub fn truncated(mut self, rank: usize) -> SpeciesBasis {
        let rank = rank.min(self.rank);
        self.data.truncate(rank * self.d);
        self.rank = rank;
        self
    }

    /// out += col(j) * c
    #[inline]
    pub fn axpy_col(&self, j: usize, c: f32, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.d);
        for (o, &u) in out.iter_mut().zip(self.col(j)) {
            *o += c * u;
        }
    }

    /// out += col(j) * c, returning the updated ‖out‖₂² accumulated in
    /// index order — the guarantee loop's axpy and residual re-measure
    /// fused into one sweep.  Each out\[i\] is updated with the same f32
    /// op as [`Self::axpy_col`] and the f64 sum of squares visits the
    /// same values in the same order as a separate pass, so the result
    /// is bit-identical to axpy-then-re-measure.
    #[inline]
    pub fn axpy_col_norm2(&self, j: usize, c: f32, out: &mut [f32]) -> f64 {
        debug_assert_eq!(out.len(), self.d);
        let mut acc = 0.0f64;
        for (o, &u) in out.iter_mut().zip(self.col(j)) {
            *o += c * u;
            let v = *o as f64;
            acc += v * v;
        }
        acc
    }

    /// Storage bytes (counted toward the compression ratio).
    pub fn payload_bytes(&self) -> usize {
        16 + self.data.len() * 4
    }

    pub fn serialize(&self, w: &mut ByteWriter) {
        w.u64(self.d as u64);
        w.u64(self.rank as u64);
        w.f32s(&self.data);
    }

    pub fn deserialize(r: &mut ByteReader) -> Result<SpeciesBasis> {
        let d = r.u64()? as usize;
        let rank = r.u64()? as usize;
        if d == 0 || rank > d || d > 1 << 20 {
            return Err(Error::format(format!("bad basis dims d={d} rank={rank}")));
        }
        let data = r.f32s(d * rank)?;
        Ok(SpeciesBasis { d, rank, data })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;

    #[test]
    fn from_mat_truncates_columns() {
        let mut m = Mat::zeros(4, 4);
        for i in 0..4 {
            for j in 0..4 {
                m[(i, j)] = (i * 10 + j) as f64;
            }
        }
        let b = SpeciesBasis::from_mat(&m, 2);
        assert_eq!(b.rank, 2);
        assert_eq!(b.col(1), &[1.0, 11.0, 21.0, 31.0]);
    }

    #[test]
    fn serialize_roundtrip() {
        let mut m = Mat::identity(6);
        m[(0, 1)] = 0.5;
        let b = SpeciesBasis::from_mat(&m, 3);
        let mut w = ByteWriter::new();
        b.serialize(&mut w);
        let bytes = w.finish();
        assert_eq!(bytes.len(), b.payload_bytes());
        let mut r = ByteReader::new(&bytes);
        let b2 = SpeciesBasis::deserialize(&mut r).unwrap();
        assert_eq!(b.data, b2.data);
        assert_eq!((b.d, b.rank), (b2.d, b2.rank));
    }

    #[test]
    fn truncated_matches_from_mat() {
        let mut m = Mat::zeros(5, 5);
        for i in 0..5 {
            for j in 0..5 {
                m[(i, j)] = (i as f64 * 0.37 + j as f64 * 1.21).sin();
            }
        }
        let full = SpeciesBasis::from_mat(&m, 5);
        for rank in 0..=5usize {
            let sliced = full.clone().truncated(rank);
            let rebuilt = SpeciesBasis::from_mat(&m, rank);
            assert_eq!(sliced.data, rebuilt.data, "rank {rank}");
            assert_eq!((sliced.d, sliced.rank), (rebuilt.d, rebuilt.rank));
        }
        // truncating above the stored rank is a no-op
        let same = full.clone().truncated(9);
        assert_eq!(same.rank, 5);
        assert_eq!(same.data, full.data);
    }

    #[test]
    fn fused_axpy_norm_matches_two_pass() {
        let mut m = Mat::zeros(7, 7);
        for i in 0..7 {
            for j in 0..7 {
                m[(i, j)] = ((i * 7 + j) as f64 * 0.731).cos();
            }
        }
        let b = SpeciesBasis::from_mat(&m, 7);
        let start: Vec<f32> = (0..7).map(|i| (i as f32) * 0.3 - 1.0).collect();
        for j in 0..7 {
            let mut fused = start.clone();
            let n2 = b.axpy_col_norm2(j, -0.77, &mut fused);
            let mut two_pass = start.clone();
            b.axpy_col(j, -0.77, &mut two_pass);
            let expect: f64 = two_pass.iter().map(|&v| (v as f64) * (v as f64)).sum();
            assert_eq!(fused, two_pass, "col {j}");
            assert_eq!(n2, expect, "col {j}");
        }
    }

    #[test]
    fn axpy_accumulates() {
        let m = Mat::identity(3);
        let b = SpeciesBasis::from_mat(&m, 3);
        let mut out = vec![1.0f32; 3];
        b.axpy_col(1, 2.0, &mut out);
        assert_eq!(out, vec![1.0, 3.0, 1.0]);
    }
}
