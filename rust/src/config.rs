//! Configuration: the artifact manifest (cross-language contract written by
//! `aot.py`) and the runtime/compression config with profile presets.
//! Formats are plain `key=value` lines — no serde in the offline image.

use std::collections::HashMap;
use std::path::Path;

use crate::error::{Error, Result};

/// Parsed `artifacts/manifest.txt`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub species: usize,
    pub block_t: usize,
    pub block_y: usize,
    pub block_x: usize,
    pub latent: usize,
    pub encoder_batch: usize,
    pub tcn_points: usize,
    pub encoder_params: usize,
    pub decoder_params: usize,
    pub tcn_params: usize,
    pub train_profile: String,
    pub extras: HashMap<String, String>,
}

fn parse_kv(text: &str) -> HashMap<String, String> {
    let mut map = HashMap::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if let Some((k, v)) = line.split_once('=') {
            map.insert(k.trim().to_string(), v.trim().to_string());
        }
    }
    map
}

fn req_usize(map: &HashMap<String, String>, key: &str) -> Result<usize> {
    map.get(key)
        .ok_or_else(|| Error::config(format!("manifest missing key `{key}`")))?
        .parse()
        .map_err(|e| Error::config(format!("manifest key `{key}`: {e}")))
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let map = parse_kv(text);
        Ok(Manifest {
            species: req_usize(&map, "species")?,
            block_t: req_usize(&map, "block_t")?,
            block_y: req_usize(&map, "block_y")?,
            block_x: req_usize(&map, "block_x")?,
            latent: req_usize(&map, "latent")?,
            encoder_batch: req_usize(&map, "encoder_batch")?,
            tcn_points: req_usize(&map, "tcn_points")?,
            encoder_params: req_usize(&map, "encoder_params")?,
            decoder_params: req_usize(&map, "decoder_params")?,
            tcn_params: req_usize(&map, "tcn_params")?,
            train_profile: map.get("train_profile").cloned().unwrap_or_default(),
            extras: map,
        })
    }

    pub fn load<P: AsRef<Path>>(path: P) -> Result<Manifest> {
        let text = std::fs::read_to_string(path.as_ref()).map_err(|e| {
            Error::config(format!(
                "cannot read manifest {}: {e} — run `make artifacts`",
                path.as_ref().display()
            ))
        })?;
        Self::parse(&text)
    }

    /// Bytes of model parameters the archive must account for (the paper
    /// counts "network parameters" in the compressed output).  Decoder +
    /// TCN, stored 8-bit quantized (see accounting module).
    pub fn model_param_count(&self, with_tcn: bool) -> usize {
        self.decoder_params + if with_tcn { self.tcn_params } else { 0 }
    }
}

/// Top-level run configuration (CLI-facing).
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Directory with AOT artifacts.
    pub artifacts_dir: String,
    /// Worker threads for CPU stages (0 = all cores).
    pub threads: usize,
    /// Per-stage channel capacity (backpressure bound).
    pub queue_depth: usize,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            artifacts_dir: "artifacts".to_string(),
            threads: 0,
            queue_depth: 4,
        }
    }
}

impl RunConfig {
    pub fn effective_threads(&self) -> usize {
        if self.threads > 0 {
            self.threads
        } else {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
species=58
block_t=4
block_y=5
block_x=4
latent=36
encoder_batch=256
tcn_points=8192
encoder_params=110100
decoder_params=111386
tcn_params=243194
train_profile=small
seed=7
";

    #[test]
    fn parses_manifest() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.species, 58);
        assert_eq!((m.block_t, m.block_y, m.block_x), (4, 5, 4));
        assert_eq!(m.latent, 36);
        assert_eq!(m.encoder_batch, 256);
        assert_eq!(m.extras.get("seed").unwrap(), "7");
        assert_eq!(m.model_param_count(true), 111386 + 243194);
        assert_eq!(m.model_param_count(false), 111386);
    }

    #[test]
    fn missing_key_is_config_error() {
        let r = Manifest::parse("species=58\n");
        assert!(matches!(r, Err(Error::Config(_))));
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let m = Manifest::parse(&format!("# header\n\n{SAMPLE}")).unwrap();
        assert_eq!(m.species, 58);
    }
}
