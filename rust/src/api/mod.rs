//! The supported way in and out of the system.
//!
//! * **Ingest** — [`CompressorBuilder`] → [`CompressSession`]: a
//!   push-based session for live producers.  Timesteps arrive one
//!   `[S, Y, X]` frame at a time; at most one `kt_window` of them is
//!   buffered; every filled window runs the exact one-shot shard path
//!   and streams its payload to any [`StreamSink`] (`File`, in-memory
//!   `Cursor`, …) through the incremental `GBA2` writer.  Streamed
//!   archives are byte-identical to one-shot compression of the
//!   assembled field.
//! * **Crash consistency** — the contract every sink gets, not just the
//!   CLI's `.part`-rename path: each shard is journaled *after* its
//!   payload bytes are written and flushed, so a process killed
//!   mid-stream leaves a scannable unsealed prefix;
//!   [`CompressorBuilder::resume_session`] reopens it and continues
//!   byte-identically, and `CompressSession::finish` flushes **and
//!   syncs** (`fsync` on `File` sinks) before returning `Ok` — a
//!   successful finish means the sealed archive is on stable storage.
//!   See the [`session`] module docs for the full protocol and
//!   `gbatc repair` for offline salvage.
//! * **Accuracy** — [`ErrorPolicy`]: the typed replacement for the scalar
//!   NRMSE knob.  Uniform, or per-species budgets addressed by index or
//!   mechanism name ([`SpeciesBudget`]), each certified per
//!   (shard, species) like the scalar knob always was.
//! * **Egress** — [`ArchiveReader`] + [`Query`]: typed random-access
//!   partial decode (`time: t0..t1`, `species: SpeciesSel`), reading only
//!   the sections a query touches, bit-identical to full decode.
//!
//! The legacy surfaces — the [`Compressor`](crate::compressor::Compressor)
//! trait with its one-call `compress_bytes`, and the `gbatc` CLI — are
//! thin adapters over this module.

pub mod policy;
pub mod reader;
pub mod session;

pub use crate::archive::stream::{ResumeReport, StreamSink};
pub use policy::{ErrorPolicy, SpeciesBudget, SpeciesSel};
pub use reader::{ArchiveReader, Query};
pub use session::{Backend, CompressReport, CompressSession, CompressorBuilder, FieldSpec};
