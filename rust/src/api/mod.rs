//! The supported way in and out of the system.
//!
//! * **Ingest** — [`CompressorBuilder`] → [`CompressSession`]: a
//!   push-based session for live producers.  Timesteps arrive one
//!   `[S, Y, X]` frame at a time; at most one `kt_window` of them is
//!   buffered; every filled window runs the exact one-shot shard path
//!   and streams its payload to any `io::Write + io::Seek` sink through
//!   the incremental `GBA2` writer.  Streamed archives are byte-identical
//!   to one-shot compression of the assembled field.
//! * **Accuracy** — [`ErrorPolicy`]: the typed replacement for the scalar
//!   NRMSE knob.  Uniform, or per-species budgets addressed by index or
//!   mechanism name ([`SpeciesBudget`]), each certified per
//!   (shard, species) like the scalar knob always was.
//! * **Egress** — [`ArchiveReader`] + [`Query`]: typed random-access
//!   partial decode (`time: t0..t1`, `species: SpeciesSel`), reading only
//!   the sections a query touches, bit-identical to full decode.
//!
//! The legacy surfaces — the [`Compressor`](crate::compressor::Compressor)
//! trait with its one-call `compress_bytes`, and the `gbatc` CLI — are
//! thin adapters over this module.

pub mod policy;
pub mod reader;
pub mod session;

pub use policy::{ErrorPolicy, SpeciesBudget, SpeciesSel};
pub use reader::{ArchiveReader, Query};
pub use session::{Backend, CompressReport, CompressSession, CompressorBuilder, FieldSpec};
