//! Typed accuracy and species-selection knobs.
//!
//! [`ErrorPolicy`] replaces the scalar NRMSE knob: a live solver can ask
//! for one bound everywhere ([`ErrorPolicy::Uniform`]) or budget accuracy
//! per quantity of interest ([`ErrorPolicy::PerSpecies`]) — e.g. a tight
//! bound on the minor species whose production rates amplify error and a
//! loose one on N2.  Budgets address species by index or by mechanism
//! name ([`SpeciesSel`]); each resolved (shard, species) section is
//! planned and certified against its own budget, exactly as the scalar
//! knob certified every section against one.

use crate::chem;
use crate::compressor::traits::select_species;
use crate::error::{Error, Result};

/// A species subset — everything, explicit indices, or mechanism names
/// (numeric tokens in a name list are treated as indices, so CLI lists
/// like `OH,7,CO` work).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SpeciesSel {
    /// Every species of the archive / field.
    All,
    /// Explicit indices on the species axis.
    Indices(Vec<usize>),
    /// Mechanism species names, resolved via [`chem::resolve_species`]
    /// (unknown names error listing the available ones).
    Names(Vec<String>),
}

impl SpeciesSel {
    /// Parse a comma-separated CLI list of names and/or indices; an
    /// empty list selects all species.
    pub fn parse(list: &str) -> SpeciesSel {
        let toks: Vec<String> = list
            .split(',')
            .map(str::trim)
            .filter(|t| !t.is_empty())
            .map(str::to_string)
            .collect();
        if toks.is_empty() {
            SpeciesSel::All
        } else {
            SpeciesSel::Names(toks)
        }
    }

    /// Resolve to ascending, deduplicated indices over an `ns`-species
    /// axis.  Every selection — including `All` — is rejected on a
    /// zero-species archive (see
    /// [`select_species`](crate::compressor::traits::select_species)).
    pub fn resolve(&self, ns: usize) -> Result<Vec<usize>> {
        match self {
            SpeciesSel::All => select_species(&[], ns),
            SpeciesSel::Indices(idx) => select_species(idx, ns),
            SpeciesSel::Names(names) => {
                let mut idx = Vec::with_capacity(names.len());
                for tok in names {
                    match tok.parse::<usize>() {
                        Ok(i) => idx.push(i),
                        Err(_) => idx.push(chem::resolve_species(tok)?),
                    }
                }
                select_species(&idx, ns)
            }
        }
    }
}

/// One [`ErrorPolicy::PerSpecies`] entry: an NRMSE target for a species
/// subset.
#[derive(Clone, Debug)]
pub struct SpeciesBudget {
    pub species: SpeciesSel,
    pub nrmse: f64,
}

impl SpeciesBudget {
    /// Catch-all budget (the usual first entry).
    pub fn all(nrmse: f64) -> SpeciesBudget {
        SpeciesBudget {
            species: SpeciesSel::All,
            nrmse,
        }
    }

    /// Budget for one species index.
    pub fn index(s: usize, nrmse: f64) -> SpeciesBudget {
        SpeciesBudget {
            species: SpeciesSel::Indices(vec![s]),
            nrmse,
        }
    }

    /// Budget for one mechanism species by name.
    pub fn name(name: impl Into<String>, nrmse: f64) -> SpeciesBudget {
        SpeciesBudget {
            species: SpeciesSel::Names(vec![name.into()]),
            nrmse,
        }
    }
}

/// The typed accuracy knob of a compression session.
#[derive(Clone, Debug)]
pub enum ErrorPolicy {
    /// One NRMSE target for every species (the paper's scalar knob).
    Uniform(f64),
    /// Per-species targets.  Entries apply in order — later entries
    /// override earlier ones, so `[SpeciesBudget::all(1e-3),
    /// SpeciesBudget::name("OH", 1e-5)]` tightens one species — and
    /// together they must cover every species.
    PerSpecies(Vec<SpeciesBudget>),
}

impl ErrorPolicy {
    /// Resolve to one positive NRMSE target per species.
    pub fn resolve(&self, ns: usize) -> Result<Vec<f64>> {
        fn check(nrmse: f64) -> Result<f64> {
            if nrmse.is_nan() || nrmse <= 0.0 {
                return Err(Error::config(format!(
                    "NRMSE target {nrmse} must be positive"
                )));
            }
            Ok(nrmse)
        }
        match self {
            ErrorPolicy::Uniform(t) => Ok(vec![check(*t)?; ns]),
            ErrorPolicy::PerSpecies(budgets) => {
                if budgets.is_empty() {
                    return Err(Error::config(
                        "per-species error policy needs at least one budget",
                    ));
                }
                let mut targets: Vec<Option<f64>> = vec![None; ns];
                for b in budgets {
                    let t = check(b.nrmse)?;
                    for s in b.species.resolve(ns)? {
                        targets[s] = Some(t);
                    }
                }
                let uncovered: Vec<usize> = targets
                    .iter()
                    .enumerate()
                    .filter(|(_, t)| t.is_none())
                    .map(|(s, _)| s)
                    .collect();
                if !uncovered.is_empty() {
                    return Err(Error::config(format!(
                        "per-species error policy leaves species {uncovered:?} unbudgeted; \
                         start with a catch-all SpeciesBudget::all(...)"
                    )));
                }
                Ok(targets.into_iter().map(|t| t.unwrap_or(0.0)).collect())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_policy_repeats_and_validates() {
        assert_eq!(ErrorPolicy::Uniform(1e-3).resolve(3).unwrap(), vec![1e-3; 3]);
        assert!(ErrorPolicy::Uniform(0.0).resolve(3).is_err());
        assert!(ErrorPolicy::Uniform(f64::NAN).resolve(3).is_err());
    }

    #[test]
    fn per_species_later_entries_override() {
        let oh = chem::resolve_species("OH").unwrap();
        let policy = ErrorPolicy::PerSpecies(vec![
            SpeciesBudget::all(1e-3),
            SpeciesBudget::name("OH", 1e-5),
            SpeciesBudget::index(0, 5e-4),
        ]);
        let targets = policy.resolve(chem::NS).unwrap();
        assert_eq!(targets[oh], 1e-5);
        assert_eq!(targets[0], 5e-4);
        assert_eq!(targets[1], 1e-3);
    }

    #[test]
    fn per_species_must_cover_everything() {
        let policy = ErrorPolicy::PerSpecies(vec![SpeciesBudget::index(0, 1e-3)]);
        let err = policy.resolve(3).unwrap_err().to_string();
        assert!(err.contains("unbudgeted"), "{err}");
        assert!(ErrorPolicy::PerSpecies(Vec::new()).resolve(3).is_err());
        let bad = ErrorPolicy::PerSpecies(vec![SpeciesBudget::all(-1.0)]);
        assert!(bad.resolve(3).is_err());
    }

    #[test]
    fn species_sel_parses_and_resolves() {
        assert_eq!(SpeciesSel::parse(""), SpeciesSel::All);
        assert_eq!(SpeciesSel::All.resolve(3).unwrap(), vec![0, 1, 2]);
        let sel = SpeciesSel::parse("CO, 2 ,OH");
        let co = chem::resolve_species("CO").unwrap();
        let oh = chem::resolve_species("OH").unwrap();
        let mut expect = vec![co, 2, oh];
        expect.sort_unstable();
        assert_eq!(sel.resolve(chem::NS).unwrap(), expect);
        // unknown names list the available species
        let err = SpeciesSel::parse("NO,bogus").resolve(chem::NS).unwrap_err();
        assert!(err.to_string().contains("available"), "{err}");
        // indices out of range and zero-species axes are rejected
        assert!(SpeciesSel::Indices(vec![9]).resolve(3).is_err());
        assert!(SpeciesSel::All.resolve(0).is_err());
    }
}
