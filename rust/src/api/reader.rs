//! Typed egress: [`ArchiveReader`] + [`Query`].
//!
//! An `ArchiveReader` wraps any archive — a `GBA2` file read section by
//! section, in-memory bytes, or a legacy `GBA1` archive (converted to its
//! one-shard `GBA2` view on open) — behind one typed query API over the
//! engine's random-access partial decode.  Queries read only the shards
//! and species sections they touch; the output is bit-identical to the
//! corresponding slice of a full decode.

use std::path::Path;

use crate::api::policy::SpeciesSel;
use crate::api::session::Backend;
use crate::archive::{
    AnyArchive, FileSource, Gba2Archive, Gba2Header, IoStats, MemSource, MeteredSource,
    MmapSource, SectionSource, ShardToc, MAGIC,
};
use crate::coordinator::engine::{RangeDecode, ShardEngine};
use crate::error::{Error, Result};
use crate::runtime::{ExecHandle, ExecService};

/// A typed partial-decode request: a half-open time window plus a
/// species subset.
#[derive(Clone, Debug)]
pub struct Query {
    /// Timesteps `[start, end)`.
    pub time: std::ops::Range<usize>,
    pub species: SpeciesSel,
}

impl Query {
    /// The full time axis, all species.
    pub fn all(nt: usize) -> Query {
        Query {
            time: 0..nt,
            species: SpeciesSel::All,
        }
    }

    /// A time window, all species.
    pub fn window(time: std::ops::Range<usize>) -> Query {
        Query {
            time,
            species: SpeciesSel::All,
        }
    }

    /// Restrict to a species subset.
    pub fn species(mut self, species: SpeciesSel) -> Query {
        self.species = species;
        self
    }
}

/// Typed reader over an archive; see the module docs.
///
/// ```
/// use std::io::Cursor;
/// use gbatc::api::{
///     ArchiveReader, Backend, CompressorBuilder, ErrorPolicy, FieldSpec, Query, SpeciesSel,
/// };
///
/// # let (nt, ns, ny, nx) = (4, 58, 5, 4);
/// # let field = FieldSpec { nt, ns, ny, nx, pressure: 40.0e5, ranges: vec![(0.0, 1.0); ns] };
/// # let mut session = CompressorBuilder::new()
/// #     .error_policy(ErrorPolicy::Uniform(1e-2))
/// #     .session(field, Cursor::new(Vec::new()))?;
/// # for t in 0..nt {
/// #     let frame: Vec<f32> = (0..ns * ny * nx)
/// #         .map(|i| 0.5 + 0.3 * ((i + t * 31) as f32 * 0.11).sin())
/// #         .collect();
/// #     session.push_timestep(&frame)?;
/// # }
/// # let (_report, sink) = session.finish_into()?;
/// let reader = ArchiveReader::from_bytes(sink.into_inner(), &Backend::Reference, 0)?;
/// let decode = reader.query(&Query {
///     time: 0..2,
///     species: SpeciesSel::Names(vec!["OH".into(), "CO".into()]),
/// })?;
/// assert_eq!(decode.species.len(), 2);
/// assert_eq!(decode.mass.len(), 2 * 2 * ny * nx);
/// # Ok::<(), gbatc::Error>(())
/// ```
pub struct ArchiveReader {
    /// Keeps a reader-started service alive (`with_handle` borrows an
    /// external one instead).
    _service: Option<ExecService>,
    handle: ExecHandle,
    src: MeteredSource,
    header: Gba2Header,
    toc: Vec<ShardToc>,
    threads: usize,
}

impl ArchiveReader {
    /// Open an archive file.  `GBA2` files are read section by section
    /// (queries touch only the byte ranges they need); legacy `GBA1`
    /// files are loaded and converted to their one-shard `GBA2` view.
    pub fn open_file<P: AsRef<Path>>(
        path: P,
        backend: &Backend,
        threads: usize,
    ) -> Result<ArchiveReader> {
        let (service, _, _) = backend.start(4)?;
        let handle = service.handle();
        Self::build(Some(service), handle, open_metered(path.as_ref())?, threads)
    }

    /// Open over owned serialized bytes of either container version.
    pub fn from_bytes(bytes: Vec<u8>, backend: &Backend, threads: usize) -> Result<ArchiveReader> {
        let (service, _, _) = backend.start(4)?;
        let handle = service.handle();
        Self::build(
            Some(service),
            handle,
            MeteredSource::new(Box::new(MemSource(v2_bytes(bytes)?))),
            threads,
        )
    }

    /// Open over owned bytes on an already-running executor handle (no
    /// second service is spawned).
    pub fn with_handle(
        handle: &ExecHandle,
        bytes: Vec<u8>,
        threads: usize,
    ) -> Result<ArchiveReader> {
        Self::build(
            None,
            handle.clone(),
            MeteredSource::new(Box::new(MemSource(v2_bytes(bytes)?))),
            threads,
        )
    }

    fn build(
        service: Option<ExecService>,
        handle: ExecHandle,
        src: MeteredSource,
        threads: usize,
    ) -> Result<ArchiveReader> {
        let (header, toc) = Gba2Archive::read_toc(&src)?;
        // the payload region starts at the first shard's offset; every
        // read below it (including the TOC re-read each query performs)
        // meters as a header/TOC read from here on
        src.set_header_limit(payload_base(&toc, &src));
        Ok(ArchiveReader {
            _service: service,
            handle,
            src,
            header,
            toc,
            threads,
        })
    }

    /// The parsed archive header (dims, block, ranges, targets...).
    pub fn header(&self) -> &Gba2Header {
        &self.header
    }

    pub fn n_shards(&self) -> usize {
        self.toc.len()
    }

    /// Total serialized archive bytes.
    pub fn archive_bytes(&self) -> u64 {
        self.src.source_len()
    }

    /// Archive bytes read since open / the last reset — header/TOC *and*
    /// payload (earlier versions missed the TOC reads).
    pub fn bytes_read(&self) -> u64 {
        self.src.stats().bytes()
    }

    /// Ranged reads served since open / the last reset.
    pub fn reads(&self) -> u64 {
        self.src.stats().reads()
    }

    /// Classified IO counters: header/TOC reads (open + the re-read each
    /// query performs) separately from payload section reads.  Surfaced
    /// by `gbatc inspect --stats`, `gbatc extract`, and the query
    /// server's `/stats` endpoint.
    pub fn io_stats(&self) -> IoStats {
        self.src.stats()
    }

    /// Zero the IO counters (e.g. to meter one query in isolation,
    /// excluding the reads at open).
    pub fn reset_io_stats(&self) {
        self.src.reset();
    }

    /// Decode a typed query, reading only the shards/sections it
    /// touches.  The output is bit-identical to the same slice of a full
    /// decode (see
    /// [`ShardEngine::decompress_range`](crate::coordinator::engine::ShardEngine::decompress_range)).
    pub fn query(&self, q: &Query) -> Result<RangeDecode> {
        let sel = q.species.resolve(self.header.dims.1)?;
        let engine = ShardEngine::new(&self.handle, 0, 0);
        engine.decompress_range(&self.src, q.time.start, q.time.end, &sel, self.threads)
    }

    /// Decode the whole field back to mass fractions `[T, S, Y, X]`.
    pub fn decompress_all(&self) -> Result<Vec<f32>> {
        Ok(self.query(&Query::all(self.header.dims.0))?.mass)
    }
}

/// Open an archive file behind a metered source: `GBA2` files stay on
/// disk — memory-mapped when the platform allows it ([`MmapSource`],
/// zero-copy page-cache reads, visible in [`IoStats::mmap_bytes`]), a
/// seek/read [`FileSource`] otherwise — and are read section by section;
/// legacy `GBA1` files are loaded whole (charged to the payload
/// counters) and converted to their one-shard `GBA2` view in memory.
/// Shared by [`ArchiveReader`] and [`crate::store::ArchiveStore`].
/// Either source yields bit-identical section bytes, asserted by the
/// `zero_copy` integration tests.
pub(crate) fn open_metered(path: &Path) -> Result<MeteredSource> {
    let file = FileSource::open(path)?;
    let magic = file.read_at(0, 4)?;
    if magic == *MAGIC {
        let bytes = std::fs::read(path)?;
        let loaded = bytes.len() as u64;
        let src = MeteredSource::new(Box::new(MemSource(v2_bytes(bytes)?)));
        // the whole-file conversion load, plus the magic probe above
        src.add_toc(1, 4);
        src.add_payload(1, loaded);
        Ok(src)
    } else {
        let src = match MmapSource::open(path) {
            Ok(map) => MeteredSource::new_mapped(Box::new(map)),
            Err(_) => MeteredSource::new(Box::new(file)),
        };
        src.add_toc(1, 4);
        Ok(src)
    }
}

/// First payload byte of a parsed TOC (the header/TOC region ends where
/// the first shard begins).
pub(crate) fn payload_base(toc: &[ShardToc], src: &MeteredSource) -> u64 {
    toc.first().map(|e| e.shard.0).unwrap_or_else(|| src.source_len())
}

/// Normalize serialized archive bytes to the `GBA2` working layout
/// (legacy `GBA1` converts to its one-shard view; anything else is
/// rejected with a clear error).
pub(crate) fn v2_bytes(bytes: Vec<u8>) -> Result<Vec<u8>> {
    if bytes.starts_with(MAGIC) {
        Ok(AnyArchive::deserialize(&bytes)?.into_v2()?.into_bytes())
    } else if bytes.starts_with(crate::archive::MAGIC2) {
        Ok(bytes)
    } else {
        Err(Error::format(
            "unknown archive magic (expected GBA1 or GBA2)",
        ))
    }
}
