//! Push-based compression sessions.
//!
//! [`CompressorBuilder`] resolves every knob (backend, codec policy,
//! [`ErrorPolicy`], shard/pipeline settings) up front and opens a
//! [`CompressSession`]: a live producer (a running CFD solver) hands over
//! one `[S, Y, X]` timestep at a time, the session buffers at most one
//! `kt_window` of them, and every filled window runs through the exact
//! shard path one-shot compression uses
//! ([`ShardEngine::shard_stage`](crate::coordinator::engine::ShardEngine))
//! before its payload streams out to the sink through the incremental
//! `GBA2` writer ([`crate::archive::Gba2StreamWriter`]).  Peak working
//! memory is bounded by one shard window — never the whole field — and
//! the finished archive is **byte-identical** to what
//! `ShardEngine::compress` would have produced from the assembled field
//! (property-tested in `tests/streaming_session.rs`).
//!
//! With `--codec auto` the per-shard *float* work still happens as each
//! window fills, but the payload choice is deferred to
//! [`CompressSession::finish`]: the rate–distortion planner needs every
//! shard's candidate sizes because the model-parameter charge is
//! archive-global.  Only encoded candidates are held in the meantime.
//!
//! ## Crash consistency
//!
//! Single-codec sessions write through the journaled
//! [`Gba2StreamWriter`]: each shard's payload is written and flushed
//! *before* the journal record that commits it, and
//! [`CompressSession::finish`] back-patches the real header + TOC, then
//! calls [`StreamSink::sync_durable`] (`fsync` for `File` sinks) before
//! returning — an `Ok` from `finish` means the sealed archive is on
//! stable storage.  If the process dies mid-stream, the sink holds an
//! unsealed journaled prefix: reopen it with
//! [`CompressorBuilder::resume_session`] (same backend, policy, codec,
//! and field as the interrupted run) and re-push the field from `t = 0`
//! — already-durable timesteps are skipped, the torn tail is rewritten,
//! and the sealed archive is **byte-identical** to an uninterrupted run
//! (property-tested in `tests/streaming_session.rs` by killing at every
//! shard boundary).  `--codec auto` sessions defer all payload writes
//! to `finish` and are not resumable; `gbatc repair` can still seal the
//! surviving prefix of any unsealed stream offline.

use std::io::Read;

use crate::api::policy::ErrorPolicy;
use crate::archive::stream::{Gba2StreamWriter, ResumeReport, StreamLayout, StreamSink};
use crate::archive::toc::{VERSION2, VERSION3};
use crate::archive::{CodecTag, Gba2Header};
use crate::compressor::accounting::{model_param_bytes, SizeBreakdown};
use crate::compressor::gba::CompressOptions;
use crate::compressor::registry::CodecChoice;
use crate::config::Manifest;
use crate::coordinator::engine::{
    effective_threads, plan_trials, PendingShard, ShardEngine, ShardRunCtx, ShardStage,
    ShardTotals, WorkspaceMeter,
};
use crate::coordinator::{Progress, StageClock, StageTimes};
use crate::data::blocks::{BlockGrid, BlockShape};
use crate::data::shards::ShardPlan;
use crate::data::Dataset;
use crate::error::{Error, Result};
use crate::runtime::{ExecHandle, ExecService, RuntimeSpec};

/// Which execution backend a [`CompressorBuilder`] or
/// [`crate::api::ArchiveReader`] starts.
#[derive(Clone, Debug, Default)]
pub enum Backend {
    /// The deterministic pure-Rust reference runtime — no artifacts
    /// needed, identical error guarantees.
    #[default]
    Reference,
    /// An AOT artifacts directory (PJRT when the `pjrt` feature is on,
    /// otherwise a manifest-shaped reference runtime).
    Artifacts(String),
}

impl Backend {
    /// Start the executor service; returns `(service, decoder_params,
    /// tcn_params)` — the parameter counts feed compression-ratio
    /// accounting (the reference backend stores no model).
    pub fn start(&self, queue_depth: usize) -> Result<(ExecService, usize, usize)> {
        match self {
            Backend::Reference => {
                let service =
                    ExecService::start_reference(RuntimeSpec::reference_default(), queue_depth)?;
                Ok((service, 0, 0))
            }
            Backend::Artifacts(dir) => {
                let manifest = Manifest::load(format!("{dir}/manifest.txt"))?;
                let service = ExecService::start(dir, queue_depth)?;
                Ok((service, manifest.decoder_params, manifest.tcn_params))
            }
        }
    }
}

/// Everything a push-based session must know about the incoming field
/// before the first timestep arrives.  A live solver knows all of it: the
/// run length, the grid, and the physical per-species bounds that become
/// the archive's normalization ranges.
#[derive(Clone, Debug)]
pub struct FieldSpec {
    pub nt: usize,
    pub ns: usize,
    pub ny: usize,
    pub nx: usize,
    /// Ambient pressure [Pa] (recorded in the archive header).
    pub pressure: f64,
    /// Global per-species `(lo, hi)` normalization ranges.  One-shot
    /// compression derives these from the full field
    /// ([`Dataset::species_ranges`]); a streaming producer supplies its
    /// physical bounds (values outside normalize linearly past [0, 1] —
    /// correctness is unaffected, compression ratio may suffer).
    pub ranges: Vec<(f32, f32)>,
}

impl FieldSpec {
    /// The spec one-shot compression would use for `ds` — with these
    /// exact ranges, a session fed `ds` timestep-by-timestep produces a
    /// byte-identical archive.
    pub fn from_dataset(ds: &Dataset) -> FieldSpec {
        FieldSpec {
            nt: ds.nt,
            ns: ds.ns,
            ny: ds.ny,
            nx: ds.nx,
            pressure: ds.pressure,
            ranges: ds.species_ranges(),
        }
    }

    /// Values in one `[S, Y, X]` timestep frame.
    pub fn timestep_len(&self) -> usize {
        self.ns * self.ny * self.nx
    }
}

/// Builder for compression sessions — the supported way into the system.
/// Every knob is validated when the session opens (absorbing what used to
/// be scattered across `CompressOptions::validate` and the CLI), so a
/// misconfiguration fails before the first timestep is accepted.
///
/// ```
/// use std::io::Cursor;
/// use gbatc::api::{CompressorBuilder, ErrorPolicy, FieldSpec, SpeciesBudget};
///
/// let (nt, ns, ny, nx) = (4, 58, 5, 4);
/// let field = FieldSpec {
///     nt,
///     ns,
///     ny,
///     nx,
///     pressure: 40.0e5,
///     ranges: vec![(0.0, 1.0); ns],
/// };
/// let mut session = CompressorBuilder::new()
///     .error_policy(ErrorPolicy::PerSpecies(vec![
///         SpeciesBudget::all(1e-2),
///         SpeciesBudget::name("OH", 1e-3),
///     ]))
///     .session(field, Cursor::new(Vec::new()))?;
/// for t in 0..nt {
///     // one [S, Y, X] frame per solver step
///     let frame: Vec<f32> = (0..ns * ny * nx)
///         .map(|i| 0.5 + 0.3 * ((i + t * 31) as f32 * 0.11).sin())
///         .collect();
///     session.push_timestep(&frame)?;
/// }
/// let (report, sink) = session.finish_into()?;
/// assert_eq!(report.n_shards, 1);
/// assert_eq!(sink.get_ref().len() as u64, report.archive_bytes);
/// # Ok::<(), gbatc::Error>(())
/// ```
#[derive(Clone, Debug)]
pub struct CompressorBuilder {
    backend: Backend,
    policy: ErrorPolicy,
    /// Single source of truth for the engine knobs — new
    /// `CompressOptions` fields flow through the builder automatically
    /// (`nrmse_target` is superseded by `policy`).
    opts: CompressOptions,
}

impl Default for CompressorBuilder {
    fn default() -> Self {
        Self::from_options(&CompressOptions::default())
    }
}

impl CompressorBuilder {
    /// Reference backend, uniform 1e-3 NRMSE, default knobs.
    pub fn new() -> CompressorBuilder {
        CompressorBuilder::default()
    }

    /// Mirror an engine-level `CompressOptions` (the `Compressor` trait
    /// adapter's bridge); the accuracy knob becomes a uniform policy.
    pub fn from_options(opts: &CompressOptions) -> CompressorBuilder {
        CompressorBuilder {
            backend: Backend::Reference,
            policy: ErrorPolicy::Uniform(opts.nrmse_target),
            opts: opts.clone(),
        }
    }

    pub fn backend(mut self, backend: Backend) -> Self {
        self.backend = backend;
        self
    }

    /// Use the pure-Rust reference backend (the default).
    pub fn reference(self) -> Self {
        self.backend(Backend::Reference)
    }

    /// Load AOT artifacts from `dir`.
    pub fn artifacts(self, dir: impl Into<String>) -> Self {
        self.backend(Backend::Artifacts(dir.into()))
    }

    /// Accuracy policy (uniform or per-species budgets).
    pub fn error_policy(mut self, policy: ErrorPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Codec policy: all-GBATC (default), all-SZ/dense, or the
    /// rate–distortion planner (`auto`).
    pub fn codec(mut self, codec: CodecChoice) -> Self {
        self.opts.codec = codec;
        self
    }

    /// Latent quantization bin width.
    pub fn latent_bin(mut self, bin: f64) -> Self {
        self.opts.latent_bin = bin;
        self
    }

    /// Apply the tensor-correction network (GBATC) or not (GBA).
    pub fn use_tcn(mut self, on: bool) -> Self {
        self.opts.use_tcn = on;
        self
    }

    /// Worker threads for CPU stages (0 = all cores).
    pub fn threads(mut self, threads: usize) -> Self {
        self.opts.threads = threads;
        self
    }

    /// Store full D×D bases (ablation).
    pub fn store_full_basis(mut self, on: bool) -> Self {
        self.opts.store_full_basis = on;
        self
    }

    /// Charge model parameters at f32 instead of 8-bit (ablation).
    pub fn model_bytes_f32(mut self, on: bool) -> Self {
        self.opts.model_bytes_f32 = on;
        self
    }

    /// Batches in flight in the encode/decode pipelines.
    pub fn queue_depth(mut self, depth: usize) -> Self {
        self.opts.queue_depth = depth;
        self
    }

    /// Shard time-window width in timesteps (0 = auto).
    pub fn kt_window(mut self, kt_window: usize) -> Self {
        self.opts.kt_window = kt_window;
        self
    }

    /// Shards processed concurrently by *one-shot* compression (a session
    /// is inherently sequential — timesteps arrive in order — but the
    /// knob passes through to [`Compressor`](crate::compressor)
    /// adapters).
    pub fn shard_workers(mut self, workers: usize) -> Self {
        self.opts.shard_workers = workers;
        self
    }

    /// The engine options this builder resolves to; `max_target` fills
    /// the legacy scalar knob (header display, back-compat paths).
    pub(crate) fn options(&self, max_target: f64) -> CompressOptions {
        CompressOptions {
            nrmse_target: max_target,
            ..self.opts.clone()
        }
    }

    /// Start the configured backend and open a push session writing to
    /// `sink`.
    pub fn session<W: StreamSink>(
        &self,
        field: FieldSpec,
        sink: W,
    ) -> Result<CompressSession<W>> {
        let (service, decoder_params, tcn_params) = self.backend.start(self.opts.queue_depth)?;
        let handle = service.handle();
        CompressSession::start(
            Some(service),
            handle,
            decoder_params,
            tcn_params,
            self,
            field,
            sink,
        )
    }

    /// Open a session on an already-running executor handle (no second
    /// service is spawned; the backend knob is ignored).  The parameter
    /// counts feed compression-ratio accounting.
    pub fn session_on<W: StreamSink>(
        &self,
        handle: &ExecHandle,
        decoder_params: usize,
        tcn_params: usize,
        field: FieldSpec,
        sink: W,
    ) -> Result<CompressSession<W>> {
        CompressSession::start(
            None,
            handle.clone(),
            decoder_params,
            tcn_params,
            self,
            field,
            sink,
        )
    }

    /// Reopen an interrupted single-codec session: scan `sink`'s journal
    /// ([`Gba2StreamWriter::resume`]), keep every CRC-verified durable
    /// shard, and return a session that silently skips the
    /// already-compressed timesteps — re-push the field from `t = 0`
    /// with the **same** backend, policy, codec, and field spec as the
    /// interrupted run, and the sealed archive is byte-identical to an
    /// uninterrupted one.  `--codec auto` sessions are not resumable
    /// (payload writes are deferred to `finish`, so nothing durable
    /// survives a crash).
    pub fn resume_session<W: StreamSink + Read>(
        &self,
        field: FieldSpec,
        sink: W,
    ) -> Result<(CompressSession<W>, ResumeReport)> {
        let (service, decoder_params, tcn_params) = self.backend.start(self.opts.queue_depth)?;
        let handle = service.handle();
        CompressSession::resume(
            Some(service),
            handle,
            decoder_params,
            tcn_params,
            self,
            field,
            sink,
        )
    }

    /// [`resume_session`](Self::resume_session) on an already-running
    /// executor handle (mirrors [`session_on`](Self::session_on)).
    pub fn resume_session_on<W: StreamSink + Read>(
        &self,
        handle: &ExecHandle,
        decoder_params: usize,
        tcn_params: usize,
        field: FieldSpec,
        sink: W,
    ) -> Result<(CompressSession<W>, ResumeReport)> {
        CompressSession::resume(
            None,
            handle.clone(),
            decoder_params,
            tcn_params,
            self,
            field,
            sink,
        )
    }
}

/// Where a session's payloads go before `finish()`.
enum SinkState<W: StreamSink> {
    /// Single-codec policies stream each finished shard immediately.
    Stream(Gba2StreamWriter<W>),
    /// `--codec auto` defers payload emission to `finish()` (the planner
    /// is archive-global); the raw sink waits here.
    Deferred(W),
}

/// Outcome of a [`CompressSession`] — the one-shot
/// [`CompressReport`](crate::compressor::CompressReport) minus the
/// in-memory archive (it went to the sink), plus the stream totals.
#[derive(Debug)]
pub struct CompressReport {
    /// `[T, S, Y, X]` of the compressed field.
    pub dims: (usize, usize, usize, usize),
    pub kt_window: usize,
    pub n_shards: usize,
    /// Serialized archive bytes written to the sink.
    pub archive_bytes: u64,
    /// Container version emitted (2 = all-GBATC layout, 3 = tagged).
    pub version: u16,
    /// Per-codec (sections, section bytes), indexed by `CodecTag as
    /// usize`.
    pub codec_totals: [(usize, u64); 3],
    /// Model-parameter bytes charged to the compression ratio.
    pub model_param_bytes: usize,
    pub breakdown: SizeBreakdown,
    /// Max per-block ℓ2 residual observed — within each species' own τ.
    pub max_block_residual: f64,
    /// Loosest per-block bound (per-species bounds are tighter).
    pub tau: f64,
    pub n_coeffs: usize,
    /// High-water mark of the session's working sets — bounded by one
    /// shard window, not the field (`benches/perf_streaming.rs` meters
    /// it).
    pub peak_workspace_bytes: usize,
    pub stage_times: StageTimes,
    pub elapsed_s: f64,
    pub progress_summary: String,
}

impl CompressReport {
    /// Compression ratio against the raw field bytes (model charge
    /// included, as the paper accounts it).
    pub fn compression_ratio(&self) -> f64 {
        let (nt, ns, ny, nx) = self.dims;
        (nt * ns * ny * nx * 4) as f64
            / (self.archive_bytes as usize + self.model_param_bytes).max(1) as f64
    }
}

/// A push-based compression session; see the module docs.
pub struct CompressSession<W: StreamSink> {
    /// Keeps a builder-started service alive for the session's lifetime
    /// (`session_on` borrows an external one instead).
    _service: Option<ExecService>,
    handle: ExecHandle,
    decoder_params: usize,
    tcn_params: usize,
    opts: CompressOptions,
    ctx: ShardRunCtx,
    field: FieldSpec,
    plan: ShardPlan,
    sink: SinkState<W>,
    /// One shard window of raw timesteps — the only field-sized-per-shard
    /// buffer the session owns.
    window: Vec<f32>,
    /// Timesteps buffered in `window`.
    w_fill: usize,
    /// Timesteps received in total.
    t_pushed: usize,
    /// Leading timesteps a resumed session discards — they are already
    /// inside durable shards recovered from the stream journal.  Always
    /// a whole number of shard windows; 0 for a fresh session.
    skip_t: usize,
    next_shard: usize,
    /// Set when a window flush failed: the archive stream is no longer
    /// consistent, so every later call returns a typed error instead of
    /// pushing into (or sealing) a half-written shard.
    poisoned: bool,
    /// Deferred `--codec auto` shards (encoded candidates only).
    pending: Vec<PendingShard>,
    totals: ShardTotals,
    meter: WorkspaceMeter,
    clock: StageClock,
    progress: Progress,
}

/// Everything `start` and `resume` share: validated knobs, the shard
/// plan, the run context, and the window buffer.
struct SessionPrep {
    opts: CompressOptions,
    ctx: ShardRunCtx,
    plan: ShardPlan,
    window: Vec<f32>,
    block: (usize, usize, usize),
    latent_dim: usize,
    model_bytes_full: usize,
}

impl SessionPrep {
    fn new(
        builder: &CompressorBuilder,
        handle: &ExecHandle,
        decoder_params: usize,
        tcn_params: usize,
        field: &FieldSpec,
    ) -> Result<SessionPrep> {
        let spec = handle.spec();
        if field.ns != spec.species {
            return Err(Error::shape(format!(
                "field has {} species, model expects {}",
                field.ns, spec.species
            )));
        }
        // garbage normalization bounds would silently destroy the archive
        // deep into the run — reject them before the first timestep.
        // (lo == hi is allowed: a genuinely constant species normalizes to
        // zero, exactly as one-shot compression handles it.)
        for (s, &(lo, hi)) in field.ranges.iter().enumerate() {
            if !lo.is_finite() || !hi.is_finite() || lo > hi {
                return Err(Error::config(format!(
                    "species {s}: invalid normalization range ({lo}, {hi})"
                )));
            }
        }
        let targets = builder.policy.resolve(field.ns)?;
        let max_target = targets.iter().fold(f64::NEG_INFINITY, |a, &t| a.max(t));
        let opts = builder.options(max_target);
        // typed config validation before the first timestep is accepted
        opts.validate(spec.block.0)?;
        let plan = ShardPlan::new(field.nt, spec.block.0, opts.kt_window)?;
        // fail fast on grid divisibility (the same check one-shot
        // compression runs on the whole field)
        let shape = BlockShape {
            kt: spec.block.0,
            by: spec.block.1,
            bx: spec.block.2,
        };
        BlockGrid::new((plan.window(0).nt, field.ns, field.ny, field.nx), shape)?;
        let block = (spec.block.0, spec.block.1, spec.block.2);
        let latent_dim = spec.latent;
        // one window in flight at a time: every core works inside it
        let threads = effective_threads(opts.threads);
        let ctx = ShardRunCtx::new(
            &opts,
            &targets,
            spec,
            (field.ns, field.ny, field.nx),
            field.ranges.clone(),
            threads,
        )?;
        let window = vec![0.0f32; plan.kt_window * field.timestep_len()];
        let model_bytes_full = model_param_bytes(
            decoder_params + if opts.use_tcn { tcn_params } else { 0 },
            opts.model_bytes_f32,
        );
        Ok(SessionPrep {
            opts,
            ctx,
            plan,
            window,
            block,
            latent_dim,
            model_bytes_full,
        })
    }

    fn stream_version(&self) -> u16 {
        if self.opts.codec == CodecChoice::Gbatc {
            VERSION2
        } else {
            VERSION3
        }
    }

    fn stream_layout(&self, field: &FieldSpec) -> StreamLayout {
        StreamLayout {
            nt: field.nt,
            ns: field.ns,
            kt_window: self.plan.kt_window,
            n_shards: self.plan.len(),
            version: self.stream_version(),
        }
    }

    /// The header the archive will seal with (modulo the final
    /// model-byte charge) — recorded provisionally in the stream journal
    /// so `gbatc repair` can seal an orphaned unsealed stream without
    /// the writing session.
    fn provisional_header(&self, field: &FieldSpec) -> Gba2Header {
        Gba2Header {
            tcn_used: self.opts.use_tcn,
            dims: (field.nt, field.ns, field.ny, field.nx),
            block: self.block,
            latent_dim: self.latent_dim,
            kt_window: self.plan.kt_window,
            pressure: field.pressure,
            nrmse_target: self.ctx.max_target(),
            model_param_bytes: self.model_bytes_full as u64,
            ranges: field.ranges.clone(),
        }
    }
}

impl<W: StreamSink> CompressSession<W> {
    fn start(
        service: Option<ExecService>,
        handle: ExecHandle,
        decoder_params: usize,
        tcn_params: usize,
        builder: &CompressorBuilder,
        field: FieldSpec,
        sink: W,
    ) -> Result<CompressSession<W>> {
        let prep = SessionPrep::new(builder, &handle, decoder_params, tcn_params, &field)?;
        let sink = if prep.opts.codec == CodecChoice::Auto {
            SinkState::Deferred(sink)
        } else {
            SinkState::Stream(Gba2StreamWriter::new_with_header(
                sink,
                prep.stream_layout(&field),
                &prep.provisional_header(&field),
            )?)
        };
        Ok(Self::from_parts(
            service,
            handle,
            decoder_params,
            tcn_params,
            prep,
            field,
            sink,
            0,
            0,
            ShardTotals::default(),
        ))
    }

    /// See [`CompressorBuilder::resume_session`].
    fn resume(
        service: Option<ExecService>,
        handle: ExecHandle,
        decoder_params: usize,
        tcn_params: usize,
        builder: &CompressorBuilder,
        field: FieldSpec,
        sink: W,
    ) -> Result<(CompressSession<W>, ResumeReport)>
    where
        W: Read,
    {
        if builder.opts.codec == CodecChoice::Auto {
            return Err(Error::config(
                "cannot resume a --codec auto session: payload writes are deferred to \
                 finish, so an interrupted run leaves no durable shards",
            ));
        }
        let prep = SessionPrep::new(builder, &handle, decoder_params, tcn_params, &field)?;
        let (writer, report) = Gba2StreamWriter::resume(sink)?;
        let expect = prep.stream_layout(&field);
        if *writer.layout() != expect {
            return Err(Error::config(format!(
                "resume layout mismatch: journal {:?} vs configured {:?} — resume with \
                 the same field, kt_window, and codec as the interrupted run",
                writer.layout(),
                expect
            )));
        }
        let skip_t = writer.timesteps_written();
        let next_shard = writer.shards_written();
        let mut totals = ShardTotals::default();
        // the sealed header's model_param_bytes depends on whether *any*
        // section decodes through the model — including recovered ones
        totals.any_gbatc |= report.any_gbatc;
        let session = Self::from_parts(
            service,
            handle,
            decoder_params,
            tcn_params,
            prep,
            field,
            SinkState::Stream(writer),
            skip_t,
            next_shard,
            totals,
        );
        Ok((session, report))
    }

    #[allow(clippy::too_many_arguments)]
    fn from_parts(
        service: Option<ExecService>,
        handle: ExecHandle,
        decoder_params: usize,
        tcn_params: usize,
        prep: SessionPrep,
        field: FieldSpec,
        sink: SinkState<W>,
        skip_t: usize,
        next_shard: usize,
        totals: ShardTotals,
    ) -> CompressSession<W> {
        CompressSession {
            _service: service,
            handle,
            decoder_params,
            tcn_params,
            opts: prep.opts,
            ctx: prep.ctx,
            field,
            plan: prep.plan,
            sink,
            window: prep.window,
            w_fill: 0,
            t_pushed: 0,
            skip_t,
            next_shard,
            poisoned: false,
            pending: Vec::new(),
            totals,
            meter: WorkspaceMeter::new(),
            clock: StageClock::new(),
            progress: Progress::new(),
        }
    }

    /// The field this session was opened for.
    pub fn field(&self) -> &FieldSpec {
        &self.field
    }

    /// Timesteps received so far.
    pub fn timesteps_pushed(&self) -> usize {
        self.t_pushed
    }

    /// Shards fully compressed so far (including shards a resumed
    /// session recovered from the journal).
    pub fn shards_compressed(&self) -> usize {
        self.next_shard
    }

    /// Leading timesteps this session discards because they are already
    /// durable in the resumed stream; 0 for a fresh session.
    pub fn timesteps_skipped(&self) -> usize {
        self.skip_t
    }

    /// Hand over one `[S, Y, X]` timestep.  When the buffered window
    /// reaches `kt_window` timesteps it is compressed and (single-codec
    /// policies) written out before this call returns.
    pub fn push_timestep(&mut self, frame: &[f32]) -> Result<()> {
        self.check_poisoned()?;
        let stride = self.field.timestep_len();
        if frame.len() != stride {
            return Err(Error::shape(format!(
                "timestep frame has {} values, field expects {stride} ([S, Y, X] = [{}, {}, {}])",
                frame.len(),
                self.field.ns,
                self.field.ny,
                self.field.nx
            )));
        }
        if self.t_pushed == self.field.nt {
            return Err(Error::shape(format!(
                "session already received all {} timesteps",
                self.field.nt
            )));
        }
        if self.t_pushed < self.skip_t {
            // resumed session: this timestep is already inside a durable
            // shard recovered from the journal — count it and move on
            self.t_pushed += 1;
            return Ok(());
        }
        let off = self.w_fill * stride;
        self.window[off..off + stride].copy_from_slice(frame);
        self.w_fill += 1;
        self.t_pushed += 1;
        if self.w_fill == self.plan.window(self.next_shard).nt {
            // a failed flush leaves the stream inconsistent — poison the
            // session so later pushes get a typed error, not a panic
            if let Err(e) = self.flush_window() {
                self.poisoned = true;
                return Err(e);
            }
        }
        Ok(())
    }

    /// Typed guard for every entry point after a failed flush.
    fn check_poisoned(&self) -> Result<()> {
        if self.poisoned {
            return Err(Error::runtime(
                "session unusable after an earlier failure (discard it and start over)",
            ));
        }
        Ok(())
    }

    /// Push `k` consecutive timesteps from one contiguous
    /// `[k, S, Y, X]` buffer.
    pub fn push_timesteps(&mut self, frames: &[f32]) -> Result<()> {
        let stride = self.field.timestep_len();
        if stride == 0 || frames.len() % stride != 0 {
            return Err(Error::shape(format!(
                "{} values is not a whole number of {stride}-value timesteps",
                frames.len()
            )));
        }
        for frame in frames.chunks_exact(stride) {
            self.push_timestep(frame)?;
        }
        Ok(())
    }

    /// Feed an in-memory dataset timestep-by-timestep (the one-shot
    /// convenience path; dims must match the session's field).
    pub fn push_dataset(&mut self, ds: &Dataset) -> Result<()> {
        if (ds.nt, ds.ns, ds.ny, ds.nx)
            != (self.field.nt, self.field.ns, self.field.ny, self.field.nx)
        {
            return Err(Error::shape(format!(
                "dataset {}x{}x{}x{} does not match the session field {}x{}x{}x{}",
                ds.nt, ds.ns, ds.ny, ds.nx, self.field.nt, self.field.ns, self.field.ny,
                self.field.nx
            )));
        }
        self.push_timesteps(&ds.mass)
    }

    /// Compress the buffered window through the shared shard path.
    fn flush_window(&mut self) -> Result<()> {
        let w = self.plan.window(self.next_shard);
        let stride = self.field.timestep_len();
        let stage = {
            let engine = ShardEngine::new(&self.handle, self.decoder_params, self.tcn_params);
            // the buffered window is live working memory during the pass
            let _window_charge = self.meter.charge(self.window.len() * 4);
            engine.shard_stage(
                &self.ctx,
                &self.window[..w.nt * stride],
                w.t0,
                w.nt,
                &self.meter,
                &self.clock,
                &self.progress,
            )?
        };
        match stage {
            ShardStage::Final(out) => {
                match &mut self.sink {
                    SinkState::Stream(writer) => writer.write_shard(&out.payload)?,
                    SinkState::Deferred(_) => {
                        return Err(Error::runtime(
                            "single-codec shard stage in a deferred (auto) session",
                        ))
                    }
                }
                self.totals.add(&out);
            }
            ShardStage::Trials(p) => self.pending.push(p),
        }
        self.next_shard += 1;
        self.w_fill = 0;
        Ok(())
    }

    /// Seal the archive: every declared timestep must have been pushed.
    /// For `--codec auto`, the archive-level planner resolves the
    /// deferred shards here, then all payloads stream out in one pass.
    ///
    /// Durability: the sealed bytes are flushed and synced
    /// ([`StreamSink::sync_durable`] — `fsync` for `File` sinks) before
    /// this returns, so `Ok` means the archive is on stable storage.
    pub fn finish(self) -> Result<CompressReport> {
        Ok(self.finish_into()?.0)
    }

    /// [`Self::finish`], additionally handing back the sink (useful for
    /// in-memory `Cursor` sinks).
    pub fn finish_into(self) -> Result<(CompressReport, W)> {
        self.check_poisoned()?;
        let CompressSession {
            handle,
            decoder_params,
            tcn_params,
            opts,
            ctx,
            field,
            plan,
            sink,
            t_pushed,
            pending,
            mut totals,
            meter,
            clock,
            progress,
            ..
        } = self;
        if t_pushed != field.nt {
            return Err(Error::shape(format!(
                "session received {} of {} timesteps at finish",
                t_pushed, field.nt
            )));
        }
        let model_bytes_full = model_param_bytes(
            decoder_params + if opts.use_tcn { tcn_params } else { 0 },
            opts.model_bytes_f32,
        );
        let spec = handle.spec();
        let make_header = |model_bytes: usize| Gba2Header {
            tcn_used: opts.use_tcn,
            dims: (field.nt, field.ns, field.ny, field.nx),
            block: (spec.block.0, spec.block.1, spec.block.2),
            latent_dim: spec.latent,
            kt_window: plan.kt_window,
            pressure: field.pressure,
            nrmse_target: ctx.max_target(),
            model_param_bytes: model_bytes as u64,
            ranges: field.ranges.clone(),
        };
        let (sink, summary, model_bytes) = match sink {
            SinkState::Stream(writer) => {
                // model parameters are charged only when some section
                // decodes through the model
                let model_bytes = if totals.any_gbatc { model_bytes_full } else { 0 };
                let (sink, summary) = writer.finish(&make_header(model_bytes))?;
                (sink, summary, model_bytes)
            }
            SinkState::Deferred(raw) => {
                // archive-global planning over the memoized candidates,
                // then stream the winning payloads out in one pass
                let mut outs = plan_trials(pending, model_bytes_full)?;
                outs.sort_by_key(|o| o.payload.t0);
                let mixed = outs
                    .iter()
                    .any(|o| o.payload.codecs.iter().any(|&c| c != CodecTag::Gbatc));
                let version = if mixed { VERSION3 } else { VERSION2 };
                let mut writer = Gba2StreamWriter::new(
                    raw,
                    StreamLayout {
                        nt: field.nt,
                        ns: field.ns,
                        kt_window: plan.kt_window,
                        n_shards: plan.len(),
                        version,
                    },
                )?;
                for o in outs {
                    totals.add(&o);
                    writer.write_shard(&o.payload)?;
                }
                let model_bytes = if totals.any_gbatc { model_bytes_full } else { 0 };
                let (sink, summary) = writer.finish(&make_header(model_bytes))?;
                (sink, summary, model_bytes)
            }
        };
        let report = CompressReport {
            dims: (field.nt, field.ns, field.ny, field.nx),
            kt_window: plan.kt_window,
            n_shards: plan.len(),
            archive_bytes: summary.bytes,
            version: summary.version,
            codec_totals: summary.codec_totals,
            model_param_bytes: model_bytes,
            breakdown: totals.breakdown(summary.bytes as usize, model_bytes),
            max_block_residual: totals.max_residual,
            tau: ctx.max_tau(),
            n_coeffs: totals.n_coeffs,
            peak_workspace_bytes: meter.peak_bytes(),
            stage_times: clock.snapshot(),
            elapsed_s: progress.elapsed_s(),
            progress_summary: progress.summary(),
        };
        Ok((report, sink))
    }
}
