//! IO-metered section source — the one counting wrapper behind
//! [`crate::api::ArchiveReader`] and `gbatc::store`'s mounted archives.
//!
//! Unlike the borrow-based [`CountingSource`](crate::archive::CountingSource)
//! (a test/bench helper), `MeteredSource` *owns* its inner source and
//! splits the counters into **header/TOC** reads and **payload section**
//! reads: every `read_at` that falls entirely inside the header + TOC
//! region is metered separately from section reads, so savings reports
//! can show what a query paid for indexing versus data.  The split point
//! is the first payload byte ([`MeteredSource::set_header_limit`], set
//! once the TOC has been parsed); until then every read counts as a
//! header read — which is exactly what reads before the TOC is known are.

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::archive::toc::SectionSource;
use crate::error::Result;

/// Snapshot of a [`MeteredSource`]'s counters.  `toc_*` covers
/// header/TOC reads (including the re-read each ranged decode performs);
/// `payload_*` covers section (latent + species) reads.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct IoStats {
    pub toc_reads: u64,
    pub toc_bytes: u64,
    pub payload_reads: u64,
    pub payload_bytes: u64,
    /// Reads served by a memory-mapped source (zero-copy page-cache
    /// borrows rather than `read(2)` into fresh buffers).  Always a
    /// subset of the toc/payload totals above — mmap-backed sources
    /// still classify every read — so `mmap_bytes == bytes()` means the
    /// whole archive was served without a syscall per section.
    pub mmap_reads: u64,
    /// Bytes served by the memory-mapped path (see [`IoStats::mmap_reads`]).
    pub mmap_bytes: u64,
}

impl IoStats {
    /// All ranged reads served.
    pub fn reads(&self) -> u64 {
        self.toc_reads + self.payload_reads
    }

    /// All bytes served — header/TOC *and* payload.
    pub fn bytes(&self) -> u64 {
        self.toc_bytes + self.payload_bytes
    }
}

impl fmt::Display for IoStats {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "toc {} B in {} reads | payload {} B in {} reads | mmap {} B in {} reads",
            self.toc_bytes,
            self.toc_reads,
            self.payload_bytes,
            self.payload_reads,
            self.mmap_bytes,
            self.mmap_reads
        )
    }
}

/// Owning section source with always-on, classified IO counters.
pub struct MeteredSource {
    inner: Box<dyn SectionSource + Send + Sync>,
    /// First payload byte; reads ending at or below it are header/TOC
    /// reads.  Starts at `u64::MAX` (everything before the TOC is parsed
    /// *is* a header read).
    header_limit: AtomicU64,
    /// True when `inner` is a memory-mapped source: every read is also
    /// charged to the mmap counters.
    mapped: bool,
    toc_reads: AtomicU64,
    toc_bytes: AtomicU64,
    payload_reads: AtomicU64,
    payload_bytes: AtomicU64,
    mmap_reads: AtomicU64,
    mmap_bytes: AtomicU64,
}

impl MeteredSource {
    pub fn new(inner: Box<dyn SectionSource + Send + Sync>) -> MeteredSource {
        Self::with_mapped(inner, false)
    }

    /// Like [`Self::new`] for a memory-mapped inner source (e.g.
    /// [`crate::archive::MmapSource`]): reads are additionally charged
    /// to [`IoStats::mmap_reads`]/[`IoStats::mmap_bytes`] so the
    /// zero-copy path is observable in `inspect --stats`.
    pub fn new_mapped(inner: Box<dyn SectionSource + Send + Sync>) -> MeteredSource {
        Self::with_mapped(inner, true)
    }

    fn with_mapped(inner: Box<dyn SectionSource + Send + Sync>, mapped: bool) -> MeteredSource {
        MeteredSource {
            inner,
            header_limit: AtomicU64::new(u64::MAX),
            mapped,
            toc_reads: AtomicU64::new(0),
            toc_bytes: AtomicU64::new(0),
            payload_reads: AtomicU64::new(0),
            payload_bytes: AtomicU64::new(0),
            mmap_reads: AtomicU64::new(0),
            mmap_bytes: AtomicU64::new(0),
        }
    }

    /// Whether the inner source is memory-mapped.
    pub fn is_mapped(&self) -> bool {
        self.mapped
    }

    /// Record where the payload region begins (the first shard's offset)
    /// so later reads classify exactly.
    pub fn set_header_limit(&self, first_payload_byte: u64) {
        self.header_limit
            .store(first_payload_byte, Ordering::Relaxed);
    }

    /// Charge an out-of-band payload load (e.g. the whole-file read a
    /// legacy `GBA1` conversion performs before this wrapper sees bytes).
    pub fn add_payload(&self, reads: u64, bytes: u64) {
        self.payload_reads.fetch_add(reads, Ordering::Relaxed);
        self.payload_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    /// Charge an out-of-band header probe (e.g. a magic sniff performed
    /// on the raw file before wrapping it).
    pub fn add_toc(&self, reads: u64, bytes: u64) {
        self.toc_reads.fetch_add(reads, Ordering::Relaxed);
        self.toc_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    pub fn stats(&self) -> IoStats {
        IoStats {
            toc_reads: self.toc_reads.load(Ordering::Relaxed),
            toc_bytes: self.toc_bytes.load(Ordering::Relaxed),
            payload_reads: self.payload_reads.load(Ordering::Relaxed),
            payload_bytes: self.payload_bytes.load(Ordering::Relaxed),
            mmap_reads: self.mmap_reads.load(Ordering::Relaxed),
            mmap_bytes: self.mmap_bytes.load(Ordering::Relaxed),
        }
    }

    /// Zero every counter (e.g. to meter one query in isolation).
    pub fn reset(&self) {
        self.toc_reads.store(0, Ordering::Relaxed);
        self.toc_bytes.store(0, Ordering::Relaxed);
        self.payload_reads.store(0, Ordering::Relaxed);
        self.payload_bytes.store(0, Ordering::Relaxed);
        self.mmap_reads.store(0, Ordering::Relaxed);
        self.mmap_bytes.store(0, Ordering::Relaxed);
    }
}

impl SectionSource for MeteredSource {
    fn read_at(&self, off: u64, len: usize) -> Result<Vec<u8>> {
        let out = self.inner.read_at(off, len)?;
        let end = off.saturating_add(out.len() as u64);
        if end <= self.header_limit.load(Ordering::Relaxed) {
            self.toc_reads.fetch_add(1, Ordering::Relaxed);
            self.toc_bytes.fetch_add(out.len() as u64, Ordering::Relaxed);
        } else {
            self.payload_reads.fetch_add(1, Ordering::Relaxed);
            self.payload_bytes
                .fetch_add(out.len() as u64, Ordering::Relaxed);
        }
        if self.mapped {
            self.mmap_reads.fetch_add(1, Ordering::Relaxed);
            self.mmap_bytes.fetch_add(out.len() as u64, Ordering::Relaxed);
        }
        Ok(out)
    }

    fn source_len(&self) -> u64 {
        self.inner.source_len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::archive::toc::MemSource;

    #[test]
    fn reads_classify_by_header_limit() {
        let src = MeteredSource::new(Box::new(MemSource(vec![0u8; 100])));
        // before the limit is known everything is a header read
        src.read_at(0, 10).unwrap();
        src.set_header_limit(20);
        src.read_at(0, 20).unwrap(); // ends exactly at the limit -> toc
        src.read_at(20, 30).unwrap(); // payload
        src.read_at(4, 60).unwrap(); // crosses the limit -> payload
        let s = src.stats();
        assert_eq!((s.toc_reads, s.toc_bytes), (2, 30));
        assert_eq!((s.payload_reads, s.payload_bytes), (2, 90));
        assert_eq!(s.reads(), 4);
        assert_eq!(s.bytes(), 120);
        src.add_payload(1, 5);
        src.add_toc(1, 2);
        assert_eq!(src.stats().bytes(), 127);
        src.reset();
        assert_eq!(src.stats(), IoStats::default());
    }

    #[test]
    fn mapped_sources_charge_the_mmap_counters() {
        let src = MeteredSource::new_mapped(Box::new(MemSource(vec![0u8; 64])));
        assert!(src.is_mapped());
        src.set_header_limit(16);
        src.read_at(0, 16).unwrap(); // toc + mmap
        src.read_at(16, 40).unwrap(); // payload + mmap
        let s = src.stats();
        assert_eq!((s.mmap_reads, s.mmap_bytes), (2, 56));
        assert_eq!(s.mmap_bytes, s.bytes(), "every read was mmap-served");

        let plain = MeteredSource::new(Box::new(MemSource(vec![0u8; 64])));
        assert!(!plain.is_mapped());
        plain.read_at(0, 16).unwrap();
        assert_eq!(plain.stats().mmap_reads, 0);
    }
}
