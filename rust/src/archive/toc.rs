//! The indexed `GBA2` archive: a versioned container with a table of
//! contents mapping every (shard, species) payload to an absolute byte
//! range, enabling random-access partial decode.
//!
//! ```text
//! off  0  magic "GBA2" | version u16 (2 or 3) | flags u16 (bit0: TCN used)
//!      8  nt ns ny nx           u32 x4
//!     24  block kt by bx        u32 x3
//!     36  latent                u32
//!     40  kt_window             u32
//!     44  n_shards              u32
//!     48  pressure f64 | nrmse_target f64 | model_param_bytes u64
//!     72  per-species ranges: ns x (lo f32, hi f32)
//!      .  TOC: n_shards x { t0 u32, nt u32, shard (off,len) u64 x2,
//!                           latent (off,len) u64 x2,
//!                           ns x species (off,len) u64 x2,
//!                           [version 3 only] ns x codec tag u8 }
//!      .  shard payloads, contiguous: latent blob, then the ns
//!         species sections (GBATC sections: same bytes as GBA1)
//! ```
//!
//! All offsets are absolute file offsets, so a reader can fetch the TOC
//! with two `read_at` calls and then touch only the sections a query
//! needs.  `GBA1` archives convert losslessly in both directions
//! ([`Gba2Archive::from_v1`] / [`Gba2Archive::to_v1`]); the section bytes
//! are identical between versions.
//!
//! **Mixed-codec archives** ([`CodecTag`]): version 3 records which codec
//! stage encoded every (shard, species) section.  Archives whose sections
//! are all GBATC (tag 0) serialize as version 2, byte-identical to the
//! pre-registry format, so existing readers keep working; any other tag
//! bumps the container to version 3.  Tags are validated while parsing
//! the TOC — a corrupt tag is rejected before any section is decoded.

use std::fs::File;
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::archive::format::{Archive, SpeciesSection};
use crate::error::{Error, Result};
use crate::util::bytes::{ByteReader, ByteWriter};

pub const MAGIC2: &[u8; 4] = b"GBA2";
pub(crate) const VERSION2: u16 = 2;
pub(crate) const VERSION3: u16 = 3;

/// Bytes of the fixed prefix through `n_shards` — enough to size the rest
/// of the header + TOC.
const PREFIX_LEN: usize = 48;

/// Which codec stage encoded one (shard, species) section.
///
/// Tag 0 is the classic GBATC payload (PCA basis + guarantee
/// coefficients refining the shard's shared latent plane); tags 1 and 2
/// are self-contained stages that need no latent plane.  The numeric
/// values are the on-disk encoding in the version-3 TOC.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum CodecTag {
    /// AE latents + TCN + per-species PCA guarantee (basis + coeffs).
    Gbatc = 0,
    /// SZ predictor pipeline on the normalized section plane.
    Sz = 1,
    /// Dense uniform-quantized plane (bit-packed; the fallback stage).
    Dense = 2,
}

impl CodecTag {
    pub const ALL: [CodecTag; 3] = [CodecTag::Gbatc, CodecTag::Sz, CodecTag::Dense];

    pub fn from_u8(v: u8) -> Result<CodecTag> {
        match v {
            0 => Ok(CodecTag::Gbatc),
            1 => Ok(CodecTag::Sz),
            2 => Ok(CodecTag::Dense),
            _ => Err(Error::format(format!("unknown codec tag {v}"))),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            CodecTag::Gbatc => "GBATC",
            CodecTag::Sz => "SZ",
            CodecTag::Dense => "DENSE",
        }
    }

    /// One-letter abbreviation for compact TOC listings.
    pub fn letter(self) -> char {
        match self {
            CodecTag::Gbatc => 'G',
            CodecTag::Sz => 'S',
            CodecTag::Dense => 'D',
        }
    }
}

/// Everything global to a `GBA2` archive (no payload).
#[derive(Clone, Debug)]
pub struct Gba2Header {
    pub tcn_used: bool,
    /// nt, ns, ny, nx.
    pub dims: (usize, usize, usize, usize),
    pub block: (usize, usize, usize),
    pub latent_dim: usize,
    /// Shard time-window width (timesteps; last shard may be shorter).
    pub kt_window: usize,
    pub pressure: f64,
    pub nrmse_target: f64,
    /// Bytes charged for model parameters (accounting; not stored inline).
    pub model_param_bytes: u64,
    pub ranges: Vec<(f32, f32)>,
}

/// One shard's TOC entry: absolute byte ranges of its payloads.
#[derive(Clone, Debug)]
pub struct ShardToc {
    pub t0: usize,
    pub nt: usize,
    /// Whole shard span (latent + species sections, contiguous).
    pub shard: (u64, u64),
    /// Latent-plane blob (may be empty when no section is GBATC).
    pub latent: (u64, u64),
    /// Per-species sections.
    pub species: Vec<(u64, u64)>,
    /// Codec stage of each species section (all GBATC in version 2).
    pub codecs: Vec<CodecTag>,
}

/// Input to [`Gba2Archive::build`]: one shard's serialized payloads.
#[derive(Clone, Debug)]
pub struct ShardPayload {
    pub t0: usize,
    pub nt: usize,
    pub latent_blob: Vec<u8>,
    /// Serialized section bytes, one per species ([`SpeciesSection`] for
    /// GBATC sections; the stage's own format otherwise).
    pub species: Vec<Vec<u8>>,
    /// Codec stage of each species section.
    pub codecs: Vec<CodecTag>,
}

impl ShardPayload {
    /// An all-GBATC shard (the classic payload shape).
    pub fn gbatc(t0: usize, nt: usize, latent_blob: Vec<u8>, species: Vec<Vec<u8>>) -> Self {
        let codecs = vec![CodecTag::Gbatc; species.len()];
        Self {
            t0,
            nt,
            latent_blob,
            species,
            codecs,
        }
    }
}

/// An in-memory `GBA2` archive: parsed header + TOC over the full
/// serialized bytes.
#[derive(Clone, Debug)]
pub struct Gba2Archive {
    pub header: Gba2Header,
    pub toc: Vec<ShardToc>,
    /// The complete serialized archive (header + TOC + payloads).
    pub bytes: Vec<u8>,
}

fn header_len(ns: usize, n_shards: usize, version: u16) -> usize {
    // v3 appends one codec-tag byte per species to every TOC entry
    let entry = 40 + 16 * ns + if version >= VERSION3 { ns } else { 0 };
    72 + ns * 8 + n_shards * entry
}

/// Absolute byte offset of the codec tag of (shard, species) in a
/// version-3 container — derived from the same layout arithmetic the
/// writer and parser use, so corruption tests target the right byte.
pub fn codec_tag_offset(ns: usize, shard: usize, species: usize) -> usize {
    // start of the entry = end of the header + `shard` full v3 entries;
    // tags sit after the fixed fields and the ns (off, len) pairs
    header_len(ns, shard, VERSION3) + 40 + 16 * ns + species
}

/// Header + TOC size in bytes for `ns` species and `n_shards` shards —
/// where the first payload byte lands.  Exposed for the incremental
/// writer, which must reserve this region before any payload is written.
pub(crate) fn header_toc_len(ns: usize, n_shards: usize, version: u16) -> usize {
    header_len(ns, n_shards, version)
}

/// Serialize the header + TOC prefix (everything before the payloads).
/// Both [`Gba2Archive::build`] and the incremental
/// [`crate::archive::stream::Gba2StreamWriter`] emit their prefix through
/// this one function, so streamed archives are byte-identical to
/// batch-built ones.
pub(crate) fn write_header_toc(
    w: &mut ByteWriter,
    header: &Gba2Header,
    toc: &[ShardToc],
    version: u16,
) {
    w.bytes(MAGIC2);
    w.u16(version);
    w.u16(if header.tcn_used { 1 } else { 0 });
    for d in [header.dims.0, header.dims.1, header.dims.2, header.dims.3] {
        w.u32(d as u32);
    }
    for d in [header.block.0, header.block.1, header.block.2] {
        w.u32(d as u32);
    }
    w.u32(header.latent_dim as u32);
    w.u32(header.kt_window as u32);
    w.u32(toc.len() as u32);
    w.f64(header.pressure);
    w.f64(header.nrmse_target);
    w.u64(header.model_param_bytes);
    for &(lo, hi) in &header.ranges {
        w.f32(lo);
        w.f32(hi);
    }
    for entry in toc {
        w.u32(entry.t0 as u32);
        w.u32(entry.nt as u32);
        w.u64(entry.shard.0);
        w.u64(entry.shard.1);
        w.u64(entry.latent.0);
        w.u64(entry.latent.1);
        for &(o, l) in &entry.species {
            w.u64(o);
            w.u64(l);
        }
        if version >= VERSION3 {
            for &c in &entry.codecs {
                w.u8(c as u8);
            }
        }
    }
}

impl Gba2Archive {
    /// Assemble an archive from per-shard payloads.  Shards must tile the
    /// time axis in order.
    pub fn build(header: Gba2Header, shards: Vec<ShardPayload>) -> Result<Gba2Archive> {
        let (nt, ns, _, _) = header.dims;
        if shards.is_empty() {
            return Err(Error::format("GBA2 build: no shards"));
        }
        if header.ranges.len() != ns {
            return Err(Error::format(format!(
                "GBA2 build: {} ranges for {ns} species",
                header.ranges.len()
            )));
        }
        let mut expect_t0 = 0usize;
        for (i, sh) in shards.iter().enumerate() {
            // uniform windows (last may be short) — the invariant
            // ShardPlan::touching and the TOC index both rely on
            let full = i + 1 < shards.len();
            if sh.t0 != expect_t0
                || sh.nt == 0
                || sh.nt > header.kt_window
                || (full && sh.nt != header.kt_window)
            {
                return Err(Error::format(format!(
                    "GBA2 build: shard at t0 {} (nt {}) does not tile (expected t0 {expect_t0})",
                    sh.t0, sh.nt
                )));
            }
            if sh.species.len() != ns || sh.codecs.len() != ns {
                return Err(Error::format(format!(
                    "GBA2 build: shard at t0 {} has {} species sections and {} codec tags, expected {ns}",
                    sh.t0,
                    sh.species.len(),
                    sh.codecs.len()
                )));
            }
            expect_t0 += sh.nt;
        }
        if expect_t0 != nt {
            return Err(Error::format(format!(
                "GBA2 build: shards cover {expect_t0} of {nt} timesteps"
            )));
        }

        // all-GBATC archives stay on version 2 — byte-identical to the
        // pre-registry container, so old readers keep working
        let mixed = shards
            .iter()
            .any(|sh| sh.codecs.iter().any(|&c| c != CodecTag::Gbatc));
        let version = if mixed { VERSION3 } else { VERSION2 };

        let base = header_len(ns, shards.len(), version) as u64;
        let mut toc = Vec::with_capacity(shards.len());
        let mut off = base;
        for sh in &shards {
            let shard_off = off;
            let latent = (off, sh.latent_blob.len() as u64);
            off += latent.1;
            let mut species = Vec::with_capacity(ns);
            for sec in &sh.species {
                species.push((off, sec.len() as u64));
                off += sec.len() as u64;
            }
            toc.push(ShardToc {
                t0: sh.t0,
                nt: sh.nt,
                shard: (shard_off, off - shard_off),
                latent,
                species,
                codecs: sh.codecs.clone(),
            });
        }

        let mut w = ByteWriter::new();
        write_header_toc(&mut w, &header, &toc, version);
        debug_assert_eq!(w.buf.len() as u64, base);
        for sh in &shards {
            w.bytes(&sh.latent_blob);
            for sec in &sh.species {
                w.bytes(sec);
            }
        }
        let bytes = w.finish();
        debug_assert_eq!(bytes.len() as u64, off);
        Ok(Gba2Archive { header, toc, bytes })
    }

    /// Parse a complete serialized archive.
    pub fn deserialize(buf: &[u8]) -> Result<Gba2Archive> {
        let (header, toc) = parse_header_toc(buf, buf.len() as u64)?;
        Ok(Gba2Archive {
            header,
            toc,
            bytes: buf.to_vec(),
        })
    }

    /// Read only the header + TOC from a byte-range source (two reads).
    pub fn read_toc<S: SectionSource + ?Sized>(src: &S) -> Result<(Gba2Header, Vec<ShardToc>)> {
        let prefix = src.read_at(0, PREFIX_LEN)?;
        let (version, ns, n_shards) = parse_prefix(&prefix)?;
        let hlen = header_len(ns, n_shards, version);
        let head = src.read_at(0, hlen)?;
        parse_header_toc(&head, src.source_len())
    }

    pub fn n_shards(&self) -> usize {
        self.toc.len()
    }

    /// Container version this archive serializes as: 2 when every section
    /// is GBATC (pre-registry byte layout), 3 otherwise.
    pub fn version(&self) -> u16 {
        let mixed = self
            .toc
            .iter()
            .any(|e| e.codecs.iter().any(|&c| c != CodecTag::Gbatc));
        if mixed {
            VERSION3
        } else {
            VERSION2
        }
    }

    /// Per-codec totals across the TOC, indexed by `CodecTag as usize`:
    /// (number of sections, section bytes).
    pub fn codec_totals(&self) -> [(usize, u64); 3] {
        let mut totals = [(0usize, 0u64); 3];
        for entry in &self.toc {
            for (&(_, len), &tag) in entry.species.iter().zip(&entry.codecs) {
                let slot = &mut totals[tag as usize];
                slot.0 += 1;
                slot.1 += len;
            }
        }
        totals
    }

    fn section(&self, range: (u64, u64), what: &str) -> Result<&[u8]> {
        let off = range.0 as usize;
        let len = range.1 as usize;
        self.bytes
            .get(off..off + len)
            .ok_or_else(|| Error::format(format!("GBA2 {what} section out of bounds")))
    }

    /// Raw latent-plane blob of one shard.
    pub fn latent_bytes(&self, shard: usize) -> Result<&[u8]> {
        let entry = self
            .toc
            .get(shard)
            .ok_or_else(|| Error::format(format!("no shard {shard}")))?;
        self.section(entry.latent, "latent")
    }

    /// Raw serialized species section of one (shard, species).
    pub fn species_bytes(&self, shard: usize, s: usize) -> Result<&[u8]> {
        let entry = self
            .toc
            .get(shard)
            .ok_or_else(|| Error::format(format!("no shard {shard}")))?;
        let range = *entry
            .species
            .get(s)
            .ok_or_else(|| Error::format(format!("no species {s} in shard {shard}")))?;
        self.section(range, "species")
    }

    /// Parse all species sections of one shard as GBATC payloads (errors
    /// with a clear message on sections encoded by other codec stages).
    pub fn species_sections(&self, shard: usize) -> Result<Vec<SpeciesSection>> {
        let ns = self.header.dims.1;
        let mut out = Vec::with_capacity(ns);
        for s in 0..ns {
            if let Some(entry) = self.toc.get(shard) {
                if entry.codecs.get(s).copied() != Some(CodecTag::Gbatc) {
                    return Err(Error::format(format!(
                        "shard {shard} species {s} is not a GBATC section"
                    )));
                }
            }
            out.push(SpeciesSection::from_bytes(self.species_bytes(shard, s)?)?);
        }
        Ok(out)
    }

    pub fn serialize(&self) -> Vec<u8> {
        self.bytes.clone()
    }

    /// Consume the archive, returning the serialized bytes without a copy.
    pub fn into_bytes(self) -> Vec<u8> {
        self.bytes
    }

    pub fn write_file<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        File::create(path)?.write_all(&self.bytes)?;
        Ok(())
    }

    pub fn read_file<P: AsRef<Path>>(path: P) -> Result<Gba2Archive> {
        let mut bytes = Vec::new();
        File::open(path.as_ref())?.read_to_end(&mut bytes)?;
        Self::deserialize(&bytes)
    }

    /// Stored payload bytes (the archive itself).
    pub fn payload_bytes(&self) -> usize {
        self.bytes.len()
    }

    /// Payload + model-parameter bytes (the paper charges network
    /// parameters to the compressed output).
    pub fn total_bytes(&self) -> usize {
        self.bytes.len() + self.header.model_param_bytes as usize
    }

    /// Compression ratio against the raw PD bytes.
    pub fn compression_ratio(&self) -> f64 {
        let (nt, ns, ny, nx) = self.header.dims;
        (nt * ns * ny * nx * 4) as f64 / self.total_bytes() as f64
    }

    /// Wrap a legacy single-shot `GBA1` archive as a one-shard `GBA2`
    /// (section bytes are shared verbatim between the formats).
    pub fn from_v1(a: &Archive) -> Result<Gba2Archive> {
        let header = Gba2Header {
            tcn_used: a.tcn_used,
            dims: a.dims,
            block: a.block,
            latent_dim: a.latent_dim,
            kt_window: a.dims.0,
            pressure: a.pressure,
            nrmse_target: a.nrmse_target,
            model_param_bytes: a.model_param_bytes,
            ranges: a.ranges.clone(),
        };
        let shard = ShardPayload::gbatc(
            0,
            a.dims.0,
            a.latent_blob.clone(),
            a.species.iter().map(|s| s.to_bytes()).collect(),
        );
        Self::build(header, vec![shard])
    }

    /// Export as legacy `GBA1` — only possible for single-shard, all-GBATC
    /// archives (compress with `kt_window >= nt` and the default codec).
    pub fn to_v1(&self) -> Result<Archive> {
        if self.toc.len() != 1 {
            return Err(Error::format(format!(
                "GBA1 export needs a single shard, archive has {} (compress with kt_window >= nt)",
                self.toc.len()
            )));
        }
        if self.version() != VERSION2 {
            return Err(Error::format(
                "GBA1 export needs all-GBATC sections (compress with --codec gbatc)",
            ));
        }
        Ok(Archive {
            tcn_used: self.header.tcn_used,
            dims: self.header.dims,
            block: self.header.block,
            latent_dim: self.header.latent_dim,
            pressure: self.header.pressure,
            ranges: self.header.ranges.clone(),
            latent_blob: self.latent_bytes(0)?.to_vec(),
            species: self.species_sections(0)?,
            model_param_bytes: self.header.model_param_bytes,
            nrmse_target: self.header.nrmse_target,
        })
    }
}

/// Parse just enough of the fixed prefix to size the header + TOC.
fn parse_prefix(buf: &[u8]) -> Result<(u16, usize, usize)> {
    let mut r = ByteReader::new(buf);
    let magic = r.bytes(4)?;
    if magic != MAGIC2 {
        return Err(Error::format(format!("bad GBA2 magic {magic:?}")));
    }
    let version = r.u16()?;
    if version != VERSION2 && version != VERSION3 {
        return Err(Error::format(format!("unsupported GBA2 version {version}")));
    }
    let _flags = r.u16()?;
    let _nt = r.u32()?;
    let ns = r.u32()? as usize;
    if ns == 0 || ns > 4096 {
        return Err(Error::format(format!("implausible species count {ns}")));
    }
    let _ny = r.u32()?;
    let _nx = r.u32()?;
    let _block = (r.u32()?, r.u32()?, r.u32()?);
    let _latent = r.u32()?;
    let _kt_window = r.u32()?;
    let n_shards = r.u32()? as usize;
    if n_shards == 0 || n_shards > 1 << 20 {
        return Err(Error::format(format!("implausible shard count {n_shards}")));
    }
    Ok((version, ns, n_shards))
}

/// Full header + TOC parse with structural validation against `file_len`.
fn parse_header_toc(buf: &[u8], file_len: u64) -> Result<(Gba2Header, Vec<ShardToc>)> {
    let (version, ns, n_shards) = parse_prefix(buf)?;
    let hlen = header_len(ns, n_shards, version) as u64;
    if hlen > file_len {
        return Err(Error::format(format!(
            "GBA2 truncated: header + TOC need {hlen} bytes, file has {file_len}"
        )));
    }
    let mut r = ByteReader::new(buf);
    r.bytes(4)?; // magic
    r.u16()?; // version
    let flags = r.u16()?;
    let dims = (
        r.u32()? as usize,
        r.u32()? as usize,
        r.u32()? as usize,
        r.u32()? as usize,
    );
    let block = (r.u32()? as usize, r.u32()? as usize, r.u32()? as usize);
    let latent_dim = r.u32()? as usize;
    let kt_window = r.u32()? as usize;
    let _n_shards = r.u32()?;
    let pressure = r.f64()?;
    let nrmse_target = r.f64()?;
    let model_param_bytes = r.u64()?;

    let total = dims
        .0
        .checked_mul(dims.1)
        .and_then(|v| v.checked_mul(dims.2))
        .and_then(|v| v.checked_mul(dims.3))
        .ok_or_else(|| Error::format("GBA2 dims overflow"))?;
    if total == 0 || total > 1 << 33 {
        return Err(Error::format(format!("implausible GBA2 dims {dims:?}")));
    }
    if block.0 == 0 || block.1 == 0 || block.2 == 0 || latent_dim == 0 || latent_dim > 65536 {
        return Err(Error::format(format!(
            "implausible GBA2 block/latent {block:?}/{latent_dim}"
        )));
    }
    if kt_window == 0 || kt_window % block.0 != 0 {
        return Err(Error::format(format!(
            "GBA2 kt_window {kt_window} not a multiple of block kt {}",
            block.0
        )));
    }

    let mut ranges = Vec::with_capacity(ns);
    for _ in 0..ns {
        ranges.push((r.f32()?, r.f32()?));
    }

    let mut toc = Vec::with_capacity(n_shards);
    let mut expect_t0 = 0usize;
    let mut expect_off = hlen;
    for i in 0..n_shards {
        let t0 = r.u32()? as usize;
        let nt_sh = r.u32()? as usize;
        let shard = (r.u64()?, r.u64()?);
        let latent = (r.u64()?, r.u64()?);
        let mut species = Vec::with_capacity(ns);
        for _ in 0..ns {
            species.push((r.u64()?, r.u64()?));
        }
        // codec tags are validated here, at TOC parse time — a corrupt
        // tag never reaches a section decoder
        let mut codecs = Vec::with_capacity(ns);
        if version >= VERSION3 {
            for _ in 0..ns {
                codecs.push(CodecTag::from_u8(r.u8()?)?);
            }
        } else {
            codecs.resize(ns, CodecTag::Gbatc);
        }
        // uniform windows, last may be short (ShardPlan's invariant)
        let full = i + 1 < n_shards;
        if t0 != expect_t0
            || nt_sh == 0
            || nt_sh > kt_window
            || nt_sh % block.0 != 0
            || (full && nt_sh != kt_window)
        {
            return Err(Error::format(format!(
                "GBA2 TOC: shard at t0 {t0} (nt {nt_sh}) does not tile (expected t0 {expect_t0})"
            )));
        }
        expect_t0 += nt_sh;
        // shard spans are contiguous from the end of the TOC
        if shard.0 != expect_off {
            return Err(Error::format(format!(
                "GBA2 TOC: shard offset {} != expected {expect_off}",
                shard.0
            )));
        }
        let shard_end = shard
            .0
            .checked_add(shard.1)
            .ok_or_else(|| Error::format("GBA2 TOC: shard span overflow"))?;
        if shard_end > file_len {
            return Err(Error::format(format!(
                "GBA2 TOC: shard end {shard_end} beyond file length {file_len}"
            )));
        }
        expect_off = shard_end;
        // latent + species sections must tile the shard span exactly
        let mut cursor = shard.0;
        for &(o, l) in std::iter::once(&latent).chain(species.iter()) {
            if o != cursor {
                return Err(Error::format(format!(
                    "GBA2 TOC: section offset {o} != expected {cursor}"
                )));
            }
            cursor = o
                .checked_add(l)
                .ok_or_else(|| Error::format("GBA2 TOC: section span overflow"))?;
        }
        if cursor != shard_end {
            return Err(Error::format(format!(
                "GBA2 TOC: sections cover {cursor} of shard end {shard_end}"
            )));
        }
        toc.push(ShardToc {
            t0,
            nt: nt_sh,
            shard,
            latent,
            species,
            codecs,
        });
    }
    if expect_t0 != dims.0 {
        return Err(Error::format(format!(
            "GBA2 TOC: shards cover {expect_t0} of {} timesteps",
            dims.0
        )));
    }
    if expect_off != file_len {
        return Err(Error::format(format!(
            "GBA2 payload ends at {expect_off}, file length is {file_len}"
        )));
    }

    Ok((
        Gba2Header {
            tcn_used: flags & 1 == 1,
            dims,
            block,
            latent_dim,
            kt_window,
            pressure,
            nrmse_target,
            model_param_bytes,
            ranges,
        },
        toc,
    ))
}

/// Lenient header + TOC parse for `gbatc repair`: header-level damage is
/// still fatal, but a torn payload tail is not — TOC entries are walked
/// in order and the walk *stops* (instead of erroring) at the first
/// entry that is malformed, breaks the tiling chain, or reaches beyond
/// `file_len`.  Returns the header, the structurally-valid shard
/// prefix, and the declared shard count, so callers can salvage the
/// prefix into a well-formed archive.
pub(crate) fn parse_header_toc_prefix(
    buf: &[u8],
    file_len: u64,
) -> Result<(Gba2Header, Vec<ShardToc>, usize)> {
    let (version, ns, n_shards) = parse_prefix(buf)?;
    let hlen = header_len(ns, n_shards, version) as u64;
    let mut r = ByteReader::new(buf);
    r.bytes(4)?; // magic
    r.u16()?; // version
    let flags = r.u16()?;
    let dims = (
        r.u32()? as usize,
        r.u32()? as usize,
        r.u32()? as usize,
        r.u32()? as usize,
    );
    let block = (r.u32()? as usize, r.u32()? as usize, r.u32()? as usize);
    let latent_dim = r.u32()? as usize;
    let kt_window = r.u32()? as usize;
    let _n_shards = r.u32()?;
    let pressure = r.f64()?;
    let nrmse_target = r.f64()?;
    let model_param_bytes = r.u64()?;
    let total = dims
        .0
        .checked_mul(dims.1)
        .and_then(|v| v.checked_mul(dims.2))
        .and_then(|v| v.checked_mul(dims.3))
        .ok_or_else(|| Error::format("GBA2 dims overflow"))?;
    if total == 0 || total > 1 << 33 {
        return Err(Error::format(format!("implausible GBA2 dims {dims:?}")));
    }
    if block.0 == 0 || block.1 == 0 || block.2 == 0 || latent_dim == 0 || latent_dim > 65536 {
        return Err(Error::format(format!(
            "implausible GBA2 block/latent {block:?}/{latent_dim}"
        )));
    }
    if kt_window == 0 || kt_window % block.0 != 0 {
        return Err(Error::format(format!(
            "GBA2 kt_window {kt_window} not a multiple of block kt {}",
            block.0
        )));
    }
    let mut ranges = Vec::with_capacity(ns);
    for _ in 0..ns {
        ranges.push((r.f32()?, r.f32()?));
    }

    let mut toc = Vec::with_capacity(n_shards);
    let mut expect_t0 = 0usize;
    let mut expect_off = hlen;
    'entries: for i in 0..n_shards {
        let parsed = (|r: &mut ByteReader| -> Result<ShardToc> {
            let t0 = r.u32()? as usize;
            let nt_sh = r.u32()? as usize;
            let shard = (r.u64()?, r.u64()?);
            let latent = (r.u64()?, r.u64()?);
            let mut species = Vec::with_capacity(ns);
            for _ in 0..ns {
                species.push((r.u64()?, r.u64()?));
            }
            let mut codecs = Vec::with_capacity(ns);
            if version >= VERSION3 {
                for _ in 0..ns {
                    codecs.push(CodecTag::from_u8(r.u8()?)?);
                }
            } else {
                codecs.resize(ns, CodecTag::Gbatc);
            }
            Ok(ShardToc {
                t0,
                nt: nt_sh,
                shard,
                latent,
                species,
                codecs,
            })
        })(&mut r);
        let entry = match parsed {
            Ok(e) => e,
            Err(_) => break, // TOC region itself truncated or rotted
        };
        let full = i + 1 < n_shards;
        if entry.t0 != expect_t0
            || entry.nt == 0
            || entry.nt > kt_window
            || entry.nt % block.0 != 0
            || (full && entry.nt != kt_window)
            || entry.shard.0 != expect_off
        {
            break;
        }
        let shard_end = match entry.shard.0.checked_add(entry.shard.1) {
            Some(e) if e <= file_len => e,
            _ => break, // payload torn off the end of the file
        };
        let mut cursor = entry.shard.0;
        for &(o, l) in std::iter::once(&entry.latent).chain(entry.species.iter()) {
            if o != cursor {
                break 'entries;
            }
            cursor = match o.checked_add(l) {
                Some(c) => c,
                None => break 'entries,
            };
        }
        if cursor != shard_end {
            break;
        }
        expect_t0 += entry.nt;
        expect_off = shard_end;
        toc.push(entry);
    }

    Ok((
        Gba2Header {
            tcn_used: flags & 1 == 1,
            dims,
            block,
            latent_dim,
            kt_window,
            pressure,
            nrmse_target,
            model_param_bytes,
            ranges,
        },
        toc,
        n_shards,
    ))
}

/// A byte-range reader over an archive — the abstraction that lets
/// partial decode touch only the sections a query needs, whether the
/// archive lives in memory or on disk.
pub trait SectionSource: Sync {
    fn read_at(&self, off: u64, len: usize) -> Result<Vec<u8>>;
    fn source_len(&self) -> u64;
}

/// In-memory source over a serialized archive.
pub struct SliceSource<'a>(pub &'a [u8]);

impl SectionSource for SliceSource<'_> {
    fn read_at(&self, off: u64, len: usize) -> Result<Vec<u8>> {
        let off = usize::try_from(off)
            .map_err(|_| Error::format(format!("read_at offset {off} overflows")))?;
        self.0
            .get(off..off.checked_add(len).ok_or_else(|| {
                Error::format(format!("read_at span {off}+{len} overflows"))
            })?)
            .map(|s| s.to_vec())
            .ok_or_else(|| {
                Error::format(format!(
                    "read_at [{off}, {}) beyond {} bytes",
                    off + len,
                    self.0.len()
                ))
            })
    }

    fn source_len(&self) -> u64 {
        self.0.len() as u64
    }
}

/// Owning in-memory source — [`SliceSource`] without the borrow, for
/// readers that hold the serialized archive themselves (e.g.
/// `api::ArchiveReader` over bytes, or a legacy `GBA1` archive converted
/// to its `GBA2` view).
pub struct MemSource(pub Vec<u8>);

impl SectionSource for MemSource {
    fn read_at(&self, off: u64, len: usize) -> Result<Vec<u8>> {
        SliceSource(&self.0).read_at(off, len)
    }

    fn source_len(&self) -> u64 {
        self.0.len() as u64
    }
}

/// File-backed source (seeks under a lock; shard workers may read
/// concurrently).
pub struct FileSource {
    file: Mutex<File>,
    len: u64,
}

impl FileSource {
    pub fn open<P: AsRef<Path>>(path: P) -> Result<FileSource> {
        let file = File::open(path.as_ref())?;
        let len = file.metadata()?.len();
        Ok(FileSource {
            file: Mutex::new(file),
            len,
        })
    }
}

impl SectionSource for FileSource {
    fn read_at(&self, off: u64, len: usize) -> Result<Vec<u8>> {
        let end = off
            .checked_add(len as u64)
            .ok_or_else(|| Error::format("read_at span overflows"))?;
        if end > self.len {
            return Err(Error::format(format!(
                "read_at [{off}, {end}) beyond {} bytes",
                self.len
            )));
        }
        let mut buf = vec![0u8; len];
        let mut f = self
            .file
            .lock()
            .map_err(|_| Error::runtime("archive file lock poisoned"))?;
        f.seek(SeekFrom::Start(off))?;
        f.read_exact(&mut buf)?;
        Ok(buf)
    }

    fn source_len(&self) -> u64 {
        self.len
    }
}

/// Wrapper counting the bytes and calls served — used by tests to assert
/// partial decode reads strictly fewer archive bytes, and by `gbatc
/// extract` to report IO savings.
pub struct CountingSource<'a, S: SectionSource + ?Sized> {
    inner: &'a S,
    bytes: AtomicU64,
    reads: AtomicU64,
}

impl<'a, S: SectionSource + ?Sized> CountingSource<'a, S> {
    pub fn new(inner: &'a S) -> Self {
        Self {
            inner,
            bytes: AtomicU64::new(0),
            reads: AtomicU64::new(0),
        }
    }

    pub fn bytes_read(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }

    pub fn reads(&self) -> u64 {
        self.reads.load(Ordering::Relaxed)
    }
}

impl<S: SectionSource + ?Sized> SectionSource for CountingSource<'_, S> {
    fn read_at(&self, off: u64, len: usize) -> Result<Vec<u8>> {
        let out = self.inner.read_at(off, len)?;
        self.bytes.fetch_add(out.len() as u64, Ordering::Relaxed);
        self.reads.fetch_add(1, Ordering::Relaxed);
        Ok(out)
    }

    fn source_len(&self) -> u64 {
        self.inner.source_len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gae::SpeciesBasis;
    use crate::linalg::Mat;

    fn sample() -> Gba2Archive {
        let basis = SpeciesBasis::from_mat(&Mat::identity(4), 2);
        let sec = SpeciesSection {
            basis,
            coeffs: vec![9, 8, 7],
        }
        .to_bytes();
        let header = Gba2Header {
            tcn_used: true,
            dims: (8, 2, 10, 8),
            block: (4, 5, 4),
            latent_dim: 6,
            kt_window: 4,
            pressure: 40.0e5,
            nrmse_target: 1e-3,
            model_param_bytes: 1234,
            ranges: vec![(0.0, 1.0), (-1.0, 2.0)],
        };
        let shards = vec![
            ShardPayload::gbatc(0, 4, vec![1, 2, 3], vec![sec.clone(), sec.clone()]),
            ShardPayload::gbatc(4, 4, vec![4, 5], vec![sec.clone(), sec]),
        ];
        Gba2Archive::build(header, shards).unwrap()
    }

    fn sample_mixed() -> Gba2Archive {
        let basis = SpeciesBasis::from_mat(&Mat::identity(4), 2);
        let sec = SpeciesSection {
            basis,
            coeffs: vec![9, 8, 7],
        }
        .to_bytes();
        let header = Gba2Header {
            tcn_used: false,
            dims: (8, 2, 10, 8),
            block: (4, 5, 4),
            latent_dim: 6,
            kt_window: 4,
            pressure: 40.0e5,
            nrmse_target: 1e-3,
            model_param_bytes: 0,
            ranges: vec![(0.0, 1.0), (-1.0, 2.0)],
        };
        let shards = vec![
            ShardPayload {
                t0: 0,
                nt: 4,
                latent_blob: vec![1, 2, 3],
                species: vec![sec.clone(), vec![0xAB; 17]],
                codecs: vec![CodecTag::Gbatc, CodecTag::Sz],
            },
            ShardPayload {
                t0: 4,
                nt: 4,
                latent_blob: Vec::new(),
                species: vec![vec![0xCD; 9], vec![0xEF; 5]],
                codecs: vec![CodecTag::Dense, CodecTag::Sz],
            },
        ];
        Gba2Archive::build(header, shards).unwrap()
    }

    #[test]
    fn build_deserialize_roundtrip() {
        let a = sample();
        let b = Gba2Archive::deserialize(&a.bytes).unwrap();
        assert_eq!(a.header.dims, b.header.dims);
        assert_eq!(a.header.kt_window, b.header.kt_window);
        assert_eq!(a.header.ranges, b.header.ranges);
        assert_eq!(a.toc.len(), b.toc.len());
        assert_eq!(a.latent_bytes(1).unwrap(), b.latent_bytes(1).unwrap());
        assert_eq!(
            a.species_bytes(0, 1).unwrap(),
            b.species_bytes(0, 1).unwrap()
        );
        let secs = b.species_sections(0).unwrap();
        assert_eq!(secs.len(), 2);
        assert_eq!(secs[0].coeffs, vec![9, 8, 7]);
    }

    #[test]
    fn toc_via_section_source_matches() {
        let a = sample();
        let src = SliceSource(&a.bytes);
        let counting = CountingSource::new(&src);
        let (h, toc) = Gba2Archive::read_toc(&counting).unwrap();
        assert_eq!(h.dims, a.header.dims);
        assert_eq!(toc.len(), 2);
        assert_eq!(counting.reads(), 2);
        assert!(counting.bytes_read() < a.bytes.len() as u64);
    }

    #[test]
    fn all_gbatc_archives_stay_on_version_2() {
        let a = sample();
        assert_eq!(a.version(), 2);
        // version field in the serialized prefix is 2 — the pre-registry
        // byte layout old readers accept
        assert_eq!(u16::from_le_bytes([a.bytes[4], a.bytes[5]]), 2);
        let totals = a.codec_totals();
        assert_eq!(totals[CodecTag::Gbatc as usize].0, 4);
        assert_eq!(totals[CodecTag::Sz as usize], (0, 0));
    }

    #[test]
    fn mixed_codec_archives_use_version_3_and_roundtrip() {
        let a = sample_mixed();
        assert_eq!(a.version(), 3);
        assert_eq!(u16::from_le_bytes([a.bytes[4], a.bytes[5]]), 3);
        let b = Gba2Archive::deserialize(&a.bytes).unwrap();
        assert_eq!(a.bytes, b.serialize());
        assert_eq!(b.toc[0].codecs, vec![CodecTag::Gbatc, CodecTag::Sz]);
        assert_eq!(b.toc[1].codecs, vec![CodecTag::Dense, CodecTag::Sz]);
        // empty latent blob on the model-free shard is valid
        assert_eq!(b.toc[1].latent.1, 0);
        assert_eq!(b.species_bytes(1, 0).unwrap(), &[0xCD; 9][..]);
        let totals = b.codec_totals();
        assert_eq!(totals[CodecTag::Gbatc as usize].0, 1);
        assert_eq!(totals[CodecTag::Sz as usize].0, 2);
        assert_eq!(totals[CodecTag::Dense as usize], (1, 9));
        // mixed archives cannot export as GBA1
        assert!(a.to_v1().is_err());
    }

    #[test]
    fn corrupt_codec_tag_rejected_at_toc_parse() {
        let a = sample_mixed();
        let ns = 2;
        for shard in 0..2 {
            for s in 0..ns {
                let pos = codec_tag_offset(ns, shard, s);
                // the helper points at the byte the writer put the tag in
                assert_eq!(a.bytes[pos], a.toc[shard].codecs[s] as u8);
                let mut bad = a.bytes.clone();
                bad[pos] = 0xFF;
                assert!(
                    Gba2Archive::deserialize(&bad).is_err(),
                    "tag ({shard},{s}) at byte {pos} accepted"
                );
            }
        }
    }

    #[test]
    fn v1_conversion_roundtrip() {
        let a = {
            let basis = SpeciesBasis::from_mat(&Mat::identity(4), 2);
            Archive {
                tcn_used: false,
                dims: (8, 2, 10, 8),
                block: (4, 5, 4),
                latent_dim: 6,
                pressure: 1e5,
                ranges: vec![(0.0, 1.0), (0.5, 2.0)],
                latent_blob: vec![1, 2, 3, 4],
                species: vec![
                    SpeciesSection {
                        basis: basis.clone(),
                        coeffs: vec![5, 6],
                    },
                    SpeciesSection {
                        basis,
                        coeffs: vec![],
                    },
                ],
                model_param_bytes: 99,
                nrmse_target: 1e-3,
            }
        };
        let v2 = Gba2Archive::from_v1(&a).unwrap();
        assert_eq!(v2.n_shards(), 1);
        assert_eq!(v2.latent_bytes(0).unwrap(), &a.latent_blob[..]);
        let back = v2.to_v1().unwrap();
        assert_eq!(back.serialize(), a.serialize());
    }

    #[test]
    fn corruption_and_truncation_rejected_without_panic() {
        let a = sample();
        // magic / version corruption
        let mut bad = a.bytes.clone();
        bad[0] = b'X';
        assert!(Gba2Archive::deserialize(&bad).is_err());
        let mut bad = a.bytes.clone();
        bad[4] = 9;
        assert!(Gba2Archive::deserialize(&bad).is_err());
        // every truncation point must error (TOC or payload extent check)
        for cut in [0, 1, PREFIX_LEN - 1, PREFIX_LEN, 60, a.bytes.len() - 1] {
            assert!(
                Gba2Archive::deserialize(&a.bytes[..cut]).is_err(),
                "cut {cut} accepted"
            );
        }
        // arbitrary bit flips must never panic
        for i in (0..a.bytes.len()).step_by(3) {
            let mut corrupt = a.bytes.clone();
            corrupt[i] ^= 0xFF;
            let _ = Gba2Archive::deserialize(&corrupt);
        }
    }
}
