//! Memory-mapped [`SectionSource`]: TOC-addressed section reads borrow
//! the page cache instead of copying through a `File` seek/read pair.
//!
//! The mapping is hand-rolled over the platform's `mmap(2)`/`munmap(2)`
//! (raw `extern "C"` declarations — the crate stays dependency-free) and
//! gated to Unix; on other platforms [`MmapSource::open`] returns an
//! error and callers fall back to [`super::FileSource`], which is also
//! the runtime fallback when `mmap` itself fails (exotic filesystems,
//! resource limits).
//!
//! ## Safety argument
//!
//! * The mapping is `PROT_READ` + `MAP_PRIVATE`: nothing can write
//!   through it, and private mode keeps other processes' writes from
//!   being required to appear (a truncation by another process can still
//!   SIGBUS — the same exposure every mmap'd reader accepts; archives
//!   are immutable once written, see `DESIGN.md`).
//! * The fd may be closed right after `mmap` returns: POSIX keeps the
//!   mapping alive until `munmap`, so the `File` handle is dropped at
//!   the end of `open` without affecting the slice.
//! * `as_slice` hands out `&[u8]` borrowing `self`, and the pointer is
//!   unmapped exactly once, in `Drop` — so no view can outlive the
//!   mapping.
//! * `Send`/`Sync` are sound because the mapping is immutable shared
//!   memory with no interior mutability.

use std::path::Path;

use crate::error::{Error, Result};

use super::toc::{SectionSource, SliceSource};

#[cfg(unix)]
mod sys {
    use std::os::raw::{c_int, c_void};

    pub const PROT_READ: c_int = 0x1;
    pub const MAP_PRIVATE: c_int = 0x02;
    pub const MAP_FAILED: *mut c_void = usize::MAX as *mut c_void;

    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> c_int;
    }
}

/// Read-only memory-mapped archive file.  See the module docs for the
/// safety argument; construct with [`MmapSource::open`].
pub struct MmapSource {
    /// Base of the mapping; null for a zero-length file (nothing mapped).
    #[cfg(unix)]
    ptr: *const u8,
    #[cfg(unix)]
    len: usize,
}

// SAFETY: the mapping is PROT_READ/MAP_PRIVATE shared immutable memory —
// no &mut access exists and no interior mutability; concurrent reads
// from any thread are sound.
unsafe impl Send for MmapSource {}
unsafe impl Sync for MmapSource {}

impl MmapSource {
    /// Map `path` read-only.  Errors on non-Unix platforms, on open
    /// failure, and on `mmap` failure (callers fall back to
    /// [`super::FileSource`]).
    #[cfg(unix)]
    pub fn open<P: AsRef<Path>>(path: P) -> Result<MmapSource> {
        use std::os::unix::io::AsRawFd;

        // Miri has no mmap(2); erroring here routes archive opens onto
        // the FileSource fallback path, same as any mmap failure.
        if cfg!(miri) {
            return Err(Error::runtime("mmap: unsupported under miri"));
        }
        let file = std::fs::File::open(path.as_ref())?;
        let len = file.metadata()?.len();
        if len > usize::MAX as u64 {
            return Err(Error::format("mmap: file larger than address space"));
        }
        let len = len as usize;
        if len == 0 {
            return Ok(MmapSource {
                ptr: std::ptr::null(),
                len: 0,
            });
        }
        // SAFETY: len > 0, fd is a freshly opened readable file, and we
        // request a private read-only mapping at a kernel-chosen address.
        let ptr = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                len,
                sys::PROT_READ,
                sys::MAP_PRIVATE,
                file.as_raw_fd(),
                0,
            )
        };
        if ptr == sys::MAP_FAILED {
            return Err(std::io::Error::last_os_error().into());
        }
        // `file` drops (closes the fd) here; the mapping persists.
        Ok(MmapSource {
            ptr: ptr as *const u8,
            len,
        })
    }

    /// Mapping is not implemented off Unix; callers use the
    /// [`super::FileSource`] fallback.
    #[cfg(not(unix))]
    pub fn open<P: AsRef<Path>>(_path: P) -> Result<MmapSource> {
        Err(Error::runtime("mmap: unsupported on this platform"))
    }

    /// The whole mapped file as a borrowed byte slice.
    pub fn as_slice(&self) -> &[u8] {
        #[cfg(unix)]
        {
            if self.len == 0 {
                return &[];
            }
            // SAFETY: ptr/len describe a live PROT_READ mapping owned by
            // self; the borrow cannot outlive the Drop that unmaps it.
            unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
        }
        #[cfg(not(unix))]
        {
            &[]
        }
    }
}

impl Drop for MmapSource {
    fn drop(&mut self) {
        #[cfg(unix)]
        if !self.ptr.is_null() {
            // SAFETY: ptr/len came from a successful mmap and are
            // unmapped exactly once.
            unsafe {
                sys::munmap(self.ptr as *mut _, self.len);
            }
        }
    }
}

impl SectionSource for MmapSource {
    fn read_at(&self, off: u64, len: usize) -> Result<Vec<u8>> {
        // same bounds checks and error text as any in-memory source
        SliceSource(self.as_slice()).read_at(off, len)
    }

    fn source_len(&self) -> u64 {
        self.as_slice().len() as u64
    }
}

#[cfg(all(test, unix, not(miri)))]
mod tests {
    use super::*;

    fn tmp_path(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("gbatc_mmap_{}_{name}", std::process::id()));
        p
    }

    #[test]
    fn maps_and_reads_like_a_slice() {
        let path = tmp_path("basic");
        let data: Vec<u8> = (0..10_000u32).map(|i| (i * 7) as u8).collect();
        std::fs::write(&path, &data).unwrap();
        let m = MmapSource::open(&path).unwrap();
        assert_eq!(m.as_slice(), &data[..]);
        assert_eq!(m.source_len(), data.len() as u64);
        assert_eq!(m.read_at(13, 100).unwrap(), data[13..113].to_vec());
        // out-of-bounds errors match the slice source's contract
        assert!(m.read_at(data.len() as u64 - 1, 2).is_err());
        drop(m);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn zero_length_file_maps_to_empty_slice() {
        let path = tmp_path("empty");
        std::fs::write(&path, b"").unwrap();
        let m = MmapSource::open(&path).unwrap();
        assert_eq!(m.as_slice(), &[] as &[u8]);
        assert_eq!(m.source_len(), 0);
        assert!(m.read_at(0, 1).is_err());
        assert_eq!(m.read_at(0, 0).unwrap(), Vec::<u8>::new());
        drop(m);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn missing_file_is_an_error() {
        assert!(MmapSource::open(tmp_path("definitely_missing")).is_err());
    }
}
