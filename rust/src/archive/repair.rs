//! Offline archive health & salvage — the library behind
//! `gbatc inspect --verify`, `gbatc repair`, and `gbatc compact`.
//!
//! Three entry points, all pure functions over archive bytes:
//!
//! * [`verify_archive`] — walk every section of a sealed (`GBA1`/`GBA2`)
//!   or unsealed (`GBJL` journal) file and report per-section health.
//!   Sealed sections are *structurally* decoded (basis + coefficient
//!   streams for GBATC, full plane decode for SZ/DENSE); unsealed shards
//!   are CRC-verified against their journal records.  Read-only.
//! * [`repair_archive`] — salvage the structurally valid shard prefix of
//!   a torn file into a well-formed `GBA2`, rewriting the header + TOC
//!   through the same `write_header_toc` every writer uses.  Works on
//!   both sealed archives (torn TOC or payload tail) and unsealed
//!   journal streams a killed writer left behind.
//! * [`compact_archives`] — merge small archives from the same run
//!   (e.g. a repaired prefix plus a fuller re-run) into one, dropping
//!   duplicate and orphaned shards.
//!
//! Sealed `GBA2` bytes carry no checksums (the format is unchanged for
//! backward compatibility), so sealed-archive verification is
//! structural: it proves every section parses and decodes, not that the
//! decoded values match the originals.  Unsealed streams *are* CRC'd —
//! each journal record commits a payload checksum — so pre-seal damage
//! is detected exactly.

use crate::archive::format::{Archive, SpeciesSection, MAGIC};
use crate::archive::stream::{
    parse_journal_header, parse_journal_records, JOURNAL_MAGIC, TRAILER_LEN, TRAILER_MAGIC,
};
use crate::archive::toc::{
    header_toc_len, parse_header_toc_prefix, CodecTag, Gba2Archive, Gba2Header, ShardPayload,
    MAGIC2,
};
use crate::codec::{CoeffCodec, LatentCodec};
use crate::compressor::registry::decode_stage;
use crate::data::blocks::{BlockGrid, BlockShape};
use crate::error::{Error, Result};
use crate::util::crc32::crc32;

/// Health of one verified unit: a species section, a latent-plane
/// section (`species: None`), or — for unsealed streams — one journaled
/// shard payload (`species: None`).
#[derive(Clone, Debug)]
pub struct SectionHealth {
    pub shard: usize,
    /// `None` for a shard-level unit (latent plane / journal payload).
    pub species: Option<usize>,
    pub ok: bool,
    /// What failed (empty when `ok`).
    pub detail: String,
}

impl SectionHealth {
    fn ok(shard: usize, species: Option<usize>) -> SectionHealth {
        SectionHealth {
            shard,
            species,
            ok: true,
            detail: String::new(),
        }
    }

    fn bad(shard: usize, species: Option<usize>, detail: impl Into<String>) -> SectionHealth {
        SectionHealth {
            shard,
            species,
            ok: false,
            detail: detail.into(),
        }
    }
}

/// Result of [`verify_archive`].
#[derive(Clone, Debug, Default)]
pub struct VerifyReport {
    /// Input was a sealed archive (vs an unsealed `GBJL` stream).
    pub sealed: bool,
    /// Shards the header / journal declares.
    pub shards_declared: usize,
    /// Structurally valid (TOC) / committed (journal) shard prefix.
    pub shards_indexed: usize,
    pub sections: Vec<SectionHealth>,
    /// Unsealed only: bytes of a complete shard payload whose journal
    /// record never landed (flushed but uncommitted — dropped by both
    /// `resume` and `repair`).
    pub uncommitted_tail: u64,
}

impl VerifyReport {
    /// Every declared shard present and every section decodes.
    pub fn healthy(&self) -> bool {
        self.shards_indexed == self.shards_declared && self.sections.iter().all(|s| s.ok)
    }

    /// Count of failed sections (missing shards included).
    pub fn damaged_sections(&self) -> usize {
        self.sections.iter().filter(|s| !s.ok).count()
    }
}

/// What [`repair_archive`] / [`compact_archives`] did.
#[derive(Clone, Debug)]
pub struct RepairOutcome {
    /// Input was sealed (vs an unsealed `GBJL` stream).
    pub sealed_input: bool,
    /// Shards declared across the input(s).
    pub shards_in: usize,
    /// Shards in the emitted archive.
    pub shards_out: usize,
    /// Timesteps the emitted archive covers.
    pub timesteps_out: usize,
    /// Size of the emitted archive.
    pub bytes_out: u64,
    /// False when the input was already well-formed and is returned
    /// unchanged.
    pub changed: bool,
    /// Compact only: shards dropped because their time span was already
    /// covered.
    pub dropped_duplicate: usize,
    /// Compact only: shards dropped because they do not connect to the
    /// tiling chain.
    pub dropped_orphaned: usize,
}

fn section_slice(bytes: &[u8], range: (u64, u64)) -> Result<&[u8]> {
    let off = usize::try_from(range.0).map_err(|_| Error::format("section offset overflows"))?;
    let len = usize::try_from(range.1).map_err(|_| Error::format("section length overflows"))?;
    bytes
        .get(off..off.checked_add(len).ok_or_else(|| Error::format("section span overflows"))?)
        .ok_or_else(|| Error::format("section range beyond file"))
}

/// Structural check of a parsed GBATC section — the exact validation
/// `GbatcShardCodec::correct_plane` performs before touching a plane
/// (block count, coefficient dimension, index-vs-rank), without paying
/// for the plane itself.
fn check_gbatc_parsed(sec: &SpeciesSection, nb: usize, d: usize) -> Result<()> {
    let coeffs = CoeffCodec::decode(&sec.coeffs)?;
    if coeffs.per_block.len() != nb || (coeffs.d != d && !coeffs.per_block.is_empty()) {
        return Err(Error::codec(format!(
            "gbatc section: {} coefficient blocks of dim {} vs grid {nb} x {d}",
            coeffs.per_block.len(),
            coeffs.d
        )));
    }
    if coeffs
        .per_block
        .iter()
        .flatten()
        .any(|&(j, _)| j >= sec.basis.rank)
    {
        return Err(Error::codec(format!(
            "gbatc section: coefficient index beyond basis rank {}",
            sec.basis.rank
        )));
    }
    Ok(())
}

fn check_latent(bytes: &[u8], nb: usize, latent_dim: usize) -> Result<()> {
    let plane = LatentCodec::decode(bytes)?;
    if plane.n != nb || plane.dim != latent_dim {
        return Err(Error::format(format!(
            "latent plane {}x{} vs expected {nb}x{latent_dim}",
            plane.n, plane.dim
        )));
    }
    Ok(())
}

fn health_of(shard: usize, species: Option<usize>, res: Result<()>) -> SectionHealth {
    match res {
        Ok(()) => SectionHealth::ok(shard, species),
        Err(e) => SectionHealth::bad(shard, species, e.to_string()),
    }
}

/// Walk every section of `bytes` — a sealed `GBA1`/`GBA2` archive or an
/// unsealed `GBJL` stream — and report per-section health.  Errors only
/// when the file is too damaged to even size (no recognizable magic,
/// rotted fixed header fields).
pub fn verify_archive(bytes: &[u8]) -> Result<VerifyReport> {
    if bytes.starts_with(JOURNAL_MAGIC) {
        return verify_unsealed(bytes);
    }
    if bytes.starts_with(MAGIC2) {
        return verify_sealed_v2(bytes);
    }
    if bytes.starts_with(MAGIC) {
        return verify_sealed_v1(bytes);
    }
    Err(Error::format(
        "unknown magic (expected GBA1, GBA2, or GBJL journal)",
    ))
}

fn verify_sealed_v2(bytes: &[u8]) -> Result<VerifyReport> {
    let (header, toc, declared) = parse_header_toc_prefix(bytes, bytes.len() as u64)?;
    let (_, ns, ny, nx) = header.dims;
    let shape = BlockShape {
        kt: header.block.0,
        by: header.block.1,
        bx: header.block.2,
    };
    let mut sections = Vec::new();
    let mut scratch = Vec::new();
    for (i, entry) in toc.iter().enumerate() {
        let grid = match BlockGrid::new((entry.nt, 1, ny, nx), shape) {
            Ok(g) => g,
            Err(e) => {
                // header-level block/dims mismatch: every section of the
                // shard is unverifiable
                sections.push(SectionHealth::bad(i, None, e.to_string()));
                continue;
            }
        };
        let nb = grid.n_blocks();
        let d = shape.d();
        let any_gbatc = entry.codecs.iter().any(|&c| c == CodecTag::Gbatc);
        if any_gbatc || entry.latent.1 > 0 {
            let res = section_slice(bytes, entry.latent)
                .and_then(|b| check_latent(b, nb, header.latent_dim));
            sections.push(health_of(i, None, res));
        }
        for (s, (&range, &tag)) in entry.species.iter().zip(&entry.codecs).enumerate() {
            let res = section_slice(bytes, range).and_then(|sec| match tag {
                CodecTag::Gbatc => {
                    SpeciesSection::from_bytes(sec).and_then(|p| check_gbatc_parsed(&p, nb, d))
                }
                tag => {
                    scratch.clear();
                    scratch.resize(entry.nt * ny * nx, 0.0f32);
                    decode_stage(tag)?.decode(sec, entry.nt, ny, nx, &mut scratch)
                }
            });
            sections.push(health_of(i, Some(s), res));
        }
    }
    for i in toc.len()..declared {
        sections.push(SectionHealth::bad(i, None, "TOC entry missing or torn"));
    }
    Ok(VerifyReport {
        sealed: true,
        shards_declared: declared,
        shards_indexed: toc.len(),
        sections,
        uncommitted_tail: 0,
    })
}

fn verify_sealed_v1(bytes: &[u8]) -> Result<VerifyReport> {
    let mut report = VerifyReport {
        sealed: true,
        shards_declared: 1,
        shards_indexed: 0,
        sections: Vec::new(),
        uncommitted_tail: 0,
    };
    let a = match Archive::deserialize(bytes) {
        Ok(a) => a,
        Err(e) => {
            report
                .sections
                .push(SectionHealth::bad(0, None, e.to_string()));
            return Ok(report);
        }
    };
    report.shards_indexed = 1;
    let (nt, _, ny, nx) = a.dims;
    let shape = BlockShape {
        kt: a.block.0,
        by: a.block.1,
        bx: a.block.2,
    };
    match BlockGrid::new((nt, 1, ny, nx), shape) {
        Ok(grid) => {
            let nb = grid.n_blocks();
            report.sections.push(health_of(
                0,
                None,
                check_latent(&a.latent_blob, nb, a.latent_dim),
            ));
            for (s, sec) in a.species.iter().enumerate() {
                report.sections.push(health_of(
                    0,
                    Some(s),
                    check_gbatc_parsed(sec, nb, shape.d()),
                ));
            }
        }
        Err(e) => report
            .sections
            .push(SectionHealth::bad(0, None, e.to_string())),
    }
    Ok(report)
}

fn verify_unsealed(bytes: &[u8]) -> Result<VerifyReport> {
    let (layout, _header) = parse_journal_header(bytes)?;
    let records = parse_journal_records(bytes, &layout);
    let base = header_toc_len(layout.ns, layout.n_shards, layout.version) as u64;
    let mut sections = Vec::new();
    let mut cursor = base;
    let mut committed = 0usize;
    for (k, rec) in records.iter().enumerate() {
        let res = section_slice(bytes, (cursor, rec.shard_len)).and_then(|payload| {
            if crc32(payload) == rec.payload_crc {
                Ok(())
            } else {
                Err(Error::format("journal payload CRC mismatch"))
            }
        });
        let ok = res.is_ok();
        sections.push(health_of(k, None, res));
        if !ok {
            break;
        }
        committed += 1;
        cursor += rec.shard_len;
    }
    Ok(VerifyReport {
        sealed: false,
        shards_declared: layout.n_shards,
        shards_indexed: committed,
        sections,
        uncommitted_tail: scan_uncommitted_tail(bytes, cursor),
    })
}

/// Scan the bytes after the last committed payload for a complete shard
/// whose `GBSH` trailer was flushed but whose journal record never
/// landed (a crash can fall between the two flushes).  The trailer
/// carries the payload length + CRC, so a forward scan for the magic can
/// validate the candidate exactly.  Such a payload is *reported*, not
/// salvaged — its per-section byte ranges lived only in the unwritten
/// record.
fn scan_uncommitted_tail(bytes: &[u8], from: u64) -> u64 {
    let from = usize::try_from(from).unwrap_or(usize::MAX);
    let Some(tail) = bytes.get(from..) else {
        return 0;
    };
    let mut p = 0usize;
    while p + TRAILER_LEN <= tail.len() {
        if &tail[p..p + 4] == TRAILER_MAGIC {
            let len = le_u64_at(tail, p + 4);
            let crc = le_u32_at(tail, p + 12);
            if len == p as u64 && p > 0 && crc == crc32(&tail[..p]) {
                return len;
            }
        }
        p += 1;
    }
    0
}

/// Panic-free little-endian reads for the trailer scan (the caller's
/// loop bound guarantees `at + 8 <= b.len()`; a short read yields 0
/// rather than a panicking `try_into().unwrap()` on the decode path).
fn le_u64_at(b: &[u8], at: usize) -> u64 {
    let mut buf = [0u8; 8];
    if let Some(src) = b.get(at..at + 8) {
        buf.copy_from_slice(src);
    }
    u64::from_le_bytes(buf)
}

fn le_u32_at(b: &[u8], at: usize) -> u32 {
    let mut buf = [0u8; 4];
    if let Some(src) = b.get(at..at + 4) {
        buf.copy_from_slice(src);
    }
    u32::from_le_bytes(buf)
}

/// Salvage the valid shard prefix of a damaged file into a well-formed
/// `GBA2` archive.  Accepts a sealed `GBA2` with a torn TOC or payload
/// tail, an unsealed `GBJL` stream a killed writer left behind, or (as a
/// pass-through) an intact `GBA1`/`GBA2`.  The emitted archive covers
/// exactly the salvaged timesteps (`dims.0` is adjusted) and is rebuilt
/// through [`Gba2Archive::build`], so its header + TOC go through the
/// same `write_header_toc` as every other writer.
///
/// Sealed salvage is TOC-level (sealed archives carry no payload
/// checksums — run [`verify_archive`] for deep structural health);
/// unsealed salvage is exact, CRC-verifying every committed payload.
pub fn repair_archive(bytes: &[u8]) -> Result<(Vec<u8>, RepairOutcome)> {
    if bytes.starts_with(JOURNAL_MAGIC) {
        return repair_unsealed(bytes);
    }
    if bytes.starts_with(MAGIC2) {
        return repair_sealed(bytes);
    }
    if bytes.starts_with(MAGIC) {
        // GBA1 has no shard TOC: either it parses whole or nothing is
        // addressable
        return match Archive::deserialize(bytes) {
            Ok(a) => Ok((
                bytes.to_vec(),
                RepairOutcome {
                    sealed_input: true,
                    shards_in: 1,
                    shards_out: 1,
                    timesteps_out: a.dims.0,
                    bytes_out: bytes.len() as u64,
                    changed: false,
                    dropped_duplicate: 0,
                    dropped_orphaned: 0,
                },
            )),
            Err(e) => Err(Error::format(format!(
                "GBA1 archive is damaged and has no shard TOC to salvage from: {e}"
            ))),
        };
    }
    Err(Error::format(
        "unknown magic (expected GBA1, GBA2, or GBJL journal)",
    ))
}

fn rebuild(
    mut header: Gba2Header,
    shards: Vec<ShardPayload>,
    sealed_input: bool,
    shards_in: usize,
) -> Result<(Vec<u8>, RepairOutcome)> {
    if shards.is_empty() {
        return Err(Error::format(
            "no intact shards to salvage — nothing recoverable",
        ));
    }
    let timesteps: usize = shards.iter().map(|s| s.nt).sum();
    header.dims.0 = timesteps;
    let shards_out = shards.len();
    let archive = Gba2Archive::build(header, shards)?;
    let bytes = archive.into_bytes();
    let bytes_out = bytes.len() as u64;
    Ok((
        bytes,
        RepairOutcome {
            sealed_input,
            shards_in,
            shards_out,
            timesteps_out: timesteps,
            bytes_out,
            changed: true,
            dropped_duplicate: 0,
            dropped_orphaned: 0,
        },
    ))
}

fn repair_sealed(bytes: &[u8]) -> Result<(Vec<u8>, RepairOutcome)> {
    if let Ok(a) = Gba2Archive::deserialize(bytes) {
        // already well-formed: pass through untouched
        return Ok((
            bytes.to_vec(),
            RepairOutcome {
                sealed_input: true,
                shards_in: a.n_shards(),
                shards_out: a.n_shards(),
                timesteps_out: a.header.dims.0,
                bytes_out: bytes.len() as u64,
                changed: false,
                dropped_duplicate: 0,
                dropped_orphaned: 0,
            },
        ));
    }
    let (header, toc, declared) = parse_header_toc_prefix(bytes, bytes.len() as u64)?;
    let mut shards = Vec::with_capacity(toc.len());
    for entry in &toc {
        let latent_blob = section_slice(bytes, entry.latent)?.to_vec();
        let mut species = Vec::with_capacity(entry.species.len());
        for &range in &entry.species {
            species.push(section_slice(bytes, range)?.to_vec());
        }
        shards.push(ShardPayload {
            t0: entry.t0,
            nt: entry.nt,
            latent_blob,
            species,
            codecs: entry.codecs.clone(),
        });
    }
    rebuild(header, shards, true, declared)
}

fn repair_unsealed(bytes: &[u8]) -> Result<(Vec<u8>, RepairOutcome)> {
    let (layout, header) = parse_journal_header(bytes)?;
    let records = parse_journal_records(bytes, &layout);
    let base = header_toc_len(layout.ns, layout.n_shards, layout.version) as u64;
    let mut shards = Vec::with_capacity(records.len());
    let mut cursor = base;
    for rec in &records {
        let Ok(payload) = section_slice(bytes, (cursor, rec.shard_len)) else {
            break; // torn payload tail
        };
        if crc32(payload) != rec.payload_crc {
            break; // bit rot or torn write under the committed record
        }
        let latent_len = usize::try_from(rec.latent_len)
            .map_err(|_| Error::format("latent length overflows"))?;
        let latent_blob = payload[..latent_len].to_vec();
        let mut species = Vec::with_capacity(rec.sec_lens.len());
        let mut off = latent_len;
        for &len in &rec.sec_lens {
            let len = usize::try_from(len).map_err(|_| Error::format("section length overflows"))?;
            species.push(payload[off..off + len].to_vec());
            off += len;
        }
        shards.push(ShardPayload {
            t0: rec.t0,
            nt: rec.nt,
            latent_blob,
            species,
            codecs: rec.codecs.clone(),
        });
        cursor += rec.shard_len;
    }
    rebuild(header, shards, false, layout.n_shards)
}

/// Merge archives from the same run (shared time origin and layout) into
/// one, in input order — e.g. a crash-repaired prefix plus a fuller
/// re-run.  Shards whose time span is already covered are dropped as
/// duplicates (first writer wins); shards that do not connect to the
/// tiling chain (a gap, a partial overlap, or anything after a short
/// final shard) are dropped as orphans.
///
/// All inputs must agree on species count, grid, block shape,
/// `latent_dim`, `kt_window`, TCN use, and normalization ranges; the
/// merged header takes the loosest `nrmse_target` (every section keeps
/// its own certified bound) and the largest `model_param_bytes` (the
/// shared model is charged once).
pub fn compact_archives(inputs: &[Gba2Archive]) -> Result<(Gba2Archive, RepairOutcome)> {
    let first = inputs
        .first()
        .ok_or_else(|| Error::format("compact: no input archives"))?;
    let mut header = first.header.clone();
    let mut shards_in = 0usize;
    for (i, a) in inputs.iter().enumerate() {
        let h = &a.header;
        let same_ranges = h.ranges.len() == header.ranges.len()
            && h.ranges
                .iter()
                .zip(&header.ranges)
                .all(|(a, b)| a.0.to_bits() == b.0.to_bits() && a.1.to_bits() == b.1.to_bits());
        if h.dims.1 != header.dims.1
            || h.dims.2 != header.dims.2
            || h.dims.3 != header.dims.3
            || h.block != header.block
            || h.latent_dim != header.latent_dim
            || h.kt_window != header.kt_window
            || h.tcn_used != header.tcn_used
            || !same_ranges
        {
            return Err(Error::format(format!(
                "compact: archive {i} has an incompatible layout (species/grid/block/\
                 latent/kt_window/ranges must match archive 0)"
            )));
        }
        header.nrmse_target = header.nrmse_target.max(h.nrmse_target);
        header.model_param_bytes = header.model_param_bytes.max(h.model_param_bytes);
        shards_in += a.n_shards();
    }

    let mut kept: Vec<ShardPayload> = Vec::new();
    let mut expect_t0 = 0usize;
    let mut closed = false; // a short (final) shard ends the chain
    let mut dropped_duplicate = 0usize;
    let mut dropped_orphaned = 0usize;
    for a in inputs {
        for (i, entry) in a.toc.iter().enumerate() {
            let end = entry.t0 + entry.nt;
            if end <= expect_t0 {
                dropped_duplicate += 1;
                continue;
            }
            if closed || entry.t0 != expect_t0 {
                // gap, partial overlap, or material after a short shard
                dropped_orphaned += 1;
                continue;
            }
            kept.push(ShardPayload {
                t0: entry.t0,
                nt: entry.nt,
                latent_blob: a.latent_bytes(i)?.to_vec(),
                species: (0..entry.species.len())
                    .map(|s| a.species_bytes(i, s).map(|b| b.to_vec()))
                    .collect::<Result<Vec<_>>>()?,
                codecs: entry.codecs.clone(),
            });
            expect_t0 = end;
            closed = entry.nt < header.kt_window;
        }
    }
    if kept.is_empty() {
        return Err(Error::format("compact: no shard starts at timestep 0"));
    }
    header.dims.0 = expect_t0;
    let shards_out = kept.len();
    let changed = inputs.len() > 1 || shards_out != shards_in;
    let archive = Gba2Archive::build(header, kept)?;
    let outcome = RepairOutcome {
        sealed_input: true,
        shards_in,
        shards_out,
        timesteps_out: expect_t0,
        bytes_out: archive.bytes.len() as u64,
        changed,
        dropped_duplicate,
        dropped_orphaned,
    };
    Ok((archive, outcome))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::archive::stream::{
        journal_header_len, journal_record_len, Gba2StreamWriter, StreamLayout,
    };
    use crate::gae::basis::SpeciesBasis;
    use crate::linalg::Mat;
    use crate::util::bytes::ByteWriter;
    use std::io::Cursor;

    const NS: usize = 2;
    const NY: usize = 4;
    const NX: usize = 4;
    const KT: usize = 4;
    const BLOCK: (usize, usize, usize) = (2, 2, 2);
    const D: usize = 8; // 2*2*2
    const NB: usize = 8; // (4/2)*(4/2)*(4/2) per shard
    const LATENT_DIM: usize = 4;

    fn header(nt: usize) -> Gba2Header {
        Gba2Header {
            tcn_used: false,
            dims: (nt, NS, NY, NX),
            block: BLOCK,
            latent_dim: LATENT_DIM,
            kt_window: KT,
            pressure: 0.5,
            nrmse_target: 1e-2,
            model_param_bytes: 64,
            ranges: vec![(0.0, 1.0); NS],
        }
    }

    /// A valid DENSE constant-plane section (width 0 ⇒ fill(lo)).
    fn dense_section(lo: f32) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.f32(lo);
        w.f64(0.5);
        w.u8(0);
        w.blob(&[]);
        w.finish()
    }

    /// A valid GBATC section: identity basis of rank 2, one coefficient
    /// pair per block.
    fn gbatc_section() -> Vec<u8> {
        let basis = SpeciesBasis::from_mat(&Mat::identity(D), 2);
        let per_block = vec![vec![(0usize, 1i64), (1, -2)]; NB];
        let coeffs = CoeffCodec::encode(&per_block, D, 0.1).unwrap();
        SpeciesSection { basis, coeffs }.to_bytes()
    }

    fn latent_blob() -> Vec<u8> {
        LatentCodec::encode(&vec![0.25f32; NB * LATENT_DIM], NB, LATENT_DIM, 0.01)
            .unwrap()
            .0
    }

    /// All-DENSE archive (version 3), `n_shards` full windows.
    fn dense_archive(n_shards: usize) -> Gba2Archive {
        let shards = (0..n_shards)
            .map(|i| ShardPayload {
                t0: i * KT,
                nt: KT,
                latent_blob: Vec::new(),
                species: (0..NS).map(|s| dense_section(0.1 * (s + 1) as f32)).collect(),
                codecs: vec![CodecTag::Dense; NS],
            })
            .collect();
        Gba2Archive::build(header(n_shards * KT), shards).unwrap()
    }

    /// All-GBATC archive (version 2), `n_shards` full windows.
    fn gbatc_archive(n_shards: usize) -> Gba2Archive {
        let shards = (0..n_shards)
            .map(|i| {
                ShardPayload::gbatc(
                    i * KT,
                    KT,
                    latent_blob(),
                    (0..NS).map(|_| gbatc_section()).collect(),
                )
            })
            .collect();
        Gba2Archive::build(header(n_shards * KT), shards).unwrap()
    }

    #[test]
    fn verify_healthy_archives_pass() {
        let dense = dense_archive(2);
        let r = verify_archive(&dense.bytes).unwrap();
        assert!(r.healthy(), "dense: {:?}", r.sections);
        assert!(r.sealed);
        assert_eq!((r.shards_declared, r.shards_indexed), (2, 2));
        // DENSE shards carry no latent section: NS entries per shard
        assert_eq!(r.sections.len(), 2 * NS);

        let gbatc = gbatc_archive(2);
        let r = verify_archive(&gbatc.bytes).unwrap();
        assert!(r.healthy(), "gbatc: {:?}", r.sections);
        // latent + NS species per shard
        assert_eq!(r.sections.len(), 2 * (1 + NS));
    }

    #[test]
    fn verify_flags_bit_flipped_section() {
        let a = gbatc_archive(2);
        let mut bytes = a.bytes.clone();
        // corrupt the basis `d` field (high byte) of shard 1, species 1
        let off = a.toc[1].species[1].0 as usize + 6;
        bytes[off] ^= 0xFF;
        let r = verify_archive(&bytes).unwrap();
        assert!(!r.healthy());
        assert_eq!(r.damaged_sections(), 1);
        let bad = r.sections.iter().find(|s| !s.ok).unwrap();
        assert_eq!((bad.shard, bad.species), (1, Some(1)));
        assert!(!bad.detail.is_empty());
    }

    #[test]
    fn verify_rejects_unknown_magic() {
        assert!(verify_archive(b"NOPE....").is_err());
    }

    #[test]
    fn repair_passes_through_intact_archive() {
        let a = dense_archive(2);
        let (bytes, outcome) = repair_archive(&a.bytes).unwrap();
        assert_eq!(bytes, a.bytes);
        assert!(!outcome.changed);
        assert_eq!(outcome.shards_out, 2);
    }

    #[test]
    fn repair_salvages_torn_sealed_archive() {
        let a = dense_archive(3);
        // tear 3 bytes off the final shard's payload
        let torn = &a.bytes[..a.bytes.len() - 3];
        assert!(Gba2Archive::deserialize(torn).is_err());
        let (bytes, outcome) = repair_archive(torn).unwrap();
        assert!(outcome.changed);
        assert_eq!(outcome.shards_in, 3);
        assert_eq!(outcome.shards_out, 2);
        assert_eq!(outcome.timesteps_out, 2 * KT);
        let repaired = Gba2Archive::deserialize(&bytes).unwrap();
        assert_eq!(repaired.n_shards(), 2);
        assert_eq!(repaired.header.dims.0, 2 * KT);
        assert!(verify_archive(&bytes).unwrap().healthy());
        // the surviving shards' payload bytes are bit-identical
        for i in 0..2 {
            for s in 0..NS {
                assert_eq!(
                    repaired.species_bytes(i, s).unwrap(),
                    a.species_bytes(i, s).unwrap()
                );
            }
        }
    }

    #[test]
    fn repair_errors_when_nothing_recoverable() {
        let a = dense_archive(2);
        // tear into the first shard's payload: no complete shard survives
        let torn = &a.bytes[..a.toc[0].shard.0 as usize + 4];
        assert!(repair_archive(torn).is_err());
    }

    fn unsealed_stream(n_written: usize, n_declared: usize) -> Vec<u8> {
        let h = header(n_declared * KT);
        let layout = StreamLayout {
            nt: n_declared * KT,
            ns: NS,
            kt_window: KT,
            n_shards: n_declared,
            version: 3,
        };
        let mut w =
            Gba2StreamWriter::new_with_header(Cursor::new(Vec::new()), layout, &h).unwrap();
        for i in 0..n_written {
            w.write_shard(&ShardPayload {
                t0: i * KT,
                nt: KT,
                latent_blob: Vec::new(),
                species: (0..NS).map(|s| dense_section(0.1 * (s + 1) as f32)).collect(),
                codecs: vec![CodecTag::Dense; NS],
            })
            .unwrap();
        }
        w.abort().into_inner()
    }

    #[test]
    fn repair_seals_interrupted_stream() {
        let bytes = unsealed_stream(2, 3);
        let r = verify_archive(&bytes).unwrap();
        assert!(!r.sealed);
        assert_eq!((r.shards_declared, r.shards_indexed), (3, 2));
        assert!(!r.healthy()); // incomplete stream needs repair/resume

        let (sealed, outcome) = repair_archive(&bytes).unwrap();
        assert!(!outcome.sealed_input);
        assert_eq!(outcome.shards_out, 2);
        assert_eq!(outcome.timesteps_out, 2 * KT);
        let a = Gba2Archive::deserialize(&sealed).unwrap();
        assert_eq!(a.n_shards(), 2);
        assert!(verify_archive(&sealed).unwrap().healthy());
        // salvaged bytes match an uninterrupted 2-shard run's payloads
        let full = dense_archive(2);
        for i in 0..2 {
            for s in 0..NS {
                assert_eq!(
                    a.species_bytes(i, s).unwrap(),
                    full.species_bytes(i, s).unwrap()
                );
            }
        }
    }

    #[test]
    fn verify_reports_uncommitted_tail() {
        let mut bytes = unsealed_stream(1, 2);
        // simulate a crash between the payload+trailer flush and the
        // journal-record flush: zero shard 0's record slot
        let slot = journal_header_len(NS);
        let rec_len = journal_record_len(NS);
        bytes[slot..slot + rec_len].fill(0);
        let r = verify_archive(&bytes).unwrap();
        assert_eq!(r.shards_indexed, 0);
        assert!(r.uncommitted_tail > 0);
        // nothing committed ⇒ nothing to salvage
        assert!(repair_archive(&bytes).is_err());
    }

    #[test]
    fn compact_merges_and_dedupes() {
        // A = crash-repaired prefix (2 shards); B = fuller re-run (3)
        let a = dense_archive(2);
        let b = dense_archive(3);
        let (merged, outcome) = compact_archives(&[a, b]).unwrap();
        assert_eq!(merged.n_shards(), 3);
        assert_eq!(merged.header.dims.0, 3 * KT);
        assert_eq!(outcome.shards_in, 5);
        assert_eq!(outcome.shards_out, 3);
        assert_eq!(outcome.dropped_duplicate, 2);
        assert_eq!(outcome.dropped_orphaned, 0);
        assert!(outcome.changed);
        assert!(verify_archive(&merged.bytes).unwrap().healthy());
        // merged bytes are byte-identical to the fuller run
        assert_eq!(merged.bytes, dense_archive(3).bytes);
    }

    #[test]
    fn compact_drops_orphans_after_short_shard() {
        // C ends on a short shard (nt 2 < kt_window 4): the chain closes
        let shards = vec![
            ShardPayload {
                t0: 0,
                nt: KT,
                latent_blob: Vec::new(),
                species: (0..NS).map(|_| dense_section(0.3)).collect(),
                codecs: vec![CodecTag::Dense; NS],
            },
            ShardPayload {
                t0: KT,
                nt: 2,
                latent_blob: Vec::new(),
                species: (0..NS).map(|_| dense_section(0.4)).collect(),
                codecs: vec![CodecTag::Dense; NS],
            },
        ];
        let c = Gba2Archive::build(header(KT + 2), shards).unwrap();
        let b = dense_archive(3);
        let (merged, outcome) = compact_archives(&[c.clone(), b]).unwrap();
        assert_eq!(merged.bytes, c.bytes);
        assert_eq!(outcome.dropped_duplicate, 1); // B shard 0 covers 0..4
        assert_eq!(outcome.dropped_orphaned, 2); // B shards 1, 2
    }

    #[test]
    fn compact_rejects_incompatible_layouts() {
        let a = dense_archive(1);
        let mut b = dense_archive(1);
        b.header.latent_dim += 1;
        assert!(compact_archives(&[a, b]).is_err());
        assert!(compact_archives(&[]).is_err());
    }
}
