//! Incremental `GBA2` writing for the streaming session API, with a
//! crash-consistent shard-completion journal.
//!
//! [`Gba2StreamWriter`] emits an archive to any [`StreamSink`] *shard by
//! shard*: the header + TOC region is reserved up front, each finished
//! shard's payload is appended immediately — so a compression session
//! never holds more than the shard it is working on — and `finish()`
//! seeks back and patches the real header + TOC into the reserved
//! region.
//!
//! The prefix is serialized by the same function
//! (`archive::toc::write_header_toc`) the one-shot
//! [`Gba2Archive::build`](crate::archive::Gba2Archive::build) uses, and
//! payload bytes land at identical offsets, so a streamed archive is
//! **byte-identical** to the batch-built archive for the same shards —
//! today's readers parse it with no changes (a trailing footer TOC was
//! rejected for exactly that reason; see DESIGN.md "Session API").
//!
//! ## Crash consistency
//!
//! A *sealed* archive's bytes are untouched by this machinery; the
//! journal lives entirely inside the reserved (otherwise zeroed) header
//! region of the **unsealed** file and is overwritten by the real
//! header + TOC at `finish()`:
//!
//! ```text
//! unsealed   [ GBJL header | rec 0 | rec 1 | … | 0-pad ][ shard 0 | … ]
//!               │              └─ one fixed-size slot per shard, CRC'd;
//!               │                 written + flushed only after that
//!               │                 shard's payload bytes are down
//!               └─ provisional Gba2Header + layout, CRC'd
//! sealed     [ GBA2 header + TOC (back-patched)        ][ shard 0 | … ]
//! ```
//!
//! Each non-final shard's payload is additionally followed by a 16-byte
//! `GBSH` trailer (length + CRC32 of the payload) that the *next*
//! shard's payload overwrites — a scan anchor for `gbatc repair` on
//! unsealed files.  The journal slot arithmetic fits inside the reserved
//! region for every layout (`82 + 8·ns + n·(34 + 9·ns)` ≤
//! `72 + 8·ns + n·(40 + 16·ns)` for all `n, ns ≥ 1`), so journaling
//! never shifts a payload offset: sealed bytes are identical to a
//! journal-free run.
//!
//! [`Gba2StreamWriter::resume`] scans the journal of an interrupted
//! stream, CRC-verifies every committed shard's payload, drops the torn
//! tail, and returns a writer positioned to continue — the sealed result
//! is byte-identical to an uninterrupted run (property-tested in
//! `tests/streaming_session.rs` by killing at every shard boundary).
//!
//! The container version (2 = all-GBATC layout, 3 = per-section codec
//! tags) must be declared at construction because the reserved region's
//! size depends on it; `finish()` re-derives the version from the tags
//! actually written and rejects a mismatch, so a misdeclared writer can
//! never emit an archive `Gba2Archive::build` would have laid out
//! differently.

use std::io::{Read, Seek, SeekFrom, Write};

use crate::archive::toc::{
    header_toc_len, write_header_toc, CodecTag, Gba2Header, ShardPayload, ShardToc, MAGIC2,
    VERSION2, VERSION3,
};
use crate::error::{Error, Result};
use crate::util::bytes::{ByteReader, ByteWriter};
use crate::util::crc32::{crc32, Crc32};

/// Magic of the unsealed-stream journal header (first bytes of a file a
/// killed writer leaves behind; replaced by `GBA2` at seal).
pub const JOURNAL_MAGIC: &[u8; 4] = b"GBJL";
/// Journal format version (independent of the container version).
pub(crate) const JOURNAL_VERSION: u8 = 1;
/// Magic of the per-shard payload trailer in an unsealed stream.
pub(crate) const TRAILER_MAGIC: &[u8; 4] = b"GBSH";
/// Trailer bytes: magic + payload length (u64) + payload CRC32.
pub(crate) const TRAILER_LEN: usize = 16;

/// Journal header bytes for `ns` species (fixed fields + per-species
/// range pair + CRC).
pub(crate) fn journal_header_len(ns: usize) -> usize {
    82 + 8 * ns
}

/// Journal record slot bytes for `ns` species.
pub(crate) fn journal_record_len(ns: usize) -> usize {
    34 + 9 * ns
}

/// A sink a [`Gba2StreamWriter`] can stream an archive to.
///
/// Beyond `Write + Seek` this captures the two durability operations the
/// crash-consistency protocol needs: forcing bytes to stable storage at
/// seal time and trimming a leftover journal trailer that would dangle
/// past the final payload byte.  Memory sinks get no-op durability;
/// sinks that cannot truncate only fail if a truncation is actually
/// required (final shard shorter than one trailer).
pub trait StreamSink: Write + Seek {
    /// Force all written bytes to durable storage (`fsync` for files;
    /// no-op for memory sinks).
    fn sync_durable(&mut self) -> std::io::Result<()> {
        Ok(())
    }

    /// Shrink the sink to exactly `len` bytes.
    fn truncate_to(&mut self, len: u64) -> std::io::Result<()> {
        let _ = len;
        Err(std::io::Error::new(
            std::io::ErrorKind::Unsupported,
            "sink does not support truncation",
        ))
    }
}

impl StreamSink for std::fs::File {
    fn sync_durable(&mut self) -> std::io::Result<()> {
        self.sync_all()
    }
    fn truncate_to(&mut self, len: u64) -> std::io::Result<()> {
        self.set_len(len)
    }
}

impl StreamSink for std::io::Cursor<Vec<u8>> {
    fn truncate_to(&mut self, len: u64) -> std::io::Result<()> {
        self.get_mut().truncate(len as usize);
        Ok(())
    }
}

impl<S: StreamSink + ?Sized> StreamSink for &mut S {
    fn sync_durable(&mut self) -> std::io::Result<()> {
        (**self).sync_durable()
    }
    fn truncate_to(&mut self, len: u64) -> std::io::Result<()> {
        (**self).truncate_to(len)
    }
}

/// Shape of one streaming archive, fixed before the first shard arrives.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StreamLayout {
    /// Total timesteps the shards must tile.
    pub nt: usize,
    /// Species per shard section list.
    pub ns: usize,
    /// Shard time-window width (last shard may be shorter).
    pub kt_window: usize,
    /// Shards that will be written (`ceil(nt / kt_window)`).
    pub n_shards: usize,
    /// Container version: 2 iff every section will be GBATC.
    pub version: u16,
}

/// Totals the writer reports once the archive is sealed.
#[derive(Clone, Debug)]
pub struct StreamSummary {
    /// Total serialized archive bytes (header + TOC + payloads).
    pub bytes: u64,
    /// Container version actually emitted (2 or 3).
    pub version: u16,
    /// Per-codec (sections, section bytes), indexed by `CodecTag as usize`.
    pub codec_totals: [(usize, u64); 3],
}

/// What [`Gba2StreamWriter::resume`] recovered from an interrupted
/// stream.
#[derive(Clone, Debug)]
pub struct ResumeReport {
    /// Committed shards whose payload bytes CRC-verified.
    pub shards: usize,
    /// Timesteps those shards cover (the resume point).
    pub timesteps: usize,
    /// Payload bytes retained (end offset of the last durable shard).
    pub bytes: u64,
    /// Whether any recovered section is GBATC (drives header model-byte
    /// accounting when the resumed session seals).
    pub any_gbatc: bool,
}

/// One committed shard as recorded in the journal (lengths only —
/// offsets are chained from the reserved-region size).
#[derive(Clone, Debug)]
pub(crate) struct JournalRecord {
    pub t0: usize,
    pub nt: usize,
    pub shard_len: u64,
    pub latent_len: u64,
    pub sec_lens: Vec<u64>,
    pub payload_crc: u32,
    pub codecs: Vec<CodecTag>,
}

fn journal_header_bytes(layout: &StreamLayout, h: &Gba2Header) -> Vec<u8> {
    let mut w = ByteWriter::new();
    w.bytes(JOURNAL_MAGIC);
    w.u8(JOURNAL_VERSION);
    w.u16(layout.version);
    w.u64(layout.nt as u64);
    w.u64(layout.ns as u64);
    w.u64(layout.kt_window as u64);
    w.u64(layout.n_shards as u64);
    w.u32(h.dims.2 as u32);
    w.u32(h.dims.3 as u32);
    w.u16(h.block.0 as u16);
    w.u16(h.block.1 as u16);
    w.u16(h.block.2 as u16);
    w.u32(h.latent_dim as u32);
    w.u8(if h.tcn_used { 1 } else { 0 });
    w.f64(h.pressure);
    w.f64(h.nrmse_target);
    w.u32(h.model_param_bytes.min(u32::MAX as u64) as u32);
    for &(lo, hi) in &h.ranges {
        w.f32(lo);
        w.f32(hi);
    }
    let body = w.finish();
    let mut out = ByteWriter::new();
    out.bytes(&body);
    out.u32(crc32(&body));
    out.finish()
}

/// Parse the journal header at the start of `prefix` (an unsealed
/// stream's bytes).  Distinguishes "already sealed" (starts with `GBA2`)
/// from garbage; the returned header carries the provisional field
/// metadata recorded at stream start.
pub(crate) fn parse_journal_header(prefix: &[u8]) -> Result<(StreamLayout, Gba2Header)> {
    if prefix.len() >= 4 && &prefix[..4] == MAGIC2 {
        return Err(Error::format(
            "GBA2 journal: archive is already sealed (GBA2 magic present)",
        ));
    }
    let mut r = ByteReader::new(prefix);
    if r.bytes(4)? != JOURNAL_MAGIC {
        return Err(Error::format(
            "GBA2 journal: no journal magic (not an unsealed stream)",
        ));
    }
    let jver = r.u8()?;
    if jver != JOURNAL_VERSION {
        return Err(Error::format(format!(
            "GBA2 journal: unsupported journal version {jver}"
        )));
    }
    let version = r.u16()?;
    if version != VERSION2 && version != VERSION3 {
        return Err(Error::format(format!(
            "GBA2 journal: unsupported container version {version}"
        )));
    }
    let nt = r.u64()? as usize;
    let ns = r.u64()? as usize;
    let kt_window = r.u64()? as usize;
    let n_shards = r.u64()? as usize;
    if ns == 0 || n_shards == 0 || kt_window == 0 || nt == 0 {
        return Err(Error::format(format!(
            "GBA2 journal: degenerate layout (nt {nt}, ns {ns}, shards {n_shards}, \
             kt_window {kt_window})"
        )));
    }
    let ny = r.u32()? as usize;
    let nx = r.u32()? as usize;
    let block = (r.u16()? as usize, r.u16()? as usize, r.u16()? as usize);
    let latent_dim = r.u32()? as usize;
    let tcn_used = r.u8()? != 0;
    let pressure = r.f64()?;
    let nrmse_target = r.f64()?;
    let model_param_bytes = r.u32()? as u64;
    let mut ranges = Vec::with_capacity(ns);
    for _ in 0..ns {
        ranges.push((r.f32()?, r.f32()?));
    }
    let body_len = r.pos();
    let crc = r.u32()?;
    if crc != crc32(&prefix[..body_len]) {
        return Err(Error::format("GBA2 journal: header CRC mismatch"));
    }
    debug_assert_eq!(body_len + 4, journal_header_len(ns));
    let layout = StreamLayout {
        nt,
        ns,
        kt_window,
        n_shards,
        version,
    };
    let header = Gba2Header {
        tcn_used,
        dims: (nt, ns, ny, nx),
        block,
        latent_dim,
        kt_window,
        pressure,
        nrmse_target,
        model_param_bytes,
        ranges,
    };
    Ok((layout, header))
}

/// Walk the journal record slots in `prefix`, returning the valid prefix
/// of committed-shard records (stops at the first empty, torn, or
/// inconsistent slot).  Payload bytes are *not* verified here — callers
/// CRC them against `payload_crc`.
pub(crate) fn parse_journal_records(prefix: &[u8], layout: &StreamLayout) -> Vec<JournalRecord> {
    let jh = journal_header_len(layout.ns);
    let rl = journal_record_len(layout.ns);
    let mut out = Vec::new();
    let mut expect_t0 = 0usize;
    for k in 0..layout.n_shards {
        let lo = jh + k * rl;
        let hi = lo + rl;
        if hi > prefix.len() {
            break;
        }
        let slot = &prefix[lo..hi];
        let body_len = rl - 4;
        let rec_crc = u32::from_le_bytes([slot[rl - 4], slot[rl - 3], slot[rl - 2], slot[rl - 1]]);
        if rec_crc != crc32(&slot[..body_len]) {
            break;
        }
        let mut r = ByteReader::new(slot);
        let parsed = (|| -> Result<JournalRecord> {
            let seq = r.u16()? as usize;
            let t0 = r.u32()? as usize;
            let nt = r.u32()? as usize;
            let shard_len = r.u64()?;
            let latent_len = r.u64()?;
            let mut sec_lens = Vec::with_capacity(layout.ns);
            for _ in 0..layout.ns {
                sec_lens.push(r.u64()?);
            }
            let payload_crc = r.u32()?;
            let mut codecs = Vec::with_capacity(layout.ns);
            for _ in 0..layout.ns {
                codecs.push(CodecTag::from_u8(r.u8()?)?);
            }
            if seq != k {
                return Err(Error::format("journal record sequence mismatch"));
            }
            Ok(JournalRecord {
                t0,
                nt,
                shard_len,
                latent_len,
                sec_lens,
                payload_crc,
                codecs,
            })
        })();
        let rec = match parsed {
            Ok(rec) => rec,
            Err(_) => break,
        };
        // the same tiling + length invariants write_shard enforced
        let full = k + 1 < layout.n_shards;
        let sections: u64 = rec.latent_len + rec.sec_lens.iter().sum::<u64>();
        if rec.t0 != expect_t0
            || rec.nt == 0
            || rec.nt > layout.kt_window
            || (full && rec.nt != layout.kt_window)
            || sections != rec.shard_len
            || (layout.version == VERSION2 && rec.codecs.iter().any(|&c| c != CodecTag::Gbatc))
        {
            break;
        }
        expect_t0 += rec.nt;
        out.push(rec);
    }
    out
}

/// Incremental `GBA2` writer over a seekable sink.
pub struct Gba2StreamWriter<W: StreamSink> {
    sink: W,
    layout: StreamLayout,
    base: u64,
    off: u64,
    toc: Vec<ShardToc>,
    expect_t0: usize,
    /// Journal header bytes (slot 0 starts here).
    jh_len: u64,
    /// Journal record slot stride.
    rec_len: u64,
    /// Highest byte ever written (payloads + trailers) — `finish`
    /// truncates when a stale trailer would dangle past the final
    /// payload byte.
    high_water: u64,
}

impl<W: StreamSink> Gba2StreamWriter<W> {
    /// Start an archive on `sink` (which must be empty and positioned at
    /// its start).  Reserves the header + TOC region — seeded with the
    /// crash-recovery journal header, zero elsewhere — so shard payloads
    /// can stream out before the TOC contents are known.
    ///
    /// The journal's provisional field metadata is zeroed; prefer
    /// [`new_with_header`](Self::new_with_header) when the final header
    /// is already known, so `gbatc repair` can seal an orphaned unsealed
    /// stream without the writing session.
    pub fn new(sink: W, layout: StreamLayout) -> Result<Gba2StreamWriter<W>> {
        let provisional = Gba2Header {
            tcn_used: false,
            dims: (layout.nt, layout.ns, 0, 0),
            block: (0, 0, 0),
            latent_dim: 0,
            kt_window: layout.kt_window,
            pressure: 0.0,
            nrmse_target: 0.0,
            model_param_bytes: 0,
            ranges: vec![(0.0, 0.0); layout.ns],
        };
        Self::new_with_header(sink, layout, &provisional)
    }

    /// [`new`](Self::new), but records `header` (provisionally — `finish`
    /// still takes the authoritative one) in the journal so repair tools
    /// can reconstruct a parseable archive from an unsealed stream.
    pub fn new_with_header(
        mut sink: W,
        layout: StreamLayout,
        header: &Gba2Header,
    ) -> Result<Gba2StreamWriter<W>> {
        if layout.version != VERSION2 && layout.version != VERSION3 {
            return Err(Error::format(format!(
                "GBA2 stream: unsupported version {}",
                layout.version
            )));
        }
        if layout.ns == 0 || layout.n_shards == 0 || layout.kt_window == 0 {
            return Err(Error::format(format!(
                "GBA2 stream: degenerate layout (ns {}, shards {}, kt_window {})",
                layout.ns, layout.n_shards, layout.kt_window
            )));
        }
        if header.ranges.len() != layout.ns {
            return Err(Error::format(format!(
                "GBA2 stream: {} ranges for {} species",
                header.ranges.len(),
                layout.ns
            )));
        }
        let base = header_toc_len(layout.ns, layout.n_shards, layout.version) as u64;
        let jh = journal_header_bytes(&layout, header);
        let jh_len = jh.len() as u64;
        let rec_len = journal_record_len(layout.ns) as u64;
        // proven to fit for every layout (see module docs) — the journal
        // must never spill into payload territory
        debug_assert!(jh_len + layout.n_shards as u64 * rec_len <= base);
        let mut region = vec![0u8; base as usize];
        region[..jh.len()].copy_from_slice(&jh);
        sink.seek(SeekFrom::Start(0))?;
        sink.write_all(&region)?;
        sink.flush()?;
        Ok(Gba2StreamWriter {
            sink,
            layout,
            base,
            off: base,
            toc: Vec::with_capacity(layout.n_shards),
            expect_t0: 0,
            jh_len,
            rec_len,
            high_water: base,
        })
    }

    /// Reopen an interrupted (unsealed) stream: scan the journal,
    /// CRC-verify every committed shard's payload bytes, drop the torn
    /// tail, and return a writer ready for the next shard plus a report
    /// of what survived.  Fails with a typed error on a sealed archive
    /// or an unrecognizable file.
    ///
    /// The caller must continue with the same field, policy, and codec
    /// configuration as the interrupted run — the sealed result is then
    /// byte-identical to an uninterrupted stream of the same shards.
    pub fn resume(mut sink: W) -> Result<(Gba2StreamWriter<W>, ResumeReport)>
    where
        W: Read,
    {
        let file_len = sink.seek(SeekFrom::End(0))?;
        sink.seek(SeekFrom::Start(0))?;
        // fixed journal fields end 78 bytes in; read them first to learn
        // ns / n_shards / version, then the full reserved region
        let fixed = (file_len as usize).min(journal_header_len(0) - 4);
        let mut prefix = vec![0u8; fixed];
        sink.read_exact(&mut prefix)?;
        let head_probe = parse_journal_header_fixed(&prefix)?;
        let (ns, n_shards, version) = head_probe;
        let base = header_toc_len(ns, n_shards, version) as u64;
        if file_len < base {
            return Err(Error::format(format!(
                "GBA2 resume: file truncated inside the reserved region \
                 ({file_len} of {base} bytes) — nothing recoverable"
            )));
        }
        prefix.resize(base as usize, 0);
        sink.read_exact(&mut prefix[fixed..])?;
        let (layout, _header) = parse_journal_header(&prefix)?;
        let records = parse_journal_records(&prefix, &layout);

        let mut toc = Vec::with_capacity(records.len());
        let mut off = base;
        let mut expect_t0 = 0usize;
        let mut any_gbatc = false;
        let mut buf = vec![0u8; 64 * 1024];
        'records: for rec in &records {
            if off + rec.shard_len > file_len {
                break; // torn payload tail
            }
            sink.seek(SeekFrom::Start(off))?;
            let mut crc = Crc32::new();
            let mut remaining = rec.shard_len as usize;
            while remaining > 0 {
                let n = remaining.min(buf.len());
                sink.read_exact(&mut buf[..n])?;
                crc.update(&buf[..n]);
                remaining -= n;
            }
            if crc.finalize() != rec.payload_crc {
                break 'records; // bit rot or torn write under the record
            }
            let latent = (off, rec.latent_len);
            let mut sec_off = off + rec.latent_len;
            let mut species = Vec::with_capacity(layout.ns);
            for &len in &rec.sec_lens {
                species.push((sec_off, len));
                sec_off += len;
            }
            any_gbatc |= rec.codecs.iter().any(|&c| c == CodecTag::Gbatc);
            toc.push(ShardToc {
                t0: rec.t0,
                nt: rec.nt,
                shard: (off, rec.shard_len),
                latent,
                species,
                codecs: rec.codecs.clone(),
            });
            off += rec.shard_len;
            expect_t0 += rec.nt;
        }

        sink.seek(SeekFrom::Start(off))?;
        let report = ResumeReport {
            shards: toc.len(),
            timesteps: expect_t0,
            bytes: off,
            any_gbatc,
        };
        Ok((
            Gba2StreamWriter {
                sink,
                layout,
                base,
                off,
                toc,
                expect_t0,
                jh_len: journal_header_len(layout.ns) as u64,
                rec_len: journal_record_len(layout.ns) as u64,
                high_water: file_len.max(base),
            },
            report,
        ))
    }

    /// Shards written so far.
    pub fn shards_written(&self) -> usize {
        self.toc.len()
    }

    /// Timesteps covered by the shards written so far.
    pub fn timesteps_written(&self) -> usize {
        self.expect_t0
    }

    /// The declared layout.
    pub fn layout(&self) -> &StreamLayout {
        &self.layout
    }

    /// Abandon the stream and hand back the (unsealed) sink — e.g. to
    /// close a file that a later `resume` will reopen.  No bytes are
    /// written; the journal already reflects every completed shard.
    pub fn abort(self) -> W {
        self.sink
    }

    /// Append one shard's payload (latent blob + species sections) and
    /// record its TOC entry.  Shards must arrive in time order and tile
    /// the time axis — the same invariants `Gba2Archive::build` enforces,
    /// checked here as each shard lands so a bad stream fails early.
    ///
    /// Durability protocol: payload bytes (plus, for non-final shards, a
    /// CRC trailer) are written and flushed *before* the journal record
    /// that commits the shard is written and flushed — a crash between
    /// the two leaves an uncommitted (ignored) payload, never a
    /// committed record over torn bytes.
    pub fn write_shard(&mut self, sh: &ShardPayload) -> Result<()> {
        let l = &self.layout;
        if self.toc.len() == l.n_shards {
            return Err(Error::format(format!(
                "GBA2 stream: shard at t0 {} beyond the declared {} shards",
                sh.t0, l.n_shards
            )));
        }
        let full = self.toc.len() + 1 < l.n_shards;
        if sh.t0 != self.expect_t0
            || sh.nt == 0
            || sh.nt > l.kt_window
            || (full && sh.nt != l.kt_window)
        {
            return Err(Error::format(format!(
                "GBA2 stream: shard at t0 {} (nt {}) does not tile (expected t0 {})",
                sh.t0, sh.nt, self.expect_t0
            )));
        }
        if sh.species.len() != l.ns || sh.codecs.len() != l.ns {
            return Err(Error::format(format!(
                "GBA2 stream: shard at t0 {} has {} species sections and {} codec tags, expected {}",
                sh.t0,
                sh.species.len(),
                sh.codecs.len(),
                l.ns
            )));
        }
        if l.version == VERSION2 && sh.codecs.iter().any(|&c| c != CodecTag::Gbatc) {
            return Err(Error::format(
                "GBA2 stream: non-GBATC section in a version-2 stream",
            ));
        }

        let shard_off = self.off;
        self.sink.seek(SeekFrom::Start(shard_off))?;
        let mut crc = Crc32::new();
        self.sink.write_all(&sh.latent_blob)?;
        crc.update(&sh.latent_blob);
        let latent = (shard_off, sh.latent_blob.len() as u64);
        let mut off = shard_off + latent.1;
        let mut species = Vec::with_capacity(l.ns);
        for sec in &sh.species {
            self.sink.write_all(sec)?;
            crc.update(sec);
            species.push((off, sec.len() as u64));
            off += sec.len() as u64;
        }
        let payload_crc = crc.finalize();
        let shard_len = off - shard_off;

        let mut high = off;
        if full {
            // scan anchor for repair; the next shard's payload overwrites it
            let mut tw = ByteWriter::new();
            tw.bytes(TRAILER_MAGIC);
            tw.u64(shard_len);
            tw.u32(payload_crc);
            let trailer = tw.finish();
            debug_assert_eq!(trailer.len(), TRAILER_LEN);
            self.sink.write_all(&trailer)?;
            high += TRAILER_LEN as u64;
        }
        // payload down before the record that commits it
        self.sink.flush()?;

        let k = self.toc.len();
        let mut rw = ByteWriter::new();
        rw.u16(k as u16);
        rw.u32(sh.t0 as u32);
        rw.u32(sh.nt as u32);
        rw.u64(shard_len);
        rw.u64(latent.1);
        for &(_, len) in &species {
            rw.u64(len);
        }
        rw.u32(payload_crc);
        for &c in &sh.codecs {
            rw.u8(c as u8);
        }
        let body = rw.finish();
        let mut rw = ByteWriter::new();
        rw.bytes(&body);
        rw.u32(crc32(&body));
        let rec = rw.finish();
        debug_assert_eq!(rec.len() as u64, self.rec_len);
        self.sink
            .seek(SeekFrom::Start(self.jh_len + k as u64 * self.rec_len))?;
        self.sink.write_all(&rec)?;
        self.sink.flush()?;

        self.high_water = self.high_water.max(high);
        self.toc.push(ShardToc {
            t0: sh.t0,
            nt: sh.nt,
            shard: (shard_off, shard_len),
            latent,
            species,
            codecs: sh.codecs.clone(),
        });
        self.expect_t0 += sh.nt;
        self.off = off;
        Ok(())
    }

    /// Seal the archive: validate coverage, back-patch the header + TOC
    /// over the journal in the reserved region, trim any dangling
    /// trailer, flush, sync, and hand the sink back.  The header's
    /// dims/kt_window must match the declared layout.
    pub fn finish(mut self, header: &Gba2Header) -> Result<(W, StreamSummary)> {
        let l = self.layout;
        if self.toc.len() != l.n_shards || self.expect_t0 != l.nt {
            return Err(Error::format(format!(
                "GBA2 stream: {} of {} shards covering {} of {} timesteps at finish",
                self.toc.len(),
                l.n_shards,
                self.expect_t0,
                l.nt
            )));
        }
        if header.dims.0 != l.nt
            || header.dims.1 != l.ns
            || header.kt_window != l.kt_window
            || header.ranges.len() != l.ns
        {
            return Err(Error::format(format!(
                "GBA2 stream: header (dims {:?}, kt_window {}, {} ranges) does not match \
                 the declared layout (nt {}, ns {}, kt_window {})",
                header.dims,
                header.kt_window,
                header.ranges.len(),
                l.nt,
                l.ns,
                l.kt_window
            )));
        }
        // the version governs the TOC entry size, so a misdeclaration
        // would shift every payload offset — re-derive and reject
        let mixed = self
            .toc
            .iter()
            .any(|e| e.codecs.iter().any(|&c| c != CodecTag::Gbatc));
        let derived = if mixed { VERSION3 } else { VERSION2 };
        if derived != l.version {
            return Err(Error::format(format!(
                "GBA2 stream: declared version {} but sections require version {derived}",
                l.version
            )));
        }

        let mut w = ByteWriter::new();
        write_header_toc(&mut w, header, &self.toc, l.version);
        let prefix = w.finish();
        debug_assert_eq!(prefix.len() as u64, self.base);
        self.sink.seek(SeekFrom::Start(0))?;
        self.sink.write_all(&prefix)?;
        if self.high_water > self.off {
            // a stale trailer (or resumed file tail) dangles past the
            // final payload byte — the strict parser requires the file
            // to end exactly at the last TOC offset
            self.sink.truncate_to(self.off)?;
        }
        self.sink.seek(SeekFrom::Start(self.off))?;
        self.sink.flush()?;
        self.sink.sync_durable()?;

        let mut codec_totals = [(0usize, 0u64); 3];
        for e in &self.toc {
            for (&(_, len), &tag) in e.species.iter().zip(&e.codecs) {
                let slot = &mut codec_totals[tag as usize];
                slot.0 += 1;
                slot.1 += len;
            }
        }
        Ok((
            self.sink,
            StreamSummary {
                bytes: self.off,
                version: l.version,
                codec_totals,
            },
        ))
    }
}

/// Parse just the fixed (pre-ranges) journal fields — enough to size the
/// reserved region before the full prefix can be read.
fn parse_journal_header_fixed(prefix: &[u8]) -> Result<(usize, usize, u16)> {
    if prefix.len() >= 4 && &prefix[..4] == MAGIC2 {
        return Err(Error::format(
            "GBA2 journal: archive is already sealed (GBA2 magic present)",
        ));
    }
    let mut r = ByteReader::new(prefix);
    if r.bytes(4)? != JOURNAL_MAGIC {
        return Err(Error::format(
            "GBA2 journal: no journal magic (not an unsealed stream)",
        ));
    }
    let jver = r.u8()?;
    if jver != JOURNAL_VERSION {
        return Err(Error::format(format!(
            "GBA2 journal: unsupported journal version {jver}"
        )));
    }
    let version = r.u16()?;
    if version != VERSION2 && version != VERSION3 {
        return Err(Error::format(format!(
            "GBA2 journal: unsupported container version {version}"
        )));
    }
    let _nt = r.u64()?;
    let ns = r.u64()? as usize;
    let _kt = r.u64()?;
    let n_shards = r.u64()? as usize;
    if ns == 0 || n_shards == 0 {
        return Err(Error::format("GBA2 journal: degenerate layout"));
    }
    Ok((ns, n_shards, version))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::archive::Gba2Archive;
    use std::io::Cursor;

    fn header(model: u64) -> Gba2Header {
        Gba2Header {
            tcn_used: true,
            dims: (8, 2, 10, 8),
            block: (4, 5, 4),
            latent_dim: 6,
            kt_window: 4,
            pressure: 40.0e5,
            nrmse_target: 1e-3,
            model_param_bytes: model,
            ranges: vec![(0.0, 1.0), (-1.0, 2.0)],
        }
    }

    fn shards_v2() -> Vec<ShardPayload> {
        vec![
            ShardPayload::gbatc(0, 4, vec![1, 2, 3], vec![vec![9; 7], vec![8; 5]]),
            ShardPayload::gbatc(4, 4, vec![4, 5], vec![vec![7; 3], vec![6; 11]]),
        ]
    }

    fn shards_v3() -> Vec<ShardPayload> {
        vec![
            ShardPayload {
                t0: 0,
                nt: 4,
                latent_blob: vec![1, 2, 3],
                species: vec![vec![9; 7], vec![0xAB; 17]],
                codecs: vec![CodecTag::Gbatc, CodecTag::Sz],
            },
            ShardPayload {
                t0: 4,
                nt: 4,
                latent_blob: Vec::new(),
                species: vec![vec![0xCD; 9], vec![0xEF; 5]],
                codecs: vec![CodecTag::Dense, CodecTag::Sz],
            },
        ]
    }

    fn layout(version: u16) -> StreamLayout {
        StreamLayout {
            nt: 8,
            ns: 2,
            kt_window: 4,
            n_shards: 2,
            version,
        }
    }

    /// The streamed bytes must equal `Gba2Archive::build` exactly — the
    /// invariant the session's byte-identity property test rests on.
    /// (The v3 final shard is shorter than one trailer, so this also
    /// exercises the dangling-trailer truncation at seal.)
    #[test]
    fn streamed_archive_is_byte_identical_to_build() {
        for (version, shards) in [(2u16, shards_v2()), (3, shards_v3())] {
            let batch = Gba2Archive::build(header(0), shards.clone()).unwrap();
            let mut w = Gba2StreamWriter::new(Cursor::new(Vec::new()), layout(version)).unwrap();
            for sh in &shards {
                w.write_shard(sh).unwrap();
            }
            let (sink, summary) = w.finish(&header(0)).unwrap();
            let streamed = sink.into_inner();
            assert_eq!(summary.bytes as usize, streamed.len());
            assert_eq!(summary.version, version);
            assert_eq!(streamed, batch.bytes, "version {version} bytes differ");
            // and it parses back with the right TOC
            let back = Gba2Archive::deserialize(&streamed).unwrap();
            assert_eq!(back.toc.len(), 2);
            assert_eq!(back.version(), version);
        }
    }

    #[test]
    fn stream_misuse_is_rejected() {
        // non-tiling shard
        let mut w = Gba2StreamWriter::new(Cursor::new(Vec::new()), layout(2)).unwrap();
        let mut bad = shards_v2()[1].clone();
        bad.t0 = 2;
        assert!(w.write_shard(&bad).is_err());
        // v2 stream refuses tagged sections
        let mut w = Gba2StreamWriter::new(Cursor::new(Vec::new()), layout(2)).unwrap();
        assert!(w.write_shard(&shards_v3()[0]).is_err());
        // finishing before every shard arrived
        let mut w = Gba2StreamWriter::new(Cursor::new(Vec::new()), layout(2)).unwrap();
        w.write_shard(&shards_v2()[0]).unwrap();
        assert!(w.finish(&header(0)).is_err());
        // declared v3 but all sections GBATC — layout mismatch at finish
        let mut w = Gba2StreamWriter::new(Cursor::new(Vec::new()), layout(3)).unwrap();
        for sh in shards_v2() {
            w.write_shard(&sh).unwrap();
        }
        assert!(w.finish(&header(0)).is_err());
        // header inconsistent with the declared layout
        let mut w = Gba2StreamWriter::new(Cursor::new(Vec::new()), layout(2)).unwrap();
        for sh in shards_v2() {
            w.write_shard(&sh).unwrap();
        }
        let mut h = header(0);
        h.kt_window = 8;
        assert!(w.finish(&h).is_err());
    }

    #[test]
    fn extra_shards_rejected() {
        let mut w = Gba2StreamWriter::new(Cursor::new(Vec::new()), layout(2)).unwrap();
        for sh in shards_v2() {
            w.write_shard(&sh).unwrap();
        }
        let extra = ShardPayload::gbatc(8, 4, Vec::new(), vec![vec![1], vec![2]]);
        assert!(w.write_shard(&extra).is_err());
    }

    /// The journal survives an abandoned stream: resume after shard 0,
    /// write shard 1, and the sealed bytes equal an uninterrupted run.
    #[test]
    fn resume_after_clean_kill_is_byte_identical() {
        for (version, shards) in [(2u16, shards_v2()), (3, shards_v3())] {
            let batch = Gba2Archive::build(header(0), shards.clone()).unwrap();
            let mut w =
                Gba2StreamWriter::new_with_header(Cursor::new(Vec::new()), layout(version), &header(0))
                    .unwrap();
            w.write_shard(&shards[0]).unwrap();
            let unsealed = w.abort().into_inner();

            let (mut w, report) = Gba2StreamWriter::resume(Cursor::new(unsealed)).unwrap();
            assert_eq!(report.shards, 1);
            assert_eq!(report.timesteps, 4);
            assert_eq!(
                report.any_gbatc,
                shards[0].codecs.iter().any(|&c| c == CodecTag::Gbatc)
            );
            w.write_shard(&shards[1]).unwrap();
            let (sink, summary) = w.finish(&header(0)).unwrap();
            assert_eq!(summary.version, version);
            assert_eq!(sink.into_inner(), batch.bytes, "v{version} resume differs");
        }
    }

    /// A torn or bit-rotted tail is dropped: only CRC-clean committed
    /// shards survive resume, and rewriting the rest still seals
    /// byte-identically.
    #[test]
    fn resume_drops_torn_and_corrupt_tails() {
        let shards = shards_v2();
        let batch = Gba2Archive::build(header(0), shards.clone()).unwrap();
        let full_unsealed = {
            let mut w = Gba2StreamWriter::new(Cursor::new(Vec::new()), layout(2)).unwrap();
            for sh in &shards {
                w.write_shard(sh).unwrap();
            }
            w.abort().into_inner()
        };

        // torn: final shard's payload loses its last 3 bytes
        let mut torn = full_unsealed.clone();
        torn.truncate(torn.len() - 3);
        let (mut w, report) = Gba2StreamWriter::resume(Cursor::new(torn)).unwrap();
        assert_eq!(report.shards, 1, "torn tail must drop the last shard");
        w.write_shard(&shards[1]).unwrap();
        let (sink, _) = w.finish(&header(0)).unwrap();
        assert_eq!(sink.into_inner(), batch.bytes);

        // bit rot inside the last shard's payload
        let mut rotted = full_unsealed.clone();
        let n = rotted.len();
        rotted[n - 2] ^= 0x40;
        let (mut w, report) = Gba2StreamWriter::resume(Cursor::new(rotted)).unwrap();
        assert_eq!(report.shards, 1, "payload CRC must reject the rotted shard");
        w.write_shard(&shards[1]).unwrap();
        let (sink, _) = w.finish(&header(0)).unwrap();
        assert_eq!(sink.into_inner(), batch.bytes);

        // everything intact: resume finds both shards and seals directly
        let (w, report) = Gba2StreamWriter::resume(Cursor::new(full_unsealed)).unwrap();
        assert_eq!(report.shards, 2);
        assert_eq!(report.timesteps, 8);
        let (sink, _) = w.finish(&header(0)).unwrap();
        assert_eq!(sink.into_inner(), batch.bytes);
    }

    #[test]
    fn resume_rejects_sealed_and_garbage_files() {
        let shards = shards_v2();
        let batch = Gba2Archive::build(header(0), shards).unwrap();
        let err = Gba2StreamWriter::resume(Cursor::new(batch.bytes)).unwrap_err();
        assert!(
            err.to_string().contains("sealed"),
            "sealed archive must be called out: {err}"
        );
        assert!(Gba2StreamWriter::resume(Cursor::new(vec![0u8; 64])).is_err());
        assert!(Gba2StreamWriter::resume(Cursor::new(Vec::new())).is_err());
        // journal header bit rot
        let mut w = Gba2StreamWriter::new(Cursor::new(Vec::new()), layout(2)).unwrap();
        w.write_shard(&shards_v2()[0]).unwrap();
        let mut unsealed = w.abort().into_inner();
        unsealed[40] ^= 0x01; // inside the journal header's CRC coverage
        assert!(Gba2StreamWriter::resume(Cursor::new(unsealed)).is_err());
    }

    /// The journal's provisional header round-trips, so repair can seal
    /// an orphaned stream without the writing session.
    #[test]
    fn journal_header_round_trips_provisional_metadata() {
        let mut w =
            Gba2StreamWriter::new_with_header(Cursor::new(Vec::new()), layout(2), &header(123))
                .unwrap();
        w.write_shard(&shards_v2()[0]).unwrap();
        let unsealed = w.abort().into_inner();
        let (lay, h) = parse_journal_header(&unsealed).unwrap();
        assert_eq!(lay, layout(2));
        assert_eq!(h.dims, (8, 2, 10, 8));
        assert_eq!(h.block, (4, 5, 4));
        assert_eq!(h.latent_dim, 6);
        assert!(h.tcn_used);
        assert_eq!(h.model_param_bytes, 123);
        assert_eq!(h.ranges, vec![(0.0, 1.0), (-1.0, 2.0)]);
        let recs = parse_journal_records(&unsealed, &lay);
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].shard_len, 3 + 7 + 5);
    }
}
