//! Incremental `GBA2` writing for the streaming session API.
//!
//! [`Gba2StreamWriter`] emits an archive to any `io::Write + io::Seek`
//! sink *shard by shard*: the header + TOC region is reserved (zeroed)
//! up front, each finished shard's payload is appended immediately — so
//! a compression session never holds more than the shard it is working
//! on — and `finish()` seeks back and patches the real header + TOC into
//! the reserved region.
//!
//! The prefix is serialized by the same function
//! (`archive::toc::write_header_toc`) the one-shot
//! [`Gba2Archive::build`](crate::archive::Gba2Archive::build) uses, and
//! payload bytes land at identical offsets, so a streamed archive is
//! **byte-identical** to the batch-built archive for the same shards —
//! today's readers parse it with no changes (a trailing footer TOC was
//! rejected for exactly that reason; see DESIGN.md "Session API").
//!
//! The container version (2 = all-GBATC layout, 3 = per-section codec
//! tags) must be declared at construction because the reserved region's
//! size depends on it; `finish()` re-derives the version from the tags
//! actually written and rejects a mismatch, so a misdeclared writer can
//! never emit an archive `Gba2Archive::build` would have laid out
//! differently.

use std::io::{Seek, SeekFrom, Write};

use crate::archive::toc::{
    header_toc_len, write_header_toc, CodecTag, Gba2Header, ShardPayload, ShardToc, VERSION2,
    VERSION3,
};
use crate::error::{Error, Result};
use crate::util::bytes::ByteWriter;

/// Shape of one streaming archive, fixed before the first shard arrives.
#[derive(Clone, Copy, Debug)]
pub struct StreamLayout {
    /// Total timesteps the shards must tile.
    pub nt: usize,
    /// Species per shard section list.
    pub ns: usize,
    /// Shard time-window width (last shard may be shorter).
    pub kt_window: usize,
    /// Shards that will be written (`ceil(nt / kt_window)`).
    pub n_shards: usize,
    /// Container version: 2 iff every section will be GBATC.
    pub version: u16,
}

/// Totals the writer reports once the archive is sealed.
#[derive(Clone, Debug)]
pub struct StreamSummary {
    /// Total serialized archive bytes (header + TOC + payloads).
    pub bytes: u64,
    /// Container version actually emitted (2 or 3).
    pub version: u16,
    /// Per-codec (sections, section bytes), indexed by `CodecTag as usize`.
    pub codec_totals: [(usize, u64); 3],
}

/// Incremental `GBA2` writer over a seekable sink.
pub struct Gba2StreamWriter<W: Write + Seek> {
    sink: W,
    layout: StreamLayout,
    base: u64,
    off: u64,
    toc: Vec<ShardToc>,
    expect_t0: usize,
}

impl<W: Write + Seek> Gba2StreamWriter<W> {
    /// Start an archive on `sink` (which must be empty and positioned at
    /// its start).  Reserves the header + TOC region with zeros so shard
    /// payloads can stream out before the TOC contents are known.
    pub fn new(mut sink: W, layout: StreamLayout) -> Result<Gba2StreamWriter<W>> {
        if layout.version != VERSION2 && layout.version != VERSION3 {
            return Err(Error::format(format!(
                "GBA2 stream: unsupported version {}",
                layout.version
            )));
        }
        if layout.ns == 0 || layout.n_shards == 0 || layout.kt_window == 0 {
            return Err(Error::format(format!(
                "GBA2 stream: degenerate layout (ns {}, shards {}, kt_window {})",
                layout.ns, layout.n_shards, layout.kt_window
            )));
        }
        let base = header_toc_len(layout.ns, layout.n_shards, layout.version) as u64;
        sink.seek(SeekFrom::Start(0))?;
        sink.write_all(&vec![0u8; base as usize])?;
        Ok(Gba2StreamWriter {
            sink,
            layout,
            base,
            off: base,
            toc: Vec::with_capacity(layout.n_shards),
            expect_t0: 0,
        })
    }

    /// Shards written so far.
    pub fn shards_written(&self) -> usize {
        self.toc.len()
    }

    /// Append one shard's payload (latent blob + species sections) and
    /// record its TOC entry.  Shards must arrive in time order and tile
    /// the time axis — the same invariants `Gba2Archive::build` enforces,
    /// checked here as each shard lands so a bad stream fails early.
    pub fn write_shard(&mut self, sh: &ShardPayload) -> Result<()> {
        let l = &self.layout;
        if self.toc.len() == l.n_shards {
            return Err(Error::format(format!(
                "GBA2 stream: shard at t0 {} beyond the declared {} shards",
                sh.t0, l.n_shards
            )));
        }
        let full = self.toc.len() + 1 < l.n_shards;
        if sh.t0 != self.expect_t0
            || sh.nt == 0
            || sh.nt > l.kt_window
            || (full && sh.nt != l.kt_window)
        {
            return Err(Error::format(format!(
                "GBA2 stream: shard at t0 {} (nt {}) does not tile (expected t0 {})",
                sh.t0, sh.nt, self.expect_t0
            )));
        }
        if sh.species.len() != l.ns || sh.codecs.len() != l.ns {
            return Err(Error::format(format!(
                "GBA2 stream: shard at t0 {} has {} species sections and {} codec tags, expected {}",
                sh.t0,
                sh.species.len(),
                sh.codecs.len(),
                l.ns
            )));
        }
        if l.version == VERSION2 && sh.codecs.iter().any(|&c| c != CodecTag::Gbatc) {
            return Err(Error::format(
                "GBA2 stream: non-GBATC section in a version-2 stream",
            ));
        }

        let shard_off = self.off;
        self.sink.write_all(&sh.latent_blob)?;
        let latent = (shard_off, sh.latent_blob.len() as u64);
        let mut off = shard_off + latent.1;
        let mut species = Vec::with_capacity(l.ns);
        for sec in &sh.species {
            self.sink.write_all(sec)?;
            species.push((off, sec.len() as u64));
            off += sec.len() as u64;
        }
        self.toc.push(ShardToc {
            t0: sh.t0,
            nt: sh.nt,
            shard: (shard_off, off - shard_off),
            latent,
            species,
            codecs: sh.codecs.clone(),
        });
        self.expect_t0 += sh.nt;
        self.off = off;
        Ok(())
    }

    /// Seal the archive: validate coverage, back-patch the header + TOC
    /// into the reserved region, flush, and hand the sink back.  The
    /// header's dims/kt_window must match the declared layout.
    pub fn finish(mut self, header: &Gba2Header) -> Result<(W, StreamSummary)> {
        let l = self.layout;
        if self.toc.len() != l.n_shards || self.expect_t0 != l.nt {
            return Err(Error::format(format!(
                "GBA2 stream: {} of {} shards covering {} of {} timesteps at finish",
                self.toc.len(),
                l.n_shards,
                self.expect_t0,
                l.nt
            )));
        }
        if header.dims.0 != l.nt
            || header.dims.1 != l.ns
            || header.kt_window != l.kt_window
            || header.ranges.len() != l.ns
        {
            return Err(Error::format(format!(
                "GBA2 stream: header (dims {:?}, kt_window {}, {} ranges) does not match \
                 the declared layout (nt {}, ns {}, kt_window {})",
                header.dims,
                header.kt_window,
                header.ranges.len(),
                l.nt,
                l.ns,
                l.kt_window
            )));
        }
        // the version governs the TOC entry size, so a misdeclaration
        // would shift every payload offset — re-derive and reject
        let mixed = self
            .toc
            .iter()
            .any(|e| e.codecs.iter().any(|&c| c != CodecTag::Gbatc));
        let derived = if mixed { VERSION3 } else { VERSION2 };
        if derived != l.version {
            return Err(Error::format(format!(
                "GBA2 stream: declared version {} but sections require version {derived}",
                l.version
            )));
        }

        let mut w = ByteWriter::new();
        write_header_toc(&mut w, header, &self.toc, l.version);
        let prefix = w.finish();
        debug_assert_eq!(prefix.len() as u64, self.base);
        self.sink.seek(SeekFrom::Start(0))?;
        self.sink.write_all(&prefix)?;
        self.sink.seek(SeekFrom::Start(self.off))?;
        self.sink.flush()?;

        let mut codec_totals = [(0usize, 0u64); 3];
        for e in &self.toc {
            for (&(_, len), &tag) in e.species.iter().zip(&e.codecs) {
                let slot = &mut codec_totals[tag as usize];
                slot.0 += 1;
                slot.1 += len;
            }
        }
        Ok((
            self.sink,
            StreamSummary {
                bytes: self.off,
                version: l.version,
                codec_totals,
            },
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::archive::Gba2Archive;
    use std::io::Cursor;

    fn header(model: u64) -> Gba2Header {
        Gba2Header {
            tcn_used: true,
            dims: (8, 2, 10, 8),
            block: (4, 5, 4),
            latent_dim: 6,
            kt_window: 4,
            pressure: 40.0e5,
            nrmse_target: 1e-3,
            model_param_bytes: model,
            ranges: vec![(0.0, 1.0), (-1.0, 2.0)],
        }
    }

    fn shards_v2() -> Vec<ShardPayload> {
        vec![
            ShardPayload::gbatc(0, 4, vec![1, 2, 3], vec![vec![9; 7], vec![8; 5]]),
            ShardPayload::gbatc(4, 4, vec![4, 5], vec![vec![7; 3], vec![6; 11]]),
        ]
    }

    fn shards_v3() -> Vec<ShardPayload> {
        vec![
            ShardPayload {
                t0: 0,
                nt: 4,
                latent_blob: vec![1, 2, 3],
                species: vec![vec![9; 7], vec![0xAB; 17]],
                codecs: vec![CodecTag::Gbatc, CodecTag::Sz],
            },
            ShardPayload {
                t0: 4,
                nt: 4,
                latent_blob: Vec::new(),
                species: vec![vec![0xCD; 9], vec![0xEF; 5]],
                codecs: vec![CodecTag::Dense, CodecTag::Sz],
            },
        ]
    }

    fn layout(version: u16) -> StreamLayout {
        StreamLayout {
            nt: 8,
            ns: 2,
            kt_window: 4,
            n_shards: 2,
            version,
        }
    }

    /// The streamed bytes must equal `Gba2Archive::build` exactly — the
    /// invariant the session's byte-identity property test rests on.
    #[test]
    fn streamed_archive_is_byte_identical_to_build() {
        for (version, shards) in [(2u16, shards_v2()), (3, shards_v3())] {
            let batch = Gba2Archive::build(header(0), shards.clone()).unwrap();
            let mut w = Gba2StreamWriter::new(Cursor::new(Vec::new()), layout(version)).unwrap();
            for sh in &shards {
                w.write_shard(sh).unwrap();
            }
            let (sink, summary) = w.finish(&header(0)).unwrap();
            let streamed = sink.into_inner();
            assert_eq!(summary.bytes as usize, streamed.len());
            assert_eq!(summary.version, version);
            assert_eq!(streamed, batch.bytes, "version {version} bytes differ");
            // and it parses back with the right TOC
            let back = Gba2Archive::deserialize(&streamed).unwrap();
            assert_eq!(back.toc.len(), 2);
            assert_eq!(back.version(), version);
        }
    }

    #[test]
    fn stream_misuse_is_rejected() {
        // non-tiling shard
        let mut w = Gba2StreamWriter::new(Cursor::new(Vec::new()), layout(2)).unwrap();
        let mut bad = shards_v2()[1].clone();
        bad.t0 = 2;
        assert!(w.write_shard(&bad).is_err());
        // v2 stream refuses tagged sections
        let mut w = Gba2StreamWriter::new(Cursor::new(Vec::new()), layout(2)).unwrap();
        assert!(w.write_shard(&shards_v3()[0]).is_err());
        // finishing before every shard arrived
        let mut w = Gba2StreamWriter::new(Cursor::new(Vec::new()), layout(2)).unwrap();
        w.write_shard(&shards_v2()[0]).unwrap();
        assert!(w.finish(&header(0)).is_err());
        // declared v3 but all sections GBATC — layout mismatch at finish
        let mut w = Gba2StreamWriter::new(Cursor::new(Vec::new()), layout(3)).unwrap();
        for sh in shards_v2() {
            w.write_shard(&sh).unwrap();
        }
        assert!(w.finish(&header(0)).is_err());
        // header inconsistent with the declared layout
        let mut w = Gba2StreamWriter::new(Cursor::new(Vec::new()), layout(2)).unwrap();
        for sh in shards_v2() {
            w.write_shard(&sh).unwrap();
        }
        let mut h = header(0);
        h.kt_window = 8;
        assert!(w.finish(&h).is_err());
    }

    #[test]
    fn extra_shards_rejected() {
        let mut w = Gba2StreamWriter::new(Cursor::new(Vec::new()), layout(2)).unwrap();
        for sh in shards_v2() {
            w.write_shard(&sh).unwrap();
        }
        let extra = ShardPayload::gbatc(8, 4, Vec::new(), vec![vec![1], vec![2]]);
        assert!(w.write_shard(&extra).is_err());
    }
}
