//! Archive containers — everything the decompressor needs: dims,
//! per-species normalization ranges, Huffman-coded latent planes, and
//! per-species PCA bases + guarantee coefficients.
//!
//! Two on-disk formats live behind one API:
//! * **`GBA1`** ([`format::Archive`]) — the legacy single-shot container.
//! * **`GBA2`** ([`toc::Gba2Archive`]) — the sharded, TOC-indexed
//!   container with per-(shard, species) byte ranges, enabling
//!   random-access partial decode through [`toc::SectionSource`].
//!
//! [`AnyArchive`] dispatches on the magic so every reader accepts both;
//! `GBA1` archives convert losslessly into one-shard `GBA2` views.
//! Model parameters (decoder + TCN) live in the AOT artifacts shared
//! across archives; their bytes are charged to the compression ratio by
//! `compressor::accounting`, following the paper's accounting of
//! "network parameters".

pub mod format;
pub mod metered;
pub mod mmap;
pub mod repair;
pub mod stream;
pub mod toc;

pub use format::{Archive, SpeciesSection, MAGIC};
pub use metered::{IoStats, MeteredSource};
pub use mmap::MmapSource;
pub use repair::{
    compact_archives, repair_archive, verify_archive, RepairOutcome, SectionHealth, VerifyReport,
};
pub use stream::{
    Gba2StreamWriter, ResumeReport, StreamLayout, StreamSink, StreamSummary, JOURNAL_MAGIC,
};
pub use toc::{
    CodecTag, CountingSource, FileSource, Gba2Archive, Gba2Header, MemSource, SectionSource,
    ShardPayload, ShardToc, SliceSource, MAGIC2,
};

use crate::error::{Error, Result};
use std::io::Read;
use std::path::Path;

/// A deserialized archive of either version.
#[derive(Clone, Debug)]
pub enum AnyArchive {
    V1(Archive),
    V2(Gba2Archive),
}

impl AnyArchive {
    /// Parse either format, dispatching on the magic.
    pub fn deserialize(buf: &[u8]) -> Result<AnyArchive> {
        if buf.starts_with(MAGIC) {
            Ok(AnyArchive::V1(Archive::deserialize(buf)?))
        } else if buf.starts_with(MAGIC2) {
            Ok(AnyArchive::V2(Gba2Archive::deserialize(buf)?))
        } else {
            Err(Error::format(
                "unknown archive magic (expected GBA1 or GBA2)",
            ))
        }
    }

    pub fn read_file<P: AsRef<Path>>(path: P) -> Result<AnyArchive> {
        let mut bytes = Vec::new();
        std::fs::File::open(path.as_ref())?.read_to_end(&mut bytes)?;
        Self::deserialize(&bytes)
    }

    /// Format version (1, 2, or 3 — mixed-codec containers report 3).
    pub fn version(&self) -> u16 {
        match self {
            AnyArchive::V1(_) => 1,
            AnyArchive::V2(a) => a.version(),
        }
    }

    pub fn dims(&self) -> (usize, usize, usize, usize) {
        match self {
            AnyArchive::V1(a) => a.dims,
            AnyArchive::V2(a) => a.header.dims,
        }
    }

    pub fn nrmse_target(&self) -> f64 {
        match self {
            AnyArchive::V1(a) => a.nrmse_target,
            AnyArchive::V2(a) => a.header.nrmse_target,
        }
    }

    pub fn compression_ratio(&self) -> f64 {
        match self {
            AnyArchive::V1(a) => a.compression_ratio(),
            AnyArchive::V2(a) => a.compression_ratio(),
        }
    }

    /// View as `GBA2` — the engine's working representation.  `GBA1`
    /// archives become one-shard `GBA2` views losslessly.
    pub fn into_v2(self) -> Result<Gba2Archive> {
        match self {
            AnyArchive::V1(a) => Gba2Archive::from_v1(&a),
            AnyArchive::V2(a) => Ok(a),
        }
    }
}
