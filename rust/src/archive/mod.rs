//! The `.gba` archive container — everything the decompressor needs:
//! dims, per-species normalization ranges, the Huffman-coded latent plane,
//! and per-species PCA bases + guarantee coefficients.  Model parameters
//! (decoder + TCN) live in the AOT artifacts shared across archives; their
//! bytes are charged to the compression ratio by `compressor::accounting`,
//! following the paper's accounting of "network parameters".

pub mod format;

pub use format::{Archive, SpeciesSection, MAGIC};
