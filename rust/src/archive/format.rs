//! Binary layout of the `.gba` archive (all little-endian, no serde):
//!
//! ```text
//! magic "GBA1" | version u16 | flags u16 (bit0: TCN used)
//! nt ns ny nx  u32 x4 | block kt by bx u32 x3 | latent u32
//! pressure f64
//! per-species ranges: ns x (lo f32, hi f32)
//! latent blob  (LatentCodec payload)
//! ns x species section: basis (SpeciesBasis) + coeff blob (CoeffCodec)
//! footer: model_param_bytes u64 (accounting), nrmse_target f64
//! ```

use std::fs::File;
use std::io::{Read, Write};
use std::path::Path;

use crate::error::{Error, Result};
use crate::gae::SpeciesBasis;
use crate::util::bytes::{ByteReader, ByteWriter};

pub const MAGIC: &[u8; 4] = b"GBA1";
const VERSION: u16 = 1;

/// Per-species guarantee payload.
#[derive(Clone, Debug)]
pub struct SpeciesSection {
    pub basis: SpeciesBasis,
    /// CoeffCodec payload.
    pub coeffs: Vec<u8>,
}

impl SpeciesSection {
    /// Standalone serialized form — byte-identical to the inline `GBA1`
    /// encoding, and what the `GBA2` TOC points at per (shard, species).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        self.basis.serialize(&mut w);
        w.blob(&self.coeffs);
        w.finish()
    }

    pub fn from_bytes(buf: &[u8]) -> Result<SpeciesSection> {
        let mut r = ByteReader::new(buf);
        let basis = SpeciesBasis::deserialize(&mut r)?;
        let coeffs = r.blob()?.to_vec();
        if r.remaining() != 0 {
            return Err(Error::format(format!(
                "species section: {} trailing bytes",
                r.remaining()
            )));
        }
        Ok(SpeciesSection { basis, coeffs })
    }
}

/// In-memory archive.
#[derive(Clone, Debug)]
pub struct Archive {
    pub tcn_used: bool,
    pub dims: (usize, usize, usize, usize), // nt, ns, ny, nx
    pub block: (usize, usize, usize),
    pub latent_dim: usize,
    pub pressure: f64,
    pub ranges: Vec<(f32, f32)>,
    pub latent_blob: Vec<u8>,
    pub species: Vec<SpeciesSection>,
    /// Bytes charged for model parameters (accounting; not stored inline).
    pub model_param_bytes: u64,
    pub nrmse_target: f64,
}

impl Archive {
    pub fn serialize(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.bytes(MAGIC);
        w.u16(VERSION);
        w.u16(if self.tcn_used { 1 } else { 0 });
        for d in [self.dims.0, self.dims.1, self.dims.2, self.dims.3] {
            w.u32(d as u32);
        }
        for d in [self.block.0, self.block.1, self.block.2] {
            w.u32(d as u32);
        }
        w.u32(self.latent_dim as u32);
        w.f64(self.pressure);
        for &(lo, hi) in &self.ranges {
            w.f32(lo);
            w.f32(hi);
        }
        w.blob(&self.latent_blob);
        for s in &self.species {
            s.basis.serialize(&mut w);
            w.blob(&s.coeffs);
        }
        w.u64(self.model_param_bytes);
        w.f64(self.nrmse_target);
        w.finish()
    }

    pub fn deserialize(buf: &[u8]) -> Result<Archive> {
        let mut r = ByteReader::new(buf);
        let magic = r.bytes(4)?;
        if magic != MAGIC {
            return Err(Error::format(format!("bad archive magic {magic:?}")));
        }
        let version = r.u16()?;
        if version != VERSION {
            return Err(Error::format(format!("unsupported archive version {version}")));
        }
        let flags = r.u16()?;
        let dims = (
            r.u32()? as usize,
            r.u32()? as usize,
            r.u32()? as usize,
            r.u32()? as usize,
        );
        let block = (r.u32()? as usize, r.u32()? as usize, r.u32()? as usize);
        let latent_dim = r.u32()? as usize;
        let pressure = r.f64()?;
        let ns = dims.1;
        if ns == 0 || ns > 4096 {
            return Err(Error::format(format!("implausible species count {ns}")));
        }
        let total = dims
            .0
            .checked_mul(dims.1)
            .and_then(|v| v.checked_mul(dims.2))
            .and_then(|v| v.checked_mul(dims.3))
            .ok_or_else(|| Error::format("archive dims overflow"))?;
        if total == 0 || total > 1 << 33 {
            return Err(Error::format(format!("implausible dims {dims:?}")));
        }
        if block.0 == 0 || block.1 == 0 || block.2 == 0 || latent_dim == 0 || latent_dim > 65536 {
            return Err(Error::format(format!(
                "implausible block/latent {block:?}/{latent_dim}"
            )));
        }
        let mut ranges = Vec::with_capacity(ns);
        for _ in 0..ns {
            ranges.push((r.f32()?, r.f32()?));
        }
        let latent_blob = r.blob()?.to_vec();
        let mut species = Vec::with_capacity(ns);
        for _ in 0..ns {
            let basis = SpeciesBasis::deserialize(&mut r)?;
            let coeffs = r.blob()?.to_vec();
            species.push(SpeciesSection { basis, coeffs });
        }
        let model_param_bytes = r.u64()?;
        let nrmse_target = r.f64()?;
        Ok(Archive {
            tcn_used: flags & 1 == 1,
            dims,
            block,
            latent_dim,
            pressure,
            ranges,
            latent_blob,
            species,
            model_param_bytes,
            nrmse_target,
        })
    }

    pub fn write_file<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        let bytes = self.serialize();
        File::create(path)?.write_all(&bytes)?;
        Ok(())
    }

    pub fn read_file<P: AsRef<Path>>(path: P) -> Result<Archive> {
        let mut bytes = Vec::new();
        File::open(path.as_ref())?.read_to_end(&mut bytes)?;
        Self::deserialize(&bytes)
    }

    /// Stored payload bytes (the archive itself).
    pub fn payload_bytes(&self) -> usize {
        self.serialize().len()
    }

    /// Total bytes charged for compression-ratio purposes: payload + model
    /// parameters (paper: network parameters count toward the output).
    pub fn total_bytes(&self) -> usize {
        self.payload_bytes() + self.model_param_bytes as usize
    }

    /// Compression ratio against the raw PD bytes.
    pub fn compression_ratio(&self) -> f64 {
        let (nt, ns, ny, nx) = self.dims;
        (nt * ns * ny * nx * 4) as f64 / self.total_bytes() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;

    fn sample() -> Archive {
        let basis = SpeciesBasis::from_mat(&Mat::identity(4), 2);
        Archive {
            tcn_used: true,
            dims: (8, 2, 10, 8),
            block: (4, 5, 4),
            latent_dim: 36,
            pressure: 40.0e5,
            ranges: vec![(0.0, 1.0), (-1.0, 2.0)],
            latent_blob: vec![1, 2, 3, 4],
            species: vec![
                SpeciesSection {
                    basis: basis.clone(),
                    coeffs: vec![9, 8],
                },
                SpeciesSection {
                    basis,
                    coeffs: vec![],
                },
            ],
            model_param_bytes: 12345,
            nrmse_target: 1e-3,
        }
    }

    #[test]
    fn roundtrip() {
        let a = sample();
        let bytes = a.serialize();
        let b = Archive::deserialize(&bytes).unwrap();
        assert_eq!(a.dims, b.dims);
        assert_eq!(a.block, b.block);
        assert_eq!(a.ranges, b.ranges);
        assert_eq!(a.latent_blob, b.latent_blob);
        assert_eq!(a.species.len(), b.species.len());
        assert_eq!(a.species[0].coeffs, b.species[0].coeffs);
        assert_eq!(a.model_param_bytes, b.model_param_bytes);
        assert!(a.tcn_used && b.tcn_used);
    }

    #[test]
    fn cr_accounting_includes_model() {
        let a = sample();
        assert_eq!(a.total_bytes(), a.payload_bytes() + 12345);
        let pd = (8 * 2 * 10 * 8 * 4) as f64;
        assert!((a.compression_ratio() - pd / a.total_bytes() as f64).abs() < 1e-12);
    }

    #[test]
    fn corrupt_magic_rejected() {
        let mut bytes = sample().serialize();
        bytes[0] = b'X';
        assert!(Archive::deserialize(&bytes).is_err());
    }

    #[test]
    fn truncation_rejected() {
        let bytes = sample().serialize();
        assert!(Archive::deserialize(&bytes[..bytes.len() - 4]).is_err());
    }
}
