//! `gbatc` CLI — the L3 leader binary: data generation, GBATC/GBA and SZ
//! compression, decompression, and evaluation.  See `gbatc help`.

use gbatc::archive::Archive;
use gbatc::chem::{self, Mechanism};
use gbatc::cli::{Args, USAGE};
use gbatc::compressor::{
    CompressOptions, GbatcCompressor, SzCompressOptions, SzCompressor, SzArchive,
};
use gbatc::config::Manifest;
use gbatc::data::{self, io, Profile};
use gbatc::error::{Error, Result};
use gbatc::metrics;
use gbatc::runtime::ExecService;
use gbatc::sz::codec::SzMode;

fn main() {
    let mut raw: Vec<String> = std::env::args().skip(1).collect();
    if raw.is_empty() {
        print!("{USAGE}");
        std::process::exit(2);
    }
    let cmd = raw.remove(0);
    let result = Args::parse(raw).and_then(|args| dispatch(&cmd, &args));
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn dispatch(cmd: &str, args: &Args) -> Result<()> {
    match cmd {
        "gen-data" => cmd_gen_data(args),
        "compress" => cmd_compress(args),
        "decompress" => cmd_decompress(args),
        "sz" => cmd_sz(args),
        "sz-decompress" => cmd_sz_decompress(args),
        "evaluate" => cmd_evaluate(args),
        "info" => cmd_info(args),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => Err(Error::config(format!("unknown command `{other}`; see `gbatc help`"))),
    }
}

fn cmd_gen_data(args: &Args) -> Result<()> {
    let out = args.require("out")?;
    let profile = Profile::parse(args.get_or("profile", "small"))
        .ok_or_else(|| Error::config("bad --profile"))?;
    let seed = args.get_parse::<u64>("seed", 7)?;
    let t = std::time::Instant::now();
    let ds = data::generate(profile, seed);
    io::write_dataset(out, &ds)?;
    println!(
        "wrote {out}: {}x{}x{}x{} ({:.1} MB) in {:.1}s",
        ds.nt,
        ds.ns,
        ds.ny,
        ds.nx,
        ds.pd_bytes() as f64 / 1e6,
        t.elapsed().as_secs_f64()
    );
    Ok(())
}

fn cmd_compress(args: &Args) -> Result<()> {
    let input = args.require("input")?;
    let output = args.require("output")?;
    let artifacts = args.get_or("artifacts", "artifacts");
    let opts = CompressOptions {
        nrmse_target: args.get_parse("nrmse", 1e-3)?,
        latent_bin: args.get_parse("latent-bin", 0.02)?,
        use_tcn: !args.has("no-tcn"),
        threads: args.get_parse("threads", 0)?,
        store_full_basis: args.has("full-basis"),
        model_bytes_f32: args.has("model-f32"),
        queue_depth: args.get_parse("queue-depth", 4)?,
    };

    let ds = io::read_dataset(input)?;
    let manifest = Manifest::load(format!("{artifacts}/manifest.txt"))?;
    let service = ExecService::start(artifacts, opts.queue_depth)?;
    let handle = service.handle();
    let comp = GbatcCompressor::new(&handle, manifest.decoder_params, manifest.tcn_params);

    let report = comp.compress(&ds, &opts)?;
    report.archive.write_file(output)?;
    println!(
        "{} -> {} | CR {:.1} | target NRMSE {:.1e} | tau {:.3e} | max block residual {:.3e} | {} coeffs",
        input,
        output,
        report.archive.compression_ratio(),
        opts.nrmse_target,
        report.tau,
        report.max_block_residual,
        report.n_coeffs
    );
    println!("  breakdown: {}", report.breakdown);
    println!("  {}", report.progress_summary);
    Ok(())
}

fn cmd_decompress(args: &Args) -> Result<()> {
    let input = args.require("input")?;
    let output = args.require("output")?;
    let artifacts = args.get_or("artifacts", "artifacts");
    let threads = args.get_parse("threads", 0)?;

    let archive = Archive::read_file(input)?;
    let service = ExecService::start(artifacts, 4)?;
    let handle = service.handle();
    let manifest = Manifest::load(format!("{artifacts}/manifest.txt"))?;
    let comp = GbatcCompressor::new(&handle, manifest.decoder_params, manifest.tcn_params);
    let t = std::time::Instant::now();
    let mass = comp.decompress(&archive, threads)?;

    let (nt, ns, ny, nx) = archive.dims;
    let mut ds = gbatc::data::Dataset::new(nt, ns, ny, nx);
    ds.mass = mass;
    ds.pressure = archive.pressure;
    if let Some(tf) = args.get("temp-from") {
        let src = io::read_dataset(tf)?;
        if (src.nt, src.ny, src.nx) != (nt, ny, nx) {
            return Err(Error::shape("--temp-from dims mismatch".to_string()));
        }
        ds.temp = src.temp;
    }
    io::write_dataset(output, &ds)?;
    println!(
        "{input} -> {output} | {}x{}x{}x{} in {:.2}s",
        nt, ns, ny, nx,
        t.elapsed().as_secs_f64()
    );
    Ok(())
}

fn cmd_sz(args: &Args) -> Result<()> {
    let input = args.require("input")?;
    let output = args.require("output")?;
    let opts = SzCompressOptions {
        mode: SzMode::parse(args.get_or("mode", "auto"))
            .ok_or_else(|| Error::config("bad --mode"))?,
        eb_scale: args.get_parse("eb-scale", 1.0)?,
        threads: args.get_parse("threads", 0)?,
    };
    let nrmse = args.get_parse("nrmse", 1e-3)?;
    let ds = io::read_dataset(input)?;
    let t = std::time::Instant::now();
    let archive = SzCompressor::new(opts).compress(&ds, nrmse)?;
    let bytes = archive.serialize();
    std::fs::write(output, &bytes)?;
    println!(
        "{input} -> {output} | SZ CR {:.1} | {:.2}s",
        ds.pd_bytes() as f64 / bytes.len() as f64,
        t.elapsed().as_secs_f64()
    );
    Ok(())
}

fn cmd_sz_decompress(args: &Args) -> Result<()> {
    let input = args.require("input")?;
    let output = args.require("output")?;
    let bytes = std::fs::read(input)?;
    let archive = SzArchive::deserialize(&bytes)?;
    let szc = SzCompressor::new(SzCompressOptions::default());
    let mass = szc.decompress(&archive)?;
    let (nt, ns, ny, nx) = archive.dims;
    let mut ds = gbatc::data::Dataset::new(nt, ns, ny, nx);
    ds.mass = mass;
    if let Some(tf) = args.get("temp-from") {
        let src = io::read_dataset(tf)?;
        ds.temp = src.temp;
    }
    io::write_dataset(output, &ds)?;
    println!("{input} -> {output}");
    Ok(())
}

fn cmd_evaluate(args: &Args) -> Result<()> {
    let orig = io::read_dataset(args.require("orig")?)?;
    let recon = io::read_dataset(args.require("recon")?)?;
    if (orig.nt, orig.ns, orig.ny, orig.nx) != (recon.nt, recon.ns, recon.ny, recon.nx) {
        return Err(Error::shape("orig/recon dims mismatch".to_string()));
    }

    // per-species NRMSE over species-major trajectories
    let mut per = Vec::with_capacity(orig.ns);
    for s in 0..orig.ns {
        let a = orig.species_field(s);
        let b = recon.species_field(s);
        per.push(metrics::nrmse(&a.data, &b.data));
    }
    let mean = per.iter().sum::<f64>() / per.len() as f64;
    println!("mean NRMSE over {} species: {:.4e}", orig.ns, mean);

    if let Some(name) = args.get("species") {
        let s = chem::index_of(name)
            .ok_or_else(|| Error::config(format!("unknown species {name}")))?;
        let a = orig.species_field(s);
        let b = recon.species_field(s);
        let t_mid = orig.nt / 2;
        println!(
            "{name}: NRMSE {:.4e} | PSNR {:.1} dB | SSIM(mid frame) {:.5}",
            per[s],
            metrics::psnr(&a.data, &b.data),
            metrics::ssim2d(a.frame(t_mid), b.frame(t_mid), orig.ny, orig.nx),
        );
    }

    if args.has("qoi") {
        let stride = args.get_parse::<usize>("sample-stride", 4)?;
        let (qoi_per, qoi_mean) = qoi_errors(&orig, &recon, stride)?;
        println!("mean QoI NRMSE: {:.4e} (stride {stride})", qoi_mean);
        if let Some(name) = args.get("species") {
            let s = chem::index_of(name).unwrap();
            println!("{name}: QoI NRMSE {:.4e}", qoi_per[s]);
        }
    }
    Ok(())
}

/// QoI (production-rate) NRMSE per species on a spatially-strided sample.
pub fn qoi_errors(
    orig: &gbatc::data::Dataset,
    recon: &gbatc::data::Dataset,
    stride: usize,
) -> Result<(Vec<f64>, f64)> {
    let mech = Mechanism::standard();
    let ns = orig.ns;
    let mut ys_o: Vec<f32> = Vec::new();
    let mut ys_r: Vec<f32> = Vec::new();
    let mut temps: Vec<f32> = Vec::new();
    // sample grid points
    let mut n = 0usize;
    for t in 0..orig.nt {
        for y in (0..orig.ny).step_by(stride) {
            for x in (0..orig.nx).step_by(stride) {
                temps.push(orig.temp_at(t, y, x));
                n += 1;
                let _ = (y, x);
            }
        }
    }
    ys_o.resize(ns * n, 0.0);
    ys_r.resize(ns * n, 0.0);
    let mut i = 0usize;
    for t in 0..orig.nt {
        for y in (0..orig.ny).step_by(stride) {
            for x in (0..orig.nx).step_by(stride) {
                for s in 0..ns {
                    ys_o[s * n + i] = orig.at(t, s, y, x);
                    ys_r[s * n + i] = recon.at(t, s, y, x);
                }
                i += 1;
            }
        }
    }
    let mut w_o = vec![0.0f64; ns * n];
    let mut w_r = vec![0.0f64; ns * n];
    chem::production_rates(&mech, &ys_o, &temps, orig.pressure, n, &mut w_o);
    chem::production_rates(&mech, &ys_r, &temps, orig.pressure, n, &mut w_r);
    Ok(metrics::nrmse::nrmse_per_species_f64(&w_o, &w_r, ns))
}

fn cmd_info(args: &Args) -> Result<()> {
    let path = args.require("archive")?;
    let bytes = std::fs::read(path)?;
    if bytes.starts_with(b"GBA1") {
        let a = Archive::deserialize(&bytes)?;
        let (nt, ns, ny, nx) = a.dims;
        println!("GBATC archive: {nt}x{ns}x{ny}x{nx}, block {:?}, latent {}", a.block, a.latent_dim);
        println!("  tcn_used={} nrmse_target={:.1e}", a.tcn_used, a.nrmse_target);
        println!(
            "  payload {} B + model {} B => CR {:.1}",
            a.payload_bytes(),
            a.model_param_bytes,
            a.compression_ratio()
        );
        let ranks: Vec<usize> = a.species.iter().map(|s| s.basis.rank).collect();
        println!(
            "  basis ranks: min {} max {} mean {:.1}",
            ranks.iter().min().unwrap(),
            ranks.iter().max().unwrap(),
            ranks.iter().sum::<usize>() as f64 / ranks.len() as f64
        );
    } else if bytes.starts_with(b"SZA1") {
        let a = SzArchive::deserialize(&bytes)?;
        let (nt, ns, ny, nx) = a.dims;
        println!("SZ archive: {nt}x{ns}x{ny}x{nx}, {} fields", a.fields.len());
        println!(
            "  total {} B => CR {:.1}",
            bytes.len(),
            (nt * ns * ny * nx * 4) as f64 / bytes.len() as f64
        );
    } else {
        return Err(Error::format("unknown archive type".to_string()));
    }
    Ok(())
}
