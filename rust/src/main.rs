//! `gbatc` CLI — the L3 leader binary: data generation, GBATC/GBA and SZ
//! compression, full and partial decompression, archive inspection, and
//! evaluation.  See `gbatc help`.

use std::sync::Arc;

use gbatc::api::{
    ArchiveReader, Backend, CompressorBuilder, ErrorPolicy, FieldSpec, Query, SpeciesBudget,
    SpeciesSel,
};
use gbatc::archive::{
    compact_archives, repair_archive, verify_archive, AnyArchive, Archive, CodecTag, Gba2Archive,
};
use gbatc::chem::{self, Mechanism};
use gbatc::cli::{Args, USAGE};
use gbatc::compressor::{CodecChoice, SzArchive, SzCompressOptions, SzCompressor};
use gbatc::data::{self, io, Profile};
use gbatc::error::{Error, Result};
use gbatc::metrics;
use gbatc::serve::{QueryClient, QueryRouter, QueryServer, RouterConfig, ServerConfig};
use gbatc::store::StoreConfig;
use gbatc::sz::codec::SzMode;

fn main() {
    let mut raw: Vec<String> = std::env::args().skip(1).collect();
    if raw.is_empty() {
        print!("{USAGE}");
        std::process::exit(2);
    }
    let cmd = raw.remove(0);
    let result = Args::parse(raw).and_then(|args| dispatch(&cmd, &args));
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn dispatch(cmd: &str, args: &Args) -> Result<()> {
    match cmd {
        "gen-data" => cmd_gen_data(args),
        "compress" => cmd_compress(args),
        "decompress" => cmd_decompress(args),
        "extract" => cmd_extract(args),
        "inspect" => cmd_inspect(args),
        "repair" => cmd_repair(args),
        "compact" => cmd_compact(args),
        "serve" => cmd_serve(args),
        "query" => cmd_query(args),
        "stats" => cmd_stats(args),
        "sz" => cmd_sz(args),
        "sz-decompress" => cmd_sz_decompress(args),
        "evaluate" => cmd_evaluate(args),
        "info" => cmd_info(args),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => Err(Error::config(format!("unknown command `{other}`; see `gbatc help`"))),
    }
}

/// Execution backend from the CLI flags: AOT artifacts by default, the
/// pure-Rust reference backend with `--reference`.
fn backend(args: &Args) -> Backend {
    if args.has("reference") {
        Backend::Reference
    } else {
        Backend::Artifacts(args.get_or("artifacts", "artifacts").to_string())
    }
}

/// Parse `--species NAME[,NAME|INDEX...]` into a typed selection.
/// Mechanism names resolve through `chem::mechanism` when the query runs;
/// unknown names list the available ones in the error.
fn parse_species_sel(args: &Args) -> SpeciesSel {
    match args.get("species") {
        Some(list) => SpeciesSel::parse(list),
        None => SpeciesSel::All,
    }
}

/// Accuracy policy from `--nrmse` plus optional `--species-nrmse`
/// `NAME=TARGET[,NAME=TARGET...]` overrides (names or indices).
fn parse_policy(args: &Args, nrmse: f64) -> Result<ErrorPolicy> {
    let Some(list) = args.get("species-nrmse") else {
        return Ok(ErrorPolicy::Uniform(nrmse));
    };
    let mut budgets = vec![SpeciesBudget::all(nrmse)];
    for tok in list.split(',').map(str::trim).filter(|t| !t.is_empty()) {
        let (name, val) = tok.split_once('=').ok_or_else(|| {
            Error::config(format!("--species-nrmse entry `{tok}` is not NAME=TARGET"))
        })?;
        // an empty NAME would parse as "all species" and silently
        // override every other budget — reject it
        if name.trim().is_empty() {
            return Err(Error::config(format!(
                "--species-nrmse entry `{tok}` has an empty species name"
            )));
        }
        let target: f64 = val
            .trim()
            .parse()
            .map_err(|e| Error::config(format!("--species-nrmse {tok}: {e}")))?;
        budgets.push(SpeciesBudget {
            species: SpeciesSel::parse(name),
            nrmse: target,
        });
    }
    Ok(ErrorPolicy::PerSpecies(budgets))
}

fn cmd_gen_data(args: &Args) -> Result<()> {
    let out = args.require("out")?;
    let profile = Profile::parse(args.get_or("profile", "small"))
        .ok_or_else(|| Error::config("bad --profile"))?;
    let seed = args.get_parse::<u64>("seed", 7)?;
    let t = std::time::Instant::now();
    let ds = data::generate(profile, seed);
    io::write_dataset(out, &ds)?;
    println!(
        "wrote {out}: {}x{}x{}x{} ({:.1} MB) in {:.1}s",
        ds.nt,
        ds.ns,
        ds.ny,
        ds.nx,
        ds.pd_bytes() as f64 / 1e6,
        t.elapsed().as_secs_f64()
    );
    Ok(())
}

fn cmd_compress(args: &Args) -> Result<()> {
    let input = args.require("input")?;
    let output = args.require("output")?;
    let codec = CodecChoice::parse(args.get_or("codec", "gbatc"))
        .ok_or_else(|| Error::config("bad --codec (auto|gbatc|sz|dense)"))?;
    let nrmse = args.get_parse("nrmse", 1e-3)?;
    if args.has("v1") && codec != CodecChoice::Gbatc {
        return Err(Error::config(
            "--v1 requires --codec gbatc (GBA1 cannot carry codec tags)",
        ));
    }

    let ds = io::read_dataset(input)?;
    let mut kt_window: usize = args.get_parse("kt-window", 0)?;
    if args.has("v1") {
        // fail fast: GBA1 export needs a single shard, so force the window
        // to cover the whole time axis (and reject a conflicting request)
        // before spending the compression run
        if kt_window != 0 && kt_window < ds.nt {
            return Err(Error::config(format!(
                "--v1 needs a single shard; drop --kt-window or set it >= {}",
                ds.nt
            )));
        }
        kt_window = kt_window.max(ds.nt);
    }

    // the builder owns every knob and validates them when the session
    // opens — the CLI is a thin adapter over `gbatc::api`
    let builder = CompressorBuilder::new()
        .backend(backend(args))
        .error_policy(parse_policy(args, nrmse)?)
        .codec(codec)
        .latent_bin(args.get_parse("latent-bin", 0.02)?)
        .use_tcn(!args.has("no-tcn"))
        .threads(args.get_parse("threads", 0)?)
        .store_full_basis(args.has("full-basis"))
        .model_bytes_f32(args.has("model-f32"))
        .queue_depth(args.get_parse("queue-depth", 4)?)
        .kt_window(kt_window)
        .shard_workers(args.get_parse("shard-workers", 2)?);
    let field = FieldSpec::from_dataset(&ds);

    // report the ratio of the container actually written (GBA1 lacks the TOC)
    let (report, cr) = if args.has("v1") {
        // in-memory sink, then convert to the legacy container
        let mut session = builder.session(field, std::io::Cursor::new(Vec::new()))?;
        session.push_dataset(&ds)?;
        let (report, sink) = session.finish_into()?;
        let v1 = AnyArchive::deserialize(sink.get_ref())?.into_v2()?.to_v1()?;
        v1.write_file(output)?;
        let cr = v1.compression_ratio();
        (report, cr)
    } else {
        // stream into a .part file shard by shard, renaming into place
        // only once the archive is sealed — a failed run never leaves a
        // truncated archive at the output path (or clobbers a good one)
        let part = format!("{output}.part");
        let run = || -> Result<gbatc::api::CompressReport> {
            let mut session = builder.session(field, std::fs::File::create(&part)?)?;
            session.push_dataset(&ds)?;
            session.finish()
        };
        match run() {
            Ok(report) => {
                std::fs::rename(&part, output)?;
                let cr = report.compression_ratio();
                (report, cr)
            }
            Err(e) => {
                let _ = std::fs::remove_file(&part);
                return Err(e);
            }
        }
    };
    println!(
        "{} -> {} | CR {:.1} | target NRMSE {:.1e} | tau {:.3e} | max block residual {:.3e} | {} coeffs",
        input, output, cr, nrmse, report.tau, report.max_block_residual, report.n_coeffs
    );
    println!(
        "  {} shards (kt_window {}) | peak workspace {:.1} MB",
        report.n_shards,
        report.kt_window,
        report.peak_workspace_bytes as f64 / 1e6
    );
    if codec != CodecChoice::Gbatc {
        println!("  {}", report_codec_totals_line(&report));
    }
    println!("  breakdown: {}", report.breakdown);
    println!("  stages: {}", report.stage_times);
    println!("  {}", report.progress_summary);
    Ok(())
}

/// Per-codec section totals of a session report, one summary line.
fn report_codec_totals_line(report: &gbatc::api::CompressReport) -> String {
    let parts: Vec<String> = CodecTag::ALL
        .iter()
        .map(|&t| {
            let (n, b) = report.codec_totals[t as usize];
            format!("{} {n} sections {b} B", t.name())
        })
        .collect();
    format!(
        "per-codec: {} (container v{})",
        parts.join(" | "),
        report.version
    )
}

/// Per-codec section totals of a GBA2 archive, one summary line.
fn codec_totals_line(a: &Gba2Archive) -> String {
    let totals = a.codec_totals();
    let parts: Vec<String> = CodecTag::ALL
        .iter()
        .map(|&t| {
            let (n, b) = totals[t as usize];
            format!("{} {n} sections {b} B", t.name())
        })
        .collect();
    format!("per-codec: {} (container v{})", parts.join(" | "), a.version())
}

fn cmd_decompress(args: &Args) -> Result<()> {
    let input = args.require("input")?;
    let output = args.require("output")?;
    let threads = args.get_parse("threads", 0)?;

    let t = std::time::Instant::now();
    let reader = ArchiveReader::open_file(input, &backend(args), threads)?;
    let (nt, ns, ny, nx) = reader.header().dims;
    let pressure = reader.header().pressure;
    let mass = reader.decompress_all()?;

    let mut ds = gbatc::data::Dataset::new(nt, ns, ny, nx);
    ds.mass = mass;
    ds.pressure = pressure;
    if let Some(tf) = args.get("temp-from") {
        let src = io::read_dataset(tf)?;
        if (src.nt, src.ny, src.nx) != (nt, ny, nx) {
            return Err(Error::shape("--temp-from dims mismatch".to_string()));
        }
        ds.temp = src.temp;
    }
    io::write_dataset(output, &ds)?;
    println!(
        "{input} -> {output} | {}x{}x{}x{} in {:.2}s",
        nt, ns, ny, nx,
        t.elapsed().as_secs_f64()
    );
    Ok(())
}

fn cmd_extract(args: &Args) -> Result<()> {
    let input = args.require("input")?;
    let output = args.require("output")?;
    let threads = args.get_parse("threads", 0)?;
    let species = parse_species_sel(args);

    let reader = ArchiveReader::open_file(input, &backend(args), threads)?;
    let nt = reader.header().dims.0;
    let pressure = reader.header().pressure;
    let t0 = args.get_parse("t0", 0usize)?;
    let t1 = args.get_parse("t1", nt)?;
    // count only what the extract itself touches, not the TOC at open
    reader.reset_io_stats();
    let t = std::time::Instant::now();
    let range = reader.query(&Query {
        time: t0..t1,
        species,
    })?;

    let mut ds = gbatc::data::Dataset::new(range.nt, range.species.len(), range.ny, range.nx);
    ds.mass = range.mass;
    ds.pressure = pressure;
    io::write_dataset(output, &ds)?;
    let total = reader.archive_bytes();
    println!(
        "{input}[t {t0}..{t1}, {} species] -> {output} in {:.2}s",
        ds.ns,
        t.elapsed().as_secs_f64()
    );
    let iostats = reader.io_stats();
    println!(
        "  read {} of {} archive bytes ({:.1}%) in {} ranged reads ({iostats}) | peak workspace {:.1} MB",
        reader.bytes_read(),
        total,
        100.0 * reader.bytes_read() as f64 / total.max(1) as f64,
        reader.reads(),
        range.peak_workspace_bytes as f64 / 1e6
    );
    Ok(())
}

/// Mount `NAME=PATH[,NAME=PATH...]` archives into a store.
fn mount_all(router: &QueryRouter, list: &str) -> Result<()> {
    for tok in list.split(',').map(str::trim).filter(|t| !t.is_empty()) {
        let (name, path) = tok.split_once('=').ok_or_else(|| {
            Error::config(format!("--mount entry `{tok}` is not NAME=PATH"))
        })?;
        let replica = router.mount_file(name.trim(), path.trim())?;
        let info = router.dataset_info(name.trim())?;
        let (nt, ns, ny, nx) = info.dims;
        println!(
            "mounted {:<16} {nt}x{ns}x{ny}x{nx} ({} shards, {} B, NRMSE {:.1e}, replica {replica}) <- {}",
            name.trim(),
            info.n_shards,
            info.archive_bytes,
            info.nrmse_target,
            path.trim()
        );
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let listen = args.get_or("listen", "127.0.0.1:7070");
    let mounts = args.require("mount")?;
    let store_cfg = StoreConfig {
        backend: backend(args),
        threads: args.get_parse("threads", 0)?,
        cache_bytes: args.get_parse::<usize>("cache-mb", 256)? << 20,
        cache_shards: 16,
    };
    let replicas: usize = args.get_parse("replicas", 1)?;
    let router = Arc::new(QueryRouter::new(RouterConfig {
        replicas: replicas.max(1),
        store: store_cfg,
        ..RouterConfig::default()
    })?);
    mount_all(&router, mounts)?;
    let server = QueryServer::bind_router(
        Arc::clone(&router),
        listen,
        ServerConfig {
            workers: args.get_parse("workers", 4)?,
            queue: args.get_parse("queue", 64)?,
            max_response_bytes: args.get_parse::<usize>("max-response-mb", 256)? << 20,
            max_conns: args.get_parse("max-conns", 1024)?,
            ..ServerConfig::default()
        },
    )?;
    println!(
        "serving {} dataset(s) on http://{} ({} loop, {} replica(s)) — \
         GET /datasets, /query, /stats, /metrics, /trace/slow",
        router.datasets().len(),
        server.addr(),
        if server.event_driven() {
            "epoll event"
        } else {
            "thread-pool"
        },
        router.replica_count()
    );
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_query(args: &Args) -> Result<()> {
    let dataset = args.positional.first().ok_or_else(|| {
        Error::config("usage: gbatc query DATASET [--server ADDR] [--t0 N] [--t1 N] [--species ...]")
    })?;
    let client = QueryClient::new(args.get_or("server", "127.0.0.1:7070"));
    let parse_opt = |name: &str| -> Result<Option<usize>> {
        match args.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|e| Error::config(format!("--{name} {v}: {e}"))),
        }
    };
    let t = std::time::Instant::now();
    let dec = client.query(
        dataset,
        parse_opt("t0")?,
        parse_opt("t1")?,
        args.get_or("species", ""),
    )?;
    println!(
        "{dataset}[t {}..{}, {} species] -> {} values ({} B) in {:.2}s | certified NRMSE {:.1e}",
        dec.t0,
        dec.t0 + dec.nt,
        dec.species.len(),
        dec.mass.len(),
        dec.mass.len() * 4,
        t.elapsed().as_secs_f64(),
        dec.nrmse_target
    );
    if let Some(out) = args.get("output") {
        let mut ds = gbatc::data::Dataset::new(dec.nt, dec.species.len(), dec.ny, dec.nx);
        ds.mass = dec.mass;
        ds.pressure = dec.pressure;
        io::write_dataset(out, &ds)?;
        println!("wrote {out}");
    }
    Ok(())
}

/// Summarize one Prometheus histogram out of `/metrics` text: sample
/// count plus p50/p90/p99 upper bounds read off the cumulative
/// `_bucket{le=...}` series (each quantile is "<= this bucket bound").
fn prom_hist_summary(text: &str, name: &str) -> Option<String> {
    let bucket_prefix = format!("{name}_bucket{{le=\"");
    let count_prefix = format!("{name}_count ");
    let mut buckets: Vec<(f64, u64)> = Vec::new();
    let mut count = 0u64;
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix(&bucket_prefix) {
            let (le, tail) = rest.split_once("\"}")?;
            let le: f64 = if le == "+Inf" {
                f64::INFINITY
            } else {
                le.parse().ok()?
            };
            buckets.push((le, tail.trim().parse().ok()?));
        } else if let Some(v) = line.strip_prefix(&count_prefix) {
            count = v.trim().parse().ok()?;
        }
    }
    if buckets.is_empty() {
        return None;
    }
    if count == 0 {
        return Some(format!("{name:<28} no samples"));
    }
    let q = |p: f64| -> f64 {
        let rank = ((p * count as f64).ceil() as u64).clamp(1, count);
        buckets
            .iter()
            .find(|&&(_, cum)| cum >= rank)
            .map(|&(le, _)| le)
            .unwrap_or(f64::INFINITY)
    };
    Some(format!(
        "{name:<28} n={count} p50<={:.3}ms p90<={:.3}ms p99<={:.3}ms",
        q(0.5) * 1e3,
        q(0.9) * 1e3,
        q(0.99) * 1e3
    ))
}

/// Render `/trace/slow` JSON as one line per span plus its phases.
fn render_slow_spans(json: &str) {
    let Some(start) = json.find("\"spans\":[") else {
        return;
    };
    let recorded = gbatc::serve::http::json_u64(json, "recorded").unwrap_or(0);
    let dropped = gbatc::serve::http::json_u64(json, "dropped").unwrap_or(0);
    println!("  ring: {recorded} recorded, {dropped} dropped");
    for chunk in json[start..].split("{\"trace_id\":\"").skip(1) {
        let id = chunk.split('"').next().unwrap_or("?");
        let status = gbatc::serve::http::json_u64(chunk, "status").unwrap_or(0);
        let total = gbatc::serve::http::json_u64(chunk, "total_ns").unwrap_or(0);
        let target = chunk
            .split("\"target\":\"")
            .nth(1)
            .and_then(|s| s.split('"').next())
            .unwrap_or("?");
        println!("  {id} {status} {:>9.3}ms {target}", total as f64 / 1e6);
        for ph in [
            "parse",
            "queue_wait",
            "cache_probe",
            "decode",
            "salvage",
            "serialize",
            "write",
        ] {
            let pat = format!("\"{ph}\":{{\"start_ns\":");
            let Some(pos) = chunk.find(&pat) else {
                continue;
            };
            let rest = &chunk[pos + pat.len()..];
            let start_ns: f64 = rest
                .split(',')
                .next()
                .and_then(|v| v.parse().ok())
                .unwrap_or(0.0);
            let dur_ns: f64 = rest
                .split("\"dur_ns\":")
                .nth(1)
                .and_then(|s| s.split('}').next())
                .and_then(|v| v.parse().ok())
                .unwrap_or(0.0);
            println!(
                "      {ph:<12} {:>9.3}ms @ {:.3}ms",
                dur_ns / 1e6,
                start_ns / 1e6
            );
        }
    }
}

fn cmd_stats(args: &Args) -> Result<()> {
    let server = match args.positional.first() {
        Some(s) => s.as_str(),
        None => args.get_or("server", "127.0.0.1:7070"),
    };
    let client = QueryClient::new(server);
    let metrics = client.metrics_text()?;
    println!("latency ({server}/metrics):");
    for name in [
        "gbatc_query_seconds",
        "gbatc_queue_wait_seconds",
        "gbatc_decode_seconds",
        "gbatc_cache_probe_seconds",
    ] {
        if let Some(line) = prom_hist_summary(&metrics, name) {
            println!("  {line}");
        }
    }
    println!("counters:");
    for line in metrics.lines() {
        if line.starts_with('#') || line.trim().is_empty() {
            continue;
        }
        let name = line.split([' ', '{']).next().unwrap_or("");
        if name.ends_with("_bucket") || name.ends_with("_sum") || name.ends_with("_count") {
            continue; // histogram components, summarized above
        }
        println!("  {line}");
    }
    let n = args.get_parse("slow", 8usize)?;
    println!("slow spans (top {n}, {server}/trace/slow):");
    render_slow_spans(&client.trace_slow_json(n)?);
    Ok(())
}

/// Walk every section of an archive (or unsealed stream) and print its
/// health; `Err` — and so a nonzero exit — when anything is damaged.
fn verify_report(path: &str, bytes: &[u8]) -> Result<()> {
    let rep = verify_archive(bytes)?;
    println!(
        "verify {path}: {} — {}/{} shards indexed, {} sections checked",
        if rep.sealed {
            "sealed archive"
        } else {
            "unsealed stream (GBJL journal)"
        },
        rep.shards_indexed,
        rep.shards_declared,
        rep.sections.len()
    );
    for h in rep.sections.iter().filter(|h| !h.ok) {
        match h.species {
            Some(s) => println!("  DAMAGED shard {} species {s}: {}", h.shard, h.detail),
            None => println!("  DAMAGED shard {}: {}", h.shard, h.detail),
        }
    }
    if rep.uncommitted_tail > 0 {
        println!(
            "  note: {} B of flushed-but-uncommitted shard payload (dropped on resume/repair)",
            rep.uncommitted_tail
        );
    }
    if rep.healthy() {
        println!("  all sections decode — archive is healthy");
        Ok(())
    } else {
        Err(Error::format(format!(
            "{path}: {} damaged section(s); run `gbatc repair` to salvage the intact prefix",
            rep.damaged_sections()
        )))
    }
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let path = args.require("archive")?;
    let bytes = std::fs::read(path)?;
    if bytes.starts_with(b"SZA1") {
        return cmd_info(args);
    }
    if args.has("verify") {
        return verify_report(path, &bytes);
    }
    let any = AnyArchive::deserialize(&bytes)?;
    if any.version() == 1 {
        println!("GBA1 (legacy single-shot) archive — per-section TOC only in GBA2:");
        return cmd_info(args);
    }
    let a = any.into_v2()?;
    if args.has("stats") && args.has("json") {
        return inspect_stats_json(path, &a);
    }
    let (nt, ns, ny, nx) = a.header.dims;
    println!(
        "GBATC archive (GBA2): {nt}x{ns}x{ny}x{nx}, block {:?}, latent {}, kt_window {}",
        a.header.block, a.header.latent_dim, a.header.kt_window
    );
    println!(
        "  tcn_used={} nrmse_target={:.1e} | payload {} B + model {} B => CR {:.1}",
        a.header.tcn_used,
        a.header.nrmse_target,
        a.payload_bytes(),
        a.header.model_param_bytes,
        a.compression_ratio()
    );
    println!(
        "  {:>5} {:>8} {:>12} {:>12} {:>12} {:>12}  codecs",
        "shard", "t range", "offset", "bytes", "latent B", "sections B"
    );
    for (i, e) in a.toc.iter().enumerate() {
        let sections: u64 = e.species.iter().map(|&(_, l)| l).sum();
        // compact per-species codec tags, e.g. "GGSD" (capped for wide S)
        let mut tags: String = e.codecs.iter().take(24).map(|c| c.letter()).collect();
        if e.codecs.len() > 24 {
            tags.push('…');
        }
        println!(
            "  {:>5} {:>3}..{:<4} {:>12} {:>12} {:>12} {:>12}  {}",
            i,
            e.t0,
            e.t0 + e.nt,
            e.shard.0,
            e.shard.1,
            e.latent.1,
            sections,
            tags
        );
    }
    if args.has("stats") {
        // reopen through the metered reader: shows what indexing costs
        // (header + TOC reads, classified) before any payload is touched
        let reader = ArchiveReader::open_file(path, &Backend::Reference, 0)?;
        let iostats = reader.io_stats();
        println!(
            "  open IO: {iostats} | indexing read {} of {} archive bytes ({:.2}%)",
            iostats.bytes(),
            reader.archive_bytes(),
            100.0 * iostats.bytes() as f64 / reader.archive_bytes().max(1) as f64
        );
        println!(
            "  IO path: {} B zero-copy (mmap) vs {} B buffered read(2) in {} + {} reads",
            iostats.mmap_bytes,
            iostats.bytes() - iostats.mmap_bytes,
            iostats.mmap_reads,
            iostats.reads() - iostats.mmap_reads
        );
    }
    println!("  {}", codec_totals_line(&a));
    // per-species totals across shards (top 5 heaviest)
    let mut per: Vec<(usize, u64)> = (0..ns)
        .map(|s| (s, a.toc.iter().map(|e| e.species[s].1).sum::<u64>()))
        .collect();
    per.sort_by_key(|&(_, b)| std::cmp::Reverse(b));
    println!("  heaviest species sections:");
    for &(s, b) in per.iter().take(5) {
        let name = chem::SPECIES.get(s).map(|sp| sp.name).unwrap_or("?");
        println!("    {:>12} (#{s:<3}) {b:>10} B", name);
    }
    Ok(())
}

/// `inspect --stats --json`: one machine-readable JSON object — dims,
/// sizes, per-codec totals, and the classified open IO (TOC vs payload,
/// mmap vs buffered `read(2)`).
fn inspect_stats_json(path: &str, a: &Gba2Archive) -> Result<()> {
    let reader = ArchiveReader::open_file(path, &Backend::Reference, 0)?;
    let io = reader.io_stats();
    let (nt, ns, ny, nx) = a.header.dims;
    let totals = a.codec_totals();
    let mut codecs = String::from("{");
    for (i, &t) in CodecTag::ALL.iter().enumerate() {
        let (n, b) = totals[t as usize];
        if i > 0 {
            codecs.push(',');
        }
        codecs.push_str(&format!(
            "\"{}\":{{\"sections\":{n},\"bytes\":{b}}}",
            t.name()
        ));
    }
    codecs.push('}');
    println!(
        "{{\"archive\":\"{}\",\"version\":{},\"dims\":[{nt},{ns},{ny},{nx}],\
         \"shards\":{},\"kt_window\":{},\"payload_bytes\":{},\"model_bytes\":{},\
         \"compression_ratio\":{:.3},\"nrmse_target\":{:e},\
         \"open_io\":{{\"toc_reads\":{},\"toc_bytes\":{},\"payload_reads\":{},\
         \"payload_bytes\":{},\"mmap_reads\":{},\"mmap_bytes\":{},\
         \"buffered_reads\":{},\"buffered_bytes\":{}}},\"codecs\":{codecs}}}",
        gbatc::serve::http::json_escape(path),
        a.version(),
        a.n_shards(),
        a.header.kt_window,
        a.payload_bytes(),
        a.header.model_param_bytes,
        a.compression_ratio(),
        a.header.nrmse_target,
        io.toc_reads,
        io.toc_bytes,
        io.payload_reads,
        io.payload_bytes,
        io.mmap_reads,
        io.mmap_bytes,
        io.reads() - io.mmap_reads,
        io.bytes() - io.mmap_bytes,
    );
    Ok(())
}

fn cmd_repair(args: &Args) -> Result<()> {
    let input = args.require("input")?;
    let output = match args.get("output") {
        Some(o) => o.to_string(),
        None if args.has("in-place") => input.to_string(),
        None => {
            return Err(Error::config(
                "repair needs --output <file> (or --in-place to overwrite the input)",
            ))
        }
    };
    let bytes = std::fs::read(input)?;
    let (fixed, outcome) = repair_archive(&bytes)?;
    println!(
        "repair {input}: {} in -> {} shards out ({} timesteps, {} B){}",
        if outcome.sealed_input {
            format!("sealed archive, {} shards", outcome.shards_in)
        } else {
            format!("unsealed stream, {} committed shards", outcome.shards_in)
        },
        outcome.shards_out,
        outcome.timesteps_out,
        outcome.bytes_out,
        if outcome.changed { "" } else { " — already well-formed" }
    );
    if outcome.changed || output != input {
        std::fs::write(&output, &fixed)?;
        println!("wrote {output}");
    }
    Ok(())
}

fn cmd_compact(args: &Args) -> Result<()> {
    let output = args.require("output")?;
    if args.positional.is_empty() {
        return Err(Error::config(
            "compact needs archive paths as positional arguments",
        ));
    }
    let archives: Vec<Gba2Archive> = args
        .positional
        .iter()
        .map(|p| AnyArchive::read_file(p)?.into_v2())
        .collect::<Result<_>>()?;
    let (merged, outcome) = compact_archives(&archives)?;
    println!(
        "compact: {} shards across {} archives -> {} shards, {} timesteps \
         ({} duplicate, {} orphaned dropped)",
        outcome.shards_in,
        args.positional.len(),
        outcome.shards_out,
        outcome.timesteps_out,
        outcome.dropped_duplicate,
        outcome.dropped_orphaned
    );
    let bytes = merged.into_bytes();
    std::fs::write(output, &bytes)?;
    println!("wrote {output} ({} B)", bytes.len());
    Ok(())
}

fn cmd_sz(args: &Args) -> Result<()> {
    let input = args.require("input")?;
    let output = args.require("output")?;
    let opts = SzCompressOptions {
        mode: SzMode::parse(args.get_or("mode", "auto"))
            .ok_or_else(|| Error::config("bad --mode"))?,
        eb_scale: args.get_parse("eb-scale", 1.0)?,
        threads: args.get_parse("threads", 0)?,
    };
    let nrmse = args.get_parse("nrmse", 1e-3)?;
    let ds = io::read_dataset(input)?;
    let t = std::time::Instant::now();
    let archive = SzCompressor::new(opts).compress(&ds, nrmse)?;
    let bytes = archive.serialize();
    std::fs::write(output, &bytes)?;
    println!(
        "{input} -> {output} | SZ CR {:.1} | {:.2}s",
        ds.pd_bytes() as f64 / bytes.len() as f64,
        t.elapsed().as_secs_f64()
    );
    Ok(())
}

fn cmd_sz_decompress(args: &Args) -> Result<()> {
    let input = args.require("input")?;
    let output = args.require("output")?;
    let bytes = std::fs::read(input)?;
    let archive = SzArchive::deserialize(&bytes)?;
    let szc = SzCompressor::new(SzCompressOptions::default());
    let mass = szc.decompress(&archive)?;
    let (nt, ns, ny, nx) = archive.dims;
    let mut ds = gbatc::data::Dataset::new(nt, ns, ny, nx);
    ds.mass = mass;
    if let Some(tf) = args.get("temp-from") {
        let src = io::read_dataset(tf)?;
        ds.temp = src.temp;
    }
    io::write_dataset(output, &ds)?;
    println!("{input} -> {output}");
    Ok(())
}

fn cmd_evaluate(args: &Args) -> Result<()> {
    let orig = io::read_dataset(args.require("orig")?)?;
    let recon = io::read_dataset(args.require("recon")?)?;
    if (orig.nt, orig.ns, orig.ny, orig.nx) != (recon.nt, recon.ns, recon.ny, recon.nx) {
        return Err(Error::shape("orig/recon dims mismatch".to_string()));
    }

    // per-species NRMSE over species-major trajectories
    let mut per = Vec::with_capacity(orig.ns);
    for s in 0..orig.ns {
        let a = orig.species_field(s);
        let b = recon.species_field(s);
        per.push(metrics::nrmse(&a.data, &b.data));
    }
    let mean = per.iter().sum::<f64>() / per.len() as f64;
    println!("mean NRMSE over {} species: {:.4e}", orig.ns, mean);

    if let Some(name) = args.get("species") {
        let s = chem::index_of(name)
            .ok_or_else(|| Error::config(format!("unknown species {name}")))?;
        let a = orig.species_field(s);
        let b = recon.species_field(s);
        let t_mid = orig.nt / 2;
        println!(
            "{name}: NRMSE {:.4e} | PSNR {:.1} dB | SSIM(mid frame) {:.5}",
            per[s],
            metrics::psnr(&a.data, &b.data),
            metrics::ssim2d(a.frame(t_mid), b.frame(t_mid), orig.ny, orig.nx),
        );
    }

    if args.has("qoi") {
        let stride = args.get_parse::<usize>("sample-stride", 4)?;
        let (qoi_per, qoi_mean) = qoi_errors(&orig, &recon, stride)?;
        println!("mean QoI NRMSE: {:.4e} (stride {stride})", qoi_mean);
        if let Some(name) = args.get("species") {
            let s = chem::index_of(name)
                .ok_or_else(|| Error::config(format!("unknown species {name}")))?;
            println!("{name}: QoI NRMSE {:.4e}", qoi_per[s]);
        }
    }
    Ok(())
}

/// QoI (production-rate) NRMSE per species on a spatially-strided sample.
pub fn qoi_errors(
    orig: &gbatc::data::Dataset,
    recon: &gbatc::data::Dataset,
    stride: usize,
) -> Result<(Vec<f64>, f64)> {
    let mech = Mechanism::standard();
    let ns = orig.ns;
    let mut ys_o: Vec<f32> = Vec::new();
    let mut ys_r: Vec<f32> = Vec::new();
    let mut temps: Vec<f32> = Vec::new();
    // sample grid points
    let mut n = 0usize;
    for t in 0..orig.nt {
        for y in (0..orig.ny).step_by(stride) {
            for x in (0..orig.nx).step_by(stride) {
                temps.push(orig.temp_at(t, y, x));
                n += 1;
                let _ = (y, x);
            }
        }
    }
    ys_o.resize(ns * n, 0.0);
    ys_r.resize(ns * n, 0.0);
    let mut i = 0usize;
    for t in 0..orig.nt {
        for y in (0..orig.ny).step_by(stride) {
            for x in (0..orig.nx).step_by(stride) {
                for s in 0..ns {
                    ys_o[s * n + i] = orig.at(t, s, y, x);
                    ys_r[s * n + i] = recon.at(t, s, y, x);
                }
                i += 1;
            }
        }
    }
    let mut w_o = vec![0.0f64; ns * n];
    let mut w_r = vec![0.0f64; ns * n];
    chem::production_rates(&mech, &ys_o, &temps, orig.pressure, n, &mut w_o);
    chem::production_rates(&mech, &ys_r, &temps, orig.pressure, n, &mut w_r);
    Ok(metrics::nrmse::nrmse_per_species_f64(&w_o, &w_r, ns))
}

fn cmd_info(args: &Args) -> Result<()> {
    let path = args.require("archive")?;
    let bytes = std::fs::read(path)?;
    if bytes.starts_with(b"GBA1") {
        let a = Archive::deserialize(&bytes)?;
        let (nt, ns, ny, nx) = a.dims;
        println!(
            "GBATC archive: {nt}x{ns}x{ny}x{nx}, block {:?}, latent {}",
            a.block, a.latent_dim
        );
        println!(
            "  version GBA1 | tcn_used={} nrmse_target={:.1e}",
            a.tcn_used, a.nrmse_target
        );
        println!(
            "  payload {} B + model {} B => CR {:.1}",
            a.payload_bytes(),
            a.model_param_bytes,
            a.compression_ratio()
        );
        let ranks: Vec<usize> = a.species.iter().map(|s| s.basis.rank).collect();
        println!(
            "  basis ranks: min {} max {} mean {:.1}",
            ranks.iter().min().unwrap_or(&0),
            ranks.iter().max().unwrap_or(&0),
            ranks.iter().sum::<usize>() as f64 / ranks.len().max(1) as f64
        );
    } else if bytes.starts_with(b"GBA2") {
        let a = Gba2Archive::deserialize(&bytes)?;
        let (nt, ns, ny, nx) = a.header.dims;
        println!(
            "GBATC archive: {nt}x{ns}x{ny}x{nx}, block {:?}, latent {}",
            a.header.block, a.header.latent_dim
        );
        println!(
            "  version GBA2 | {} shards (kt_window {}) | tcn_used={} nrmse_target={:.1e}",
            a.n_shards(),
            a.header.kt_window,
            a.header.tcn_used,
            a.header.nrmse_target
        );
        println!(
            "  payload {} B + model {} B => CR {:.1}",
            a.payload_bytes(),
            a.header.model_param_bytes,
            a.compression_ratio()
        );
    } else if bytes.starts_with(b"SZA1") {
        let a = SzArchive::deserialize(&bytes)?;
        let (nt, ns, ny, nx) = a.dims;
        println!("SZ archive: {nt}x{ns}x{ny}x{nx}, {} fields", a.fields.len());
        println!(
            "  total {} B => CR {:.1}",
            bytes.len(),
            (nt * ns * ny * nx * 4) as f64 / bytes.len() as f64
        );
    } else {
        return Err(Error::format("unknown archive type".to_string()));
    }
    Ok(())
}
