//! Time-window shard planning — the data-layer half of the streaming
//! engine.
//!
//! A `[T, S, Y, X]` field is processed as `ceil(T / kt_window)` independent
//! shards, each covering a contiguous run of timesteps that is a multiple
//! of the block extent `kt`.  Because the layout is time-major, a shard's
//! mass data is a *contiguous slice* of the field — no gather copies; the
//! per-shard working buffers (normalized input, reconstructed output,
//! latent plane) are what bound peak memory.

use crate::data::field::Dataset;
use crate::error::{Error, Result};

/// One shard's time extent.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TimeWindow {
    /// First timestep covered.
    pub t0: usize,
    /// Number of timesteps (a multiple of the block `kt`).
    pub nt: usize,
}

impl TimeWindow {
    /// Exclusive end timestep.
    pub fn end(&self) -> usize {
        self.t0 + self.nt
    }
}

/// Partition of `0..nt` into uniform windows of `kt_window` timesteps
/// (the last window may be shorter, still a `kt` multiple).
#[derive(Clone, Debug)]
pub struct ShardPlan {
    pub nt: usize,
    pub kt_window: usize,
    windows: Vec<TimeWindow>,
}

impl ShardPlan {
    /// Build a plan.  `kt_window == 0` selects the auto window
    /// `min(4 * block_kt, nt)`; otherwise it must be a positive multiple of
    /// `block_kt`.  `nt` must itself be divisible by `block_kt` (the same
    /// precondition [`crate::data::blocks::BlockGrid`] enforces).
    pub fn new(nt: usize, block_kt: usize, kt_window: usize) -> Result<ShardPlan> {
        if block_kt == 0 || nt == 0 || nt % block_kt != 0 {
            return Err(Error::shape(format!(
                "shard plan: nt {nt} not divisible by block kt {block_kt}"
            )));
        }
        let w = if kt_window == 0 {
            (4 * block_kt).min(nt)
        } else {
            kt_window
        };
        if w % block_kt != 0 {
            return Err(Error::shape(format!(
                "kt_window {w} is not a multiple of block kt {block_kt}"
            )));
        }
        let w = w.min(nt);
        let windows = (0..nt)
            .step_by(w)
            .map(|t0| TimeWindow {
                t0,
                nt: w.min(nt - t0),
            })
            .collect();
        Ok(ShardPlan {
            nt,
            kt_window: w,
            windows,
        })
    }

    pub fn len(&self) -> usize {
        self.windows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.windows.is_empty()
    }

    pub fn window(&self, i: usize) -> TimeWindow {
        self.windows[i]
    }

    pub fn windows(&self) -> &[TimeWindow] {
        &self.windows
    }

    /// Indices of the windows intersecting the half-open range `[t0, t1)`.
    pub fn touching(&self, t0: usize, t1: usize) -> Result<std::ops::Range<usize>> {
        if t0 >= t1 || t1 > self.nt {
            return Err(Error::shape(format!(
                "time range [{t0}, {t1}) out of bounds for nt {}",
                self.nt
            )));
        }
        // windows are uniform (last may be short), so index = t / width
        Ok((t0 / self.kt_window)..((t1 - 1) / self.kt_window + 1))
    }
}

/// A borrowed time-window view of a dataset's mass data (contiguous in the
/// `[T, S, Y, X]` layout).
#[derive(Clone, Copy, Debug)]
pub struct ShardView<'a> {
    pub window: TimeWindow,
    pub ns: usize,
    pub ny: usize,
    pub nx: usize,
    /// `[window.nt, S, Y, X]` row-major.
    pub mass: &'a [f32],
}

impl Dataset {
    /// Borrow the contiguous mass slice of one time window.
    pub fn shard_view(&self, window: TimeWindow) -> Result<ShardView<'_>> {
        if window.end() > self.nt {
            return Err(Error::shape(format!(
                "shard window [{}, {}) exceeds nt {}",
                window.t0,
                window.end(),
                self.nt
            )));
        }
        let stride = self.ns * self.ny * self.nx;
        Ok(ShardView {
            window,
            ns: self.ns,
            ny: self.ny,
            nx: self.nx,
            mass: &self.mass[window.t0 * stride..window.end() * stride],
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_covers_time_axis_exactly() {
        let p = ShardPlan::new(24, 4, 8).unwrap();
        assert_eq!(p.len(), 3);
        assert_eq!(p.window(0), TimeWindow { t0: 0, nt: 8 });
        assert_eq!(p.window(2), TimeWindow { t0: 16, nt: 8 });
        let covered: usize = p.windows().iter().map(|w| w.nt).sum();
        assert_eq!(covered, 24);

        // short last window, still a kt multiple
        let p = ShardPlan::new(20, 4, 8).unwrap();
        assert_eq!(p.len(), 3);
        assert_eq!(p.window(2), TimeWindow { t0: 16, nt: 4 });
    }

    #[test]
    fn auto_window_and_degenerate_cases() {
        let p = ShardPlan::new(8, 4, 0).unwrap();
        assert_eq!(p.len(), 1);
        assert_eq!(p.kt_window, 8);
        let p = ShardPlan::new(48, 4, 0).unwrap();
        assert_eq!(p.kt_window, 16);
        assert_eq!(p.len(), 3);
        assert!(ShardPlan::new(10, 4, 0).is_err());
        assert!(ShardPlan::new(8, 4, 6).is_err());
        assert!(ShardPlan::new(0, 4, 4).is_err());
    }

    #[test]
    fn touching_selects_overlapping_windows() {
        let p = ShardPlan::new(32, 4, 8).unwrap();
        assert_eq!(p.touching(0, 32).unwrap(), 0..4);
        assert_eq!(p.touching(8, 16).unwrap(), 1..2);
        assert_eq!(p.touching(7, 9).unwrap(), 0..2);
        assert_eq!(p.touching(31, 32).unwrap(), 3..4);
        assert!(p.touching(4, 4).is_err());
        assert!(p.touching(0, 33).is_err());
    }

    #[test]
    fn shard_view_is_contiguous_slice() {
        let mut ds = Dataset::new(8, 2, 3, 3);
        for (i, v) in ds.mass.iter_mut().enumerate() {
            *v = i as f32;
        }
        let v = ds.shard_view(TimeWindow { t0: 4, nt: 4 }).unwrap();
        let stride = 2 * 3 * 3;
        assert_eq!(v.mass.len(), 4 * stride);
        assert_eq!(v.mass[0], (4 * stride) as f32);
        assert!(ds.shard_view(TimeWindow { t0: 6, nt: 4 }).is_err());
    }
}
