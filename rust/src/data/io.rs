//! `SDF1` dataset container IO — the cross-language format written by
//! `python/compile/data.py::write_dataset` and read here at request time.
//!
//! Layout (little-endian): magic `SDF1`, dims `[T, S, Y, X]` as u32,
//! temperature `[T, Y, X]` f32, mass `[T, S, Y, X]` f32.

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use crate::data::field::Dataset;
use crate::error::{Error, Result};

const MAGIC: &[u8; 4] = b"SDF1";

/// Read a dataset; validates magic and exact payload length.
pub fn read_dataset<P: AsRef<Path>>(path: P) -> Result<Dataset> {
    let f = File::open(path.as_ref())?;
    let mut r = BufReader::with_capacity(1 << 20, f);

    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(Error::format(format!(
            "bad SDF1 magic {:?} in {}",
            magic,
            path.as_ref().display()
        )));
    }
    let mut dims = [0u8; 16];
    r.read_exact(&mut dims)?;
    let d = |i: usize| u32::from_le_bytes(dims[i * 4..i * 4 + 4].try_into().unwrap()) as usize;
    let (nt, ns, ny, nx) = (d(0), d(1), d(2), d(3));
    if nt == 0 || ns == 0 || ny == 0 || nx == 0 || nt * ns * ny * nx > (1 << 33) {
        return Err(Error::format(format!(
            "implausible dims {nt}x{ns}x{ny}x{nx}"
        )));
    }

    let mut ds = Dataset::new(nt, ns, ny, nx);
    read_f32s(&mut r, &mut ds.temp)?;
    read_f32s(&mut r, &mut ds.mass)?;
    Ok(ds)
}

/// Write a dataset in `SDF1` format.
pub fn write_dataset<P: AsRef<Path>>(path: P, ds: &Dataset) -> Result<()> {
    let f = File::create(path)?;
    let mut w = BufWriter::with_capacity(1 << 20, f);
    w.write_all(MAGIC)?;
    for dim in [ds.nt, ds.ns, ds.ny, ds.nx] {
        w.write_all(&(dim as u32).to_le_bytes())?;
    }
    write_f32s(&mut w, &ds.temp)?;
    write_f32s(&mut w, &ds.mass)?;
    w.flush()?;
    Ok(())
}

fn read_f32s<R: Read>(r: &mut R, out: &mut [f32]) -> Result<()> {
    // bulk read into the f32 buffer via a byte view (LE hosts: direct copy)
    let mut bytes = vec![0u8; out.len() * 4];
    r.read_exact(&mut bytes)?;
    for (o, c) in out.iter_mut().zip(bytes.chunks_exact(4)) {
        *o = f32::from_le_bytes(c.try_into().unwrap());
    }
    Ok(())
}

fn write_f32s<W: Write>(w: &mut W, xs: &[f32]) -> Result<()> {
    // chunked to keep memory bounded on the medium/paper profiles
    let mut buf = Vec::with_capacity(1 << 20);
    for chunk in xs.chunks(1 << 18) {
        buf.clear();
        for &v in chunk {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        w.write_all(&buf)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    #[test]
    fn roundtrip() {
        let mut ds = Dataset::new(2, 3, 4, 5);
        let mut rng = Prng::new(1);
        for v in ds.mass.iter_mut() {
            *v = rng.next_f32();
        }
        for v in ds.temp.iter_mut() {
            *v = 1000.0 + rng.next_f32();
        }
        let path = std::env::temp_dir().join("gbatc_io_test.bin");
        write_dataset(&path, &ds).unwrap();
        let ds2 = read_dataset(&path).unwrap();
        assert_eq!(ds.mass, ds2.mass);
        assert_eq!(ds.temp, ds2.temp);
        assert_eq!((ds2.nt, ds2.ns, ds2.ny, ds2.nx), (2, 3, 4, 5));
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn bad_magic_rejected() {
        let path = std::env::temp_dir().join("gbatc_io_bad.bin");
        std::fs::write(&path, b"NOPE0000000000000000").unwrap();
        assert!(read_dataset(&path).is_err());
        std::fs::remove_file(path).ok();
    }
}
