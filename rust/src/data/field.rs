//! 4-D field container for multi-species CFD snapshots.
//!
//! Layout matches the python build path and the `SDF1` file format:
//! `mass` is row-major `[T, S, Y, X]` (time, species, rows, cols) and
//! `temp` is `[T, Y, X]`.

use crate::error::{Error, Result};

/// A `[T, Y, X]` scalar field (temperature, or one species' trajectory).
#[derive(Clone, Debug)]
pub struct Field3 {
    pub nt: usize,
    pub ny: usize,
    pub nx: usize,
    pub data: Vec<f32>,
}

impl Field3 {
    pub fn zeros(nt: usize, ny: usize, nx: usize) -> Self {
        Self {
            nt,
            ny,
            nx,
            data: vec![0.0; nt * ny * nx],
        }
    }

    #[inline]
    pub fn at(&self, t: usize, y: usize, x: usize) -> f32 {
        self.data[(t * self.ny + y) * self.nx + x]
    }

    #[inline]
    pub fn at_mut(&mut self, t: usize, y: usize, x: usize) -> &mut f32 {
        &mut self.data[(t * self.ny + y) * self.nx + x]
    }

    /// One time frame as a contiguous slice of length ny*nx.
    pub fn frame(&self, t: usize) -> &[f32] {
        let n = self.ny * self.nx;
        &self.data[t * n..(t + 1) * n]
    }
}

/// The full dataset: S species mass-fraction fields + temperature.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub nt: usize,
    pub ns: usize,
    pub ny: usize,
    pub nx: usize,
    /// Row-major `[T, S, Y, X]`.
    pub mass: Vec<f32>,
    /// Row-major `[T, Y, X]`.
    pub temp: Vec<f32>,
    /// Ambient pressure [Pa] (constant-volume HCCI window; single value).
    pub pressure: f64,
}

impl Dataset {
    pub fn new(nt: usize, ns: usize, ny: usize, nx: usize) -> Self {
        Self {
            nt,
            ns,
            ny,
            nx,
            mass: vec![0.0; nt * ns * ny * nx],
            temp: vec![0.0; nt * ny * nx],
            pressure: 40.0e5,
        }
    }

    #[inline]
    pub fn idx(&self, t: usize, s: usize, y: usize, x: usize) -> usize {
        ((t * self.ns + s) * self.ny + y) * self.nx + x
    }

    #[inline]
    pub fn at(&self, t: usize, s: usize, y: usize, x: usize) -> f32 {
        self.mass[self.idx(t, s, y, x)]
    }

    #[inline]
    pub fn temp_at(&self, t: usize, y: usize, x: usize) -> f32 {
        self.temp[(t * self.ny + y) * self.nx + x]
    }

    /// Number of mass-fraction scalars.
    pub fn len(&self) -> usize {
        self.mass.len()
    }

    pub fn is_empty(&self) -> bool {
        self.mass.is_empty()
    }

    /// Primary-data payload bytes (the paper's CR numerator): mass only.
    pub fn pd_bytes(&self) -> usize {
        self.mass.len() * 4
    }

    /// Contiguous `[Y, X]` frame of one species at one time.
    pub fn species_frame(&self, t: usize, s: usize) -> &[f32] {
        let n = self.ny * self.nx;
        let off = (t * self.ns + s) * n;
        &self.mass[off..off + n]
    }

    /// Gather one species' full `[T, Y, X]` trajectory (copy).
    pub fn species_field(&self, s: usize) -> Field3 {
        let mut f = Field3::zeros(self.nt, self.ny, self.nx);
        let n = self.ny * self.nx;
        for t in 0..self.nt {
            let off = (t * self.ns + s) * n;
            f.data[t * n..(t + 1) * n].copy_from_slice(&self.mass[off..off + n]);
        }
        f
    }

    /// Overwrite one species' trajectory from a `[T, Y, X]` field.
    pub fn set_species_field(&mut self, s: usize, f: &Field3) -> Result<()> {
        if f.nt != self.nt || f.ny != self.ny || f.nx != self.nx {
            return Err(Error::shape(format!(
                "species field {}x{}x{} != dataset {}x{}x{}",
                f.nt, f.ny, f.nx, self.nt, self.ny, self.nx
            )));
        }
        let n = self.ny * self.nx;
        for t in 0..self.nt {
            let off = (t * self.ns + s) * n;
            self.mass[off..off + n].copy_from_slice(&f.data[t * n..(t + 1) * n]);
        }
        Ok(())
    }

    /// Per-species (min, max) over all space-time — the NRMSE normalizer and
    /// the normalization the AE artifacts expect.
    pub fn species_ranges(&self) -> Vec<(f32, f32)> {
        let mut ranges = vec![(f32::INFINITY, f32::NEG_INFINITY); self.ns];
        let n = self.ny * self.nx;
        for t in 0..self.nt {
            for s in 0..self.ns {
                let off = (t * self.ns + s) * n;
                let (lo, hi) = &mut ranges[s];
                for &v in &self.mass[off..off + n] {
                    if v < *lo {
                        *lo = v;
                    }
                    if v > *hi {
                        *hi = v;
                    }
                }
            }
        }
        ranges
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indexing_roundtrip() {
        let mut ds = Dataset::new(2, 3, 4, 5);
        let i = ds.idx(1, 2, 3, 4);
        ds.mass[i] = 7.5;
        assert_eq!(ds.at(1, 2, 3, 4), 7.5);
        assert_eq!(ds.len(), 2 * 3 * 4 * 5);
    }

    #[test]
    fn species_field_roundtrip() {
        let mut ds = Dataset::new(3, 2, 4, 4);
        for (i, v) in ds.mass.iter_mut().enumerate() {
            *v = i as f32;
        }
        let f = ds.species_field(1);
        assert_eq!(f.at(2, 3, 3), ds.at(2, 1, 3, 3));
        let mut ds2 = Dataset::new(3, 2, 4, 4);
        ds2.set_species_field(1, &f).unwrap();
        assert_eq!(ds2.at(2, 1, 3, 3), ds.at(2, 1, 3, 3));
        assert_eq!(ds2.at(2, 0, 3, 3), 0.0);
    }

    #[test]
    fn ranges_cover_extremes() {
        let mut ds = Dataset::new(1, 2, 2, 2);
        ds.mass = vec![1.0, 2.0, 3.0, 4.0, -1.0, 0.0, 5.0, 2.0];
        let r = ds.species_ranges();
        assert_eq!(r[0], (1.0, 4.0));
        assert_eq!(r[1], (-1.0, 5.0));
    }

    #[test]
    fn shape_mismatch_rejected() {
        let mut ds = Dataset::new(2, 2, 4, 4);
        let f = Field3::zeros(2, 3, 4);
        assert!(ds.set_species_field(0, &f).is_err());
    }
}
