//! Synthetic S3D-HCCI-like dataset generator — rust port of
//! `python/compile/data.py::generate` (same formulas & parameters; the PRNG
//! differs, so fields are distribution-identical, not bit-identical — the
//! AE artifacts are trained on the python output and generalize across
//! seeds because the manifold is the same).  See DESIGN.md §3 for why this
//! substitutes for the paper's S3D data.

use crate::chem::species::{Role, NS, SPECIES};
use crate::data::field::Dataset;
use crate::util::Prng;

/// Dataset size presets (mirrors python `PROFILES`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Profile {
    Tiny,
    Small,
    Medium,
    Paper,
}

impl Profile {
    pub fn dims(self) -> (usize, usize, usize) {
        match self {
            Profile::Tiny => (8, 40, 40),
            Profile::Small => (16, 80, 80),
            Profile::Medium => (24, 320, 320),
            Profile::Paper => (48, 640, 640),
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "tiny" => Some(Profile::Tiny),
            "small" => Some(Profile::Small),
            "medium" => Some(Profile::Medium),
            "paper" => Some(Profile::Paper),
            _ => None,
        }
    }
}

const N_MODES: usize = 12;

/// One advected Fourier-mode bundle (the GRF-like inhomogeneity field).
struct Modes {
    kx: [f32; N_MODES],
    ky: [f32; N_MODES],
    ph: [f32; N_MODES],
    amp: [f32; N_MODES],
    ux: [f32; N_MODES],
    uy: [f32; N_MODES],
}

impl Modes {
    fn random(rng: &mut Prng) -> Self {
        let mut m = Modes {
            kx: [0.0; N_MODES],
            ky: [0.0; N_MODES],
            ph: [0.0; N_MODES],
            amp: [0.0; N_MODES],
            ux: [0.0; N_MODES],
            uy: [0.0; N_MODES],
        };
        let mut asum = 0.0f32;
        for i in 0..N_MODES {
            m.kx[i] = rng.range_u64(1, 9) as f32;
            m.ky[i] = rng.range_u64(1, 9) as f32;
            m.ph[i] = rng.uniform(0.0, std::f64::consts::TAU) as f32;
            m.amp[i] =
                (rng.uniform(0.4, 1.0) as f32) / (m.kx[i] * m.kx[i] + m.ky[i] * m.ky[i]).sqrt();
            m.ux[i] = rng.uniform(-0.15, 0.15) as f32;
            m.uy[i] = rng.uniform(-0.15, 0.15) as f32;
            asum += m.amp[i];
        }
        for i in 0..N_MODES {
            m.amp[i] /= asum;
        }
        m
    }

    /// Evaluate the advected field at (gx, gy, t).
    #[inline]
    fn eval(&self, gx: f32, gy: f32, t: f32) -> f32 {
        let mut f = 0.0f32;
        for i in 0..N_MODES {
            f += self.amp[i]
                * (std::f32::consts::TAU
                    * (self.kx[i] * (gx - self.ux[i] * t) + self.ky[i] * (gy - self.uy[i] * t))
                    + self.ph[i])
                    .sin();
        }
        f
    }
}

#[inline]
fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// Generate a synthetic HCCI-like dataset (mass fractions + temperature).
pub fn generate(profile: Profile, seed: u64) -> Dataset {
    let (nt, ny, nx) = profile.dims();
    let mut ds = Dataset::new(nt, NS, ny, nx);
    let mut rng = Prng::new(seed);
    let m1 = Modes::random(&mut rng);
    let m2 = Modes::random(&mut rng);
    let m3 = Modes::random(&mut rng);

    let npix = ny * nx;
    let mut theta = vec![0.0f32; npix];
    let mut eps1 = vec![0.0f32; npix];
    let mut eps2 = vec![0.0f32; npix];

    for it in 0..nt {
        let t = if nt > 1 {
            it as f32 / (nt - 1) as f32
        } else {
            0.0
        };
        for y in 0..ny {
            let gy = y as f32 / ny as f32;
            for x in 0..nx {
                let gx = x as f32 / nx as f32;
                let p = y * nx + x;
                theta[p] = m1.eval(gx, gy, t);
                eps1[p] = m2.eval(gx, gy, t);
                eps2[p] = m3.eval(gx, gy, t);
            }
        }

        let tbase = 1050.0 + 120.0 * t;
        for p in 0..npix {
            let th = theta[p];
            let d1 = 0.18 - 0.22 * th;
            let d2 = 0.55 - 0.35 * th;
            let c1 = sigmoid((t - d1) / 0.035);
            let c2 = sigmoid((t - d2) / 0.045);
            let temp = tbase + 55.0 * th + 140.0 * c1 + 950.0 * c2;
            ds.temp[it * npix + p] = temp;

            let c = 0.25 * c1 + 0.75 * c2;
            let tn = (temp - 1050.0) / 1200.0;

            for (k, sp) in SPECIES.iter().enumerate() {
                let f = match sp.role {
                    Role::Fuel => (1.0 - c1) * (1.0 - 0.92 * c2),
                    Role::Oxidizer => 1.0 - 0.55 * c2 - 0.05 * c1,
                    Role::Inert => 1.0 + 0.0008 * eps1[p],
                    Role::Product => {
                        let g = sigmoid((c - sp.center) / (0.25 * sp.width + 0.05));
                        g * (1.0 + 0.05 * tn)
                    }
                    Role::Co => {
                        let b = (-((c - sp.center) * (c - sp.center))
                            / (2.0 * sp.width * sp.width))
                            .exp();
                        b * (0.25 + 0.75 * c2) + 0.15 * c2
                    }
                    Role::LowT => {
                        let a = 0.25 * c1 + 0.02 - sp.center;
                        (-(a * a) / (2.0 * sp.width * sp.width)).exp()
                            * c1
                            * (1.0 - c2)
                            * (1.0 - c2)
                    }
                    Role::Intermediate | Role::Radical => {
                        let mut b = (-((c - sp.center) * (c - sp.center))
                            / (2.0 * sp.width * sp.width))
                            .exp();
                        if sp.role == Role::Radical {
                            b *= (2.2 * (tn - 0.5)).exp();
                        }
                        b
                    }
                };
                let noise =
                    1.0 + 0.004 * eps1[p] + 0.0024 * eps2[p] * (3.1 * k as f32 + 0.7).sin();
                let v = (sp.magnitude * f * noise).max(0.0);
                ds.mass[((it * NS + k) * ny) * nx + p] = v;
            }
        }
    }
    ds
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chem::species::index_of;

    #[test]
    fn tiny_profile_shape_and_determinism() {
        let a = generate(Profile::Tiny, 7);
        let b = generate(Profile::Tiny, 7);
        assert_eq!((a.nt, a.ns, a.ny, a.nx), (8, 58, 40, 40));
        assert_eq!(a.mass, b.mass);
        let c = generate(Profile::Tiny, 8);
        assert_ne!(a.mass, c.mass);
    }

    #[test]
    fn physical_plausibility() {
        let ds = generate(Profile::Tiny, 7);
        assert!(ds.mass.iter().all(|v| *v >= 0.0 && v.is_finite()));
        assert!(ds.temp.iter().all(|v| *v > 900.0 && *v < 3000.0));
        // fuel decays in time on average; products grow
        let npix = ds.ny * ds.nx;
        let mean = |t: usize, s: usize| -> f64 {
            ds.species_frame(t, s).iter().map(|v| *v as f64).sum::<f64>() / npix as f64
        };
        let fuel = index_of("nC7H16").unwrap();
        let h2o = index_of("H2O").unwrap();
        assert!(mean(ds.nt - 1, fuel) < mean(0, fuel));
        assert!(mean(ds.nt - 1, h2o) > mean(0, h2o));
    }

    #[test]
    fn species_span_decades() {
        let ds = generate(Profile::Tiny, 7);
        let ranges = ds.species_ranges();
        let maxmax = ranges.iter().map(|r| r.1).fold(0.0f32, f32::max);
        let minmax = ranges.iter().map(|r| r.1).fold(f32::INFINITY, f32::min);
        assert!(maxmax > 0.5); // N2
        assert!(minmax < 1e-6); // NNH-scale radicals
    }

    #[test]
    fn spatial_correlation_present() {
        // neighboring pixels should be far more similar than random pairs
        let ds = generate(Profile::Small, 7);
        let f = ds.species_frame(8, 5); // CO mid-ignition
        let mut rng = Prng::new(3);
        let (mut dn, mut dr, n) = (0.0f64, 0.0f64, 4000);
        for _ in 0..n {
            let y = rng.index(ds.ny - 1);
            let x = rng.index(ds.nx - 1);
            dn += (f[y * ds.nx + x] - f[y * ds.nx + x + 1]).abs() as f64;
            let (y2, x2) = (rng.index(ds.ny), rng.index(ds.nx));
            dr += (f[y * ds.nx + x] - f[y2 * ds.nx + x2]).abs() as f64;
        }
        assert!(dn < 0.65 * dr, "neighbor diff {dn} vs random diff {dr}");
    }
}
