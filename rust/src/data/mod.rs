//! Dataset substrate: the 4-D field container ([T, S, Y, X] mass fractions +
//! [T, Y, X] temperature), the `SDF1` on-disk format shared with the python
//! build path, the paper's spatiotemporal block partitioner, and the
//! synthetic S3D-HCCI-like generator (rust port of `python/compile/data.py`).

pub mod blocks;
pub mod field;
pub mod io;
pub mod shards;
pub mod synth;

pub use blocks::{BlockGrid, BlockShape};
pub use field::{Dataset, Field3};
pub use shards::{ShardPlan, ShardView, TimeWindow};
pub use synth::{generate, Profile};
