//! Spatiotemporal block partitioner — the paper's GBA input layout.
//!
//! The field is cut into non-overlapping blocks of `kt` timesteps by
//! `by x bx` grid points; every AE instance carries *all* S species of one
//! block in `[S, kt, by, bx]` order (species = conv channels).  The
//! guarantee post-processing re-views each instance as S per-species block
//! vectors of length `D = kt*by*bx` (paper: D = 4*5*4 = 80).

use crate::data::field::Dataset;
use crate::error::{Error, Result};

/// Block extents (paper default 4 x 5 x 4).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BlockShape {
    pub kt: usize,
    pub by: usize,
    pub bx: usize,
}

impl Default for BlockShape {
    fn default() -> Self {
        Self { kt: 4, by: 5, bx: 4 }
    }
}

impl BlockShape {
    /// Per-species block vector length D.
    pub fn d(&self) -> usize {
        self.kt * self.by * self.bx
    }
}

/// Partitioning of a `[T, S, Y, X]` dataset into blocks.
#[derive(Clone, Debug)]
pub struct BlockGrid {
    pub shape: BlockShape,
    pub nt: usize,
    pub ns: usize,
    pub ny: usize,
    pub nx: usize,
    pub tb: usize,
    pub yb: usize,
    pub xb: usize,
}

impl BlockGrid {
    pub fn new(ds_dims: (usize, usize, usize, usize), shape: BlockShape) -> Result<Self> {
        let (nt, ns, ny, nx) = ds_dims;
        if nt % shape.kt != 0 || ny % shape.by != 0 || nx % shape.bx != 0 {
            return Err(Error::shape(format!(
                "dims {nt}x{ny}x{nx} not divisible by block {}x{}x{}",
                shape.kt, shape.by, shape.bx
            )));
        }
        Ok(Self {
            shape,
            nt,
            ns,
            ny,
            nx,
            tb: nt / shape.kt,
            yb: ny / shape.by,
            xb: nx / shape.bx,
        })
    }

    pub fn for_dataset(ds: &Dataset, shape: BlockShape) -> Result<Self> {
        Self::new((ds.nt, ds.ns, ds.ny, ds.nx), shape)
    }

    /// Total number of blocks (AE instances).
    pub fn n_blocks(&self) -> usize {
        self.tb * self.yb * self.xb
    }

    /// Instance length S * D.
    pub fn instance_len(&self) -> usize {
        self.ns * self.shape.d()
    }

    /// Block id -> (tb, yb, xb) coordinates.
    #[inline]
    pub fn coords(&self, b: usize) -> (usize, usize, usize) {
        let per_frame = self.yb * self.xb;
        (b / per_frame, (b % per_frame) / self.xb, b % self.xb)
    }

    /// Gather block `b` from `mass` (layout `[T,S,Y,X]`) into `out` in
    /// `[S, kt, by, bx]` order.  `out.len() == instance_len()`.
    pub fn gather(&self, mass: &[f32], b: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.instance_len());
        let (bt, byy, bxx) = self.coords(b);
        let (kt, by, bx) = (self.shape.kt, self.shape.by, self.shape.bx);
        let (t0, y0, x0) = (bt * kt, byy * by, bxx * bx);
        let mut o = 0;
        for s in 0..self.ns {
            for dt in 0..kt {
                for dy in 0..by {
                    let base = (((t0 + dt) * self.ns + s) * self.ny + (y0 + dy)) * self.nx + x0;
                    out[o..o + bx].copy_from_slice(&mass[base..base + bx]);
                    o += bx;
                }
            }
        }
    }

    /// Scatter an instance (layout `[S, kt, by, bx]`) back into `mass`.
    pub fn scatter(&self, mass: &mut [f32], b: usize, inst: &[f32]) {
        debug_assert_eq!(inst.len(), self.instance_len());
        let (bt, byy, bxx) = self.coords(b);
        let (kt, by, bx) = (self.shape.kt, self.shape.by, self.shape.bx);
        let (t0, y0, x0) = (bt * kt, byy * by, bxx * bx);
        let mut o = 0;
        for s in 0..self.ns {
            for dt in 0..kt {
                for dy in 0..by {
                    let base = (((t0 + dt) * self.ns + s) * self.ny + (y0 + dy)) * self.nx + x0;
                    mass[base..base + bx].copy_from_slice(&inst[o..o + bx]);
                    o += bx;
                }
            }
        }
    }

    /// View an instance as S per-species block vectors: returns slices of
    /// length D (no copy; the layout is already species-major).
    pub fn species_vectors<'a>(&self, inst: &'a [f32]) -> impl Iterator<Item = &'a [f32]> {
        let d = self.shape.d();
        inst.chunks_exact(d)
    }

    /// Gather the per-species block vector (length D) of block `b`,
    /// species `s` straight from `[T,S,Y,X]` mass data.
    pub fn gather_species(&self, mass: &[f32], b: usize, s: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.shape.d());
        let (bt, byy, bxx) = self.coords(b);
        let (kt, by, bx) = (self.shape.kt, self.shape.by, self.shape.bx);
        let (t0, y0, x0) = (bt * kt, byy * by, bxx * bx);
        let mut o = 0;
        for dt in 0..kt {
            for dy in 0..by {
                let base = (((t0 + dt) * self.ns + s) * self.ny + (y0 + dy)) * self.nx + x0;
                out[o..o + bx].copy_from_slice(&mass[base..base + bx]);
                o += bx;
            }
        }
    }

    /// Scatter a per-species block vector back into `[T,S,Y,X]` mass data.
    pub fn scatter_species(&self, mass: &mut [f32], b: usize, s: usize, vec: &[f32]) {
        debug_assert_eq!(vec.len(), self.shape.d());
        let (bt, byy, bxx) = self.coords(b);
        let (kt, by, bx) = (self.shape.kt, self.shape.by, self.shape.bx);
        let (t0, y0, x0) = (bt * kt, byy * by, bxx * bx);
        let mut o = 0;
        for dt in 0..kt {
            for dy in 0..by {
                let base = (((t0 + dt) * self.ns + s) * self.ny + (y0 + dy)) * self.nx + x0;
                mass[base..base + bx].copy_from_slice(&vec[o..o + bx]);
                o += bx;
            }
        }
    }

    /// Instance `[S, D]` -> point-major `[D, S]` (TCN input ordering).
    pub fn to_points(&self, inst: &[f32], out: &mut [f32]) {
        let d = self.shape.d();
        debug_assert_eq!(inst.len(), self.ns * d);
        debug_assert_eq!(out.len(), self.ns * d);
        for s in 0..self.ns {
            for p in 0..d {
                out[p * self.ns + s] = inst[s * d + p];
            }
        }
    }

    /// Point-major `[D, S]` -> instance `[S, D]`.
    pub fn from_points(&self, pts: &[f32], out: &mut [f32]) {
        let d = self.shape.d();
        for p in 0..d {
            for s in 0..self.ns {
                out[s * d + p] = pts[p * self.ns + s];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    fn random_ds(nt: usize, ns: usize, ny: usize, nx: usize) -> Dataset {
        let mut ds = Dataset::new(nt, ns, ny, nx);
        let mut rng = Prng::new(17);
        for v in ds.mass.iter_mut() {
            *v = rng.next_f32();
        }
        ds
    }

    #[test]
    fn gather_scatter_roundtrip_covers_everything() {
        let ds = random_ds(8, 3, 10, 8);
        let grid = BlockGrid::for_dataset(&ds, BlockShape::default()).unwrap();
        assert_eq!(grid.n_blocks(), 2 * 2 * 2);
        let mut out = vec![0.0f32; ds.mass.len()];
        let mut inst = vec![0.0f32; grid.instance_len()];
        for b in 0..grid.n_blocks() {
            grid.gather(&ds.mass, b, &mut inst);
            grid.scatter(&mut out, b, &inst);
        }
        assert_eq!(out, ds.mass);
    }

    #[test]
    fn gather_matches_direct_indexing() {
        let ds = random_ds(4, 2, 5, 4);
        let grid = BlockGrid::for_dataset(&ds, BlockShape::default()).unwrap();
        let mut inst = vec![0.0f32; grid.instance_len()];
        grid.gather(&ds.mass, 0, &mut inst);
        // inst[s, dt, dy, dx] == ds[dt, s, dy, dx] for block 0
        let sh = grid.shape;
        for s in 0..2 {
            for dt in 0..sh.kt {
                for dy in 0..sh.by {
                    for dx in 0..sh.bx {
                        let i = ((s * sh.kt + dt) * sh.by + dy) * sh.bx + dx;
                        assert_eq!(inst[i], ds.at(dt, s, dy, dx));
                    }
                }
            }
        }
    }

    #[test]
    fn points_roundtrip() {
        let ds = random_ds(4, 5, 5, 4);
        let grid = BlockGrid::for_dataset(&ds, BlockShape::default()).unwrap();
        let mut inst = vec![0.0f32; grid.instance_len()];
        grid.gather(&ds.mass, 0, &mut inst);
        let mut pts = vec![0.0f32; inst.len()];
        let mut back = vec![0.0f32; inst.len()];
        grid.to_points(&inst, &mut pts);
        grid.from_points(&pts, &mut back);
        assert_eq!(inst, back);
        // spot-check ordering: point 0 holds species 0..S at (t0,y0,x0)
        assert_eq!(pts[3], inst[3 * grid.shape.d()]);
    }

    #[test]
    fn species_gather_matches_instance_slice() {
        let ds = random_ds(4, 3, 5, 8);
        let grid = BlockGrid::for_dataset(&ds, BlockShape::default()).unwrap();
        let d = grid.shape.d();
        let mut inst = vec![0.0f32; grid.instance_len()];
        let mut sv = vec![0.0f32; d];
        for b in 0..grid.n_blocks() {
            grid.gather(&ds.mass, b, &mut inst);
            for s in 0..3 {
                grid.gather_species(&ds.mass, b, s, &mut sv);
                assert_eq!(&inst[s * d..(s + 1) * d], &sv[..]);
            }
        }
        // scatter_species inverts gather_species
        let mut out = vec![0.0f32; ds.mass.len()];
        for b in 0..grid.n_blocks() {
            for s in 0..3 {
                grid.gather_species(&ds.mass, b, s, &mut sv);
                grid.scatter_species(&mut out, b, s, &sv);
            }
        }
        assert_eq!(out, ds.mass);
    }

    #[test]
    fn indivisible_dims_rejected() {
        let ds = random_ds(5, 2, 10, 8);
        assert!(BlockGrid::for_dataset(&ds, BlockShape::default()).is_err());
    }
}
