//! Hand-rolled CLI argument parsing (no clap in the offline image).
//!
//! Supports `--key value`, `--flag`, and positional arguments.

use std::collections::HashMap;

use crate::error::{Error, Result};

/// Parsed command line.
#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: HashMap<String, String>,
    present: Vec<String>,
}

impl Args {
    /// Parse from raw arguments (excluding program name and subcommand).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Args> {
        let mut args = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(name) = a.strip_prefix("--") {
                let name = name.to_string();
                args.present.push(name.clone());
                // value if next token isn't another flag
                if let Some(next) = iter.peek() {
                    if !next.starts_with("--") {
                        args.flags.insert(name, iter.next().unwrap());
                        continue;
                    }
                }
                args.flags.insert(name, String::new());
            } else {
                args.positional.push(a);
            }
        }
        Ok(args)
    }

    pub fn has(&self, name: &str) -> bool {
        self.present.iter().any(|p| p == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str()).filter(|s| !s.is_empty())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_parse<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|e| Error::config(format!("--{name} {s}: {e}"))),
        }
    }

    pub fn require(&self, name: &str) -> Result<&str> {
        self.get(name)
            .ok_or_else(|| Error::config(format!("missing required --{name}")))
    }
}

pub const USAGE: &str = "\
gbatc — Guaranteed Block Autoencoder with Tensor Correction (CFD data reduction)

USAGE: gbatc <command> [options]

COMMANDS:
  gen-data    --out <file> [--profile tiny|small|medium|paper] [--seed N]
              Generate a synthetic S3D-HCCI-like dataset (SDF1).
  compress    --input <sdf> --output <gba> [--nrmse 1e-3] [--no-tcn]
              [--species-nrmse NAME=T[,NAME=T...]]
              [--codec auto|gbatc|sz|dense] [--latent-bin 0.02]
              [--artifacts DIR | --reference] [--threads N]
              [--kt-window N] [--shard-workers N]
              [--full-basis] [--model-f32] [--v1]
              Streams the dataset through a push-based api session
              (gbatc::api::CompressSession): guaranteed per-species
              error bounds, shard payloads written to the output file as
              each kt-window finishes, peak memory bounded by one shard.
              A session compresses windows in arrival order (all cores
              work inside the current shard); --shard-workers applies to
              the library's one-shot ShardEngine::compress path.
              --nrmse is the uniform accuracy target; --species-nrmse
              overrides it per species (by mechanism name or index),
              e.g. --species-nrmse OH=1e-5,nC7H16=5e-4 — each
              (shard, species) is certified against its own budget.
              --codec auto runs the rate-distortion planner: per
              (shard, species) it trials GBATC, SZ, and a dense-plane
              fallback and keeps the smallest encoding certifying that
              species' bound (mixed-codec v3 container; all-GBATC
              archives stay v2).  --v1 emits the legacy single-shot GBA1
              container (needs kt-window >= T and --codec gbatc).  The
              report prints per-stage wall times (PCA fit, guarantee
              loop, entropy encode, planner trials) for perf attribution.
  decompress  --input <gba> --output <sdf> [--artifacts DIR | --reference]
              [--threads N] [--temp-from <sdf>]
              Reconstruct mass fractions (temperature copied from
              --temp-from if given, else zeros).  Accepts GBA1 and GBA2.
  extract     --input <gba2> --output <sdf> [--t0 N] [--t1 N]
              [--species NAME|INDEX[,NAME|INDEX...]]
              [--artifacts DIR | --reference] [--threads N]
              Random-access partial decode through the typed api query
              (gbatc::api::ArchiveReader): reads only the shards/species
              sections the query touches; reports archive bytes read.
              Species are mechanism names (e.g. OH,CO) or numeric
              indices; unknown names list the available ones.
  inspect     --archive <gba|gba2|szf> [--stats [--json]] [--verify]
              Print the GBA2 table of contents (per-shard and per-species
              byte ranges), per-section codec tags, per-codec byte
              totals, and size breakdown.  --stats additionally reopens
              the archive through the metered reader and reports the
              classified open IO (header/TOC reads vs payload reads) and
              how the bytes were served: zero-copy mmap vs buffered
              read(2); with --json the stats (dims, sizes, per-codec
              totals, IO split) print as one machine-readable JSON
              object instead.  --verify instead walks every section (latent
              planes, per-species payloads, journal records of an
              unsealed stream) and decodes each; prints the damaged
              (shard, species) list and exits nonzero if anything fails.
  repair      --input <gba|gba2|stream> (--output <file> | --in-place)
              Salvage the valid prefix of a damaged archive into a
              well-formed GBA2: a torn sealed archive keeps its intact
              shard prefix; an interrupted stream (GBJL journal, e.g. a
              crash mid-compression) is sealed from its committed shards
              (CRC-checked).  Already-intact inputs pass through
              unchanged.  Errors when nothing is recoverable.
  compact     <gba2>... --output <file>
              Merge shard-compatible archives from one (possibly
              interrupted and resumed) compression run into a single
              GBA2, walking the shard tiling from t=0 and dropping
              duplicate (time-covered) and orphaned (gap/after-torn)
              shards.  Headers must agree on dims/block/latent/ranges.
  serve       --mount NAME=PATH[,NAME=PATH...] [--listen 127.0.0.1:7070]
              [--workers 4] [--queue 64] [--replicas 1] [--max-conns 1024]
              [--cache-mb 256] [--max-response-mb 256] [--threads N]
              [--artifacts DIR | --reference]
              Mount archives under named dataset keys and serve them over
              HTTP/1.1 (gbatc::store + gbatc::serve).  On Linux an epoll
              event loop handles keep-alive + pipelined connections with
              admission control (connection cap, bounded decode queue,
              idle reaping); elsewhere (or with GBATC_NO_EPOLL=1) a
              thread pool speaks the same protocol.  --replicas N
              consistent-hashes datasets across N in-process store
              replicas (warm-cache affinity).  Warm queries decode
              nothing and read no archive bytes, and responses are
              bit-identical to a local decode.  Endpoints: GET /datasets
              (catalog), GET /query?dataset=..&t0=..&t1=..&species=..
              (binary f32 body + X-Gbatc-Meta JSON header), GET /stats
              (cache/decode/IO/server/event-loop/replica counters),
              GET /metrics (Prometheus text: latency/decode/cache-probe
              histograms + counters), GET /trace/slow (worst request
              spans with per-phase timings).  Tracing is sampled 1-in-N
              (GBATC_TRACE_SAMPLE, default 16; GBATC_NO_TRACE=1
              disables); every response carries X-Gbatc-Trace-Id while
              enabled.
  stats       [SERVER] [--server 127.0.0.1:7070] [--slow N]
              Render a running server's /metrics (histogram quantiles +
              counters) and its /trace/slow spans with per-phase
              breakdowns.
  query       DATASET [--server 127.0.0.1:7070] [--t0 N] [--t1 N]
              [--species NAME|INDEX[,...]] [--output <sdf>]
              Remote partial decode against a running `gbatc serve`:
              fetches the window/species subset over HTTP keep-alive
              (one reused connection) and optionally writes it as an
              SDF1 dataset.  Defaults to the full time axis and all
              species.
  sz          --input <sdf> --output <szf> [--nrmse 1e-3]
              [--mode auto|lorenzo|interp] [--eb-scale 1.0]
              SZ baseline compression.
  sz-decompress --input <szf> --output <sdf> [--temp-from <sdf>]
  evaluate    --orig <sdf> --recon <sdf> [--species NAME] [--qoi]
              [--sample-stride N]
              NRMSE/PSNR/SSIM per species (+ QoI errors with --qoi).
  info        --archive <gba|szf>
              Print archive layout and compression ratio.
  help        Show this message.

AOT artifacts are produced by `make artifacts` (python build path);
--reference runs the deterministic pure-Rust backend instead (no
artifacts needed — same guaranteed error bounds, lower CR).
";
#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn flags_and_values() {
        let a = parse(&["--input", "x.bin", "--no-tcn", "--nrmse", "1e-3", "pos"]);
        assert_eq!(a.get("input"), Some("x.bin"));
        assert!(a.has("no-tcn"));
        assert!(!a.has("tcn"));
        assert_eq!(a.get_parse::<f64>("nrmse", 0.0).unwrap(), 1e-3);
        assert_eq!(a.positional, vec!["pos"]);
    }

    #[test]
    fn defaults_and_required() {
        let a = parse(&["--x", "1"]);
        assert_eq!(a.get_or("y", "def"), "def");
        assert_eq!(a.get_parse::<usize>("z", 7).unwrap(), 7);
        assert!(a.require("missing").is_err());
        assert_eq!(a.require("x").unwrap(), "1");
    }

    #[test]
    fn bad_parse_is_error() {
        let a = parse(&["--n", "abc"]);
        assert!(a.get_parse::<usize>("n", 0).is_err());
    }
}
