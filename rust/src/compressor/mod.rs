//! Top-level compressors: GBA/GBATC (the paper's method) and the SZ
//! baseline behind a common trait, the codec-stage registry with its
//! per-(shard, species) rate–distortion planner, plus compression-ratio
//! accounting.

pub mod accounting;
pub mod gba;
pub mod registry;
pub mod szc;
pub mod traits;

pub use accounting::SizeBreakdown;
pub use gba::{CompressOptions, CompressReport, GbatcCompressor};
pub use registry::{
    CodecChoice, DensePlaneCodec, GbatcShardCodec, SectionCodec, SectionEncoding, SectionSalvage,
    SectionView, SzSectionCodec, TrialCache,
};
pub use szc::{SzCompressOptions, SzCompressor, SzArchive};
pub use traits::Compressor;
