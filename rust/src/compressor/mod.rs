//! Top-level compressors: GBA/GBATC (the paper's method) and the SZ
//! baseline behind a common trait, plus compression-ratio accounting.

pub mod accounting;
pub mod gba;
pub mod szc;
pub mod traits;

pub use accounting::SizeBreakdown;
pub use gba::{CompressOptions, CompressReport, GbatcCompressor};
pub use szc::{SzCompressOptions, SzCompressor, SzArchive};
pub use traits::Compressor;
