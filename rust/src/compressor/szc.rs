//! SZ baseline wrapper: compresses each species' `[T, Y, X]` field
//! independently (as SZ does — the paper highlights this as the contrast
//! with GBATC's cross-species modeling), with a per-species absolute error
//! bound derived from the NRMSE target.
//!
//! For a uniform quantization error in [-eb, eb], RMSE ≈ eb/√3, so
//! eb = √3 · nrmse_target · range hits the target NRMSE from above;
//! `eb_scale` lets the benches sweep around it.

use crate::coordinator::scheduler::par_try_map;
use crate::data::Dataset;
use crate::error::{Error, Result};
use crate::sz::codec::{sz_compress, sz_decompress, SzMode};
use crate::sz::SzField;
use crate::util::bytes::{ByteReader, ByteWriter};

/// Options for the SZ baseline.
#[derive(Clone, Copy, Debug)]
pub struct SzCompressOptions {
    pub mode: SzMode,
    /// eb = eb_scale * sqrt(3) * nrmse_target * per-species range.
    pub eb_scale: f64,
    pub threads: usize,
}

impl Default for SzCompressOptions {
    fn default() -> Self {
        Self {
            mode: SzMode::Auto,
            eb_scale: 1.0,
            threads: 0,
        }
    }
}

/// Serialized multi-species SZ archive.
pub struct SzArchive {
    pub dims: (usize, usize, usize, usize),
    pub fields: Vec<SzField>,
}

impl SzArchive {
    pub fn serialize(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.bytes(b"SZA1");
        for d in [self.dims.0, self.dims.1, self.dims.2, self.dims.3] {
            w.u32(d as u32);
        }
        for f in &self.fields {
            w.u8(match f.mode {
                SzMode::Lorenzo => 0,
                SzMode::Interp => 1,
                SzMode::Auto => 2,
            });
            w.f64(f.eb);
            w.blob(&f.payload);
        }
        w.finish()
    }

    pub fn deserialize(buf: &[u8]) -> Result<SzArchive> {
        let mut r = ByteReader::new(buf);
        if r.bytes(4)? != b"SZA1" {
            return Err(Error::format("bad SZ archive magic"));
        }
        let dims = (
            r.u32()? as usize,
            r.u32()? as usize,
            r.u32()? as usize,
            r.u32()? as usize,
        );
        let total = dims
            .0
            .checked_mul(dims.1)
            .and_then(|v| v.checked_mul(dims.2))
            .and_then(|v| v.checked_mul(dims.3))
            .ok_or_else(|| Error::format("SZ archive dims overflow"))?;
        if total == 0 || total > 1 << 33 {
            return Err(Error::format(format!("implausible SZ dims {dims:?}")));
        }
        let fdims = (dims.0, dims.2, dims.3);
        let mut fields = Vec::with_capacity(dims.1);
        for _ in 0..dims.1 {
            let mode = match r.u8()? {
                0 => SzMode::Lorenzo,
                1 => SzMode::Interp,
                m => return Err(Error::format(format!("bad SZ mode {m}"))),
            };
            let eb = r.f64()?;
            let payload = r.blob()?.to_vec();
            fields.push(SzField {
                mode,
                eb,
                dims: fdims,
                payload,
            });
        }
        Ok(SzArchive { dims, fields })
    }

    pub fn total_bytes(&self) -> usize {
        self.serialize().len()
    }

    /// Dims from the fixed header prefix only — no payload parse/copies
    /// (the cheap path behind `Compressor::archive_dims`).
    pub fn peek_dims(buf: &[u8]) -> Result<(usize, usize, usize, usize)> {
        let mut r = ByteReader::new(buf);
        if r.bytes(4)? != b"SZA1" {
            return Err(Error::format("bad SZ archive magic"));
        }
        Ok((
            r.u32()? as usize,
            r.u32()? as usize,
            r.u32()? as usize,
            r.u32()? as usize,
        ))
    }
}

impl crate::compressor::traits::Compressor for SzCompressor {
    fn name(&self) -> &str {
        "SZ"
    }

    fn compress_bytes(&self, ds: &Dataset, nrmse_target: f64) -> Result<Vec<u8>> {
        Ok(self.compress(ds, nrmse_target)?.serialize())
    }

    fn decompress_mass(&self, bytes: &[u8]) -> Result<Vec<f32>> {
        self.decompress(&SzArchive::deserialize(bytes)?)
    }

    fn archive_dims(&self, bytes: &[u8]) -> Result<(usize, usize, usize, usize)> {
        SzArchive::peek_dims(bytes)
    }

    /// Species-granular partial decode.  The SZ predictors run over each
    /// species' whole `[T, Y, X]` trajectory, so the time axis cannot be
    /// decoded partially — but decoding the *selected species only*, one
    /// at a time, bounds peak extra memory at one species field plus the
    /// output window instead of the full `[T, S, Y, X]` decode the trait
    /// default would materialize.
    fn decompress_range(
        &self,
        bytes: &[u8],
        t0: usize,
        t1: usize,
        species: &[usize],
    ) -> Result<Vec<f32>> {
        let archive = SzArchive::deserialize(bytes)?;
        let (nt, ns, ny, nx) = archive.dims;
        if t0 >= t1 || t1 > nt {
            return Err(Error::shape(format!(
                "time range [{t0}, {t1}) out of bounds for nt {nt}"
            )));
        }
        if archive.fields.len() != ns {
            return Err(Error::format(format!(
                "SZ archive has {} fields for {ns} species",
                archive.fields.len()
            )));
        }
        let sel = crate::compressor::traits::select_species(species, ns)?;
        let npix = ny * nx;
        let nsel = sel.len();
        let mut out = vec![0.0f32; (t1 - t0) * nsel * npix];
        for (k, &s) in sel.iter().enumerate() {
            let field = sz_decompress(&archive.fields[s])?;
            if field.len() != nt * npix {
                return Err(Error::format(format!(
                    "SZ field {s} decoded to {} values, expected {}",
                    field.len(),
                    nt * npix
                )));
            }
            for t in t0..t1 {
                let dst = ((t - t0) * nsel + k) * npix;
                out[dst..dst + npix].copy_from_slice(&field[t * npix..(t + 1) * npix]);
            }
        }
        Ok(out)
    }
}

/// The SZ baseline compressor.
pub struct SzCompressor {
    pub opts: SzCompressOptions,
}

impl SzCompressor {
    pub fn new(opts: SzCompressOptions) -> Self {
        Self { opts }
    }

    fn threads(&self) -> usize {
        crate::coordinator::engine::effective_threads(self.opts.threads)
    }

    /// Compress every species field in parallel.
    pub fn compress(&self, ds: &Dataset, nrmse_target: f64) -> Result<SzArchive> {
        let ranges = ds.species_ranges();
        let fields = par_try_map(ds.ns, self.threads(), |s| {
            let field = ds.species_field(s);
            let range = (ranges[s].1 - ranges[s].0).max(1e-30) as f64;
            let eb = (self.opts.eb_scale * 3f64.sqrt() * nrmse_target * range).max(1e-300);
            sz_compress(&field.data, (ds.nt, ds.ny, ds.nx), eb, self.opts.mode)
        })?;
        Ok(SzArchive {
            dims: (ds.nt, ds.ns, ds.ny, ds.nx),
            fields,
        })
    }

    /// Decompress to mass fractions `[T, S, Y, X]`.
    pub fn decompress(&self, archive: &SzArchive) -> Result<Vec<f32>> {
        let (nt, ns, ny, nx) = archive.dims;
        if archive.fields.len() != ns {
            return Err(Error::format(format!(
                "SZ archive has {} fields for {ns} species",
                archive.fields.len()
            )));
        }
        let npix = ny * nx;
        let mut mass = vec![0.0f32; nt * ns * npix];
        let decoded = par_try_map(ns, self.threads(), |s| sz_decompress(&archive.fields[s]))?;
        for (s, field) in decoded.into_iter().enumerate() {
            if field.len() != nt * npix {
                return Err(Error::format(format!(
                    "SZ field {s} decoded to {} values, expected {}",
                    field.len(),
                    nt * npix
                )));
            }
            for t in 0..nt {
                let off = (t * ns + s) * npix;
                mass[off..off + npix].copy_from_slice(&field[t * npix..(t + 1) * npix]);
            }
        }
        Ok(mass)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate, Profile};
    use crate::metrics::nrmse_per_species;

    #[test]
    fn end_to_end_nrmse_near_target() {
        let ds = generate(Profile::Tiny, 21);
        let szc = SzCompressor::new(SzCompressOptions {
            mode: SzMode::Interp,
            ..Default::default()
        });
        let target = 1e-3;
        let archive = szc.compress(&ds, target).unwrap();
        let mass = szc.decompress(&archive).unwrap();
        // species-major view: [T,S,Y,X] -> per-species check via nrmse on
        // species_field ordering; reuse dataset gather
        let mut ds2 = ds.clone();
        ds2.mass = mass;
        let mut per = Vec::new();
        for s in 0..ds.ns {
            let a = ds.species_field(s);
            let b = ds2.species_field(s);
            per.push(crate::metrics::nrmse(&a.data, &b.data));
        }
        let mean = per.iter().sum::<f64>() / per.len() as f64;
        assert!(mean <= target * 1.2, "mean NRMSE {mean} vs target {target}");
        assert!(mean >= target * 0.05, "suspiciously low {mean}");
        let _ = nrmse_per_species; // silence unused import in some cfgs
    }

    #[test]
    fn serialize_roundtrip() {
        let ds = generate(Profile::Tiny, 22);
        let szc = SzCompressor::new(SzCompressOptions::default());
        let archive = szc.compress(&ds, 1e-2).unwrap();
        let bytes = archive.serialize();
        let back = SzArchive::deserialize(&bytes).unwrap();
        assert_eq!(back.dims, archive.dims);
        assert_eq!(back.fields.len(), archive.fields.len());
        let m1 = szc.decompress(&archive).unwrap();
        let m2 = szc.decompress(&back).unwrap();
        assert_eq!(m1, m2);
    }

    #[test]
    fn partial_decode_override_matches_default_slicing() {
        use crate::compressor::traits::Compressor;
        let ds = generate(Profile::Tiny, 24);
        let szc = SzCompressor::new(SzCompressOptions::default());
        let bytes = szc.compress_bytes(&ds, 1e-2).unwrap();
        // the species-granular override...
        let fast = szc.decompress_range(&bytes, 2, 5, &[1, 4]).unwrap();
        // ...must agree bit-for-bit with slicing a full decode
        let full = szc.decompress_mass(&bytes).unwrap();
        let npix = ds.ny * ds.nx;
        let mut manual = Vec::new();
        for t in 2..5usize {
            for &s in &[1usize, 4] {
                let off = (t * ds.ns + s) * npix;
                manual.extend_from_slice(&full[off..off + npix]);
            }
        }
        assert_eq!(fast.len(), manual.len());
        for (a, b) in fast.iter().zip(&manual) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // out-of-range queries are clean errors
        assert!(szc.decompress_range(&bytes, 3, 3, &[]).is_err());
        assert!(szc.decompress_range(&bytes, 0, ds.nt + 1, &[]).is_err());
    }

    #[test]
    fn compresses_well_below_raw() {
        let ds = generate(Profile::Tiny, 23);
        let szc = SzCompressor::new(SzCompressOptions::default());
        let archive = szc.compress(&ds, 1e-2).unwrap();
        let cr = ds.pd_bytes() as f64 / archive.total_bytes() as f64;
        assert!(cr > 10.0, "SZ CR only {cr:.1}");
    }
}
