//! Common compressor interface used by the benches and the CLI.

use crate::data::Dataset;
use crate::error::{Error, Result};

/// Normalize a species selection: empty = all `ns`, otherwise ascending
/// deduplicated indices, rejected if any is out of range.
///
/// A zero-species archive is rejected outright — *every* selection
/// (including "all") would otherwise resolve to an empty set and the
/// caller would hand back an empty-but-"successful" buffer for a request
/// that can never be satisfied.
pub fn select_species(species: &[usize], ns: usize) -> Result<Vec<usize>> {
    if ns == 0 {
        return Err(Error::shape(
            "species selection on a zero-species archive",
        ));
    }
    if species.is_empty() {
        return Ok((0..ns).collect());
    }
    let mut v = species.to_vec();
    v.sort_unstable();
    v.dedup();
    if let Some(&bad) = v.iter().find(|&&s| s >= ns) {
        return Err(Error::shape(format!(
            "species index {bad} out of range (archive has {ns})"
        )));
    }
    Ok(v)
}

/// An error-bounded dataset compressor.
pub trait Compressor {
    /// Name for reports ("GBATC", "GBA", "SZ-interp", ...).
    fn name(&self) -> &str;

    /// Compress to opaque bytes; `nrmse_target` is the paper's per-species
    /// NRMSE accuracy knob.
    fn compress_bytes(&self, ds: &Dataset, nrmse_target: f64) -> Result<Vec<u8>>;

    /// Reconstruct mass fractions `[T, S, Y, X]` from compressed bytes.
    fn decompress_mass(&self, bytes: &[u8]) -> Result<Vec<f32>>;

    /// `[T, S, Y, X]` dims recorded in a serialized archive (header parse).
    fn archive_dims(&self, bytes: &[u8]) -> Result<(usize, usize, usize, usize)>;

    /// Bytes charged beyond the payload (e.g. model parameters).
    fn extra_bytes(&self) -> usize {
        0
    }

    /// Partial decode: timesteps `[t0, t1)` of the given species indices
    /// (all species if empty), as row-major `[t1-t0, n_species, Y, X]`
    /// with species in ascending index order.
    ///
    /// The default decodes everything and slices, so its peak memory is
    /// the full `[T, S, Y, X]` field *plus* the output window even for a
    /// 1-timestep request — formats whose payload is only decodable end
    /// to end pay that cost here.  Format-aware implementations override
    /// it with what their container allows: the `GBA2` TOC decodes only
    /// the touched shards/sections (memory bounded by one shard), and the
    /// SZ archive decodes species-by-species (memory bounded by one
    /// species' `[T, Y, X]` trajectory, since its predictors cannot skip
    /// timesteps).
    fn decompress_range(
        &self,
        bytes: &[u8],
        t0: usize,
        t1: usize,
        species: &[usize],
    ) -> Result<Vec<f32>> {
        let (nt, ns, ny, nx) = self.archive_dims(bytes)?;
        if t0 >= t1 || t1 > nt {
            return Err(Error::shape(format!(
                "time range [{t0}, {t1}) out of bounds for nt {nt}"
            )));
        }
        let sel = select_species(species, ns)?;
        let full = self.decompress_mass(bytes)?;
        let npix = ny * nx;
        let nsel = sel.len();
        let mut out = vec![0.0f32; (t1 - t0) * nsel * npix];
        for t in t0..t1 {
            for (k, &s) in sel.iter().enumerate() {
                let src = (t * ns + s) * npix;
                let dst = ((t - t0) * nsel + k) * npix;
                out[dst..dst + npix].copy_from_slice(&full[src..src + npix]);
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn select_species_normalizes_and_validates() {
        assert_eq!(select_species(&[], 3).unwrap(), vec![0, 1, 2]);
        assert_eq!(select_species(&[2, 0, 2], 3).unwrap(), vec![0, 2]);
        assert!(matches!(
            select_species(&[3], 3),
            Err(Error::Shape(_))
        ));
    }

    /// Regression: a zero-species archive must be a typed shape error for
    /// *any* selection — not an empty Vec that flows into an
    /// empty-but-"successful" `decompress_range` buffer.
    #[test]
    fn zero_species_archive_is_a_typed_error() {
        assert!(matches!(select_species(&[], 0), Err(Error::Shape(_))));
        assert!(matches!(select_species(&[0], 0), Err(Error::Shape(_))));

        /// Minimal compressor whose archive claims zero species, driving
        /// the trait's *default* `decompress_range` implementation.
        struct ZeroSpecies;
        impl Compressor for ZeroSpecies {
            fn name(&self) -> &str {
                "zero"
            }
            fn compress_bytes(&self, _ds: &Dataset, _t: f64) -> Result<Vec<u8>> {
                Ok(Vec::new())
            }
            fn decompress_mass(&self, _bytes: &[u8]) -> Result<Vec<f32>> {
                Ok(Vec::new())
            }
            fn archive_dims(&self, _bytes: &[u8]) -> Result<(usize, usize, usize, usize)> {
                Ok((4, 0, 8, 8))
            }
        }
        let err = ZeroSpecies.decompress_range(&[], 0, 2, &[]);
        assert!(matches!(err, Err(Error::Shape(_))), "{err:?}");
        let err = ZeroSpecies.decompress_range(&[], 0, 2, &[1]);
        assert!(matches!(err, Err(Error::Shape(_))), "{err:?}");
    }
}
