//! Common compressor interface used by the benches and the CLI.

use crate::data::Dataset;
use crate::error::Result;

/// An error-bounded dataset compressor.
pub trait Compressor {
    /// Name for reports ("GBATC", "GBA", "SZ-interp", ...).
    fn name(&self) -> &str;

    /// Compress to opaque bytes; `nrmse_target` is the paper's per-species
    /// NRMSE accuracy knob.
    fn compress_bytes(&self, ds: &Dataset, nrmse_target: f64) -> Result<Vec<u8>>;

    /// Reconstruct mass fractions `[T, S, Y, X]` from compressed bytes.
    fn decompress_mass(&self, bytes: &[u8]) -> Result<Vec<f32>>;

    /// Bytes charged beyond the payload (e.g. model parameters).
    fn extra_bytes(&self) -> usize {
        0
    }
}
