//! The GBA / GBATC compressor — the paper's system, end to end:
//! normalize → block → AE encode (PJRT) → quantize+Huffman latents →
//! AE decode (+ TCN) → per-species PCA guarantee (Algorithm 1) → archive.

use std::sync::Mutex;

use crate::archive::{Archive, SpeciesSection};
use crate::codec::{CoeffCodec, LatentCodec};
use crate::compressor::accounting::{model_param_bytes, SizeBreakdown};
use crate::coordinator::scheduler::par_for;
use crate::coordinator::{Pipeline, Progress};
use crate::data::blocks::{BlockGrid, BlockShape};
use crate::data::Dataset;
use crate::error::{Error, Result};
use crate::gae::guarantee::{apply_correction, guarantee_species, GuaranteeParams};
use crate::runtime::ExecHandle;

/// Knobs of a GBA/GBATC compression run.
#[derive(Clone, Debug)]
pub struct CompressOptions {
    /// Per-species NRMSE accuracy target (the paper's 1e-3 knob); converted
    /// to the per-block ℓ2 bound τ = target · √D in normalized units.
    pub nrmse_target: f64,
    /// Latent quantization bin width (paper §II-A).
    pub latent_bin: f64,
    /// Apply the tensor-correction network (GBATC) or not (GBA).
    pub use_tcn: bool,
    /// Worker threads for CPU stages (0 = all cores).
    pub threads: usize,
    /// Store full D x D bases (ablation) instead of truncating.
    pub store_full_basis: bool,
    /// Charge model parameters at f32 instead of 8-bit (ablation).
    pub model_bytes_f32: bool,
    /// Batches in flight in the pipelines.
    pub queue_depth: usize,
}

impl Default for CompressOptions {
    fn default() -> Self {
        Self {
            nrmse_target: 1e-3,
            latent_bin: 0.02,
            use_tcn: true,
            threads: 0,
            store_full_basis: false,
            model_bytes_f32: false,
            queue_depth: 4,
        }
    }
}

/// Outcome of a compression run.
#[derive(Debug)]
pub struct CompressReport {
    pub archive: Archive,
    pub breakdown: SizeBreakdown,
    /// Max per-block ℓ2 residual (normalized) observed — must be <= tau.
    pub max_block_residual: f64,
    pub tau: f64,
    pub n_coeffs: usize,
    pub elapsed_s: f64,
    pub progress_summary: String,
}

/// The compressor; borrows an executor-service handle.
pub struct GbatcCompressor<'a> {
    handle: &'a ExecHandle,
    /// Decoder+TCN parameter counts from the manifest (CR accounting).
    pub decoder_params: usize,
    pub tcn_params: usize,
}

impl<'a> GbatcCompressor<'a> {
    pub fn new(handle: &'a ExecHandle, decoder_params: usize, tcn_params: usize) -> Self {
        Self {
            handle,
            decoder_params,
            tcn_params,
        }
    }

    fn threads(opts: &CompressOptions) -> usize {
        if opts.threads > 0 {
            opts.threads
        } else {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
        }
    }

    /// Compress a dataset.
    pub fn compress(&self, ds: &Dataset, opts: &CompressOptions) -> Result<CompressReport> {
        let progress = Progress::new();
        let spec = self.handle.spec();
        if ds.ns != spec.species {
            return Err(Error::shape(format!(
                "dataset has {} species, model expects {}",
                ds.ns, spec.species
            )));
        }
        let shape = BlockShape {
            kt: spec.block.0,
            by: spec.block.1,
            bx: spec.block.2,
        };
        let grid = BlockGrid::for_dataset(ds, shape)?;
        let n_blocks = grid.n_blocks();
        let d = shape.d();
        let threads = Self::threads(opts);

        // 1. normalize (per species, parallel over species)
        let ranges = ds.species_ranges();
        let norm = normalize_mass(ds, &ranges, threads);

        // 2. AE encode -> latents
        let pipeline = Pipeline {
            queue_depth: opts.queue_depth,
        };
        let latents = pipeline.encode_all(&grid, &norm, self.handle, &progress)?;

        // 3. latent quantization + Huffman
        let (latent_blob, latents_deq) =
            LatentCodec::encode(&latents, n_blocks, spec.latent, opts.latent_bin)?;

        // 4. decode (+ TCN) from the *dequantized* latents — exactly what
        // the decompressor will see
        let recon_norm =
            pipeline.decode_all(&grid, &latents_deq, self.handle, opts.use_tcn, &progress)?;

        // 5. per-species guarantee (Algorithm 1), parallel over species.
        // Certify against a 0.1%-conservative tau so that the f32
        // denormalize/renormalize round trip on the decompressor side
        // (worst for species with offset >> range, e.g. N2) cannot push a
        // block past the user's bound.
        let tau = opts.nrmse_target * (d as f64).sqrt();
        let tau_cert = tau * 0.999;
        let params = GuaranteeParams {
            tau: tau_cert,
            coeff_bin: tau_cert / (d as f64).sqrt(),
            store_full_basis: opts.store_full_basis,
        };
        let sections: Vec<Mutex<Option<(SpeciesSection, f64, usize)>>> =
            (0..ds.ns).map(|_| Mutex::new(None)).collect();
        let err: Mutex<Option<Error>> = Mutex::new(None);
        par_for(ds.ns, threads, |s| {
            let t = std::time::Instant::now();
            let mut orig_s = vec![0.0f32; n_blocks * d];
            let mut recon_s = vec![0.0f32; n_blocks * d];
            for b in 0..n_blocks {
                grid.gather_species(&norm, b, s, &mut orig_s[b * d..(b + 1) * d]);
                grid.gather_species(&recon_norm, b, s, &mut recon_s[b * d..(b + 1) * d]);
            }
            let res = guarantee_species(&orig_s, &recon_s, n_blocks, d, &params);
            match CoeffCodec::encode(&res.per_block, d, effective_bin(&params, d)) {
                Ok(coeffs) => {
                    *sections[s].lock().unwrap() = Some((
                        SpeciesSection {
                            basis: res.basis,
                            coeffs,
                        },
                        res.max_residual,
                        res.n_coeffs,
                    ));
                }
                Err(e) => {
                    *err.lock().unwrap() = Some(e);
                }
            }
            progress.add(&progress.species_guaranteed, 1);
            progress.add(&progress.cpu_ns, t.elapsed().as_nanos() as u64);
        });
        if let Some(e) = err.into_inner().unwrap() {
            return Err(e);
        }

        let mut species = Vec::with_capacity(ds.ns);
        let mut max_block_residual = 0.0f64;
        let mut n_coeffs = 0usize;
        let mut bases_bytes = 0usize;
        let mut coeff_bytes = 0usize;
        for slot in sections {
            let (sec, maxr, nc) = slot.into_inner().unwrap().expect("species missing");
            max_block_residual = max_block_residual.max(maxr);
            n_coeffs += nc;
            bases_bytes += sec.basis.payload_bytes();
            coeff_bytes += sec.coeffs.len();
            species.push(sec);
        }

        let model_params = self.decoder_params + if opts.use_tcn { self.tcn_params } else { 0 };
        let model_bytes = model_param_bytes(model_params, opts.model_bytes_f32);
        let archive = Archive {
            tcn_used: opts.use_tcn,
            dims: (ds.nt, ds.ns, ds.ny, ds.nx),
            block: (shape.kt, shape.by, shape.bx),
            latent_dim: spec.latent,
            pressure: ds.pressure,
            ranges,
            latent_blob,
            species,
            model_param_bytes: model_bytes as u64,
            nrmse_target: opts.nrmse_target,
        };
        let payload = archive.payload_bytes();
        let breakdown = SizeBreakdown {
            latents: archive.latent_blob.len(),
            bases: bases_bytes,
            coeffs: coeff_bytes,
            header: payload
                .saturating_sub(archive.latent_blob.len() + bases_bytes + coeff_bytes),
            model_params: model_bytes,
        };
        Ok(CompressReport {
            archive,
            breakdown,
            max_block_residual,
            tau,
            n_coeffs,
            elapsed_s: progress.elapsed_s(),
            progress_summary: progress.summary(),
        })
    }

    /// Decompress an archive back to mass fractions `[T, S, Y, X]`.
    pub fn decompress(&self, archive: &Archive, threads: usize) -> Result<Vec<f32>> {
        let progress = Progress::new();
        let spec = self.handle.spec();
        let (nt, ns, ny, nx) = archive.dims;
        let shape = BlockShape {
            kt: archive.block.0,
            by: archive.block.1,
            bx: archive.block.2,
        };
        let grid = BlockGrid::new((nt, ns, ny, nx), shape)?;
        let n_blocks = grid.n_blocks();
        let d = shape.d();

        // 1. latents
        let plane = LatentCodec::decode(&archive.latent_blob)?;
        if plane.n != n_blocks || plane.dim != spec.latent {
            return Err(Error::format(format!(
                "latent plane {}x{} vs expected {}x{}",
                plane.n, plane.dim, n_blocks, spec.latent
            )));
        }

        // 2. decode + optional TCN
        let pipeline = Pipeline { queue_depth: 4 };
        let mut norm =
            pipeline.decode_all(&grid, &plane.values, self.handle, archive.tcn_used, &progress)?;

        // 3. apply per-species corrections (parallel over species — writes
        // are species-disjoint, done via raw pointer wrapper)
        let threads = if threads > 0 {
            threads
        } else {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
        };
        let norm_cell = SpeciesDisjoint(std::cell::UnsafeCell::new(norm.as_mut_slice()));
        let err: Mutex<Option<Error>> = Mutex::new(None);
        par_for(ns, threads, |s| {
            let run = || -> Result<()> {
                let coeffs = CoeffCodec::decode(&archive.species[s].coeffs)?;
                let basis = &archive.species[s].basis;
                let mass: &mut [f32] = unsafe { norm_cell.slice() };
                let mut block_vec = vec![0.0f32; d];
                for (b, per_block) in coeffs.per_block.iter().enumerate() {
                    if per_block.is_empty() {
                        continue;
                    }
                    grid.gather_species(mass, b, s, &mut block_vec);
                    apply_correction(&mut block_vec, 1, d, basis, std::slice::from_ref(per_block));
                    grid.scatter_species(mass, b, s, &block_vec);
                }
                Ok(())
            };
            if let Err(e) = run() {
                *err.lock().unwrap() = Some(e);
            }
        });
        if let Some(e) = err.into_inner().unwrap() {
            return Err(e);
        }

        // 4. denormalize
        denormalize_in_place(&mut norm, &archive.ranges, nt, ns, ny * nx, threads);
        Ok(norm)
    }
}

/// Wrapper asserting that concurrent accesses touch disjoint species slices.
struct SpeciesDisjoint<'a>(std::cell::UnsafeCell<&'a mut [f32]>);
unsafe impl<'a> Sync for SpeciesDisjoint<'a> {}

impl<'a> SpeciesDisjoint<'a> {
    /// SAFETY: callers must only touch indices belonging to "their" species
    /// (the `[T,S,Y,X]` layout makes per-species index sets disjoint).
    #[allow(clippy::mut_from_ref)]
    unsafe fn slice(&self) -> &mut [f32] {
        &mut *self.0.get()
    }
}

fn effective_bin(params: &GuaranteeParams, d: usize) -> f64 {
    params.coeff_bin.min(1.9 * params.tau / (d as f64).sqrt())
}

/// Normalize `[T,S,Y,X]` mass to per-species [0, 1] (parallel over species).
pub fn normalize_mass(ds: &Dataset, ranges: &[(f32, f32)], threads: usize) -> Vec<f32> {
    let npix = ds.ny * ds.nx;
    let mut norm = vec![0.0f32; ds.mass.len()];
    let cell = SpeciesDisjoint(std::cell::UnsafeCell::new(norm.as_mut_slice()));
    par_for(ds.ns, threads, |s| {
        let (lo, hi) = ranges[s];
        let inv = 1.0 / (hi - lo).max(1e-30);
        let out: &mut [f32] = unsafe { cell.slice() };
        for t in 0..ds.nt {
            let off = (t * ds.ns + s) * npix;
            for i in off..off + npix {
                out[i] = (ds.mass[i] - lo) * inv;
            }
        }
    });
    norm
}

/// In-place denormalization (inverse of [`normalize_mass`]).
pub fn denormalize_in_place(
    norm: &mut [f32],
    ranges: &[(f32, f32)],
    nt: usize,
    ns: usize,
    npix: usize,
    threads: usize,
) {
    let cell = SpeciesDisjoint(std::cell::UnsafeCell::new(norm));
    par_for(ns, threads, |s| {
        let (lo, hi) = ranges[s];
        let range = (hi - lo).max(1e-30);
        let out: &mut [f32] = unsafe { cell.slice() };
        for t in 0..nt {
            let off = (t * ns + s) * npix;
            for v in &mut out[off..off + npix] {
                *v = *v * range + lo;
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate, Profile};

    #[test]
    fn normalize_roundtrip() {
        let ds = generate(Profile::Tiny, 3);
        let ranges = ds.species_ranges();
        let mut norm = normalize_mass(&ds, &ranges, 4);
        assert!(norm.iter().all(|&v| (-1e-3..=1.0 + 1e-3).contains(&v)));
        denormalize_in_place(&mut norm, &ranges, ds.nt, ds.ns, ds.ny * ds.nx, 4);
        for (a, b) in norm.iter().zip(&ds.mass) {
            assert!((a - b).abs() <= 1e-6 * b.abs().max(1e-12) + 1e-9);
        }
    }
}
