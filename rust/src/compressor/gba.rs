//! The GBA / GBATC compressor facade — the paper's system, end to end:
//! normalize → block → AE encode → quantize+Huffman latents → AE decode
//! (+ TCN) → per-species PCA guarantee (Algorithm 1) → indexed `GBA2`
//! archive.
//!
//! Since the shard refactor the orchestration lives in
//! [`crate::coordinator::engine::ShardEngine`]; this module keeps the
//! public compressor type, its options/report, the normalization
//! primitives shared with the engine, and the [`Compressor`] trait
//! implementation that unifies GBA/GBATC with the SZ baseline.

use crate::archive::{AnyArchive, Gba2Archive, SectionSource, SliceSource, MAGIC2};
use crate::compressor::accounting::{model_param_bytes, SizeBreakdown};
use crate::compressor::registry::CodecChoice;
use crate::compressor::traits::Compressor;
use crate::coordinator::engine::{RangeDecode, ShardEngine};
use crate::coordinator::progress::StageTimes;
use crate::coordinator::scheduler::par_for;
use crate::data::Dataset;
use crate::error::{Error, Result};
use crate::gae::guarantee::GuaranteeParams;
use crate::runtime::ExecHandle;

/// Knobs of a GBA/GBATC compression run.
#[derive(Clone, Debug)]
pub struct CompressOptions {
    /// Per-species NRMSE accuracy target (the paper's 1e-3 knob); converted
    /// to the per-block ℓ2 bound τ = target · √D in normalized units.
    pub nrmse_target: f64,
    /// Latent quantization bin width (paper §II-A).
    pub latent_bin: f64,
    /// Apply the tensor-correction network (GBATC) or not (GBA).
    pub use_tcn: bool,
    /// Worker threads for CPU stages (0 = all cores).
    pub threads: usize,
    /// Store full D x D bases (ablation) instead of truncating.
    pub store_full_basis: bool,
    /// Charge model parameters at f32 instead of 8-bit (ablation).
    pub model_bytes_f32: bool,
    /// Batches in flight in the pipelines.
    pub queue_depth: usize,
    /// Shard time-window width in timesteps (0 = auto, `4 * block_kt`;
    /// `>= nt` for a single shard).  Must be a multiple of the block kt.
    pub kt_window: usize,
    /// Shards processed concurrently; peak working memory scales with
    /// `shard_workers * shard size`.
    pub shard_workers: usize,
    /// Codec policy: classic all-GBATC (default), a single self-contained
    /// stage, or the per-(shard, species) rate–distortion planner.
    pub codec: CodecChoice,
}

impl Default for CompressOptions {
    fn default() -> Self {
        Self {
            nrmse_target: 1e-3,
            latent_bin: 0.02,
            use_tcn: true,
            threads: 0,
            store_full_basis: false,
            model_bytes_f32: false,
            queue_depth: 4,
            kt_window: 0,
            shard_workers: 2,
            codec: CodecChoice::Gbatc,
        }
    }
}

impl CompressOptions {
    /// Up-front validation of the user-facing knobs — typed config errors
    /// instead of downstream panics or silent clamping.  `block_kt` is the
    /// runtime's block time extent.
    pub fn validate(&self, block_kt: usize) -> Result<()> {
        if self.queue_depth == 0 {
            return Err(Error::config("queue_depth must be at least 1"));
        }
        if self.shard_workers == 0 {
            return Err(Error::config("shard_workers must be at least 1"));
        }
        if block_kt > 0 && self.kt_window != 0 && self.kt_window % block_kt != 0 {
            return Err(Error::config(format!(
                "kt_window {} is not a multiple of the block kt {block_kt}",
                self.kt_window
            )));
        }
        if self.nrmse_target.is_nan() || self.nrmse_target <= 0.0 {
            return Err(Error::config(format!(
                "nrmse_target {} must be positive",
                self.nrmse_target
            )));
        }
        if self.latent_bin.is_nan() || self.latent_bin <= 0.0 {
            return Err(Error::config(format!(
                "latent_bin {} must be positive",
                self.latent_bin
            )));
        }
        Ok(())
    }
}

/// Outcome of a compression run.
#[derive(Debug)]
pub struct CompressReport {
    pub archive: Gba2Archive,
    pub breakdown: SizeBreakdown,
    /// Max per-block ℓ2 residual (normalized) observed — must be <= tau.
    pub max_block_residual: f64,
    pub tau: f64,
    pub n_coeffs: usize,
    /// Time-window shards the field was processed as.
    pub n_shards: usize,
    /// High-water mark of the engine's shard working sets (bytes) — the
    /// memory the run needed beyond the input field itself.
    pub peak_workspace_bytes: usize,
    /// Per-stage wall-time attribution (PCA fit, guarantee loop, entropy
    /// encode, planner trials), summed across workers.
    pub stage_times: StageTimes,
    pub elapsed_s: f64,
    pub progress_summary: String,
}

/// The compressor; borrows an executor-service handle.
pub struct GbatcCompressor<'a> {
    handle: &'a ExecHandle,
    /// Decoder+TCN parameter counts from the manifest (CR accounting).
    pub decoder_params: usize,
    pub tcn_params: usize,
    /// Options used by the [`Compressor`] trait entry points (the
    /// explicit [`Self::compress`] takes options per call).
    pub opts: CompressOptions,
}

impl<'a> GbatcCompressor<'a> {
    pub fn new(handle: &'a ExecHandle, decoder_params: usize, tcn_params: usize) -> Self {
        Self {
            handle,
            decoder_params,
            tcn_params,
            opts: CompressOptions::default(),
        }
    }

    pub fn with_options(mut self, opts: CompressOptions) -> Self {
        self.opts = opts;
        self
    }

    /// The shard engine bound to this compressor's handle.
    pub fn engine(&self) -> ShardEngine<'a> {
        ShardEngine::new(self.handle, self.decoder_params, self.tcn_params)
    }

    /// Compress a dataset (shard-by-shard; see `CompressOptions::kt_window`).
    pub fn compress(&self, ds: &Dataset, opts: &CompressOptions) -> Result<CompressReport> {
        self.engine().compress(ds, opts)
    }

    /// [`Self::compress`] under a typed [`crate::api::ErrorPolicy`] —
    /// per-species budgets thread through the planner and guarantee
    /// stage, certified per (shard, species).
    pub fn compress_with_policy(
        &self,
        ds: &Dataset,
        opts: &CompressOptions,
        policy: &crate::api::ErrorPolicy,
    ) -> Result<CompressReport> {
        let targets = policy.resolve(ds.ns)?;
        self.engine().compress_with_budgets(ds, opts, &targets)
    }

    /// Decompress an archive back to mass fractions `[T, S, Y, X]`.
    pub fn decompress(&self, archive: &Gba2Archive, threads: usize) -> Result<Vec<f32>> {
        self.engine().decompress_all(archive, threads)
    }

    /// Partial decode straight from a byte-range source (file, slice, or
    /// counting wrapper) — see [`ShardEngine::decompress_range`].
    pub fn extract<S: SectionSource + ?Sized>(
        &self,
        src: &S,
        t0: usize,
        t1: usize,
        species: &[usize],
        threads: usize,
    ) -> Result<RangeDecode> {
        self.engine().decompress_range(src, t0, t1, species, threads)
    }
}

impl Compressor for GbatcCompressor<'_> {
    fn name(&self) -> &str {
        if self.opts.use_tcn {
            "GBATC"
        } else {
            "GBA"
        }
    }

    fn compress_bytes(&self, ds: &Dataset, nrmse_target: f64) -> Result<Vec<u8>> {
        // thin adapter over the api facade: one-shot compression is a
        // push session fed from the in-memory dataset into a Cursor sink
        // (byte-identical to the engine's parallel one-shot pass)
        let opts = CompressOptions {
            nrmse_target,
            ..self.opts.clone()
        };
        let mut session = crate::api::CompressorBuilder::from_options(&opts).session_on(
            self.handle,
            self.decoder_params,
            self.tcn_params,
            crate::api::FieldSpec::from_dataset(ds),
            std::io::Cursor::new(Vec::new()),
        )?;
        session.push_dataset(ds)?;
        let (_report, sink) = session.finish_into()?;
        Ok(sink.into_inner())
    }

    fn decompress_mass(&self, bytes: &[u8]) -> Result<Vec<f32>> {
        let archive = AnyArchive::deserialize(bytes)?.into_v2()?;
        self.engine().decompress_all(&archive, self.opts.threads)
    }

    fn archive_dims(&self, bytes: &[u8]) -> Result<(usize, usize, usize, usize)> {
        if bytes.starts_with(MAGIC2) {
            // header + TOC only — no full-archive copy
            let (header, _toc) = Gba2Archive::read_toc(&SliceSource(bytes))?;
            return Ok(header.dims);
        }
        Ok(AnyArchive::deserialize(bytes)?.dims())
    }

    fn decompress_range(
        &self,
        bytes: &[u8],
        t0: usize,
        t1: usize,
        species: &[usize],
    ) -> Result<Vec<f32>> {
        if bytes.starts_with(MAGIC2) {
            // GBA2 bytes are already section-addressable: skip the
            // full-archive deserialize and read only the touched sections
            let src = SliceSource(bytes);
            return Ok(self
                .engine()
                .decompress_range(&src, t0, t1, species, self.opts.threads)?
                .mass);
        }
        let archive = AnyArchive::deserialize(bytes)?.into_v2()?;
        let src = SliceSource(&archive.bytes);
        Ok(self
            .engine()
            .decompress_range(&src, t0, t1, species, self.opts.threads)?
            .mass)
    }

    fn extra_bytes(&self) -> usize {
        let params = self.decoder_params + if self.opts.use_tcn { self.tcn_params } else { 0 };
        model_param_bytes(params, self.opts.model_bytes_f32)
    }
}

/// Wrapper asserting that concurrent accesses touch disjoint species slices.
pub(crate) struct SpeciesDisjoint<'a>(std::cell::UnsafeCell<&'a mut [f32]>);
// SAFETY: sharing is sound because every user writes only the index set
// of "its" species and the `[T,S,Y,X]` layout makes those sets disjoint
// — see the contract on `slice()`.
unsafe impl<'a> Sync for SpeciesDisjoint<'a> {}

impl<'a> SpeciesDisjoint<'a> {
    pub(crate) fn new(slice: &'a mut [f32]) -> Self {
        Self(std::cell::UnsafeCell::new(slice))
    }

    /// SAFETY: callers must only touch indices belonging to "their" species
    /// (the `[T,S,Y,X]` layout makes per-species index sets disjoint).
    #[allow(clippy::mut_from_ref)]
    pub(crate) unsafe fn slice(&self) -> &mut [f32] {
        // SAFETY: the pointer is derived from a live `&mut [f32]` held
        // by the cell; disjointness of concurrent users is the caller's
        // obligation, stated above.
        unsafe { &mut *self.0.get() }
    }
}

pub(crate) fn effective_bin(params: &GuaranteeParams, d: usize) -> f64 {
    params.coeff_bin.min(1.9 * params.tau / (d as f64).sqrt())
}

/// Normalize a `[nt, S, Y, X]` window of mass data to per-species [0, 1]
/// using the *global* per-species ranges (parallel over species).
pub fn normalize_window(
    mass: &[f32],
    ranges: &[(f32, f32)],
    nt: usize,
    ns: usize,
    npix: usize,
    threads: usize,
) -> Vec<f32> {
    debug_assert_eq!(mass.len(), nt * ns * npix);
    let mut norm = vec![0.0f32; mass.len()];
    let cell = SpeciesDisjoint::new(norm.as_mut_slice());
    par_for(ns, threads, |s| {
        let (lo, hi) = ranges[s];
        let inv = 1.0 / (hi - lo).max(1e-30);
        // SAFETY: this task writes only species `s`'s indices; par_for
        // runs one task per species, so the write sets are disjoint.
        let out: &mut [f32] = unsafe { cell.slice() };
        for t in 0..nt {
            let off = (t * ns + s) * npix;
            for i in off..off + npix {
                out[i] = (mass[i] - lo) * inv;
            }
        }
    });
    norm
}

/// Normalize a whole dataset (see [`normalize_window`]).
pub fn normalize_mass(ds: &Dataset, ranges: &[(f32, f32)], threads: usize) -> Vec<f32> {
    normalize_window(&ds.mass, ranges, ds.nt, ds.ns, ds.ny * ds.nx, threads)
}

/// In-place denormalization (inverse of [`normalize_window`]).
pub fn denormalize_in_place(
    norm: &mut [f32],
    ranges: &[(f32, f32)],
    nt: usize,
    ns: usize,
    npix: usize,
    threads: usize,
) {
    let cell = SpeciesDisjoint::new(norm);
    par_for(ns, threads, |s| {
        let (lo, hi) = ranges[s];
        let range = (hi - lo).max(1e-30);
        // SAFETY: this task writes only species `s`'s indices; par_for
        // runs one task per species, so the write sets are disjoint.
        let out: &mut [f32] = unsafe { cell.slice() };
        for t in 0..nt {
            let off = (t * ns + s) * npix;
            for v in &mut out[off..off + npix] {
                *v = *v * range + lo;
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate, Profile};

    #[test]
    fn normalize_roundtrip() {
        let ds = generate(Profile::Tiny, 3);
        let ranges = ds.species_ranges();
        let mut norm = normalize_mass(&ds, &ranges, 4);
        assert!(norm.iter().all(|&v| (-1e-3..=1.0 + 1e-3).contains(&v)));
        denormalize_in_place(&mut norm, &ranges, ds.nt, ds.ns, ds.ny * ds.nx, 4);
        for (a, b) in norm.iter().zip(&ds.mass) {
            assert!((a - b).abs() <= 1e-6 * b.abs().max(1e-12) + 1e-9);
        }
    }

    /// The `SpeciesDisjoint` contract under real parallelism, sized for
    /// Miri: per-species writers must never alias, and the result must
    /// not depend on the thread count.
    #[test]
    fn species_disjoint_parallel_writes_are_exact_at_any_thread_count() {
        let (nt, ns, npix) = (2usize, 3usize, 4usize);
        let mass: Vec<f32> = (0..nt * ns * npix).map(|i| i as f32 * 0.25 - 1.0).collect();
        let ranges: Vec<(f32, f32)> = (0..ns).map(|s| (-1.0 - s as f32, 5.0 + s as f32)).collect();
        let want = normalize_window(&mass, &ranges, nt, ns, npix, 1);
        for threads in 2..=3 {
            let got = normalize_window(&mass, &ranges, nt, ns, npix, threads);
            assert_eq!(got, want, "threads {threads}");
            let mut back = got;
            denormalize_in_place(&mut back, &ranges, nt, ns, npix, threads);
            for (a, b) in back.iter().zip(&mass) {
                assert!((a - b).abs() <= 1e-5, "threads {threads}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn options_validated_up_front() {
        let ok = CompressOptions::default();
        assert!(ok.validate(4).is_ok());
        let bad = CompressOptions {
            queue_depth: 0,
            ..Default::default()
        };
        assert!(matches!(bad.validate(4), Err(crate::Error::Config(_))));
        let bad = CompressOptions {
            shard_workers: 0,
            ..Default::default()
        };
        assert!(matches!(bad.validate(4), Err(crate::Error::Config(_))));
        let bad = CompressOptions {
            kt_window: 6,
            ..Default::default()
        };
        assert!(matches!(bad.validate(4), Err(crate::Error::Config(_))));
        let bad = CompressOptions {
            nrmse_target: 0.0,
            ..Default::default()
        };
        assert!(matches!(bad.validate(4), Err(crate::Error::Config(_))));
        let bad = CompressOptions {
            latent_bin: -1.0,
            ..Default::default()
        };
        assert!(matches!(bad.validate(4), Err(crate::Error::Config(_))));
    }

    #[test]
    fn window_normalization_matches_full_slice() {
        let ds = generate(Profile::Tiny, 4);
        let ranges = ds.species_ranges();
        let full = normalize_mass(&ds, &ranges, 2);
        let stride = ds.ns * ds.ny * ds.nx;
        let window = normalize_window(
            &ds.mass[2 * stride..6 * stride],
            &ranges,
            4,
            ds.ns,
            ds.ny * ds.nx,
            2,
        );
        assert_eq!(&full[2 * stride..6 * stride], &window[..]);
    }
}
