//! Compressed-output accounting (paper §III: "the compressed output
//! comprises the encoded representation of the AE encoder, encoded
//! coefficients with their basis indicators, network parameters, and all
//! the dictionaries for entropy coding").
//!
//! Model parameters (decoder + TCN) are charged at 1 byte/parameter —
//! 8-bit post-training quantization is standard for deployment and is what
//! lets a single archive amortize the network the way the paper's 4.75 GB
//! dataset amortizes its float networks.  The toggle `model_bytes_f32`
//! charges full f32 instead (ablation).

/// Byte breakdown of one GBATC archive.
#[derive(Clone, Debug, Default)]
pub struct SizeBreakdown {
    pub latents: usize,
    pub bases: usize,
    pub coeffs: usize,
    /// Sections encoded by self-contained registry stages (SZ / dense)
    /// in mixed-codec archives.
    pub alt_sections: usize,
    pub header: usize,
    pub model_params: usize,
}

impl SizeBreakdown {
    pub fn payload(&self) -> usize {
        self.latents + self.bases + self.coeffs + self.alt_sections + self.header
    }

    pub fn total(&self) -> usize {
        self.payload() + self.model_params
    }

    pub fn ratio(&self, pd_bytes: usize) -> f64 {
        pd_bytes as f64 / self.total() as f64
    }
}

impl std::fmt::Display for SizeBreakdown {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "latents {} B | bases {} B | coeffs {} B | sz/dense {} B | header {} B | model {} B | total {} B",
            self.latents, self.bases, self.coeffs, self.alt_sections, self.header,
            self.model_params,
            self.total()
        )
    }
}

/// Bytes charged for model parameters.
pub fn model_param_bytes(param_count: usize, f32_storage: bool) -> usize {
    if f32_storage {
        param_count * 4
    } else {
        param_count // 8-bit quantized + negligible scale table
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals_add_up() {
        let b = SizeBreakdown {
            latents: 100,
            bases: 50,
            coeffs: 20,
            alt_sections: 10,
            header: 20,
            model_params: 200,
        };
        assert_eq!(b.payload(), 200);
        assert_eq!(b.total(), 400);
        assert!((b.ratio(4000) - 10.0).abs() < 1e-12);
    }

    #[test]
    fn model_bytes_modes() {
        assert_eq!(model_param_bytes(1000, false), 1000);
        assert_eq!(model_param_bytes(1000, true), 4000);
    }
}
