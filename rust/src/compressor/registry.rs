//! The codec-stage registry and the per-(shard, species) rate–distortion
//! planner primitives.
//!
//! A [`SectionCodec`] encodes/decodes one `[kt_window, 1, Y, X]` section —
//! a single species' normalized time-window plane — to tagged bytes under
//! a per-species NRMSE budget.  Three stages are registered:
//!
//! | tag | stage                | needs shard latent plane | trial cost |
//! |-----|----------------------|--------------------------|------------|
//! | 0   | [`GbatcShardCodec`]  | yes (shared per shard)   | shared-model trial: the AE encode + decode (+ TCN) runs once per shard; per species only the Algorithm-1 guarantee is re-run |
//! | 1   | [`SzSectionCodec`]   | no                       | encode-only trial: the predictor's working buffer *is* the decode, so the NRMSE measure pays no decode pass |
//! | 2   | [`DensePlaneCodec`]  | no                       | encode-only trial: quantize + bit-pack with the error measured in the same sweep |
//!
//! All stages operate in *normalized* units (per-species [0, 1] with the
//! global ranges), so the engine's shared denormalize step applies
//! uniformly and partial decode stays bit-identical to full decode.  SZ
//! and Dense certify their budget by *measuring* the trial decode and
//! tightening the error bound until the measured NRMSE fits (or giving
//! up); GBATC certifies by construction (per-block ℓ2 ≤ τ ⇒ section
//! NRMSE ≤ τ/√D).
//!
//! [`plan_shard`] is the planner's cost model: per shard, either pay the
//! shared latent blob once and let every species pick the cheaper of its
//! GBATC section and its best self-contained encoding, or drop the latent
//! plane entirely and use self-contained stages everywhere — whichever
//! total is smaller.  This is exact-optimal for the cost structure
//! (the latent blob is the only shared term) and therefore never worse
//! than all-GBATC or all-SZ on the same sections.

use crate::archive::{CodecTag, SpeciesSection};
use crate::codec::CoeffCodec;
use crate::compressor::gba::effective_bin;
use crate::data::blocks::BlockGrid;
use crate::error::{Error, Result};
use crate::gae::guarantee::{apply_correction, guarantee_species_timed, GuaranteeParams};
use crate::gae::SpeciesBasis;
use crate::sz::codec::{sz_compress_with_recon, sz_decompress, SzMode};
use crate::sz::SzField;
use crate::util::bytes::{ByteReader, ByteWriter};
use crate::util::{BitReader, BitWriter};

/// Compression-time codec policy (the CLI's `--codec` knob).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CodecChoice {
    /// Rate–distortion planner: trial the candidate stages per
    /// (shard, species) and keep the smallest certifying encoding.
    Auto,
    /// Classic all-GBATC archives (version-2 container, default).
    Gbatc,
    /// SZ stage for every section (no model, no latent planes).
    Sz,
    /// Dense-plane stage for every section (diagnostic baseline).
    Dense,
}

impl CodecChoice {
    pub fn parse(s: &str) -> Option<CodecChoice> {
        match s {
            "auto" => Some(CodecChoice::Auto),
            "gbatc" => Some(CodecChoice::Gbatc),
            "sz" => Some(CodecChoice::Sz),
            "dense" => Some(CodecChoice::Dense),
            _ => None,
        }
    }
}

/// One species' normalized `[nt, Y, X]` plane of a shard.
pub struct SectionView<'a> {
    /// Species index within the shard (stages holding shard context use
    /// it to reach their shared buffers).
    pub species: usize,
    pub nt: usize,
    pub ny: usize,
    pub nx: usize,
    /// Row-major `[nt, ny, nx]`, normalized units.
    pub norm: &'a [f32],
}

/// Outcome of one codec trial on one section.
pub struct SectionEncoding {
    pub tag: CodecTag,
    pub bytes: Vec<u8>,
    /// Certified NRMSE of the trial in normalized units (measured for
    /// self-contained stages, τ/√D-derived for GBATC).
    pub nrmse: f64,
}

/// One stage in the codec registry.
pub trait SectionCodec: Sync {
    fn tag(&self) -> CodecTag;
    fn name(&self) -> &'static str;

    /// Full encode trial under `budget` (normalized NRMSE).  Returns
    /// `Ok(None)` when this stage cannot certify the budget on this
    /// section (the planner then falls back to another stage).
    fn encode(&self, view: &SectionView<'_>, budget: f64) -> Result<Option<SectionEncoding>>;

    /// Decode into `out` (row-major `[nt, ny, nx]`, normalized units).
    /// Stages that refine a shared-model reconstruction (GBATC) read the
    /// prior plane already present in `out`; self-contained stages
    /// overwrite it.
    fn decode(&self, bytes: &[u8], nt: usize, ny: usize, nx: usize, out: &mut [f32]) -> Result<()>;
}

/// RMSE between two equal-length planes (normalized units, f64 accumulate).
pub fn plane_rmse(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    let se: f64 = a
        .iter()
        .zip(b)
        .map(|(&x, &y)| {
            let e = x as f64 - y as f64;
            e * e
        })
        .sum();
    (se / a.len() as f64).sqrt()
}

/// Copy one species' `[nt, Y, X]` plane out of a `[nt, S, Y, X]` buffer.
pub fn gather_plane(buf: &[f32], nt: usize, ns: usize, npix: usize, s: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; nt * npix];
    gather_plane_into(&mut out, buf, nt, ns, npix, s);
    out
}

/// [`gather_plane`] into a caller-owned buffer (`dst.len() == nt * npix`)
/// — the zero-copy fill path of the store cache's `Arc<[f32]>` planes.
pub fn gather_plane_into(
    dst: &mut [f32],
    buf: &[f32],
    nt: usize,
    ns: usize,
    npix: usize,
    s: usize,
) {
    debug_assert_eq!(buf.len(), nt * ns * npix);
    debug_assert_eq!(dst.len(), nt * npix);
    for t in 0..nt {
        let src = (t * ns + s) * npix;
        dst[t * npix..(t + 1) * npix].copy_from_slice(&buf[src..src + npix]);
    }
}

/// Scatter a `[nt, Y, X]` plane back into a `[nt, S, Y, X]` buffer.
pub fn scatter_plane(buf: &mut [f32], plane: &[f32], nt: usize, ns: usize, npix: usize, s: usize) {
    debug_assert_eq!(buf.len(), nt * ns * npix);
    debug_assert_eq!(plane.len(), nt * npix);
    for t in 0..nt {
        let dst = (t * ns + s) * npix;
        buf[dst..dst + npix].copy_from_slice(&plane[t * npix..(t + 1) * npix]);
    }
}

// ---------------------------------------------------------------------------
// SZ stage (tag 1)
// ---------------------------------------------------------------------------

/// SZ predictor pipeline on one normalized section plane.
///
/// Section bytes: `mode u8 (0 lorenzo / 1 interp) | eb f64 | payload blob`
/// (dims come from the TOC/header).
pub struct SzSectionCodec {
    pub mode: SzMode,
}

/// The registry's SZ stage (per-field auto predictor selection).
pub static SZ_STAGE: SzSectionCodec = SzSectionCodec { mode: SzMode::Auto };

impl SectionCodec for SzSectionCodec {
    fn tag(&self) -> CodecTag {
        CodecTag::Sz
    }

    fn name(&self) -> &'static str {
        "SZ"
    }

    fn encode(&self, view: &SectionView<'_>, budget: f64) -> Result<Option<SectionEncoding>> {
        if budget.is_nan() || budget <= 0.0 {
            return Ok(None);
        }
        let dims = (view.nt, view.ny, view.nx);
        // uniform quantization error in [-eb, eb] gives RMSE ≈ eb/√3 in
        // normalized units; certify by measuring the reconstruction the
        // compressor already tracked (bit-identical to a decode pass —
        // zero-recompute trial), tightening when the error budget
        // saturates
        let mut eb = (3f64.sqrt() * budget).max(1e-300);
        for _ in 0..4 {
            let (field, back) = sz_compress_with_recon(view.norm, dims, eb, self.mode)?;
            let nrmse = plane_rmse(view.norm, &back);
            if nrmse <= budget {
                let mode = match field.mode {
                    SzMode::Lorenzo => 0u8,
                    SzMode::Interp => 1u8,
                    SzMode::Auto => {
                        return Err(Error::codec("sz stage: Auto is not a stored mode"))
                    }
                };
                let mut w = ByteWriter::new();
                w.u8(mode);
                w.f64(field.eb);
                w.blob(&field.payload);
                return Ok(Some(SectionEncoding {
                    tag: CodecTag::Sz,
                    bytes: w.finish(),
                    nrmse,
                }));
            }
            eb *= 0.5;
        }
        Ok(None)
    }

    fn decode(&self, bytes: &[u8], nt: usize, ny: usize, nx: usize, out: &mut [f32]) -> Result<()> {
        let mut r = ByteReader::new(bytes);
        let mode = match r.u8()? {
            0 => SzMode::Lorenzo,
            1 => SzMode::Interp,
            m => return Err(Error::codec(format!("sz section: bad mode {m}"))),
        };
        let eb = r.f64()?;
        let payload = r.blob()?.to_vec();
        if r.remaining() != 0 {
            return Err(Error::codec(format!(
                "sz section: {} trailing bytes",
                r.remaining()
            )));
        }
        let field = SzField {
            mode,
            eb,
            dims: (nt, ny, nx),
            payload,
        };
        let vals = sz_decompress(&field)?;
        if vals.len() != out.len() {
            return Err(Error::codec(format!(
                "sz section decoded {} values, expected {}",
                vals.len(),
                out.len()
            )));
        }
        out.copy_from_slice(&vals);
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Dense-plane stage (tag 2)
// ---------------------------------------------------------------------------

/// Uniform scalar quantization of the whole plane, bit-packed at fixed
/// width — the cheap fallback for near-constant or noise-dominated
/// sections where prediction overhead loses.
///
/// Section bytes: `lo f32 | bin f64 | width u8 | packed blob`; a width of
/// 0 encodes a constant plane (just `lo`).
pub struct DensePlaneCodec;

/// The registry's dense-plane stage.
pub static DENSE_STAGE: DensePlaneCodec = DensePlaneCodec;

impl DensePlaneCodec {
    fn try_encode(norm: &[f32], lo: f32, bin: f64, maxq: u64) -> (Vec<u8>, f64) {
        let width = if maxq == 0 {
            0u32
        } else {
            64 - maxq.leading_zeros()
        };
        let mut bw = BitWriter::new();
        let mut se = 0.0f64;
        for &v in norm {
            let qf = ((v - lo) as f64 / bin).round();
            let q = if qf < 0.0 {
                0
            } else if qf > maxq as f64 {
                maxq
            } else {
                qf as u64
            };
            // the exact decode-side expression, so the measured error is
            // the stored error
            let rec = (lo as f64 + q as f64 * bin) as f32;
            let e = (v - rec) as f64;
            se += e * e;
            if width > 0 {
                bw.write(q, width);
            }
        }
        let rmse = (se / norm.len().max(1) as f64).sqrt();
        let mut w = ByteWriter::new();
        w.f32(lo);
        w.f64(bin);
        w.u8(width as u8);
        w.blob(&bw.finish());
        (w.finish(), rmse)
    }
}

impl SectionCodec for DensePlaneCodec {
    fn tag(&self) -> CodecTag {
        CodecTag::Dense
    }

    fn name(&self) -> &'static str {
        "DENSE"
    }

    fn encode(&self, view: &SectionView<'_>, budget: f64) -> Result<Option<SectionEncoding>> {
        if budget.is_nan() || budget <= 0.0 {
            return Ok(None);
        }
        let mut lo = f32::INFINITY;
        let mut hi = f32::NEG_INFINITY;
        for &v in view.norm {
            lo = lo.min(v);
            hi = hi.max(v);
        }
        if !lo.is_finite() || !hi.is_finite() {
            return Ok(None);
        }
        // |err| ≤ bin/2 = √3·budget in the worst case; the measured RMSE
        // is usually ≈ budget and certifies the bound exactly
        let mut bin = 2.0 * 3f64.sqrt() * budget;
        for _ in 0..6 {
            let range = (hi - lo) as f64;
            let maxqf = (range / bin).round();
            if !maxqf.is_finite() || maxqf >= (1u64 << 32) as f64 {
                return Ok(None);
            }
            let (bytes, nrmse) = Self::try_encode(view.norm, lo, bin, maxqf as u64);
            if nrmse <= budget {
                return Ok(Some(SectionEncoding {
                    tag: CodecTag::Dense,
                    bytes,
                    nrmse,
                }));
            }
            bin *= 0.5;
        }
        Ok(None)
    }

    fn decode(&self, bytes: &[u8], nt: usize, ny: usize, nx: usize, out: &mut [f32]) -> Result<()> {
        debug_assert_eq!(out.len(), nt * ny * nx);
        let mut r = ByteReader::new(bytes);
        let lo = r.f32()?;
        let bin = r.f64()?;
        let width = r.u8()? as u32;
        let packed = r.blob()?;
        if r.remaining() != 0 {
            return Err(Error::codec(format!(
                "dense section: {} trailing bytes",
                r.remaining()
            )));
        }
        if width == 0 {
            if !packed.is_empty() {
                return Err(Error::codec("dense section: payload on constant plane"));
            }
            out.fill(lo);
            return Ok(());
        }
        if width > 32 {
            return Err(Error::codec(format!("dense section: width {width} > 32")));
        }
        let expect = (out.len() * width as usize + 7) >> 3;
        if packed.len() != expect {
            return Err(Error::codec(format!(
                "dense section: {} packed bytes, expected {expect}",
                packed.len()
            )));
        }
        let mut br = BitReader::new(packed);
        for o in out.iter_mut() {
            let q = br
                .read(width)
                .ok_or_else(|| Error::codec("dense section: bit stream underrun"))?;
            *o = (lo as f64 + q as f64 * bin) as f32;
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// GBATC stage (tag 0)
// ---------------------------------------------------------------------------

/// Guarantee-pass statistics of one GBATC section (size-breakdown,
/// report accounting, and per-stage wall-time attribution).
pub struct GbatcSectionStats {
    pub max_residual: f64,
    pub n_coeffs: usize,
    pub bases_bytes: usize,
    pub coeff_bytes: usize,
    /// PCA covariance fit + eigendecomposition time.
    pub pca_fit_ns: u64,
    /// Projection + greedy coefficient loop time.
    pub guarantee_ns: u64,
    /// Coefficient entropy-encode time.
    pub entropy_ns: u64,
}

/// GBATC as a registry stage, bound to one shard's shared-model trial:
/// the normalized input and the AE (+ TCN) reconstruction.  Per species
/// it runs the Algorithm-1 guarantee and emits the same
/// [`SpeciesSection`] bytes `GBA1`/`GBA2` always stored (tag 0) — the
/// expensive model stages are shared across all species of the shard.
pub struct GbatcShardCodec<'a> {
    /// Full shard grid (`[nt, S, Y, X]` extents).
    pub grid: &'a BlockGrid,
    /// Normalized shard input, `[nt, S, Y, X]`.
    pub norm: &'a [f32],
    /// Shared-model reconstruction of the shard, `[nt, S, Y, X]`.
    pub recon: &'a [f32],
    /// Per-species guarantee parameters (`ErrorPolicy` budgets resolve
    /// to one τ per species; a uniform policy repeats the same value).
    pub params: &'a [GuaranteeParams],
    /// Thread budget for each species' PCA covariance fit (bit-identical
    /// for any value; see `Pca::fit_threads`).
    pub pca_threads: usize,
}

impl GbatcShardCodec<'_> {
    /// This species' guarantee parameters.
    fn species_params(&self, s: usize) -> Result<&GuaranteeParams> {
        self.params
            .get(s)
            .ok_or_else(|| Error::codec(format!("no guarantee params for species {s}")))
    }

    /// Run the guarantee for one species; returns the serialized section
    /// and its stats.
    pub fn encode_species(&self, s: usize) -> Result<(Vec<u8>, GbatcSectionStats)> {
        let params = *self.species_params(s)?;
        let grid = self.grid;
        let d = grid.shape.d();
        let nb = grid.n_blocks();
        let mut orig_s = vec![0.0f32; nb * d];
        let mut recon_s = vec![0.0f32; nb * d];
        for b in 0..nb {
            grid.gather_species(self.norm, b, s, &mut orig_s[b * d..(b + 1) * d]);
            grid.gather_species(self.recon, b, s, &mut recon_s[b * d..(b + 1) * d]);
        }
        let (res, times) = guarantee_species_timed(
            &orig_s,
            &recon_s,
            nb,
            d,
            &params,
            self.pca_threads.max(1),
        );
        let t_ent = std::time::Instant::now();
        let coeffs = CoeffCodec::encode(&res.per_block, d, effective_bin(&params, d))?;
        let stats = GbatcSectionStats {
            max_residual: res.max_residual,
            n_coeffs: res.n_coeffs,
            bases_bytes: res.basis.payload_bytes(),
            coeff_bytes: coeffs.len(),
            pca_fit_ns: times.pca_fit_ns,
            guarantee_ns: times.loop_ns,
            entropy_ns: t_ent.elapsed().as_nanos() as u64,
        };
        let sec = SpeciesSection {
            basis: res.basis,
            coeffs,
        };
        Ok((sec.to_bytes(), stats))
    }

    /// Apply one decoded section's corrections to a single-species plane
    /// (`prior` = the shared-model reconstruction of that plane).  The
    /// block order of a `[nt, 1, Y, X]` grid matches the per-species
    /// block order of the full shard grid, so this reproduces the
    /// engine's in-place correction exactly.
    pub fn correct_plane(
        shape: crate::data::blocks::BlockShape,
        bytes: &[u8],
        nt: usize,
        ny: usize,
        nx: usize,
        prior: &mut [f32],
    ) -> Result<()> {
        let grid = BlockGrid::new((nt, 1, ny, nx), shape)?;
        let nb = grid.n_blocks();
        let d = shape.d();
        let sec = SpeciesSection::from_bytes(bytes)?;
        let coeffs = CoeffCodec::decode(&sec.coeffs)?;
        if coeffs.per_block.len() != nb || (coeffs.d != d && !coeffs.per_block.is_empty()) {
            return Err(Error::codec(format!(
                "gbatc section: {} coefficient blocks of dim {} vs grid {nb} x {d}",
                coeffs.per_block.len(),
                coeffs.d
            )));
        }
        if coeffs
            .per_block
            .iter()
            .flatten()
            .any(|&(j, _)| j >= sec.basis.rank)
        {
            return Err(Error::codec(format!(
                "gbatc section: coefficient index beyond basis rank {}",
                sec.basis.rank
            )));
        }
        let mut v = vec![0.0f32; d];
        for (b, per_block) in coeffs.per_block.iter().enumerate() {
            if per_block.is_empty() {
                continue;
            }
            grid.gather_species(prior, b, 0, &mut v);
            apply_correction(&mut v, 1, d, &sec.basis, std::slice::from_ref(per_block));
            grid.scatter_species(prior, b, 0, &v);
        }
        Ok(())
    }

    /// Best-effort [`Self::correct_plane`] for degraded-mode serving:
    /// apply whatever correction prefix survives in a damaged section
    /// instead of failing.  Never errors — `prior` keeps the
    /// shared-model reconstruction for every block whose correction is
    /// unrecoverable (zero salvageable coefficients ⇒ a pure prior
    /// plane), and the returned [`SectionSalvage`] reports how much was
    /// applied so the serving tier can loosen its certified bound.
    pub fn correct_plane_salvage(
        shape: crate::data::blocks::BlockShape,
        bytes: &[u8],
        nt: usize,
        ny: usize,
        nx: usize,
        prior: &mut [f32],
    ) -> SectionSalvage {
        let none = SectionSalvage {
            salvaged_fraction: 0.0,
            max_correction: 0.0,
        };
        let Ok(grid) = BlockGrid::new((nt, 1, ny, nx), shape) else {
            return none;
        };
        let nb = grid.n_blocks();
        let d = shape.d();
        let Some((basis, coeff_bytes)) = parse_section_lenient(bytes) else {
            return none;
        };
        if basis.d != d {
            return none;
        }
        let Ok((coeffs, salvaged)) = CoeffCodec::decode_salvage(&coeff_bytes) else {
            return none;
        };
        if coeffs.per_block.len() != nb || (coeffs.d != d && !coeffs.per_block.is_empty()) {
            return none;
        }
        let mut v = vec![0.0f32; d];
        let mut applied = 0usize;
        let mut max_corr2 = 0.0f64;
        for (b, per_block) in coeffs.per_block.iter().take(salvaged).enumerate() {
            if per_block.iter().any(|&(j, _)| j >= basis.rank) {
                break; // index rot: stop at the last trustworthy block
            }
            applied += 1;
            if per_block.is_empty() {
                continue;
            }
            // correction ℓ2 from the coefficients alone — the basis
            // columns are orthonormal, so ‖Σ cⱼ·uⱼ‖₂ = ‖c‖₂
            let c2: f64 = per_block.iter().map(|&(_, c)| c * c).sum();
            max_corr2 = max_corr2.max(c2);
            grid.gather_species(prior, b, 0, &mut v);
            apply_correction(&mut v, 1, d, &basis, std::slice::from_ref(per_block));
            grid.scatter_species(prior, b, 0, &v);
        }
        SectionSalvage {
            salvaged_fraction: if nb == 0 {
                1.0
            } else {
                applied as f64 / nb as f64
            },
            max_correction: max_corr2.sqrt(),
        }
    }
}

/// Outcome of [`GbatcShardCodec::correct_plane_salvage`]: how much of a
/// damaged section's correction the degraded decode could apply.
#[derive(Clone, Copy, Debug)]
pub struct SectionSalvage {
    /// Fraction of the plane's blocks whose stored corrections were
    /// applied (1.0 = bit-identical to a healthy decode, 0.0 = pure
    /// shared-model prior).
    pub salvaged_fraction: f64,
    /// Largest applied correction ℓ2 norm, normalized units — feeds the
    /// loosened degraded-mode error bound.
    pub max_correction: f64,
}

/// Lenient [`SpeciesSection`] parse for salvage: recover the basis plus
/// as much of the coefficient payload as survives (a declared blob
/// length overrunning the buffer is clamped to the remaining bytes; a
/// missing length yields an empty payload).
fn parse_section_lenient(bytes: &[u8]) -> Option<(SpeciesBasis, Vec<u8>)> {
    let mut r = ByteReader::new(bytes);
    let basis = SpeciesBasis::deserialize(&mut r).ok()?;
    let coeffs = match r.u64() {
        Ok(len) => {
            let take = usize::try_from(len).unwrap_or(usize::MAX).min(r.remaining());
            r.bytes(take).ok()?.to_vec()
        }
        Err(_) => Vec::new(),
    };
    Some((basis, coeffs))
}

impl SectionCodec for GbatcShardCodec<'_> {
    fn tag(&self) -> CodecTag {
        CodecTag::Gbatc
    }

    fn name(&self) -> &'static str {
        "GBATC"
    }

    fn encode(&self, view: &SectionView<'_>, budget: f64) -> Result<Option<SectionEncoding>> {
        let tau = self.species_params(view.species)?.tau;
        let (bytes, stats) = self.encode_species(view.species)?;
        if stats.max_residual > tau + 1e-12 {
            // the guarantee loop could not reach τ (pathological input)
            return Ok(None);
        }
        // section NRMSE² = Σ‖r_b‖² / (nb·D) ≤ max_residual²/D, so this is
        // a certified bound — honor the caller's budget even when it is
        // tighter than the τ the guarantee params were built for
        let d = self.grid.shape.d() as f64;
        let nrmse = stats.max_residual / d.sqrt();
        if nrmse.is_nan() || nrmse > budget {
            return Ok(None);
        }
        Ok(Some(SectionEncoding {
            tag: CodecTag::Gbatc,
            bytes,
            nrmse,
        }))
    }

    fn decode(&self, bytes: &[u8], nt: usize, ny: usize, nx: usize, out: &mut [f32]) -> Result<()> {
        Self::correct_plane(self.grid.shape, bytes, nt, ny, nx, out)
    }
}

/// Look up the self-contained decode stage for a tag.  GBATC sections
/// decode through the shard engine (they need the shard's shared latent
/// plane), so tag 0 is rejected here.
pub fn decode_stage(tag: CodecTag) -> Result<&'static dyn SectionCodec> {
    match tag {
        CodecTag::Sz => Ok(&SZ_STAGE),
        CodecTag::Dense => Ok(&DENSE_STAGE),
        CodecTag::Gbatc => Err(Error::codec(
            "GBATC sections decode through the shard engine (shared latent plane)",
        )),
    }
}

// ---------------------------------------------------------------------------
// Rate–distortion planner
// ---------------------------------------------------------------------------

/// One species' candidate costs for a shard: the GBATC section size
/// (`None` when Algorithm 1 could not certify τ on this section) and the
/// best self-contained alternative (if any stage certified).  Callers
/// must ensure every species has at least one candidate before planning.
pub struct SectionPlan {
    pub gbatc: Option<usize>,
    pub alt: Option<(CodecTag, usize)>,
}

/// Memoized trial outcomes of one (shard, species): one slot per registry
/// stage, filled during the trial pass and drained by the archive writer.
///
/// Lifetime: a cache lives from the trial pass until its shard is
/// assembled — [`plan_shard`]/[`plan_archive`] read only sizes from it,
/// and the winning stage's *bytes* are emitted verbatim with
/// [`Self::take`], so `--codec auto` costs exactly the trials and nothing
/// more (no re-encode of the chosen stage).
#[derive(Default)]
pub struct TrialCache {
    slots: [Option<SectionEncoding>; 3],
}

impl TrialCache {
    pub fn new() -> TrialCache {
        TrialCache::default()
    }

    /// Memoize one stage's trial (replacing an earlier trial of the same
    /// stage).
    pub fn insert(&mut self, enc: SectionEncoding) {
        self.slots[enc.tag as usize] = Some(enc);
    }

    pub fn get(&self, tag: CodecTag) -> Option<&SectionEncoding> {
        self.slots[tag as usize].as_ref()
    }

    /// Hand the winning encoding to the archive writer (consuming it).
    pub fn take(&mut self, tag: CodecTag) -> Option<SectionEncoding> {
        self.slots[tag as usize].take()
    }

    /// Smallest memoized self-contained (non-GBATC) trial.  Ties prefer
    /// SZ, matching the pre-cache planner's choice so archives stay
    /// byte-identical.
    pub fn best_alt(&self) -> Option<(CodecTag, usize)> {
        let mut best: Option<(CodecTag, usize)> = None;
        for tag in [CodecTag::Sz, CodecTag::Dense] {
            if let Some(e) = self.get(tag) {
                let len = e.bytes.len();
                match best {
                    Some((_, b)) if b <= len => {}
                    _ => best = Some((tag, len)),
                }
            }
        }
        best
    }

    /// Drop any memoized self-contained trial that [`Self::best_alt`] can
    /// never select (the larger of SZ/dense).  The planner only ever
    /// drains the winner, so evicting the loser frees its bytes during
    /// the archive-level planning wait without changing any choice.
    pub fn evict_losing_alt(&mut self) {
        if let Some((keep, _)) = self.best_alt() {
            for tag in [CodecTag::Sz, CodecTag::Dense] {
                if tag != keep {
                    self.slots[tag as usize] = None;
                }
            }
        }
    }

    /// The planner's per-species cost row; `gbatc_certified` gates the
    /// GBATC candidate (an uncertified section is never selectable).
    pub fn plan(&self, gbatc_certified: bool) -> SectionPlan {
        SectionPlan {
            gbatc: if gbatc_certified {
                self.get(CodecTag::Gbatc).map(|e| e.bytes.len())
            } else {
                None
            },
            alt: self.best_alt(),
        }
    }
}

/// Pick the byte-minimal codec assignment for one shard.
///
/// Cost model: the latent blob is shared by every GBATC section of the
/// shard, self-contained sections carry no shared cost.  Two scenarios
/// are exact-optimal under that structure:
/// (b) pay `latent_bytes` once, each species picks
///     `min(gbatc, alt)`; (a) no GBATC at all, every species uses its
///     alternative (only valid when all have one).  Returns
/// `(keep_latent, per-species tags)` for the smaller total.
pub fn plan_shard(latent_bytes: usize, plans: &[SectionPlan]) -> (bool, Vec<CodecTag>) {
    // scenario-b per-species choice: the cheaper available candidate
    // (GBATC only when it certified)
    let choose_b = |p: &SectionPlan| -> (CodecTag, usize) {
        match (p.gbatc, p.alt) {
            (Some(g), Some((t, a))) if a < g => (t, a),
            (Some(g), _) => (CodecTag::Gbatc, g),
            (None, Some((t, a))) => (t, a),
            // unreachable when the caller upheld the one-candidate
            // invariant; kept total so planning never panics
            (None, None) => (CodecTag::Gbatc, 0),
        }
    };
    let total_b: usize = latent_bytes + plans.iter().map(|p| choose_b(p).1).sum::<usize>();
    let total_a: Option<usize> = plans.iter().map(|p| p.alt.map(|(_, a)| a)).sum();
    match total_a {
        Some(a) if a < total_b => (false, plans.iter().map(|p| p.alt.unwrap().0).collect()),
        _ => (true, plans.iter().map(|p| choose_b(p).0).collect()),
    }
}

/// Archive-level planning: per-shard [`plan_shard`] choices, refined by
/// the model-parameter charge.  The decoder (+ TCN) bytes are paid once
/// for the whole archive iff *any* section anywhere is GBATC, so the
/// exact optimum is `min(B, A)` where B = per-shard payload minima +
/// `model_bytes` (when they retain any GBATC section) and A = the fully
/// model-free assignment (feasible only when every section has a
/// certified self-contained alternative).  Returns one
/// `(keep_latent, tags)` pair per shard.
pub fn plan_archive(
    shards: &[(usize, Vec<SectionPlan>)],
    model_bytes: usize,
) -> Vec<(bool, Vec<CodecTag>)> {
    let per_shard: Vec<(bool, Vec<CodecTag>)> = shards
        .iter()
        .map(|(latent, plans)| plan_shard(*latent, plans))
        .collect();
    let any_gbatc = per_shard
        .iter()
        .any(|(_, tags)| tags.iter().any(|&t| t == CodecTag::Gbatc));
    if model_bytes == 0 || !any_gbatc {
        return per_shard;
    }
    let cost_b: usize = shards
        .iter()
        .zip(&per_shard)
        .map(|((latent, plans), (keep, tags))| {
            let sections: usize = tags
                .iter()
                .zip(plans)
                .map(|(&t, p)| match t {
                    CodecTag::Gbatc => p.gbatc.unwrap_or(0),
                    _ => p.alt.map(|(_, a)| a).unwrap_or(0),
                })
                .sum();
            sections + if *keep { *latent } else { 0 }
        })
        .sum::<usize>()
        + model_bytes;
    let cost_a: Option<usize> = shards
        .iter()
        .map(|(_, plans)| {
            plans
                .iter()
                .map(|p| p.alt.map(|(_, a)| a))
                .sum::<Option<usize>>()
        })
        .sum();
    match cost_a {
        Some(a) if a < cost_b => shards
            .iter()
            .map(|(_, plans)| (false, plans.iter().map(|p| p.alt.unwrap().0).collect()))
            .collect(),
        _ => per_shard,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::blocks::BlockShape;
    use crate::util::Prng;

    fn smooth_plane(nt: usize, ny: usize, nx: usize) -> Vec<f32> {
        let mut v = Vec::with_capacity(nt * ny * nx);
        for t in 0..nt {
            for y in 0..ny {
                for x in 0..nx {
                    v.push(
                        0.5 + 0.3
                            * ((t as f32) * 0.2 + (y as f32) * 0.11 + (x as f32) * 0.07).sin(),
                    );
                }
            }
        }
        v
    }

    #[test]
    fn sz_stage_roundtrips_under_budget() {
        let (nt, ny, nx) = (4, 20, 20);
        let plane = smooth_plane(nt, ny, nx);
        let view = SectionView {
            species: 0,
            nt,
            ny,
            nx,
            norm: &plane,
        };
        let budget = 1e-3;
        let enc = SZ_STAGE.encode(&view, budget).unwrap().expect("certifies");
        assert_eq!(enc.tag, CodecTag::Sz);
        assert!(enc.nrmse <= budget, "{}", enc.nrmse);
        assert!(enc.bytes.len() < plane.len() * 4);
        let mut out = vec![0.0f32; plane.len()];
        SZ_STAGE.decode(&enc.bytes, nt, ny, nx, &mut out).unwrap();
        assert!((plane_rmse(&plane, &out) - enc.nrmse).abs() < 1e-12);
        // trailing garbage is rejected
        let mut bad = enc.bytes.clone();
        bad.push(0);
        assert!(SZ_STAGE.decode(&bad, nt, ny, nx, &mut out).is_err());
    }

    #[test]
    fn dense_stage_constant_plane_is_tiny_and_exact() {
        let plane = vec![0.25f32; 4 * 10 * 10];
        let view = SectionView {
            species: 0,
            nt: 4,
            ny: 10,
            nx: 10,
            norm: &plane,
        };
        let enc = DENSE_STAGE.encode(&view, 1e-4).unwrap().expect("certifies");
        assert!(enc.bytes.len() < 32, "{} B", enc.bytes.len());
        assert_eq!(enc.nrmse, 0.0);
        let mut out = vec![0.0f32; plane.len()];
        DENSE_STAGE.decode(&enc.bytes, 4, 10, 10, &mut out).unwrap();
        assert_eq!(out, plane);
    }

    #[test]
    fn dense_stage_noise_bounded_and_validated() {
        let mut rng = Prng::new(3);
        let plane: Vec<f32> = (0..4 * 15 * 15).map(|_| rng.next_f32()).collect();
        let view = SectionView {
            species: 0,
            nt: 4,
            ny: 15,
            nx: 15,
            norm: &plane,
        };
        let budget = 5e-3;
        let enc = DENSE_STAGE.encode(&view, budget).unwrap().expect("certifies");
        let mut out = vec![0.0f32; plane.len()];
        DENSE_STAGE.decode(&enc.bytes, 4, 15, 15, &mut out).unwrap();
        let rmse = plane_rmse(&plane, &out);
        assert!(rmse <= budget, "{rmse}");
        assert!((rmse - enc.nrmse).abs() < 1e-12);
        // truncated payload is a clean error
        assert!(DENSE_STAGE
            .decode(&enc.bytes[..enc.bytes.len() - 2], 4, 15, 15, &mut out)
            .is_err());
    }

    #[test]
    fn gbatc_stage_matches_engine_style_correction() {
        let shape = BlockShape { kt: 4, by: 5, bx: 4 };
        let (nt, ns, ny, nx) = (4, 2, 10, 8);
        let grid = BlockGrid::new((nt, ns, ny, nx), shape).unwrap();
        let mut rng = Prng::new(11);
        let n = nt * ns * ny * nx;
        let norm: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
        let recon: Vec<f32> = norm
            .iter()
            .map(|&v| v + (rng.normal() * 0.05) as f32)
            .collect();
        let d = shape.d();
        let tau = 0.02 * (d as f64).sqrt();
        let params = vec![GuaranteeParams::for_tau(tau, d); ns];
        let codec = GbatcShardCodec {
            grid: &grid,
            norm: &norm,
            recon: &recon,
            params: &params,
            pca_threads: 1,
        };
        let npix = ny * nx;
        for s in 0..ns {
            let plane = gather_plane(&norm, nt, ns, npix, s);
            let view = SectionView {
                species: s,
                nt,
                ny,
                nx,
                norm: &plane,
            };
            let enc = codec.encode(&view, 0.02).unwrap().expect("certifies");
            assert_eq!(enc.tag, CodecTag::Gbatc);
            // trait decode refines the prior plane; every block must land
            // within tau of the original
            let mut prior = gather_plane(&recon, nt, ns, npix, s);
            codec.decode(&enc.bytes, nt, ny, nx, &mut prior).unwrap();
            let plane_grid = BlockGrid::new((nt, 1, ny, nx), shape).unwrap();
            let mut ov = vec![0.0f32; d];
            let mut cv = vec![0.0f32; d];
            for b in 0..plane_grid.n_blocks() {
                plane_grid.gather_species(&plane, b, 0, &mut ov);
                plane_grid.gather_species(&prior, b, 0, &mut cv);
                let e2: f64 = ov
                    .iter()
                    .zip(&cv)
                    .map(|(&a, &b)| {
                        let e = a as f64 - b as f64;
                        e * e
                    })
                    .sum();
                assert!(e2.sqrt() <= tau + 1e-9, "s {s} block {b}: {}", e2.sqrt());
            }
        }
    }

    #[test]
    fn salvage_decode_degrades_gracefully() {
        let shape = BlockShape { kt: 2, by: 2, bx: 2 };
        let (nt, ny, nx) = (4, 4, 4);
        let d = shape.d();
        let grid = BlockGrid::new((nt, 1, ny, nx), shape).unwrap();
        let nb = grid.n_blocks();
        let basis = SpeciesBasis::from_mat(&crate::linalg::Mat::identity(d), 3);
        let per_block: Vec<Vec<(usize, i64)>> =
            (0..nb).map(|b| vec![(b % 3, 1 + b as i64)]).collect();
        let coeffs = CoeffCodec::encode(&per_block, d, 0.5).unwrap();
        let bytes = SpeciesSection { basis, coeffs }.to_bytes();

        // intact input: salvage is bit-identical to the strict decode
        let prior0 = vec![0.5f32; nt * ny * nx];
        let mut strict = prior0.clone();
        GbatcShardCodec::correct_plane(shape, &bytes, nt, ny, nx, &mut strict).unwrap();
        let mut sal = prior0.clone();
        let rep = GbatcShardCodec::correct_plane_salvage(shape, &bytes, nt, ny, nx, &mut sal);
        assert_eq!(rep.salvaged_fraction, 1.0);
        assert!(rep.max_correction > 0.0);
        assert_eq!(sal, strict);

        // every truncation point: strict may error, salvage never does —
        // it applies a trustworthy prefix or falls back to the prior
        for cut in 0..bytes.len() {
            let mut part = prior0.clone();
            let rep =
                GbatcShardCodec::correct_plane_salvage(shape, &bytes[..cut], nt, ny, nx, &mut part);
            assert!((0.0..=1.0).contains(&rep.salvaged_fraction), "cut {cut}");
            if rep.salvaged_fraction == 0.0 {
                assert_eq!(part, prior0, "cut {cut}: untouched prior expected");
            }
        }
        let mut out = prior0.clone();
        assert!(
            GbatcShardCodec::correct_plane(shape, &bytes[..bytes.len() - 3], nt, ny, nx, &mut out)
                .is_err()
        );
    }

    #[test]
    fn planner_picks_byte_minimal_scenario() {
        // latent amortized across GBATC sections: keeping it wins here
        let plans = vec![
            SectionPlan { gbatc: Some(100), alt: Some((CodecTag::Sz, 400)) },
            SectionPlan { gbatc: Some(120), alt: Some((CodecTag::Sz, 90)) },
            SectionPlan { gbatc: Some(80), alt: None },
        ];
        let (keep, tags) = plan_shard(50, &plans);
        assert!(keep);
        assert_eq!(tags, vec![CodecTag::Gbatc, CodecTag::Sz, CodecTag::Gbatc]);

        // dropping the latent wins when alternatives dominate
        let plans = vec![
            SectionPlan { gbatc: Some(100), alt: Some((CodecTag::Sz, 20)) },
            SectionPlan { gbatc: Some(120), alt: Some((CodecTag::Dense, 10)) },
        ];
        let (keep, tags) = plan_shard(500, &plans);
        assert!(!keep);
        assert_eq!(tags, vec![CodecTag::Sz, CodecTag::Dense]);

        // no alternative anywhere: classic all-GBATC
        let plans = vec![SectionPlan { gbatc: Some(10), alt: None }];
        let (keep, tags) = plan_shard(1000, &plans);
        assert!(keep);
        assert_eq!(tags, vec![CodecTag::Gbatc]);

        // an uncertified GBATC candidate is never selected, even when the
        // alternative is far more expensive
        let plans = vec![SectionPlan { gbatc: None, alt: Some((CodecTag::Dense, 999)) }];
        let (_, tags) = plan_shard(5, &plans);
        assert_eq!(tags, vec![CodecTag::Dense]);
    }

    #[test]
    fn archive_planner_drops_model_when_alternatives_dominate() {
        // two shards; per-shard minima would keep one cheap GBATC section,
        // but the archive-level model charge makes the model-free plan win
        let shards = vec![
            (
                10usize,
                vec![SectionPlan { gbatc: Some(50), alt: Some((CodecTag::Sz, 60)) }],
            ),
            (
                10usize,
                vec![SectionPlan { gbatc: Some(100), alt: Some((CodecTag::Sz, 40)) }],
            ),
        ];
        // without a model charge, the per-shard choice keeps the cheap
        // GBATC section of shard 0
        let free = plan_archive(&shards, 0);
        assert_eq!(free[0], (true, vec![CodecTag::Gbatc]));
        assert_eq!(free[1], (false, vec![CodecTag::Sz]));
        // with the model charged once per archive, going fully
        // self-contained wins: (60 + 40) < (60 + 40 + 1000)
        let with_model = plan_archive(&shards, 1000);
        assert_eq!(with_model[0], (false, vec![CodecTag::Sz]));
        assert_eq!(with_model[1], (false, vec![CodecTag::Sz]));
        // a section without any certified alternative pins the model
        let pinned = vec![(10usize, vec![SectionPlan { gbatc: Some(50), alt: None }])];
        assert_eq!(plan_archive(&pinned, 1000)[0].1, vec![CodecTag::Gbatc]);
    }

    #[test]
    fn trial_cache_memoizes_and_drains() {
        let enc = |tag: CodecTag, n: usize| SectionEncoding {
            tag,
            bytes: vec![0u8; n],
            nrmse: 1e-4,
        };
        let mut cache = TrialCache::new();
        assert!(cache.best_alt().is_none());
        cache.insert(enc(CodecTag::Gbatc, 50));
        cache.insert(enc(CodecTag::Dense, 40));
        cache.insert(enc(CodecTag::Sz, 60));
        // certified GBATC + cheaper dense alternative
        let plan = cache.plan(true);
        assert_eq!(plan.gbatc, Some(50));
        assert_eq!(plan.alt, Some((CodecTag::Dense, 40)));
        // uncertified GBATC never becomes a candidate
        assert_eq!(cache.plan(false).gbatc, None);
        // ties prefer SZ (the pre-cache planner's choice)
        cache.insert(enc(CodecTag::Sz, 40));
        assert_eq!(cache.best_alt(), Some((CodecTag::Sz, 40)));
        // evicting the losing alternative frees it without changing the plan
        cache.evict_losing_alt();
        assert!(cache.get(CodecTag::Dense).is_none());
        assert_eq!(cache.best_alt(), Some((CodecTag::Sz, 40)));
        // the winner drains as the exact trial bytes — no re-encode
        let won = cache.take(CodecTag::Sz).expect("memoized");
        assert_eq!(won.bytes.len(), 40);
        assert!(cache.take(CodecTag::Sz).is_none());
        assert!(cache.get(CodecTag::Gbatc).is_some());
    }

    #[test]
    fn planner_total_never_worse_than_single_codec() {
        let mut rng = Prng::new(7);
        for _ in 0..200 {
            let ns = 1 + rng.index(6);
            let latent = rng.index(2000);
            let plans: Vec<SectionPlan> = (0..ns)
                .map(|_| SectionPlan {
                    gbatc: Some(1 + rng.index(1000)),
                    alt: if rng.next_f64() < 0.8 {
                        Some((CodecTag::Sz, 1 + rng.index(1000)))
                    } else {
                        None
                    },
                })
                .collect();
            let (keep, tags) = plan_shard(latent, &plans);
            let total: usize = tags
                .iter()
                .zip(&plans)
                .map(|(&t, p)| match t {
                    CodecTag::Gbatc => p.gbatc.unwrap(),
                    _ => p.alt.unwrap().1,
                })
                .sum::<usize>()
                + if keep { latent } else { 0 };
            let all_gbatc: usize = latent + plans.iter().map(|p| p.gbatc.unwrap()).sum::<usize>();
            assert!(total <= all_gbatc, "{total} > all-GBATC {all_gbatc}");
            if plans.iter().all(|p| p.alt.is_some()) {
                let all_alt: usize = plans.iter().map(|p| p.alt.unwrap().1).sum();
                assert!(total <= all_alt, "{total} > all-alt {all_alt}");
            }
            if !keep {
                assert!(tags.iter().all(|&t| t != CodecTag::Gbatc));
            }
        }
    }
}
