//! Net production rates ω̇_k(T, P, Y) — the paper's QoI.
//!
//! Pointwise evaluation: ideal-gas density from (T, P, Y), molar
//! concentrations [X_j] = ρ Y_j / MW_j, then for each reversible reaction
//! `A + B -> νc C + νd D`:  q = kf [A][B] − kr Π [prod]^ν with
//! kr = kf / Keq.  ω̇_k = MW_k Σ_r ν_kr q_r  [kg m⁻³ s⁻¹].

use crate::chem::arrhenius::R_GAS;
use crate::chem::mechanism::Mechanism;
use crate::chem::species::{NS, SPECIES};

/// Net production rates for one grid point.
/// `y` = 58 mass fractions, `t` [K], `p` [Pa]; `out` length 58.
pub fn production_rates_point(mech: &Mechanism, y: &[f32], t: f64, p: f64, out: &mut [f64]) {
    debug_assert_eq!(y.len(), NS);
    debug_assert_eq!(out.len(), NS);

    // mean molecular weight & density (MW table is g/mol -> kg/mol)
    let mut inv_mbar = 0.0f64;
    for (k, sp) in SPECIES.iter().enumerate() {
        inv_mbar += (y[k].max(0.0) as f64) / (sp.mw as f64 * 1e-3);
    }
    let inv_mbar = inv_mbar.max(1e-12);
    let rho = p / (R_GAS * t * inv_mbar); // kg/m^3

    // molar concentrations [mol/m^3]
    let mut x = [0.0f64; NS];
    for (k, sp) in SPECIES.iter().enumerate() {
        x[k] = rho * (y[k].max(0.0) as f64) / (sp.mw as f64 * 1e-3);
    }

    out.fill(0.0);
    for r in &mech.reactions {
        let kf = r.rate.k(t);
        let keq = (r.q0 - r.q1 * 1000.0 / t).exp();
        let kr = kf / keq;

        let fwd = kf * x[r.reac[0]] * x[r.reac[1]];
        let mut rev = kr;
        for &(s, nu) in &r.prod {
            rev *= x[s].max(0.0).powf(nu);
        }
        let q = fwd - rev; // mol/m^3/s

        out[r.reac[0]] -= q * (SPECIES[r.reac[0]].mw as f64 * 1e-3);
        out[r.reac[1]] -= q * (SPECIES[r.reac[1]].mw as f64 * 1e-3);
        for &(s, nu) in &r.prod {
            out[s] += nu * q * (SPECIES[s].mw as f64 * 1e-3);
        }
    }
}

/// Production rates for a full `[S, n]`-shaped batch of points.
/// `ys` is species-major: ys[s * n + i]; `out` likewise.
pub fn production_rates(
    mech: &Mechanism,
    ys: &[f32],
    temps: &[f32],
    p: f64,
    n: usize,
    out: &mut [f64],
) {
    debug_assert_eq!(ys.len(), NS * n);
    debug_assert_eq!(temps.len(), n);
    debug_assert_eq!(out.len(), NS * n);
    let mut y = [0.0f32; NS];
    let mut w = [0.0f64; NS];
    for i in 0..n {
        for s in 0..NS {
            y[s] = ys[s * n + i];
        }
        production_rates_point(mech, &y, temps[i] as f64, p, &mut w);
        for s in 0..NS {
            out[s * n + i] = w[s];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chem::species::index_of;

    fn test_y() -> [f32; NS] {
        let mut y = [0.0f32; NS];
        for (k, sp) in SPECIES.iter().enumerate() {
            y[k] = sp.magnitude * 0.5;
        }
        // renormalize to sum 1
        let s: f32 = y.iter().sum();
        for v in y.iter_mut() {
            *v /= s;
        }
        y
    }

    #[test]
    fn mass_conservation() {
        let mech = Mechanism::standard();
        let y = test_y();
        let mut w = [0.0f64; NS];
        production_rates_point(&mech, &y, 1400.0, 40.0e5, &mut w);
        let total: f64 = w.iter().sum();
        let scale: f64 = w.iter().map(|v| v.abs()).sum::<f64>().max(1e-30);
        assert!(
            total.abs() < 1e-9 * scale,
            "net mass production {total} vs scale {scale}"
        );
    }

    #[test]
    fn rates_finite_and_nonzero() {
        let mech = Mechanism::standard();
        let y = test_y();
        let mut w = [0.0f64; NS];
        for t in [1000.0, 1600.0, 2200.0] {
            production_rates_point(&mech, &y, t, 40.0e5, &mut w);
            assert!(w.iter().all(|v| v.is_finite()));
            assert!(w.iter().any(|v| v.abs() > 0.0));
        }
    }

    #[test]
    fn qoi_is_nonlinear_in_temperature() {
        // Arrhenius nonlinearity: +1% T produces >> +1% change in rate
        // magnitudes — the property that amplifies PD errors into QoI
        // errors (Figs. 6/8 of the paper).
        let mech = Mechanism::standard();
        let y = test_y();
        let mut w0 = [0.0f64; NS];
        let mut w1 = [0.0f64; NS];
        production_rates_point(&mech, &y, 1300.0, 40.0e5, &mut w0);
        production_rates_point(&mech, &y, 1300.0 * 1.01, 40.0e5, &mut w1);
        let m0: f64 = w0.iter().map(|v| v.abs()).sum();
        let m1: f64 = w1.iter().map(|v| v.abs()).sum();
        let rel = (m1 - m0).abs() / m0;
        assert!(rel > 0.02, "QoI barely responded to T: {rel}");
    }

    #[test]
    fn species_perturbation_propagates_cross_species() {
        // perturbing one species' mass fraction must change *other*
        // species' production rates (the QoI is cross-species).
        let mech = Mechanism::standard();
        let y0 = test_y();
        let fuel = index_of("nC7H16").unwrap();
        let mut w0 = [0.0f64; NS];
        let mut w1 = [0.0f64; NS];
        production_rates_point(&mech, &y0, 1300.0, 40.0e5, &mut w0);
        let mut y1 = y0;
        y1[fuel] *= 1.5;
        production_rates_point(&mech, &y1, 1300.0, 40.0e5, &mut w1);
        let changed = (0..NS)
            .filter(|&k| k != fuel && (w1[k] - w0[k]).abs() > 1e-12 * w0[k].abs().max(1e-30))
            .count();
        assert!(changed > 5, "only {changed} species responded");
    }

    #[test]
    fn batch_matches_pointwise() {
        let mech = Mechanism::standard();
        let y = test_y();
        let n = 3;
        let mut ys = vec![0.0f32; NS * n];
        for s in 0..NS {
            for i in 0..n {
                ys[s * n + i] = y[s] * (1.0 + 0.01 * i as f32);
            }
        }
        let temps = [1200.0f32, 1400.0, 1800.0];
        let mut out = vec![0.0f64; NS * n];
        production_rates(&mech, &ys, &temps, 40.0e5, n, &mut out);

        let mut yi = [0.0f32; NS];
        let mut w = [0.0f64; NS];
        for i in 0..n {
            for s in 0..NS {
                yi[s] = ys[s * n + i];
            }
            production_rates_point(&mech, &yi, temps[i] as f64, 40.0e5, &mut w);
            for s in 0..NS {
                assert_eq!(out[s * n + i], w[s]);
            }
        }
    }
}
