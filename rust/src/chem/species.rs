//! The 58-species table — the cross-language ABI mirrored from
//! `python/compile/data.py::SPECIES` (same order, same parameters).
//!
//! Names follow the Yoo et al. 58-species n-heptane skeletal mechanism
//! flavor used by the paper's S3D dataset; `Role` + (magnitude, center,
//! width) drive both the synthetic data manifold and the synthetic reaction
//! mechanism (the Cantera substitute, see DESIGN.md §3).

/// Chemical role of a species in the synthetic HCCI manifold.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Role {
    Fuel,
    Oxidizer,
    Inert,
    Product,
    Co,
    Intermediate,
    Radical,
    LowT,
}

/// Static description of one species.
#[derive(Clone, Copy, Debug)]
pub struct Species {
    pub name: &'static str,
    pub role: Role,
    /// Peak mass-fraction magnitude (spans ~8 decades across the table).
    pub magnitude: f32,
    /// Progress-variable center of the species' manifold bump.
    pub center: f32,
    /// Width of the bump.
    pub width: f32,
    /// Molecular weight [g/mol] (synthetic but element-plausible).
    pub mw: f32,
}

use Role::*;

/// Number of species (fixed by the paper's dataset).
pub const NS: usize = 58;

macro_rules! sp {
    ($name:literal, $role:ident, $mag:expr, $ctr:expr, $wid:expr, $mw:expr) => {
        Species {
            name: $name,
            role: $role,
            magnitude: $mag,
            center: $ctr,
            width: $wid,
            mw: $mw,
        }
    };
}

/// The full table, index-aligned with the dataset's species axis.
pub static SPECIES: [Species; NS] = [
    sp!("nC7H16", Fuel, 2.5e-02, 0.00, 0.30, 100.2),
    sp!("O2", Oxidizer, 2.2e-01, 0.00, 0.40, 32.0),
    sp!("N2", Inert, 7.2e-01, 0.00, 1.00, 28.0),
    sp!("CO2", Product, 8.0e-02, 0.95, 0.30, 44.0),
    sp!("H2O", Product, 6.5e-02, 0.90, 0.30, 18.0),
    sp!("CO", Co, 4.5e-02, 0.55, 0.22, 28.0),
    sp!("H2", Co, 1.5e-03, 0.50, 0.25, 2.0),
    sp!("H", Radical, 3.0e-05, 0.80, 0.12, 1.0),
    sp!("O", Radical, 8.0e-05, 0.78, 0.12, 16.0),
    sp!("OH", Radical, 2.5e-03, 0.82, 0.15, 17.0),
    sp!("HO2", Radical, 1.2e-04, 0.45, 0.18, 33.0),
    sp!("H2O2", Intermediate, 3.0e-04, 0.40, 0.16, 34.0),
    sp!("CH3", Radical, 2.0e-04, 0.55, 0.15, 15.0),
    sp!("CH4", Intermediate, 9.0e-04, 0.50, 0.22, 16.0),
    sp!("CH2O", Intermediate, 1.8e-03, 0.42, 0.16, 30.0),
    sp!("HCO", Radical, 6.0e-06, 0.60, 0.12, 29.0),
    sp!("CH3O", Radical, 2.0e-06, 0.48, 0.12, 31.0),
    sp!("C2H2", Intermediate, 4.0e-04, 0.62, 0.15, 26.0),
    sp!("C2H3", Radical, 5.0e-06, 0.60, 0.11, 27.0),
    sp!("C2H4", Intermediate, 3.5e-03, 0.52, 0.18, 28.0),
    sp!("C2H5", Radical, 4.0e-06, 0.45, 0.12, 29.0),
    sp!("C2H6", Intermediate, 4.0e-04, 0.40, 0.18, 30.0),
    sp!("CH2CHO", Radical, 3.0e-06, 0.55, 0.11, 43.0),
    sp!("CH3CHO", Intermediate, 2.5e-04, 0.38, 0.15, 44.0),
    sp!("C3H4", Intermediate, 8.0e-05, 0.55, 0.14, 40.0),
    sp!("C3H5", Radical, 6.0e-05, 0.52, 0.13, 41.0),
    sp!("C3H6", Intermediate, 1.5e-03, 0.45, 0.16, 42.0),
    sp!("nC3H7", Radical, 2.0e-06, 0.30, 0.10, 43.0),
    sp!("C4H7", Radical, 4.0e-06, 0.35, 0.11, 55.0),
    sp!("C4H8-1", Intermediate, 6.0e-04, 0.38, 0.14, 56.0),
    sp!("pC4H9", Radical, 1.5e-06, 0.28, 0.10, 57.0),
    sp!("C5H9", Radical, 2.5e-06, 0.33, 0.10, 69.0),
    sp!("C5H10-1", Intermediate, 3.5e-04, 0.35, 0.13, 70.0),
    sp!("C6H12-1", Intermediate, 2.5e-04, 0.32, 0.12, 84.0),
    sp!("C7H15-2", Radical, 3.0e-06, 0.20, 0.09, 99.0),
    sp!("C7H15O2", LowT, 5.0e-05, 0.15, 0.10, 131.0),
    sp!("C7H14OOH", LowT, 1.2e-05, 0.16, 0.09, 131.0),
    sp!("OC7H13OOH", LowT, 4.0e-06, 0.18, 0.09, 146.0),
    sp!("nC7KET12", LowT, 2.0e-05, 0.17, 0.09, 146.0),
    sp!("C5H11CO", LowT, 1.5e-06, 0.22, 0.09, 99.0),
    sp!("nC3H7COCH2", LowT, 8.0e-07, 0.20, 0.08, 85.0),
    sp!("CH3COCH2", Radical, 2.0e-06, 0.42, 0.11, 57.0),
    sp!("CH3COCH3", Intermediate, 8.0e-05, 0.35, 0.13, 58.0),
    sp!("C2H5CHO", Intermediate, 4.0e-05, 0.30, 0.12, 58.0),
    sp!("C2H5CO", Radical, 8.0e-07, 0.32, 0.10, 57.0),
    sp!("CH3OCH3", Intermediate, 2.0e-05, 0.33, 0.12, 46.0),
    sp!("CH3OCH2", Radical, 5.0e-07, 0.36, 0.10, 45.0),
    sp!("HOCH2O", LowT, 3.0e-06, 0.25, 0.10, 47.0),
    sp!("HCOOH", Intermediate, 5.0e-05, 0.38, 0.13, 46.0),
    sp!("CH3O2", LowT, 8.0e-06, 0.22, 0.10, 47.0),
    sp!("CH3O2H", LowT, 6.0e-06, 0.24, 0.10, 48.0),
    sp!("C2H3CHO", Intermediate, 6.0e-05, 0.48, 0.13, 56.0),
    sp!("C2H3CO", Radical, 4.0e-07, 0.50, 0.10, 55.0),
    sp!("aC3H5CHO", Intermediate, 1.5e-05, 0.44, 0.12, 70.0),
    sp!("NO", Product, 1.2e-04, 0.97, 0.25, 30.0),
    sp!("NO2", Intermediate, 1.5e-05, 0.70, 0.18, 46.0),
    sp!("N2O", Intermediate, 8.0e-06, 0.75, 0.18, 44.0),
    sp!("NNH", Radical, 2.0e-08, 0.85, 0.12, 29.0),
];

/// Look up a species index by name.
pub fn index_of(name: &str) -> Option<usize> {
    SPECIES.iter().position(|s| s.name == name)
}

/// Paper's "major" species (reactants + products of Figs. 5/7).
pub const MAJORS: [&str; 5] = ["nC7H16", "O2", "CO2", "CO", "H2O"];

/// Paper's representative minor species (Figs. 6/8).
pub const MINOR_C2H3: &str = "C2H3";
pub const MINOR_LOWT: &str = "nC3H7COCH2";

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_has_58_unique_names() {
        let mut names: Vec<_> = SPECIES.iter().map(|s| s.name).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), NS);
    }

    #[test]
    fn lookups() {
        assert_eq!(index_of("nC7H16"), Some(0));
        assert_eq!(index_of("C2H3"), Some(18));
        assert!(index_of("nC3H7COCH2").is_some());
        assert_eq!(index_of("unobtainium"), None);
    }

    #[test]
    fn magnitudes_span_decades() {
        let max = SPECIES.iter().map(|s| s.magnitude).fold(0.0f32, f32::max);
        let min = SPECIES
            .iter()
            .map(|s| s.magnitude)
            .fold(f32::INFINITY, f32::min);
        assert!(max / min > 1e6, "span {max}/{min}");
    }
}
