//! Chemistry substrate — the Cantera substitute (DESIGN.md §3).
//!
//! The paper's QoI is the net production rate of each of the 58 species,
//! computed from reconstructed primary data with Cantera (Arrhenius
//! kinetics over a reduced n-heptane mechanism).  Here a synthetic
//! 58-species reversible-reaction mechanism provides the same structure:
//! a pointwise, strongly nonlinear, cross-species map
//! `omega_k = f(T, P, Y_1..Y_58)` so that small PD errors in minor species
//! amplify into large QoI errors — the effect Figs. 6/8 hinge on.

pub mod arrhenius;
pub mod mechanism;
pub mod production;
pub mod species;

pub use mechanism::{resolve_species, species_names, Mechanism, Reaction};
pub use production::production_rates;
pub use species::{index_of, Role, Species, MAJORS, MINOR_C2H3, MINOR_LOWT, NS, SPECIES};
