//! Synthetic 58-species reversible reaction mechanism (Cantera substitute).
//!
//! Procedurally constructed — deterministically, from a fixed seed — so the
//! same mechanism exists in every process without shipping a data file.
//! Structure mirrors a reduced n-heptane mechanism: a fuel-breakdown chain,
//! an H2/O2 radical pool, CO oxidation, and a low-temperature (RO2) branch.
//! Every reaction is bimolecular A + B -> c C + d D with product
//! stoichiometries chosen to conserve mass exactly (Σ ν MW balanced), so
//! net production rates sum to zero over species — a tested invariant.
//! Reverse rates come from a synthetic equilibrium constant
//! Keq = exp(q0 - q1 * 1000 / T).

use crate::chem::arrhenius::Arrhenius;
use crate::chem::species::{index_of, Role, NS, SPECIES};
use crate::util::Prng;

/// One reversible reaction: A + B -> nu_c C + nu_d D.
#[derive(Clone, Debug)]
pub struct Reaction {
    pub reac: [usize; 2],
    pub prod: [(usize, f64); 2],
    pub rate: Arrhenius,
    /// Keq = exp(q0 - q1 * 1000 / T)
    pub q0: f64,
    pub q1: f64,
}

/// The full mechanism.
#[derive(Clone, Debug)]
pub struct Mechanism {
    pub reactions: Vec<Reaction>,
}

fn mass_balanced(a: usize, b: usize, c: usize, d: usize) -> [(usize, f64); 2] {
    // choose nu_c, nu_d >= 0 with nu_c*MWc + nu_d*MWd = MWa + MWb, split 50/50
    let total = (SPECIES[a].mw + SPECIES[b].mw) as f64;
    let nu_c = 0.5 * total / SPECIES[c].mw as f64;
    let nu_d = 0.5 * total / SPECIES[d].mw as f64;
    [(c, nu_c), (d, nu_d)]
}

impl Mechanism {
    /// Build the canonical synthetic mechanism (fixed seed -> identical in
    /// every process; ~2 reactions per species).
    pub fn standard() -> Mechanism {
        let mut rng = Prng::new(0x6bca_7c58);
        let mut reactions = Vec::new();

        let radical_pool: Vec<usize> = ["OH", "H", "O", "HO2", "CH3"]
            .iter()
            .map(|n| index_of(n).unwrap())
            .collect();
        let o2 = index_of("O2").unwrap();
        let co = index_of("CO").unwrap();
        let co2 = index_of("CO2").unwrap();
        let h2o = index_of("H2O").unwrap();
        let oh = index_of("OH").unwrap();
        let h = index_of("H").unwrap();
        let o = index_of("O").unwrap();

        let mut push = |reac: [usize; 2], prod_c: usize, prod_d: usize, rng: &mut Prng| {
            let a = 10f64.powf(rng.uniform(4.0, 7.5));
            let b = rng.uniform(-0.5, 1.5);
            let ea = rng.uniform(6.0e4, 1.8e5);
            reactions.push(Reaction {
                reac,
                prod: mass_balanced(reac[0], reac[1], prod_c, prod_d),
                rate: Arrhenius::new(a, b, ea),
                q0: rng.uniform(1.0, 8.0),
                q1: rng.uniform(0.5, 6.0),
            });
        };

        // H2/O2 core (explicit, the stiff backbone)
        push([h, o2], oh, o, &mut rng);
        push([o, index_of("H2").unwrap()], oh, h, &mut rng);
        push([oh, index_of("H2").unwrap()], h2o, h, &mut rng);
        push([index_of("HO2").unwrap(), h], oh, oh, &mut rng);
        // CO oxidation
        push([co, oh], co2, h, &mut rng);
        push([co, o2], co2, o, &mut rng);

        // per-species attachment: every species appears as a reactant in at
        // least one reaction with a pool radical or O2
        for k in 0..NS {
            let sp = &SPECIES[k];
            if sp.role == Role::Inert {
                continue;
            }
            let n_rx = match sp.role {
                Role::Fuel | Role::LowT => 3,
                Role::Radical => 2,
                _ => 2,
            };
            for _ in 0..n_rx {
                let partner = if sp.role == Role::LowT || rng.next_f64() < 0.4 {
                    o2
                } else {
                    radical_pool[rng.index(radical_pool.len())]
                };
                // products: a nearby species in the table (correlated
                // chains) + a pool product
                let mut c = rng.index(NS);
                // bias products toward smaller species later in the chain
                if c == k || SPECIES[c].role == Role::Inert {
                    c = co;
                }
                let d = match rng.index(4) {
                    0 => h2o,
                    1 => oh,
                    2 => h,
                    _ => co2,
                };
                if partner == k || c == k {
                    continue;
                }
                push([k, partner], c, d, &mut rng);
            }
        }
        Mechanism { reactions }
    }

    pub fn n_reactions(&self) -> usize {
        self.reactions.len()
    }

    /// Indices of species participating anywhere in the mechanism.
    pub fn active_species(&self) -> Vec<bool> {
        let mut active = vec![false; NS];
        for r in &self.reactions {
            for &s in &r.reac {
                active[s] = true;
            }
            for &(s, _) in &r.prod {
                active[s] = true;
            }
        }
        active
    }
}

/// All mechanism species names, table order (the dataset's species axis).
pub fn species_names() -> Vec<&'static str> {
    SPECIES.iter().map(|s| s.name).collect()
}

/// Resolve a mechanism species *name* to its index on the species axis.
/// Unknown names are a typed config error that lists every available
/// name, so callers (the CLI, `api::SpeciesSel`) never guess.
pub fn resolve_species(name: &str) -> crate::error::Result<usize> {
    crate::chem::species::index_of(name).ok_or_else(|| {
        crate::error::Error::config(format!(
            "unknown species `{name}`; available: {}",
            species_names().join(", ")
        ))
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_nontrivial() {
        let m1 = Mechanism::standard();
        let m2 = Mechanism::standard();
        assert_eq!(m1.n_reactions(), m2.n_reactions());
        assert!(m1.n_reactions() > 80, "got {}", m1.n_reactions());
        for (a, b) in m1.reactions.iter().zip(&m2.reactions) {
            assert_eq!(a.reac, b.reac);
            assert_eq!(a.prod[0].0, b.prod[0].0);
        }
    }

    #[test]
    fn every_non_inert_species_participates() {
        let m = Mechanism::standard();
        let active = m.active_species();
        for (k, sp) in SPECIES.iter().enumerate() {
            if sp.role != Role::Inert {
                assert!(active[k], "species {} inactive", sp.name);
            }
        }
    }

    #[test]
    fn reactions_conserve_mass() {
        let m = Mechanism::standard();
        for (i, r) in m.reactions.iter().enumerate() {
            let lhs = SPECIES[r.reac[0]].mw as f64 + SPECIES[r.reac[1]].mw as f64;
            let rhs: f64 = r
                .prod
                .iter()
                .map(|&(s, nu)| nu * SPECIES[s].mw as f64)
                .sum();
            assert!(
                (lhs - rhs).abs() < 1e-9 * lhs,
                "reaction {i}: {lhs} vs {rhs}"
            );
        }
    }

    #[test]
    fn species_names_resolve_with_helpful_errors() {
        assert_eq!(resolve_species("OH").unwrap(), 9);
        assert_eq!(resolve_species("nC7H16").unwrap(), 0);
        let err = resolve_species("unobtainium").unwrap_err().to_string();
        // the error lists the available names so the caller can fix the
        // query without a round trip to the docs
        assert!(err.contains("unobtainium"), "{err}");
        assert!(err.contains("nC7H16"), "{err}");
        assert!(err.contains("NNH"), "{err}");
    }

    #[test]
    fn rates_finite_at_operating_temperatures() {
        let m = Mechanism::standard();
        for t in [1000.0, 1500.0, 2300.0] {
            for r in &m.reactions {
                let k = r.rate.k(t);
                assert!(k.is_finite() && k >= 0.0);
                let keq = (r.q0 - r.q1 * 1000.0 / t).exp();
                assert!(keq.is_finite() && keq > 0.0);
            }
        }
    }
}
