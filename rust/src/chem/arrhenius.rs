//! Modified Arrhenius rate constants: k(T) = A * T^b * exp(-Ea / (R T)).

/// Universal gas constant [J/(mol K)].
pub const R_GAS: f64 = 8.314462618;

/// Modified Arrhenius parameters.
#[derive(Clone, Copy, Debug)]
pub struct Arrhenius {
    /// Pre-exponential factor (units depend on reaction order).
    pub a: f64,
    /// Temperature exponent.
    pub b: f64,
    /// Activation energy [J/mol].
    pub ea: f64,
}

impl Arrhenius {
    pub const fn new(a: f64, b: f64, ea: f64) -> Self {
        Self { a, b, ea }
    }

    /// Forward rate constant at temperature `t` [K].
    #[inline]
    pub fn k(&self, t: f64) -> f64 {
        self.a * t.powf(self.b) * (-self.ea / (R_GAS * t)).exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn increases_with_temperature_for_positive_ea() {
        let a = Arrhenius::new(1e10, 0.0, 1.5e5);
        assert!(a.k(1200.0) > a.k(1000.0));
        assert!(a.k(2000.0) > a.k(1200.0));
    }

    #[test]
    fn exponential_sensitivity() {
        // the QoI nonlinearity: ~small T change -> large k change
        let a = Arrhenius::new(1e10, 0.0, 2.0e5);
        let ratio = a.k(1100.0) / a.k(1000.0);
        assert!(ratio > 5.0, "ratio {ratio}");
    }

    #[test]
    fn zero_ea_reduces_to_power_law() {
        let a = Arrhenius::new(2.0, 1.0, 0.0);
        assert!((a.k(500.0) - 1000.0).abs() < 1e-9);
    }
}
