//! Runtime-dispatched SIMD kernels for the measured hot loops.
//!
//! Every kernel here has exactly two implementations: a portable scalar
//! one (the reference the property tests treat as the oracle, and the
//! fallback on non-x86 targets or when `GBATC_NO_SIMD` is set) and an
//! AVX2 one selected once per process by [`active`] via
//! `is_x86_feature_detected!`.  The pair is **bit-identical by
//! construction**, which is what lets the SIMD paths sit under the
//! archive-bytes determinism contract (`DESIGN.md` §Hot paths):
//!
//! * **Elementwise kernels** ([`axpy_f64`], [`center_f32_to_f64`]) touch
//!   each output element with the same two IEEE ops (`mul` then `add`,
//!   never a fused multiply-add) in both implementations, so lane width
//!   cannot change a single bit.
//! * **Multi-accumulator dots** ([`dot4_cols`]) map one basis column per
//!   lane; each column's `d`-long f64 reduction stays one sequential
//!   chain exactly as the blocked scalar GEMM runs it.
//! * **Lane reductions** ([`sum_sq_diff`], [`minmax`]) use *fixed-width*
//!   lane accumulators ([`LANES_F64`]/[`LANES_F32`] lanes, independent of
//!   the ISA) combined sequentially in lane order at the end.  The scalar
//!   fallback emulates the identical lane pattern, so the result is the
//!   same with SIMD on, off, or unavailable — the lane order itself is
//!   the canonical reduction order, not an approximation of one.
//!
//! Single-chain reductions whose order is certified (e.g. the guarantee
//! pass's per-coefficient dot, [`dot_col`]) are *not* lane-split on any
//! path: the determinism invariant forbids it, so they stay scalar
//! everywhere and SIMD is applied across independent outputs instead.

use std::sync::OnceLock;

/// f64 accumulator lanes of the canonical lane-reduction order.  Fixed —
/// not a property of the selected ISA.
pub const LANES_F64: usize = 4;

/// f32 lanes of the canonical min/max sweep.  Fixed — not a property of
/// the selected ISA.
pub const LANES_F32: usize = 8;

/// Instruction-set path selected for this process.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Isa {
    /// 256-bit AVX2 paths (x86-64 with runtime-detected support).
    Avx2,
    /// Portable scalar paths emulating the same fixed lane pattern.
    Scalar,
}

impl Isa {
    /// Short name for logs and `inspect --stats`.
    pub fn name(self) -> &'static str {
        match self {
            Isa::Avx2 => "avx2",
            Isa::Scalar => "scalar",
        }
    }
}

/// The ISA selected for this process: AVX2 when the CPU supports it and
/// the `GBATC_NO_SIMD` environment variable is unset (or `0`/empty),
/// scalar otherwise.  Decided once and cached — kernels dispatch on a
/// single branch.
pub fn active() -> Isa {
    static ACTIVE: OnceLock<Isa> = OnceLock::new();
    *ACTIVE.get_or_init(|| {
        // Miri interprets no vendor intrinsics; the scalar oracle is the
        // whole point of running these kernels under it.
        if cfg!(miri) || simd_disabled_by_env() {
            return Isa::Scalar;
        }
        #[cfg(target_arch = "x86_64")]
        {
            if std::arch::is_x86_feature_detected!("avx2") {
                return Isa::Avx2;
            }
        }
        Isa::Scalar
    })
}

fn simd_disabled_by_env() -> bool {
    match std::env::var_os("GBATC_NO_SIMD") {
        Some(v) => !v.is_empty() && v != "0",
        None => false,
    }
}

// ---------------------------------------------------------------------------
// Lane reductions (canonical fixed-lane order on every path)
// ---------------------------------------------------------------------------

/// Σ (a\[i\] − b\[i\])² in f64, accumulated over [`LANES_F64`] fixed
/// lanes (element `i` feeds lane `i % LANES_F64`) with a sequential
/// final combine in lane order.  This *is* the canonical reduction order
/// of the NRMSE numerator — identical bits whichever ISA runs it.
///
/// NaN/inf inputs propagate exactly as the scalar lane loop would
/// (a NaN difference poisons its lane and therefore the combine).
pub fn sum_sq_diff(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    #[cfg(target_arch = "x86_64")]
    if active() == Isa::Avx2 {
        // SAFETY: AVX2 support was runtime-verified by `active()`.
        return unsafe { sum_sq_diff_avx2(a, b) };
    }
    sum_sq_diff_scalar(a, b)
}

/// Scalar oracle of [`sum_sq_diff`] — the same fixed-lane pattern
/// without intrinsics.
pub(crate) fn sum_sq_diff_scalar(a: &[f32], b: &[f32]) -> f64 {
    let mut acc = [0.0f64; LANES_F64];
    let whole = a.len() / LANES_F64 * LANES_F64;
    let mut i = 0;
    while i < whole {
        for l in 0..LANES_F64 {
            let d = a[i + l] as f64 - b[i + l] as f64;
            acc[l] += d * d;
        }
        i += LANES_F64;
    }
    for (l, k) in (i..a.len()).enumerate() {
        let d = a[k] as f64 - b[k] as f64;
        acc[l] += d * d;
    }
    combine_lanes_f64(&acc)
}

/// AVX2 path of [`sum_sq_diff`].
///
/// # Safety
/// SAFETY: the caller must have runtime-verified AVX2 support (the
/// [`active`] dispatch does) before calling.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn sum_sq_diff_avx2(a: &[f32], b: &[f32]) -> f64 {
    use std::arch::x86_64::*;
    let n = a.len();
    let whole = n / LANES_F64 * LANES_F64;
    // SAFETY: every unaligned load reads `i .. i + 4` with
    // `i + 4 <= whole <= n == a.len() == b.len()` (asserted by the
    // dispatch wrapper), and the store targets a local `[f64; 4]`.
    unsafe {
        let mut accv = _mm256_setzero_pd();
        let (ap, bp) = (a.as_ptr(), b.as_ptr());
        let mut i = 0;
        while i < whole {
            // 4 f32 pairs -> 4 exact f64 lanes; sub, mul, add are the same
            // three IEEE ops the scalar lane loop performs (no FMA)
            let av = _mm256_cvtps_pd(_mm_loadu_ps(ap.add(i)));
            let bv = _mm256_cvtps_pd(_mm_loadu_ps(bp.add(i)));
            let d = _mm256_sub_pd(av, bv);
            accv = _mm256_add_pd(accv, _mm256_mul_pd(d, d));
            i += LANES_F64;
        }
        let mut acc = [0.0f64; LANES_F64];
        _mm256_storeu_pd(acc.as_mut_ptr(), accv);
        for (l, k) in (i..n).enumerate() {
            let d = a[k] as f64 - b[k] as f64;
            acc[l] += d * d;
        }
        combine_lanes_f64(&acc)
    }
}

#[inline]
fn combine_lanes_f64(acc: &[f64; LANES_F64]) -> f64 {
    // sequential in lane order: (((0 + l0) + l1) + l2) + l3
    let mut s = 0.0f64;
    for &v in acc {
        s += v;
    }
    s
}

/// `(min, max)` of `xs` over [`LANES_F32`] fixed lanes (element `i`
/// feeds lane `i % LANES_F32`) combined sequentially in lane order.
/// Comparison semantics match the pre-SIMD sweep exactly: a value
/// replaces the running bound only when `v < lo` / `v > hi` holds, so
/// NaNs never enter and an all-NaN (or empty) input returns
/// `(inf, -inf)` as before.
pub fn minmax(xs: &[f32]) -> (f32, f32) {
    #[cfg(target_arch = "x86_64")]
    if active() == Isa::Avx2 {
        // SAFETY: AVX2 support was runtime-verified by `active()`.
        return unsafe { minmax_avx2(xs) };
    }
    minmax_scalar(xs)
}

/// Scalar oracle of [`minmax`] — the same fixed-lane pattern without
/// intrinsics.
pub(crate) fn minmax_scalar(xs: &[f32]) -> (f32, f32) {
    let mut lo = [f32::INFINITY; LANES_F32];
    let mut hi = [f32::NEG_INFINITY; LANES_F32];
    let whole = xs.len() / LANES_F32 * LANES_F32;
    let mut i = 0;
    while i < whole {
        for l in 0..LANES_F32 {
            let v = xs[i + l];
            if v < lo[l] {
                lo[l] = v;
            }
            if v > hi[l] {
                hi[l] = v;
            }
        }
        i += LANES_F32;
    }
    for (l, k) in (i..xs.len()).enumerate() {
        let v = xs[k];
        if v < lo[l] {
            lo[l] = v;
        }
        if v > hi[l] {
            hi[l] = v;
        }
    }
    combine_lanes_minmax(&lo, &hi)
}

/// AVX2 path of [`minmax`].
///
/// # Safety
/// SAFETY: the caller must have runtime-verified AVX2 support (the
/// [`active`] dispatch does) before calling.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn minmax_avx2(xs: &[f32]) -> (f32, f32) {
    use std::arch::x86_64::*;
    let n = xs.len();
    let whole = n / LANES_F32 * LANES_F32;
    // SAFETY: every unaligned load reads `i .. i + 8` with
    // `i + 8 <= whole <= n == xs.len()`; the stores target local
    // `[f32; 8]` arrays.
    unsafe {
        // vminps(v, lo) = v < lo ? v : lo (lo on NaN) — exactly the scalar
        // `if v < lo { lo = v }`, including signed-zero and NaN behavior
        let mut lov = _mm256_set1_ps(f32::INFINITY);
        let mut hiv = _mm256_set1_ps(f32::NEG_INFINITY);
        let p = xs.as_ptr();
        let mut i = 0;
        while i < whole {
            let v = _mm256_loadu_ps(p.add(i));
            lov = _mm256_min_ps(v, lov);
            hiv = _mm256_max_ps(v, hiv);
            i += LANES_F32;
        }
        let mut lo = [f32::INFINITY; LANES_F32];
        let mut hi = [f32::NEG_INFINITY; LANES_F32];
        _mm256_storeu_ps(lo.as_mut_ptr(), lov);
        _mm256_storeu_ps(hi.as_mut_ptr(), hiv);
        for (l, k) in (i..n).enumerate() {
            let v = xs[k];
            if v < lo[l] {
                lo[l] = v;
            }
            if v > hi[l] {
                hi[l] = v;
            }
        }
        combine_lanes_minmax(&lo, &hi)
    }
}

#[inline]
fn combine_lanes_minmax(lo: &[f32; LANES_F32], hi: &[f32; LANES_F32]) -> (f32, f32) {
    let (mut l, mut h) = (f32::INFINITY, f32::NEG_INFINITY);
    for i in 0..LANES_F32 {
        if lo[i] < l {
            l = lo[i];
        }
        if hi[i] > h {
            h = hi[i];
        }
    }
    (l, h)
}

// ---------------------------------------------------------------------------
// Elementwise kernels (lane width cannot change a bit)
// ---------------------------------------------------------------------------

/// `acc[j] += x * v[j]` — the PCA covariance row update.  Every element
/// sees exactly one `mul` and one `add` (no FMA) on both paths, so each
/// covariance entry's sample-order reduction chain is untouched and the
/// eigenbasis (and the archive bytes behind it) is bit-identical at any
/// lane width.
pub fn axpy_f64(acc: &mut [f64], x: f64, v: &[f64]) {
    assert_eq!(acc.len(), v.len());
    #[cfg(target_arch = "x86_64")]
    if active() == Isa::Avx2 {
        // SAFETY: AVX2 support was runtime-verified by `active()`.
        unsafe { axpy_f64_avx2(acc, x, v) };
        return;
    }
    axpy_f64_scalar(acc, x, v);
}

/// Scalar oracle of [`axpy_f64`].
pub(crate) fn axpy_f64_scalar(acc: &mut [f64], x: f64, v: &[f64]) {
    for (a, &b) in acc.iter_mut().zip(v) {
        *a += x * b;
    }
}

/// AVX2 path of [`axpy_f64`].
///
/// # Safety
/// SAFETY: the caller must have runtime-verified AVX2 support (the
/// [`active`] dispatch does) before calling.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn axpy_f64_avx2(acc: &mut [f64], x: f64, v: &[f64]) {
    use std::arch::x86_64::*;
    let n = acc.len();
    let whole = n / 4 * 4;
    // SAFETY: loads and stores touch `i .. i + 4` with
    // `i + 4 <= whole <= n == acc.len() == v.len()` (asserted by the
    // dispatch wrapper); `ap` is the only live pointer into `acc`.
    unsafe {
        let xv = _mm256_set1_pd(x);
        let ap = acc.as_mut_ptr();
        let vp = v.as_ptr();
        let mut i = 0;
        while i < whole {
            let a = _mm256_loadu_pd(ap.add(i));
            let b = _mm256_loadu_pd(vp.add(i));
            // mul then add — never vfmadd, which would fuse the rounding
            _mm256_storeu_pd(ap.add(i), _mm256_add_pd(a, _mm256_mul_pd(xv, b)));
            i += 4;
        }
        while i < n {
            acc[i] += x * v[i];
            i += 1;
        }
    }
}

/// `out[j] = row[j] as f64 - mean[j]` — the PCA sample-centering sweep.
/// The f32→f64 widening is exact and the subtraction elementwise, so the
/// paths agree bit for bit.
pub fn center_f32_to_f64(out: &mut [f64], row: &[f32], mean: &[f64]) {
    assert_eq!(out.len(), row.len());
    assert_eq!(out.len(), mean.len());
    #[cfg(target_arch = "x86_64")]
    if active() == Isa::Avx2 {
        // SAFETY: AVX2 support was runtime-verified by `active()`.
        unsafe { center_f32_to_f64_avx2(out, row, mean) };
        return;
    }
    center_f32_to_f64_scalar(out, row, mean);
}

/// Scalar oracle of [`center_f32_to_f64`].
pub(crate) fn center_f32_to_f64_scalar(out: &mut [f64], row: &[f32], mean: &[f64]) {
    for j in 0..out.len() {
        out[j] = row[j] as f64 - mean[j];
    }
}

/// AVX2 path of [`center_f32_to_f64`].
///
/// # Safety
/// SAFETY: the caller must have runtime-verified AVX2 support (the
/// [`active`] dispatch does) before calling.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn center_f32_to_f64_avx2(out: &mut [f64], row: &[f32], mean: &[f64]) {
    use std::arch::x86_64::*;
    let n = out.len();
    let whole = n / 4 * 4;
    // SAFETY: loads and stores touch `i .. i + 4` with `i + 4 <= whole
    // <= n`, and the dispatch wrapper asserts all three slices have
    // length `n`; `op` is the only live pointer into `out`.
    unsafe {
        let op = out.as_mut_ptr();
        let rp = row.as_ptr();
        let mp = mean.as_ptr();
        let mut i = 0;
        while i < whole {
            let r = _mm256_cvtps_pd(_mm_loadu_ps(rp.add(i)));
            let m = _mm256_loadu_pd(mp.add(i));
            _mm256_storeu_pd(op.add(i), _mm256_sub_pd(r, m));
            i += 4;
        }
        while i < n {
            out[i] = row[i] as f64 - mean[i];
            i += 1;
        }
    }
}

// ---------------------------------------------------------------------------
// Multi-accumulator column dots (one column per lane, chains sequential)
// ---------------------------------------------------------------------------

/// Four simultaneous column dots `aₖ = Σᵢ cₖ[i]·r[i]` in f64 — the
/// guarantee pass's projection GEMM inner tile.  One basis column per
/// lane: each column's `d`-long reduction is a single sequential f64
/// chain (the certified order), and the four chains advance in lockstep.
/// Bit-identical to four independent scalar dots.
pub fn dot4_cols(c0: &[f32], c1: &[f32], c2: &[f32], c3: &[f32], r: &[f32]) -> [f64; 4] {
    let d = r.len();
    assert!(c0.len() == d && c1.len() == d && c2.len() == d && c3.len() == d);
    #[cfg(target_arch = "x86_64")]
    if active() == Isa::Avx2 {
        // SAFETY: AVX2 support was runtime-verified by `active()`.
        return unsafe { dot4_cols_avx2(c0, c1, c2, c3, r) };
    }
    dot4_cols_scalar(c0, c1, c2, c3, r)
}

/// Scalar oracle of [`dot4_cols`] — four independent accumulators, as
/// the blocked GEMM ran before dispatch.
pub(crate) fn dot4_cols_scalar(
    c0: &[f32],
    c1: &[f32],
    c2: &[f32],
    c3: &[f32],
    r: &[f32],
) -> [f64; 4] {
    let (mut a0, mut a1, mut a2, mut a3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
    for i in 0..r.len() {
        let x = r[i] as f64;
        a0 += c0[i] as f64 * x;
        a1 += c1[i] as f64 * x;
        a2 += c2[i] as f64 * x;
        a3 += c3[i] as f64 * x;
    }
    [a0, a1, a2, a3]
}

/// AVX2 path of [`dot4_cols`].
///
/// # Safety
/// SAFETY: the caller must have runtime-verified AVX2 support (the
/// [`active`] dispatch does) before calling.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn dot4_cols_avx2(c0: &[f32], c1: &[f32], c2: &[f32], c3: &[f32], r: &[f32]) -> [f64; 4] {
    use std::arch::x86_64::*;
    // SAFETY: all element access is bounds-checked slice indexing; the
    // one raw-pointer store targets the local `[f64; 4]` result.
    unsafe {
        let mut acc = _mm256_setzero_pd();
        for i in 0..r.len() {
            // lane k holds column k's accumulator; the gather across the
            // four column arrays keeps each per-column chain sequential
            let cols = _mm256_cvtps_pd(_mm_set_ps(c3[i], c2[i], c1[i], c0[i]));
            let x = _mm256_set1_pd(r[i] as f64);
            acc = _mm256_add_pd(acc, _mm256_mul_pd(cols, x));
        }
        let mut out = [0.0f64; 4];
        _mm256_storeu_pd(out.as_mut_ptr(), acc);
        out
    }
}

/// One column dot `Σᵢ c[i]·r[i]` as a single sequential f64 chain.
/// Deliberately scalar on every ISA: this reduction's order is part of
/// the certified-bound contract and may not be lane-split.
pub fn dot_col(c: &[f32], r: &[f32]) -> f64 {
    debug_assert_eq!(c.len(), r.len());
    let mut a = 0.0f64;
    for i in 0..r.len() {
        a += c[i] as f64 * r[i] as f64;
    }
    a
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    fn fuzz(rng: &mut Prng, n: usize) -> Vec<f32> {
        (0..n).map(|_| (rng.normal() * 3.0) as f32).collect()
    }

    /// Every lane-unaligned length around the lane widths, so
    /// `len % lanes` covers every residue in {0, .., lanes-1}.
    fn lengths() -> Vec<usize> {
        let mut v: Vec<usize> = (0..=2 * LANES_F32 + 3).collect();
        v.extend([61, 64, 127, 128, 1000, 1003]);
        v
    }

    #[cfg(target_arch = "x86_64")]
    fn have_avx2() -> bool {
        // Miri interprets no vendor intrinsics — oracle comparisons only.
        cfg!(not(miri)) && std::arch::is_x86_feature_detected!("avx2")
    }

    #[test]
    fn sum_sq_diff_simd_is_bit_identical_to_scalar_oracle() {
        let mut rng = Prng::new(11);
        for n in lengths() {
            let a = fuzz(&mut rng, n);
            let b = fuzz(&mut rng, n);
            let want = sum_sq_diff_scalar(&a, &b);
            assert_eq!(sum_sq_diff(&a, &b).to_bits(), want.to_bits(), "len {n}");
            #[cfg(target_arch = "x86_64")]
            if have_avx2() {
                // SAFETY: AVX2 presence checked by `have_avx2()` above.
                let got = unsafe { sum_sq_diff_avx2(&a, &b) };
                assert_eq!(got.to_bits(), want.to_bits(), "avx2 len {n}");
            }
        }
    }

    #[test]
    fn minmax_simd_is_bit_identical_to_scalar_oracle() {
        let mut rng = Prng::new(13);
        for n in lengths() {
            let xs = fuzz(&mut rng, n);
            let want = minmax_scalar(&xs);
            let got = minmax(&xs);
            assert_eq!(got.0.to_bits(), want.0.to_bits(), "len {n} lo");
            assert_eq!(got.1.to_bits(), want.1.to_bits(), "len {n} hi");
            #[cfg(target_arch = "x86_64")]
            if have_avx2() {
                // SAFETY: AVX2 presence checked by `have_avx2()` above.
                let v = unsafe { minmax_avx2(&xs) };
                assert_eq!(v.0.to_bits(), want.0.to_bits(), "avx2 len {n} lo");
                assert_eq!(v.1.to_bits(), want.1.to_bits(), "avx2 len {n} hi");
            }
        }
    }

    #[test]
    fn minmax_matches_presimd_sequential_sweep_on_finite_data() {
        // min/max with the `v < lo` update rule is order-insensitive on
        // finite data without signed-zero mixes, so the fixed-lane order
        // must agree with the historical sequential sweep
        let mut rng = Prng::new(17);
        for n in lengths() {
            let xs = fuzz(&mut rng, n);
            let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
            for &v in &xs {
                if v < lo {
                    lo = v;
                }
                if v > hi {
                    hi = v;
                }
            }
            assert_eq!(minmax(&xs), (lo, hi), "len {n}");
        }
    }

    #[test]
    fn nan_and_inf_inputs_agree_across_paths() {
        let mut rng = Prng::new(19);
        for n in lengths() {
            let mut a = fuzz(&mut rng, n);
            let mut b = fuzz(&mut rng, n);
            // sprinkle NaN/±inf through both operands
            for k in 0..n {
                match k % 7 {
                    1 => a[k] = f32::NAN,
                    3 => a[k] = f32::INFINITY,
                    5 => b[k] = f32::NEG_INFINITY,
                    _ => {}
                }
            }
            let (wl, wh) = minmax_scalar(&a);
            let (gl, gh) = minmax(&a);
            assert_eq!(gl.to_bits(), wl.to_bits(), "len {n} lo");
            assert_eq!(gh.to_bits(), wh.to_bits(), "len {n} hi");
            // NaNs never enter the running bounds
            assert!(!gl.is_nan() && !gh.is_nan(), "len {n}");
            let want = sum_sq_diff_scalar(&a, &b);
            let got = sum_sq_diff(&a, &b);
            assert_eq!(got.to_bits(), want.to_bits(), "len {n} sq");
            if n > 1 {
                assert!(got.is_nan(), "len {n}: NaN must poison the sum");
            }
            #[cfg(target_arch = "x86_64")]
            if have_avx2() {
                // SAFETY: AVX2 presence checked by `have_avx2()` above.
                let v = unsafe { minmax_avx2(&a) };
                assert_eq!((v.0.to_bits(), v.1.to_bits()), (wl.to_bits(), wh.to_bits()));
                // SAFETY: same AVX2 check covers this call.
                let s = unsafe { sum_sq_diff_avx2(&a, &b) };
                assert_eq!(s.to_bits(), want.to_bits());
            }
        }
    }

    #[test]
    fn empty_slices_are_well_defined() {
        assert_eq!(sum_sq_diff(&[], &[]), 0.0);
        assert_eq!(minmax(&[]), (f32::INFINITY, f32::NEG_INFINITY));
        assert_eq!(dot_col(&[], &[]), 0.0);
        assert_eq!(dot4_cols(&[], &[], &[], &[], &[]), [0.0; 4]);
        let mut acc: [f64; 0] = [];
        axpy_f64(&mut acc, 2.0, &[]);
        let mut out: [f64; 0] = [];
        center_f32_to_f64(&mut out, &[], &[]);
    }

    #[test]
    fn axpy_and_center_simd_are_bit_identical_to_scalar_oracle() {
        let mut rng = Prng::new(23);
        for n in lengths() {
            let row = fuzz(&mut rng, n);
            let mean: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let x = rng.normal();

            let mut want = vec![0.0f64; n];
            center_f32_to_f64_scalar(&mut want, &row, &mean);
            let mut got = vec![0.0f64; n];
            center_f32_to_f64(&mut got, &row, &mean);
            assert_eq!(bits64(&got), bits64(&want), "center len {n}");

            let mut acc_want = want.clone();
            axpy_f64_scalar(&mut acc_want, x, &v);
            let mut acc_got = want.clone();
            axpy_f64(&mut acc_got, x, &v);
            assert_eq!(bits64(&acc_got), bits64(&acc_want), "axpy len {n}");

            #[cfg(target_arch = "x86_64")]
            if have_avx2() {
                let mut g = vec![0.0f64; n];
                // SAFETY: AVX2 presence checked by `have_avx2()` above.
                unsafe { center_f32_to_f64_avx2(&mut g, &row, &mean) };
                assert_eq!(bits64(&g), bits64(&want), "avx2 center len {n}");
                let mut ga = want.clone();
                // SAFETY: same AVX2 check covers this call.
                unsafe { axpy_f64_avx2(&mut ga, x, &v) };
                assert_eq!(bits64(&ga), bits64(&acc_want), "avx2 axpy len {n}");
            }
        }
    }

    #[test]
    fn dot4_simd_is_bit_identical_to_scalar_oracle() {
        let mut rng = Prng::new(29);
        for n in lengths() {
            let cols: Vec<Vec<f32>> = (0..4).map(|_| fuzz(&mut rng, n)).collect();
            let r = fuzz(&mut rng, n);
            let want = dot4_cols_scalar(&cols[0], &cols[1], &cols[2], &cols[3], &r);
            let got = dot4_cols(&cols[0], &cols[1], &cols[2], &cols[3], &r);
            for k in 0..4 {
                assert_eq!(got[k].to_bits(), want[k].to_bits(), "len {n} lane {k}");
                // the lane chain must equal the plain sequential dot too
                assert_eq!(
                    want[k].to_bits(),
                    dot_col(&cols[k], &r).to_bits(),
                    "len {n} lane {k} vs dot_col"
                );
            }
            #[cfg(target_arch = "x86_64")]
            if have_avx2() {
                // SAFETY: AVX2 presence checked by `have_avx2()` above.
                let v = unsafe { dot4_cols_avx2(&cols[0], &cols[1], &cols[2], &cols[3], &r) };
                for k in 0..4 {
                    assert_eq!(v[k].to_bits(), want[k].to_bits(), "avx2 len {n} lane {k}");
                }
            }
        }
    }

    #[test]
    fn active_is_stable_and_named() {
        let a = active();
        assert_eq!(a, active());
        assert!(a.name() == "avx2" || a.name() == "scalar");
    }

    fn bits64(v: &[f64]) -> Vec<u64> {
        v.iter().map(|x| x.to_bits()).collect()
    }
}
