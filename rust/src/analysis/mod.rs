//! Static analysis: the in-repo invariant linter behind `gbatc-verify`.
//!
//! The crate's guarantees lean on properties the compiler cannot see:
//! archive bytes must be bit-identical across thread counts and ISAs
//! (so no fused rounding or hash-ordered iteration where bytes are
//! produced), the serving request path must return typed errors rather
//! than panic, the epoll reactor thread must never block, and every
//! `unsafe` site must carry a reviewed `SAFETY` rationale.  This module
//! enforces those properties mechanically from a checked-in manifest
//! (`verify.toml`), in the same no-external-crates style as the HTTP,
//! epoll, and mmap stacks:
//!
//! * [`scanner`] — a minimal token/brace-aware Rust scanner: strips
//!   comments and string literals, tracks `#[cfg(test)]` regions with a
//!   three-valued cfg evaluator, and locates `unsafe` sites and their
//!   SAFETY comments.
//! * [`manifest`] — the hand-parsed `verify.toml` subset: unsafe
//!   inventory, lint scopes, and the per-line waiver list.
//! * [`lints`] — the four invariant lints plus manifest consistency
//!   checks (inventory drift, stale waivers).
//!
//! The `gbatc-verify` binary (CI's `verify` job) drives
//! [`verify_root`] and exits nonzero on any finding.

pub mod lints;
pub mod manifest;
pub mod scanner;

use std::path::{Path, PathBuf};

use crate::error::{Error, Result};

pub use lints::{Finding, Lint};
pub use manifest::Manifest;

/// One scanned source file: its path relative to the source root
/// (separators normalized to `/`) and its token/region model.
pub struct ScannedFile {
    pub rel: String,
    pub model: scanner::SourceModel,
}

/// The result of a full verification run.
pub struct Report {
    /// Violations after waivers, sorted by (file, line, lint).
    pub findings: Vec<Finding>,
    /// Number of `.rs` files scanned.
    pub files_scanned: usize,
    /// Total `unsafe` sites seen across the tree.
    pub unsafe_sites: usize,
}

/// Scan every `.rs` file under `src_root`, sorted by relative path.
pub fn scan_tree(src_root: &Path) -> Result<Vec<ScannedFile>> {
    let mut rel_paths: Vec<String> = Vec::new();
    collect_rs(src_root, src_root, &mut rel_paths)?;
    rel_paths.sort();
    let mut files = Vec::with_capacity(rel_paths.len());
    for rel in rel_paths {
        let abs = src_root.join(&rel);
        let src = std::fs::read_to_string(&abs)
            .map_err(|e| Error::io_ctx(format!("read {}", abs.display()), e))?;
        files.push(ScannedFile {
            rel,
            model: scanner::scan(&src),
        });
    }
    Ok(files)
}

fn collect_rs(root: &Path, dir: &Path, out: &mut Vec<String>) -> Result<()> {
    let entries = std::fs::read_dir(dir)
        .map_err(|e| Error::io_ctx(format!("read_dir {}", dir.display()), e))?;
    for entry in entries {
        let entry = entry.map_err(|e| Error::io_ctx("read_dir entry", e))?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(root, &path, out)?;
        } else if path.extension().and_then(|e| e.to_str()) == Some("rs") {
            if let Ok(rel) = path.strip_prefix(root) {
                let mut s = String::new();
                for comp in rel.components() {
                    if !s.is_empty() {
                        s.push('/');
                    }
                    s.push_str(&comp.as_os_str().to_string_lossy());
                }
                out.push(s);
            }
        }
    }
    Ok(())
}

/// Verify the tree rooted at `root` (the directory holding
/// `verify.toml`; the manifest's `source_root` is resolved against it).
pub fn verify_root(root: &Path) -> Result<Report> {
    let manifest_path = root.join("verify.toml");
    let text = std::fs::read_to_string(&manifest_path)
        .map_err(|e| Error::io_ctx(format!("read {}", manifest_path.display()), e))?;
    let m = manifest::parse(&text)?;
    let src_root = root.join(&m.source_root);
    let files = scan_tree(&src_root)?;
    let unsafe_sites = files
        .iter()
        .map(|f| scanner::unsafe_sites(&f.model).len())
        .sum();
    let findings = lints::run_lints(&files, &m);
    Ok(Report {
        findings,
        files_scanned: files.len(),
        unsafe_sites,
    })
}

/// Walk upward from `start` looking for a directory containing
/// `verify.toml` (so the binary works from any subdirectory).
pub fn find_root(start: &Path) -> Option<PathBuf> {
    let mut cur = Some(start);
    while let Some(dir) = cur {
        if dir.join("verify.toml").is_file() {
            return Some(dir.to_path_buf());
        }
        cur = dir.parent();
    }
    None
}
