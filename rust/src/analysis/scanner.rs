//! Minimal token/brace-aware Rust source scanner.
//!
//! This is deliberately **not** a Rust parser: `gbatc-verify` needs just
//! enough lexical structure to enforce the project invariants — exact
//! identifier tokens with line numbers (comments, string/char literals,
//! and lifetimes stripped), per-line comment text (for `SAFETY:`
//! proximity checks), and the line ranges gated behind `#[cfg(test)]`
//! (brace-matched over the token stream).  The same no-external-crates
//! ethos as the HTTP/epoll/mmap stacks: ~300 lines of `std`-only code
//! the repo fully owns, instead of a syn/proc-macro dependency the
//! offline image cannot build.

use std::collections::BTreeMap;

/// One lexed token: an identifier/keyword, a number, or a single
/// punctuation character.  String and char literal *contents* never
/// become tokens.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Token {
    /// The token text (one punctuation char, or a full identifier).
    pub text: String,
    /// 1-based source line the token starts on.
    pub line: usize,
}

/// Lexical model of one source file, produced by [`scan`].
pub struct SourceModel {
    /// Identifier/punctuation tokens in source order.
    pub tokens: Vec<Token>,
    /// 1-based line number → concatenated comment text on that line
    /// (line comments, and every line a block comment spans).
    pub comment_lines: BTreeMap<usize, String>,
    /// Raw source lines (index with `line - 1`).
    pub lines: Vec<String>,
    /// Inclusive 1-based line ranges compiled only under `cfg(test)`
    /// (or marked `#[test]`).  Ranges may nest/overlap.
    pub test_regions: Vec<(usize, usize)>,
}

impl SourceModel {
    /// True when `line` falls inside a test-gated region.
    pub fn in_test(&self, line: usize) -> bool {
        self.test_regions
            .iter()
            .any(|&(a, b)| a <= line && line <= b)
    }
}

/// One `unsafe` keyword occurrence.
#[derive(Clone, Debug)]
pub struct UnsafeSite {
    /// 1-based line of the `unsafe` token.
    pub line: usize,
    /// `"fn"`, `"impl"`, or `"block"`.
    pub kind: &'static str,
    /// A comment containing `SAFETY` sits on or adjacent to the site
    /// (see [`has_safety_comment`] for the exact proximity rule).
    pub has_safety: bool,
}

/// Lex `src` into a [`SourceModel`].
pub fn scan(src: &str) -> SourceModel {
    let lines: Vec<String> = src.lines().map(str::to_string).collect();
    let mut tokens: Vec<Token> = Vec::new();
    let mut comment_lines: BTreeMap<usize, String> = BTreeMap::new();
    let b = src.as_bytes();
    let n = b.len();
    let mut i = 0usize;
    let mut line = 1usize;

    let note = |map: &mut BTreeMap<usize, String>, line: usize, text: &str| {
        let slot = map.entry(line).or_default();
        slot.push_str(text);
        slot.push(' ');
    };

    while i < n {
        let c = b[i];
        if c == b'\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_ascii_whitespace() {
            i += 1;
            continue;
        }
        // line comment (also covers /// and //! doc comments)
        if c == b'/' && i + 1 < n && b[i + 1] == b'/' {
            let start = i;
            while i < n && b[i] != b'\n' {
                i += 1;
            }
            note(&mut comment_lines, line, &src[start..i]);
            continue;
        }
        // block comment, nested, recorded on every line it spans
        if c == b'/' && i + 1 < n && b[i + 1] == b'*' {
            let mut depth = 1usize;
            let mut seg = i;
            i += 2;
            while i < n && depth > 0 {
                if b[i] == b'\n' {
                    note(&mut comment_lines, line, &src[seg..i]);
                    line += 1;
                    seg = i + 1;
                    i += 1;
                } else if b[i] == b'/' && i + 1 < n && b[i + 1] == b'*' {
                    depth += 1;
                    i += 2;
                } else if b[i] == b'*' && i + 1 < n && b[i + 1] == b'/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            note(&mut comment_lines, line, &src[seg..i.min(n)]);
            continue;
        }
        // string-ish literals: "..", b"..", r".."/r#".."#, br#".."#
        if c == b'"' {
            i = skip_string(b, i, &mut line);
            continue;
        }
        if (c == b'r' || c == b'b' || c == b'c') && is_string_start(b, i) {
            i = skip_prefixed_string(b, i, &mut line);
            continue;
        }
        // char literal vs lifetime
        if c == b'\'' {
            if i + 1 < n && b[i + 1] == b'\\' {
                i += 2; // skip the backslash + escaped char
                while i < n && b[i] != b'\'' {
                    i += 1;
                }
                i += 1;
                continue;
            }
            if i + 2 < n && b[i + 2] == b'\'' {
                i += 3; // plain 'x'
                continue;
            }
            // lifetime: consume the quote, the ident lexes next round
            i += 1;
            continue;
        }
        // identifier / keyword
        if c.is_ascii_alphabetic() || c == b'_' {
            let start = i;
            while i < n && (b[i].is_ascii_alphanumeric() || b[i] == b'_') {
                i += 1;
            }
            tokens.push(Token {
                text: src[start..i].to_string(),
                line,
            });
            continue;
        }
        // number: consume so `1e3`/`0xFF` don't shed ident fragments;
        // `0..9` must stay `0` `.` `.` `9`, so a dot is only eaten when
        // a digit follows it
        if c.is_ascii_digit() {
            while i < n && (b[i].is_ascii_alphanumeric() || b[i] == b'_') {
                i += 1;
            }
            if i + 1 < n && b[i] == b'.' && b[i + 1].is_ascii_digit() {
                i += 1;
                while i < n && (b[i].is_ascii_alphanumeric() || b[i] == b'_') {
                    i += 1;
                }
            }
            continue;
        }
        // single punctuation character
        tokens.push(Token {
            text: (c as char).to_string(),
            line,
        });
        i += 1;
    }

    let test_regions = find_test_regions(&tokens);
    SourceModel {
        tokens,
        comment_lines,
        lines,
        test_regions,
    }
}

/// Does `b[i]` start a raw/byte/c string (`r"`, `r#"`, `b"`, `br#"`, …)?
fn is_string_start(b: &[u8], i: usize) -> bool {
    let mut j = i;
    // up to two prefix letters (b + r, c + r)
    for _ in 0..2 {
        if j < b.len() && (b[j] == b'r' || b[j] == b'b' || b[j] == b'c') {
            j += 1;
        }
    }
    while j < b.len() && b[j] == b'#' {
        j += 1;
    }
    j < b.len() && b[j] == b'"' && j > i
}

/// Skip a plain `"..."` string starting at `b[i] == '"'`; returns the
/// index just past the closing quote and advances `line` for embedded
/// newlines.
fn skip_string(b: &[u8], mut i: usize, line: &mut usize) -> usize {
    i += 1;
    while i < b.len() {
        match b[i] {
            b'\\' => {
                // a `\` line-continuation escapes the newline itself —
                // the skipped newline still counts toward line numbers
                if i + 1 < b.len() && b[i + 1] == b'\n' {
                    *line += 1;
                }
                i += 2;
            }
            b'"' => return i + 1,
            b'\n' => {
                *line += 1;
                i += 1;
            }
            _ => i += 1,
        }
    }
    i
}

/// Skip a prefixed string (`b".."`, `r".."`, `r#".."#`, `br#".."#`, a
/// byte char `b'x'`) starting at the prefix letter.
fn skip_prefixed_string(b: &[u8], mut i: usize, line: &mut usize) -> usize {
    let mut raw = false;
    while i < b.len() && (b[i] == b'r' || b[i] == b'b' || b[i] == b'c') {
        raw |= b[i] == b'r';
        i += 1;
    }
    let mut hashes = 0usize;
    while i < b.len() && b[i] == b'#' {
        hashes += 1;
        i += 1;
    }
    if i >= b.len() || b[i] != b'"' {
        return i; // not actually a string (e.g. `b'x'` handled elsewhere)
    }
    i += 1;
    while i < b.len() {
        match b[i] {
            b'\\' if !raw => {
                // count an escaped (line-continuation) newline
                if i + 1 < b.len() && b[i + 1] == b'\n' {
                    *line += 1;
                }
                i += 2;
            }
            b'\n' => {
                *line += 1;
                i += 1;
            }
            b'"' => {
                let mut j = i + 1;
                let mut h = 0usize;
                while j < b.len() && b[j] == b'#' && h < hashes {
                    h += 1;
                    j += 1;
                }
                if h == hashes {
                    return j;
                }
                i += 1;
            }
            _ => i += 1,
        }
    }
    i
}

/// Three-valued truth for `cfg` predicate evaluation under `test = false`.
#[derive(Clone, Copy, PartialEq, Eq)]
enum Tri {
    False,
    Unknown,
    True,
}

/// Evaluate a `cfg(...)` predicate token list with `test` bound to
/// false and every other flag unknown.  A region is test-only exactly
/// when the predicate is then *definitely* false.
fn cfg_pred(toks: &[&str], pos: &mut usize) -> Tri {
    // skip a leading '('
    if toks.get(*pos) == Some(&"(") {
        *pos += 1;
        let v = cfg_pred(toks, pos);
        if toks.get(*pos) == Some(&")") {
            *pos += 1;
        }
        return v;
    }
    let head = match toks.get(*pos) {
        Some(t) => *t,
        None => return Tri::Unknown,
    };
    *pos += 1;
    match head {
        "test" => Tri::False,
        "all" | "any" | "not" => {
            let mut vals: Vec<Tri> = Vec::new();
            if toks.get(*pos) == Some(&"(") {
                *pos += 1;
                loop {
                    match toks.get(*pos) {
                        None | Some(&")") => {
                            *pos += 1;
                            break;
                        }
                        Some(&",") => *pos += 1,
                        _ => vals.push(cfg_pred(toks, pos)),
                    }
                }
            }
            match head {
                "all" => {
                    if vals.contains(&Tri::False) {
                        Tri::False
                    } else if vals.contains(&Tri::Unknown) {
                        Tri::Unknown
                    } else {
                        Tri::True
                    }
                }
                "any" => {
                    if vals.contains(&Tri::True) {
                        Tri::True
                    } else if vals.contains(&Tri::Unknown) {
                        Tri::Unknown
                    } else {
                        Tri::False
                    }
                }
                _ => match vals.first() {
                    Some(Tri::False) => Tri::True,
                    Some(Tri::True) => Tri::False,
                    _ => Tri::Unknown,
                },
            }
        }
        _ => {
            // `ident`, `ident = "literal"` (the literal was stripped by
            // the lexer), or `ident(...)`: value unknown — consume an
            // optional `=`, or a parenthesized argument list
            if toks.get(*pos) == Some(&"=") {
                *pos += 1;
            } else if toks.get(*pos) == Some(&"(") {
                let mut depth = 0usize;
                while let Some(t) = toks.get(*pos) {
                    match *t {
                        "(" => depth += 1,
                        ")" => {
                            depth -= 1;
                            *pos += 1;
                            if depth == 0 {
                                break;
                            }
                            continue;
                        }
                        _ => {}
                    }
                    *pos += 1;
                }
            }
            Tri::Unknown
        }
    }
}

/// Is this attribute token list (the tokens between `#[` and `]`) a
/// test gate — `#[test]`, or `#[cfg(...)]` whose predicate is false
/// without `cfg(test)`?
fn is_test_attr(attr: &[&str]) -> bool {
    match attr.first() {
        Some(&"test") if attr.len() == 1 => true,
        Some(&"cfg") => {
            let mut pos = 1;
            cfg_pred(attr, &mut pos) == Tri::False
        }
        _ => false,
    }
}

/// Brace-match the item following each test-gating attribute into an
/// inclusive line range.
fn find_test_regions(tokens: &[Token]) -> Vec<(usize, usize)> {
    let mut regions = Vec::new();
    let len = tokens.len();
    let mut i = 0usize;
    while i < len {
        if tokens[i].text != "#" || i + 1 >= len || tokens[i + 1].text != "[" {
            i += 1;
            continue;
        }
        let attr_line = tokens[i].line;
        // collect the attribute's tokens up to the matching ]
        let mut j = i + 2;
        let mut depth = 1usize;
        let mut attr: Vec<&str> = Vec::new();
        while j < len && depth > 0 {
            match tokens[j].text.as_str() {
                "[" => depth += 1,
                "]" => depth -= 1,
                t => attr.push(t),
            }
            j += 1;
        }
        if !is_test_attr(&attr) {
            i = j;
            continue;
        }
        // skip any further attributes stacked on the same item
        let mut k = j;
        while k + 1 < len && tokens[k].text == "#" && tokens[k + 1].text == "[" {
            let mut d = 1usize;
            k += 2;
            while k < len && d > 0 {
                match tokens[k].text.as_str() {
                    "[" => d += 1,
                    "]" => d -= 1,
                    _ => {}
                }
                k += 1;
            }
        }
        // region ends at the item's `;` (brace-less item) or at the
        // close of its first brace-matched block
        let mut end_line = tokens[j.min(len - 1)].line;
        while k < len {
            match tokens[k].text.as_str() {
                ";" => {
                    end_line = tokens[k].line;
                    break;
                }
                "{" => {
                    let mut d = 1usize;
                    let mut m = k + 1;
                    while m < len && d > 0 {
                        match tokens[m].text.as_str() {
                            "{" => d += 1,
                            "}" => d -= 1,
                            _ => {}
                        }
                        m += 1;
                    }
                    end_line = tokens[m.saturating_sub(1)].line;
                    break;
                }
                _ => k += 1,
            }
        }
        regions.push((attr_line, end_line));
        i = j;
    }
    regions
}

/// All `unsafe` keyword occurrences with their SAFETY-comment status.
pub fn unsafe_sites(model: &SourceModel) -> Vec<UnsafeSite> {
    let mut out = Vec::new();
    for (idx, tok) in model.tokens.iter().enumerate() {
        if tok.text != "unsafe" {
            continue;
        }
        let kind = match model.tokens.get(idx + 1).map(|t| t.text.as_str()) {
            Some("fn") => "fn",
            Some("impl") => "impl",
            _ => "block",
        };
        out.push(UnsafeSite {
            line: tok.line,
            kind,
            has_safety: has_safety_comment(model, tok.line),
        });
    }
    out
}

/// The SAFETY proximity rule: a comment containing `SAFETY` on the
/// site's own line, on the first line inside the block, or in the
/// comment/attribute run directly above (at most two interleaved code
/// lines tolerated, so multi-line statements and `unsafe impl` pairs
/// sharing one argument still associate).
pub fn has_safety_comment(model: &SourceModel, line: usize) -> bool {
    let has = |l: usize| {
        model
            .comment_lines
            .get(&l)
            .is_some_and(|t| t.contains("SAFETY"))
    };
    if has(line) || has(line + 1) {
        return true;
    }
    let mut code_skips = 0usize;
    let mut l = line;
    while l > 1 {
        l -= 1;
        if has(l) {
            return true;
        }
        let raw = model.lines.get(l - 1).map(String::as_str).unwrap_or("");
        let t = raw.trim();
        let skippable = t.is_empty()
            || t.starts_with("#[")
            || t.starts_with("#![")
            || model.comment_lines.contains_key(&l);
        if !skippable {
            code_skips += 1;
            if code_skips > 2 {
                return false;
            }
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        scan(src)
            .tokens
            .into_iter()
            .filter(|t| t.text.chars().next().is_some_and(|c| c.is_alphabetic() || c == '_'))
            .map(|t| t.text)
            .collect()
    }

    #[test]
    fn strings_comments_and_lifetimes_do_not_tokenize() {
        let src = r##"
// unwrap in a comment
/* block unsafe comment /* nested */ still */
fn f<'a>(x: &'a str) -> String {
    let s = "unsafe unwrap() mul_add";
    let r = r#"HashMap "quoted" inside"#;
    let c = 'u';
    let esc = '\'';
    let b = b"unwrap";
    format!("{s}{r}{c}{esc}{}", b.len())
}
"##;
        let ids = idents(src);
        assert!(!ids.contains(&"unwrap".to_string()), "{ids:?}");
        assert!(!ids.contains(&"unsafe".to_string()));
        assert!(!ids.contains(&"HashMap".to_string()));
        assert!(!ids.contains(&"mul_add".to_string()));
        assert!(ids.contains(&"format".to_string()));
        assert!(ids.contains(&"len".to_string()));
        // the lifetime's ident is lexed but 'u' the char is not
        assert!(ids.iter().any(|s| s == "a"));
    }

    #[test]
    fn line_numbers_survive_multiline_strings_and_comments() {
        let src = "let a = \"x\ny\nz\";\n/* c\nc2 */\nlet marker = 1;\n";
        let m = scan(src);
        let tok = m
            .tokens
            .iter()
            .find(|t| t.text == "marker")
            .expect("marker token");
        assert_eq!(tok.line, 6);
        assert!(m.comment_lines.contains_key(&4) && m.comment_lines.contains_key(&5));
    }

    #[test]
    fn backslash_line_continuation_in_strings_counts_its_newline() {
        let src = "let a = \"first \\\n    second\";\nlet marker = 1;\n";
        let m = scan(src);
        let tok = m
            .tokens
            .iter()
            .find(|t| t.text == "marker")
            .expect("marker token");
        assert_eq!(tok.line, 3);
    }

    #[test]
    fn test_regions_cover_cfg_test_mods_and_attr_combos() {
        let src = "\
fn live() {}
#[cfg(test)]
mod tests {
    fn helper() {}
}
#[cfg(all(test, unix, not(miri)))]
mod more {
    fn t() {}
}
#[cfg(not(test))]
fn also_live() {}
#[cfg(test)]
use std::fmt;
#[test]
fn standalone() {}
";
        let m = scan(src);
        assert!(!m.in_test(1), "free fn is live");
        assert!(m.in_test(3) && m.in_test(4) && m.in_test(5), "cfg(test) mod");
        assert!(m.in_test(7) && m.in_test(9), "cfg(all(test, ...)) mod");
        assert!(!m.in_test(11), "cfg(not(test)) is live code");
        assert!(m.in_test(13), "cfg(test) use item");
        assert!(m.in_test(15), "#[test] fn");
    }

    #[test]
    fn cfg_miri_alone_is_not_a_test_region() {
        let m = scan("#[cfg(miri)]\nfn miri_only() {}\n");
        assert!(!m.in_test(2));
    }

    #[test]
    fn unsafe_sites_classify_and_find_safety_comments() {
        let src = "\
// SAFETY: above the impl.
unsafe impl Send for X {}
unsafe impl Sync for X {}
fn f(p: *const u8) -> u8 {
    // SAFETY: p is valid.
    unsafe { *p }
}
fn g(p: *const u8) -> u8 {
    unsafe { *p }
}
";
        let m = scan(src);
        let sites = unsafe_sites(&m);
        assert_eq!(sites.len(), 4);
        assert_eq!(sites[0].kind, "impl");
        assert!(sites[0].has_safety);
        // the Sync impl rides the Send impl's comment (≤2 code lines)
        assert!(sites[1].has_safety);
        assert_eq!(sites[2].kind, "block");
        assert!(sites[2].has_safety);
        assert!(!sites[3].has_safety, "bare block must fail the audit");
    }

    #[test]
    fn safety_comment_through_attributes_and_multiline_statements() {
        let src = "\
/// SAFETY: doc-comment form, attribute in between.
#[allow(clippy::mut_from_ref)]
pub unsafe fn slice() {}
fn h() {
    // SAFETY: multi-line let binding.
    let _x =
        unsafe { core::ptr::null::<u8>() };
}
";
        let m = scan(src);
        let sites = unsafe_sites(&m);
        assert_eq!(sites.len(), 2);
        assert!(sites[0].has_safety && sites[0].kind == "fn");
        assert!(sites[1].has_safety && sites[1].kind == "block");
    }
}
