//! The invariant lints `gbatc-verify` enforces over the scanned tree.
//!
//! Four source lints plus the manifest consistency checks:
//!
//! 1. **unsafe audit** — every `unsafe` occurrence carries a `SAFETY`
//!    comment, and the per-file site counts match the committed
//!    `[unsafe_inventory]` exactly, so growing the unsafe surface
//!    always shows up as a reviewable manifest diff.  Not waivable.
//! 2. **determinism** — in the archive-byte-producing modules, forbid
//!    `mul_add`/FMA intrinsics (fused rounding breaks the bit-identity
//!    contract), `HashMap`/`HashSet` (iteration order), and `std::simd`
//!    (all vectorization goes through `gbatc::simd`'s fixed-lane
//!    kernels — the lane order *is* the canonical reduction order).
//! 3. **panic freedom** — no `unwrap`/`expect` calls or `panic!`-family
//!    macros in request-path modules outside `#[cfg(test)]`.
//! 4. **reactor blocking** — no filesystem handles or sleeps in the
//!    event-loop files; cold work must be offloaded to the worker pool.
//!
//! Lints 2–4 accept per-line waivers (`[waivers]` in `verify.toml`,
//! keyed `"lint:file:line"`), each requiring a non-empty justification;
//! a waiver that matches no finding is itself a finding, so the list
//! can only shrink or be consciously re-justified.

use std::collections::BTreeSet;
use std::fmt;

use super::manifest::Manifest;
use super::scanner;
use super::ScannedFile;

/// Which lint produced a finding.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Lint {
    /// Missing SAFETY comment (inventory drift reports as `Manifest`).
    UnsafeAudit,
    /// FMA / map-iteration / ad-hoc SIMD in archive-byte-producing code.
    Determinism,
    /// `unwrap`/`expect`/`panic!` on the request path.
    PanicFreedom,
    /// Blocking I/O in the event-loop files.
    Blocking,
    /// Manifest drift: stale inventory entries or stale waivers.
    Manifest,
}

impl Lint {
    /// Stable name — used in waiver keys and in output.
    pub fn name(self) -> &'static str {
        match self {
            Lint::UnsafeAudit => "unsafe_audit",
            Lint::Determinism => "determinism",
            Lint::PanicFreedom => "panic_freedom",
            Lint::Blocking => "blocking",
            Lint::Manifest => "manifest",
        }
    }
}

/// One verified violation.
#[derive(Clone, Debug)]
pub struct Finding {
    pub lint: Lint,
    /// Path relative to the scanned source root (or a waiver key for
    /// manifest findings about waivers).
    pub file: String,
    /// 1-based line, 0 when the finding is not line-anchored.
    pub line: usize,
    pub message: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}: [{}] {}",
            self.file,
            self.line,
            self.lint.name(),
            self.message
        )
    }
}

/// Run every lint over the scanned files and apply the manifest's
/// waivers.  Findings come back sorted by (file, line, lint).
pub fn run_lints(files: &[ScannedFile], m: &Manifest) -> Vec<Finding> {
    let mut raw: Vec<Finding> = Vec::new();
    for f in files {
        unsafe_audit(f, &mut raw);
        if in_scope(&f.rel, &m.determinism_modules) {
            determinism(f, &mut raw);
        }
        if in_scope(&f.rel, &m.panic_modules) {
            panic_freedom(f, &mut raw);
        }
        if m.blocking_files.iter().any(|b| b == &f.rel) {
            blocking(f, &mut raw);
        }
    }
    inventory(files, m, &mut raw);

    // waivers suppress line-anchored findings of the waivable lints
    let mut used: BTreeSet<String> = BTreeSet::new();
    let mut findings: Vec<Finding> = Vec::new();
    for fi in raw {
        let waivable = matches!(
            fi.lint,
            Lint::Determinism | Lint::PanicFreedom | Lint::Blocking
        );
        if waivable {
            let key = format!("{}:{}:{}", fi.lint.name(), fi.file, fi.line);
            if let Some(reason) = m.waivers.get(&key) {
                if !reason.trim().is_empty() {
                    used.insert(key);
                    continue;
                }
            }
        }
        findings.push(fi);
    }
    for key in m.waivers.keys() {
        if !used.contains(key) {
            findings.push(Finding {
                lint: Lint::Manifest,
                file: key.clone(),
                line: 0,
                message: format!(
                    "waiver `{key}` matches no finding (or lacks a justification) — \
                     remove it from [waivers]"
                ),
            });
        }
    }
    findings.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.lint).cmp(&(b.file.as_str(), b.line, b.lint))
    });
    findings
}

fn in_scope(rel: &str, prefixes: &[String]) -> bool {
    prefixes.iter().any(|p| rel.starts_with(p.as_str()))
}

/// Lint 1 (comment half): every `unsafe` site needs a SAFETY comment.
fn unsafe_audit(f: &ScannedFile, out: &mut Vec<Finding>) {
    for site in scanner::unsafe_sites(&f.model) {
        if !site.has_safety {
            out.push(Finding {
                lint: Lint::UnsafeAudit,
                file: f.rel.clone(),
                line: site.line,
                message: format!(
                    "`unsafe` {} without a SAFETY comment on or directly above the site",
                    site.kind
                ),
            });
        }
    }
}

/// Lint 1 (inventory half): per-file site counts must match the
/// manifest exactly, in both directions.
fn inventory(files: &[ScannedFile], m: &Manifest, out: &mut Vec<Finding>) {
    let mut seen: BTreeSet<&str> = BTreeSet::new();
    for f in files {
        seen.insert(f.rel.as_str());
        let sites = scanner::unsafe_sites(&f.model);
        let count = sites.len();
        match m.unsafe_inventory.get(&f.rel) {
            None if count > 0 => out.push(Finding {
                lint: Lint::Manifest,
                file: f.rel.clone(),
                line: sites[0].line,
                message: format!(
                    "{count} unsafe site(s) not in [unsafe_inventory] — new unsafe \
                     requires an explicit verify.toml diff"
                ),
            }),
            Some(&want) if want != count => out.push(Finding {
                lint: Lint::Manifest,
                file: f.rel.clone(),
                line: sites.first().map(|s| s.line).unwrap_or(0),
                message: format!(
                    "[unsafe_inventory] expects {want} unsafe site(s), the file has {count}"
                ),
            }),
            _ => {}
        }
    }
    for rel in m.unsafe_inventory.keys() {
        if !seen.contains(rel.as_str()) {
            out.push(Finding {
                lint: Lint::Manifest,
                file: rel.clone(),
                line: 0,
                message: "stale [unsafe_inventory] entry: no such source file".to_string(),
            });
        }
    }
}

/// Lint 2: fused rounding, unordered map iteration, and ad-hoc SIMD
/// are forbidden where archive bytes or certified bounds are produced.
fn determinism(f: &ScannedFile, out: &mut Vec<Finding>) {
    let toks = &f.model.tokens;
    for (i, t) in toks.iter().enumerate() {
        if f.model.in_test(t.line) {
            continue;
        }
        let id = t.text.as_str();
        let msg = if id == "mul_add" {
            Some("`mul_add` fuses the rounding step — archive-byte-producing code must \
                  keep separate IEEE mul/add (PR 6 lane invariant)")
        } else if id.contains("fmadd") || id == "fma" || id == "fmaf" {
            Some("FMA intrinsic — fused rounding breaks bit-identity across ISAs")
        } else if id == "HashMap" || id == "HashSet" {
            Some("hash-map iteration order is nondeterministic — use BTreeMap/BTreeSet \
                  or index by position")
        } else if id == "simd" && path_prefix_is(toks, i, &["std", "core"]) {
            Some("`std::simd` lane widths are ISA-shaped — vectorize through \
                  `gbatc::simd`'s fixed-lane kernels instead")
        } else {
            None
        };
        if let Some(msg) = msg {
            out.push(Finding {
                lint: Lint::Determinism,
                file: f.rel.clone(),
                line: t.line,
                message: msg.to_string(),
            });
        }
    }
}

/// Lint 3: the request path returns typed errors, it does not panic.
fn panic_freedom(f: &ScannedFile, out: &mut Vec<Finding>) {
    let toks = &f.model.tokens;
    for (i, t) in toks.iter().enumerate() {
        if f.model.in_test(t.line) {
            continue;
        }
        let id = t.text.as_str();
        let next = toks.get(i + 1).map(|n| n.text.as_str());
        let msg = if (id == "unwrap" || id == "expect") && next == Some("(") {
            Some(format!(
                "`.{id}()` on the request path — return a typed `Error` (or add a \
                 justified waiver)"
            ))
        } else if matches!(id, "panic" | "unreachable" | "todo" | "unimplemented")
            && next == Some("!")
        {
            Some(format!("`{id}!` on the request path — workers must never die"))
        } else {
            None
        };
        if let Some(message) = msg {
            out.push(Finding {
                lint: Lint::PanicFreedom,
                file: f.rel.clone(),
                line: t.line,
                message,
            });
        }
    }
}

/// Lint 4: nothing on the event loop may touch the filesystem or sleep.
fn blocking(f: &ScannedFile, out: &mut Vec<Finding>) {
    const BANNED: [&str; 6] = [
        "File",
        "OpenOptions",
        "read_to_string",
        "read_to_end",
        "canonicalize",
        "sleep",
    ];
    let toks = &f.model.tokens;
    for (i, t) in toks.iter().enumerate() {
        if f.model.in_test(t.line) {
            continue;
        }
        let id = t.text.as_str();
        let hit = if BANNED.contains(&id) {
            Some(format!("`{id}`"))
        } else if id == "fs" && path_prefix_is(toks, i, &["std"]) {
            Some("`std::fs`".to_string())
        } else {
            None
        };
        if let Some(what) = hit {
            out.push(Finding {
                lint: Lint::Blocking,
                file: f.rel.clone(),
                line: t.line,
                message: format!(
                    "{what} in an event-loop file — blocking work belongs on the \
                     decode worker pool"
                ),
            });
        }
    }
}

/// True when `toks[i]` is preceded by `<root> :: ` with `<root>` in
/// `roots` (used to spot `std::fs` / `std::simd` style paths).
fn path_prefix_is(toks: &[scanner::Token], i: usize, roots: &[&str]) -> bool {
    i >= 3
        && toks[i - 1].text == ":"
        && toks[i - 2].text == ":"
        && roots.contains(&toks[i - 3].text.as_str())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::manifest;

    fn file(rel: &str, src: &str) -> ScannedFile {
        ScannedFile {
            rel: rel.to_string(),
            model: scanner::scan(src),
        }
    }

    fn manifest_with(extra: &str) -> Manifest {
        let text = format!("[paths]\nsource_root = \"src\"\n{extra}");
        manifest::parse(&text).expect("test manifest parses")
    }

    #[test]
    fn panic_lint_respects_test_regions_and_scope() {
        let m = manifest_with("[panic_freedom]\nmodules = [\"serve/\"]\n");
        let src = "\
pub fn f(x: Option<u32>) -> u32 {
    x.unwrap()
}
#[cfg(test)]
mod tests {
    fn g(x: Option<u32>) -> u32 {
        x.unwrap()
    }
}
";
        let fs = vec![file("serve/a.rs", src), file("codec/b.rs", src)];
        let got = run_lints(&fs, &m);
        assert_eq!(got.len(), 1, "{got:?}");
        assert_eq!(got[0].lint, Lint::PanicFreedom);
        assert_eq!((got[0].file.as_str(), got[0].line), ("serve/a.rs", 2));
    }

    #[test]
    fn unwrap_or_else_is_not_unwrap() {
        let m = manifest_with("[panic_freedom]\nmodules = [\"serve/\"]\n");
        let src = "pub fn f(x: Option<u32>) -> u32 {\n    x.unwrap_or_else(|| 0)\n}\n";
        assert!(run_lints(&[file("serve/a.rs", src)], &m).is_empty());
    }

    #[test]
    fn determinism_lint_catches_fma_maps_and_std_simd() {
        let m = manifest_with("[determinism]\nmodules = [\"gae/\"]\n");
        let src = "\
use std::simd::f32x4;
pub fn f(a: f64, b: f64, c: f64) -> f64 {
    a.mul_add(b, c)
}
pub fn g(m: &std::collections::HashMap<u32, u32>) -> usize {
    m.len()
}
";
        let got = run_lints(&[file("gae/a.rs", src)], &m);
        assert_eq!(got.len(), 3, "{got:?}");
        assert!(got.iter().all(|f| f.lint == Lint::Determinism));
        // crate::simd is the sanctioned path and must not be flagged
        let ok = "use crate::simd::dot_col;\npub fn h() {}\n";
        assert!(run_lints(&[file("gae/b.rs", ok)], &m).is_empty());
    }

    #[test]
    fn blocking_lint_flags_fs_and_sleep_in_listed_files_only() {
        let m = manifest_with("[blocking]\nfiles = [\"serve/reactor.rs\"]\n");
        let src = "\
pub fn probe(p: &str) -> bool {
    std::fs::metadata(p).is_ok()
}
pub fn nap() {
    std::thread::sleep(std::time::Duration::from_millis(1));
}
";
        let got = run_lints(&[file("serve/reactor.rs", src)], &m);
        assert_eq!(got.len(), 2, "{got:?}");
        assert!(got.iter().all(|f| f.lint == Lint::Blocking));
        assert!(run_lints(&[file("serve/other.rs", src)], &m).is_empty());
    }

    #[test]
    fn unsafe_needs_safety_comment_and_inventory_entry() {
        let src = "\
pub fn f(p: *const u8) -> u8 {
    // SAFETY: p is valid for reads by contract.
    unsafe { *p }
}
";
        // correct inventory + comment: clean
        let m = manifest_with("[unsafe_inventory]\n\"util/a.rs\" = 1\n");
        assert!(run_lints(&[file("util/a.rs", src)], &m).is_empty());
        // missing inventory entry
        let m2 = manifest_with("");
        let got = run_lints(&[file("util/a.rs", src)], &m2);
        assert_eq!(got.len(), 1, "{got:?}");
        assert_eq!(got[0].lint, Lint::Manifest);
        // count drift
        let m3 = manifest_with("[unsafe_inventory]\n\"util/a.rs\" = 3\n");
        let got = run_lints(&[file("util/a.rs", src)], &m3);
        assert_eq!(got.len(), 1, "{got:?}");
        // stale entry for a file that does not exist
        let m4 = manifest_with("[unsafe_inventory]\n\"util/gone.rs\" = 1\n");
        let got = run_lints(&[file("util/a.rs", "pub fn f() {}\n")], &m4);
        assert_eq!(got.len(), 1, "{got:?}");
        assert!(got[0].message.contains("stale"));
    }

    #[test]
    fn waivers_suppress_and_stale_waivers_report() {
        let src = "pub fn f(x: Option<u32>) -> u32 {\n    x.unwrap()\n}\n";
        let m = manifest_with(
            "[panic_freedom]\nmodules = [\"serve/\"]\n[waivers]\n\
             \"panic_freedom:serve/a.rs:2\" = \"boot path, runs before accept\"\n",
        );
        assert!(run_lints(&[file("serve/a.rs", src)], &m).is_empty());
        // unmatched waiver is itself a finding
        let m2 = manifest_with(
            "[waivers]\n\"panic_freedom:serve/a.rs:99\" = \"nothing here\"\n",
        );
        let got = run_lints(&[file("serve/a.rs", "pub fn f() {}\n")], &m2);
        assert_eq!(got.len(), 1, "{got:?}");
        assert_eq!(got[0].lint, Lint::Manifest);
        // an empty justification does not waive
        let m3 = manifest_with(
            "[panic_freedom]\nmodules = [\"serve/\"]\n[waivers]\n\
             \"panic_freedom:serve/a.rs:2\" = \"\"\n",
        );
        let got = run_lints(&[file("serve/a.rs", src)], &m3);
        assert_eq!(got.len(), 2, "finding survives and the waiver reports: {got:?}");
    }
}
