//! Hand-parsed `verify.toml` — the checked-in invariant manifest.
//!
//! The format is a deliberately small TOML subset (sections, `key =
//! value` with integer, quoted-string, and single-line string-array
//! values, `#` comments), parsed line by line with no external crates.
//! Unknown sections or keys are hard errors: a typo in the manifest
//! must fail the build, not silently disable a lint.
//!
//! ```toml
//! [paths]
//! source_root = "rust/src"
//!
//! [unsafe_inventory]          # file → expected number of unsafe sites
//! "archive/mmap.rs" = 5
//!
//! [determinism]               # archive-byte-producing module prefixes
//! modules = ["gae/", "sz/"]
//!
//! [panic_freedom]             # request-path module prefixes
//! modules = ["serve/", "store/"]
//!
//! [blocking]                  # event-loop files (no blocking I/O)
//! files = ["serve/reactor.rs"]
//!
//! [waivers]                   # "lint:file:line" = "justification"
//! "panic_freedom:serve/server.rs:380" = "fallback accept loop"
//! ```

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// Parsed manifest.  Missing sections mean "empty" (no scope, no
/// inventory) — except `[paths] source_root`, which is required.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    /// Directory of source files to scan, relative to the manifest.
    pub source_root: String,
    /// Relative file path → expected count of `unsafe` tokens.
    pub unsafe_inventory: BTreeMap<String, usize>,
    /// Module prefixes under the determinism lint.
    pub determinism_modules: Vec<String>,
    /// Module prefixes under the panic-freedom lint.
    pub panic_modules: Vec<String>,
    /// Files under the reactor-blocking lint.
    pub blocking_files: Vec<String>,
    /// `"lint:file:line"` → justification.
    pub waivers: BTreeMap<String, String>,
}

/// Parse manifest text.  Errors carry the 1-based line number.
pub fn parse(text: &str) -> Result<Manifest> {
    let mut m = Manifest::default();
    let mut section = String::new();
    for (idx, raw) in text.lines().enumerate() {
        let ln = idx + 1;
        let line = strip_comment(raw);
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
            section = name.trim().to_string();
            match section.as_str() {
                "paths" | "unsafe_inventory" | "determinism" | "panic_freedom" | "blocking"
                | "waivers" => {}
                other => {
                    return Err(Error::config(format!(
                        "verify.toml:{ln}: unknown section [{other}]"
                    )))
                }
            }
            continue;
        }
        let (key, value) = split_assignment(line)
            .ok_or_else(|| Error::config(format!("verify.toml:{ln}: expected `key = value`")))?;
        let key = unquote(key.trim()).to_string();
        let value = value.trim();
        match (section.as_str(), key.as_str()) {
            ("paths", "source_root") => m.source_root = parse_string(value, ln)?,
            ("unsafe_inventory", _) => {
                let n: usize = value.parse().map_err(|_| {
                    Error::config(format!(
                        "verify.toml:{ln}: [unsafe_inventory] values are integers, got `{value}`"
                    ))
                })?;
                if m.unsafe_inventory.insert(key.clone(), n).is_some() {
                    return Err(Error::config(format!(
                        "verify.toml:{ln}: duplicate inventory entry `{key}`"
                    )));
                }
            }
            ("determinism", "modules") => m.determinism_modules = parse_string_array(value, ln)?,
            ("panic_freedom", "modules") => m.panic_modules = parse_string_array(value, ln)?,
            ("blocking", "files") => m.blocking_files = parse_string_array(value, ln)?,
            ("waivers", _) => {
                let reason = parse_string(value, ln)?;
                if m.waivers.insert(key.clone(), reason).is_some() {
                    return Err(Error::config(format!(
                        "verify.toml:{ln}: duplicate waiver `{key}`"
                    )));
                }
            }
            (s, k) => {
                return Err(Error::config(format!(
                    "verify.toml:{ln}: unknown key `{k}` in section [{s}]"
                )))
            }
        }
    }
    if m.source_root.is_empty() {
        return Err(Error::config(
            "verify.toml: missing [paths] source_root".to_string(),
        ));
    }
    Ok(m)
}

/// Drop a trailing `#` comment, respecting quoted strings.
fn strip_comment(line: &str) -> &str {
    let bytes = line.as_bytes();
    let mut in_str = false;
    let mut i = 0usize;
    while i < bytes.len() {
        match bytes[i] {
            b'\\' if in_str => i += 1,
            b'"' => in_str = !in_str,
            b'#' if !in_str => return &line[..i],
            _ => {}
        }
        i += 1;
    }
    line
}

/// Split on the first `=` outside quotes.
fn split_assignment(line: &str) -> Option<(&str, &str)> {
    let bytes = line.as_bytes();
    let mut in_str = false;
    let mut i = 0usize;
    while i < bytes.len() {
        match bytes[i] {
            b'\\' if in_str => i += 1,
            b'"' => in_str = !in_str,
            b'=' if !in_str => return Some((&line[..i], &line[i + 1..])),
            _ => {}
        }
        i += 1;
    }
    None
}

fn unquote(s: &str) -> &str {
    s.strip_prefix('"')
        .and_then(|t| t.strip_suffix('"'))
        .unwrap_or(s)
}

fn parse_string(value: &str, ln: usize) -> Result<String> {
    let v = value.trim();
    if v.len() >= 2 && v.starts_with('"') && v.ends_with('"') {
        Ok(v[1..v.len() - 1].to_string())
    } else {
        Err(Error::config(format!(
            "verify.toml:{ln}: expected a quoted string, got `{v}`"
        )))
    }
}

fn parse_string_array(value: &str, ln: usize) -> Result<Vec<String>> {
    let v = value.trim();
    let inner = v
        .strip_prefix('[')
        .and_then(|s| s.strip_suffix(']'))
        .ok_or_else(|| {
            Error::config(format!(
                "verify.toml:{ln}: expected a single-line [\"a\", \"b\"] array, got `{v}`"
            ))
        })?;
    let mut out = Vec::new();
    for part in inner.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        out.push(parse_string(part, ln)?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_all_sections() {
        let text = r#"
# header comment
[paths]
source_root = "rust/src"

[unsafe_inventory]
"archive/mmap.rs" = 5   # trailing comment
"simd/mod.rs" = 22

[determinism]
modules = ["gae/", "codec/"]

[panic_freedom]
modules = ["serve/"]

[blocking]
files = ["serve/reactor.rs"]

[waivers]
"blocking:serve/server.rs:380" = "fallback accept loop, not the reactor"
"#;
        let m = parse(text).expect("parses");
        assert_eq!(m.source_root, "rust/src");
        assert_eq!(m.unsafe_inventory.get("archive/mmap.rs"), Some(&5));
        assert_eq!(m.unsafe_inventory.get("simd/mod.rs"), Some(&22));
        assert_eq!(m.determinism_modules, vec!["gae/", "codec/"]);
        assert_eq!(m.panic_modules, vec!["serve/"]);
        assert_eq!(m.blocking_files, vec!["serve/reactor.rs"]);
        assert_eq!(
            m.waivers.get("blocking:serve/server.rs:380").map(String::as_str),
            Some("fallback accept loop, not the reactor")
        );
    }

    #[test]
    fn unknown_sections_and_keys_are_errors() {
        assert!(parse("[paths]\nsource_root = \"s\"\n[mystery]\n").is_err());
        assert!(parse("[paths]\nsource_root = \"s\"\nextra = \"x\"\n").is_err());
        assert!(parse("[determinism]\nbogus = [\"a\"]\n").is_err());
    }

    #[test]
    fn missing_source_root_is_an_error() {
        assert!(parse("[determinism]\nmodules = []\n").is_err());
    }

    #[test]
    fn bad_values_are_errors_with_line_numbers() {
        let e = parse("[paths]\nsource_root = \"s\"\n[unsafe_inventory]\n\"a.rs\" = lots\n")
            .expect_err("non-integer count");
        assert!(format!("{e}").contains(":4:"), "{e}");
        assert!(parse("[paths]\nsource_root = unquoted\n").is_err());
        assert!(parse("[paths]\nsource_root\n").is_err());
    }

    #[test]
    fn duplicate_entries_are_errors() {
        let text = "[paths]\nsource_root = \"s\"\n[unsafe_inventory]\n\"a.rs\" = 1\n\"a.rs\" = 2\n";
        assert!(parse(text).is_err());
    }

    #[test]
    fn hash_inside_quoted_value_is_not_a_comment() {
        let text = "[paths]\nsource_root = \"s\"\n[waivers]\n\"k:a.rs:1\" = \"issue #42\"\n";
        let m = parse(text).expect("parses");
        assert_eq!(m.waivers.get("k:a.rs:1").map(String::as_str), Some("issue #42"));
    }
}
