//! # GBATC — Guaranteed Block Autoencoder with Tensor Correction
//!
//! A production reproduction of *"Machine Learning Techniques for Data
//! Reduction of CFD Applications"* (Lee et al., 2024): error-bounded learned
//! compression of multi-species CFD fields.
//!
//! Three-layer architecture (see `DESIGN.md`):
//! * **L1/L2 (build time, python)** — a Pallas fused-matmul kernel and a JAX
//!   3D-conv autoencoder + tensor-correction network, trained once and
//!   AOT-lowered to HLO text in `artifacts/`.
//! * **L3 (this crate)** — the request-path coordinator: block partitioning,
//!   PJRT execution of the AOT artifacts, latent/coefficient entropy coding,
//!   the PCA residual guarantee (Algorithm 1), the SZ baseline, the QoI
//!   chemistry substrate, metrics, and the archive container.
//!
//! Python never runs on the compression/decompression path; after
//! `make artifacts` the `gbatc` binary is self-contained.

pub mod archive;
pub mod chem;
pub mod cli;
pub mod codec;
pub mod compressor;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod entropy;
pub mod error;
pub mod gae;
pub mod linalg;
pub mod metrics;
pub mod quant;
pub mod runtime;
pub mod sz;
pub mod util;

pub use error::{Error, Result};
