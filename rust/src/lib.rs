//! # GBATC — Guaranteed Block Autoencoder with Tensor Correction
//!
//! A production reproduction of *"Machine Learning Techniques for Data
//! Reduction of CFD Applications"* (Lee et al., 2024): error-bounded learned
//! compression of multi-species CFD fields, grown into a shard-streaming
//! service with random-access partial decode.  See `DESIGN.md` for the full
//! architecture document.
//!
//! ## Layers
//!
//! * **Build time (python)** — a Pallas fused-matmul kernel and a JAX 3D-conv
//!   autoencoder + tensor-correction network, trained once and AOT-lowered to
//!   HLO text in `artifacts/` (`python/compile/`).
//! * **Data layer** ([`data`]) — the `[T, S, Y, X]` field container, the
//!   `SDF1` interchange format, the paper's spatiotemporal block partitioner,
//!   and *time-window shard views* ([`data::shards`]): a field is processed
//!   as `ceil(T / kt_window)` independent shards so peak working memory is
//!   bounded by the shard extent, not the field.
//! * **Coordinator layer** ([`coordinator`]) — the shard engine
//!   ([`coordinator::engine::ShardEngine`]) owns the executor handle, the
//!   codec-stage registry, and the Algorithm-1 guarantee stage, and drives
//!   shards through bounded encode/decode pipelines with queue-depth
//!   backpressure; a work-stealing `par_for`/`par_try_for` covers the CPU
//!   stages.  Per (shard, species) section a rate–distortion planner
//!   ([`compressor::registry`]) can trial the registered codec stages —
//!   GBATC (shared-model trial), SZ, and a dense-plane fallback — and keep
//!   the smallest encoding that certifies the per-species NRMSE budget:
//!
//!   ```text
//!   shard ──normalize──►  AE encode ► latents ► AE decode (+TCN)   (shared trial)
//!            │                                        │
//!            ├─ per species: GBATC guarantee ─────────┤  candidate sections
//!            ├─ per species: SZ trial  ───────────────┤  (bytes + certified
//!            └─ per species: dense trial ─────────────┘   NRMSE each)
//!                                 │
//!                        plan_shard() — keep latent plane + min per species,
//!                        or drop it and go all self-contained; tags go into
//!                        the GBA2 v3 TOC (all-GBATC archives stay v2)
//!   ```
//! * **Execution runtime** ([`runtime`]) — encoder/decoder/TCN behind one
//!   [`runtime::ExecHandle`] service: the PJRT backend (AOT artifacts, `pjrt`
//!   feature) or the deterministic pure-Rust reference backend.  Algorithm 1
//!   certifies the same per-block ℓ2 bound against either, so the guarantees
//!   do not depend on the backend.
//! * **Archive layer** ([`archive`]) — the legacy single-shot `GBA1`
//!   container and the indexed `GBA2` container: a table of contents maps
//!   every (shard, species) payload to an absolute byte range plus its
//!   codec tag ([`archive::CodecTag`]), so
//!   [`coordinator::engine::ShardEngine::decompress_range`] reconstructs a
//!   time window × species subset while reading only the touched sections
//!   through an [`archive::SectionSource`] (in-memory, file, or counting)
//!   and dispatching each section's decode by tag.  `GBA1` archives remain
//!   readable (and writable) behind [`archive::AnyArchive`], and all-GBATC
//!   archives keep the pre-registry version-2 byte layout.
//! * **API facade** ([`api`]) — the supported way in and out:
//!
//!   ```text
//!   ingest   CompressorBuilder ──► CompressSession::push_timestep(&[f32])
//!              backend | codec        │  buffers ≤ 1 kt_window
//!              ErrorPolicy ───────────┤  per-species budgets → planner +
//!              (Uniform | PerSpecies) │  guarantee stage, certified per
//!                                     ▼  (shard, species)
//!            ShardEngine::shard_stage ──► Gba2StreamWriter (incremental:
//!            payloads stream out as shards finish; a CRC'd shard-
//!            completion journal in the reserved header region commits
//!            each shard after its bytes are flushed; header + TOC
//!            back-patched + fsync'd at finish() — byte-identical to
//!            one-shot.  A killed run resumes via resume_session_on:
//!            torn tail truncated, sealed bytes still identical)
//!
//!   egress   ArchiveReader::query(Query { time: t0..t1, species })
//!            └─ TOC walk, reads only touched sections, bit-identical
//!               to the same slice of a full decode
//!   ```
//! * **Recovery layer** ([`archive::repair`] + the salvage decode path) —
//!   `verify_archive` walks every section of a sealed archive or an
//!   unsealed `GBJL` stream (`gbatc inspect --verify`); `repair_archive`
//!   salvages the valid shard prefix of torn inputs and seals interrupted
//!   streams from their CRC-committed shards (`gbatc repair`);
//!   `compact_archives` merges the pieces of an interrupted-and-resumed
//!   run, dropping duplicate and orphaned shards (`gbatc compact`).
//! * **Serving layer** ([`store`] + [`serve`]) — the read side at scale:
//!   an [`store::ArchiveStore`] mounts many archives under named dataset
//!   keys and executes [`api::Query`]s through a sharded, byte-metered
//!   LRU cache of decoded (shard, species) planes (per-shard locking, no
//!   global mutex on the hot path; cached and uncached reads are
//!   bit-identical), and [`serve::QueryServer`] exposes it over a
//!   dependency-free `std::net` HTTP/1.1 stack — an epoll event loop on
//!   Linux (keep-alive, pipelining, fairness, admission control), a
//!   thread pool speaking the identical protocol elsewhere:
//!
//!   ```text
//!   keep-alive clients ──► epoll reactor (1 thread, nonblocking conns)
//!     (pipelined GETs)      │ HttpParser: incremental framing
//!                           │ admission: conn cap ► 503, byte-metered
//!                           │   read buffers, per-conn in-flight cap,
//!                           │   idle reap; round-robin readiness
//!                           ├── warm + small ──► answered inline
//!                           └── cold /query ──► bounded job queue
//!                                (503 on overflow)  ──► decode workers
//!                           ▼  in-order per-conn response queue
//!               QueryRouter ── consistent-hash ring (vnodes) ──►
//!                      │        dataset → home replica (affinity,
//!                      │        mount failover to ring sibling)
//!               ArchiveStore replica ── SectionCache (sharded LRU) ── miss?
//!                      │               hit: zero decode, zero IO   │
//!                      └── mounted GBA1/GBA2 archives ◄── decode one
//!                          (TOC parsed once, IO metered)   shard's planes
//!   ```
//!
//!   `serve::QueryClient` is the matching blocking keep-alive client
//!   (`gbatc serve` / `gbatc query` front both).  GBA2 archives opened
//!   from a path are
//!   mmap-backed ([`archive::MmapSource`], `FileSource` fallback), cache
//!   planes are `Arc<[f32]>` (a warm hit is a refcount bump, zero bytes
//!   copied), and shard decode workspaces are arena-reused across shards.
//!   Sections that fail to decode are quarantined, not fatal: queries
//!   touching them are served from best-effort salvage (retained PCA
//!   basis over the surviving coefficient prefix), flagged
//!   `degraded: true` with a loosened bound in `X-Gbatc-Meta` — never
//!   cached, so the warm path serves healthy bytes only — and strict
//!   clients (`X-Gbatc-Strict: 1`) get `503` instead.
//! * **SIMD kernels** ([`simd`]) — runtime-dispatched (AVX2 via
//!   `is_x86_feature_detected!`, scalar fallback/oracle, `GBATC_NO_SIMD`
//!   force-off) vectorized hot loops for the guarantee-pass GEMM, PCA
//!   covariance, and NRMSE/minmax sweeps; fixed-width lane accumulators
//!   with a sequential combine keep every reduction bit-identical at any
//!   lane width, so archive bytes and certified bounds never depend on
//!   the ISA.
//! * **Observability** ([`obs`]) — dependency-free instruments threaded
//!   through the hot paths: lock-free log-bucketed latency histograms
//!   (integer-only record path, ≤1.6% quantile error) for query
//!   latency, decode time, cache probes, and reactor queue-wait;
//!   per-request trace spans (u64 ID minted at parse, `X-Gbatc-Trace-Id`
//!   on every response) with phase timings landing in a bounded
//!   lock-sharded slow-query ring; and egress endpoints:
//!
//!   ```text
//!   request ──► span {parse, queue_wait, cache_probe, decode,
//!      │              salvage, serialize, write}
//!      │         │ histograms: serve (latency, queue-wait)
//!      │         │             store (decode, cache-probe)
//!      ▼         ▼
//!   GET /metrics      Prometheus text (cumulative buckets + sum/count)
//!   GET /trace/slow   N worst span trees, per-phase breakdown
//!   gbatc stats URL   renders both
//!   ```
//!
//!   The compression side reports on the same type:
//!   [`coordinator::StageClock`] records per-stage *distributions*
//!   (p50/p99/max, not just totals) into `CompressReport::stage_times`.
//! * **Static analysis** ([`analysis`]) — the in-repo invariant linter
//!   behind the `gbatc-verify` binary (CI's `verify` job): a minimal
//!   token/brace-aware scanner plus a hand-parsed `verify.toml`
//!   manifest enforce the unsafe audit (every `unsafe` site carries a
//!   `SAFETY` comment and appears in the committed inventory),
//!   determinism lints over the archive-byte-producing modules (no
//!   FMA, no hash-ordered iteration, no ad-hoc SIMD), panic freedom on
//!   the serving request path, and no blocking I/O in the reactor
//!   files.  Dynamic verification rides alongside: Miri runs the
//!   unsafe-adjacent unit tests (mmap falls back to `FileSource`, SIMD
//!   dispatch to the scalar oracle under Miri), and scheduled
//!   ASan/TSan legs cover the concurrency-heavy suites.
//! * **Compressor trait / CLI** — [`compressor::Compressor`] unifies
//!   GBA/GBATC/SZ as a thin adapter over [`api`] (`compress_bytes` stays
//!   as the one-call convenience); the `gbatc` binary routes `compress`
//!   through a session, `extract` through [`api::ArchiveReader`] (species
//!   by mechanism *name* or index), and adds `inspect` (TOC, codec tags,
//!   size breakdown).
//!
//! Python never runs on the compression/decompression path; after
//! `make artifacts` the `gbatc` binary is self-contained, and with the
//! default (reference) backend it needs no artifacts at all.

#![allow(clippy::needless_range_loop)]
#![deny(unsafe_op_in_unsafe_fn)]

pub mod analysis;
pub mod api;
pub mod archive;
pub mod chem;
pub mod cli;
pub mod codec;
pub mod compressor;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod entropy;
pub mod error;
pub mod gae;
pub mod linalg;
pub mod metrics;
pub mod obs;
pub mod quant;
pub mod runtime;
pub mod serve;
pub mod simd;
pub mod store;
pub mod sz;
pub mod util;

pub use error::{Error, Result};
