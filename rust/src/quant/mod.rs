//! Uniform scalar quantization (the paper's §II-A "discrete bins, each with
//! a bin size of d; all values within each bin represented by its central
//! value"), used for both AE latents and PCA coefficients before Huffman
//! coding.

pub mod uniform;

pub use uniform::UniformQuantizer;
